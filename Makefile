# Tier-1 verify, benchmarks and lint in one invocation each.
# All targets run from the repo root with PYTHONPATH=src.

PY        ?= python
PYTHONPATH := src

.PHONY: test bench bench-quick bench-cpals lint quickstart clean ratchet anchor

test:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m pytest -x -q

bench:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.run

bench-quick:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.run --quick

bench-compress:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_compress

bench-plan:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_plan

bench-ingest:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_ingest

bench-methods:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_methods

bench-api:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_api --json BENCH_api.json

bench-serve:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_serve --json BENCH_serve.json

# tracing overhead gates (disabled < 1%, enabled < 5%) — exits non-zero on
# a gate failure; the CI test job runs exactly this target.
bench-obs:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_obs --json BENCH_obs.json

# quick per-routine CP-ALS breakdown on the scaled paper tensors — covers
# every registered workspace impl (incl. linearized) x fused epilogue; the
# CI quick-bench job runs exactly this target.
bench-cpals:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.bench_cpals_routines --quick --json BENCH_cpals.json

# perf ratchet: latest BENCH_history record vs the last anchor (>10% time
# regression fails).  `make anchor` promotes the latest records to the new
# accepted floor after a deliberate perf change lands.
ratchet:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.ratchet

anchor:
	PYTHONPATH=$(PYTHONPATH) $(PY) -m benchmarks.ratchet --anchor

# no third-party linter is baked into the image; byte-compile every tree
# (syntax + tabs/indentation errors) and import the package graph.
lint:
	$(PY) -m compileall -q src tests benchmarks examples
	PYTHONPATH=$(PYTHONPATH) $(PY) -c "import repro.api, repro.api.cli, repro.core, repro.dist, repro.ingest, repro.plan, repro.serve, repro.methods, repro.kernels, repro.launch.mesh, repro.launch.steps, repro.models, repro.obs, repro.obs.report, repro.obs.exposition, repro.obs.recorder, repro.obs.aggregate, repro.optim, repro.checkpoint, repro.data, repro.utils.roofline, repro.configs"

quickstart:
	PYTHONPATH=$(PYTHONPATH) $(PY) examples/quickstart.py

# remove generated artifacts: bytecode caches (src/tests/benchmarks/examples),
# benchmark JSONs, and the pytest cache.  The ingest/dataset cache under
# .cache/ is intentionally kept (delete it explicitly to force cold runs).
clean:
	find src tests benchmarks examples -type d -name __pycache__ -prune -exec rm -rf {} +
	rm -rf .pytest_cache
	rm -f BENCH_*.json
