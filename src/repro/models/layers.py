"""Shared transformer layers: norms, RoPE (std + M-RoPE), GQA attention
(train / prefill / decode with full, local-window and cross variants), MLPs.

Everything is a pure function over explicit param dicts; specs built by
``*_specs`` functions carry the logical sharding axes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec

Array = jax.Array
NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# activation sharding hook (MaxText-style logical constraints)
# ---------------------------------------------------------------------------
# The launch layer installs a callback mapping (array, logical_axes) ->
# with_sharding_constraint'ed array.  Without it (unit tests, single device)
# constraints are no-ops.  Constraining activations at layer boundaries is
# what keeps the SPMD partitioner from replicating attention/MLP internals.

_SHARDING_HOOK = None
_MESH = None  # set together with the hook; enables shard_map layers (EP MoE)


def set_sharding_hook(fn, mesh=None) -> None:
    global _SHARDING_HOOK, _MESH
    _SHARDING_HOOK = fn
    _MESH = mesh


def get_mesh():
    return _MESH


def shard_act(x: Array, axes: tuple) -> Array:
    if _SHARDING_HOOK is None:
        return x
    return _SHARDING_HOOK(x, axes)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def norm_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    if cfg.norm == "layernorm":
        return {"w": ParamSpec((d,), ("norm",), "ones"),
                "b": ParamSpec((d,), ("norm",), "zeros")}
    return {"w": ParamSpec((d,), ("norm",), "ones")}


def apply_norm(p: dict, cfg: ModelConfig, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["w"] + p["b"]
    else:
        ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["w"]
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def _rope_rotate(x: Array, sin: Array, cos: Array) -> Array:
    """x: (..., hd) with interleaved halves [x1 | x2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def rope_sincos(positions: Array, head_dim: int, theta: float):
    """positions (B, S) -> sin/cos (B, S, hd/2), f32."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B,S,half)
    return jnp.sin(ang), jnp.cos(ang)


def mrope_sincos(positions: Array, head_dim: int, theta: float, sections):
    """M-RoPE (Qwen2-VL): positions (3, B, S) for (t, h, w); the half-dim is
    split into ``sections`` (sums to hd/2), each section using its own
    position stream."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    # (3, B, S, half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    parts = []
    start = 0
    for i, sec in enumerate(sections):
        parts.append(ang[i, ..., start : start + sec])
        start += sec
    ang_sel = jnp.concatenate(parts, axis=-1)  # (B, S, half)
    return jnp.sin(ang_sel), jnp.cos(ang_sel)


def apply_rope(cfg: ModelConfig, q: Array, k: Array, positions: Array):
    """q (B,S,H,hd), k (B,S,KV,hd); positions (B,S) or (3,B,S) for mrope."""
    if cfg.rope == "none":
        return q, k
    if cfg.rope == "mrope":
        sin, cos = mrope_sincos(positions, cfg.head_dim, cfg.rope_theta,
                                cfg.mrope_sections)
    else:
        sin, cos = rope_sincos(positions, cfg.head_dim, cfg.rope_theta)
    sin, cos = sin[:, :, None, :], cos[:, :, None, :]
    qf, kf = q.astype(jnp.float32), k.astype(jnp.float32)
    return (_rope_rotate(qf, sin, cos).astype(q.dtype),
            _rope_rotate(kf, sin, cos).astype(k.dtype))


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def attn_specs(cfg: ModelConfig) -> dict:
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }


def _sdpa(cfg: ModelConfig, q: Array, k: Array, v: Array, mask: Array) -> Array:
    """q (B,S,H,hd); k,v (B,T,KV,hd); mask broadcastable to (B,1,1,S,T)."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = q.reshape(b, s, kvh, g, hd)
    scale = hd ** -0.5
    scores = jnp.einsum("bsngd,btnd->bngst", qg, k) * scale
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bngst,btnd->bsngd", probs, v)
    return out.reshape(b, s, h, hd)


def _train_mask(kind: str, s: int, window: int, dtype=bool) -> Array:
    """(S, S) mask: causal / bidir / local(causal+window)."""
    i = jnp.arange(s)[:, None]
    j = jnp.arange(s)[None, :]
    if kind == "bidir":
        return jnp.ones((s, s), dtype=bool)
    m = j <= i
    if kind == "local":
        m = jnp.logical_and(m, j > i - window)
    return m


# Blockwise (flash-style) attention: never materializes the (S, T) score
# matrix — running max/sum over KV blocks, vmapped over independent Q blocks.
# This is what makes the 32k prefill cells lowerable at sane memory; on a
# real TPU it is also the right compute structure (VMEM-resident tiles).
FLASH_MIN_SEQ = 4096
FLASH_QB = 1024
FLASH_KB = 1024


def _flash_attention(cfg: ModelConfig, q: Array, k: Array, v: Array,
                     mask_kind: str, *, qb: int = FLASH_QB,
                     kb: int = FLASH_KB,
                     block_skip: bool = False) -> Array:
    """q (B,S,H,hd); k,v (B,T,KV,hd) -> (B,S,H,hd).

    ``block_skip``: skip KV blocks that are fully masked (strictly-future
    causal blocks / outside the local window) — halves causal-prefill
    compute; a beyond-paper optimization toggled by the perf pass."""
    b, s, h, hd = q.shape
    t, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    # enough q blocks that they can shard over the model axis when the head
    # count cannot (context-parallel attention: rules override flash_q)
    qb = min(qb, max(128, s // 16))
    kb = min(kb, t)
    nq, nk = s // qb, t // kb
    scale = hd ** -0.5
    qr = shard_act(q.reshape(b, nq, qb, h, hd),
                   ("act_batch", "flash_q", None, "heads", None))

    def one_q(qi, qblk):
        def inner(carry, ki):
            m, l, acc = carry

            def compute(args):
                m, l, acc = args
                kblk = jax.lax.dynamic_slice(
                    k, (0, ki * kb, 0, 0), (b, kb, kvh, hd))
                vblk = jax.lax.dynamic_slice(
                    v, (0, ki * kb, 0, 0), (b, kb, kvh, hd))
                kblk = shard_act(jnp.repeat(kblk, g, axis=2),
                                 ("act_batch", None, "heads", None))
                vblk = shard_act(jnp.repeat(vblk, g, axis=2),
                                 ("act_batch", None, "heads", None))
                sc = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk).astype(
                    jnp.float32) * scale
                qpos = qi * qb + jnp.arange(qb)
                kpos = ki * kb + jnp.arange(kb)
                if mask_kind == "bidir":
                    msk = jnp.ones((qb, kb), dtype=bool)
                else:
                    msk = kpos[None, :] <= qpos[:, None]
                    if mask_kind == "local":
                        msk = jnp.logical_and(
                            msk, kpos[None, :] > qpos[:, None] - cfg.window)
                sc = jnp.where(msk[None, None], sc, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
                p = jnp.exp(sc - m_new[..., None])
                corr = jnp.exp(m - m_new)
                l_new = l * corr + jnp.sum(p, axis=-1)
                acc_new = acc * corr[..., None] + jnp.einsum(
                    "bhqk,bkhd->bhqd", p.astype(vblk.dtype), vblk).astype(
                        jnp.float32)
                return m_new, l_new, acc_new

            if block_skip and mask_kind in ("causal", "local"):
                # fully-masked block iff first kpos > last qpos (causal) or
                # last kpos <= first qpos - window (local)
                first_k = ki * kb
                last_q = qi * qb + qb - 1
                dead = first_k > last_q
                if mask_kind == "local":
                    dead = jnp.logical_or(
                        dead, (ki * kb + kb - 1) <= qi * qb - cfg.window)
                m, l, acc = jax.lax.cond(dead, lambda a: a, compute,
                                         (m, l, acc))
            else:
                m, l, acc = compute((m, l, acc))
            return (m, l, acc), None

        m0 = jnp.full((b, h, qb), -jnp.inf, dtype=jnp.float32)
        l0 = jnp.zeros((b, h, qb), dtype=jnp.float32)
        a0 = jnp.zeros((b, h, qb, hd), dtype=jnp.float32)
        (m, l, acc), _ = jax.lax.scan(inner, (m0, l0, a0), jnp.arange(nk),
                                      unroll=True if cfg.unroll_loops else 1)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        # downcast INSIDE the block: everything crossing the sharding
        # boundary (and its cotangent in the backward pass) stays bf16 —
        # keeping this f32 doubled the boundary all-reduce wire bytes
        return jnp.transpose(out, (0, 2, 1, 3)).astype(q.dtype)  # (B,qb,H,hd)

    out = jax.vmap(one_q, in_axes=(0, 1), out_axes=1)(jnp.arange(nq), qr)
    out = shard_act(out, ("act_batch", "flash_q", None, "heads", None))
    return out.reshape(b, s, h, hd)


def attention(
    p: dict,
    cfg: ModelConfig,
    x: Array,
    *,
    mask_kind: str,                      # causal | bidir | local
    positions: Optional[Array] = None,   # (B,S) or (3,B,S)
    memory: Optional[Array] = None,      # encoder output for cross-attn
    cache: Optional[dict] = None,        # decode cache for this layer
    pos: Optional[Array] = None,         # scalar decode position
):
    """Returns (out, new_cache). Modes:
      * train/prefill: full-sequence; new_cache returned iff cache is not
        None (prefill populates it);
      * decode: x is (B, 1, D), cache holds K/V (ring buffer when local).
    """
    b, s, d = x.shape
    q = shard_act(jnp.einsum("bsd,dhk->bshk", x, p["wq"]),
                  ("act_batch", None, "heads", None))
    if memory is not None:
        # cross-attention: K/V from encoder memory (cached after prefill)
        if cache is not None and "ck" in cache and s == 1:
            k, v = cache["ck"], cache["cv"]
            new_cache = cache
        else:
            k = jnp.einsum("btd,dnk->btnk", memory, p["wk"])
            v = jnp.einsum("btd,dnk->btnk", memory, p["wv"])
            new_cache = {"ck": k, "cv": v} if cache is not None else None
        mask = jnp.ones((1, 1, 1, s, k.shape[1]), dtype=bool)
        out = _sdpa(cfg, q, k, v, mask)
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache

    k = shard_act(jnp.einsum("bsd,dnk->bsnk", x, p["wk"]),
                  ("act_batch", None, "kv_heads", None))
    v = shard_act(jnp.einsum("bsd,dnk->bsnk", x, p["wv"]),
                  ("act_batch", None, "kv_heads", None))

    if cache is not None and s == 1 and "k" in cache:
        # ---- decode: single new token against the cache ----
        assert pos is not None
        q, k = apply_rope(cfg, q, k, _decode_positions(cfg, positions, pos, b))
        cap = cache["k"].shape[1]
        if mask_kind == "local":
            slot = pos % cap
        else:
            slot = pos
        ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                          (0, slot, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                          (0, slot, 0, 0))
        spos = cache["slot_pos"]
        spos = jax.lax.dynamic_update_slice(spos, pos[None].astype(spos.dtype), (slot,))
        valid = spos <= pos
        if mask_kind == "local":
            valid = jnp.logical_and(valid, spos > pos - cfg.window)
        mask = valid[None, None, None, None, :]
        out = _sdpa(cfg, q, ck, cv, mask)
        new_cache = {"k": ck, "v": cv, "slot_pos": spos}
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"]), new_cache

    # ---- train / prefill: full sequence ----
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    q, k = apply_rope(cfg, q, k, positions)
    if s >= FLASH_MIN_SEQ and s % FLASH_QB == 0:
        out = _flash_attention(cfg, q, k, v, mask_kind,
                               block_skip=getattr(cfg, "flash_block_skip", False))
    else:
        mask = _train_mask(mask_kind, s, cfg.window)[None, None, None, :, :]
        out = _sdpa(cfg, q, k, v, mask)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])

    new_cache = None
    if cache is not None:
        cap = cache["k"].shape[1]
        if cap >= s:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
            spos = jnp.where(jnp.arange(cap) < s, jnp.arange(cap),
                             cache["slot_pos"])
        else:  # local ring: keep the last `cap` tokens
            ck = k[:, s - cap:].astype(cache["k"].dtype)
            cv = v[:, s - cap:].astype(cache["v"].dtype)
            spos = jnp.arange(s - cap, s)
            # ring layout: slot = pos % cap
            roll = (s - cap) % cap
            ck = jnp.roll(ck, roll, axis=1)
            cv = jnp.roll(cv, roll, axis=1)
            spos = jnp.roll(spos, roll, axis=0)
        new_cache = {"k": ck, "v": cv, "slot_pos": spos.astype(jnp.int32)}
    return y, new_cache


def _decode_positions(cfg: ModelConfig, positions, pos, b):
    if positions is not None:
        return positions
    p = jnp.full((b, 1), pos, dtype=jnp.int32)
    if cfg.rope == "mrope":
        return jnp.broadcast_to(p[None], (3, b, 1))
    return p


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "wg": ParamSpec((d, f), ("embed", "mlp")),
            "wu": ParamSpec((d, f), ("embed", "mlp")),
            "wd": ParamSpec((f, d), ("mlp", "embed")),
        }
    return {
        "wu": ParamSpec((d, f), ("embed", "mlp")),
        "wd": ParamSpec((f, d), ("mlp", "embed")),
    }


def mlp(p: dict, cfg: ModelConfig, x: Array) -> Array:
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(x @ p["wg"]) * (x @ p["wu"])
    else:
        h = jax.nn.gelu(x @ p["wu"])
    h = shard_act(h, ("act_batch", None, "mlp"))
    return h @ p["wd"]


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def embed_specs(cfg: ModelConfig) -> dict:
    out = {"table": ParamSpec((cfg.vocab, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        out["head"] = ParamSpec((cfg.d_model, cfg.vocab), ("embed", "vocab"))
    return out


def embed(p: dict, cfg: ModelConfig, tokens: Array) -> Array:
    x = shard_act(p["table"][tokens].astype(cfg.cdtype),
                  ("act_batch", None, None))
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype=x.dtype)
    return x


def unembed(p: dict, cfg: ModelConfig, x: Array) -> Array:
    if cfg.tie_embeddings:
        out = jnp.einsum("bsd,vd->bsv", x, p["table"].astype(x.dtype))
    else:
        out = jnp.einsum("bsd,dv->bsv", x, p["head"].astype(x.dtype))
    return shard_act(out, ("act_batch", None, "vocab"))
