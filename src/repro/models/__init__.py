"""LM substrate for the assigned architecture pool.

LEGACY SEED MODULE: not part of the public decomposition API
(``repro.api``) and not reachable from the sparse-tensor stack — kept for
the dry-run compile matrix and the historical LM launch/tests.  See
docs/architecture.md ("Legacy LM substrate")."""
from .config import ModelConfig, MoEConfig, ShapeConfig, SHAPES, cell_is_skipped
from .transformer import Model

__all__ = ["ModelConfig", "MoEConfig", "ShapeConfig", "SHAPES",
           "cell_is_skipped", "Model"]
