"""LM substrate for the assigned architecture pool."""
from .config import ModelConfig, MoEConfig, ShapeConfig, SHAPES, cell_is_skipped
from .transformer import Model

__all__ = ["ModelConfig", "MoEConfig", "ShapeConfig", "SHAPES",
           "cell_is_skipped", "Model"]
