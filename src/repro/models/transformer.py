"""Config-driven stacked model: scan-over-layers, remat, train/prefill/decode.

The layer stack is ``prefix`` (unscanned) + ``pattern`` x reps (lax.scan over
stacked params — keeps the HLO compact for 512-device compiles) + ``suffix``
(unscanned).  Caches mirror the same structure so decode scans over
(params, cache) pairs.  Heterogeneous patterns (e.g. Griffin's
rec/rec/attn) put one full pattern instance inside each scan step.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from . import layers as L
from . import moe as MOE
from . import rglru as RG
from . import rwkv as RW
from .config import ModelConfig
from .params import ParamSpec, abstract_params, init_params, stack_specs

Array = jax.Array


# ---------------------------------------------------------------------------
# per-block specs
# ---------------------------------------------------------------------------

def block_specs(cfg: ModelConfig, kind: str, *, decoder: bool = False) -> dict:
    if kind == "attn":
        out = {"n1": L.norm_specs(cfg), "attn": L.attn_specs(cfg),
               "n2": L.norm_specs(cfg), "mlp": L.mlp_specs(cfg)}
        if decoder and cfg.encdec:
            out["nx"] = L.norm_specs(cfg)
            out["xattn"] = L.attn_specs(cfg)
        return out
    if kind == "moe":
        return {"n1": L.norm_specs(cfg), "attn": L.attn_specs(cfg),
                "n2": L.norm_specs(cfg), "moe": MOE.moe_specs(cfg)}
    if kind == "rec":
        return {"n1": L.norm_specs(cfg), "rec": RG.rglru_specs(cfg),
                "n2": L.norm_specs(cfg), "mlp": L.mlp_specs(cfg)}
    if kind == "rwkv":
        return {"n1": L.norm_specs(cfg), "n2": L.norm_specs(cfg),
                "rwkv": RW.rwkv_specs(cfg)}
    raise ValueError(kind)


def apply_block(kind: str, p: dict, cfg: ModelConfig, x: Array, ctx: dict):
    """Returns (x, new_cache, metrics)."""
    cache = ctx.get("cache")
    metrics: dict = {}
    if kind in ("attn", "moe"):
        h, acache = L.attention(
            p["attn"], cfg, L.apply_norm(p["n1"], cfg, x),
            mask_kind=ctx["mask_kind"], positions=ctx.get("positions"),
            cache=cache.get("self") if cache else None, pos=ctx.get("pos"))
        x = x + h
        new_cache = {"self": acache} if cache is not None else None
        if cfg.encdec and "xattn" in p:
            h, xcache = L.attention(
                p["xattn"], cfg, L.apply_norm(p["nx"], cfg, x),
                mask_kind="bidir", memory=ctx.get("memory"),
                cache=cache.get("cross") if cache else None, pos=ctx.get("pos"))
            x = x + h
            if new_cache is not None:
                new_cache["cross"] = xcache
        h2 = L.apply_norm(p["n2"], cfg, x)
        if kind == "moe":
            h2, metrics = MOE.moe_ffn(p["moe"], cfg, h2)
        else:
            h2 = L.mlp(p["mlp"], cfg, h2)
        return x + h2, new_cache, metrics
    if kind == "rec":
        h, rcache = RG.rglru_block(p["rec"], cfg, L.apply_norm(p["n1"], cfg, x),
                                   cache)
        x = x + h
        return x + L.mlp(p["mlp"], cfg, L.apply_norm(p["n2"], cfg, x)), rcache, metrics
    if kind == "rwkv":
        h, c1 = RW.time_mix(p["rwkv"], cfg, L.apply_norm(p["n1"], cfg, x), cache,
                            use_chunked=ctx.get("chunked", False))
        x = x + h
        h2, c2 = RW.channel_mix(p["rwkv"], cfg, L.apply_norm(p["n2"], cfg, x),
                                c1 if c1 is not None else cache)
        return x + h2, c2, metrics
    raise ValueError(kind)


def _block_mask_kind(cfg: ModelConfig, kind: str, *, encoder: bool = False) -> str:
    if encoder:
        return "bidir"
    if kind in ("attn", "moe") and cfg.attn_kind == "local":
        return "local"
    return "causal"


# ---------------------------------------------------------------------------
# cache construction
# ---------------------------------------------------------------------------

def _block_cache_spec(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                      *, src_len: int = 0, decoder: bool = False) -> dict:
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    if kind in ("attn", "moe"):
        cap = min(cfg.window, cache_len) if cfg.attn_kind == "local" else cache_len
        spec = {"self": {
            "k": ParamSpec((batch, cap, kv, hd),
                           ("cache_batch", "cache_seq", "kv_heads", "head_dim"), "zeros"),
            "v": ParamSpec((batch, cap, kv, hd),
                           ("cache_batch", "cache_seq", "kv_heads", "head_dim"), "zeros"),
            "slot_pos": ParamSpec((cap,), ("cache_seq",), "zeros"),
        }}
        if decoder and cfg.encdec:
            spec["cross"] = {
                "ck": ParamSpec((batch, src_len, kv, hd),
                                ("cache_batch", "cache_seq", "kv_heads", "head_dim"), "zeros"),
                "cv": ParamSpec((batch, src_len, kv, hd),
                                ("cache_batch", "cache_seq", "kv_heads", "head_dim"), "zeros"),
            }
        return spec
    if kind == "rec":
        w = cfg.rglru_width or cfg.d_model
        return {"h": ParamSpec((batch, w), ("cache_batch", "rnn"), "zeros"),
                "conv": ParamSpec((batch, cfg.conv_width - 1, w),
                                  ("cache_batch", None, "rnn"), "zeros")}
    if kind == "rwkv":
        d, n = cfg.d_model, cfg.rwkv_head_dim
        return {
            "state": ParamSpec((batch, d // n, n, n),
                               ("cache_batch", "heads", None, None), "zeros"),
            "tm_prev": ParamSpec((batch, d), ("cache_batch", "embed"), "zeros"),
            "cm_prev": ParamSpec((batch, d), ("cache_batch", "embed"), "zeros"),
        }
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# the model
# ---------------------------------------------------------------------------

class Model:
    """Functional model wrapper bound to a ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.prefix_kinds, self.reps, self.suffix_kinds = cfg.layer_plan
        self.pattern = cfg.pattern

    # -- parameter specs ----------------------------------------------------
    def param_specs(self) -> dict:
        cfg = self.cfg
        dec = cfg.encdec
        specs: dict[str, Any] = {"embed": L.embed_specs(cfg)}
        if self.prefix_kinds:
            specs["prefix"] = {
                f"p{i}": block_specs(cfg, k, decoder=dec)
                for i, k in enumerate(self.prefix_kinds)}
        unit = {f"b{i}": block_specs(cfg, k, decoder=dec)
                for i, k in enumerate(self.pattern)}
        specs["stack"] = stack_specs(unit, self.reps)
        if self.suffix_kinds:
            specs["suffix"] = {
                f"s{i}": block_specs(cfg, k, decoder=dec)
                for i, k in enumerate(self.suffix_kinds)}
        specs["final_norm"] = L.norm_specs(cfg)
        if cfg.encdec:
            enc_unit = {"b0": block_specs(cfg, "attn")}
            specs["encoder"] = {
                "stack": stack_specs(enc_unit, cfg.enc_layers),
                "final_norm": L.norm_specs(cfg),
            }
        return specs

    def init(self, key: Array):
        return init_params(self.param_specs(), key, self.cfg.pdtype)

    def abstract(self, sharding_fn=None):
        return abstract_params(self.param_specs(), self.cfg.pdtype, sharding_fn)

    # -- cache specs ----------------------------------------------------------
    def cache_specs(self, batch: int, cache_len: int, *, src_len: int = 0) -> dict:
        cfg = self.cfg
        dec = cfg.encdec
        mk = lambda k: _block_cache_spec(cfg, k, batch, cache_len,
                                         src_len=src_len, decoder=dec)
        out: dict[str, Any] = {}
        if self.prefix_kinds:
            out["prefix"] = {f"p{i}": mk(k) for i, k in enumerate(self.prefix_kinds)}
        unit = {f"b{i}": mk(k) for i, k in enumerate(self.pattern)}
        out["stack"] = stack_specs(unit, self.reps)
        if self.suffix_kinds:
            out["suffix"] = {f"s{i}": mk(k) for i, k in enumerate(self.suffix_kinds)}
        return out

    def init_cache(self, batch: int, cache_len: int, *, src_len: int = 0):
        specs = self.cache_specs(batch, cache_len, src_len=src_len)

        def mat(s: ParamSpec):
            dt = jnp.float32 if (s.axes and s.axes[-1] is None) or \
                 s.shape[-1] == (self.cfg.rglru_width or self.cfg.d_model) else self.cfg.cdtype
            # slot_pos / rwkv state need specific dtypes
            return jnp.zeros(s.shape, dtype=dt)

        cache = jax.tree.map(mat, specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        return self._fix_cache_dtypes(cache)

    def _fix_cache_dtypes(self, cache):
        def fix(path, leaf):
            name = path[-1].key if hasattr(path[-1], "key") else ""
            if name == "slot_pos":
                # large POSITIVE sentinel: empty slots must fail `spos <= pos`
                return jnp.full(leaf.shape, 2 ** 30, dtype=jnp.int32)
            if name in ("state", "h"):
                return leaf.astype(jnp.float32)
            if name in ("k", "v", "ck", "cv", "conv", "tm_prev", "cm_prev"):
                return leaf.astype(self.cfg.cdtype)
            return leaf
        return jax.tree_util.tree_map_with_path(fix, cache)

    # -- forward ------------------------------------------------------------
    def _inputs_to_x(self, params, batch):
        cfg = self.cfg
        if cfg.input_mode == "embeds":
            x = batch["embeds"].astype(cfg.cdtype)
            if cfg.scale_embed:
                x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
            return x
        return L.embed(params["embed"], cfg, batch["tokens"])

    def _encode(self, params, batch):
        cfg = self.cfg
        x = batch["src_embeds"].astype(cfg.cdtype)
        enc = params["encoder"]

        def unit(carry, up):
            h, _, _ = apply_block("attn", up["b0"], cfg, carry,
                                  {"mask_kind": "bidir"})
            return h, ()

        x, _ = jax.lax.scan(unit, x, enc["stack"],
                            unroll=True if cfg.unroll_loops else 1)
        return L.apply_norm(enc["final_norm"], cfg, x)

    def forward(self, params, batch, *, mode: str = "train", cache=None,
                pos=None):
        """mode: train | prefill | decode. Returns (logits, new_cache, metrics)."""
        cfg = self.cfg
        x = self._inputs_to_x(params, batch)
        memory = self._encode(params, batch) if cfg.encdec else None
        positions = batch.get("positions")
        use_cache = cache is not None

        # chunked (parallel-form) WKV only in the unrolled cost probes: the
        # pairwise-decay intermediate is O(B*S*C*H*N) — deployment uses the
        # sequential scan whose memory is O(B*H*N^2) (see DESIGN.md §6).
        base_ctx = {"positions": positions, "memory": memory, "pos": pos,
                    "chunked": cfg.unroll_loops}

        metrics_acc: list[dict] = []
        new_cache: dict[str, Any] = {}

        def run_block(kind, p, x, c):
            ctx = dict(base_ctx, mask_kind=_block_mask_kind(cfg, kind),
                       cache=c)
            return apply_block(kind, p, cfg, x, ctx)

        # prefix
        if self.prefix_kinds:
            new_cache["prefix"] = {}
            for i, kind in enumerate(self.prefix_kinds):
                c = cache["prefix"][f"p{i}"] if use_cache else None
                x, nc, met = run_block(kind, params["prefix"][f"p{i}"], x, c)
                new_cache["prefix"][f"p{i}"] = nc
                metrics_acc.append(met)

        # scanned body
        def unit(carry, xs):
            h = carry
            up, uc = xs
            ncs, mets = {}, {}
            for i, kind in enumerate(self.pattern):
                c = uc[f"b{i}"] if use_cache else None
                h, nc, met = run_block(kind, up[f"b{i}"], h, c)
                ncs[f"b{i}"] = nc if use_cache else ()
                mets.update({k: jnp.asarray(v) for k, v in met.items()})
            return h, (ncs, mets)

        unit_fn = unit
        if cfg.remat and mode == "train":
            if cfg.remat_policy == "dots":
                unit_fn = jax.checkpoint(
                    unit,
                    policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
            else:
                unit_fn = jax.checkpoint(unit)

        unroll = True if cfg.unroll_loops else 1
        if not use_cache:
            x, (scache, smets) = jax.lax.scan(
                lambda c, up: unit_fn(c, (up, None)), x, params["stack"],
                unroll=unroll)
        else:
            x, (scache, smets) = jax.lax.scan(
                unit_fn, x, (params["stack"], cache["stack"]), unroll=unroll)
        new_cache["stack"] = scache
        if smets:
            metrics_acc.append({k: jnp.mean(v) for k, v in smets.items()})

        # suffix
        if self.suffix_kinds:
            new_cache["suffix"] = {}
            for i, kind in enumerate(self.suffix_kinds):
                c = cache["suffix"][f"s{i}"] if use_cache else None
                x, nc, met = run_block(kind, params["suffix"][f"s{i}"], x, c)
                new_cache["suffix"][f"s{i}"] = nc
                metrics_acc.append(met)

        x = L.apply_norm(params["final_norm"], cfg, x)
        if mode in ("prefill", "decode"):
            x = x[:, -1:]  # only the last position's logits are needed

        metrics: dict = {}
        for m in metrics_acc:
            for k, v in m.items():
                metrics[k] = metrics.get(k, 0.0) + v / max(1, len(metrics_acc))
        if mode == "hidden":
            return x, (new_cache if use_cache else None), metrics
        logits = L.unembed(params["embed"], cfg, x)
        return logits, (new_cache if use_cache else None), metrics

    # -- public steps ---------------------------------------------------------
    def loss(self, params, batch):
        cfg = self.cfg
        labels = batch["labels"]
        if cfg.chunked_loss:
            # beyond-paper memory optimization: never materialize the full
            # (B, S, V) logits — unembed + CE one sequence chunk at a time.
            x, _, metrics = self.forward(params, batch, mode="hidden")
            c = cfg.chunked_loss
            b, s, d = x.shape
            assert s % c == 0, (s, c)
            xc = x.reshape(b, s // c, c, d).swapaxes(0, 1)
            lc = labels.reshape(b, s // c, c).swapaxes(0, 1)

            def chunk(carry, xs):
                xch, lch = xs
                logits = L.unembed(params["embed"], cfg, xch)
                lf = logits.astype(jnp.float32)
                lse = jax.scipy.special.logsumexp(lf, axis=-1)
                gold = jnp.take_along_axis(
                    lf, lch[..., None].astype(jnp.int32), axis=-1)[..., 0]
                valid = (lch >= 0).astype(jnp.float32)
                tot, cnt = carry
                return (tot + jnp.sum((lse - gold) * valid),
                        cnt + jnp.sum(valid)), None

            (tot, cnt), _ = jax.lax.scan(chunk, (jnp.float32(0), jnp.float32(0)),
                                         (xc, lc))
            ce = tot / jnp.maximum(cnt, 1.0)
            return ce, dict(metrics, loss=ce)

        logits, _, metrics = self.forward(params, batch, mode="train")
        lf = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(lf, axis=-1)
        gold = jnp.take_along_axis(lf, labels[..., None].astype(jnp.int32),
                                   axis=-1)[..., 0]
        valid = (labels >= 0).astype(jnp.float32)
        ce = jnp.sum((lse - gold) * valid) / jnp.maximum(jnp.sum(valid), 1.0)
        metrics = dict(metrics, loss=ce)
        return ce, metrics

    def prefill(self, params, batch, cache):
        logits, new_cache, _ = self.forward(params, batch, mode="prefill",
                                            cache=cache)
        return logits, new_cache

    def decode_step(self, params, tokens, cache, pos, *, positions=None,
                    memory=None):
        """tokens (B, 1) -> (logits (B,1,V), new_cache)."""
        batch = {"tokens": tokens}
        if self.cfg.input_mode == "embeds":
            # decode always proceeds in token space (text generation)
            batch = {"embeds": L.embed({"table": params["embed"]["table"]},
                                       self.cfg, tokens)}
        if positions is not None:
            batch["positions"] = positions
        if self.cfg.encdec:
            # cross K/V are cached; encoder is not re-run at decode time
            batch["src_embeds"] = jnp.zeros(
                (tokens.shape[0], 1, self.cfg.d_model), self.cfg.cdtype)
        logits, new_cache, _ = self.forward(batch=batch, params=params,
                                            mode="decode", cache=cache, pos=pos)
        return logits, new_cache
