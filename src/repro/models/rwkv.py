"""RWKV-6 (Finch) blocks: data-dependent-decay linear attention.

Time-mix ("attention") recurrence per head (key dim N), per channel n:

    out_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t   = diag(w_t) S_{t-1} + k_t v_t^T        w_t = exp(-exp(base + lora))

with data-dependent token-shift interpolation (ddlerp) feeding r/k/v/g/w.
Channel-mix is RWKV's squared-relu FFN with token shift.

Two wkv implementations:
  * ``wkv_scan``    — exact sequential lax.scan over time.  The correctness
    oracle, and what the dry-run lowers (recurrence FLOPs are counted via
    the while-loop trip count).
  * ``wkv_chunked`` — intra-chunk pairwise-decay form.  Pairwise exponent
    differences are always <= 0 for causal pairs so it is overflow-safe
    without clamping, at the cost of an (B, nc, C, C, H, N) intermediate —
    the TPU-parallel trade-off, validated against the scan in tests.

Decode carries (state, tm_prev, cm_prev) per layer — O(1) in context length,
which is why rwkv6 runs the ``long_500k`` cell.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec

Array = jax.Array

LORA_R = 32       # ddlerp lora rank
DECAY_LORA_R = 64


def rwkv_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    h = d // n
    f = cfg.d_ff
    return {
        # time-mix
        "mu": ParamSpec((5, d), ("five", "embed"), "uniform", 0.5),
        "lora_a": ParamSpec((d, 5 * LORA_R), ("embed", "lora")),
        "lora_b": ParamSpec((5, LORA_R, d), ("five", "lora", "embed")),
        "w0": ParamSpec((d,), ("embed",), "uniform", 1.0),
        "wlora_a": ParamSpec((d, DECAY_LORA_R), ("embed", "lora")),
        "wlora_b": ParamSpec((DECAY_LORA_R, d), ("lora", "embed")),
        "u": ParamSpec((h, n), ("heads", "head_dim"), "uniform", 0.5),
        "wr": ParamSpec((d, d), ("embed", "embed_out")),
        "wk": ParamSpec((d, d), ("embed", "embed_out")),
        "wv": ParamSpec((d, d), ("embed", "embed_out")),
        "wg": ParamSpec((d, d), ("embed", "embed_out")),
        "wo": ParamSpec((d, d), ("embed_out", "embed")),
        "ln_w": ParamSpec((d,), ("norm",), "ones"),
        # channel-mix
        "cm_mu_k": ParamSpec((d,), ("embed",), "uniform", 0.5),
        "cm_mu_r": ParamSpec((d,), ("embed",), "uniform", 0.5),
        "cm_wk": ParamSpec((d, f), ("embed", "mlp")),
        "cm_wv": ParamSpec((f, d), ("mlp", "embed")),
        "cm_wr": ParamSpec((d, d), ("embed", "embed_out")),
    }


def _shift(x: Array, prev: Array | None) -> Array:
    """Token shift: x_{t-1}; first position uses `prev` (decode carry) or 0."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None, :]
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def _ddlerp(p: dict, x: Array, xs: Array):
    """Data-dependent lerp producing the 5 mixed inputs (w,k,v,r,g)."""
    dx = xs - x
    mixed = x + dx * p["mu"][0][None, None]
    lora = jnp.tanh(mixed @ p["lora_a"])
    b, s, _ = lora.shape
    lora = lora.reshape(b, s, 5, LORA_R)
    dyn = jnp.einsum("bsfr,frd->bsfd", lora, p["lora_b"])  # (B,S,5,D)
    mus = p["mu"][None, None] + dyn
    return tuple(x + dx * mus[:, :, i] for i in range(5))


def wkv_scan(r: Array, k: Array, v: Array, w: Array, u: Array,
             state: Array | None = None):
    """Exact recurrence. r/k/v/w: (B,S,H,N); u: (H,N).
    Returns (out (B,S,H,N), final_state (B,H,N,N))."""
    b, s, h, n = r.shape
    if state is None:
        state = jnp.zeros((b, h, n, n), dtype=jnp.float32)

    def step(st, inp):
        rt, kt, vt, wt = inp  # (B,H,N) each
        kv = kt[..., :, None] * vt[..., None, :]            # (B,H,N,N)
        out = jnp.einsum("bhn,bhnm->bhm", rt,
                         st + u[None, :, :, None] * kv)
        st = wt[..., :, None] * st + kv
        return st, out

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 1, 0) for a in (r, k, v, w))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1).astype(r.dtype), state


def wkv_chunked(r: Array, k: Array, v: Array, w: Array, u: Array,
                state: Array | None = None, *, chunk: int = 32,
                unroll: bool = False):
    """Chunked parallel form; exact (pairwise log-decay differences <= 0)."""
    b, s, h, n = r.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    f32 = jnp.float32
    rc, kc, vc = (a.astype(f32).reshape(b, nc, chunk, h, n) for a in (r, k, v))
    lw = jnp.log(jnp.maximum(w.astype(f32), 1e-38)).reshape(b, nc, chunk, h, n)
    cum = jnp.cumsum(lw, axis=2)                    # inclusive within chunk
    ex = cum - lw                                   # exclusive (sum up to t-1)

    # intra-chunk: att[b,c,t,s,h] = sum_n r_t k_s exp(ex_t - cum_s), s < t
    diff = ex[:, :, :, None] - cum[:, :, None, :, :, :]  # (B,nc,C,C,H,N)
    tri = (jnp.arange(chunk)[:, None] > jnp.arange(chunk)[None, :])
    # mask BEFORE exp: for s >= t the exponent is positive (would overflow)
    dec = jnp.exp(jnp.where(tri[None, None, :, :, None, None], diff, -jnp.inf))
    att = jnp.einsum("bcthn,bcshn,bctshn->bctsh", rc, kc, dec)
    intra = jnp.einsum("bctsh,bcshn->bcthn", att, vc)
    # current-token bonus
    bonus = jnp.einsum("bcthn,bcthn->bcth", rc, u[None, None, None] * kc)
    intra = intra + bonus[..., None] * vc

    # inter-chunk: carry state across chunks with a scan over nc (length S/C)
    if state is None:
        state = jnp.zeros((b, h, n, n), dtype=f32)
    decay_q = jnp.exp(ex)                            # (B,nc,C,H,N), safe: ex<=0
    decay_total = jnp.exp(cum[:, :, -1])             # (B,nc,H,N)
    decay_k = jnp.exp(cum[:, :, -1][:, :, None] - cum)  # (B,nc,C,H,N) <= 1

    def chunk_step(st, inp):
        rq, kq, vq, dtot = inp
        # rq: r * per-token decay from chunk start (B,C,H,N)
        inter = jnp.einsum("bthn,bhnm->bthm", rq, st)
        st = dtot[..., None] * st + jnp.einsum("bthn,bthm->bhnm", kq, vq)
        return st, inter

    xs = (jnp.moveaxis(rc * decay_q, 1, 0), jnp.moveaxis(kc * decay_k, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(decay_total, 1, 0))
    state, inter = jax.lax.scan(chunk_step, state, xs,
                                unroll=True if unroll else 1)
    inter = jnp.moveaxis(inter, 0, 1)               # (B,nc,C,H,N)

    out = (intra + inter).reshape(b, s, h, n)
    return out.astype(r.dtype), state


def _group_norm(x: Array, w: Array, n: int, eps: float = 64e-5) -> Array:
    """Per-head group norm over the flattened (H*N) dim (RWKV ln_x)."""
    b, s, d = x.shape
    xg = x.reshape(b, s, d // n, n).astype(jnp.float32)
    mu = jnp.mean(xg, axis=-1, keepdims=True)
    var = jnp.var(xg, axis=-1, keepdims=True)
    xg = (xg - mu) * jax.lax.rsqrt(var + eps)
    return (xg.reshape(b, s, d) * w).astype(x.dtype)


def time_mix(p: dict, cfg: ModelConfig, x: Array, cache: dict | None,
             *, use_chunked: bool = False):
    """RWKV6 attention analogue. Returns (out, new_cache)."""
    b, s, d = x.shape
    n = cfg.rwkv_head_dim
    h = d // n
    prev = cache["tm_prev"] if cache is not None else None
    xs = _shift(x, prev)
    xw, xk, xv, xr, xg = _ddlerp(p, x, xs)

    logw = p["w0"][None, None] + jnp.tanh(xw @ p["wlora_a"]) @ p["wlora_b"]
    w = jnp.exp(-jnp.exp(logw.astype(jnp.float32)))

    r = (xr @ p["wr"]).reshape(b, s, h, n)
    k = (xk @ p["wk"]).reshape(b, s, h, n)
    v = (xv @ p["wv"]).reshape(b, s, h, n)
    g = jax.nn.silu(xg @ p["wg"])

    state = cache["state"] if cache is not None else None
    wh = w.reshape(b, s, h, n)
    if use_chunked and s % 32 == 0 and s > 32:
        chunk = 128 if s % 128 == 0 else 32
        wkv, new_state = wkv_chunked(r, k, v, wh, p["u"], state, chunk=chunk,
                                     unroll=cfg.unroll_loops)
    else:
        wkv, new_state = wkv_scan(r, k, v, wh, p["u"], state)

    out = _group_norm(wkv.reshape(b, s, d), p["ln_w"], n) * g
    out = out @ p["wo"]
    new_cache = None
    if cache is not None:
        new_cache = {"state": new_state, "tm_prev": x[:, -1],
                     "cm_prev": cache["cm_prev"]}
    return out, new_cache


def channel_mix(p: dict, cfg: ModelConfig, x: Array, cache: dict | None):
    prev = cache["cm_prev"] if cache is not None else None
    xs = _shift(x, prev)
    dx = xs - x
    xk = x + dx * p["cm_mu_k"][None, None]
    xr = x + dx * p["cm_mu_r"][None, None]
    k = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    out = jax.nn.sigmoid(xr @ p["cm_wr"]) * (k @ p["cm_wv"])
    new_cache = None
    if cache is not None:
        new_cache = dict(cache, cm_prev=x[:, -1])
    return out, new_cache


def init_rwkv_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    n = cfg.rwkv_head_dim
    return {
        "state": jnp.zeros((batch, d // n, n, n), dtype=jnp.float32),
        "tm_prev": jnp.zeros((batch, d), dtype=dtype),
        "cm_prev": jnp.zeros((batch, d), dtype=dtype),
    }
