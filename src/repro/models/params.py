"""Parameter spec trees: one definition drives init, abstract shapes and
sharding (logical axis names -> mesh axes via rules in repro.launch.mesh)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    """Leaf of a parameter tree before materialization."""

    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis name per dim
    init: str = "normal"          # normal | zeros | ones | uniform
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def stack_specs(spec_tree, n: int) -> Any:
    """Prepend a scanned 'layers' dim of length n to every leaf."""
    def f(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, ("layers",) + s.axes, s.init, s.scale)
    return jax.tree.map(f, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def init_params(spec_tree, key: Array, dtype) -> Any:
    """Materialize a spec tree (smoke tests / real training)."""
    leaves, treedef = jax.tree.flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    out = []
    for k, s in zip(keys, leaves):
        if s.init == "zeros":
            a = jnp.zeros(s.shape, dtype)
        elif s.init == "ones":
            a = jnp.ones(s.shape, dtype)
        elif s.init == "uniform":
            a = jax.random.uniform(k, s.shape, dtype, -s.scale, s.scale)
        else:
            a = (s.scale * jax.random.normal(k, s.shape)).astype(dtype)
        out.append(a)
    return jax.tree.unflatten(treedef, out)


def abstract_params(spec_tree, dtype, sharding_fn: Callable | None = None) -> Any:
    """ShapeDtypeStructs (dry-run: no allocation).  ``sharding_fn`` maps a
    leaf's logical axes tuple -> a Sharding (or None)."""
    def f(s: ParamSpec):
        sh = sharding_fn(s.axes, s.shape) if sharding_fn else None
        return jax.ShapeDtypeStruct(s.shape, dtype, sharding=sh)
    return jax.tree.map(f, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))


def axes_tree(spec_tree) -> Any:
    return jax.tree.map(
        lambda s: s.axes, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def param_bytes(spec_tree, dtype) -> int:
    leaves = jax.tree.leaves(spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec))
    itemsize = jnp.dtype(dtype).itemsize
    return sum(int(np.prod(s.shape)) * itemsize for s in leaves)
