"""Mixture-of-Experts FFN: top-k router + sort-based capacity dispatch.

Dispatch is the MegaBlocks/MaxText-style dropping implementation adapted to
pure jnp (static shapes): tokens' (token, expert) assignments are sorted by
expert id, each expert takes at most ``capacity`` tokens, the expert FFN is
one batched einsum over the (E, C, D) buffer, and results scatter back with
the router's combine weights.  Under pjit the expert dim shards over the
'model'/'expert' mesh axis (EP); the sort/gathers become the all-to-all-like
collectives visible in the dry-run's HLO.
"""
from __future__ import annotations

import jax
import numpy as np
import jax.numpy as jnp

from repro.dist.collectives import shard_map

from .config import ModelConfig
from .params import ParamSpec
from .layers import shard_act

Array = jax.Array


def moe_specs(cfg: ModelConfig) -> dict:
    e = cfg.moe
    d, f = cfg.d_model, e.d_ff
    out = {
        "router": ParamSpec((d, e.num_experts), ("embed", "experts_r")),
        "wg": ParamSpec((e.num_experts, d, f), ("experts", "embed", "mlp")),
        "wu": ParamSpec((e.num_experts, d, f), ("experts", "embed", "mlp")),
        "wd": ParamSpec((e.num_experts, f, d), ("experts", "mlp", "embed")),
    }
    if e.num_shared:
        out["shared_wg"] = ParamSpec((d, e.num_shared * f), ("embed", "mlp"))
        out["shared_wu"] = ParamSpec((d, e.num_shared * f), ("embed", "mlp"))
        out["shared_wd"] = ParamSpec((e.num_shared * f, d), ("mlp", "embed"))
    return out


def capacity(cfg: ModelConfig, num_tokens: int) -> int:
    e = cfg.moe
    c = int(e.top_k * num_tokens * e.capacity_factor / e.num_experts)
    return max(8, -(-c // 8) * 8)  # pad to sublane multiple


def _expert_act(cfg: ModelConfig, h_g: Array, h_u: Array) -> Array:
    if cfg.mlp == "geglu":
        return jax.nn.gelu(h_g) * h_u
    return jax.nn.silu(h_g) * h_u


def moe_ffn(p: dict, cfg: ModelConfig, x: Array) -> tuple[Array, dict]:
    """Dispatch: expert-parallel shard_map when a mesh is installed (the
    production path), dense single-host dispatch otherwise (tests)."""
    from .layers import get_mesh

    mesh = get_mesh()
    if mesh is not None:
        dp_axes = tuple(a for a in mesh.axis_names if a != "model")
        dp = 1
        for a in dp_axes:
            dp *= mesh.shape[a]
        if (x.shape[0] % dp == 0
                and x.shape[1] % mesh.shape["model"] == 0
                and cfg.moe.num_experts % mesh.shape["model"] == 0):
            return moe_ffn_ep(p, cfg, x, mesh)
    return _moe_ffn_dense_dispatch(p, cfg, x)


def _moe_ffn_dense_dispatch(p: dict, cfg: ModelConfig, x: Array) -> tuple[Array, dict]:
    """x (B, S, D) -> (out, metrics). Dropped tokens pass through as zeros
    from the routed experts (shared experts still contribute)."""
    e = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt @ p["router"].astype(jnp.float32)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(probs, e.top_k)            # (T, k)
    topv = topv / jnp.sum(topv, axis=-1, keepdims=True)   # renormalize

    flat_e = topi.reshape(t * e.top_k)
    flat_w = topv.reshape(t * e.top_k)
    flat_tok = jnp.arange(t * e.top_k, dtype=jnp.int32) // e.top_k

    order = jnp.argsort(flat_e)                           # stable
    se, sw, st = flat_e[order], flat_w[order], flat_tok[order]

    # rank of each entry within its expert
    starts = jnp.searchsorted(se, jnp.arange(e.num_experts), side="left")
    rank = jnp.arange(t * e.top_k) - starts[se]

    cap = capacity(cfg, t)
    keep = rank < cap
    slot = jnp.where(keep, se * cap + rank, e.num_experts * cap)  # OOB drops

    buf = jnp.zeros((e.num_experts * cap, d), dtype=x.dtype)
    buf = buf.at[slot].set(xt[st], mode="drop")
    h = shard_act(buf.reshape(e.num_experts, cap, d),
                  ("experts", None, None))

    h_g = jnp.einsum("ecd,edf->ecf", h, p["wg"])
    h_u = jnp.einsum("ecd,edf->ecf", h, p["wu"])
    y = shard_act(jnp.einsum("ecf,efd->ecd", _expert_act(cfg, h_g, h_u), p["wd"]),
                  ("experts", None, None))
    yt = y.reshape(e.num_experts * cap, d)

    gathered = yt[jnp.minimum(slot, e.num_experts * cap - 1)]
    contrib = gathered * (sw * keep).astype(x.dtype)[:, None]
    out = jnp.zeros((t, d), dtype=x.dtype).at[st].add(contrib)

    if e.num_shared:
        hs = _expert_act(cfg, xt @ p["shared_wg"], xt @ p["shared_wu"])
        out = out + hs @ p["shared_wd"]

    # load-balance metrics (Switch-style aux loss terms, reported not applied)
    frac_tokens = jnp.mean(jax.nn.one_hot(topi[:, 0], e.num_experts), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    metrics = {
        "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32)),
        "moe_balance_loss": e.num_experts * jnp.sum(frac_tokens * frac_probs),
    }
    return out.reshape(b, s, d), metrics


# ---------------------------------------------------------------------------
# expert-parallel dispatch (shard_map + all_to_all over the 'model' axis)
# ---------------------------------------------------------------------------
#
# Tokens live on their data shard; experts are sharded over 'model'.  Each
# device routes its local tokens, packs per-destination-column send buffers
# of static capacity, all_to_all's them across the expert axis, runs its
# local experts, and all_to_all's results back (the return all_to_all
# restores the send layout, so combine is a local scatter).  This is the
# communication pattern of production MoE systems (GShard/Switch); the naive
# pjit dispatch above is kept as the measured design ablation — its dry-run
# showed 1.6 TiB/device peak on kimi-k2 (artifacts/dryrun, tag moe-naive).

def _capacity_rounded(n: float) -> int:
    return max(8, -(-int(n) // 8) * 8)


def _dispatch_to_buffer(tokens: Array, expert_of: Array, weight: Array,
                        valid: Array, n_buckets: int, cap: int):
    """Sort (token, expert) pairs into an (n_buckets, cap, ...) buffer.
    Returns (buf, slot) where slot[i] is entry i's position (or OOB)."""
    n = expert_of.shape[0]
    order = jnp.argsort(jnp.where(valid, expert_of, n_buckets))
    se = expert_of[order]
    starts = jnp.searchsorted(se, jnp.arange(n_buckets), side="left")
    rank = jnp.arange(n) - starts[jnp.minimum(se, n_buckets - 1)]
    keep = (rank < cap) & valid[order]
    slot_sorted = jnp.where(keep, se * cap + rank, n_buckets * cap)
    # slot per ORIGINAL entry
    slot = jnp.zeros((n,), jnp.int32).at[order].set(slot_sorted.astype(jnp.int32))
    buf = jnp.zeros((n_buckets * cap,) + tokens.shape[1:], tokens.dtype)
    buf = buf.at[slot].set(tokens, mode="drop")
    return buf.reshape((n_buckets, cap) + tokens.shape[1:]), slot


def moe_ffn_ep(p: dict, cfg: ModelConfig, x: Array, mesh) -> tuple[Array, dict]:
    e = cfg.moe
    b, s, d = x.shape
    ncol = mesh.shape["model"]
    e_loc = e.num_experts // ncol
    dp_axes = tuple(a for a in mesh.axis_names if a != "model")

    from jax.sharding import PartitionSpec as P

    # tokens sharded over (data x model): sequence splits over the expert
    # axis (sequence parallelism), so routing work and send buffers are
    # per-device local — no replicated dispatch.
    t_loc = (b // int(np.prod([mesh.shape[a] for a in dp_axes]))) * (s // ncol)
    cap_send = _capacity_rounded(e.top_k * t_loc * e.capacity_factor / ncol)
    cap_exp = _capacity_rounded(ncol * cap_send * 1.25 / e_loc)

    # FSDP: expert weights enter the shard_map in their true (model, data)
    # layout and are all-gathered EXPLICITLY once per call — the backward of
    # a tiled all_gather is a reduce-scatter, so weight gradients cross the
    # data axis once at 1/dp size instead of as full f32 all-reduces (the
    # implicit-resharding failure mode this replaced cost ~2.9 TiB/step/device
    # wire on kimi-k2; see EXPERIMENTS.md §Perf).
    fsdp = getattr(cfg, "fsdp", False)
    wspec_g = P("model", dp_axes, None) if fsdp else P("model")
    wspec_d = P("model", None, dp_axes) if fsdp else P("model")

    def body(x_loc, router, wg, wu, wd):
        if fsdp:
            wg = jax.lax.all_gather(wg, dp_axes, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, dp_axes, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, dp_axes, axis=2, tiled=True)
        bl, sl, _ = x_loc.shape
        tl = bl * sl
        xt = x_loc.reshape(tl, d)

        logits = (xt @ router.astype(jnp.float32)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(probs, e.top_k)
        topv = topv / jnp.sum(topv, axis=-1, keepdims=True)

        flat_e = topi.reshape(tl * e.top_k).astype(jnp.int32)
        flat_w = topv.reshape(tl * e.top_k)
        flat_tok = (jnp.arange(tl * e.top_k, dtype=jnp.int32) // e.top_k)

        # --- pack per-destination-column send buffers ---
        dest_col = flat_e // e_loc
        payload = jnp.concatenate(
            [xt[flat_tok],
             flat_e[:, None].astype(xt.dtype),           # global expert id
             flat_w[:, None].astype(xt.dtype)], axis=1)  # combine weight
        send, slot = _dispatch_to_buffer(
            payload, dest_col, flat_w, jnp.ones_like(dest_col, bool),
            ncol, cap_send)

        # --- exchange across the expert axis ---
        recv = jax.lax.all_to_all(send, "model", split_axis=0, concat_axis=0,
                                  tiled=True)
        r_tok = recv[..., :d].reshape(ncol * cap_send, d)
        r_e = recv[..., d].reshape(ncol * cap_send).astype(jnp.int32)
        r_w = recv[..., d + 1].reshape(ncol * cap_send)
        col_id = jax.lax.axis_index("model")
        r_loc_e = r_e - col_id * e_loc
        r_valid = (r_w > 0) & (r_loc_e >= 0) & (r_loc_e < e_loc)

        # --- local expert FFN over an (e_loc, cap_exp, d) buffer ---
        ebuf, eslot = _dispatch_to_buffer(r_tok, r_loc_e, r_w, r_valid,
                                          e_loc, cap_exp)
        h_g = jnp.einsum("ecd,edf->ecf", ebuf, wg)
        h_u = jnp.einsum("ecd,edf->ecf", ebuf, wu)
        y = jnp.einsum("ecf,efd->ecd", _expert_act(cfg, h_g, h_u), wd)
        yt = y.reshape(e_loc * cap_exp, d)
        r_out = yt[jnp.minimum(eslot, e_loc * cap_exp - 1)] * \
            r_valid[:, None].astype(yt.dtype)

        # --- return trip: all_to_all back restores the send layout ---
        back = jax.lax.all_to_all(r_out.reshape(ncol, cap_send, d), "model",
                                  split_axis=0, concat_axis=0, tiled=True)
        flat_back = back.reshape(ncol * cap_send, d)
        contrib = flat_back[jnp.minimum(slot, ncol * cap_send - 1)]
        kept = (slot < ncol * cap_send).astype(xt.dtype)
        out = jnp.zeros((tl, d), xt.dtype).at[flat_tok].add(
            contrib * (flat_w * kept)[:, None].astype(xt.dtype))

        drop = 1.0 - jnp.mean(kept)
        drop = jax.lax.pmean(jax.lax.pmean(drop, "model"), dp_axes)
        return out.reshape(bl, sl, d), drop

    smapped = shard_map(
        body, mesh=mesh,
        in_specs=(P(dp_axes, "model", None), P(), wspec_g, wspec_g, wspec_d),
        out_specs=(P(dp_axes, "model", None), P()),
    )
    out, drop = smapped(x, p["router"], p["wg"], p["wu"], p["wd"])

    if e.num_shared:
        xt = x.reshape(b * s, d)
        hs = _expert_act(cfg, xt @ p["shared_wg"], xt @ p["shared_wu"])
        out = out + (hs @ p["shared_wd"]).reshape(b, s, d)

    return out, {"moe_drop_frac": drop}
