"""Model / shape configuration for the assigned architecture pool.

One frozen dataclass drives everything: parameter construction, forward
pass, sharding (via logical axis names), and the dry-run's input specs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int                   # per-expert hidden
    num_shared: int = 0         # always-on shared experts (Kimi K2)
    capacity_factor: float = 1.25
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | vlm | audio | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int

    mlp: str = "swiglu"         # swiglu | geglu | gelu
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    rope: str = "std"           # none | std | mrope
    rope_theta: float = 10_000.0
    mrope_sections: tuple = ()  # (t, h, w) half-dim split for M-RoPE

    attn_kind: str = "full"     # full | local
    window: int = 0             # local-attention window (hybrid archs)

    # layer stacking: `prefix` unscanned leading layers, then `pattern`
    # repeated over the remaining layers (must divide), then `suffix`.
    # kinds: 'attn' (attention+mlp), 'moe' (attention+moe), 'rec' (RG-LRU
    # temporal block + mlp), 'rwkv' (RWKV6 time-mix + channel-mix).
    pattern: tuple = ("attn",)
    prefix: tuple = ()
    suffix: tuple = ()

    moe: Optional[MoEConfig] = None
    tie_embeddings: bool = False
    scale_embed: bool = False           # gemma: x *= sqrt(d_model)
    input_mode: str = "tokens"          # tokens | embeds (vlm/audio stubs)

    # encoder-decoder (seamless): encoder layers use the same dims
    encdec: bool = False
    enc_layers: int = 0

    # recurrent families
    rwkv_head_dim: int = 64
    rglru_width: int = 0                # 0 -> d_model
    conv_width: int = 4

    # numerics / memory
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save matmul outputs: fewer
                                 # recomputed collectives, more live memory)
    # perf-pass knobs (beyond-paper optimizations; off = paper-faithful base)
    flash_block_skip: bool = False   # skip fully-masked KV blocks in flash
    chunked_loss: int = 0            # CE over seq chunks (0 = full logits)
    # dry-run cost probes: fully unroll layer/flash/chunk scans so XLA's
    # cost_analysis (which counts while bodies ONCE) sees every op.  The
    # roofline extrapolates probe costs at k=1,2 pattern reps to the full
    # depth; production lowering keeps scans (compact HLO).
    unroll_loops: bool = False
    # which logical axis the FSDP ('data') rule applies to, for >=34B archs
    fsdp: bool = False

    # --- derived -----------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def layer_plan(self) -> tuple[tuple[str, ...], int, tuple[str, ...]]:
        """(prefix, n_pattern_repeats, suffix); validates the layer count."""
        body = self.num_layers - len(self.prefix) - len(self.suffix)
        if body < 0 or (len(self.pattern) and body % len(self.pattern)):
            raise ValueError(
                f"{self.name}: {self.num_layers} layers does not decompose "
                f"into prefix {self.prefix} + k*{self.pattern} + suffix {self.suffix}"
            )
        reps = body // len(self.pattern) if self.pattern else 0
        return self.prefix, reps, self.suffix

    @property
    def attn_param_count(self) -> int:
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        return d * h * hd + 2 * d * kv * hd + h * hd * d

    def param_count(self) -> int:
        """Total parameter count (for 6ND MODEL_FLOPS and sanity checks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        mlp_p = {"swiglu": 3 * d * f, "geglu": 3 * d * f, "gelu": 2 * d * f}[self.mlp]
        kind_counts = {}
        kind_counts["attn"] = self.attn_param_count + mlp_p + 2 * d
        if self.moe:
            e = self.moe
            moe_mlp = e.num_experts * 3 * d * e.d_ff + d * e.num_experts
            moe_mlp += e.num_shared * 3 * d * e.d_ff
            kind_counts["moe"] = self.attn_param_count + moe_mlp + 2 * d
        if "rec" in self.prefix + self.pattern + self.suffix:
            w = self.rglru_width or d
            # in/out proj + conv + rglru gates/decay + mlp + norms
            rec = 2 * d * w + self.conv_width * w + 3 * w + 2 * w * w + mlp_p + 2 * d
            kind_counts["rec"] = rec
        if "rwkv" in self.prefix + self.pattern + self.suffix:
            # r,k,v,g,o projections + decay/bonus + ddlerp lora + channel mix
            tm = 5 * d * d + 2 * d + d * 160 + 5 * 32 * d
            cm = 2 * d * f + d * d  # rwkv channel mix: k, v, r
            kind_counts["rwkv"] = tm + cm + 2 * d
        total = 0
        prefix, reps, suffix = self.layer_plan
        seq = list(prefix) + list(self.pattern) * reps + list(suffix)
        for i, kind in enumerate(seq):
            if kind == "moe" and i < len(prefix) and self.moe:
                pass
            total += kind_counts[kind]
        total += v * d  # embedding
        if not self.tie_embeddings:
            total += d * v
        total += d  # final norm
        if self.encdec:
            total += self.enc_layers * kind_counts["attn"]
            # decoder cross-attention blocks
            total += self.num_layers * (self.attn_param_count + d)
        return total

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts top_k + shared experts."""
        if not self.moe:
            return self.param_count()
        e = self.moe
        d = self.d_model
        inactive_per_moe = (e.num_experts - e.top_k) * 3 * d * e.d_ff
        prefix, reps, suffix = self.layer_plan
        seq = list(prefix) + list(self.pattern) * reps + list(suffix)
        n_moe = sum(1 for k in seq if k == "moe")
        return self.param_count() - n_moe * inactive_per_moe


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell: what step to lower and at what size."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def step_name(self) -> str:
        return {"train": "train_step", "prefill": "prefill_step",
                "decode": "serve_step"}[self.kind]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# archs whose attention is sub-quadratic (fixed-state or windowed): the only
# ones that run long_500k (see DESIGN.md shape-skip table)
SUBQUADRATIC = ("rwkv6-3b", "recurrentgemma-9b")


def cell_is_skipped(arch: str, shape: str) -> str | None:
    """Return a skip reason or None. Mirrors DESIGN.md §5."""
    if shape == "long_500k" and arch not in SUBQUADRATIC:
        return "pure full attention: 524k dense KV decode is the wrong tool"
    return None
