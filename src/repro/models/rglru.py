"""RG-LRU recurrent block (Griffin / RecurrentGemma).

Temporal-mixing block: x -> (linear branch -> causal conv1d -> RG-LRU)
                          * (linear branch -> GeLU)  -> output projection.

RG-LRU per channel:  a_t = exp(c * log(sigmoid(L)) * sigmoid(r_t))
                     h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The recurrence is a first-order linear scan -> jax.lax.associative_scan
(log-depth, TPU-parallel; this is the Griffin-native formulation, unlike
RWKV's data-dependent matrix state which needs the sequential/chunked form).
Decode carries (h, conv buffer) — fixed-size state, so recurrentgemma runs
``long_500k``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .params import ParamSpec

Array = jax.Array

C_RGLRU = 8.0


def rglru_specs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    cw = cfg.conv_width
    return {
        "wx": ParamSpec((d, w), ("embed", "rnn")),
        "wy": ParamSpec((d, w), ("embed", "rnn")),
        "conv_w": ParamSpec((cw, w), ("conv", "rnn")),
        "conv_b": ParamSpec((w,), ("rnn",), "zeros"),
        "lam": ParamSpec((w,), ("rnn",), "uniform", 2.0),
        "w_rg": ParamSpec((w, w), ("rnn", "rnn_out")),
        "b_rg": ParamSpec((w,), ("rnn",), "zeros"),
        "w_ig": ParamSpec((w, w), ("rnn", "rnn_out")),
        "b_ig": ParamSpec((w,), ("rnn",), "zeros"),
        "wo": ParamSpec((w, d), ("rnn", "embed")),
    }


def _causal_conv1d(u: Array, w: Array, b: Array, prev: Array | None):
    """Depthwise causal conv, width CW.  prev: (B, CW-1, W) decode buffer."""
    cw = w.shape[0]
    if prev is None:
        prev = jnp.zeros((u.shape[0], cw - 1, u.shape[-1]), dtype=u.dtype)
    ext = jnp.concatenate([prev, u], axis=1)  # (B, S+CW-1, W)
    out = sum(ext[:, i : i + u.shape[1]] * w[i][None, None] for i in range(cw))
    new_prev = ext[:, -(cw - 1):] if cw > 1 else prev
    return out + b[None, None], new_prev


def _rglru_scan(a: Array, b_in: Array, h0: Array | None):
    """h_t = a_t h_{t-1} + b_t via associative scan.  a,b: (B,S,W) f32."""
    if h0 is not None:
        # fold the carried state into the first step's additive term
        b_in = b_in.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b_in), axis=1)
    return h


def rglru_block(p: dict, cfg: ModelConfig, x: Array, cache: dict | None):
    """Returns (out, new_cache); cache = {'h': (B,W) f32, 'conv': (B,CW-1,W)}."""
    u = x @ p["wx"]
    gate = jax.nn.gelu(x @ p["wy"])

    prev_conv = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv1d(u, p["conv_w"], p["conv_b"], prev_conv)

    uf = u.astype(jnp.float32)
    rg = jax.nn.sigmoid(uf @ p["w_rg"].astype(jnp.float32) + p["b_rg"])
    ig = jax.nn.sigmoid(uf @ p["w_ig"].astype(jnp.float32) + p["b_ig"])
    log_a = C_RGLRU * jax.nn.log_sigmoid(p["lam"].astype(jnp.float32)) * rg
    a = jnp.exp(log_a)
    b_in = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (ig * uf)

    h0 = cache["h"] if cache is not None else None
    if x.shape[1] == 1 and h0 is not None:
        h = (a[:, 0] * h0 + b_in[:, 0])[:, None]
    else:
        h = _rglru_scan(a, b_in, h0)

    out = (h.astype(x.dtype) * gate) @ p["wo"]
    new_cache = None
    if cache is not None:
        new_cache = {"h": h[:, -1], "conv": new_conv}
    return out, new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    w = cfg.rglru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype=dtype),
    }
