"""``python -m repro`` — thin arg -> :class:`RunConfig` translators.

    python -m repro --list-methods            # method capability matrix
    python -m repro --list-impls              # kernel-impl capability matrix
    python -m repro ingest  --source data.tns --reorder degree_sort
    python -m repro plan    --dataset yelp --scale 0.002 --rank 35
    python -m repro fit     --config run.json [--dryrun]
    python -m repro serve   --dataset yelp --scale 0.002 --queries 2048
    python -m repro serve-daemon --dataset yelp --scale 0.002 --port 9300
    python -m repro dryrun  --workload cpals-yelp --mesh single
    python -m repro fit     --dataset yelp --trace-dir artifacts/trace
    python -m repro trace   artifacts/trace   # Table-III-style breakdown
    python -m repro metrics artifacts/trace   # standalone metrics table
    python -m repro fit     --dataset yelp --trace-dir t --http-port 9100
    python -m repro ratchet -- --attribute    # name a regressed routine

Every subcommand builds one RunConfig (``--config file.json`` loads a base;
explicit flags override it field by field) and drives a
:class:`~repro.api.Session` — no subcommand re-plumbs ingest, planning,
capability checks or checkpointing.  ``dryrun`` is the exception in
mechanism only: it re-execs ``repro.launch.dryrun`` in a subprocess because
the compile-matrix needs XLA_FLAGS set before jax initializes.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional, Sequence

from .config import ConfigError, RunConfig


# ---------------------------------------------------------------------------
# capability matrices (sourced from the registries, never hand-maintained)
# ---------------------------------------------------------------------------


def _table(rows: list[dict]) -> str:
    cols = list(rows[0]) if rows else []
    widths = {c: max(len(c), *(len(str(r[c])) for r in rows)) for c in cols}
    line = lambda r: "| " + " | ".join(
        str(r[c]).ljust(widths[c]) for c in cols) + " |"
    sep = "|" + "|".join("-" * (widths[c] + 2) for c in cols) + "|"
    return "\n".join([line({c: c for c in cols}), sep] + [line(r) for r in rows])


def list_methods() -> str:
    """Method capability matrix + executor matrix, from the registries."""
    from repro.methods import METHODS

    from .executor import executor_matrix

    rows = [{
        "method": name, "family": s.family, "kernel": s.kernel,
        "dist": "y" if s.supports_dist else "-",
        "streaming": "y" if s.supports_streaming else "-",
        "nonneg": "y" if s.nonnegative else "-",
        "order>3": "y" if s.supports_order_gt3 else "-",
    } for name, s in METHODS.items()]
    ex_rows = [{
        "executor": r["executor"], "requires": r["requires"],
        "methods": " ".join(r["methods"]), "description": r["description"],
    } for r in executor_matrix()]
    return ("# methods (repro.methods registry)\n" + _table(rows)
            + "\n\n# executors (repro.api registry)\n" + _table(ex_rows))


def list_impls() -> str:
    """Kernel-impl capability matrix for both registries (mttkrp + ttmc)."""
    from repro.core import REGISTRY, TTMC_REGISTRY

    out = []
    for kernel, reg in (("mttkrp", REGISTRY), ("ttmc", TTMC_REGISTRY)):
        rows = [{
            "impl": name, "layout": s.layout,
            "sorted": "y" if s.needs_sorted else "-",
            "order>3": "y" if s.supports_order_gt3 else "-",
            "backend": s.backend,
            "notes": ("benchmark-only" if s.benchmark_only
                      else "oracle" if s.oracle else "-"),
        } for name, s in reg.items()]
        out.append(f"# {kernel} impls (repro.core registry)\n" + _table(rows))
    return "\n\n".join(out)


# ---------------------------------------------------------------------------
# arg -> RunConfig
# ---------------------------------------------------------------------------


def _add_config_args(p: argparse.ArgumentParser) -> None:
    p.add_argument("--config", default=None, metavar="FILE.json",
                   help="RunConfig JSON to start from (flags override)")
    g = p.add_argument_group("data")
    g.add_argument("--source", default=None, help=".tns/.tnsb path")
    g.add_argument("--dataset", default=None,
                   help="synthetic paper replica (yelp/nell-2/netflix/...)")
    g.add_argument("--scale", type=float, default=None)
    g.add_argument("--data-seed", type=int, default=None)
    g.add_argument("--reorder", default=None)
    g.add_argument("--compact", action="store_true", default=None)
    g.add_argument("--cache", default=None, help="ingest cache root")
    g = p.add_argument_group("plan")
    g.add_argument("--impl", default=None,
                   help="planner policy: auto or a registered impl name")
    g.add_argument("--calibrate", action="store_true", default=None)
    g.add_argument("--recalibrate", action="store_true", default=None,
                   help="force a fresh measured pass, overwriting the "
                        "persisted autotune entry (implies --calibrate)")
    g = p.add_argument_group("method")
    g.add_argument("--method", default=None)
    g.add_argument("--rank", type=int, nargs="+", default=None,
                   help="int, or one int per mode (Tucker)")
    g.add_argument("--iters", type=int, default=None)
    g.add_argument("--tol", type=float, default=None)
    g.add_argument("--seed", type=int, default=None)
    g.add_argument("--option", action="append", default=[], metavar="K=V",
                   help="method option, JSON-valued (e.g. --option decay=0.9)")
    g = p.add_argument_group("exec")
    g.add_argument("--executor", default=None,
                   choices=["local", "dist", "streaming"])
    g.add_argument("--checkpoint-dir", default=None)
    g.add_argument("--checkpoint-every", type=int, default=None)
    g.add_argument("--monitor", action="store_true", default=None)
    g.add_argument("--n-chunks", type=int, default=None)
    g.add_argument("--chunk-nnz", type=int, default=None)
    g = p.add_argument_group("obs")
    g.add_argument("--trace-dir", default=None, metavar="DIR",
                   help="record a span trace + metrics there "
                        "(implies obs.enabled; read back with "
                        "`python -m repro trace DIR`)")
    g.add_argument("--trace-split", action="store_true", default=None,
                   help="trace the paper's full Table-III routine set "
                        "(ata/inverse/norm/fit) instead of the low-overhead "
                        "fused sort/mttkrp/epilogue split")
    g.add_argument("--http-port", type=int, default=None, metavar="PORT",
                   help="serve live /metrics + /healthz + /trace on "
                        "127.0.0.1:PORT for the duration of fit/serve "
                        "(implies obs.enabled; 0 = ephemeral port)")
    g.add_argument("--heartbeat-s", type=float, default=None, metavar="S",
                   help="atomically rewrite <trace-dir>/heartbeat.json "
                        "(metrics + recent events) every S seconds "
                        "(needs --trace-dir)")
    g.add_argument("--events-buffer", type=int, default=None, metavar="N",
                   help="flight-recorder ring capacity (events kept for "
                        "crash dumps / events.jsonl; default 1024)")
    g = p.add_argument_group("serve")
    g.add_argument("--port", type=int, default=None, metavar="PORT",
                   help="serve-daemon HTTP port (0 = ephemeral)")
    g.add_argument("--tenants", nargs="+", default=None, metavar="ID",
                   help="tenant ids to publish the fit under "
                        "(default: default)")
    g.add_argument("--serve-workers", type=int, default=None, metavar="N",
                   help="batch-executing worker threads")
    g.add_argument("--max-wait-ms", type=float, default=None, metavar="MS",
                   help="batch coalescing window from the first request")
    g.add_argument("--buckets", type=int, nargs="+", default=None,
                   metavar="N", help="padded batch-size buckets "
                                     "(strictly increasing)")
    g.add_argument("--budget-mb", type=float, default=None, metavar="MB",
                   help="registry resident-bytes LRU eviction budget")


def config_from_args(args: argparse.Namespace) -> RunConfig:
    """Layer CLI flags over (--config base or defaults), then validate once
    through RunConfig.from_dict so every error carries its field path."""
    if args.config:
        from pathlib import Path

        try:
            base = json.loads(Path(args.config).read_text())
        except OSError as e:
            raise ConfigError(f"--config {args.config}: {e}") from None
        except json.JSONDecodeError as e:
            raise ConfigError(
                f"--config {args.config}: not valid JSON ({e})") from None
        if not isinstance(base, dict):
            raise ConfigError(
                f"--config {args.config}: wants a JSON object, got "
                f"{type(base).__name__}")
    else:
        base = {}
    for section in ("data", "plan", "method", "exec", "obs", "serve"):
        base.setdefault(section, {})
        if not isinstance(base[section], dict):
            # catch before flag overlay: put() below would TypeError on it
            raise ConfigError(
                f"--config {args.config}: {section}: wants a mapping, got "
                f"{type(base[section]).__name__}")

    def put(section: str, key: str, val) -> None:
        if val is not None:
            base[section][key] = val

    put("data", "source", args.source)
    put("data", "dataset", args.dataset)
    put("data", "scale", args.scale)
    put("data", "seed", args.data_seed)
    put("data", "reorder", args.reorder)
    put("data", "compact", args.compact)
    put("data", "cache", args.cache)
    put("plan", "policy", args.impl)
    put("plan", "calibrate", args.calibrate)
    if getattr(args, "recalibrate", None):
        # the escape hatch implies a calibration run — setting only
        # plan.recalibrate would trip PlanConfig's requires-calibrate check
        base["plan"]["calibrate"] = True
        base["plan"]["recalibrate"] = True
    put("method", "name", args.method)
    if args.rank is not None:
        put("method", "rank",
            args.rank[0] if len(args.rank) == 1 else tuple(args.rank))
    put("method", "niters", args.iters)
    put("method", "tol", args.tol)
    put("method", "seed", args.seed)
    if args.option:
        opts = dict(base["method"].get("options", {}))
        for kv in args.option:
            k, sep, v = kv.partition("=")
            if not sep or not k:
                raise ConfigError(
                    f"--option {kv!r}: expected KEY=VALUE "
                    "(e.g. --option decay=0.9)")
            try:
                opts[k] = json.loads(v)
            except json.JSONDecodeError:
                opts[k] = v
        base["method"]["options"] = opts
    put("exec", "executor", args.executor)
    put("exec", "checkpoint_dir", args.checkpoint_dir)
    put("exec", "checkpoint_every", args.checkpoint_every)
    put("exec", "monitor", args.monitor)
    put("exec", "n_chunks", args.n_chunks)
    put("exec", "chunk_nnz", args.chunk_nnz)
    if getattr(args, "trace_dir", None):
        base["obs"]["enabled"] = True
        base["obs"]["trace_dir"] = args.trace_dir
    if getattr(args, "trace_split", None):
        base["obs"]["enabled"] = True
        base["obs"]["routines"] = "split"
    if getattr(args, "http_port", None) is not None:
        base["obs"]["enabled"] = True
        base["obs"]["http_port"] = args.http_port
    put("obs", "heartbeat_s", getattr(args, "heartbeat_s", None))
    put("obs", "events_buffer", getattr(args, "events_buffer", None))
    put("serve", "port", getattr(args, "port", None))
    if getattr(args, "tenants", None):
        base["serve"]["tenants"] = tuple(args.tenants)
    put("serve", "workers", getattr(args, "serve_workers", None))
    put("serve", "max_wait_ms", getattr(args, "max_wait_ms", None))
    if getattr(args, "buckets", None):
        base["serve"]["buckets"] = tuple(args.buckets)
    put("serve", "max_resident_mb", getattr(args, "budget_mb", None))
    return RunConfig.from_dict(base)


# ---------------------------------------------------------------------------
# subcommands
# ---------------------------------------------------------------------------


def cmd_ingest(args) -> int:
    from .session import Session

    cfg = config_from_args(args)
    sess = Session.from_config(cfg)
    t0 = time.time()
    ing = sess.ingest()
    dt = time.time() - t0
    print(f"# ingest: {cfg.summary()}")
    print(f"dims={ing.dims} nnz={ing.tensor.nnz:,} "
          f"reorder={cfg.data.reorder} cache_hit={ing.cache_hit} "
          f"wall={dt:.2f}s")
    for m, s in enumerate(ing.stats):
        print(f"  mode {m}: rows={s.rows} collision={s.block_collision_rate:.3f} "
              f"padding={s.padding_overhead:.3f} skew={s.skew:.3f}")
    return 0


def cmd_plan(args) -> int:
    from .session import Session

    cfg = config_from_args(args)
    print(f"# plan: {cfg.summary()}")
    print(Session.from_config(cfg).plan_report())
    return 0


def cmd_fit(args) -> int:
    import jax

    from .session import Session

    cfg = config_from_args(args)
    sess = Session.from_config(cfg)
    print(f"# fit: {cfg.summary()}")
    print(sess.plan_report())
    if args.dryrun:
        print("# --dryrun: plan only, skipping execution")
        return 0
    if cfg.obs.http_port is not None:
        # bring the endpoint up (and print the resolved port) BEFORE the
        # fit blocks, so a watcher can start curling immediately
        print(f"# live metrics at {sess.exposition().url}/metrics",
              flush=True)
    t0 = time.time()
    try:
        dec = sess.fit()
        jax.block_until_ready(dec.fit)
        if args.hold_s:
            # keep the live endpoints up for scrapers that arrived late
            # (the CI smoke curls a backgrounded fit through this window)
            time.sleep(args.hold_s)
    finally:
        sess.close()
    print(f"fit={float(dec.fit):.6f} wall={time.time() - t0:.2f}s")
    if cfg.obs.trace_dir:
        print(f"# trace written to {cfg.obs.trace_dir} "
              f"(python -m repro trace {cfg.obs.trace_dir})")
    if args.out:
        _save_factors(args.out, dec)
        print(f"# wrote {args.out}")
    return 0


def _save_factors(path: str, dec) -> None:
    import numpy as np

    arrays = {f"factor_{m}": np.asarray(f)
              for m, f in enumerate(dec.factors)}
    if hasattr(dec, "lmbda"):
        arrays["lmbda"] = np.asarray(dec.lmbda)
    if hasattr(dec, "core"):
        arrays["core"] = np.asarray(dec.core)
    arrays["fit"] = np.asarray(dec.fit)
    np.savez(path, **arrays)


def cmd_serve(args) -> int:
    from .session import Session

    cfg = config_from_args(args)
    sess = Session.from_config(cfg)
    print(f"# serve: {cfg.summary()}")
    print(sess.plan_report())
    import jax

    t0 = time.time()
    try:
        handle = sess.serve_handle()
        jax.block_until_ready(handle.decomp.fit)  # async dispatch: drain
        t_fit = time.time() - t0
        bench = handle.benchmark(queries=args.queries, batch=args.batch,
                                 seed=cfg.method.seed)
        lat = bench["latency_ms"]
        print(f"fit={handle.fit:.4f} decompose={t_fit:.2f}s "
              f"serve={bench['serve_s']:.2f}s ({bench['qps']:,.0f} vals/s, "
              f"p50 {lat['p50']:.2f}ms p99 {lat['p99']:.2f}ms)")
        sess.export_obs()  # serve spans + latency histogram join the trace
    finally:
        sess.close()
    return 0


def cmd_serve_daemon(args) -> int:
    """Fit (or load) the configured decomposition, publish it under every
    ``serve.tenants`` id, and serve the HTTP query API until
    ``POST /v1/shutdown`` (or ``--duration-s``)."""
    from repro.serve import ServeDaemon

    from .session import Session

    cfg = config_from_args(args)
    sess = Session.from_config(cfg)
    print(f"# serve-daemon: {cfg.summary()}")
    try:
        server = sess.decomp_server()  # fit + publish cfg.serve.tenants
        daemon = ServeDaemon(server, port=cfg.serve.port or 0).start()
        print(f"# serving {list(cfg.serve.tenants)} at {daemon.url}  "
              f"(GET /healthz /metrics /v1/tenants "
              f"/v1/top_k?tenant=&user=&k=; POST /v1/values_at "
              f"/v1/shutdown)", flush=True)
        try:
            daemon.serve_until_shutdown(duration_s=args.duration_s)
        finally:
            daemon.stop()
        stats = server.stats()
        print(f"# shutdown: {stats['batches_executed']} batches executed, "
              f"queue depth {stats['queue_depth']}")
    finally:
        sess.close()
    return 0


def cmd_trace(args) -> int:
    """Table-III-style per-routine breakdown of a recorded trace dir."""
    from repro.obs.report import trace_report

    try:
        print(trace_report(args.dir, with_metrics=not args.no_metrics))
    except FileNotFoundError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    return 0


def cmd_metrics(args) -> int:
    """Render a standalone ``metrics.json`` (or a trace dir holding one)
    as the markdown metrics table."""
    from pathlib import Path

    from repro.obs.report import format_metrics
    from repro.obs.trace import METRICS_FILENAME

    path = Path(args.dir)
    if path.is_dir():
        path = path / METRICS_FILENAME
    if not path.exists():
        print(f"error: no {METRICS_FILENAME} at {args.dir} — record one "
              f"with `python -m repro fit ... --trace-dir {args.dir}`",
              file=sys.stderr)
        return 2
    print(format_metrics(json.loads(path.read_text())))
    return 0


def cmd_ratchet(args) -> int:
    """Delegate to the benchmark-history perf ratchet (``benchmarks``
    imports only from the repo root, where ``python -m`` puts the cwd)."""
    try:
        from benchmarks.ratchet import main as ratchet_main
    except ImportError:
        print("error: the benchmarks package is not importable — run "
              "`python -m repro ratchet` from the repository root",
              file=sys.stderr)
        return 2
    fwd = list(args.ratchet_args)
    if fwd[:1] == ["--"]:  # REMAINDER keeps the separator; ratchet won't
        fwd = fwd[1:]
    return ratchet_main(fwd)


def cmd_dryrun(args) -> int:
    """Compile-matrix dry-run.  Re-execs ``repro.launch.dryrun`` in a fresh
    interpreter: the 512-placeholder-device XLA_FLAGS must be set before jax
    initializes, and this process has already imported jax."""
    import subprocess

    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", args.workload, "--mesh", args.mesh]
    if args.tag:
        cmd += ["--tag", args.tag]
    for ov in args.override:
        cmd += ["--override", ov]
    return subprocess.call(cmd)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro",
        description="One front door over the decomposition stack: "
                    "ingest -> plan -> fit -> serve (repro.api).")
    ap.add_argument("--list-methods", action="store_true",
                    help="print the method + executor capability matrices")
    ap.add_argument("--list-impls", action="store_true",
                    help="print the kernel-impl capability matrices")
    sub = ap.add_subparsers(dest="command")

    for name, fn, extra in (
            ("ingest", cmd_ingest, ()),
            ("plan", cmd_plan, ()),
            ("fit", cmd_fit, ("dryrun", "out")),
            ("serve", cmd_serve, ("queries", "batch")),
    ):
        p = sub.add_parser(name, help=f"{name} stage of the pipeline")
        _add_config_args(p)
        if "dryrun" in extra:
            p.add_argument("--dryrun", action="store_true",
                           help="print the plan and exit without fitting")
            p.add_argument("--hold-s", type=float, default=None, metavar="S",
                           help="keep the live exposition endpoints up S "
                                "seconds after the fit completes (for "
                                "scrapers watching a short run)")
        if "out" in extra:
            p.add_argument("--out", default=None, metavar="FACTORS.npz",
                           help="save factors/lambda/fit to an .npz")
        if "queries" in extra:
            p.add_argument("--queries", type=int, default=2048)
            p.add_argument("--batch", type=int, default=256)
        p.set_defaults(fn=fn)

    p = sub.add_parser(
        "serve-daemon",
        help="fit, publish under serve.tenants, and serve the HTTP query "
             "API (repro.serve.DecompServer) until POST /v1/shutdown")
    _add_config_args(p)
    p.add_argument("--duration-s", type=float, default=None, metavar="S",
                   help="exit after S seconds even without /v1/shutdown")
    p.set_defaults(fn=cmd_serve_daemon)

    p = sub.add_parser(
        "trace",
        help="print the Table-III-style per-routine breakdown of a "
             "recorded trace dir (see fit --trace-dir)")
    p.add_argument("dir", help="directory holding trace.jsonl/metrics.json")
    p.add_argument("--no-metrics", action="store_true",
                   help="skip the metrics dump, print the routine table only")
    p.set_defaults(fn=cmd_trace)

    p = sub.add_parser(
        "metrics",
        help="render a recorded metrics.json as the metrics table "
             "(see fit --trace-dir)")
    p.add_argument("dir", help="directory holding metrics.json (or the "
                               "file itself)")
    p.set_defaults(fn=cmd_metrics)

    p = sub.add_parser(
        "ratchet",
        help="benchmark-history perf ratchet (benchmarks/ratchet.py); "
             "pass --attribute to name the routine behind a regression")
    p.add_argument("ratchet_args", nargs=argparse.REMAINDER,
                   help="arguments forwarded to benchmarks.ratchet "
                        "(--history/--section/--tolerance/--attribute/...)")
    p.set_defaults(fn=cmd_ratchet)

    p = sub.add_parser("dryrun",
                       help="compile-matrix dry-run (repro.launch.dryrun)")
    p.add_argument("--workload", required=True,
                   help="cpals-<workload> or an arch id")
    p.add_argument("--mesh", choices=["single", "multi"], default="single")
    p.add_argument("--tag", default="")
    p.add_argument("--override", action="append", default=[])
    p.set_defaults(fn=cmd_dryrun)

    args = ap.parse_args(argv)
    if args.list_methods:
        print(list_methods())
        return 0
    if args.list_impls:
        print(list_impls())
        return 0
    if args.command is None:
        ap.print_help()
        return 2
    try:
        return args.fn(args)
    except (ConfigError, ValueError, OSError) as e:
        # OSError: a missing/unreadable --source or --cache path is a user
        # mistake, not a crash — same friendly exit as config errors
        print(f"error: {e}", file=sys.stderr)
        return 2


if __name__ == "__main__":
    sys.exit(main())
