"""repro.api — the single public API over the decomposition stack.

    config.py    RunConfig = DataConfig + PlanConfig + MethodConfig +
                 ExecConfig + ObsConfig + ServeConfig: frozen, validated,
                 JSON-round-trippable
    executor.py  ExecutorSpec registry (local / dist / streaming) + the one
                 method-capability gate (require_capability)
    session.py   Session.from_config -> .ingest() -> .plan() -> .fit() ->
                 .serve_handle(), lazy cached stages, checkpoint resume;
                 run(cfg) one-shot
    cli.py       python -m repro {ingest,plan,fit,serve,dryrun} and the
                 --list-methods / --list-impls capability matrices

Everything else under ``repro.*`` is either machinery this API drives
(core/plan/ingest/methods/dist/checkpoint) or legacy seed modules kept for
back-compat (``repro.models``, ``repro.optim``, the LM arch presets in
``repro.configs`` — see docs/architecture.md "Legacy LM substrate"); new
callers should enter through this package.
"""
from .config import (ConfigError, DataConfig, ExecConfig, MethodConfig,
                     ObsConfig, PlanConfig, RunConfig, ServeConfig)
from .executor import (EXECUTORS, ExecutorSpec, executor_matrix, get_executor,
                       register_executor, require_capability)
from .session import ServeHandle, Session, run

__all__ = [
    "ConfigError", "DataConfig", "PlanConfig", "MethodConfig", "ExecConfig",
    "ObsConfig", "ServeConfig", "RunConfig",
    "EXECUTORS", "ExecutorSpec", "executor_matrix", "get_executor",
    "register_executor", "require_capability",
    "ServeHandle", "Session", "run",
]
