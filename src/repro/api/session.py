"""``Session`` — the one front door: ingest -> plan -> fit -> serve.

    from repro.api import RunConfig, DataConfig, MethodConfig, Session

    cfg = RunConfig(data=DataConfig(source="data.tns"),
                    method=MethodConfig(name="cp_als", rank=35))
    sess = Session.from_config(cfg)
    ing    = sess.ingest()        # Ingested handle (stats, cache, relabel)
    plan   = sess.plan()          # per-mode DecompPlan (None for streaming)
    dec    = sess.fit()           # decomposition via the configured executor
    handle = sess.serve_handle()  # jitted batched values_at queries

Stages are lazy and cached: each runs at most once per session, later
stages trigger earlier ones, and ``repro.api.run(cfg)`` is the one-shot
``Session.from_config(cfg).fit()``.  With ``exec.checkpoint_dir`` set the
fit checkpoints every ``exec.checkpoint_every`` iterations through
``repro.checkpoint.CheckpointManager`` as the shared
:class:`~repro.methods.DecompState`, and a NEW session over the same config
resumes from the latest complete step — kill-safe long decompositions.
"""
from __future__ import annotations

import time
from contextlib import contextmanager

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import Histogram, get_registry

from .config import RunConfig
from .executor import get_executor, require_capability

# per-batch latency sampling in ServeHandle.benchmark: enough batches for
# stable p50/p99, few enough that the sync-per-batch probe stays cheap next
# to the async throughput loop it must not perturb
_LATENCY_SAMPLE_BATCHES = 64


class ServeHandle:
    """Batched reconstruction queries against a fitted decomposition.

    ``query(coords)`` takes an (n, order) int32 coordinate batch in the
    tensor's ORIGINAL label space (the session's ingest restored factor
    labels) and returns the reconstructed values; the underlying
    ``values_at`` is jitted once per coordinate-batch shape.

    ``tracer``: an optional :class:`repro.obs.Tracer`; queries then record
    ``serve.query`` spans (the Session passes its own when obs is on)."""

    def __init__(self, decomp, dims: tuple[int, ...], tracer=None):
        self.decomp = decomp
        self.dims = dims
        self._qfn = jax.jit(decomp.values_at)
        self._topk_fns = {}  # (user_mode, item_mode) -> jitted fn, k static
        self._tracer = tracer

    def query(self, coords) -> jax.Array:
        coords = jnp.asarray(coords, dtype=jnp.int32)
        if self._tracer is not None:
            with self._tracer.span("serve.query",
                                   batch=int(coords.shape[0])):
                return self._qfn(coords)
        return self._qfn(coords)

    def top_k_for_user(self, user: int, k: int, *, user_mode: int = 0,
                       item_mode: int = 1):
        """``(scores (k,), items (k,))`` — the k best items for one user,
        scored against ALL items via the factor matrices (item ids in the
        tensor's ORIGINAL label space).  The multi-tenant batching version
        lives in :meth:`Session.decomp_server`; this is the direct
        single-model path."""
        fn = self._topk_fns.get((user_mode, item_mode))
        if fn is None:
            from repro.serve.queries import make_top_k_fn

            fn = jax.jit(make_top_k_fn(self.decomp, user_mode=user_mode,
                                       item_mode=item_mode),
                         static_argnums=1)
            self._topk_fns[(user_mode, item_mode)] = fn
        users = jnp.asarray([int(user)], dtype=jnp.int32)
        if self._tracer is not None:
            with self._tracer.span("serve.top_k", k=int(k)):
                scores, items = fn(users, int(k))
        else:
            scores, items = fn(users, int(k))
        return scores[0], items[0]

    def benchmark(self, *, queries: int, batch: int, seed: int = 0) -> dict:
        """Timed random-coordinate query loop (the serving benchmark the
        CLI and ``launch/serve.py`` both report): uniform coordinates over
        the handle's dims, one warmup/compile batch, then ``queries``
        reconstructions in ``batch``-sized calls.

        Throughput (``serve_s``/``qps``) comes from the async pipelined
        loop — one device sync at the end, queries overlap.  Per-query
        latency is a *separate* smaller probe with a sync per batch (an
        async loop has no per-batch latency to report), summarized as a
        histogram: the ``latency_ms`` dict carries mean/p50/p90/p99 and
        the observations feed the ``serve.query_ms`` histogram in the
        metrics registry."""
        rng = np.random.default_rng(seed)
        n_batches = max(1, queries // batch)
        # n_batches + 1 batches: batch 0 is a DEDICATED warmup/compile
        # batch, never re-timed — re-timing it would make the first timed
        # batch warm-cache biased relative to the rest
        coords = jnp.asarray(np.stack(
            [rng.integers(0, d, (n_batches + 1, batch)) for d in self.dims],
            axis=-1).astype(np.int32))
        jax.block_until_ready(self.query(coords[0]))  # warmup/compile
        t0 = time.time()
        out = None
        for b in range(1, n_batches + 1):
            out = self.query(coords[b])
        jax.block_until_ready(out)
        serve_s = time.time() - t0

        hist = Histogram()
        registry_hist = get_registry().histogram("serve.query_ms")
        for b in range(1, min(n_batches, _LATENCY_SAMPLE_BATCHES) + 1):
            t1 = time.perf_counter()
            jax.block_until_ready(self.query(coords[b]))
            dt_ms = (time.perf_counter() - t1) * 1e3
            hist.observe(dt_ms)
            registry_hist.observe(dt_ms)
        qps = n_batches * batch / max(serve_s, 1e-9)
        # throughput next to the latency histogram, so the exposition
        # endpoint shows both sides of the serving story
        get_registry().gauge("serve.qps").set(qps)
        return {"serve_s": serve_s, "queries": n_batches * batch,
                "qps": qps, "latency_ms": hist.summary()}

    @property
    def fit(self) -> float:
        return float(self.decomp.fit)


class Session:
    """Lazy, cached, resumable pipeline over one :class:`RunConfig`.

    ``tensor`` optionally hands in-memory data to a config whose ``data``
    section names no source (the programmatic path the tests and benchmarks
    use): a :class:`~repro.core.coo.SparseTensor`, or an already-built
    :class:`~repro.ingest.Ingested` handle — the latter becomes the ingest
    stage as-is (its reorder/cache/tile choices win over ``data``'s), which
    is how several sessions share one ingest."""

    def __init__(self, cfg: RunConfig, tensor=None):
        if not isinstance(cfg, RunConfig):
            raise TypeError(
                f"Session wants a RunConfig, got {type(cfg).__name__}")
        if tensor is not None and (cfg.data.source or cfg.data.dataset):
            raise ValueError(
                "data.source: config already names a data source; drop it "
                "to pass an in-memory tensor")
        self.cfg = cfg
        self._tensor = tensor
        self._tracer = None
        self._recorder = None
        self._exposition = None
        self._heartbeat = None
        self._stage_name = None
        self._ing = None
        self._server = None
        self._plan = None
        self._plan_done = False
        self._result = None
        self._handle = None
        self._mesh = None
        self._key = None
        self._monitor = None
        self._ckpt_mgr = None
        self._resume_state = None
        self._resume_checked = False

    @classmethod
    def from_config(cls, cfg: RunConfig, tensor=None) -> "Session":
        return cls(cfg, tensor=tensor)

    # -- observability -----------------------------------------------------
    def tracer(self):
        """The session's one :class:`repro.obs.Tracer` (lazy; None with
        ``obs.enabled=false``) — every stage runs with it active, so spans
        from ingest/plan/fit/serve all land in one trace."""
        if self._tracer is None and self.cfg.obs.enabled:
            from repro.obs import Tracer

            o = self.cfg.obs
            self._tracer = Tracer(sample_rate=o.sample_rate,
                                  routines=o.routines,
                                  xla_annotations=o.xla_annotations)
        return self._tracer

    def recorder(self):
        """The session's flight recorder (lazy; None with obs off) —
        active during every stage, so instrumented modules'
        ``record_event`` calls land in its ring."""
        if self._recorder is None and self.cfg.obs.enabled:
            from repro.obs.recorder import FlightRecorder

            self._recorder = FlightRecorder(
                capacity=self.cfg.obs.events_buffer)
        return self._recorder

    def exposition(self):
        """The live ``/metrics`` + ``/healthz`` + ``/trace`` endpoint
        (started on first access when ``obs.http_port`` is set; None
        otherwise).  ``http_port=0`` binds an ephemeral port — read it
        back from ``session.exposition().port``."""
        if self._exposition is None and self.cfg.obs.http_port is not None:
            from repro.obs.exposition import ExpositionServer

            tracer = self.tracer()
            self._exposition = ExpositionServer(
                self.cfg.obs.http_port,
                events_fn=tracer.events if tracer is not None else None,
                info_fn=lambda: {"stage": self._stage_name,
                                 "run": self.cfg.summary()},
            ).start()
        return self._exposition

    def _start_live(self):
        """Bring up the live surfaces configured in ``obs``: the HTTP
        exposition endpoint and the heartbeat writer (both no-ops when
        their fields are unset)."""
        self.exposition()
        if self._heartbeat is None and self.cfg.obs.heartbeat_s > 0:
            from repro.obs.recorder import Heartbeat

            self._heartbeat = Heartbeat(
                self.cfg.obs.trace_dir, self.cfg.obs.heartbeat_s,
                registry_fn=lambda: get_registry().snapshot(),
                recorder=self.recorder(),
                info_fn=lambda: {"stage": self._stage_name}).start()

    def close(self):
        """Stop the live surfaces (heartbeat flushes a final snapshot;
        the exposition socket closes).  Idempotent; the CLI calls it
        after fit/serve, and both threads are daemons so an unclosed
        session still exits cleanly."""
        if self._server is not None:
            self._server.close()
            self._server = None
        if self._heartbeat is not None:
            self._heartbeat.stop()
            self._heartbeat = None
        if self._exposition is not None:
            self._exposition.stop()
            self._exposition = None

    def __enter__(self) -> "Session":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    @contextmanager
    def _stage(self, name: str):
        """Activate the session tracer + flight recorder and open a
        ``stage.<name>`` span around one pipeline stage (a no-op when obs
        is disabled — zero tracer traffic)."""
        tracer = self.tracer()
        if tracer is None:
            yield
            return
        recorder = self.recorder()
        prev, self._stage_name = self._stage_name, name
        try:
            with tracer.activate(), recorder.activate(), \
                    tracer.span(f"stage.{name}"):
                yield
        finally:
            self._stage_name = prev

    def export_obs(self):
        """Write ``trace.jsonl`` + ``metrics.json`` (+ ``events.jsonl``
        when the flight recorder saw traffic, + ``metrics-aggregated.json``
        when per-host snapshots exist) under ``obs.trace_dir`` — called
        after fit and after serve benchmarks; returns the trace path, or
        None when no trace dir is configured."""
        tracer = self.tracer()
        if tracer is None or not self.cfg.obs.trace_dir:
            return None
        from pathlib import Path

        from repro.obs.aggregate import aggregate_dir
        from repro.obs.recorder import EVENTS_FILENAME
        from repro.obs.trace import METRICS_FILENAME, TRACE_FILENAME

        d = Path(self.cfg.obs.trace_dir)
        path = tracer.export_jsonl(d / TRACE_FILENAME)
        (d / METRICS_FILENAME).write_text(get_registry().to_json())
        recorder = self.recorder()
        if recorder is not None and recorder.recorded:
            recorder.export_jsonl(d / EVENTS_FILENAME)
        # dist runs drop metrics-<host>.json next to the trace; fold them
        # into one cluster view (None / no-op for single-process runs)
        aggregate_dir(d, write=True)
        return path

    # -- stage 1: ingest ---------------------------------------------------
    def load_tensor(self):
        """The raw tensor (before ingest options): the in-memory one, the
        synthetic paper replica, or the file source read by the ingest
        reader."""
        from repro.core import paper_dataset
        from repro.ingest import reader

        d = self.cfg.data
        if self._tensor is not None:
            return self._tensor
        if d.dataset is not None:
            self._tensor = paper_dataset(
                d.dataset, jax.random.PRNGKey(d.seed), scale=d.scale)
            return self._tensor
        if d.source is None:
            raise ValueError(
                "data.source: config names no data (no source, no dataset) "
                "and no in-memory tensor was passed to Session.from_config")
        self._tensor = reader.read_any(d.source, dims=d.dims,
                                       duplicates=d.duplicates)
        return self._tensor

    def ingest(self):
        """The :class:`~repro.ingest.Ingested` handle (cached): relabeled
        tensor + per-mode stats + (possibly cache-warm) CSF workspaces.  A
        pre-built handle passed to :meth:`from_config` is adopted as-is."""
        if self._ing is None:
            from repro.ingest import Ingested, ingest

            if isinstance(self._tensor, Ingested):
                self._ing = self._tensor
                return self._ing
            d = self.cfg.data
            x = d.source if (d.source and self._tensor is None) \
                else self.load_tensor()
            with self._stage("ingest"):
                self._ing = ingest(x, reorder=d.reorder, compact=d.compact,
                                   cache=d.cache, tile=d.tile, dims=d.dims,
                                   duplicates=d.duplicates, seed=d.seed)
        return self._ing

    def chunk_source(self):
        """What the streaming executor folds: the file path itself when the
        data is on disk with no ingest transforms (true streaming — never
        one COO in memory), else the ingested handle.  A non-default
        duplicates policy also forces the ingest path: chunk folds sum
        scatter contributions, which IS "sum" but cannot "keep" or
        "error"."""
        from repro.core.coo import SparseTensor

        d = self.cfg.data
        if d.reorder == "identity" and not d.compact:
            if (d.source is not None and self._tensor is None
                    and d.duplicates == "sum"):
                return d.source
            if isinstance(self._tensor, SparseTensor):
                # no transforms requested: the fold splits the tensor
                # directly, skipping ingest's per-mode stats pass entirely
                return self._tensor
        return self.ingest()

    # -- stage 2: plan -----------------------------------------------------
    def plan(self):
        """The per-mode :class:`~repro.plan.DecompPlan` (cached), scored
        against the method's declared kernel registry at the method's rank
        (Kronecker widths for the ttmc kernel).  Streaming methods fold
        unsorted chunks and never execute a per-mode plan -> None."""
        if self._plan_done:
            return self._plan
        from repro.methods import get_method

        cfg = self.cfg
        spec = get_method(cfg.method.name)
        if spec.supports_streaming:
            # streaming folds unsorted chunks through gather_scatter only —
            # a pinned policy, a calibration pass, or an allow set that
            # excludes gather_scatter cannot be honored, so reject instead
            # of silently ignoring the validated setting
            if (cfg.plan.policy not in ("auto", "gather_scatter")
                    or cfg.plan.calibrate
                    or (cfg.plan.allow is not None
                        and "gather_scatter" not in cfg.plan.allow)):
                from .config import ConfigError

                raise ConfigError(
                    f"plan.policy: streaming method {cfg.method.name!r} "
                    f"executes gather_scatter chunk folds only (no sorted "
                    f"workspace is ever built) — drop the pinned policy/"
                    f"calibration or pick a batch method")
            self._plan, self._plan_done = None, True
            return None
        ing = self.ingest()
        allow = cfg.plan.allow
        if cfg.exec.executor == "dist":
            # restrict candidates to what the shard_map body expresses —
            # the ONE set core.distributed declares, not a private copy;
            # an allow entry the body cannot express is rejected, never
            # silently filtered (the user believed it was a candidate)
            from repro.core.distributed import DIST_IMPLS

            inexpressible = tuple(a for a in (allow or ())
                                  if a not in DIST_IMPLS)
            if inexpressible:
                from .config import ConfigError

                raise ConfigError(
                    f"plan.allow: {inexpressible} cannot execute under the "
                    f"dist executor; the shard_map body expresses only "
                    f"{DIST_IMPLS}")
            allow = allow or DIST_IMPLS
        factor_ranks = None
        if spec.kernel == "ttmc":
            from repro.methods.tucker_hooi import _kron_widths, _resolve_ranks

            factor_ranks = _resolve_ranks(cfg.method.rank, ing.dims)
            rank = _kron_widths(factor_ranks)
        else:
            rank = cfg.method.rank
        with self._stage("plan"):
            self._plan = ing.plan(cfg.plan.policy, rank=rank,
                                  kernel=spec.kernel,
                                  backend=cfg.plan.backend, allow=allow,
                                  calibrate=cfg.plan.calibrate,
                                  factor_ranks=factor_ranks,
                                  recalibrate=cfg.plan.recalibrate)
        rec = self.recorder()
        if rec is not None:
            rec.record("plan", policy=cfg.plan.policy,
                       impls=list(self._plan.impls),
                       calibrated=cfg.plan.calibrate)
        self._plan_done = True
        return self._plan

    def plan_report(self) -> str:
        """The human-readable per-mode planner table (serve/dryrun print),
        with a provenance footer surfacing the ingest-cache and autotune
        hit/miss counters behind this session's plan."""
        from repro.utils.report import plan_report

        plan = self.plan()
        if plan is None:
            return (f"# method={self.cfg.method.name}: chunked "
                    "gather_scatter fold, no per-mode plan")
        return plan_report(plan, reorder_deltas=self.ingest().reorder_deltas(),
                           method=self.cfg.method.name,
                           provenance=self._plan_provenance())

    def _plan_provenance(self) -> dict:
        """Cache provenance for the plan_report footer: whether this
        ingest was warm, and the per-store hit/miss counters."""
        ing = self.ingest()
        prov = {"cache_hit": ing.cache_hit}
        if ing.cache is not None:
            prov["ingest"] = {"hits": ing.cache.hits,
                              "misses": ing.cache.misses}
            store = ing.cache.autotune
            prov["autotune"] = {"hits": store.hits, "misses": store.misses}
        return prov

    # -- stage 3: fit ------------------------------------------------------
    def fit(self, *, force: bool = False):
        """The decomposition, computed by the configured executor (cached;
        ``force=True`` re-runs — the benchmark's overhead probe).

        With ``obs.trace_dir`` set, an unhandled executor exception
        leaves a ``crash.json`` postmortem (traceback + config + metrics
        + flight-recorder tail) before re-raising."""
        if self._result is None or force:
            ex = get_executor(self.cfg.exec.executor)
            require_capability(self.cfg.method.name, ex.name)
            self._start_live()
            try:
                with self._stage("fit"):
                    self._result = ex.fn(self)
            except Exception as exc:
                self._write_crash_dump(exc)
                raise
            self.export_obs()
        return self._result

    def _write_crash_dump(self, exc: BaseException):
        if not self.cfg.obs.trace_dir:
            return None
        from repro.obs.recorder import write_crash_dump

        return write_crash_dump(self.cfg.obs.trace_dir, exc,
                                recorder=self.recorder(),
                                metrics=get_registry().snapshot(),
                                config=self.cfg.to_dict(),
                                stage="fit")

    # -- stage 4: serve ----------------------------------------------------
    def serve_handle(self) -> ServeHandle:
        """Jitted batched-query handle over the fitted decomposition (runs
        the fit if it has not happened yet; cached like every other stage —
        per-call handles would re-jit ``values_at`` on each request)."""
        if self._handle is None or self._handle.decomp is not self._result:
            dec = self.fit()
            if self._ing is not None:
                dims = self._ing.original_dims
            else:  # streaming straight off a path: dims from factor rows
                dims = tuple(int(f.shape[0]) for f in dec.factors)
            self._handle = ServeHandle(dec, tuple(dims),
                                       tracer=self.tracer())
        return self._handle

    def decomp_server(self):
        """The continuous-batching multi-tenant server
        (:class:`repro.serve.DecompServer`, cached), configured from the
        ``serve`` section with this session's fit published under every
        ``serve.tenants`` id.  Runs fit if needed; ``close()`` drains and
        stops it."""
        if self._server is None:
            from repro.serve import DecompServer

            handle = self.serve_handle()  # fit + original-label dims
            self._server = DecompServer.from_config(self.cfg.serve)
            self._stage_name = "serve"
            for tenant in self.cfg.serve.tenants:
                self._server.publish(tenant, handle.decomp, handle.dims)
            self._start_live()
        return self._server

    # -- executor plumbing (consumed by repro.api.executor) ----------------
    def method_key(self):
        """The factor-init PRNG key (cached: key creation is a device op,
        and re-fitting the same session must reuse the same key anyway)."""
        if getattr(self, "_key", None) is None:
            self._key = jax.random.PRNGKey(self.cfg.method.seed)
        return self._key

    def mesh(self):
        """The dist executor's device mesh: ``exec.mesh_shape`` verbatim;
        else with ``exec.multi_pod`` the production pod mesh
        (``launch.mesh.make_production_mesh`` — needs the simulated device
        count); else every local device on the 'data' axis."""
        if self._mesh is None:
            from repro.dist.collectives import make_mesh

            shape = self.cfg.exec.mesh_shape
            if shape is None and self.cfg.exec.multi_pod:
                from repro.launch.mesh import make_production_mesh

                self._mesh = make_production_mesh(multi_pod=True)
                return self._mesh
            if shape is None:
                shape = {"data": len(jax.devices()), "model": 1}
            self._mesh = make_mesh(tuple(shape.values()), tuple(shape))
        return self._mesh

    def monitor(self):
        """The per-iteration StragglerMonitor, when configured."""
        if self._monitor is None and self.cfg.exec.monitor:
            from repro.dist import StragglerMonitor

            e = self.cfg.exec
            self._monitor = StragglerMonitor(window=e.monitor_window,
                                             threshold=e.monitor_threshold,
                                             patience=e.monitor_patience)
        return self._monitor

    def checkpoint_manager(self):
        if self._ckpt_mgr is None and self.cfg.exec.checkpoint_dir:
            from repro.checkpoint import CheckpointManager

            self._ckpt_mgr = CheckpointManager(self.cfg.exec.checkpoint_dir,
                                               async_save=False)
        return self._ckpt_mgr

    def checkpoint_cb(self):
        """The fit's checkpoint callback: every ``checkpoint_every``-th
        :class:`DecompState` goes through the manager's atomic save."""
        mgr = self.checkpoint_manager()
        if mgr is None:
            return None
        every = self.cfg.exec.checkpoint_every
        extra = {"method": self.cfg.method.name,
                 "rank": self._rank_record(), "seed": self.cfg.method.seed}

        def cb(state):
            it = int(state.iteration)
            if it % every == 0:
                mgr.save(it, state, extra=dict(extra))
        return cb

    def _rank_record(self):
        """JSON-safe rank for checkpoint provenance (tuples become lists)."""
        r = self.cfg.method.rank
        return list(r) if isinstance(r, tuple) else r

    def resume_state(self):
        """The latest complete checkpointed :class:`DecompState` under
        ``exec.checkpoint_dir`` (None when absent) — what makes a re-created
        Session continue a killed fit bit-exactly."""
        if self._resume_checked:
            return self._resume_state
        self._resume_checked = True
        mgr = self.checkpoint_manager()
        if mgr is None or mgr.latest_step() is None:
            return None
        step = mgr.latest_step()
        # validate provenance BEFORE the structural restore: a foreign
        # method's state has a different pytree shape and would die with an
        # opaque leaf-count assert instead of this error.  read_extra loads
        # only the metadata, not the factor arrays.
        extra = mgr.read_extra(step)
        if extra.get("method") not in (None, self.cfg.method.name):
            raise ValueError(
                f"exec.checkpoint_dir: checkpoint at step {extra['step']} "
                f"was written by method {extra['method']!r}, config says "
                f"{self.cfg.method.name!r}")
        # rank/seed mismatches would resume into a silently-wrong result
        # (e.g. rank-4 factors answering a rank-8 request) — reject them
        # like the method mismatch (absent keys = pre-provenance checkpoint)
        for field, want in (("rank", self._rank_record()),
                            ("seed", self.cfg.method.seed)):
            have = extra.get(field)
            if have is not None and have != want:
                raise ValueError(
                    f"exec.checkpoint_dir: checkpoint at step "
                    f"{extra['step']} was written with method.{field}="
                    f"{have!r}, config says {want!r}")
        state, _ = mgr.restore(self._blank_state(), step=step)
        self._resume_state = state
        return state

    def _blank_state(self):
        """A structure-only DecompState template for checkpoint restore
        (leaf shapes come from the npz; only the pytree structure counts).
        The aux key set is method knowledge — ``MethodSpec.state_aux``
        declares it, so a newly registered method resumes without touching
        this code."""
        from repro.methods import DecompState, get_method

        d = self.cfg.data
        if self._ing is not None:
            order = len(self._ing.dims)
        elif hasattr(self._tensor, "order"):
            order = self._tensor.order
        elif d.dims is not None:
            order = len(d.dims)
        elif d.source is not None and self._tensor is None:
            from repro.ingest.reader import open_chunk_source

            order = len(open_chunk_source(d.source).dims)
        else:
            order = len(self.ingest().dims)
        aux = {k: jnp.zeros(())
               for k in get_method(self.cfg.method.name).state_aux}
        z = jnp.zeros(())
        return DecompState(tuple(jnp.zeros(()) for _ in range(order)),
                           aux, z, z, jnp.zeros((), jnp.int32))


def run(cfg: RunConfig, tensor=None):
    """One-shot: ``Session.from_config(cfg, tensor).fit()``."""
    return Session.from_config(cfg, tensor=tensor).fit()
