"""Declarative run configuration — the one parameter surface for the stack.

A decomposition run is six frozen dataclasses composed into a
:class:`RunConfig`:

    RunConfig(
        data=DataConfig(source="data.tns", reorder="degree_sort",
                        cache=".cache/ingest"),
        plan=PlanConfig(policy="auto"),
        method=MethodConfig(name="cp_als", rank=35, niters=20),
        exec=ExecConfig(executor="local"),
        obs=ObsConfig(enabled=True, trace_dir="artifacts/trace"),
    )

Every field is validated at construction; a bad value raises
:class:`ConfigError` naming the offending field (``method.rank: ...``), and
an unknown key in :meth:`RunConfig.from_dict` is rejected with its full path
plus the nearest valid name.  ``to_dict``/``from_dict`` (and the JSON
convenience wrappers) round-trip bit-exactly:

    RunConfig.from_json(cfg.to_json()) == cfg

which is what makes a config file, a CLI invocation and a programmatic
``repro.api.run(cfg)`` interchangeable descriptions of the same run.
"""
from __future__ import annotations

import dataclasses
import difflib
import json
from typing import Any, Optional, Sequence, Union


class ConfigError(ValueError):
    """A RunConfig field failed validation; the message names the field."""


def _suggest(name: str, candidates: Sequence[str]) -> str:
    """'; did you mean X?' when a close match exists, else ''."""
    close = difflib.get_close_matches(name, list(candidates), n=1, cutoff=0.5)
    return f"; did you mean {close[0]!r}?" if close else ""


def _err(section: str, field: str, msg: str) -> ConfigError:
    return ConfigError(f"{section}.{field}: {msg}")


def _require(cond: bool, section: str, field: str, msg: str) -> None:
    if not cond:
        raise _err(section, field, msg)


# ---------------------------------------------------------------------------
# the sections
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DataConfig:
    """Where the tensor comes from and how it is ingested.

    Exactly one of ``source`` (a ``.tns``/``.tnsb`` path), ``dataset`` (a
    synthetic paper replica from ``repro.core.PAPER_DATASETS``, scaled by
    ``scale``), or an in-memory tensor handed to
    :meth:`~repro.api.Session.from_config` describes the bytes; the rest of
    the fields are the ``repro.ingest`` options (reorder / compact / cache /
    tile geometry / reader hints)."""

    _section = "data"

    source: Optional[str] = None
    dataset: Optional[str] = None
    scale: float = 1.0
    seed: int = 0
    dims: Optional[tuple[int, ...]] = None
    duplicates: str = "sum"
    reorder: str = "identity"
    compact: bool = False
    cache: Optional[str] = None
    tile: tuple[int, int] = (512, 128)

    def __post_init__(self):
        from repro.ingest import DUPLICATE_POLICIES, REORDERINGS

        _canon_field(self, "dims")
        _canon_field(self, "tile")
        s = self._section
        _require(not (self.source and self.dataset), s, "source",
                 "give either a file source or a synthetic dataset, not both")
        if self.dataset is not None:
            from repro.core import PAPER_DATASETS

            _require(self.dataset in PAPER_DATASETS, s, "dataset",
                     f"unknown dataset {self.dataset!r}; one of "
                     f"{tuple(PAPER_DATASETS)}"
                     + _suggest(self.dataset, PAPER_DATASETS))
        _require(self.scale > 0.0, s, "scale",
                 f"must be > 0, got {self.scale}")
        _require(self.duplicates in DUPLICATE_POLICIES, s, "duplicates",
                 f"unknown policy {self.duplicates!r}; one of "
                 f"{tuple(DUPLICATE_POLICIES)}"
                 + _suggest(self.duplicates, DUPLICATE_POLICIES))
        _require(self.reorder in REORDERINGS, s, "reorder",
                 f"unknown reordering {self.reorder!r}; one of "
                 f"{tuple(REORDERINGS)}"
                 + _suggest(self.reorder, REORDERINGS))
        _require(len(self.tile) == 2
                 and all(int(v) > 0 for v in self.tile), s, "tile",
                 f"must be a positive (block, row_tile) pair, got {self.tile}")


@dataclasses.dataclass(frozen=True)
class PlanConfig:
    """Per-mode planner policy (``repro.plan``).

    ``policy``: ``"auto"`` (cost-model argmin per mode) or a registered
    kernel-impl name that pins every mode.  ``calibrate`` replaces the cost
    models with measured timings on the actual tensor — persisted in the
    ingest cache's autotune store when ``data.cache`` is set, so only the
    first run times anything.  ``recalibrate`` is the escape hatch: force a
    fresh measured pass and overwrite the stored entry (requires
    ``calibrate``; the CLI's ``--recalibrate`` sets both).  ``allow``
    restricts the candidate set; ``backend`` overrides backend detection."""

    _section = "plan"

    policy: str = "auto"
    calibrate: bool = False
    recalibrate: bool = False
    backend: Optional[str] = None
    allow: Optional[tuple[str, ...]] = None

    def __post_init__(self):
        _canon_field(self, "allow")
        names = _known_impl_names()
        _require(self.policy == "auto" or self.policy in names,
                 self._section, "policy",
                 f"unknown impl {self.policy!r}; 'auto' or one of {names}"
                 + _suggest(self.policy, names))
        _require(not self.recalibrate or self.calibrate,
                 self._section, "recalibrate",
                 "requires plan.calibrate=true (a recalibration IS a "
                 "calibration run; the CLI's --recalibrate sets both)")
        if self.allow is not None:
            for a in self.allow:
                _require(a in names, self._section, "allow",
                         f"unknown impl {a!r}; one of {names}"
                         + _suggest(a, names))


def _known_impl_names() -> tuple[str, ...]:
    """Union of the kernel-impl registries (MTTKRP + TTMc)."""
    from repro.core import REGISTRY, TTMC_REGISTRY

    return tuple(dict.fromkeys(list(REGISTRY) + list(TTMC_REGISTRY)))


@dataclasses.dataclass(frozen=True)
class MethodConfig:
    """Which decomposition to compute (``repro.methods`` registry).

    ``rank`` is an int for the CP family, an int or per-mode tuple for
    Tucker.  ``seed`` derives the factor-init PRNG key.  ``options`` carries
    method-specific keywords (``decay=``, ``first_norm=``, ``timers=``, ...)
    forwarded verbatim to the registered implementation."""

    _section = "method"

    name: str = "cp_als"
    rank: Union[int, tuple[int, ...]] = 16
    niters: int = 20
    tol: float = 0.0
    seed: int = 0
    options: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        from repro.methods import METHODS

        # canonicalize sequence-valued options to tuples so the JSON
        # round-trip (which can only carry lists) reproduces an EQUAL
        # config — the bit-exact contract covers option payloads too
        object.__setattr__(self, "options", _canon_options(self.options))
        _canon_field(self, "rank")
        s = self._section
        # options that shadow section-backed kwargs would be silently
        # overwritten at dispatch (the executor composes niters/tol/key/...
        # from the sections); reject the collision at construction
        reserved = _RESERVED_OPTIONS & set(self.options)
        _require(not reserved, s, "options",
                 f"{sorted(reserved)} collide with section-backed settings; "
                 "configure them via method.niters/method.tol/method.seed/"
                 "plan.policy/exec.* instead")
        _require(self.name in METHODS, s, "name",
                 f"unknown method {self.name!r}; one of {tuple(METHODS)}"
                 + _suggest(self.name, METHODS))
        ranks = self.rank if isinstance(self.rank, tuple) else (self.rank,)
        _require(len(ranks) > 0 and all(
            isinstance(r, int) and r > 0 for r in ranks), s, "rank",
            f"must be a positive int or tuple of positive ints, "
            f"got {self.rank!r}")
        _require(self.niters >= 1, s, "niters",
                 f"must be >= 1, got {self.niters}")
        _require(self.tol >= 0.0, s, "tol",
                 f"must be >= 0, got {self.tol}")


# method.options keys the executors compose from the config sections; a
# user option with one of these names would either be dropped or shadow
# the section value (n_chunks/chunk_nnz/dims are exec/data-section-owned)
_RESERVED_OPTIONS = {"rank", "method", "niters", "tol", "key", "seed",
                     "state", "checkpoint_cb", "monitor", "plan", "impl",
                     "n_chunks", "chunk_nnz", "dims"}


def _canon_field(cfg, name: str) -> None:
    """Frozen-dataclass field canonicalization: a list-valued sequence field
    (Python callers can pass lists; JSON always does) becomes the tuple the
    bit-exact round-trip contract compares against."""
    v = getattr(cfg, name)
    if isinstance(v, list):
        object.__setattr__(cfg, name,
                           tuple(tuple(e) if isinstance(e, list) else e
                                 for e in v))


def _canon_options(v):
    """Lists/tuples -> tuples, recursively through dicts (JSON-expressible
    payloads only; other values pass through untouched).  Dicts keep their
    object identity when nothing inside changed: options like
    ``{"timers": {}}`` are out-params whose reference the caller reads
    back after the fit."""
    if isinstance(v, (list, tuple)):
        return tuple(_canon_options(e) for e in v)
    if isinstance(v, dict):
        new = {k: _canon_options(e) for k, e in v.items()}
        return v if all(new[k] is v[k] for k in v) else new
    return v


@dataclasses.dataclass(frozen=True)
class ExecConfig:
    """How and where the method executes (``repro.api.executor`` registry).

    ``executor``: ``"local"`` (single-process ``methods.fit``), ``"dist"``
    (the medium-grained shard_map driver over a mesh), or ``"streaming"``
    (chunked folds from an ``ingest.reader`` chunk source).  ``mesh_shape``
    maps axis names to extents for the dist executor (default: every local
    device on the ``data`` axis).  ``monitor*`` configure the per-iteration
    :class:`repro.dist.StragglerMonitor`; ``checkpoint_dir``/``_every``
    attach a :class:`repro.checkpoint.CheckpointManager` so a killed fit
    resumes from its last complete :class:`repro.methods.DecompState`."""

    _section = "exec"

    executor: str = "local"
    mesh_shape: Optional[dict] = None
    multi_pod: bool = False
    shard_c: bool = False
    mode_order: str = "natural"
    monitor: bool = False
    monitor_window: int = 8
    monitor_threshold: float = 1.5
    monitor_patience: int = 3
    chunk_nnz: int = 1 << 20
    n_chunks: Optional[int] = None
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 1

    def __post_init__(self):
        from .executor import EXECUTORS

        s = self._section
        _require(self.executor in EXECUTORS, s, "executor",
                 f"unknown executor {self.executor!r}; one of "
                 f"{tuple(EXECUTORS)}"
                 + _suggest(self.executor, EXECUTORS))
        _require(self.mode_order in ("natural", "auto"), s, "mode_order",
                 f"must be 'natural' or 'auto', got {self.mode_order!r}")
        if self.mesh_shape is not None:
            _require(all(isinstance(v, int) and v > 0
                         for v in self.mesh_shape.values()), s, "mesh_shape",
                     f"axis extents must be positive ints, "
                     f"got {self.mesh_shape}")
        _require(self.chunk_nnz > 0, s, "chunk_nnz",
                 f"must be > 0, got {self.chunk_nnz}")
        _require(self.n_chunks is None or self.n_chunks > 0, s, "n_chunks",
                 f"must be > 0, got {self.n_chunks}")
        _require(self.checkpoint_every >= 1, s, "checkpoint_every",
                 f"must be >= 1, got {self.checkpoint_every}")


@dataclasses.dataclass(frozen=True)
class ObsConfig:
    """Observability (``repro.obs``): structured tracing + metrics.

    ``enabled`` turns span recording on — the fit drivers then take their
    per-routine timed path so spans carry honest durations.  ``trace_dir``
    makes the Session export ``trace.jsonl`` (Chrome-trace/Perfetto JSONL;
    read it back with ``python -m repro trace <dir>``) and ``metrics.json``
    there after fit/serve.  ``sample_rate`` keeps that fraction of root
    spans (deterministic stride).  ``routines`` picks the traced routine
    set: ``"fused"`` (sort/mttkrp/epilogue — two syncs per mode, the
    low-overhead default) or ``"split"`` (the paper's full Table III:
    ata/inverse/norm/fit, one sync per routine).  ``xla_annotations``
    mirrors spans into ``jax.profiler.TraceAnnotation`` so they show up
    inside XLA profiles.

    The live half (phase 2): ``http_port`` starts the Prometheus
    exposition endpoint (``/metrics`` + ``/healthz`` + ``/trace``) on
    127.0.0.1 for the duration of fit/serve — 0 binds an ephemeral port,
    read back from ``Session.exposition.port``.  ``heartbeat_s`` > 0
    atomically rewrites ``<trace_dir>/heartbeat.json`` (metrics + recent
    events + stage) at that interval so a live or killed run can be
    inspected from the filesystem.  ``events_buffer`` bounds the flight
    recorder's event ring (the crash-dump / events.jsonl tail)."""

    _section = "obs"

    enabled: bool = False
    trace_dir: Optional[str] = None
    sample_rate: float = 1.0
    routines: str = "fused"
    xla_annotations: bool = True
    http_port: Optional[int] = None
    heartbeat_s: float = 0.0
    events_buffer: int = 1024

    def __post_init__(self):
        s = self._section
        _require(0.0 < self.sample_rate <= 1.0, s, "sample_rate",
                 f"must be in (0, 1], got {self.sample_rate}")
        _require(self.routines in ("fused", "split"), s, "routines",
                 f"must be 'fused' or 'split', got {self.routines!r}"
                 + _suggest(self.routines, ("fused", "split")))
        _require(self.trace_dir is None or self.enabled, s, "trace_dir",
                 "set obs.enabled=true to record a trace "
                 "(a trace_dir with tracing off would silently write "
                 "nothing)")
        if self.http_port is not None:
            _require(isinstance(self.http_port, int)
                     and 0 <= self.http_port <= 65535, s, "http_port",
                     f"must be a port in [0, 65535] (0 = ephemeral), "
                     f"got {self.http_port!r}")
            _require(self.enabled, s, "http_port",
                     "set obs.enabled=true to expose live metrics "
                     "(an endpoint over a disabled registry would serve "
                     "nothing)")
        _require(self.heartbeat_s >= 0.0, s, "heartbeat_s",
                 f"must be >= 0 (0 = off), got {self.heartbeat_s}")
        _require(self.heartbeat_s == 0.0 or self.trace_dir is not None,
                 s, "heartbeat_s",
                 "requires obs.trace_dir (heartbeat snapshots are "
                 "written under the trace directory)")
        _require(isinstance(self.events_buffer, int)
                 and self.events_buffer >= 1, s, "events_buffer",
                 f"must be >= 1, got {self.events_buffer!r}")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """The serving layer (``repro.serve``): continuous batching + tenancy.

    ``buckets`` are the padded batch sizes the worker coalesces into
    (strictly increasing; each bucket shape jits exactly once, and
    anything larger than the last bucket is chunked).  ``max_wait_ms`` is
    the coalescing window measured from the first request in a batch —
    the latency a caller trades for batch fill.  ``workers`` is the
    number of batch-executing threads.  ``tenants`` names the models
    ``serve-daemon`` publishes from the session's fit; ``max_resident_mb``
    is the registry's LRU eviction budget over all resident models.
    ``port`` binds the daemon's HTTP frontend (0 = ephemeral, read back
    from ``ServeDaemon.port``; None = library use, no HTTP)."""

    _section = "serve"

    buckets: tuple[int, ...] = (16, 64, 256)
    max_wait_ms: float = 2.0
    workers: int = 1
    tenants: tuple[str, ...] = ("default",)
    max_resident_mb: float = 256.0
    port: Optional[int] = None

    def __post_init__(self):
        _canon_field(self, "buckets")
        _canon_field(self, "tenants")
        s = self._section
        _require(len(self.buckets) > 0, s, "buckets",
                 "need at least one batch bucket")
        _require(all(isinstance(b, int) and b > 0 for b in self.buckets),
                 s, "buckets",
                 f"bucket sizes must be positive ints, got {self.buckets}")
        _require(all(a < b for a, b in zip(self.buckets, self.buckets[1:])),
                 s, "buckets",
                 f"bucket sizes must be strictly increasing, "
                 f"got {self.buckets}")
        _require(self.max_wait_ms >= 0.0, s, "max_wait_ms",
                 f"must be >= 0 (0 = no coalescing wait), "
                 f"got {self.max_wait_ms}")
        _require(isinstance(self.workers, int) and self.workers >= 1,
                 s, "workers", f"must be >= 1, got {self.workers!r}")
        _require(len(self.tenants) > 0, s, "tenants",
                 "need at least one tenant id")
        _require(all(isinstance(t, str) and t for t in self.tenants),
                 s, "tenants",
                 f"tenant ids must be non-empty strings, got {self.tenants}")
        _require(len(set(self.tenants)) == len(self.tenants), s, "tenants",
                 f"tenant ids must be unique, got {self.tenants}")
        _require(self.max_resident_mb > 0, s, "max_resident_mb",
                 f"eviction budget must be > 0, got {self.max_resident_mb}")
        if self.port is not None:
            _require(isinstance(self.port, int) and 0 <= self.port <= 65535,
                     s, "port",
                     f"must be a port in [0, 65535] (0 = ephemeral), "
                     f"got {self.port!r}")


# ---------------------------------------------------------------------------
# composition + (de)serialization
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunConfig:
    """The complete declarative description of one decomposition run."""

    data: DataConfig = dataclasses.field(default_factory=DataConfig)
    plan: PlanConfig = dataclasses.field(default_factory=PlanConfig)
    method: MethodConfig = dataclasses.field(default_factory=MethodConfig)
    exec: ExecConfig = dataclasses.field(default_factory=ExecConfig)
    obs: ObsConfig = dataclasses.field(default_factory=ObsConfig)
    serve: ServeConfig = dataclasses.field(default_factory=ServeConfig)

    def __post_init__(self):
        # the (method, executor) capability gate lives in exactly one place
        # (executor.require_capability); running it here means a bad combo
        # fails at RunConfig construction, not deep inside a fit
        from .executor import require_capability

        require_capability(self.method.name, self.exec.executor)

    # -- serialization -----------------------------------------------------
    def to_dict(self) -> dict:
        """Nested plain-python dict (tuples preserved; JSON-safe)."""
        return {name: dataclasses.asdict(getattr(self, name))
                for name in _SECTIONS}

    @classmethod
    def from_dict(cls, d: dict) -> "RunConfig":
        """Build + validate from a nested dict; unknown keys are rejected
        with their full path and a nearest-name suggestion."""
        if not isinstance(d, dict):
            raise ConfigError(f"RunConfig wants a dict, got {type(d).__name__}")
        kwargs = {}
        for k, v in d.items():
            if k not in _SECTIONS:
                raise ConfigError(
                    f"unknown section {k!r}; one of {tuple(_SECTIONS)}"
                    + _suggest(k, _SECTIONS))
            kwargs[k] = _build_section(_SECTIONS[k], v, path=k)
        return cls(**kwargs)

    def to_json(self, *, indent: int = 1) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "RunConfig":
        return cls.from_dict(json.loads(s))

    # -- convenience -------------------------------------------------------
    def replace(self, **kwargs) -> "RunConfig":
        """``dataclasses.replace`` over sections: ``cfg.replace(method=...)``."""
        return dataclasses.replace(self, **kwargs)

    def summary(self) -> str:
        src = (self.data.source or
               (f"{self.data.dataset}@{self.data.scale:g}"
                if self.data.dataset else "memory"))
        return (f"{self.method.name} rank={self.method.rank} "
                f"niters={self.method.niters} on {src} "
                f"[plan={self.plan.policy} exec={self.exec.executor}]")


_SECTIONS = {"data": DataConfig, "plan": PlanConfig,
             "method": MethodConfig, "exec": ExecConfig,
             "obs": ObsConfig, "serve": ServeConfig}


def _build_section(cls, d: Any, *, path: str):
    if not isinstance(d, dict):
        raise ConfigError(f"{path}: wants a mapping, got {type(d).__name__}")
    names = tuple(f.name for f in dataclasses.fields(cls))
    kwargs = {}
    for k, v in d.items():
        if k not in names:
            raise ConfigError(
                f"{path}.{k}: unknown key; {path} accepts {names}"
                + _suggest(k, names))
        # JSON lists become tuples in each section's __post_init__
        # (_canon_field / _canon_options) — no special casing here
        kwargs[k] = v
    try:
        return cls(**kwargs)
    except ConfigError:
        raise
    except TypeError as e:
        raise ConfigError(f"{path}: {e}") from None
