"""The executor registry: local / distributed / streaming execution of a
registered decomposition method, selected by name and gated by the
capability flags the method's :class:`~repro.methods.MethodSpec` declares.

Before this module, the local/shard_map/chunked split was hard-coded across
``methods/driver.py``, ``core/distributed.py`` and ``methods/streaming.py``,
and each launcher re-validated method capabilities with its own error text.
Now an :class:`ExecutorSpec` pairs an execution strategy with the
``MethodSpec`` flag it requires, and :func:`require_capability` is the ONE
capability gate — ``dist_cp_als``, the dry-run, the serve launcher and
``Session.fit`` all raise the same error with the same capability listing.

Each executor's ``fn`` consumes a :class:`~repro.api.session.Session` (the
stage cache: ingested tensor, plan, checkpoint state) and returns a
decomposition with ``factors`` / ``fit`` / ``values_at`` — the common
surface ``Session.serve_handle`` builds on.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

# executor name -> the available_methods() filter keyword proving capability
_CAPABILITY_FILTER = {"supports_dist": "dist", "supports_streaming": "streaming"}


@dataclasses.dataclass(frozen=True)
class ExecutorSpec:
    """One execution strategy and the method capability it requires.

    requires: the :class:`~repro.methods.MethodSpec` boolean attribute that
              must be True for a method to run under this executor (None =
              any method).
    """

    name: str
    fn: Callable[..., object]
    requires: Optional[str] = None
    description: str = ""


EXECUTORS: dict[str, ExecutorSpec] = {}


def register_executor(spec: ExecutorSpec) -> ExecutorSpec:
    """Add (or replace) an executor in the registry."""
    if spec.requires is not None and spec.requires not in _CAPABILITY_FILTER:
        raise ValueError(
            f"executor {spec.name!r} requires unknown capability flag "
            f"{spec.requires!r}; one of {tuple(_CAPABILITY_FILTER)}")
    EXECUTORS[spec.name] = spec
    return spec


def get_executor(name: str) -> ExecutorSpec:
    try:
        return EXECUTORS[name]
    except KeyError:
        from .config import _suggest

        raise ValueError(
            f"unknown executor {name!r}; one of {tuple(EXECUTORS)}"
            + _suggest(name, EXECUTORS)) from None


def require_capability(method: str, executor: str):
    """THE capability gate: validate that ``method`` can run under
    ``executor``; returns the :class:`~repro.methods.MethodSpec` on success,
    raises ValueError with the capability listing otherwise.  Every driver
    and launcher funnels through here so the error text exists once."""
    from repro.methods import available_methods, get_method

    spec = get_method(method)
    ex = get_executor(executor)
    if ex.requires is not None and not getattr(spec, ex.requires):
        kw = _CAPABILITY_FILTER[ex.requires]
        capable = available_methods(**{kw: True})
        raise ValueError(
            f"method {method!r} cannot run under the {executor!r} executor "
            f"(MethodSpec.{ex.requires}=False); {executor}-capable methods: "
            f"{capable}.  Run it with executor='local' via repro.api, or "
            f"repro.methods.fit(..., method={method!r})")
    return spec


def executor_matrix() -> list[dict]:
    """Rows of (executor, requires, supported methods) — what the CLI's
    ``--list-methods`` renders, sourced from the registries (never
    hand-maintained)."""
    from repro.methods import METHODS

    out = []
    for name, ex in EXECUTORS.items():
        methods = tuple(m for m in METHODS
                        if ex.requires is None
                        or getattr(METHODS[m], ex.requires))
        out.append({"executor": name, "requires": ex.requires or "-",
                    "methods": methods, "description": ex.description})
    return out


# ---------------------------------------------------------------------------
# the three execution strategies
# ---------------------------------------------------------------------------


def _method_kwargs(session) -> dict:
    """Keywords shared by every strategy, from the session's RunConfig."""
    cfg = session.cfg
    kw = dict(cfg.method.options)
    kw.update(niters=cfg.method.niters, tol=cfg.method.tol,
              key=session.method_key(),
              state=session.resume_state(),
              checkpoint_cb=session.checkpoint_cb(),
              monitor=session.monitor())
    return kw


def _check_options(spec, options: dict) -> None:
    """Reject method options the registered implementation does not accept,
    with the field path and a nearest-name hint — a typo'd option must not
    surface as a raw TypeError from deep inside a fit."""
    import inspect

    params = inspect.signature(spec.fn).parameters
    if any(p.kind == p.VAR_KEYWORD for p in params.values()):
        return
    bad = sorted(set(options) - set(params))
    if bad:
        from .config import _suggest

        names = tuple(p for p in params
                      if p not in ("t", "source", "rank", "self"))
        raise ValueError(
            f"method.options: {bad} not accepted by method "
            f"{spec.name!r} (accepts {names})"
            + _suggest(bad[0], names))


def _run_local(session):
    """Single-process ``methods.fit`` over the planner/ingest stack."""
    from repro.methods import fit

    cfg = session.cfg
    spec = require_capability(cfg.method.name, "local")
    if spec.supports_streaming:
        # a streaming-only method executes as chunk folds either way; going
        # through the streaming strategy (chunk_source) avoids eagerly
        # building per-mode CSF workspaces the fold would never touch
        return _run_streaming(session)
    if cfg.exec.n_chunks is not None:
        raise ValueError(
            f"exec.n_chunks: method {cfg.method.name!r} is a batch method "
            "and does not fold chunks; chunk geometry applies only to "
            "streaming-capable methods")
    _check_options(spec, cfg.method.options)
    return fit(session.ingest(), cfg.method.rank, method=cfg.method.name,
               plan=session.plan(), **_method_kwargs(session))


def _run_dist(session):
    """The medium-grained shard_map driver (``core.distributed``)."""
    from repro.core.cpals import CPDecomp
    from repro.core.distributed import dist_cp_als

    cfg = session.cfg
    require_capability(cfg.method.name, "dist")
    if cfg.exec.checkpoint_dir is not None:
        raise ValueError(
            "exec.checkpoint_dir: the dist executor's shard_map body has no "
            "mid-fit checkpoint hook; checkpoint/resume needs executor="
            "'local' or 'streaming'")
    if cfg.method.tol > 0.0:
        raise ValueError(
            "method.tol: the dist executor's shard_map body runs a fixed "
            "iteration count (no early-stop hook); drop tol or use "
            "executor='local'")
    kw = _method_kwargs(session)
    # the shard_map body owns its loop: no mid-fit state/tol hooks
    # (state/checkpoint_cb are always None here — checkpoint_dir was
    # rejected above — and tol>0 was rejected; tol=0.0 is just the default)
    for unsupported in ("state", "checkpoint_cb", "tol"):
        kw.pop(unsupported, None)
    # dist_cp_als has no **kwargs sink: reject foreign method options with
    # the field path instead of letting a raw TypeError escape
    supported = {"niters", "key", "monitor", "verbose", "init"}
    bad = sorted(set(kw) - supported)
    if bad:
        raise ValueError(
            f"method.options: {bad} not supported by the dist executor "
            f"(dist_cp_als accepts only {sorted(supported)} from the "
            "method section)")
    factors, lam, fit = dist_cp_als(
        session.ingest(), cfg.method.rank, session.mesh(),
        shard_c=cfg.exec.shard_c, mode_order=cfg.exec.mode_order,
        plan=session.plan(), method=cfg.method.name, **kw)
    _emit_host_metrics(session)
    return CPDecomp(factors=tuple(factors), lmbda=lam, fit=fit)


def _emit_host_metrics(session) -> None:
    """Drop this process's registry snapshot (histogram windows included)
    as ``metrics-<host>.json`` under ``obs.trace_dir`` — the per-host half
    of cross-host aggregation; ``Session.export_obs`` folds every such
    file into ``metrics-aggregated.json``."""
    cfg = session.cfg
    if not (cfg.obs.enabled and cfg.obs.trace_dir):
        return
    import socket

    import jax

    from repro.obs.aggregate import write_host_metrics
    from repro.obs.metrics import get_registry

    host = f"{socket.gethostname()}-p{jax.process_index()}"
    write_host_metrics(cfg.obs.trace_dir, host, registry=get_registry())


def _run_streaming(session):
    """Chunked-fold execution straight off the chunk source — a ``.tnsb``
    mmap or re-streamed ``.tns`` is never materialized as one COO."""
    from repro.methods import fit

    cfg = session.cfg
    spec = require_capability(cfg.method.name, "streaming")
    _check_options(spec, cfg.method.options)
    # for its validation side effect: a pinned plan policy / calibration
    # that chunk folds cannot honor raises here (returns None otherwise)
    session.plan()
    kw = _method_kwargs(session)
    kw.setdefault("chunk_nnz", cfg.exec.chunk_nnz)
    if cfg.exec.n_chunks is not None:
        kw.setdefault("n_chunks", cfg.exec.n_chunks)
    source = session.chunk_source()
    if cfg.data.dims is not None and not hasattr(source, "order"):
        kw.setdefault("dims", cfg.data.dims)
    return fit(source, cfg.method.rank, method=cfg.method.name, **kw)


register_executor(ExecutorSpec(
    name="local", fn=_run_local, requires=None,
    description="single-process methods.fit over the planned workspaces"))
register_executor(ExecutorSpec(
    name="dist", fn=_run_dist, requires="supports_dist",
    description="medium-grained shard_map CP-ALS over a device mesh"))
register_executor(ExecutorSpec(
    name="streaming", fn=_run_streaming, requires="supports_streaming",
    description="chunked MTTKRP folds from an ingest.reader chunk source"))
