"""Content-addressed preprocessing cache for ingest workspaces.

The paper's Chapel port (and our JAX one) spends a large pre-processing
fraction sorting non-zeros into CSF before the first MTTKRP; today every
benchmark / serve / dry-run cold-start repeats that sort from scratch.
:class:`IngestCache` persists the expensive products of ingestion — the
relabeled COO tensor, the :class:`~repro.ingest.relabel.Relabeling` maps,
one :class:`~repro.core.csf.CSF` workspace per mode (SPLATT's ALLMODE
policy) and the measured :class:`~repro.plan.stats.ModeStats` — keyed by a
sha256 over the *tensor content* plus every option that shapes the
workspace (tile geometry, reorder/compact choice, format version).  A
second run on the same tensor skips parse + relabel + stats + sort
entirely.

Storage: ``<root>/<key[:2]>/<key>/`` — one raw ``.npy`` per array plus a
``meta.json`` with dims/options/stats.  (A single ``numpy.savez`` bundle
was measured ~5x slower to warm-read than the sum of its members: the zip
container CRC-checks every byte; raw ``.npy`` files load via ``mmap``.)
Writes are atomic — everything lands in a tmp directory that is renamed
into place — so concurrent runs at worst redo work, never read a torn
entry.  ``hits``/``misses`` counters make cache behaviour assertable in
tests and visible in benchmarks.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from pathlib import Path
from typing import Optional, Union

import numpy as np
import jax.numpy as jnp

from repro.core.coo import SparseTensor
from repro.core.csf import CSF
from repro.core.linearized import Linearized
from repro.plan.stats import ModeStats

from .relabel import Relabeling

# v2: entries additionally carry the mode-agnostic linearized workspace
# (core/linearized.py) — lin_hi/lin_lo/lin_vals/lin_block_tile arrays plus
# its geometry in meta["lin"].  The version is part of content_key, so v1
# entries are simply never addressed again (stale dirs, no torn reads).
CACHE_FORMAT_VERSION = 2


def content_key(
    x: Union[SparseTensor, str, os.PathLike],
    *,
    block: int,
    row_tile: int,
    reorder: str = "identity",
    compact: bool = False,
    dims=None,
    duplicates: str = "sum",
    extra: str = "",
) -> str:
    """sha256 key over tensor content + every option that shapes the
    ingested state (tile geometry, reorder/compact, the reader's ``dims``
    override and duplicate policy).

    For a file path the *file bytes* are hashed (a warm start never parses
    the text); for an in-memory tensor the index/value buffers are.  The CP
    rank is deliberately excluded — workspaces are rank-independent.
    """
    h = hashlib.sha256()
    dims_s = "infer" if dims is None else tuple(int(d) for d in dims)
    h.update(f"ingest-v{CACHE_FORMAT_VERSION}|block={block}|"
             f"row_tile={row_tile}|reorder={reorder}|compact={compact}|"
             f"dims={dims_s}|duplicates={duplicates}|"
             f"extra={extra}|".encode())
    if isinstance(x, SparseTensor):
        h.update(f"mem|dims={x.dims}|nnz={x.nnz}|".encode())
        h.update(np.ascontiguousarray(np.asarray(x.inds[: x.nnz])).tobytes())
        h.update(np.ascontiguousarray(np.asarray(x.vals[: x.nnz])).tobytes())
    else:
        path = Path(x)
        h.update(f"file|size={path.stat().st_size}|".encode())
        with open(path, "rb") as f:
            for chunk in iter(lambda: f.read(1 << 22), b""):
                h.update(chunk)
    return h.hexdigest()


@dataclasses.dataclass
class IngestCache:
    """Content-addressed store of ingest products under ``root``."""

    root: Path
    hits: int = 0
    misses: int = 0

    def __init__(self, root: Union[str, os.PathLike]):
        self.root = Path(root)
        self.hits = 0
        self.misses = 0
        self._autotune = None

    @property
    def autotune(self):
        """The persistent calibration store riding inside this cache
        (``repro.plan.autotune.AutotuneStore`` rooted at
        ``<root>/autotune``): measured ``plan(calibrate=True)`` outcomes
        live next to the workspaces they were measured on, so any
        ``Ingested`` handle with a cache attached gets warm calibration
        for free."""
        if self._autotune is None:
            from repro.plan.autotune import AutotuneStore

            self._autotune = AutotuneStore(self.root / "autotune")
        return self._autotune

    def _dir(self, key: str) -> Path:
        return self.root / key[:2] / key

    def has(self, key: str) -> bool:
        return (self._dir(key) / "meta.json").exists()

    # -- store -------------------------------------------------------------
    def store(self, key: str, t: SparseTensor,
              relabeling: Optional[Relabeling],
              csfs: list[CSF], stats: list[ModeStats],
              stats_before: Optional[list[ModeStats]] = None,
              lin: Optional[Linearized] = None) -> None:
        entry = self._dir(key)
        entry.parent.mkdir(parents=True, exist_ok=True)

        arrays: dict[str, np.ndarray] = {
            "coo_inds": np.asarray(t.inds[: t.nnz]),
            "coo_vals": np.asarray(t.vals[: t.nnz]),
        }
        if relabeling is not None:
            for m in range(relabeling.order):
                arrays[f"rel_new_of_old_{m}"] = np.asarray(
                    relabeling.new_of_old[m])
                arrays[f"rel_old_of_new_{m}"] = np.asarray(
                    relabeling.old_of_new[m])
            if relabeling.entry_perm is not None:
                arrays["rel_entry_perm"] = np.asarray(relabeling.entry_perm)
        for c in csfs:
            m = c.mode
            arrays[f"csf{m}_row_ids"] = np.asarray(c.row_ids)
            arrays[f"csf{m}_other_ids"] = np.asarray(c.other_ids)
            arrays[f"csf{m}_vals"] = np.asarray(c.vals)
            arrays[f"csf{m}_block_tile"] = np.asarray(c.block_tile)
        if lin is not None:
            arrays["lin_hi"] = np.asarray(lin.hi)
            arrays["lin_lo"] = np.asarray(lin.lo)
            arrays["lin_vals"] = np.asarray(lin.vals)
            arrays["lin_block_tile"] = np.asarray(lin.block_tile)

        meta = {
            "version": CACHE_FORMAT_VERSION,
            "dims": list(t.dims),
            "nnz": t.nnz,
            "csf": {str(c.mode): {"block": c.block, "row_tile": c.row_tile}
                    for c in csfs},
            "lin": None if lin is None else {
                "block": lin.block, "row_tile": lin.row_tile,
                "sort_mode": lin.sort_mode},
            "relabeling": None if relabeling is None else {
                "dims_old": list(relabeling.dims_old),
                "dims_new": list(relabeling.dims_new),
                "has_entry_perm": relabeling.entry_perm is not None,
                "linearized_mode": relabeling.linearized_mode,
            },
            "stats": [dataclasses.asdict(s) for s in stats],
            "stats_before": (None if stats_before is None
                             else [dataclasses.asdict(s)
                                   for s in stats_before]),
        }

        tmp = entry.with_name(entry.name + f".tmp{os.getpid()}")
        tmp.mkdir(parents=True, exist_ok=True)
        for name, arr in arrays.items():
            np.save(tmp / f"{name}.npy", arr, allow_pickle=False)
        (tmp / "meta.json").write_text(json.dumps(meta))
        try:
            os.replace(tmp, entry)
        except OSError:
            # a concurrent run published the same key first — keep theirs
            import shutil
            shutil.rmtree(tmp, ignore_errors=True)

    # -- load --------------------------------------------------------------
    def load(self, key: str):
        """Returns ``(tensor, relabeling, {mode: CSF}, lin, stats,
        stats_before)`` — ``lin`` is the shared linearized workspace, or
        None when the tensor's dims exceed its bit budget — or None on a
        miss.  Counts hits/misses."""
        from repro.obs.metrics import get_registry
        from repro.obs.recorder import record_event

        entry = self._dir(key)
        meta_path = entry / "meta.json"
        if not meta_path.exists():
            self.misses += 1
            get_registry().counter("ingest.cache.miss").inc()
            record_event("cache", store="ingest", key=key, hit=False)
            return None
        meta = json.loads(meta_path.read_text())
        if meta.get("version") != CACHE_FORMAT_VERSION:
            # evict, or the follow-up store() would hit the existing
            # directory on os.replace and the entry would never self-heal
            import shutil
            shutil.rmtree(entry, ignore_errors=True)
            self.misses += 1
            get_registry().counter("ingest.cache.miss").inc()
            record_event("cache", store="ingest", key=key, hit=False)
            return None
        arrays = {p.stem: np.load(p, mmap_mode="r")
                  for p in entry.glob("*.npy")}
        self.hits += 1
        get_registry().counter("ingest.cache.hit").inc()
        record_event("cache", store="ingest", key=key, hit=True)

        dims = tuple(meta["dims"])
        nnz = int(meta["nnz"])
        t = SparseTensor(inds=jnp.asarray(arrays["coo_inds"]),
                         vals=jnp.asarray(arrays["coo_vals"]),
                         dims=dims, nnz=nnz)
        relabeling = None
        rmeta = meta.get("relabeling")
        if rmeta is not None:
            order = len(rmeta["dims_old"])
            relabeling = Relabeling(
                new_of_old=tuple(jnp.asarray(arrays[f"rel_new_of_old_{m}"])
                                 for m in range(order)),
                old_of_new=tuple(jnp.asarray(arrays[f"rel_old_of_new_{m}"])
                                 for m in range(order)),
                dims_old=tuple(rmeta["dims_old"]),
                dims_new=tuple(rmeta["dims_new"]),
                entry_perm=(jnp.asarray(arrays["rel_entry_perm"])
                            if rmeta["has_entry_perm"] else None),
                linearized_mode=rmeta["linearized_mode"],
            )
        csfs = {}
        for mode_s, geom in meta["csf"].items():
            m = int(mode_s)
            csfs[m] = CSF(
                mode=m,
                row_ids=jnp.asarray(arrays[f"csf{m}_row_ids"]),
                other_ids=jnp.asarray(arrays[f"csf{m}_other_ids"]),
                vals=jnp.asarray(arrays[f"csf{m}_vals"]),
                block_tile=jnp.asarray(arrays[f"csf{m}_block_tile"]),
                dims=dims, nnz=nnz,
                block=int(geom["block"]), row_tile=int(geom["row_tile"]),
            )
        lin = None
        lmeta = meta.get("lin")
        if lmeta is not None:
            # widths/offsets are pure functions of (dims, sort_mode): only
            # the arrays and the tile geometry need to round-trip
            lin = Linearized(
                hi=jnp.asarray(arrays["lin_hi"]),
                lo=jnp.asarray(arrays["lin_lo"]),
                vals=jnp.asarray(arrays["lin_vals"]),
                block_tile=jnp.asarray(arrays["lin_block_tile"]),
                dims=dims, nnz=nnz,
                block=int(lmeta["block"]), row_tile=int(lmeta["row_tile"]),
                sort_mode=int(lmeta["sort_mode"]),
            )
        stats = [ModeStats(**d) for d in meta["stats"]]
        stats_before = (None if meta["stats_before"] is None
                        else [ModeStats(**d) for d in meta["stats_before"]])
        return t, relabeling, csfs, lin, stats, stats_before
