"""repro.ingest — streaming ingestion, locality-aware reordering, and the
content-addressed workspace cache (bytes on disk -> planner-ready state).

    reader.py   chunked FROSTT .tns reader + mmap-able .tnsb binary format
    relabel.py  invertible mode relabelings / non-zero relinearizations
    cache.py    content-addressed cache of COO + CSF workspaces + stats
    api.py      ingest(...) -> Ingested, the handle every driver accepts
"""
from .reader import (read_tns, write_tns, read_tnsb, write_tnsb, convert_tns,
                     read_any, is_tnsb, DUPLICATE_POLICIES)
from .relabel import (Relabeling, identity_relabeling, compact, degree_sort,
                      random_block, make_reorder, REORDERINGS)
from .cache import IngestCache, content_key
from .api import Ingested, ingest

__all__ = [
    "read_tns", "write_tns", "read_tnsb", "write_tnsb", "convert_tns",
    "read_any", "is_tnsb", "DUPLICATE_POLICIES",
    "Relabeling", "identity_relabeling", "compact", "degree_sort",
    "random_block", "make_reorder", "REORDERINGS",
    "IngestCache", "content_key", "Ingested", "ingest",
]
