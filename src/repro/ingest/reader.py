"""Streaming tensor readers/writers: FROSTT ``.tns`` text and binary ``.tnsb``.

The paper's SPLATT port reads FROSTT-style text files as its ingestion step;
Anderson & Dunlavy (arXiv:2310.10872) make the case that the I/O between
ingestion and decomposition is itself a first-class performance problem.
This module owns the bytes-on-disk end of the ingest pipeline:

* :func:`read_tns` — a chunked, streaming FROSTT reader.  Tolerates ``#``/
  ``%`` comment lines and blank lines, validates that every data line has
  the same arity (with the offending line number in the error), keeps an
  explicit ``dims=`` override (so trailing empty slices are not silently
  dropped — the old ``np.loadtxt`` one-shot shrank ``dims`` to max index
  + 1), and applies an explicit duplicate-coordinate policy.
* :func:`write_tns` — buffered, vectorized formatting (the old per-line
  Python loop was quadratic-feeling at 1M nnz).  Floats are written with
  enough significant digits that ``read_tns(write_tns(t)) == t`` exactly.
* ``.tnsb`` — a mmap-able binary format with a fixed header (magic,
  version, order, dims, nnz, dtype) followed by the raw index and value
  arrays: :func:`write_tnsb` / :func:`read_tnsb` / :func:`convert_tns`.
  Reading a ``.tnsb`` skips all text parsing; this is what the benchmark
  dataset cache stores.

Everything here is host-side numpy; arrays enter jax only at the final
:class:`~repro.core.coo.SparseTensor` construction.
"""
from __future__ import annotations

import os
import struct
from pathlib import Path
from typing import Optional, Sequence

import numpy as np
import jax.numpy as jnp

from repro.core.coo import SparseTensor, dedupe

_COMMENT_PREFIXES = ("#", "%")
DUPLICATE_POLICIES = ("sum", "keep", "error")


def _is_data_line(line: str) -> bool:
    s = line.lstrip()
    return bool(s) and not s.startswith(_COMMENT_PREFIXES)


def read_tns(
    path: str | os.PathLike,
    *,
    dtype=np.float32,
    dims: Optional[Sequence[int]] = None,
    duplicates: str = "sum",
    chunk_lines: int = 1 << 20,
) -> SparseTensor:
    """Stream a FROSTT ``.tns`` text file (1-indexed ``i j k val`` lines).

    ``dims``: explicit mode lengths.  Without it, dims are inferred as
    max index + 1 per mode — which silently loses trailing empty slices;
    pass the true shape to keep them.
    ``duplicates``: ``"sum"`` collapses repeated coordinates (what SPLATT
    and the fit formula assume), ``"keep"`` preserves them verbatim,
    ``"error"`` raises on the first duplicate.
    ``chunk_lines``: lines parsed per streaming chunk (memory bound, not
    a correctness knob).
    """
    if duplicates not in DUPLICATE_POLICIES:
        raise ValueError(
            f"duplicates policy {duplicates!r} not in {DUPLICATE_POLICIES}")
    chunks = list(_iter_tns_arrays(path, chunk_lines=chunk_lines))
    if not chunks:
        raise ValueError(f"{path}: no data lines")
    raw = chunks[0] if len(chunks) == 1 else np.concatenate(chunks)
    return _assemble(raw, path=path, dtype=dtype, dims=dims,
                     duplicates=duplicates)


def _parse_batch(batch: list[str], batch_nos: list[int],
                 arity: Optional[int], path) -> np.ndarray:
    """Parse one chunk of data lines into an (n, arity) float64 array,
    validating that every line has the same number of fields."""
    rows = [line.split() for line in batch]
    counts = np.fromiter((len(r) for r in rows), dtype=np.int64,
                         count=len(rows))
    want = arity if arity is not None else int(counts[0])
    bad = np.flatnonzero(counts != want)
    if bad.size:
        i = int(bad[0])
        raise ValueError(
            f"{path}:{batch_nos[i]}: expected {want} fields "
            f"(order {want - 1} + value), got {int(counts[i])}: "
            f"{batch[i].strip()!r}")
    if want < 3:
        raise ValueError(
            f"{path}:{batch_nos[0]}: a .tns line needs at least 2 indices "
            f"+ 1 value, got {want} fields")
    flat = [tok for r in rows for tok in r]
    try:
        out = np.array(flat, dtype=np.float64)
    except ValueError as e:
        raise ValueError(f"{path}: non-numeric field in lines "
                         f"{batch_nos[0]}..{batch_nos[-1]}: {e}") from None
    return out.reshape(len(rows), want)


def _assemble(raw: np.ndarray, *, path, dtype, dims, duplicates) -> SparseTensor:
    icols = raw[:, :-1]
    vals = raw[:, -1].astype(dtype)
    if not np.all(icols == np.floor(icols)):
        raise ValueError(f"{path}: non-integer index column")
    if icols.size and icols.min() < 1:
        raise ValueError(f"{path}: FROSTT indices are 1-based; found "
                         f"index {int(icols.min())}")
    inds = icols.astype(np.int64) - 1
    order = inds.shape[1]
    inferred = tuple(int(inds[:, m].max()) + 1 for m in range(order))
    if dims is not None:
        dims = tuple(int(d) for d in dims)
        if len(dims) != order:
            raise ValueError(
                f"{path}: dims={dims} has {len(dims)} modes, file has {order}")
        short = [m for m in range(order) if inferred[m] > dims[m]]
        if short:
            raise ValueError(
                f"{path}: index out of range for dims={dims} in mode(s) "
                f"{short} (max+1 per mode is {inferred})")
    else:
        dims = inferred
    t = SparseTensor(inds=jnp.asarray(inds.astype(np.int32)),
                     vals=jnp.asarray(vals), dims=dims, nnz=len(vals))
    if duplicates == "keep":
        return t
    if duplicates == "error":
        lin = np.ravel_multi_index(
            tuple(inds[:, m] for m in range(order)), dims)
        uniq = np.unique(lin)
        if uniq.shape[0] != lin.shape[0]:
            raise ValueError(
                f"{path}: {lin.shape[0] - uniq.shape[0]} duplicate "
                "coordinate(s) (duplicates='error')")
        return t
    return dedupe(t)


# ---------------------------------------------------------------------------
# vectorized .tns writer
# ---------------------------------------------------------------------------

def write_tns(path: str | os.PathLike, t: SparseTensor, *,
              chunk: int = 1 << 18) -> None:
    """Write FROSTT text, formatting in vectorized chunks.

    Float significant digits are chosen per value dtype (9 for float32,
    17 for float64) so a ``read_tns`` round-trip reproduces every value
    bit-exactly.
    """
    inds = np.asarray(t.inds[: t.nnz]).astype(np.int64) + 1
    vals = np.asarray(t.vals[: t.nnz])
    vfmt = "%.9g" if vals.dtype == np.float32 else "%.17g"
    n = inds.shape[0]
    with open(path, "w") as f:
        for s in range(0, n, chunk):
            e = min(n, s + chunk)
            cols = [np.char.mod("%d", inds[s:e, m])
                    for m in range(t.order)]
            cols.append(np.char.mod(vfmt, vals[s:e].astype(np.float64)))
            line = cols[0]
            for c in cols[1:]:
                line = np.char.add(np.char.add(line, " "), c)
            f.write("\n".join(line))
            f.write("\n")


# ---------------------------------------------------------------------------
# .tnsb — mmap-able binary tensor format
# ---------------------------------------------------------------------------
#
# layout (little-endian):
#   magic   4s   b"TNSB"
#   version u32  1
#   order   u32
#   dtcode  u32  value dtype (index into _DTYPE_CODES)
#   nnz     u64
#   dims    i64[order]
#   inds    i32[nnz, order]  (C order)
#   vals    <dtype>[nnz]

TNSB_MAGIC = b"TNSB"
TNSB_VERSION = 1
_HEADER = struct.Struct("<4sIIIQ")
_DTYPE_CODES = {0: np.float32, 1: np.float64}
_CODE_OF = {np.dtype(v): k for k, v in _DTYPE_CODES.items()}


def write_tnsb(path: str | os.PathLike, t: SparseTensor) -> None:
    """Write the binary format atomically (tmp file + rename)."""
    inds = np.ascontiguousarray(np.asarray(t.inds[: t.nnz]), dtype=np.int32)
    vals = np.ascontiguousarray(np.asarray(t.vals[: t.nnz]))
    code = _CODE_OF.get(vals.dtype)
    if code is None:
        raise ValueError(f"unsupported value dtype {vals.dtype} "
                         f"(one of {list(_CODE_OF)})")
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.with_suffix(path.suffix + ".tmp")
    with open(tmp, "wb") as f:
        f.write(_HEADER.pack(TNSB_MAGIC, TNSB_VERSION, t.order, code, t.nnz))
        f.write(np.asarray(t.dims, dtype=np.int64).tobytes())
        f.write(inds.tobytes())
        f.write(vals.tobytes())
    os.replace(tmp, path)


def read_tnsb(path: str | os.PathLike, *, mmap: bool = True) -> SparseTensor:
    """Read the binary format; with ``mmap=True`` the index/value arrays
    are memory-mapped so the OS pages them in lazily."""
    path = Path(path)
    with open(path, "rb") as f:
        head = f.read(_HEADER.size)
        if len(head) < _HEADER.size:
            raise ValueError(f"{path}: truncated .tnsb header")
        magic, version, order, code, nnz = _HEADER.unpack(head)
        if magic != TNSB_MAGIC:
            raise ValueError(f"{path}: not a .tnsb file (magic {magic!r})")
        if version != TNSB_VERSION:
            raise ValueError(f"{path}: .tnsb version {version}, "
                             f"expected {TNSB_VERSION}")
        if code not in _DTYPE_CODES:
            raise ValueError(f"{path}: unknown value dtype code {code}")
        dims = tuple(int(d) for d in
                     np.frombuffer(f.read(8 * order), dtype=np.int64))
        off = f.tell()
    vdtype = _DTYPE_CODES[code]
    if mmap:
        inds = np.memmap(path, dtype=np.int32, mode="r", offset=off,
                         shape=(nnz, order))
        vals = np.memmap(path, dtype=vdtype, mode="r",
                         offset=off + 4 * nnz * order, shape=(nnz,))
    else:
        with open(path, "rb") as f:
            f.seek(off)
            inds = np.fromfile(f, dtype=np.int32,
                               count=nnz * order).reshape(nnz, order)
            vals = np.fromfile(f, dtype=vdtype, count=nnz)
    return SparseTensor(inds=jnp.asarray(np.asarray(inds)),
                        vals=jnp.asarray(np.asarray(vals)),
                        dims=dims, nnz=int(nnz))


def convert_tns(src: str | os.PathLike, dst: str | os.PathLike,
                **read_kwargs) -> SparseTensor:
    """``.tns`` text -> ``.tnsb`` binary; returns the loaded tensor."""
    t = read_tns(src, **read_kwargs)
    write_tnsb(dst, t)
    return t


def is_tnsb(path: str | os.PathLike) -> bool:
    try:
        with open(path, "rb") as f:
            return f.read(4) == TNSB_MAGIC
    except OSError:
        return False


# ---------------------------------------------------------------------------
# chunk sources — what cp_als_streaming consumes
# ---------------------------------------------------------------------------
#
# A "chunk source" is a re-iterable sequence of SparseTensor chunks that all
# share the FULL tensor dims (each chunk owns a disjoint subset of the
# non-zeros), so per-chunk kernel partials sum to the batch result.  Only
# one chunk is materialized at a time: the .tnsb source slices the mmap, the
# .tns source re-streams the text file, and the in-memory source slices the
# resident tensor (a convenience for tests/benchmarks, not a memory win).
# Streaming assumes coordinates are already unique across chunks (a global
# duplicate-sum needs the full tensor — exactly what streaming avoids);
# .tnsb files written by the ingest/benchmark caches are deduped.


class ChunkSource:
    """Re-iterable chunk sequence with known ``dims`` and ``nnz``.

    ``make_iter`` is a zero-arg callable returning a fresh iterator of
    :class:`SparseTensor` chunks — each pass over the source calls it again,
    so file-backed sources re-stream instead of buffering.
    """

    def __init__(self, dims: Sequence[int], nnz: int, make_iter):
        self.dims = tuple(int(d) for d in dims)
        self.nnz = int(nnz)
        self._make_iter = make_iter

    def __iter__(self):
        return self._make_iter()


def scan_tns_dims(path: str | os.PathLike,
                  chunk_lines: int = 1 << 20) -> tuple[tuple[int, ...], int]:
    """One streaming pass over a ``.tns``: (inferred dims, line count).

    Used by the streaming driver when the caller does not pass ``dims=`` —
    the pass is index-only (no value parsing kept) and never materializes
    the tensor."""
    arity: Optional[int] = None
    maxes: Optional[np.ndarray] = None
    count = 0
    for raw in _iter_tns_arrays(path, chunk_lines=chunk_lines):
        arity = raw.shape[1]
        icols = raw[:, :-1]
        if icols.size and icols.min() < 1:
            raise ValueError(f"{path}: FROSTT indices are 1-based; found "
                             f"index {int(icols.min())}")
        m = icols.max(axis=0)
        maxes = m if maxes is None else np.maximum(maxes, m)
        count += raw.shape[0]
    if maxes is None:
        raise ValueError(f"{path}: no data lines")
    return tuple(int(v) for v in maxes), count


def _iter_tns_arrays(path, *, chunk_lines: int):
    """Yield parsed (n, arity) float64 arrays per text chunk (shared by the
    scan pass and the chunk iterator)."""
    arity: Optional[int] = None
    with open(path, "r") as f:
        lineno = 0
        batch: list[str] = []
        batch_nos: list[int] = []
        while True:
            line = f.readline()
            at_eof = not line
            if not at_eof:
                lineno += 1
                if _is_data_line(line):
                    batch.append(line)
                    batch_nos.append(lineno)
            if batch and (at_eof or len(batch) >= chunk_lines):
                raw = _parse_batch(batch, batch_nos, arity, path)
                arity = raw.shape[1]
                yield raw
                batch, batch_nos = [], []
            if at_eof:
                break


def iter_tns_chunks(path: str | os.PathLike, *, dims: Sequence[int],
                    chunk_nnz: int = 1 << 20, dtype=np.float32):
    """Yield :class:`SparseTensor` chunks of a FROSTT text file.

    ``dims`` is required: every chunk must carry the FULL tensor shape (use
    :func:`scan_tns_dims` for one cheap inference pass).  Duplicates are
    kept verbatim (see the chunk-source contract above)."""
    for raw in _iter_tns_arrays(path, chunk_lines=chunk_nnz):
        yield _assemble(raw, path=path, dtype=dtype, dims=dims,
                        duplicates="keep")


def iter_tnsb_chunks(path: str | os.PathLike, *, chunk_nnz: int = 1 << 20):
    """Yield chunks of a binary ``.tnsb`` by slicing the mmap — the OS pages
    in only the active chunk, so tensors larger than memory stream fine."""
    t = read_tnsb(path, mmap=True)
    yield from iter_chunks(t, chunk_nnz=chunk_nnz)


def iter_chunks(t: SparseTensor, *, chunk_nnz: Optional[int] = None,
                n_chunks: Optional[int] = None):
    """Slice a tensor's non-zeros into chunks sharing the full dims."""
    if (chunk_nnz is None) == (n_chunks is None):
        raise ValueError("pass exactly one of chunk_nnz= / n_chunks=")
    if n_chunks is not None:
        chunk_nnz = -(-t.nnz // int(n_chunks))
    chunk_nnz = max(1, int(chunk_nnz))
    for s in range(0, t.nnz, chunk_nnz):
        e = min(t.nnz, s + chunk_nnz)
        yield SparseTensor(inds=jnp.asarray(np.asarray(t.inds[s:e])),
                           vals=jnp.asarray(np.asarray(t.vals[s:e])),
                           dims=t.dims, nnz=e - s)


def open_chunk_source(source, *, dims: Optional[Sequence[int]] = None,
                      chunk_nnz: int = 1 << 20,
                      n_chunks: Optional[int] = None) -> ChunkSource:
    """Normalize anything chunk-shaped into a re-iterable :class:`ChunkSource`.

    Accepts a :class:`SparseTensor` (sliced in memory), a ``.tns``/``.tnsb``
    path (re-streamed per pass; ``.tns`` without ``dims=`` costs one extra
    scan pass), or an existing list/tuple of same-dims chunks."""
    if isinstance(source, SparseTensor):
        if n_chunks is not None:
            chunk_nnz = -(-source.nnz // int(n_chunks))
        cn = max(1, int(chunk_nnz))
        return ChunkSource(source.dims, source.nnz,
                           lambda: iter_chunks(source, chunk_nnz=cn))
    if isinstance(source, (list, tuple)):
        chunks = list(source)
        if not chunks:
            raise ValueError("empty chunk list")
        d0 = chunks[0].dims
        for i, c in enumerate(chunks):
            if not isinstance(c, SparseTensor) or c.dims != d0:
                raise ValueError(
                    f"chunk {i} is not a SparseTensor with dims {d0}")
        return ChunkSource(d0, sum(c.nnz for c in chunks),
                           lambda: iter(chunks))
    if isinstance(source, (str, os.PathLike)):
        path = Path(source)
        if is_tnsb(path):
            t = read_tnsb(path, mmap=True)
            if n_chunks is not None:
                chunk_nnz = -(-t.nnz // int(n_chunks))
            cn = max(1, int(chunk_nnz))
            return ChunkSource(t.dims, t.nnz,
                               lambda: iter_tnsb_chunks(path, chunk_nnz=cn))
        if dims is None:
            dims, count = scan_tns_dims(path)
        else:
            count = sum(r.shape[0]
                        for r in _iter_tns_arrays(path, chunk_lines=chunk_nnz))
        if n_chunks is not None:
            chunk_nnz = -(-count // int(n_chunks))
        cn = max(1, int(chunk_nnz))
        d = tuple(int(x) for x in dims)
        return ChunkSource(d, count,
                           lambda: iter_tns_chunks(path, dims=d, chunk_nnz=cn))
    raise TypeError(
        f"cannot stream chunks from {type(source).__name__}; pass a "
        "SparseTensor, a .tns/.tnsb path, or a list of SparseTensor chunks")


def read_any(path: str | os.PathLike, *, dims=None, duplicates: str = "sum",
             **read_kwargs) -> SparseTensor:
    """Dispatch on content: ``.tnsb`` by magic, FROSTT text otherwise.

    ``dims``/``duplicates`` apply to both formats: for ``.tnsb`` the header
    dims are authoritative, so an explicit ``dims`` that disagrees raises
    instead of being silently dropped, and the duplicate policy is enforced
    on the loaded coordinates."""
    if not is_tnsb(path):
        return read_tns(path, dims=dims, duplicates=duplicates,
                        **read_kwargs)
    t = read_tnsb(path)
    if dims is not None and tuple(int(d) for d in dims) != t.dims:
        raise ValueError(
            f"{path}: .tnsb header says dims={t.dims}, caller asked "
            f"dims={tuple(dims)}")
    if duplicates == "keep":
        return t
    if duplicates not in DUPLICATE_POLICIES:
        raise ValueError(
            f"duplicates policy {duplicates!r} not in {DUPLICATE_POLICIES}")
    deduped = dedupe(t)
    if duplicates == "error" and deduped.nnz != t.nnz:
        raise ValueError(f"{path}: {t.nnz - deduped.nnz} duplicate "
                         "coordinate(s) (duplicates='error')")
    return deduped
