"""Mode relabelings and locality-aware non-zero reorderings.

Laukemann et al.'s ALTO line of work (arXiv:2403.06348) shows that how a
sparse tensor's non-zeros are *labeled and linearized* dominates locality
and load balance — exactly the skew/collision statistics ``repro.plan``
measures.  This module makes those transformations first-class and, above
all, **invertible**: every transform is a :class:`Relabeling` pytree that

* relabels each mode's index space (``new_of_old`` / ``old_of_new`` maps,
  with ``-1`` marking slices dropped by compaction),
* optionally relinearizes the non-zero list (``entry_perm``),
* composes (:meth:`Relabeling.then`) and inverts (:meth:`Relabeling.invert`)
  exactly, and
* maps factor matrices both ways (:meth:`apply_factors` /
  :meth:`restore_factors`), so a decomposition computed in the relabeled
  space is reported in the tensor's **original labels**.

Transform builders:

``compact``       drop empty slices per mode (dims shrink; the planner stops
                  paying tile padding for rows that can never receive mass).
``degree_sort``   hot-rows-first per mode (locality: the heavy rows share
                  tiles/cache lines) + a contention-aware relinearization of
                  the non-zero list: entries are round-robined over the mode
                  with the most *reducible* measured intra-block collision
                  (occurrence-within-row major), so a chunked scatter-add
                  sees near-minimal same-row conflicts per chunk.
``random_block``  shuffle row blocks and the entry order — the
                  locality-destroying baseline the benchmarks compare
                  against.
``identity``      no-op (still a valid, composable Relabeling).

All builders are host-side numpy (pre-processing cost class, like the CSF
sort itself); the resulting maps are jax arrays so ``apply``/``restore``
stay jit-compatible.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.coo import SparseTensor
from repro.core.csf import DEFAULT_BLOCK
from repro.plan.stats import measured_block_collision

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Relabeling:
    """An invertible per-mode relabeling + optional entry relinearization.

    new_of_old[m][old] = new index of slice ``old`` in mode ``m`` (or -1 if
                         the slice was dropped by compaction — only ever
                         empty slices are dropped);
    old_of_new[m][new] = original index (always total and injective);
    entry_perm:          new storage order: ``new_list[i] = old_list[p[i]]``
                         (None = order preserved);
    linearized_mode:     which mode the entry relinearization round-robins
                         over (None when entry order is untouched/shuffled).
    """

    new_of_old: tuple[Array, ...]
    old_of_new: tuple[Array, ...]
    dims_old: tuple[int, ...]
    dims_new: tuple[int, ...]
    entry_perm: Optional[Array] = None
    linearized_mode: Optional[int] = None

    def tree_flatten(self):
        children = (self.new_of_old, self.old_of_new, self.entry_perm)
        aux = (self.dims_old, self.dims_new, self.linearized_mode)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        new_of_old, old_of_new, entry_perm = children
        dims_old, dims_new, linearized_mode = aux
        return cls(tuple(new_of_old), tuple(old_of_new), dims_old, dims_new,
                   entry_perm, linearized_mode)

    @property
    def order(self) -> int:
        return len(self.dims_old)

    @property
    def is_identity(self) -> bool:
        if self.entry_perm is not None or self.dims_old != self.dims_new:
            return False
        return all(bool(jnp.all(m == jnp.arange(m.shape[0])))
                   for m in self.new_of_old)

    # -- tensors -----------------------------------------------------------
    def apply(self, t: SparseTensor) -> SparseTensor:
        """Relabel (and relinearize) ``t``.  Padding entries are dropped —
        relabeling is a host/build-time step; re-pad downstream if needed."""
        if t.dims != self.dims_old:
            raise ValueError(f"tensor dims {t.dims} != relabeling "
                             f"dims_old {self.dims_old}")
        inds = t.inds[: t.nnz]
        vals = t.vals[: t.nnz]
        cols = [jnp.take(self.new_of_old[m], inds[:, m])
                for m in range(self.order)]
        new_inds = jnp.stack(cols, axis=1).astype(jnp.int32)
        if self.entry_perm is not None:
            new_inds = new_inds[self.entry_perm]
            vals = vals[self.entry_perm]
        return SparseTensor(inds=new_inds, vals=vals, dims=self.dims_new,
                            nnz=t.nnz)

    def invert(self) -> "Relabeling":
        perm = None
        if self.entry_perm is not None:
            perm = jnp.argsort(self.entry_perm)
        return Relabeling(
            new_of_old=self.old_of_new, old_of_new=self.new_of_old,
            dims_old=self.dims_new, dims_new=self.dims_old,
            entry_perm=perm, linearized_mode=None)

    def then(self, other: "Relabeling") -> "Relabeling":
        """Composition: apply ``self`` first, then ``other`` (which operates
        in ``self``'s new index space)."""
        if self.dims_new != other.dims_old:
            raise ValueError(f"cannot compose: dims_new {self.dims_new} != "
                             f"next dims_old {other.dims_old}")
        new_of_old = []
        for m in range(self.order):
            a = self.new_of_old[m]
            safe = jnp.clip(a, 0, None)
            new_of_old.append(jnp.where(
                a >= 0, jnp.take(other.new_of_old[m], safe), -1
            ).astype(jnp.int32))
        old_of_new = tuple(
            jnp.take(self.old_of_new[m], other.old_of_new[m])
            for m in range(self.order))
        if self.entry_perm is None:
            perm = other.entry_perm
        elif other.entry_perm is None:
            perm = self.entry_perm
        else:
            perm = self.entry_perm[other.entry_perm]
        lin = (other.linearized_mode if other.linearized_mode is not None
               else self.linearized_mode)
        return Relabeling(tuple(new_of_old), old_of_new, self.dims_old,
                          other.dims_new, perm, lin)

    # -- factors -----------------------------------------------------------
    def apply_factors(self, factors: Sequence[Array]) -> tuple[Array, ...]:
        """Original-label factors -> relabeled space (row gather)."""
        return tuple(f[self.old_of_new[m]] for m, f in enumerate(factors))

    def restore_factors(self, factors: Sequence[Array]) -> tuple[Array, ...]:
        """Relabeled-space factors -> original labels.  Rows of slices that
        compaction dropped (necessarily empty) come back as zeros."""
        out = []
        for m, f in enumerate(factors):
            full = jnp.zeros((self.dims_old[m],) + f.shape[1:], dtype=f.dtype)
            out.append(full.at[self.old_of_new[m]].set(f))
        return tuple(out)


def identity_relabeling(dims: Sequence[int]) -> Relabeling:
    dims = tuple(int(d) for d in dims)
    maps = tuple(jnp.arange(d, dtype=jnp.int32) for d in dims)
    return Relabeling(maps, maps, dims, dims)


def _from_row_orders(t: SparseTensor, orders: list[np.ndarray],
                     dims_new: tuple[int, ...]) -> Relabeling:
    """Build a Relabeling from per-mode ``old_of_new`` row orders (each an
    injective array of old ids; old ids not listed are dropped)."""
    new_of_old, old_of_new = [], []
    for m, order in enumerate(orders):
        fwd = np.full(t.dims[m], -1, dtype=np.int32)
        fwd[order] = np.arange(order.shape[0], dtype=np.int32)
        new_of_old.append(jnp.asarray(fwd))
        old_of_new.append(jnp.asarray(order.astype(np.int32)))
    return Relabeling(tuple(new_of_old), tuple(old_of_new), t.dims, dims_new)


def _mode_counts(t: SparseTensor) -> list[np.ndarray]:
    inds = np.asarray(t.inds[: t.nnz])
    return [np.bincount(inds[:, m], minlength=t.dims[m])
            for m in range(t.order)]


# ---------------------------------------------------------------------------
# transform builders
# ---------------------------------------------------------------------------

def identity(t: SparseTensor, **_) -> Relabeling:
    return identity_relabeling(t.dims)


def compact(t: SparseTensor, **_) -> Relabeling:
    """Drop empty slices per mode (relative order preserved)."""
    orders = [np.flatnonzero(c > 0).astype(np.int32)
              for c in _mode_counts(t)]
    dims_new = tuple(int(o.shape[0]) for o in orders)
    return _from_row_orders(t, orders, dims_new)


def degree_sort(t: SparseTensor, *, block: int = DEFAULT_BLOCK,
                **_) -> Relabeling:
    """Hot-rows-first per mode + contention-aware entry relinearization.

    Row relabeling: each mode's slices are renumbered by descending non-zero
    count (stable), so the heavy rows share the low row-tiles.  Entry
    relinearization: among all modes, pick the one with the largest
    *reducible* measured intra-block collision (measured minus the
    ``1 - rows/block`` floor no ordering can beat) and sort entries by
    (occurrence-within-row, row) — a jagged-diagonal-style round-robin that
    puts each row's k-th entry in the k-th wave, so consecutive chunks touch
    near-distinct rows.
    """
    counts = _mode_counts(t)
    orders = [np.argsort(-c, kind="stable").astype(np.int32) for c in counts]
    rel = _from_row_orders(t, orders, t.dims)

    inds = np.asarray(t.inds[: t.nnz])
    new_cols = [np.asarray(rel.new_of_old[m])[inds[:, m]]
                for m in range(t.order)]

    # pick the linearization mode: most reducible measured collision
    reducible = []
    for m in range(t.order):
        floor = max(0.0, 1.0 - t.dims[m] / block)
        reducible.append(
            measured_block_collision(new_cols[m], block) - floor)
    lin_mode = int(np.argmax(reducible))

    rows = new_cols[lin_mode]
    occ = _occurrence_within_row(rows)
    entry_perm = np.lexsort((rows, occ)).astype(np.int32)
    return dataclasses.replace(rel, entry_perm=jnp.asarray(entry_perm),
                               linearized_mode=lin_mode)


def _occurrence_within_row(rows: np.ndarray) -> np.ndarray:
    """occ[n] = how many earlier entries share rows[n]'s row (grouped
    cumulative count, vectorized)."""
    n = rows.shape[0]
    perm = np.argsort(rows, kind="stable")
    sr = rows[perm]
    first = np.ones(n, dtype=bool)
    first[1:] = sr[1:] != sr[:-1]
    starts = np.flatnonzero(first)
    group = np.cumsum(first) - 1
    occ_sorted = np.arange(n) - starts[group]
    occ = np.empty(n, dtype=np.int64)
    occ[perm] = occ_sorted
    return occ


def random_block(t: SparseTensor, *, seed: int = 0, block_rows: int = 128,
                 **_) -> Relabeling:
    """Shuffle each mode's row blocks and the non-zero order — the
    locality-destroying baseline."""
    rng = np.random.default_rng(seed)
    orders = []
    for d in t.dims:
        n_blocks = -(-d // block_rows)
        blocks = rng.permutation(n_blocks)
        order = np.concatenate(
            [np.arange(b * block_rows, min(d, (b + 1) * block_rows))
             for b in blocks]).astype(np.int32)
        orders.append(order)
    rel = _from_row_orders(t, orders, t.dims)
    perm = rng.permutation(t.nnz).astype(np.int32)
    return dataclasses.replace(rel, entry_perm=jnp.asarray(perm))


REORDERINGS = {
    "identity": identity,
    "degree_sort": degree_sort,
    "random_block": random_block,
}


def make_reorder(t: SparseTensor, name: str, *, block: int = DEFAULT_BLOCK,
                 seed: int = 0) -> Relabeling:
    """Build the named reordering for ``t`` (registry: ``REORDERINGS``)."""
    try:
        fn = REORDERINGS[name]
    except KeyError:
        raise ValueError(
            f"unknown reorder {name!r}; one of {tuple(REORDERINGS)}") from None
    return fn(t, block=block, seed=seed)
