"""``ingest()`` — the one path from bytes-on-disk to planner-ready workspace.

    ing = ingest("data.tns", reorder="degree_sort", cache=".cache/ingest")
    plan = ing.plan("auto", rank=16)
    dec  = cp_als(ing, rank=16, plan=plan)   # factors in ORIGINAL labels

:func:`ingest` accepts a FROSTT ``.tns`` path, a binary ``.tnsb`` path, or
an in-memory :class:`~repro.core.coo.SparseTensor`, and returns an
:class:`Ingested` handle that every driver (``cp_als``, ``dist_cp_als``,
the serve/dryrun launchers, the benchmarks) accepts in place of a raw
tensor.  The handle owns:

* the (possibly relabeled) tensor and its invertible
  :class:`~repro.ingest.relabel.Relabeling`;
* per-mode :class:`~repro.plan.stats.ModeStats`, measured **once** at
  ingest and reused by the planner (no second stats pass);
* the per-mode CSF workspaces, built lazily — or loaded from / stored to a
  content-addressed :class:`~repro.ingest.cache.IngestCache`, in which case
  a warm run skips sort + stats entirely.

CSF builds go through the ``repro.core.csf`` *module* attribute (not a
bound import) precisely so tests can monkeypatch ``csf.build_csf`` and
assert that a warm cache hit performs zero builds.
"""
from __future__ import annotations

import dataclasses
import os
from pathlib import Path
from typing import Optional, Sequence, Union

import jax

from repro.core import csf as csf_mod
from repro.core import linearized as lin_mod
from repro.core.coo import SparseTensor
from repro.core.csf import DEFAULT_BLOCK, DEFAULT_ROW_TILE
from repro.plan.stats import ModeStats, tensor_stats

from . import reader
from .cache import IngestCache, content_key
from repro.obs import trace as obs_trace

from .relabel import REORDERINGS, Relabeling, compact as compact_fn, make_reorder

Array = jax.Array


@dataclasses.dataclass
class Ingested:
    """Planner-ready handle over an ingested tensor.

    ``tensor`` lives in the relabeled index space; ``relabeling`` (when not
    None) maps back to the original labels — ``restore_factors`` /
    ``restore`` do that for factor matrices and decompositions, and the
    drivers call them automatically.
    """

    tensor: SparseTensor
    relabeling: Optional[Relabeling]
    stats: tuple[ModeStats, ...]
    stats_before: Optional[tuple[ModeStats, ...]]
    block: int
    row_tile: int
    source: str
    key: Optional[str] = None
    cache: Optional[IngestCache] = None
    cache_hit: bool = False
    _csf: dict = dataclasses.field(default_factory=dict)
    _lin: Optional[object] = None

    # -- basics ------------------------------------------------------------
    @property
    def order(self) -> int:
        return self.tensor.order

    @property
    def dims(self) -> tuple[int, ...]:
        """Dims of the relabeled (working) tensor."""
        return self.tensor.dims

    @property
    def original_dims(self) -> tuple[int, ...]:
        """Dims in the original label space (what queries/reports use)."""
        if self.relabeling is not None:
            return self.relabeling.dims_old
        return self.tensor.dims

    # -- planning ----------------------------------------------------------
    def plan(self, policy: str = "auto", *, rank=16,
             backend: Optional[str] = None,
             allow: Optional[Sequence[str]] = None,
             calibrate: bool = False, kernel: str = "mttkrp",
             factor_ranks: Optional[Sequence[int]] = None,
             autotune=None, recalibrate: bool = False):
        """Plan the decomposition, reusing the stats measured at ingest.

        ``kernel`` selects the scored kernel family ("mttkrp" for the CP
        methods, "ttmc" for Tucker/HOOI) — the stats are kernel-agnostic
        tensor properties, so both reuse the same ingest-time measurement;
        ``factor_ranks`` carries the per-mode Tucker ranks the ttmc
        calibration path needs.  ``calibrate=True`` with a cache attached
        consults the cache's persistent autotune store first (keyed by this
        handle's content key), so a warm plan performs zero timing runs;
        ``recalibrate=True`` forces a fresh measured pass and overwrites
        the stored entry.  ``autotune`` overrides the store (any
        :class:`~repro.plan.autotune.AutotuneStore` or root path)."""
        from repro.plan import plan_decomposition

        if autotune is None and self.cache is not None:
            autotune = self.cache.autotune
        return plan_decomposition(
            self.tensor, policy, rank=rank, backend=backend,
            block=self.block, row_tile=self.row_tile, allow=allow,
            calibrate=calibrate, stats=self.stats, kernel=kernel,
            factor_ranks=factor_ranks, autotune=autotune,
            tensor_key=self.key, recalibrate=recalibrate)

    # -- workspaces --------------------------------------------------------
    def csf_for(self, mode: int):
        """The mode's CSF workspace: cached if available, else built once
        and memoized (and persisted when a cache is attached)."""
        if mode not in self._csf:
            self._csf[mode] = csf_mod.build_csf(
                self.tensor, mode, block=self.block, row_tile=self.row_tile)
        return self._csf[mode]

    def lin(self):
        """The tensor's single mode-agnostic linearized workspace
        (``core/linearized.py``): cached if available, else built once and
        memoized.  Goes through the module attribute so tests can
        monkeypatch ``linearized.build_linearized`` and assert a warm cache
        hit performs zero builds."""
        if self._lin is None:
            self._lin = lin_mod.build_linearized(
                self.tensor, block=self.block, row_tile=self.row_tile)
        return self._lin

    def workspace(self, plan) -> list:
        """Per-mode workspace list for ``plan`` (CSF, the shared linearized
        workspace, or raw COO per the planned layout) — the cache-aware
        analogue of :func:`repro.core.cpals.build_workspace`."""
        out = []
        for p in plan.modes:
            if p.layout in ("csf", "lin"):
                if (p.block, p.row_tile) != (self.block, self.row_tile):
                    raise ValueError(
                        f"plan wants (block={p.block}, row_tile={p.row_tile})"
                        f" but this tensor was ingested with tile="
                        f"({self.block}, {self.row_tile})")
            if p.layout == "csf":
                out.append(self.csf_for(p.mode))
            elif p.layout == "lin":
                out.append(self.lin())
            else:
                out.append(self.tensor)
        return out

    # -- label restoration -------------------------------------------------
    def restore_factors(self, factors: Sequence[Array]) -> tuple[Array, ...]:
        if self.relabeling is None:
            return tuple(factors)
        return self.relabeling.restore_factors(factors)

    def restore(self, decomp):
        """Map a CPDecomp computed in the relabeled space back to the
        original labels (lambda and fit are label-invariant)."""
        if self.relabeling is None:
            return decomp
        return dataclasses.replace(
            decomp, factors=self.restore_factors(decomp.factors))

    # -- reporting ---------------------------------------------------------
    def reorder_deltas(self) -> Optional[list[dict]]:
        """Per-mode (after - before) deltas of the reorder-sensitive stats,
        for the plan report's "reorder" column.  None when no reordering
        was applied (or a warm cache entry predates the stats)."""
        if self.stats_before is None:
            return None
        out = []
        for b, a in zip(self.stats_before, self.stats):
            out.append({
                "collision": a.block_collision_rate - b.block_collision_rate,
                "padding": a.padding_overhead - b.padding_overhead,
                "skew": a.skew - b.skew,
            })
        return out


def ingest(
    x: Union[SparseTensor, str, os.PathLike],
    *,
    reorder: str = "identity",
    compact: bool = False,
    cache: Union[IngestCache, str, os.PathLike, None] = None,
    tile: tuple[int, int] = (DEFAULT_BLOCK, DEFAULT_ROW_TILE),
    dims: Optional[Sequence[int]] = None,
    duplicates: str = "sum",
    seed: int = 0,
) -> Ingested:
    """Bytes-on-disk (or an in-memory tensor) -> planner-ready workspace.

    ``reorder``: one of ``repro.ingest.relabel.REORDERINGS``
    (``identity`` / ``degree_sort`` / ``random_block``).
    ``compact``: drop empty slices first (composes with ``reorder``).
    ``cache``: an :class:`IngestCache` or a root directory; a warm hit
    skips parse + relabel + stats + CSF build.
    ``tile``: the ``(block, row_tile)`` workspace geometry.
    ``dims``/``duplicates``: forwarded to the text reader for ``.tns``
    sources.
    """
    if reorder not in REORDERINGS:
        raise ValueError(
            f"unknown reorder {reorder!r}; one of {tuple(REORDERINGS)}")
    block, row_tile = int(tile[0]), int(tile[1])
    if isinstance(cache, (str, os.PathLike)):
        cache = IngestCache(cache)

    source = "memory" if isinstance(x, SparseTensor) else str(x)
    key = None
    if cache is not None:
        key = content_key(x, block=block, row_tile=row_tile,
                          reorder=reorder, compact=compact,
                          dims=dims, duplicates=duplicates,
                          extra=f"seed={seed}" if reorder == "random_block"
                          else "")
        with obs_trace.span("ingest.cache.load", warm=True):
            hit = cache.load(key)
        if hit is not None:
            t, relabeling, csfs, lin, stats, stats_before = hit
            return Ingested(
                tensor=t, relabeling=relabeling, stats=tuple(stats),
                stats_before=(None if stats_before is None
                              else tuple(stats_before)),
                block=block, row_tile=row_tile, source=source, key=key,
                cache=cache, cache_hit=True, _csf=csfs, _lin=lin)

    # -- cold path ---------------------------------------------------------
    if isinstance(x, SparseTensor):
        t = x
    else:
        with obs_trace.span("ingest.parse", source=source):
            t = reader.read_any(x, dims=dims, duplicates=duplicates)

    relabeling: Optional[Relabeling] = None
    stats_before = None
    if compact or reorder != "identity":
        with obs_trace.span("ingest.relabel", reorder=reorder,
                            compact=compact):
            stats_before = tuple(tensor_stats(t, block=block,
                                              row_tile=row_tile))
            rel = None
            if compact:
                rel = compact_fn(t)
                t = rel.apply(t)
            if reorder != "identity":
                r2 = make_reorder(t, reorder, block=block, seed=seed)
                t = r2.apply(t)
                rel = r2 if rel is None else rel.then(r2)
            relabeling = rel

    with obs_trace.span("ingest.stats"):
        stats = tuple(tensor_stats(t, block=block, row_tile=row_tile))

    csfs: dict[int, object] = {}
    lin = None
    if cache is not None:
        # ALLMODE build (SPLATT's storage policy): persist every mode so any
        # later plan — whatever layouts it picks — is a pure cache read.
        # The linearized workspace rides along (one buffer for all modes)
        # unless the tensor's dims exceed its 64-bit packed-index budget.
        with obs_trace.span("ingest.build", modes=t.order):
            for m in range(t.order):
                csfs[m] = csf_mod.build_csf(t, m, block=block,
                                            row_tile=row_tile)
            try:
                lin = lin_mod.build_linearized(t, block=block,
                                               row_tile=row_tile)
            except ValueError:
                lin = None
        with obs_trace.span("ingest.cache.store"):
            cache.store(key, t, relabeling, list(csfs.values()), list(stats),
                        None if stats_before is None else list(stats_before),
                        lin=lin)

    return Ingested(tensor=t, relabeling=relabeling, stats=stats,
                    stats_before=stats_before, block=block, row_tile=row_tile,
                    source=source, key=key, cache=cache, cache_hit=False,
                    _csf=csfs, _lin=lin)
