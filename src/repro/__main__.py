"""``python -m repro`` — the CLI front door (see repro.api.cli)."""
import sys

from repro.api.cli import main

sys.exit(main())
