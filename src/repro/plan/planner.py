"""The decomposition planner: per-mode (layout, impl, tile sizes) selection.

This is the seam the paper's central finding demands: the best MTTKRP
strategy is a *per-mode, per-tensor* property (§V-D), so the decomposition
drivers must not hardcode one ``impl`` string.  ``plan_decomposition``
inspects per-mode statistics (``repro.plan.stats``) and emits an explicit
:class:`DecompPlan` — one :class:`ModePlan` per mode — which
``core/cpals.py``, ``core/distributed.py`` and the launch layer all consume.

Policies:

* ``"auto"`` — the paper's regime rules: for each mode, every registered,
  capability-compatible impl (``repro.core.mttkrp.available_impls``) is
  scored with its declared cost model against the measured stats, and the
  argmin wins.  Contention-heavy modes (YELP-like skew) land on the sorted
  no-lock ``segment`` path; collision-light long modes (NELL-2-like) stay on
  ``gather_scatter``; on a TPU backend the Pallas kernel is preferred
  wherever its tile-padding overhead stays reasonable.
* any registered impl name — manual override, applied to every mode (still
  validated against the impl's declared capabilities and annotated with the
  measured stats, so ``plan_report`` can show what the override costs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax

from repro.core.coo import SparseTensor
from repro.core.csf import DEFAULT_BLOCK, DEFAULT_ROW_TILE, build_csf
from repro.core.mttkrp import REGISTRY, available_impls, get_impl, mttkrp

from .autotune import canonical_candidates
from .stats import ModeStats, mode_stats, tensor_stats


def _fits_lin_budget(t: SparseTensor, names, *, registry=None):
    """Drop linearized-layout candidates when the tensor's dims exceed the
    64-bit packed-index budget — the format simply does not apply there
    (``core/linearized.check_bit_budget``); CSF/COO candidates remain."""
    if any(get_impl(n, registry=registry).layout == "lin" for n in names):
        from repro.core.linearized import check_bit_budget

        try:
            check_bit_budget(t.dims)
        except ValueError:
            names = tuple(
                n for n in names
                if get_impl(n, registry=registry).layout != "lin")
    return names


def _kernel_registry(kernel: str) -> dict:
    """Impl table for a kernel family: "mttkrp" (CP family) or "ttmc" (the
    Tucker chain-of-modes contraction — same ImplSpec shape, own table)."""
    if kernel == "mttkrp":
        return REGISTRY
    if kernel == "ttmc":
        from repro.core.ttmc import TTMC_REGISTRY

        return TTMC_REGISTRY
    raise ValueError(f"unknown kernel {kernel!r}; one of ('mttkrp', 'ttmc')")


def _rank_for_mode(rank, mode: int) -> int:
    """Per-mode scoring width: an int applies to every mode; a sequence
    gives each mode its own width (the Tucker driver passes
    prod_{m != mode} R_m — the TTMc's per-entry work multiplier)."""
    if isinstance(rank, (int, float)):
        return int(rank)
    return int(rank[mode])


@dataclasses.dataclass(frozen=True)
class ModePlan:
    """The planner's decision for one mode.

    ``stats`` is None when planning skipped measurement (fixed policy with
    ``with_stats=False`` — the choice needs no evidence)."""

    mode: int
    impl: str
    layout: str            # "csf" (unified workspace) or "coo"
    block: int
    row_tile: int
    stats: Optional[ModeStats]
    costs: dict[str, float]  # candidate impl -> predicted/measured cost
    reason: str
    kernel: str = "mttkrp"   # kernel family the impl belongs to
    # where the cost table came from: "predicted" (declared cost models),
    # "measured-fresh" (timed this run), or "measured-cached" (loaded from
    # the persistent autotune store — repro.plan.autotune)
    source: str = "predicted"

    @property
    def predicted_regime(self) -> str:
        return self.stats.regime if self.stats is not None else "n/a"


@dataclasses.dataclass(frozen=True)
class DecompPlan:
    """Per-mode execution plan for one decomposition."""

    modes: tuple[ModePlan, ...]
    policy: str
    backend: str
    rank: int

    @property
    def order(self) -> int:
        return len(self.modes)

    @property
    def impls(self) -> tuple[str, ...]:
        return tuple(p.impl for p in self.modes)

    @property
    def layouts(self) -> tuple[str, ...]:
        return tuple(p.layout for p in self.modes)

    def mode_order_by_length(self) -> tuple[int, ...]:
        """Modes sorted longest-first — the distributed driver partitions the
        two longest modes over the grid and exchanges the shortest."""
        if any(p.stats is None for p in self.modes):
            raise ValueError("plan was built with with_stats=False; "
                             "mode lengths are unknown")
        return tuple(sorted(range(self.order),
                            key=lambda m: -self.modes[m].stats.rows))

    def summary(self) -> str:
        return " ".join(f"m{p.mode}:{p.impl}" for p in self.modes)


def _layout_for(impl: str, *, registry: Optional[dict] = None) -> str:
    spec = get_impl(impl, registry=registry)
    # "any"-layout impls (gather_scatter) run straight off COO when they are
    # the only consumer of a mode, skipping that mode's sort entirely.
    if spec.layout in ("csf", "lin"):
        return spec.layout
    return "coo"


def _measure_ms(fn, *args, iters: int = 3) -> float:
    """Median wall-clock ms of a jitted call (1 warmup compile)."""
    jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e3


def _calibrate_mode(t: SparseTensor, mode: int, names, *, rank: int,
                    block: int, row_tile: int, kernel: str = "mttkrp",
                    factor_ranks: Optional[Sequence[int]] = None
                    ) -> dict[str, float]:
    """Measured per-impl kernel ms for one mode on the actual tensor.

    Part of planning-time pre-processing (same budget class as the sort
    stage): one workspace build shared by the sorted candidates, a short
    median-of-3 timing per candidate.  ``kernel`` selects what is timed —
    the MTTKRP (CP family) or the TTMc (Tucker); the TTMc needs
    ``factor_ranks`` (the per-mode Tucker ranks) to build timing factors,
    because its scoring ``rank`` is the Kronecker *output* width, not any
    factor's width."""
    import functools

    registry = _kernel_registry(kernel)
    if kernel == "ttmc":
        from repro.core.ttmc import ttmc as kernel_fn

        if factor_ranks is None:
            raise ValueError(
                "calibrate=True for kernel='ttmc' needs factor_ranks= (the "
                "per-mode Tucker ranks) to build timing factors; the Tucker "
                "drivers and repro.api.Session pass them automatically")
        keys = jax.random.split(jax.random.PRNGKey(0), t.order)
        factors = tuple(
            jax.random.normal(k, (int(d), int(r)), dtype=t.vals.dtype)
            for k, d, r in zip(keys, t.dims, factor_ranks))
    else:
        from repro.core.cpals import init_factors

        kernel_fn = mttkrp
        factors = init_factors(t.dims, rank, jax.random.PRNGKey(0),
                               dtype=t.vals.dtype)
    csf = None
    lin = None
    measured = {}
    for name in names:
        spec = get_impl(name, registry=registry)
        if spec.layout == "csf":
            if csf is None:
                csf = build_csf(t, mode, block=block, row_tile=row_tile)
            ws = csf
        elif spec.layout == "lin":
            if lin is None:
                from repro.core.linearized import build_linearized

                lin = build_linearized(t, block=block, row_tile=row_tile)
            ws = lin
        else:
            ws = t
        fn = jax.jit(functools.partial(kernel_fn, impl=name, mode=mode))
        measured[name] = _measure_ms(fn, ws, factors)
    return measured


def _measured_costs(t: SparseTensor, mode: int, names, *, rank: int,
                    block: int, row_tile: int, backend: str, kernel: str,
                    factor_ranks: Optional[Sequence[int]],
                    stats: Optional[ModeStats], autotune, tensor_key,
                    recalibrate: bool) -> tuple[dict[str, float], str]:
    """The calibration path with the persistent autotune store in front.

    Returns ``(costs, source)`` where ``source`` is ``"measured-cached"``
    (store hit: zero timing runs) or ``"measured-fresh"`` (a true miss —
    or ``recalibrate=True`` — timed on the actual tensor and, when a store
    is attached, persisted for the next planner)."""
    key = None
    if autotune is not None and tensor_key is not None:
        from .autotune import calibration_key
        from .stats import stats_digest

        key = calibration_key(
            tensor_key, mode=mode, names=names, backend=backend, rank=rank,
            kernel=kernel, block=block, row_tile=row_tile,
            stats_digest=stats_digest(() if stats is None else (stats,)))
        if not recalibrate:
            hit = autotune.load(key)
            if hit is not None and set(hit["costs"]) == set(names):
                return dict(hit["costs"]), "measured-cached"
    from repro.obs import trace as obs_trace

    with obs_trace.span("plan.calibrate", mode=mode, kernel=kernel,
                        candidates=len(tuple(names))):
        costs = _calibrate_mode(t, mode, names, rank=rank, block=block,
                                row_tile=row_tile, kernel=kernel,
                                factor_ranks=factor_ranks)
    if key is not None:
        autotune.store(key, costs, meta={
            "mode": mode, "backend": backend, "rank": int(rank),
            "kernel": kernel, "block": block, "row_tile": row_tile})
    return costs, "measured-fresh"


def plan_mode(t: SparseTensor, mode: int, *, rank,
              backend: str, block: int, row_tile: int,
              allow: Optional[Sequence[str]] = None,
              calibrate: bool = False,
              stats: Optional[ModeStats] = None,
              kernel: str = "mttkrp",
              factor_ranks: Optional[Sequence[int]] = None,
              autotune=None, tensor_key: Optional[str] = None,
              recalibrate: bool = False) -> ModePlan:
    """Score every capability-compatible impl for one mode, pick the argmin.

    ``calibrate=True`` replaces the declared cost models with measured
    timings on the actual tensor (costs are then in milliseconds).
    ``stats``: precomputed :class:`ModeStats` (e.g. measured once at ingest
    — ``repro.ingest``); when given, the stats pass is skipped.
    ``kernel``: the sparse kernel family being planned — ``"mttkrp"`` (CP
    family) or ``"ttmc"`` (Tucker); ``rank`` is the per-entry output width
    the cost models score (an int, or a per-mode sequence — the Tucker
    driver passes prod of the *other* modes' ranks).  ``factor_ranks``:
    the per-mode Tucker ranks, required when calibrating the ttmc kernel
    (timing factors cannot be recovered from the Kronecker widths alone).
    ``autotune``/``tensor_key``: the persistent calibration store and the
    tensor's content key (``repro.plan.autotune``) — on a hit the timing
    loop is skipped entirely; ``recalibrate=True`` forces a fresh measured
    pass and overwrites the stored entry."""
    registry = _kernel_registry(kernel)
    mode_rank = _rank_for_mode(rank, mode)
    if stats is None:
        stats = mode_stats(t, mode, block=block, row_tile=row_tile)
    elif (stats.block, stats.row_tile) != (block, row_tile):
        raise ValueError(
            f"precomputed stats were measured for (block={stats.block}, "
            f"row_tile={stats.row_tile}), planner asked (block={block}, "
            f"row_tile={row_tile})")
    names = canonical_candidates(
        _fits_lin_budget(t, available_impls(order=t.order, backend=backend,
                                            allow=allow, registry=registry),
                         registry=registry))
    if not names:
        raise ValueError(
            f"no registered {kernel} impl covers order={t.order} on "
            f"backend={backend!r} (allow={allow})")
    if calibrate:
        costs, source = _measured_costs(
            t, mode, names, rank=mode_rank, block=block, row_tile=row_tile,
            backend=backend, kernel=kernel, factor_ranks=factor_ranks,
            stats=stats, autotune=autotune, tensor_key=tensor_key,
            recalibrate=recalibrate)
        unit = "ms"
    else:
        costs = {}
        for name in names:
            spec = get_impl(name, registry=registry)
            costs[name] = (spec.cost_model(stats, mode_rank)
                           if spec.cost_model is not None else float("inf"))
        unit, source = "", "predicted"
    winner = min(costs, key=costs.get)
    runner_up = sorted(costs.values())[1] if len(costs) > 1 else float("inf")
    reason = (
        f"{stats.regime} regime (collision={stats.collision_rate:.2f}, "
        f"padding={stats.padding_overhead:.2f}); {source} cost "
        f"{costs[winner]:.3g}{unit} vs next {runner_up:.3g}{unit}")
    return ModePlan(mode=mode, impl=winner,
                    layout=_layout_for(winner, registry=registry),
                    block=block, row_tile=row_tile, stats=stats,
                    costs=costs, reason=reason, kernel=kernel, source=source)


def plan_decomposition(
    t: SparseTensor,
    policy: str = "auto",
    *,
    rank=16,
    backend: Optional[str] = None,
    block: int = DEFAULT_BLOCK,
    row_tile: int = DEFAULT_ROW_TILE,
    allow: Optional[Sequence[str]] = None,
    calibrate: bool = False,
    with_stats: bool = True,
    stats: Optional[Sequence[ModeStats]] = None,
    kernel: str = "mttkrp",
    factor_ranks: Optional[Sequence[int]] = None,
    autotune=None,
    tensor_key: Optional[str] = None,
    recalibrate: bool = False,
) -> DecompPlan:
    """Emit a :class:`DecompPlan` for ``t`` under ``policy``.

    ``policy="auto"`` selects per mode by capability + cost model;
    any registered impl name pins every mode to that impl (manual override).
    ``backend`` defaults to ``jax.default_backend()``; ``allow`` restricts
    the candidate set (the distributed driver passes the impls its shard_map
    body can express — a fixed policy outside it is rejected).
    ``calibrate=True`` spends planning-time compute (a short timed MTTKRP
    per candidate per mode, on the actual tensor) to replace predicted costs
    with measured ones — the fully adaptive selection of Laukemann et al.'s
    format-aware line of work.  ``with_stats=False`` skips the per-mode
    stats pass for fixed policies whose decision needs no evidence (the
    drivers' zero-overhead path); auto always measures.
    ``stats``: precomputed per-mode statistics (one per mode, same tile
    geometry) — what ``repro.ingest`` measures once at ingestion so the
    planner never re-walks the tensor.
    ``kernel``: the sparse kernel family whose registry is scored —
    ``"mttkrp"`` (CP-family methods) or ``"ttmc"`` (Tucker/HOOI; the
    Tucker driver passes a per-mode ``rank`` sequence of Kronecker widths,
    and ``factor_ranks`` — the underlying per-mode Tucker ranks — when
    calibration needs to build timing factors).
    ``autotune``: a persistent calibration store (an
    :class:`~repro.plan.autotune.AutotuneStore` or its root path) consulted
    before any timing run; on a hit the plan is measured-cost-accurate with
    **zero** measurements.  ``tensor_key`` is the store's tensor content
    key (``repro.ingest`` passes the ingest-cache key; computed from the
    tensor's bytes here when omitted).  ``recalibrate=True`` skips the
    lookup, re-times every candidate and overwrites the stored entries.
    """
    registry = _kernel_registry(kernel)
    if backend is None:
        backend = jax.default_backend()
    if stats is not None and len(stats) != t.order:
        raise ValueError(f"precomputed stats cover {len(stats)} modes, "
                         f"tensor has {t.order}")
    if calibrate and autotune is not None:
        from .autotune import as_store

        autotune = as_store(autotune)
        if tensor_key is None:
            from repro.ingest.cache import content_key

            tensor_key = content_key(t, block=block, row_tile=row_tile)
    if policy == "auto":
        modes = tuple(
            plan_mode(t, m, rank=rank, backend=backend, block=block,
                      row_tile=row_tile, allow=allow, calibrate=calibrate,
                      stats=None if stats is None else stats[m],
                      kernel=kernel, factor_ranks=factor_ranks,
                      autotune=autotune, tensor_key=tensor_key,
                      recalibrate=recalibrate)
            for m in range(t.order))
        return DecompPlan(modes=modes, policy=policy, backend=backend,
                          rank=rank)

    # raises with the registry listing if unknown
    spec = get_impl(policy, registry=registry)
    if allow is not None and policy not in allow:
        raise ValueError(f"impl {policy!r} is not in the allowed set {allow}")
    if t.order > 3 and not spec.supports_order_gt3:
        raise ValueError(
            f"impl {policy!r} does not support order-{t.order} tensors "
            "(capability supports_order_gt3=False)")
    if stats is not None:
        for s in stats:
            if (s.block, s.row_tile) != (block, row_tile):
                raise ValueError(
                    f"precomputed stats were measured for (block={s.block}, "
                    f"row_tile={s.row_tile}), planner asked (block={block}, "
                    f"row_tile={row_tile})")
        stats_per_mode = list(stats)
    else:
        if with_stats or calibrate:
            from repro.obs import trace as obs_trace

            with obs_trace.span("plan.stats"):
                stats_per_mode = tensor_stats(t, block=block,
                                              row_tile=row_tile)
        else:
            stats_per_mode = [None] * t.order
    modes = []
    for m, stats in enumerate(stats_per_mode):
        source = "predicted"
        if calibrate:
            costs, source = _measured_costs(
                t, m, (policy,), rank=_rank_for_mode(rank, m), block=block,
                row_tile=row_tile, backend=backend, kernel=kernel,
                factor_ranks=factor_ranks, stats=stats, autotune=autotune,
                tensor_key=tensor_key, recalibrate=recalibrate)
            reason = (f"fixed policy {policy!r}; {source} "
                      f"{costs[policy]:.3g}ms")
        elif stats is not None:
            cost = (spec.cost_model(stats, _rank_for_mode(rank, m))
                    if spec.cost_model is not None else float("inf"))
            costs = {policy: cost}
            reason = f"fixed policy {policy!r}"
        else:
            costs = {}
            reason = f"fixed policy {policy!r} (stats skipped)"
        modes.append(ModePlan(
            mode=m, impl=policy,
            layout=_layout_for(policy, registry=registry),
            block=block, row_tile=row_tile, stats=stats,
            costs=costs, reason=reason, kernel=kernel, source=source))
    return DecompPlan(modes=tuple(modes), policy=policy, backend=backend,
                      rank=rank)
