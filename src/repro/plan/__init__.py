"""repro.plan — per-mode decomposition planning.

The paper's §V-D finding (the best MTTKRP strategy is a per-mode, per-tensor
property) as an explicit subsystem: measure per-mode statistics, score the
registered implementations' declared cost models, and emit a
:class:`DecompPlan` that the CP-ALS drivers, the distributed driver and the
launch layer all execute.  See ``docs/architecture.md`` ("The decomposition
planner").
"""
from .stats import (CONTENTION_THRESHOLD, ModeStats, mode_stats,
                    stats_digest, tensor_stats)
from .planner import DecompPlan, ModePlan, plan_decomposition, plan_mode
from .autotune import AutotuneStore, calibration_key, registry_fingerprint

__all__ = [
    "CONTENTION_THRESHOLD", "ModeStats", "mode_stats", "tensor_stats",
    "stats_digest",
    "DecompPlan", "ModePlan", "plan_decomposition", "plan_mode",
    "AutotuneStore", "calibration_key", "registry_fingerprint",
]
