"""Per-mode tensor statistics — the planner's evidence.

The paper's §V-D finding is that the best MTTKRP strategy is a property of
the *mode being updated*, not of the decomposition: a mode whose non-zeros
concentrate on few output rows (YELP-like skew) lands scatter-adds in the
mutex/atomic contention regime, while a mode with long, uniformly-hit output
dimension pays mostly padding on the sorted path.  ``mode_stats`` measures
exactly the quantities the registry's cost models consume:

* ``collision_rate`` — expected fraction of entries in a random block of
  ``block`` non-zeros that collide (share an output row) with another entry
  of the block.  This is the contention the scatter-add serializes and the
  one-hot MXU matmul absorbs.  Computed exactly from the row histogram:
  E[unique rows in a k-sample] = sum_i (1 - (1 - c_i/nnz)^k).
* ``block_collision_rate`` — the *measured* analogue of ``collision_rate``
  on the tensor's actual storage order: the fraction of entries that share
  their output row with another entry of the same consecutive size-``block``
  chunk of the non-zero list.  Unlike the histogram expectation (which is
  invariant under any relabeling/reordering), this depends on how the
  non-zeros are linearized — it is the quantity ``repro.ingest``'s
  locality-aware reorderings act on (ALTO's observation, arXiv:2403.06348:
  non-zero linearization dominates locality and contention).
* ``padding_overhead`` — fraction of the unified CSF workspace that would be
  padding for this mode (tile-align + block-pad), computed without building
  the workspace.  This is the sorted path's cost.
* ``skew`` / ``hot_row_share`` — max-row concentration, the YELP-vs-NELL-2
  axis of the paper's Table I.

Everything is host-side numpy over the COO indices (same cost class as the
sort stage itself).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.coo import SparseTensor

# collision_rate above this puts a mode in the paper's mutex/atomic
# contention regime (scatter-adds mostly serialize); below it the mode is
# collision-light ("no-lock cheap either way").
CONTENTION_THRESHOLD = 0.5


@dataclasses.dataclass(frozen=True)
class ModeStats:
    """Measured per-mode statistics for one candidate workspace geometry."""

    mode: int
    order: int
    rows: int
    nnz: int
    avg_nnz_per_row: float
    max_nnz_per_row: int
    skew: float             # max_nnz_per_row / avg_nnz_per_row
    hot_row_share: float    # max_nnz_per_row / nnz
    collision_rate: float   # expected intra-block colliding fraction
    padding_overhead: float  # padding fraction of the tiled CSF workspace
    block: int
    row_tile: int
    # measured colliding fraction over consecutive storage-order chunks
    # (layout-dependent; see module docstring).  Defaults keep older
    # construction sites / cached payloads valid.
    block_collision_rate: float = 0.0

    @property
    def regime(self) -> str:
        """The paper-§V-D regime this mode lands in for scatter-style impls."""
        return ("contention" if self.collision_rate > CONTENTION_THRESHOLD
                else "no-lock")


def _collision_rate(counts: np.ndarray, nnz: int, block: int) -> float:
    """1 - E[unique rows in a uniform k-sample] / k, k = min(block, nnz)."""
    if nnz <= 1:
        return 0.0
    k = min(block, nnz)
    p = counts[counts > 0].astype(np.float64) / float(nnz)
    expected_unique = float(np.sum(1.0 - np.power(1.0 - p, k)))
    return float(max(0.0, 1.0 - expected_unique / k))


def measured_block_collision(idx: np.ndarray, block: int) -> float:
    """Measured intra-block collision of ``idx`` (output rows in storage
    order): ``1 - unique rows per consecutive size-``block`` chunk / chunk
    size`` — the same functional form as the expected ``collision_rate``,
    but over the *actual* chunks a vectorized scatter-add would process.

    Unlike the histogram expectation (invariant under any relabeling), this
    changes when the non-zero list is relinearized
    (``repro.ingest.relabel``)."""
    idx = np.asarray(idx)
    n = int(idx.shape[0])
    if n <= 1:
        return 0.0
    chunk = (np.arange(n, dtype=np.int64) // block)
    key = chunk * (int(idx.max()) + 1) + idx.astype(np.int64)
    unique_per_chunk_total = np.unique(key).shape[0]
    return float(max(0.0, 1.0 - unique_per_chunk_total / n))


def _padding_overhead(rows_sorted_counts_per_tile: np.ndarray, nnz: int,
                      block: int) -> float:
    blocks_per = np.maximum(1, -(-rows_sorted_counts_per_tile // block))
    pnnz = int(blocks_per.sum()) * block
    return 1.0 - nnz / max(1, pnnz)


def mode_stats(t: SparseTensor, mode: int, *, block: int,
               row_tile: int) -> ModeStats:
    """Measure one mode of ``t`` against a (block, row_tile) workspace."""
    if not 0 <= mode < t.order:
        raise ValueError(f"mode {mode} out of range for order-{t.order} tensor")
    rows = int(t.dims[mode])
    nnz = int(t.nnz)
    idx = np.asarray(t.inds[:nnz, mode])
    counts = np.bincount(idx, minlength=rows)
    max_c = int(counts.max()) if nnz else 0
    avg = nnz / max(1, rows)

    n_tiles = -(-rows // row_tile)
    tile_counts = np.bincount(idx // row_tile, minlength=n_tiles)

    return ModeStats(
        mode=mode,
        order=t.order,
        rows=rows,
        nnz=nnz,
        avg_nnz_per_row=avg,
        max_nnz_per_row=max_c,
        skew=max_c / max(avg, 1e-12),
        hot_row_share=max_c / max(1, nnz),
        collision_rate=_collision_rate(counts, nnz, block),
        padding_overhead=_padding_overhead(tile_counts, nnz, block),
        block=block,
        row_tile=row_tile,
        block_collision_rate=measured_block_collision(idx, block),
    )


def tensor_stats(t: SparseTensor, *, block: int,
                 row_tile: int) -> list[ModeStats]:
    """One :class:`ModeStats` per mode (the planner's full evidence set)."""
    return [mode_stats(t, m, block=block, row_tile=row_tile)
            for m in range(t.order)]


def stats_digest(stats) -> str:
    """Short content digest over measured :class:`ModeStats`.

    Part of the autotune store's calibration key
    (``repro.plan.autotune``): two tensors whose bytes hash alike but whose
    measured per-mode statistics differ (e.g. an in-memory relabeling that
    reused a content key) must not share cached timings.  Floats survive
    the JSON round-trip of the ingest cache exactly (``repr`` is
    shortest-round-trip), so warm-loaded stats digest identically to the
    ones measured at ingest."""
    import hashlib

    h = hashlib.sha256()
    for s in stats:
        h.update(repr(dataclasses.astuple(s)).encode())
    return h.hexdigest()[:16]
