"""Persistent autotune store — measured calibration outcomes that outlive
the process.

``plan_decomposition(calibrate=True)`` replaces the registry's declared
cost models with measured per-impl kernel timings on the actual tensor.
That measurement is planning-time compute in the same budget class as the
CSF sort — and, like the sort (``repro.ingest.IngestCache``), its outcome
is a pure function of inputs that rarely change: the tensor's bytes, the
candidate impl set, the jax backend, the scored rank and the workspace
geometry.  This module persists those outcomes so a warm plan performs
**zero timing runs**:

* :func:`calibration_key` — sha256 over (tensor content key, mode,
  candidate impl names, backend, rank, kernel family, block/row_tile
  geometry, a per-mode stats digest) *plus* :func:`registry_fingerprint`,
  a digest of every registered :class:`~repro.core.mttkrp.ImplSpec`'s
  declared capabilities.  Registering, removing or re-declaring an impl
  changes the fingerprint, so every cached measurement made against the
  old registry is invalidated implicitly — stale entries are simply never
  addressed again.
* :class:`AutotuneStore` — one small JSON per key under
  ``<root>/<key[:2]>/<key>.json``, written atomically (tmp + rename) like
  the ingest cache's entries; ``hits``/``misses`` counters make cache
  behaviour assertable in tests.

The store rides inside :class:`~repro.ingest.IngestCache` (its
``autotune`` property roots one at ``<cache root>/autotune``), so any
``Ingested`` handle with a cache attached gets persistent calibration for
free, and ``--recalibrate`` (``repro.api.cli``) is the escape hatch that
forces a fresh measured pass and overwrites the entry.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import time
from pathlib import Path
from typing import Optional, Sequence, Union

CALIBRATION_FORMAT_VERSION = 1


def canonical_candidates(names: Sequence[str]) -> tuple[str, ...]:
    """THE canonical ordering of a candidate impl set — sorted by name.

    Every consumer of a candidate set (the calibration key here, the
    planner's cost table, ``plan_report``'s costs column) goes through this
    one helper, so the on-disk key, the in-memory ``ModePlan.costs`` dict
    and the human-facing report all agree on one ordering.  Registry
    *insertion* order is never part of any cache identity: re-ordering
    registrations must not invalidate cached calibrations (only the
    registry fingerprint — declared capabilities — may)."""
    return tuple(sorted(names))


def registry_fingerprint(kernel: str) -> str:
    """Digest of the kernel family's registry *as declared*: impl names plus
    every capability field of each :class:`ImplSpec`.  Any registry change
    (new impl, removed impl, changed layout/backend/capability) yields a new
    fingerprint, which invalidates every calibration key built on the old
    one — the store's staleness rule, enforced by construction."""
    from .planner import _kernel_registry

    registry = _kernel_registry(kernel)
    h = hashlib.sha256()
    h.update(f"calib-v{CALIBRATION_FORMAT_VERSION}|kernel={kernel}|".encode())
    for name in sorted(registry):
        s = registry[name]
        h.update(f"{name}|{s.layout}|{int(s.needs_sorted)}|"
                 f"{int(s.supports_order_gt3)}|{s.backend}|"
                 f"{int(s.benchmark_only)}|{int(s.oracle)}|".encode())
    return h.hexdigest()[:16]


def calibration_key(
    tensor_key: str,
    *,
    mode: int,
    names: Sequence[str],
    backend: str,
    rank: int,
    kernel: str = "mttkrp",
    block: int,
    row_tile: int,
    stats_digest: str = "",
) -> str:
    """sha256 key for one mode's measured cost table.

    ``tensor_key`` is the ingest cache's content key (sha256 over the
    tensor/file bytes + ingest options); ``names`` is the candidate impl
    set that was measured (order-insensitive: sorted into the key);
    ``rank`` is the mode's scoring rank (the Kronecker width for ttmc);
    ``stats_digest`` is a short digest of the mode's measured
    :class:`~repro.plan.stats.ModeStats` — a tripwire separating tensors
    that hash alike but were relabeled in memory."""
    h = hashlib.sha256()
    h.update(f"reg={registry_fingerprint(kernel)}|tensor={tensor_key}|"
             f"mode={mode}|names={','.join(canonical_candidates(names))}|"
             f"backend={backend}|rank={rank}|kernel={kernel}|"
             f"block={block}|row_tile={row_tile}|"
             f"stats={stats_digest}|".encode())
    return h.hexdigest()


@dataclasses.dataclass
class AutotuneStore:
    """Content-addressed store of measured calibration tables under ``root``.

    Each entry is one JSON file ``{"version", "costs": {impl: ms}, "meta"}``;
    writes are atomic (tmp file + ``os.replace``) so concurrent planners at
    worst re-measure, never read a torn entry."""

    root: Path
    hits: int = 0
    misses: int = 0

    def __init__(self, root: Union[str, os.PathLike]):
        self.root = Path(root)
        self.hits = 0
        self.misses = 0

    def _path(self, key: str) -> Path:
        return self.root / key[:2] / f"{key}.json"

    def has(self, key: str) -> bool:
        return self._path(key).exists()

    def load(self, key: str) -> Optional[dict]:
        """The stored ``{"costs": {impl: ms}, "meta": {...}}`` payload, or
        None on a miss / version mismatch.  Counts hits/misses (instance
        counters AND the obs metrics registry's ``autotune.hit`` /
        ``autotune.miss``)."""
        from repro.obs.metrics import get_registry
        from repro.obs.recorder import record_event

        p = self._path(key)
        try:
            payload = json.loads(p.read_text())
        except (OSError, json.JSONDecodeError):
            self.misses += 1
            get_registry().counter("autotune.miss").inc()
            record_event("cache", store="autotune", key=key, hit=False)
            return None
        if payload.get("version") != CALIBRATION_FORMAT_VERSION:
            p.unlink(missing_ok=True)  # self-heal: next store() republishes
            self.misses += 1
            get_registry().counter("autotune.miss").inc()
            record_event("cache", store="autotune", key=key, hit=False)
            return None
        self.hits += 1
        get_registry().counter("autotune.hit").inc()
        record_event("cache", store="autotune", key=key, hit=True)
        return payload

    def store(self, key: str, costs: dict, *,
              meta: Optional[dict] = None) -> None:
        p = self._path(key)
        p.parent.mkdir(parents=True, exist_ok=True)
        payload = {
            "version": CALIBRATION_FORMAT_VERSION,
            "costs": {name: float(ms) for name, ms in costs.items()},
            "meta": dict(meta or {}),
            "measured_at": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        }
        tmp = p.with_name(p.name + f".tmp{os.getpid()}")
        tmp.write_text(json.dumps(payload, indent=1))
        os.replace(tmp, p)


def as_store(x: Union["AutotuneStore", str, os.PathLike, None]
             ) -> Optional[AutotuneStore]:
    """Normalize a store argument: an AutotuneStore passes through, a path
    roots a new one, None stays None."""
    if x is None or isinstance(x, AutotuneStore):
        return x
    return AutotuneStore(x)
