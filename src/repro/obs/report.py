"""Render a recorded trace as the paper's Table-III-style breakdown.

``python -m repro trace <dir>`` reads ``<dir>/trace.jsonl`` (written by
:meth:`repro.api.Session` when ``obs.trace_dir`` is set) and prints a
per-routine table mirroring the paper's Table III — total time, share of
the fit stage, and the per-mode impl split — followed by a dump of
``<dir>/metrics.json`` when present.

The routine rows are the span names the fit drivers emit:
``sort`` / ``mttkrp`` / ``epilogue`` on the default fused path, plus
``ata`` / ``inverse`` / ``norm`` / ``fit`` under ``obs.routines="split"``
(the paper's full routine set) and ``ttmc`` for Tucker/HOOI.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Optional, Sequence

from .trace import METRICS_FILENAME, TRACE_FILENAME, read_trace

# paper ordering: Table III lists sort, MTTKRP, then the epilogue chain
ROUTINE_ORDER = ("sort", "mttkrp", "epilogue", "ata", "inverse", "norm",
                 "fit", "ttmc", "solve")
_ROUTINE_LABEL = {"inverse": "inverse (solve)", "norm": "normalize",
                  "fit": "fit calc"}


def _complete(events: Sequence[dict]) -> list[dict]:
    return [e for e in events if e.get("ph") == "X"]


def _total_s(events: Sequence[dict], name: str) -> float:
    return sum(e.get("dur", 0.0) for e in events
               if e.get("name") == name) / 1e6


def _fmt_s(x: float) -> str:
    return f"{x:.3f}" if x < 100 else f"{x:.1f}"


def routine_breakdown(events: Sequence[dict]) -> dict:
    """Aggregate routine spans: per-routine totals, call counts and the
    per-mode/per-impl split, normalized against the fit stage's wall
    time.  Returns a plain dict (the CLI formats it; tests assert on
    it)."""
    events = _complete(events)
    wall_s = 0.0
    if events:
        start = min(e["ts"] for e in events)
        end = max(e["ts"] + e.get("dur", 0.0) for e in events)
        wall_s = (end - start) / 1e6

    stages = {}
    for stage in ("ingest", "plan", "fit", "serve"):
        total = _total_s(events, f"stage.{stage}")
        if total > 0:
            stages[stage] = total

    iterations = [e for e in events if e.get("name") == "iteration"]
    methods = sorted({e.get("args", {}).get("method") for e in iterations
                      if e.get("args", {}).get("method")})
    iteration_s = sum(e.get("dur", 0.0) for e in iterations) / 1e6

    # denominator for "% fit": the fit stage when the Session recorded
    # one, else the iterations themselves (driver called directly)
    fit_s = stages.get("fit") or iteration_s or wall_s

    routines = {}
    for e in events:
        name = e.get("name")
        if name not in ROUTINE_ORDER:
            continue
        args = e.get("args", {})
        row = routines.setdefault(name, {"calls": 0, "total_s": 0.0,
                                         "modes": {}})
        dur_s = e.get("dur", 0.0) / 1e6
        row["calls"] += 1
        row["total_s"] += dur_s
        mode = args.get("mode")
        if mode is not None:
            cell = row["modes"].setdefault(
                int(mode), {"impl": args.get("impl"), "total_s": 0.0})
            cell["total_s"] += dur_s
            if args.get("impl"):
                cell["impl"] = args["impl"]

    accounted = sum(r["total_s"] for r in routines.values())
    return {
        "events": len(events),
        "wall_s": wall_s,
        "stages": stages,
        "methods": methods,
        "iterations": len(iterations),
        "iteration_s": iteration_s,
        "fit_s": fit_s,
        "routines": routines,
        "unaccounted_s": max(0.0, fit_s - accounted),
    }


def format_breakdown(summary: dict) -> str:
    """The Table-III-style markdown table for one trace."""
    lines = [f"# trace: {summary['events']} events, "
             f"wall {_fmt_s(summary['wall_s'])}s"]
    if summary["stages"]:
        lines.append("# stages: " + " | ".join(
            f"{k} {_fmt_s(v)}s" for k, v in summary["stages"].items()))
    if summary["iterations"]:
        lines.append(
            f"# fit: method={','.join(summary['methods']) or '?'} "
            f"iterations={summary['iterations']} "
            f"({_fmt_s(summary['iteration_s'])}s inside iterations)")

    fit_s = summary["fit_s"]
    routines = summary["routines"]
    if not routines:
        lines.append("# no routine spans recorded (was the fit traced?)")
        return "\n".join(lines)

    lines += ["",
              "| routine | calls | total_s | % fit | per-mode impl split |",
              "|---|---|---|---|---|"]
    for name in ROUTINE_ORDER:
        if name not in routines:
            continue
        row = routines[name]
        share = 100.0 * row["total_s"] / fit_s if fit_s > 0 else 0.0
        per_mode = " · ".join(
            f"m{m} {cell['impl'] or '-'} {_fmt_s(cell['total_s'])}s"
            for m, cell in sorted(row["modes"].items())) or "-"
        lines.append(f"| {_ROUTINE_LABEL.get(name, name)} | {row['calls']} "
                     f"| {_fmt_s(row['total_s'])} | {share:5.1f}% "
                     f"| {per_mode} |")
    if summary["unaccounted_s"] > 0 and fit_s > 0:
        share = 100.0 * summary["unaccounted_s"] / fit_s
        lines.append(f"| (untraced) | - | {_fmt_s(summary['unaccounted_s'])} "
                     f"| {share:5.1f}% | dispatch, init, convergence |")
    return "\n".join(lines)


def format_metrics(snapshot: dict) -> str:
    """Metrics dump as a markdown table (one row per instrument)."""
    lines = ["", "# metrics", "| name | type | value |", "|---|---|---|"]
    for name, m in sorted(snapshot.items()):
        kind = m.get("type", "?")
        if kind == "histogram":
            mean = m.get("mean")
            value = (f"count={m.get('count')} "
                     f"mean={mean:.3g} " if mean is not None else
                     f"count={m.get('count')} ")
            for p in ("p50", "p90", "p99"):
                if m.get(p) is not None:
                    value += f"{p}={m[p]:.3g} "
            value = value.rstrip()
        else:
            value = f"{m.get('value')}"
        lines.append(f"| {name} | {kind} | {value} |")
    return "\n".join(lines)


def trace_report(trace_dir, *, with_metrics: bool = True) -> str:
    """The full ``python -m repro trace`` output for a trace directory
    (accepts the directory or a direct path to a ``trace.jsonl``)."""
    path = Path(trace_dir)
    trace_path = path if path.is_file() else path / TRACE_FILENAME
    if not trace_path.exists():
        raise FileNotFoundError(
            f"no {TRACE_FILENAME} under {path} — record one with "
            f"`python -m repro fit ... --trace-dir {path}`")
    out = format_breakdown(routine_breakdown(read_trace(trace_path)))
    if with_metrics:
        metrics_path = trace_path.parent / METRICS_FILENAME
        if metrics_path.exists():
            try:
                snapshot = json.loads(metrics_path.read_text())
            except json.JSONDecodeError:
                snapshot = None
            if isinstance(snapshot, dict) and snapshot:
                out += "\n" + format_metrics(snapshot)
    return out
