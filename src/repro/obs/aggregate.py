"""Cross-host metrics aggregation.

Each participating process dumps its registry with histogram windows
included (:func:`write_host_metrics` → ``metrics-<host>.json`` under the
trace dir); :func:`aggregate_dir` / :func:`merge_snapshots` fold those
per-host snapshots into one cluster view with fixed, documented
semantics:

* **counters sum** across hosts; the merged entry keeps a per-host
  ``hosts`` breakdown so a skewed host is visible in the merged view.
* **gauges keep per-host labels** — a last-write-wins scalar has no
  meaningful cross-host sum, so the merged entry's ``value`` is the
  last host's (sorted order) and ``hosts`` carries every host's value.
* **histogram windows merge**: exact ``count``/``total``/``min``/``max``
  combine exactly; the retained windows concatenate, truncate to the
  largest per-host ``window_size`` (keeping the most recent samples),
  and percentiles are recomputed over the merged window with the same
  nearest-rank rule as :class:`~repro.obs.metrics.Histogram`.

A name carrying different instrument types on different hosts is a
schema bug, not something to paper over — it raises ``ValueError``
naming the metric and both types.
"""
from __future__ import annotations

import json
import re
from pathlib import Path
from typing import Iterable, Optional

from .metrics import MetricsRegistry, window_percentile

HOST_METRICS_PATTERN = "metrics-*.json"
AGGREGATED_FILENAME = "metrics-aggregated.json"

_HOST_RE = re.compile(r"^metrics-(?P<host>.+)\.json$")


def host_metrics_filename(host: str) -> str:
    return f"metrics-{host}.json"


def write_host_metrics(directory, host: str, *,
                       registry: Optional[MetricsRegistry] = None,
                       snapshot: Optional[dict] = None) -> Path:
    """Dump one host's registry (windows included) as
    ``<dir>/metrics-<host>.json`` for later aggregation."""
    if snapshot is None:
        if registry is None:
            raise ValueError("need a registry or a snapshot to write")
        snapshot = registry.snapshot(with_window=True)
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    path = directory / host_metrics_filename(host)
    path.write_text(json.dumps({"host": host, "metrics": snapshot},
                               indent=1, sort_keys=True))
    return path


def _merge_histograms(name: str, entries: dict[str, dict]) -> dict:
    merged_window: list[tuple[float, float]] = []
    count = 0
    total = 0.0
    lo: Optional[float] = None
    hi: Optional[float] = None
    window_size = 0
    hosts = {}
    for host in sorted(entries):
        entry = entries[host]
        count += int(entry.get("count", 0))
        total += float(entry.get("total", 0.0))
        e_min, e_max = entry.get("min"), entry.get("max")
        if e_min is not None:
            lo = e_min if lo is None else min(lo, e_min)
        if e_max is not None:
            hi = e_max if hi is None else max(hi, e_max)
        window = entry.get("window", [])
        window_size = max(window_size, int(entry.get("window_size",
                                                     len(window))))
        merged_window.extend(float(v) for v in window)
        hosts[host] = {"count": entry.get("count", 0),
                       "total": entry.get("total", 0.0)}
    # keep the most recent samples up to the largest per-host bound, so
    # the merged histogram honors the same retention contract
    if window_size and len(merged_window) > window_size:
        merged_window = merged_window[-window_size:]
    ordered = sorted(merged_window)
    return {
        "type": "histogram",
        "count": count,
        "total": total,
        "mean": (total / count) if count else None,
        "min": lo,
        "max": hi,
        "p50": window_percentile(ordered, 50),
        "p90": window_percentile(ordered, 90),
        "p99": window_percentile(ordered, 99),
        "window_size": window_size,
        "hosts": hosts,
    }


def merge_snapshots(snapshots: dict[str, dict]) -> dict:
    """Merge ``{host: registry_snapshot}`` into one cluster snapshot.

    See the module docstring for the per-instrument semantics.  Raises
    ``ValueError`` if a metric name maps to different instrument types
    on different hosts."""
    by_name: dict[str, dict[str, dict]] = {}
    types: dict[str, str] = {}
    for host in sorted(snapshots):
        for name, entry in snapshots[host].items():
            kind = entry.get("type")
            seen = types.setdefault(name, kind)
            if seen != kind:
                raise ValueError(
                    f"metric {name!r} is a {seen} on one host and a "
                    f"{kind} on {host!r}; refusing to merge")
            by_name.setdefault(name, {})[host] = entry

    merged: dict[str, dict] = {}
    for name in sorted(by_name):
        entries = by_name[name]
        kind = types[name]
        if kind == "counter":
            hosts = {h: entries[h].get("value", 0.0)
                     for h in sorted(entries)}
            merged[name] = {"type": "counter",
                            "value": sum(hosts.values()),
                            "hosts": hosts}
        elif kind == "gauge":
            hosts = {h: entries[h].get("value") for h in sorted(entries)}
            last = hosts[sorted(hosts)[-1]]
            merged[name] = {"type": "gauge", "value": last, "hosts": hosts}
        elif kind == "histogram":
            merged[name] = _merge_histograms(name, entries)
        else:
            merged[name] = {"type": kind,
                            "hosts": {h: entries[h]
                                      for h in sorted(entries)}}
    return merged


def load_host_metrics(path) -> tuple[str, dict]:
    """Read one ``metrics-<host>.json``; host comes from the payload,
    falling back to the filename."""
    path = Path(path)
    payload = json.loads(path.read_text())
    host = payload.get("host")
    if not host:
        match = _HOST_RE.match(path.name)
        host = match.group("host") if match else path.stem
    return host, payload.get("metrics", {})


def aggregate_dir(directory, *,
                  write: bool = False) -> Optional[dict]:
    """Merge every ``metrics-<host>.json`` under ``directory``.

    Returns the merged snapshot wrapped with the host list, or None when
    no per-host files exist (single-process runs: ``metrics.json`` is
    already the whole story).  ``write=True`` also persists the result
    as ``metrics-aggregated.json``."""
    directory = Path(directory)
    paths = sorted(directory.glob(HOST_METRICS_PATTERN))
    paths = [p for p in paths if p.name != AGGREGATED_FILENAME]
    if not paths:
        return None
    snapshots: dict[str, dict] = {}
    for path in paths:
        host, snapshot = load_host_metrics(path)
        snapshots[host] = snapshot
    merged = {"hosts": sorted(snapshots), "metrics": merge_snapshots(snapshots)}
    if write:
        out = directory / AGGREGATED_FILENAME
        out.write_text(json.dumps(merged, indent=1, sort_keys=True))
    return merged


def read_aggregated(directory) -> Optional[dict]:
    path = Path(directory) / AGGREGATED_FILENAME
    if not path.exists():
        return None
    return json.loads(path.read_text())


def merge_files(paths: Iterable) -> dict:
    """Merge an explicit list of per-host metric files (CLI helper)."""
    snapshots: dict[str, dict] = {}
    for path in paths:
        host, snapshot = load_host_metrics(path)
        snapshots[host] = snapshot
    return {"hosts": sorted(snapshots),
            "metrics": merge_snapshots(snapshots)}
