"""Process-local metrics registry: counters, gauges, histograms.

One registry per process (module default, swappable for tests via
:func:`scoped_registry`) unifies the perf signals the repo already
measures but keeps scattered and internal:

* ``autotune.hit`` / ``autotune.miss``       — AutotuneStore lookups
* ``ingest.cache.hit`` / ``ingest.cache.miss`` — IngestCache loads
* ``straggler.slow`` / ``straggler.persistent`` — monitor escalations
* ``fit.iterations`` (counter), ``fit.fit`` (gauge),
  ``fit.iteration_ms`` (histogram)            — fit trajectory
* ``serve.query_ms`` (histogram)              — serve-query latency,
  summarized with p50/p90/p99

Deliberately jax-free and dependency-free so jax-free modules
(``repro.dist.straggler``) can feed it without import cycles, and so the
disabled-observability path costs a dict lookup plus a lock, nothing
more.
"""
from __future__ import annotations

import json
import math
import threading
from collections import deque
from contextlib import contextmanager
from typing import Iterator, Optional, Sequence

# per-histogram retention for percentile estimates; count/total/min/max
# are exact over ALL observations regardless
HISTOGRAM_WINDOW = 4096


class Counter:
    """Monotonically increasing float counter."""

    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> float:
        if amount < 0:
            raise ValueError(f"counters only go up, got inc({amount})")
        with self._lock:
            self.value += amount
            return self.value


class Gauge:
    """Last-write-wins scalar (fit value, active plan rank, ...)."""

    __slots__ = ("_lock", "value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self.value = None

    def set(self, value: float) -> None:
        with self._lock:
            self.value = float(value)


class Histogram:
    """Windowed histogram: exact count/total/min/max over every
    observation, percentiles over the last :data:`HISTOGRAM_WINDOW`."""

    __slots__ = ("_lock", "_window", "count", "total", "min", "max")

    def __init__(self, window: int = HISTOGRAM_WINDOW) -> None:
        self._lock = threading.Lock()
        self._window: deque = deque(maxlen=window)
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None

    def observe(self, value: float) -> None:
        value = float(value)
        with self._lock:
            self._window.append(value)
            self.count += 1
            self.total += value
            self.min = value if self.min is None else min(self.min, value)
            self.max = value if self.max is None else max(self.max, value)

    @property
    def window_size(self) -> int:
        """The retention bound (deque maxlen) percentiles are computed
        over — what a cross-host merge must preserve."""
        return self._window.maxlen

    def percentile(self, p: float) -> Optional[float]:
        """p-th percentile (nearest-rank) of the retained window."""
        with self._lock:
            window = sorted(self._window)
        return window_percentile(window, p)

    def summary(self) -> dict:
        with self._lock:
            window = sorted(self._window)
            count, total = self.count, self.total
            lo, hi = self.min, self.max

        return {
            "count": count,
            "total": total,
            "mean": (total / count) if count else None,
            "min": lo,
            "max": hi,
            "p50": window_percentile(window, 50),
            "p90": window_percentile(window, 90),
            "p99": window_percentile(window, 99),
        }

    def state(self) -> dict:
        """:meth:`summary` plus the raw retained window and its bound —
        the mergeable per-host form (``repro.obs.aggregate`` recomputes
        percentiles from the concatenated windows)."""
        with self._lock:
            window = list(self._window)
        return {**self.summary(), "window": window,
                "window_size": self.window_size}


def window_percentile(window: Sequence[float], p: float) -> Optional[float]:
    """Nearest-rank percentile of an already-sorted window (None when
    empty) — shared by :class:`Histogram` and the cross-host merge."""
    if not window:
        return None
    rank = max(1, math.ceil(p / 100.0 * len(window)))
    return window[rank - 1]


class MetricsRegistry:
    """Named instruments, created on first use.  A name is one kind of
    instrument forever — asking for ``counter("x")`` after ``gauge("x")``
    raises rather than silently splitting the signal."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._instruments: dict[str, object] = {}

    def _get(self, name: str, cls):
        with self._lock:
            instrument = self._instruments.get(name)
            if instrument is None:
                instrument = cls()
                self._instruments[name] = instrument
        if not isinstance(instrument, cls):
            raise TypeError(
                f"metric {name!r} is a {type(instrument).__name__}, "
                f"asked for {cls.__name__}")
        return instrument

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._get(name, Histogram)

    def snapshot(self, *, with_window: bool = False) -> dict:
        """JSON-ready dump: ``{name: {"type": ..., ...values...}}``.

        ``with_window=True`` includes each histogram's raw retained
        window (and its bound) — the per-host form
        ``repro.obs.aggregate`` merges across processes."""
        with self._lock:
            items = sorted(self._instruments.items())
        out = {}
        for name, instrument in items:
            if isinstance(instrument, Counter):
                out[name] = {"type": "counter", "value": instrument.value}
            elif isinstance(instrument, Gauge):
                out[name] = {"type": "gauge", "value": instrument.value}
            else:
                assert isinstance(instrument, Histogram)
                dump = (instrument.state() if with_window
                        else instrument.summary())
                out[name] = {"type": "histogram", **dump}
        return out

    def to_json(self, **dump_kwargs) -> str:
        dump_kwargs.setdefault("indent", 1)
        dump_kwargs.setdefault("sort_keys", True)
        return json.dumps(self.snapshot(), **dump_kwargs)

    def reset(self) -> None:
        with self._lock:
            self._instruments.clear()


_DEFAULT = MetricsRegistry()
_DEFAULT_LOCK = threading.Lock()


def get_registry() -> MetricsRegistry:
    """The process-wide default registry every instrumented module
    feeds."""
    return _DEFAULT


@contextmanager
def scoped_registry(
        registry: Optional[MetricsRegistry] = None
) -> Iterator[MetricsRegistry]:
    """Swap in a fresh (or given) default registry for the block —
    isolation for tests and benchmarks."""
    global _DEFAULT
    fresh = registry if registry is not None else MetricsRegistry()
    with _DEFAULT_LOCK:
        previous, _DEFAULT = _DEFAULT, fresh
    try:
        yield fresh
    finally:
        with _DEFAULT_LOCK:
            _DEFAULT = previous
