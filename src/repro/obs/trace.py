"""Hierarchical spans with Chrome-trace JSONL export.

Design constraints, in order:

1. **Near-zero cost when disabled.**  The module-level :func:`span` is
   what hot code calls (``with obs_trace.span("mttkrp", mode=n): ...``).
   When no tracer is active it returns one shared no-op context manager
   without touching the :class:`Tracer` class at all — a contextvar get,
   an ``is None`` check, done.  ``tests/test_obs.py`` pins this with a
   counting monkeypatch: a fit with obs disabled makes **zero**
   ``Tracer.span`` / ``Tracer._record`` calls.
2. **Thread-safe nesting via contextvars.**  The active tracer and the
   current parent span id both live in contextvars, so spans opened on
   worker threads (or under ``jax`` callbacks) nest under the right
   parent and two threads never corrupt each other's stacks.
3. **Chrome-trace/Perfetto-compatible output.**  :meth:`Tracer.export_jsonl`
   writes one JSON object per line using the trace-event schema's
   complete events (``"ph": "X"``, ``ts``/``dur`` in microseconds,
   ``pid``/``tid``) — ``chrome://tracing`` and https://ui.perfetto.dev
   load the file directly (both accept newline-delimited events).  The
   span hierarchy rides in ``args`` (``id``/``parent``) so
   :mod:`repro.obs.report` can rebuild the tree without relying on
   timestamp containment.
4. **XLA bridge.**  Each recorded span also opens a
   ``jax.profiler.TraceAnnotation`` so the same names show up inside an
   XLA profile (TensorBoard / Perfetto) when one is being captured.
   Disabled per-tracer with ``xla_annotations=False``, and skipped
   automatically when jax is not importable.
"""
from __future__ import annotations

import functools
import json
import os
import threading
import time
from contextlib import contextmanager
from contextvars import ContextVar
from pathlib import Path
from typing import Any, Callable, Iterator, Optional

TRACE_FILENAME = "trace.jsonl"
METRICS_FILENAME = "metrics.json"

# the active tracer (None → module-level span() is a no-op) and the id of
# the innermost open span in THIS thread/context (None → next span is a
# root; _DROPPED → inside an unsampled root, record nothing)
_ACTIVE: ContextVar[Optional["Tracer"]] = ContextVar(
    "repro_obs_active_tracer", default=None)
_PARENT: ContextVar[Any] = ContextVar("repro_obs_parent_span", default=None)
_DROPPED = object()


class _NullSpan:
    """Shared do-nothing span: the entire disabled-path cost."""

    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False


_NULL_SPAN = _NullSpan()


class _DroppedSpan:
    """An unsampled root span: marks the context so every descendant
    span is dropped with it (a half-recorded subtree would render as
    orphans in the trace viewer)."""

    __slots__ = ("_token",)

    def __enter__(self) -> "_DroppedSpan":
        self._token = _PARENT.set(_DROPPED)
        return self

    def __exit__(self, *exc) -> bool:
        _PARENT.reset(self._token)
        return False


class Span:
    """One open span; records a complete ("X") trace event on exit."""

    __slots__ = ("_tracer", "name", "cat", "attrs", "id", "parent",
                 "_token", "_start_ns", "_annotation")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 attrs: dict) -> None:
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.attrs = attrs

    def __enter__(self) -> "Span":
        tracer = self._tracer
        self.id = tracer._next_id()
        self.parent = _PARENT.get()
        self._token = _PARENT.set(self.id)
        self._annotation = None
        if tracer._annotation_cls is not None:
            self._annotation = tracer._annotation_cls(self.name)
            self._annotation.__enter__()
        self._start_ns = time.perf_counter_ns()
        return self

    def __exit__(self, *exc) -> bool:
        end_ns = time.perf_counter_ns()
        if self._annotation is not None:
            self._annotation.__exit__(*exc)
        _PARENT.reset(self._token)
        tracer = self._tracer
        args: dict = {"id": self.id}
        if self.parent is not None:
            args["parent"] = self.parent
        args.update(self.attrs)
        tracer._record({
            "name": self.name,
            "cat": self.cat,
            "ph": "X",
            "ts": (self._start_ns - tracer._epoch_ns) / 1e3,
            "dur": (end_ns - self._start_ns) / 1e3,
            "pid": tracer._pid,
            "tid": threading.get_ident(),
            "args": args,
        })
        return False


class Tracer:
    """Collects spans for one run; export with :meth:`export_jsonl`.

    ``sample_rate`` keeps 1-in-``round(1/rate)`` **root** spans
    (deterministic stride, not random — reruns produce identical traces);
    descendants always follow their root's fate.  ``routines`` is advice
    to the fit drivers: ``"fused"`` (default) times sort/mttkrp/epilogue —
    two device syncs per mode, the path that keeps enabled-tracing
    overhead under the benchmark gate — while ``"split"`` opts into the
    paper's full Table-III routine set (ata / inverse / norm / fit) at
    the cost of routine-by-routine synchronization (2.8-3.3x slower
    epilogue portion; see BENCH_cpals.json).
    """

    def __init__(self, *, enabled: bool = True, sample_rate: float = 1.0,
                 routines: str = "fused",
                 xla_annotations: bool = True) -> None:
        if not 0.0 < sample_rate <= 1.0:
            raise ValueError(f"sample_rate must be in (0, 1], "
                             f"got {sample_rate}")
        if routines not in ("fused", "split"):
            raise ValueError(f"routines must be 'fused' or 'split', "
                             f"got {routines!r}")
        self.enabled = bool(enabled)
        self.sample_rate = float(sample_rate)
        self.routines = routines
        self._stride = max(1, round(1.0 / self.sample_rate))
        self._events: list[dict] = []
        self._lock = threading.Lock()
        self._id_counter = 0
        self._root_counter = 0
        self._epoch_ns = time.perf_counter_ns()
        self._pid = os.getpid()
        self._annotation_cls = None
        if self.enabled and xla_annotations:
            try:
                from jax.profiler import TraceAnnotation
                self._annotation_cls = TraceAnnotation
            except Exception:  # jax absent or too old — spans still work
                self._annotation_cls = None

    # -- span construction -------------------------------------------------

    def span(self, name: str, *, cat: str = "repro", **attrs):
        """A context manager timing one span.  Keyword attrs land in the
        event's ``args`` (mode=, impl=, ...)."""
        if not self.enabled:
            return _NULL_SPAN
        parent = _PARENT.get()
        if parent is _DROPPED:
            return _NULL_SPAN
        if parent is None and self._stride > 1:
            with self._lock:
                root_index = self._root_counter
                self._root_counter += 1
            if root_index % self._stride:
                return _DroppedSpan()
        return Span(self, name, cat, attrs)

    @contextmanager
    def activate(self) -> Iterator["Tracer"]:
        """Make this tracer the target of the module-level :func:`span`
        within the block (contextvar-scoped: per thread/task)."""
        token = _ACTIVE.set(self)
        try:
            yield self
        finally:
            _ACTIVE.reset(token)

    # -- recording ---------------------------------------------------------

    def _next_id(self) -> int:
        with self._lock:
            self._id_counter += 1
            return self._id_counter

    def _record(self, event: dict) -> None:
        with self._lock:
            self._events.append(event)

    def events(self) -> list[dict]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
        self._epoch_ns = time.perf_counter_ns()

    # -- export ------------------------------------------------------------

    def export_jsonl(self, path) -> Path:
        """Write the trace as Chrome-trace JSONL (one event per line; a
        leading ``"M"`` metadata event names the process).  Returns the
        path written."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        lines = [json.dumps({
            "name": "process_name", "ph": "M", "pid": self._pid,
            "tid": 0, "args": {"name": "repro"}})]
        lines.extend(json.dumps(e, sort_keys=True) for e in self.events())
        path.write_text("\n".join(lines) + "\n")
        return path


# ---------------------------------------------------------------------------
# module-level API — what instrumented code imports
# ---------------------------------------------------------------------------


def current_tracer() -> Optional[Tracer]:
    """The tracer activated in this context, or None."""
    return _ACTIVE.get()


def tracing() -> bool:
    """True when an *enabled* tracer is active — drivers use this to
    switch onto their timed iteration path."""
    tracer = _ACTIVE.get()
    return tracer is not None and tracer.enabled


def span(name: str, *, cat: str = "repro", **attrs):
    """Open a span on the active tracer, or do nothing.

    The disabled path (no active tracer) is one contextvar read and
    returns a shared singleton — it never touches :class:`Tracer`.
    """
    tracer = _ACTIVE.get()
    if tracer is None:
        return _NULL_SPAN
    return tracer.span(name, cat=cat, **attrs)


def traced(name: Optional[str] = None, *, cat: str = "repro",
           **attrs) -> Callable:
    """Decorator form: ``@traced("ingest.parse")`` wraps the call in a
    span (named after the function when ``name`` is omitted)."""

    def decorate(fn: Callable) -> Callable:
        label = name if name is not None else fn.__qualname__

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            with span(label, cat=cat, **attrs):
                return fn(*args, **kwargs)

        return wrapper

    return decorate


def read_trace(path) -> list[dict]:
    """Parse a trace JSONL file back into its event dicts (metadata
    ``"M"`` events included; corrupt lines are skipped, never fatal)."""
    events = []
    for line in Path(path).read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            event = json.loads(line)
        except json.JSONDecodeError:
            continue
        if isinstance(event, dict) and "ph" in event:
            events.append(event)
    return events
