"""Flight recorder: a bounded ring buffer of structured events, plus the
postmortem machinery built on it (heartbeat snapshots, crash dumps).

Spans (``obs.trace``) answer *where the time went*; the flight recorder
answers *what just happened* when a long run dies or drifts.  Instrumented
code calls the module-level :func:`record_event` — one global read and an
``is None`` check when no recorder is active, mirroring ``trace.span()``'s
disabled-path contract — and the active :class:`FlightRecorder` keeps the
last ``capacity`` events in a deque:

* ``iteration``    — per-iteration fit/time records (``methods.iteration``)
* ``straggler``    — monitor escalations (``dist.straggler``)
* ``cache``        — ingest-cache / autotune hits and misses
* ``plan``         — planner decisions (per-mode impls, policy, source)
* ``stream.drift`` — streaming fit drops on new chunks (drift signal)
* ``dist.iteration`` — shard_map driver iterations

Three consumers:

* :class:`Heartbeat` — a daemon thread that atomically rewrites
  ``heartbeat.json`` under ``obs.trace_dir`` every ``interval`` seconds
  (metrics snapshot + recorder tail + stage), so a *live* long run can be
  inspected from the filesystem even with the HTTP exposition off, and a
  killed one leaves its last known state behind.
* :func:`write_crash_dump` — called by ``Session.fit`` on an unhandled
  exception: traceback + config + metrics + the event tail into
  ``crash.json``.  The postmortem for OOM-killed / preempted fits.
* ``Session.export_obs`` — dumps the ring as ``events.jsonl`` next to the
  trace.

Deliberately jax-free (like ``obs.metrics``) so jax-free modules feed it
without import cycles.
"""
from __future__ import annotations

import json
import os
import threading
import time
import traceback as traceback_mod
from collections import deque
from contextlib import contextmanager
from pathlib import Path
from typing import Callable, Iterator, Optional

HEARTBEAT_FILENAME = "heartbeat.json"
CRASH_FILENAME = "crash.json"
EVENTS_FILENAME = "events.jsonl"

DEFAULT_CAPACITY = 1024

# events kept inline in heartbeat/crash payloads — the full ring lives in
# events.jsonl; dumps want the recent tail, not megabytes of history
_TAIL_EVENTS = 64


class FlightRecorder:
    """Bounded ring buffer of structured events.

    ``record(kind, **fields)`` appends ``{"kind", "t", "seq", **fields}``;
    once ``capacity`` events are resident the oldest drop (``recorded``
    counts everything ever seen, so ``recorded - len(events())`` is the
    drop count).  Field values must be JSON-expressible — the ring is
    written verbatim into heartbeats, crash dumps and ``events.jsonl``.
    """

    def __init__(self, capacity: int = DEFAULT_CAPACITY) -> None:
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._events: deque = deque(maxlen=self.capacity)
        self.recorded = 0

    def record(self, kind: str, **fields) -> dict:
        event = {"kind": kind, "t": time.time(), **fields}
        with self._lock:
            event["seq"] = self.recorded
            self.recorded += 1
            self._events.append(event)
        return event

    def events(self, *, kind: Optional[str] = None) -> list[dict]:
        with self._lock:
            events = list(self._events)
        if kind is not None:
            events = [e for e in events if e.get("kind") == kind]
        return events

    def snapshot(self, *, tail: Optional[int] = None) -> dict:
        """JSON-ready state: capacity / total recorded / drop count and
        the (optionally tail-truncated) resident events."""
        with self._lock:
            events = list(self._events)
            recorded = self.recorded
        if tail is not None:
            events = events[-tail:]
        return {"capacity": self.capacity, "recorded": recorded,
                "dropped": recorded - len(self._events), "events": events}

    def export_jsonl(self, path) -> Path:
        """One event per line, oldest first (the resident ring only)."""
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        lines = [json.dumps(e, sort_keys=True) for e in self.events()]
        path.write_text("\n".join(lines) + ("\n" if lines else ""))
        return path

    @contextmanager
    def activate(self) -> Iterator["FlightRecorder"]:
        """Make this recorder the target of :func:`record_event` for the
        block (process-global, like the default metrics registry)."""
        global _ACTIVE
        with _ACTIVE_LOCK:
            previous, _ACTIVE = _ACTIVE, self
        try:
            yield self
        finally:
            with _ACTIVE_LOCK:
                _ACTIVE = previous


_ACTIVE: Optional[FlightRecorder] = None
_ACTIVE_LOCK = threading.Lock()


def current_recorder() -> Optional[FlightRecorder]:
    """The active recorder, or None (events are then dropped for free)."""
    return _ACTIVE


def record_event(kind: str, **fields) -> None:
    """Record one structured event on the active recorder, or do nothing.

    The disabled path is one global read and an ``is None`` check —
    jax-free modules (straggler monitor, ingest cache) call this
    unconditionally."""
    recorder = _ACTIVE
    if recorder is not None:
        recorder.record(kind, **fields)


# ---------------------------------------------------------------------------
# heartbeat snapshots
# ---------------------------------------------------------------------------


def _atomic_write_json(path: Path, payload: dict) -> None:
    tmp = path.with_suffix(path.suffix + f".tmp{os.getpid()}")
    tmp.write_text(json.dumps(payload, indent=1, sort_keys=True))
    os.replace(tmp, path)


class Heartbeat:
    """Daemon thread that periodically snapshots live state to disk.

    Every ``interval`` seconds (plus once at start and once at stop, so
    even a sub-interval run leaves a heartbeat behind) it atomically
    rewrites ``<dir>/heartbeat.json``::

        {"seq": 3, "t": ..., "interval_s": 5.0, "stage": "fit",
         "metrics": {...registry snapshot...},
         "events": {...recorder tail...}}

    ``info_fn`` contributes extra context (the Session passes its current
    stage and config summary).  Writes are atomic (tmp + rename): a
    reader never sees a torn heartbeat.
    """

    def __init__(self, directory, interval: float, *,
                 registry_fn: Optional[Callable[[], dict]] = None,
                 recorder: Optional[FlightRecorder] = None,
                 info_fn: Optional[Callable[[], dict]] = None) -> None:
        if interval <= 0:
            raise ValueError(f"interval must be > 0, got {interval}")
        self.path = Path(directory) / HEARTBEAT_FILENAME
        self.interval = float(interval)
        self._registry_fn = registry_fn
        self._recorder = recorder
        self._info_fn = info_fn
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.beats = 0

    def beat(self) -> None:
        """Write one heartbeat now (also called from the timer thread)."""
        payload: dict = {"seq": self.beats, "t": time.time(),
                         "interval_s": self.interval}
        if self._info_fn is not None:
            try:
                payload.update(self._info_fn())
            except Exception:  # info is advisory; the beat must land
                pass
        if self._registry_fn is not None:
            payload["metrics"] = self._registry_fn()
        if self._recorder is not None:
            payload["events"] = self._recorder.snapshot(tail=_TAIL_EVENTS)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        _atomic_write_json(self.path, payload)
        self.beats += 1

    def start(self) -> "Heartbeat":
        if self._thread is not None:
            return self
        self.beat()  # one beat immediately: short runs still leave state

        def loop() -> None:
            while not self._stop.wait(self.interval):
                self.beat()

        self._thread = threading.Thread(target=loop, name="repro-heartbeat",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None
        self.beat()  # final flush: the last known state survives the stop


# ---------------------------------------------------------------------------
# crash dumps
# ---------------------------------------------------------------------------


def write_crash_dump(directory, exc: BaseException, *,
                     recorder: Optional[FlightRecorder] = None,
                     metrics: Optional[dict] = None,
                     config: Optional[dict] = None,
                     stage: Optional[str] = None) -> Path:
    """Write ``<dir>/crash.json`` — the postmortem for a killed long run.

    Payload: the exception (type / message / formatted traceback), the
    stage it died in, the run config, the final metrics snapshot, and the
    flight recorder's event tail.  Never raises on its own account beyond
    filesystem errors — it is called from an exception handler."""
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    payload: dict = {
        "t": time.time(),
        "error": {
            "type": type(exc).__name__,
            "message": str(exc),
            "traceback": traceback_mod.format_exception(
                type(exc), exc, exc.__traceback__),
        },
    }
    if stage is not None:
        payload["stage"] = stage
    if config is not None:
        payload["config"] = config
    if metrics is not None:
        payload["metrics"] = metrics
    if recorder is not None:
        payload["events"] = recorder.snapshot(tail=_TAIL_EVENTS)
    path = directory / CRASH_FILENAME
    _atomic_write_json(path, payload)
    return path
