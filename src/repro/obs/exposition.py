"""Live metrics exposition: Prometheus text format over stdlib HTTP.

:func:`render_prometheus` turns a ``MetricsRegistry`` snapshot into
Prometheus text exposition format 0.0.4 — counters and gauges as plain
samples, histograms as summaries (quantile-labelled samples plus
``_sum``/``_count``).  Metric names are sanitized to the Prometheus
charset (``fit.iteration_ms`` → ``fit_iteration_ms``) with the original
name kept in a ``# HELP`` line.

:class:`ExpositionServer` wraps ``http.server.ThreadingHTTPServer`` in a
daemon thread and serves:

* ``GET /metrics`` — the live registry, text/plain version=0.0.4
* ``GET /healthz`` — JSON liveness: ``{"status": "ok", "stage": ...}``
* ``GET /trace``   — JSON summary of the current tracer's events
  (per-routine breakdown via ``obs.report.routine_breakdown``)

The server holds *callables*, not objects: the registry function is
resolved per request, so ``scoped_registry`` swaps (tests, benchmarks)
are visible live, and the Session can feed its stage/tracer without the
server importing any jax-touching module.  Opt-in via
``ObsConfig.http_port`` (0 binds an ephemeral port — the bound port is
on ``server.port``); started by ``Session.fit`` / ``serve_handle`` and
stopped by ``Session.close()``.
"""
from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Callable, Optional

from . import metrics as obs_metrics

CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_QUANTILES = (("0.5", "p50"), ("0.9", "p90"), ("0.99", "p99"))


def sanitize_metric_name(name: str) -> str:
    """Map a registry name onto the Prometheus metric-name charset
    (``[a-zA-Z_:][a-zA-Z0-9_:]*``)."""
    sanitized = _NAME_RE.sub("_", name)
    if not sanitized or sanitized[0].isdigit():
        sanitized = "_" + sanitized
    return sanitized


def _format_value(value) -> str:
    if value is None:
        return "NaN"
    return repr(float(value))


def render_prometheus(snapshot: Optional[dict] = None, *,
                      registry: Optional[obs_metrics.MetricsRegistry] = None
                      ) -> str:
    """Render a registry (or a ``snapshot()`` dict) as Prometheus text.

    Histograms render as summaries: one quantile-labelled sample per
    retained percentile plus exact ``_sum`` and ``_count`` — matching
    what ``Histogram`` actually keeps (windowed percentiles, exact
    totals)."""
    if snapshot is None:
        reg = registry if registry is not None else obs_metrics.get_registry()
        snapshot = reg.snapshot()
    lines: list[str] = []
    for name in sorted(snapshot):
        entry = snapshot[name]
        kind = entry.get("type")
        metric = sanitize_metric_name(name)
        lines.append(f"# HELP {metric} repro metric {name!r}")
        if kind == "counter":
            lines.append(f"# TYPE {metric} counter")
            lines.append(f"{metric} {_format_value(entry.get('value', 0.0))}")
        elif kind == "gauge":
            lines.append(f"# TYPE {metric} gauge")
            lines.append(f"{metric} {_format_value(entry.get('value'))}")
        elif kind == "histogram":
            lines.append(f"# TYPE {metric} summary")
            for quantile, key in _QUANTILES:
                lines.append(
                    f'{metric}{{quantile="{quantile}"}} '
                    f"{_format_value(entry.get(key))}")
            lines.append(
                f"{metric}_sum {_format_value(entry.get('total', 0.0))}")
            count = entry.get("count", 0)
            lines.append(f"{metric}_count {int(count)}")
        else:  # unknown instrument type: expose nothing but keep HELP
            lines.append(f"# TYPE {metric} untyped")
    return "\n".join(lines) + "\n"


class _Handler(BaseHTTPRequestHandler):
    server: "ExpositionServer"

    # silence the default stderr access log — this runs inside fits
    def log_message(self, fmt, *args) -> None:  # noqa: A002
        pass

    def _send(self, status: int, content_type: str, body: str) -> None:
        payload = body.encode("utf-8")
        self.send_response(status)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(payload)))
        self.end_headers()
        self.wfile.write(payload)

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        path = self.path.split("?", 1)[0].rstrip("/") or "/"
        try:
            if path == "/metrics":
                snapshot = self.server.exposition.registry_fn().snapshot()
                self._send(200, CONTENT_TYPE, render_prometheus(snapshot))
            elif path == "/healthz":
                self._send(200, "application/json",
                           json.dumps(self.server.exposition.health()))
            elif path == "/trace":
                self._send(200, "application/json",
                           json.dumps(self.server.exposition.trace_summary()))
            else:
                self._send(404, "application/json",
                           json.dumps({"error": "not found", "path": path,
                                       "routes": ["/metrics", "/healthz",
                                                  "/trace"]}))
        except BrokenPipeError:  # client went away mid-response
            pass
        except Exception as exc:  # never take the fit down from a scrape
            try:
                self._send(500, "application/json",
                           json.dumps({"error": str(exc)}))
            except Exception:
                pass


class ExpositionServer:
    """Background ``/metrics`` + ``/healthz`` + ``/trace`` endpoint.

    ``port=0`` binds an ephemeral port (read it back from ``.port``).
    ``registry_fn`` defaults to the live process registry, so scoped
    swaps are reflected per request.  ``events_fn`` supplies the tracer
    events behind ``/trace``; ``info_fn`` extends the ``/healthz``
    payload (the Session passes its current stage)."""

    def __init__(self, port: int, *, host: str = "127.0.0.1",
                 registry_fn: Optional[
                     Callable[[], obs_metrics.MetricsRegistry]] = None,
                 events_fn: Optional[Callable[[], list]] = None,
                 info_fn: Optional[Callable[[], dict]] = None) -> None:
        self.registry_fn = registry_fn or obs_metrics.get_registry
        self._events_fn = events_fn
        self._info_fn = info_fn
        self._httpd = ThreadingHTTPServer((host, port), _Handler)
        self._httpd.daemon_threads = True
        self._httpd.exposition = self  # type: ignore[attr-defined]
        self._thread: Optional[threading.Thread] = None
        self.host = host
        self.port = self._httpd.server_address[1]

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def health(self) -> dict:
        payload = {"status": "ok", "port": self.port}
        if self._info_fn is not None:
            try:
                payload.update(self._info_fn())
            except Exception as exc:
                payload["status"] = "degraded"
                payload["error"] = str(exc)
        return payload

    def trace_summary(self) -> dict:
        events = []
        if self._events_fn is not None:
            events = list(self._events_fn())
        # deferred import: report is jax-free but pulls trace
        from .report import routine_breakdown
        return {"events": len(events),
                "routines": routine_breakdown(events)}

    def start(self) -> "ExpositionServer":
        if self._thread is not None:
            return self
        self._thread = threading.Thread(
            target=self._httpd.serve_forever,
            kwargs={"poll_interval": 0.1},
            name=f"repro-exposition:{self.port}", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._httpd.shutdown()
        self._thread.join(timeout=5.0)
        self._thread = None
        self._httpd.server_close()

    def __enter__(self) -> "ExpositionServer":
        return self.start()

    def __exit__(self, *exc_info) -> None:
        self.stop()
