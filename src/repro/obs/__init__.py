"""repro.obs — structured observability: hierarchical spans + metrics.

The paper's central artifact is a per-routine timing table (Table III:
sort / MTTKRP / inverse / normalization / fit).  This package is that
table as infrastructure: :mod:`repro.obs.trace` records hierarchical
spans across ingest → plan → fit → serve and exports Chrome-trace /
Perfetto-compatible JSONL; :mod:`repro.obs.metrics` is a process-local
registry of counters/gauges/histograms that unifies the signals the rest
of the repo already measures but keeps internal (autotune hits/misses,
ingest cache warm/cold, straggler escalations, fit trajectory, serve
latency percentiles); :mod:`repro.obs.report` renders a recorded trace
as the paper's Table-III-style per-routine breakdown
(``python -m repro trace <dir>``).

Phase 2 adds the *live* half: :mod:`repro.obs.exposition` renders the
registry in Prometheus text format and serves ``/metrics`` +
``/healthz`` + ``/trace`` from a stdlib-HTTP daemon thread
(``ObsConfig.http_port``); :mod:`repro.obs.recorder` is a bounded
ring-buffer flight recorder of structured events with periodic heartbeat
snapshots and crash dumps for killed runs; :mod:`repro.obs.aggregate`
merges per-host metrics snapshots (counters sum, gauges keep host
labels, histogram windows merge) into one cluster view.

Everything here is jax-optional: the tracer bridges spans into
``jax.profiler.TraceAnnotation`` when jax is importable, and degrades to
plain perf_counter spans when it is not — so ``repro.dist.straggler``
and other jax-free modules can feed metrics without import cycles.
"""
from .aggregate import aggregate_dir, merge_snapshots, write_host_metrics
from .exposition import ExpositionServer, render_prometheus
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry, scoped_registry)
from .recorder import (FlightRecorder, Heartbeat, current_recorder,
                       record_event, write_crash_dump)
from .trace import (Span, Tracer, current_tracer, read_trace, span, traced,
                    tracing)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_registry",
    "scoped_registry",
    "Span", "Tracer", "current_tracer", "read_trace", "span", "traced",
    "tracing",
    "ExpositionServer", "render_prometheus",
    "FlightRecorder", "Heartbeat", "current_recorder", "record_event",
    "write_crash_dump",
    "aggregate_dir", "merge_snapshots", "write_host_metrics",
]
