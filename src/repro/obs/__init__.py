"""repro.obs — structured observability: hierarchical spans + metrics.

The paper's central artifact is a per-routine timing table (Table III:
sort / MTTKRP / inverse / normalization / fit).  This package is that
table as infrastructure: :mod:`repro.obs.trace` records hierarchical
spans across ingest → plan → fit → serve and exports Chrome-trace /
Perfetto-compatible JSONL; :mod:`repro.obs.metrics` is a process-local
registry of counters/gauges/histograms that unifies the signals the rest
of the repo already measures but keeps internal (autotune hits/misses,
ingest cache warm/cold, straggler escalations, fit trajectory, serve
latency percentiles); :mod:`repro.obs.report` renders a recorded trace
as the paper's Table-III-style per-routine breakdown
(``python -m repro trace <dir>``).

Everything here is jax-optional: the tracer bridges spans into
``jax.profiler.TraceAnnotation`` when jax is importable, and degrades to
plain perf_counter spans when it is not — so ``repro.dist.straggler``
and other jax-free modules can feed metrics without import cycles.
"""
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry, scoped_registry)
from .trace import (Span, Tracer, current_tracer, read_trace, span, traced,
                    tracing)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "get_registry",
    "scoped_registry",
    "Span", "Tracer", "current_tracer", "read_trace", "span", "traced",
    "tracing",
]
