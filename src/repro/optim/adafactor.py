"""Adafactor (factored second moments), for the trillion-param configs.

For params with ndim >= 2 the second moment is stored as a row statistic
(shape[:-1]) and a column statistic (shape[:-2] + last dim) — O(n+m) instead
of O(nm).  First moment is omitted (beta1=0, the standard Adafactor choice).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Optimizer, OPTIMIZERS, clip_by_global_norm

Array = jax.Array


def adafactor(lr: float = 1e-3, decay: float = 0.8, eps: float = 1e-30,
              clip_norm: float = 1.0) -> Optimizer:
    def _factored(p) -> bool:
        # purely ndim-based so it agrees with state_axes (which only sees
        # the axes tuple); size-1 dims factor fine (mean over 1 element)
        return p.ndim >= 2

    def init(params):
        def per(p):
            if _factored(p):
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"stats": jax.tree.map(per, params)}

    def update(grads, state, params, step):
        grads, _ = clip_by_global_norm(grads, clip_norm)
        stepf = (step + 1).astype(jnp.float32)
        beta2 = 1.0 - stepf ** (-decay)

        def upd(g, st, p):
            gf = g.astype(jnp.float32)
            g2 = gf * gf + eps
            if "vr" in st:
                vr = beta2 * st["vr"] + (1 - beta2) * jnp.mean(g2, axis=-1)
                vc = beta2 * st["vc"] + (1 - beta2) * jnp.mean(g2, axis=-2)
                denom = (vr[..., None] * vc[..., None, :]
                         / jnp.maximum(jnp.mean(vr, axis=-1,
                                                keepdims=True)[..., None], eps))
                u = gf * jax.lax.rsqrt(denom + eps)
                new_st = {"vr": vr, "vc": vc}
            else:
                v = beta2 * st["v"] + (1 - beta2) * g2
                u = gf * jax.lax.rsqrt(v + eps)
                new_st = {"v": v}
            # update clipping (Adafactor's d=1.0 RMS rule)
            rms = jnp.sqrt(jnp.mean(u * u) + eps)
            u = u / jnp.maximum(1.0, rms)
            newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return newp, new_st

        flat_p, td = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        stats_leaves = jax.tree.flatten(
            state["stats"], is_leaf=lambda x: isinstance(x, dict) and
            ("v" in x or "vr" in x))[0]
        out = [upd(g, st, p) for g, st, p in zip(flat_g, stats_leaves, flat_p)]
        new_p = jax.tree.unflatten(td, [o[0] for o in out])
        new_stats = jax.tree.unflatten(td, [o[1] for o in out])
        return new_p, {"stats": new_stats}

    def state_axes(param_axes):
        def per(axes):
            # mirrors _factored on the axes tuple length; callers pass the
            # matching param shapes implicitly (ndim == len(axes))
            if len(axes) >= 2:
                return {"vr": axes[:-1], "vc": axes[:-2] + axes[-1:]}
            return {"v": axes}
        return {"stats": jax.tree.map(
            per, param_axes, is_leaf=lambda x: isinstance(x, tuple))}

    return Optimizer(init=init, update=update, state_axes=state_axes)


OPTIMIZERS["adafactor"] = adafactor
