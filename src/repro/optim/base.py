"""Optimizer interface + shared utilities."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """Functional optimizer:  state = init(params);
    new_params, new_state = update(grads, state, params, step)."""

    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any, Array], tuple[Any, Any]]
    # axes_fn(param_axes) -> state axes tree for the same param leaf;
    # used to shard optimizer state in the dry-run / checkpointer.
    state_axes: Callable[[Any], Any]


def clip_by_global_norm(grads, max_norm: float):
    if max_norm <= 0:
        return grads, jnp.array(0.0)
    gsq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree.leaves(grads))
    gnorm = jnp.sqrt(gsq)
    scale = jnp.minimum(1.0, max_norm / (gnorm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gnorm


def cast_like(x, ref):
    return x.astype(ref.dtype)


# registry filled by the concrete modules (import order via __init__)
OPTIMIZERS: dict[str, Callable[..., Optimizer]] = {}
