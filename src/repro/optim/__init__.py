"""Optimizers from scratch (no optax): AdamW and Adafactor.

LEGACY SEED MODULE: consumed only by the LM train/dry-run paths, not by the
decomposition stack or the public ``repro.api`` surface (ALS has no
gradient optimizer).  See docs/architecture.md ("Legacy LM substrate").

Both keep fp32 statistics regardless of param dtype; Adafactor factors the
second moment over the last two dims (rows/cols) which is what makes the
1T-param Kimi config's optimizer state fit the mesh.  ``abstract_state``
mirrors ``init`` at the ShapeDtypeStruct level for the dry-run, including the
logical sharding axes of every state leaf.
"""
from .adamw import adamw
from .adafactor import adafactor
from .base import Optimizer, clip_by_global_norm, OPTIMIZERS

__all__ = ["adamw", "adafactor", "Optimizer", "clip_by_global_norm",
           "OPTIMIZERS"]
