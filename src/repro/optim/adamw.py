"""AdamW with fp32 moments and decoupled weight decay."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .base import Optimizer, OPTIMIZERS, clip_by_global_norm

Array = jax.Array


def adamw(lr: float = 3e-4, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0,
          clip_norm: float = 1.0) -> Optimizer:
    def init(params):
        f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {"m": jax.tree.map(f32, params), "v": jax.tree.map(f32, params)}

    def update(grads, state, params, step):
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        stepf = (step + 1).astype(jnp.float32)
        bc1 = 1.0 - b1 ** stepf
        bc2 = 1.0 - b2 ** stepf

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m = b1 * m + (1.0 - b1) * gf
            v = b2 * v + (1.0 - b2) * gf * gf
            u = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            newp = (p.astype(jnp.float32) - lr * u).astype(p.dtype)
            return newp, m, v

        flat_p, td = jax.tree.flatten(params)
        flat_g = jax.tree.leaves(grads)
        flat_m = jax.tree.leaves(state["m"])
        flat_v = jax.tree.leaves(state["v"])
        out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = jax.tree.unflatten(td, [o[0] for o in out])
        new_m = jax.tree.unflatten(td, [o[1] for o in out])
        new_v = jax.tree.unflatten(td, [o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v}

    def state_axes(param_axes):
        return {"m": param_axes, "v": param_axes}

    return Optimizer(init=init, update=update, state_axes=state_axes)


OPTIMIZERS["adamw"] = adamw
