"""Three-term roofline from a compiled dry-run artifact (TPU v5e targets).

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s
  memory term     = HLO_bytes_per_device / HBM_bw
  collective term = wire_bytes_per_device / ICI link_bw

cost_analysis() on the SPMD-partitioned module is PER-DEVICE (verified
empirically: reported flops ~= global/num_devices for a known matmul), so no
further division by chip count.  Collective bytes are NOT in cost_analysis:
we parse the optimized HLO (compiled.as_text()) for
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
ops (including their async -start forms), take per-device result shapes, and
convert to ring-algorithm wire bytes:

  all-reduce       2 * B * (g-1)/g        (B = per-device block bytes)
  all-gather       B_out * (g-1)/g        (B_out = gathered result bytes)
  reduce-scatter   B_out * (g-1)          (B_out = scattered result bytes)
  all-to-all       B * (g-1)/g
  collective-perm  B

Hardware constants (TPU v5e): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
ICI.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Any

import numpy as np

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<result>\([^)]*\)|\S+)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)"
    r"(?P<start>-start)?\(",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def parse_collectives(hlo: str) -> list[dict]:
    """One record per collective op: kind, result bytes (per device), group
    size, wire bytes (per device, ring algorithm)."""
    out = []
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if m is None:
            continue
        if "-done" in line.split("=")[0]:
            continue
        kind = m.group("op")
        b = _shape_bytes(m.group("result"))
        g = max(1, _group_size(line))
        if kind == "all-reduce":
            wire = 2.0 * b * (g - 1) / g
        elif kind == "all-gather":
            wire = b * (g - 1) / g
        elif kind == "reduce-scatter":
            wire = b * (g - 1)
        elif kind == "all-to-all":
            wire = b * (g - 1) / g
        else:  # collective-permute
            wire = float(b)
        out.append({"kind": kind, "bytes": b, "group": g, "wire": wire})
    return out


def collective_summary(colls: list[dict]) -> dict:
    agg: dict[str, dict] = defaultdict(lambda: {"count": 0, "bytes": 0.0,
                                                "wire": 0.0})
    for c in colls:
        a = agg[c["kind"]]
        a["count"] += 1
        a["bytes"] += c["bytes"]
        a["wire"] += c["wire"]
    return dict(agg)


@dataclasses.dataclass
class Roofline:
    flops: float                 # per device
    bytes_accessed: float        # per device
    wire_bytes: float            # per device
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float           # global 6ND (or 2ND serve)
    useful_ratio: float          # model_flops / (flops * chips)
    collectives: dict
    bound_s: float

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def analyze_values(*, flops: float, bytes_accessed: float, wire_bytes: float,
                   collectives: dict, n_chips: int,
                   model_flops: float) -> Roofline:
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = wire_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful = model_flops / max(flops * n_chips, 1.0)
    return Roofline(
        flops=flops, bytes_accessed=bytes_accessed, wire_bytes=wire_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops, useful_ratio=useful,
        collectives=collectives, bound_s=max(terms.values()),
    )


def normalize_cost(cost) -> dict:
    """XLA cost analysis as a plain dict.  Newer jax returns the dict
    directly; 0.4.x returns a one-element list of dicts."""
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)


class CompatCompiled:
    """Wraps a jax Compiled so ``cost_analysis()`` is a dict on every
    jax version; everything else delegates."""

    def __init__(self, compiled):
        self._compiled = compiled

    def cost_analysis(self) -> dict:
        return normalize_cost(self._compiled.cost_analysis())

    def __getattr__(self, name):
        return getattr(self._compiled, name)


class CompatLowered:
    """Wraps a jax Lowered so ``compile()`` yields a CompatCompiled."""

    def __init__(self, lowered):
        self._lowered = lowered

    def compile(self, *args, **kwargs) -> CompatCompiled:
        return CompatCompiled(self._lowered.compile(*args, **kwargs))

    def __getattr__(self, name):
        return getattr(self._lowered, name)


def analyze(cost, hlo: str, *, n_chips: int, model_flops: float) -> Roofline:
    colls = parse_collectives(hlo)
    cost = normalize_cost(cost)
    return analyze_values(
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        wire_bytes=sum(c["wire"] for c in colls),
        collectives=collective_summary(colls),
        n_chips=n_chips, model_flops=model_flops)


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6*N_active*D for training, 2*N_active*D for serving
    (D = tokens processed by the step)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n * tokens
