from . import roofline

__all__ = ["roofline"]
