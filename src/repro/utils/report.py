"""Render EXPERIMENTS.md tables from the dry-run artifact JSONs.

  PYTHONPATH=src python -m repro.utils.report [--dir artifacts/dryrun]
prints the §Dry-run and §Roofline markdown tables to stdout.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path


def load_cells(d: Path) -> list[dict]:
    return [json.loads(p.read_text()) for p in sorted(d.glob("*.json"))]


def plan_report(plan, *, reorder_deltas=None, method=None,
                provenance=None) -> str:
    """Per-mode planner table for a :class:`repro.plan.DecompPlan`.

    One row per mode: workspace layout, chosen impl, measured collision rate
    and padding overhead, and the predicted §V-D regime — what the dry-run
    and the serving launcher print so the per-mode choice is inspectable.

    ``reorder_deltas``: per-mode dicts of (after - before) stat deltas from
    ``repro.ingest.Ingested.reorder_deltas()`` — renders a "reorder" column
    showing what the locality-aware reordering bought (negative collision /
    padding deltas are wins).

    The "costs" column states where each mode's impl costs came from —
    ``predicted`` (cost models), ``measured-fresh`` (timed on this tensor,
    just now) or ``measured-cached`` (timed earlier, replayed from the
    persistent autotune store) — followed by the per-candidate cost table
    in THE canonical candidate ordering
    (:func:`repro.plan.autotune.canonical_candidates` — the same ordering
    the calibration key hashes, so the printed table and the cached entry
    can never disagree about which candidate set was scored).

    ``method``: the decomposition method executing the plan
    (``repro.methods``); the "method" column renders it together with the
    kernel family each mode was scored against (``mttkrp`` / ``ttmc``).

    ``provenance``: cache counters behind this plan (what
    ``Session.plan_report`` assembles) — ``{"cache_hit": bool, "ingest":
    {"hits", "misses"}, "autotune": {"hits", "misses"}}`` — rendered as a
    footer line so warm/cold ingest and replayed/fresh calibration stop
    being internal-only counters.
    """
    head = (f"# plan: policy={plan.policy} backend={plan.backend} "
            f"rank={plan.rank}"
            + (f" method={method}" if method is not None else ""))
    rows = ["| mode | method | rows | nnz/row | collision | padding "
            "| reorder | layout | impl | costs | regime | reason |",
            "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for p in plan.modes:
        s = p.stats
        kernel = getattr(p, "kernel", "mttkrp")
        m_cell = f"{method}:{kernel}" if method is not None else kernel
        if s is not None:
            cells = (f"{s.rows} | {s.avg_nnz_per_row:.1f} "
                     f"| {s.collision_rate:.2f} | {s.padding_overhead:.2f}")
        else:  # fixed policy planned with with_stats=False
            cells = "- | - | - | -"
        if reorder_deltas is not None:
            d = reorder_deltas[p.mode]
            re_cell = (f"coll {d['collision']:+.2f} "
                       f"pad {d['padding']:+.2f}")
        else:
            re_cell = "-"
        costs_cell = getattr(p, "source", "predicted")
        if p.costs:
            from repro.plan.autotune import canonical_candidates

            costs_cell += " " + " ".join(
                f"{name}={p.costs[name]:.3g}"
                for name in canonical_candidates(p.costs))
        rows.append(
            f"| {p.mode} | {m_cell} | {cells} | {re_cell} "
            f"| {p.layout} | **{p.impl}** "
            f"| {costs_cell} | {p.predicted_regime} "
            f"| {p.reason} |")
    if provenance is not None:
        rows.append(_provenance_footer(provenance))
    return "\n".join([head] + rows)


def _provenance_footer(prov: dict) -> str:
    """One ``# provenance:`` line from the Session's cache counters."""
    parts = []
    hit = prov.get("cache_hit")
    if "ingest" in prov:
        ing = prov["ingest"]
        state = "warm" if hit else "cold"
        parts.append(f"ingest-cache {state} "
                     f"(hits={ing['hits']} misses={ing['misses']})")
    else:
        parts.append("no ingest cache (cold build; attach data.cache "
                     "for warm starts)")
    if "autotune" in prov:
        at = prov["autotune"]
        parts.append(f"autotune hits={at['hits']} misses={at['misses']}")
    return "# provenance: " + " | ".join(parts)


def _fmt_s(x: float) -> str:
    if x >= 1.0:
        return f"{x:.2f}s"
    return f"{x*1e3:.1f}ms"


def dryrun_table(cells: list[dict]) -> str:
    rows = ["| cell | mesh | compile | peak/dev | args/dev | collective mix |",
            "|---|---|---|---|---|---|"]
    for c in cells:
        if "skipped" in c:
            rows.append(f"| {c['cell']} | — | SKIP | — | — | {c['skipped']} |")
            continue
        mesh = "x".join(str(v) for v in c["mesh"].values())
        colls = c["roofline"]["collectives"]
        mix = " ".join(f"{k.split('-')[-1]}:{int(v['count'])}"
                       for k, v in sorted(colls.items()))
        rows.append(
            f"| {c['cell']} | {mesh} | {c['compile_s']:.1f}s "
            f"| {c['memory']['peak_estimate_gib']:.1f}GiB "
            f"| {c['memory']['argument_bytes']/2**30:.2f}GiB | {mix} |")
    return "\n".join(rows)


def roofline_table(cells: list[dict], *, single_only: bool = True) -> str:
    rows = ["| cell | compute | memory | collective | dominant | bound "
            "| MODEL_FLOPS/HLO | note |",
            "|---|---|---|---|---|---|---|---|"]
    for c in cells:
        if "skipped" in c:
            continue
        if single_only and "__multi" in c["cell"]:
            continue
        r = c["roofline"]
        useful = r["useful_ratio"]
        note = ""
        if useful > 1.0:
            note = "HLO<6ND (sparse/active<total)"
        rows.append(
            f"| {c['cell'].replace('__single','')} | {_fmt_s(r['compute_s'])} "
            f"| {_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} "
            f"| **{r['dominant']}** | {_fmt_s(r['bound_s'])} "
            f"| {useful:.2f} | {note} |")
    return "\n".join(rows)


def pick_hillclimb(cells: list[dict]) -> list[str]:
    """worst useful ratio, most collective-bound, paper-representative."""
    live = [c for c in cells if "skipped" not in c and "__single" in c["cell"]
            and not c["cell"].startswith("cpals")]
    worst = min(live, key=lambda c: min(1.0, c["roofline"]["useful_ratio"])
                / max(c["roofline"]["bound_s"], 1e-9)
                * c["roofline"]["compute_s"])
    coll = max(live, key=lambda c: c["roofline"]["collective_s"]
               / max(c["roofline"]["bound_s"], 1e-9))
    return [worst["cell"], coll["cell"], "cpals-nell2__iteration__single"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", type=Path,
                    default=Path("artifacts/dryrun"))
    ap.add_argument("--section", choices=["dryrun", "roofline", "pick"],
                    default="roofline")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    if args.section == "dryrun":
        print(dryrun_table(cells))
    elif args.section == "roofline":
        print(roofline_table(cells))
    else:
        print(pick_hillclimb(cells))


if __name__ == "__main__":
    main()
