"""int8 gradient compression with error feedback, over arbitrary pytrees.

The data-parallel gradient all-reduce is the dominant wire cost of the
training path (see the dry-run's collective analysis); quantizing each
gradient leaf to int8 + one f32 scale cuts that volume ~4x vs f32.  Plain
quantization biases the update — error feedback (Seide et al., 2014;
Karimireddy et al., 2019) fixes this by carrying the per-element
quantization residual into the next step, so the *accumulated* update is
unbiased and SGD-style convergence is preserved (exercised end-to-end by
``tests/test_distributed.py::test_grad_compression_equivalence``).

Scheme, per floating-point leaf ``g`` with residual ``e``:

    a     = f32(g) + e                  # fold in last step's residual
    scale = max|a| / 127                # symmetric per-tensor scale
    q     = clip(round(a / scale))      # int8 payload
    e'    = a - q * scale               # residual carried forward

Non-float leaves (step counters, int masks) pass through unchanged.  All
functions are jit-safe (dtype dispatch is static) and tree-structure
preserving, so ``(q, scales)`` can cross a ``psum``/``all_reduce`` with
the same sharding logic as the gradients themselves.

Entry points: opt-in via ``make_train_step(..., grad_compress=True)``
(``repro.launch.steps``), which stores the residual tree in
``opt_state['ef']``.  Note the current train step exercises the fidelity
loop (quantize -> dequantize around where XLA's implicit all-reduce
sits); realizing the wire saving end-to-end means reducing ``(q,
scales)`` through an explicit shard_map psum — see ``docs/architecture.md``.
Throughput/fidelity numbers: ``benchmarks/bench_compress.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jax.Array

_QMAX = 127.0  # symmetric int8: [-127, 127]; -128 unused


def _quantizable(x: Array) -> bool:
    return jnp.issubdtype(jnp.asarray(x).dtype, jnp.floating)


def init_error_feedback(params):
    """Zero residual tree matching ``params`` (f32, one leaf per leaf)."""
    return jax.tree.map(
        lambda p: jnp.zeros(jnp.shape(p), jnp.float32), params)


def _quantize_leaf(g: Array, e: Array):
    a = jnp.asarray(g, jnp.float32) + e
    amax = jnp.max(jnp.abs(a))
    scale = amax / _QMAX
    safe = jnp.where(scale > 0.0, scale, 1.0)
    q = jnp.clip(jnp.round(a / safe), -_QMAX, _QMAX).astype(jnp.int8)
    new_e = a - q.astype(jnp.float32) * scale
    return q, scale, new_e


def compress_grads_int8(grads, ef=None):
    """Quantize every float leaf of ``grads`` to (int8, f32 scale).

    Returns ``(q, scales, new_ef)`` — three trees with the structure of
    ``grads``.  ``ef`` is the residual tree from the previous step (from
    :func:`init_error_feedback` on the first step; ``None`` means zero
    residuals).  Integer leaves are passed through in ``q`` untouched,
    with a unit scale and a zero residual.
    """
    leaves, treedef = jax.tree.flatten(grads)
    ef_leaves = (jax.tree.leaves(ef) if ef is not None
                 else [jnp.zeros(jnp.shape(g), jnp.float32) for g in leaves])
    if len(ef_leaves) != len(leaves):
        raise ValueError("error-feedback tree does not match gradient tree")
    qs, scales, new_ef = [], [], []
    for g, e in zip(leaves, ef_leaves):
        if _quantizable(g):
            q, s, ne = _quantize_leaf(g, e)
        else:
            q, s, ne = g, jnp.float32(1.0), jnp.zeros(jnp.shape(g), jnp.float32)
        qs.append(q)
        scales.append(s)
        new_ef.append(ne)
    return (treedef.unflatten(qs), treedef.unflatten(scales),
            treedef.unflatten(new_ef))


def decompress_grads_int8(q, scales):
    """Inverse of :func:`compress_grads_int8`: int8 leaves -> f32 * scale;
    passthrough leaves are returned as-is."""
    def one(qq: Array, s: Array) -> Array:
        if jnp.asarray(qq).dtype == jnp.int8:
            return qq.astype(jnp.float32) * s
        return qq
    return jax.tree.map(one, q, scales)


def compression_ratio(grads) -> float:
    """Wire-bytes ratio (uncompressed / compressed) for a gradient tree."""
    raw = comp = 0
    for g in jax.tree.leaves(grads):
        n = int(jnp.size(g))
        b = jnp.asarray(g).dtype.itemsize
        raw += n * b
        comp += (n + 4) if _quantizable(g) else n * b
    return raw / max(comp, 1)
