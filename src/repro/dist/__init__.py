"""repro.dist — the distributed-runtime layer.

The paper's performance study is, at heart, a study of how a sparse
CP-ALS runtime schedules irregular work across parallel workers; its
named future work is SPLATT's medium-grained *distributed* algorithm.
``repro.core.distributed`` implements that algorithm with ``shard_map``;
this package supplies the runtime plumbing around it, shared with the LM
training path:

``collectives``
    The single mesh/axis vocabulary: which mesh axes partition CP-ALS
    rows vs columns, pod-aware batch axes, and the psum / reduce-scatter
    / all-gather helpers used inside ``shard_map`` bodies.  Consumed by
    both ``repro.core.distributed`` and ``repro.launch.mesh``.

``straggler``
    :class:`StragglerMonitor` — windowed per-worker wall-time tracking
    that flags persistently slow hosts.  Worker imbalance is the central
    hazard of distributed sparse tensor work (irregular non-zero
    distributions make some ranks structurally slower); the monitor
    makes it observable at the driver loop.

``compress``
    int8 gradient quantization with error-feedback residuals over
    arbitrary pytrees — halves (vs bf16) or quarters (vs f32) the bytes
    the data-parallel all-reduce moves.  Opt-in via
    ``make_train_step(..., grad_compress=True)``.

See ``docs/architecture.md`` ("The distributed layer") for how these
pieces stack on top of the core CP-ALS kernels.
"""
from .collectives import (CPAxes, MODEL_AXIS, axis_product, batch_axes,
                          cpals_axes, gather_rows, make_mesh, pgram,
                          pnormalize_columns, scatter_rows, shard_map)
from .compress import (compress_grads_int8, decompress_grads_int8,
                       init_error_feedback)
from .straggler import StragglerMonitor

__all__ = [
    "CPAxes", "MODEL_AXIS", "axis_product", "batch_axes", "cpals_axes",
    "gather_rows", "make_mesh", "pgram", "pnormalize_columns",
    "scatter_rows", "shard_map",
    "compress_grads_int8", "decompress_grads_int8", "init_error_feedback",
    "StragglerMonitor",
]
