"""Windowed straggler detection for distributed drivers.

A straggler is a worker whose *recent* step times are persistently slower
than its peers'.  Distributed CP-ALS is iteration-synchronous (every mode
update ends in an all-reduce), so one slow host gates the whole mesh — the
medium-grained algorithm's known failure mode when the non-zero partition
is imbalanced.  The monitor is deliberately runtime-only: it never touches
jax state, so it works identically under the real multi-host launcher and
the single-process smoke runs.

Detection is relative, not absolute: a host is *slow* when the mean of its
last ``window`` step times exceeds ``threshold`` x the median of all
hosts' means, and *persistent* once that has held for ``patience``
consecutive :meth:`StragglerMonitor.check` calls.  The median makes the
baseline robust to the stragglers themselves; the patience counter
debounces one-off hiccups (GC pauses, checkpoint writes).

See ``docs/architecture.md`` ("The distributed layer").
"""
from __future__ import annotations

import statistics
from collections import deque
from typing import Dict


class StragglerMonitor:
    """Track per-host step wall-times; flag persistently slow hosts.

    Args:
      window:    number of recent step times kept per host.
      threshold: a host is slow when its window mean exceeds
                 ``threshold`` x the median of all hosts' window means.
      patience:  consecutive slow ``check()`` results before a host is
                 escalated from ``"slow"`` to ``"persistent"``.
      warmup:    minimum samples a host needs before it participates in
                 ``check()`` at all (avoids flagging on compile-step
                 noise).
    """

    def __init__(self, window: int = 20, threshold: float = 1.5,
                 patience: int = 3, warmup: int = 2):
        if window < 1 or patience < 1 or warmup < 1:
            raise ValueError("window, patience and warmup must be >= 1")
        if warmup > window:
            raise ValueError(f"warmup ({warmup}) > window ({window}) would "
                             "never report: the rolling window can't fill")
        if threshold <= 1.0:
            raise ValueError("threshold must be > 1.0 (relative slowdown)")
        self.window = window
        self.threshold = threshold
        self.patience = patience
        self.warmup = warmup
        self._times: Dict[int, deque] = {}
        self._strikes: Dict[int, int] = {}

    def record(self, host: int, seconds: float) -> None:
        """Record one step's wall time for ``host``."""
        dq = self._times.get(host)
        if dq is None:
            dq = self._times[host] = deque(maxlen=self.window)
            self._strikes[host] = 0
        dq.append(float(seconds))

    def means(self) -> Dict[int, float]:
        """Window mean per host, warmed-up hosts only."""
        return {h: sum(dq) / len(dq) for h, dq in self._times.items()
                if len(dq) >= self.warmup}

    def check(self) -> Dict[int, str]:
        """Flag slow hosts: ``{host: "slow" | "persistent"}``.

        Returns ``{}`` during warmup (no host has ``warmup`` samples yet).
        A host whose window mean drops back under the threshold has its
        patience counter reset — recovery clears the flag immediately.
        """
        means = self.means()
        if not means:
            return {}
        baseline = statistics.median(means.values())
        flags: Dict[int, str] = {}
        for host, mean in means.items():
            if baseline > 0.0 and mean > self.threshold * baseline:
                self._strikes[host] += 1
                flags[host] = ("persistent"
                               if self._strikes[host] >= self.patience
                               else "slow")
            else:
                self._strikes[host] = 0
        if flags:
            # escalations feed the obs metrics registry (straggler.slow /
            # straggler.persistent counters) so single-host runs see the
            # flags too, not just the dist launcher's log line.  obs.metrics
            # is jax-free, preserving this module's contract.
            from repro.obs.metrics import get_registry
            from repro.obs.recorder import record_event

            registry = get_registry()
            for host, flag in flags.items():
                registry.counter(f"straggler.{flag}").inc()
                record_event("straggler", host=int(host), flag=flag,
                             mean_s=means[host], baseline_s=baseline)
        return flags

    def reset(self) -> None:
        """Drop all history (e.g. after a rebalance or restart)."""
        self._times.clear()
        self._strikes.clear()


def record_step_times(monitor: StragglerMonitor, seconds: float) -> None:
    """Record one step's wall time under EVERY participating host.

    Detection is relative, so each process's monitor needs its peers'
    times: with several jax processes this exchanges the local wall time
    via a host all-gather (every process then holds the full picture and
    flags the same hosts); single-process runs just record host 0.  The
    monitor itself stays jax-free — only this exchange touches jax, and
    only when there is something to exchange.
    """
    import jax

    if jax.process_count() > 1:
        import numpy as np
        from jax.experimental import multihost_utils
        times = np.asarray(multihost_utils.process_allgather(
            np.float32(seconds))).reshape(-1)
        for host, t in enumerate(times):
            monitor.record(host, float(t))
    else:
        monitor.record(0, float(seconds))
