"""Shared collectives vocabulary: mesh-axis resolution + psum plumbing.

Before this module existed, ``repro.core.distributed`` (medium-grained
CP-ALS) and ``repro.launch.mesh`` (LM sharding rules) each re-derived the
same facts about the production mesh: which axes partition rows vs
columns, how the pod axis joins the batch/row partition, and how a
column-normalize or Gram reduce is phrased inside ``shard_map``.  This
module is the single home for that vocabulary so both paths agree by
construction.

Conventions (see ``launch/mesh.py`` for the physical shapes):

  * ``"model"`` is always the *column* axis of the CP-ALS grid and the
    tensor-parallel axis of the LM path;
  * every other axis — ``("data",)`` single-pod, ``("pod", "data")``
    multi-pod — is a *row* axis.  The pod axis joining the row partition
    is what makes one reduce spec express "psum within the pod over ICI
    + across pods over DCN".

The reduce helpers (:func:`pnormalize_columns`, :func:`pgram`,
:func:`scatter_rows`, :func:`gather_rows`) are for use *inside*
``shard_map`` bodies; the resolution helpers (:func:`cpals_axes`,
:func:`batch_axes`, :func:`axis_product`) are host-side and touch no jax
device state.  See ``docs/architecture.md`` ("The distributed layer").
"""
from __future__ import annotations

import dataclasses
from typing import Sequence, Union

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

Array = jax.Array
AxisName = Union[str, tuple]

MODEL_AXIS = "model"
DATA_AXIS = "data"
POD_AXIS = "pod"


# ---------------------------------------------------------------------------
# jax version portability
# ---------------------------------------------------------------------------

def shard_map(f, *, mesh: Mesh, in_specs, out_specs):
    """``jax.shard_map`` where available (>= 0.6), else the experimental
    spelling older releases ship.  All shard_map entry points in the repo
    (distributed CP-ALS, expert-parallel MoE) route through here."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs)


def make_mesh(shape: Sequence[int], axes: Sequence[str]) -> Mesh:
    """``jax.make_mesh`` with Auto axis types when the installed jax has
    them (explicit-sharding releases), plain otherwise."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(axis_type.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


# ---------------------------------------------------------------------------
# host-side axis resolution
# ---------------------------------------------------------------------------

def axis_product(mesh: Mesh, axes: Sequence[str]) -> int:
    """Number of devices along ``axes`` (product of mesh extents)."""
    return int(np.prod([mesh.shape[a] for a in axes], dtype=np.int64)) \
        if axes else 1


def batch_axes(multi_pod: bool = False) -> AxisName:
    """The pod-aware batch/data-parallel rule: across pods the batch is
    purely data-parallel, so the pod axis prepends the data axis."""
    return (POD_AXIS, DATA_AXIS) if multi_pod else DATA_AXIS


@dataclasses.dataclass(frozen=True)
class CPAxes:
    """Resolved CP-ALS grid axes for a mesh.

    ``row`` partitions mode-0 factor rows (and the non-zero blocks' first
    grid dim); ``col`` partitions mode-1; ``all_axes`` is the whole mesh
    (mode-2 reduce scope).  ``spec()`` helpers phrase the matching
    PartitionSpecs so callers never re-spell the tuples.
    """
    row: tuple
    col: str
    n_row: int
    n_col: int

    @property
    def all_axes(self) -> tuple:
        return self.row + (self.col,)

    @property
    def n_all(self) -> int:
        return self.n_row * self.n_col

    def grid_spec(self) -> P:
        """Spec of the (n_row, n_col, ...) partitioned non-zero blocks."""
        return P(self.row, self.col)

    def row_spec(self) -> P:
        return P(self.row)

    def col_spec(self) -> P:
        return P(self.col)

    def all_spec(self) -> P:
        return P(self.all_axes)


def cpals_axes(mesh: Mesh) -> CPAxes:
    """Resolve the CP-ALS row/column axes of ``mesh``: ``"model"`` is the
    column axis, everything else (``data``, optionally led by ``pod``)
    partitions rows."""
    if MODEL_AXIS not in mesh.axis_names:
        raise ValueError(f"mesh {mesh.axis_names} has no {MODEL_AXIS!r} axis")
    row = tuple(a for a in mesh.axis_names if a != MODEL_AXIS)
    return CPAxes(row=row, col=MODEL_AXIS,
                  n_row=axis_product(mesh, row),
                  n_col=mesh.shape[MODEL_AXIS])


# ---------------------------------------------------------------------------
# shard_map-body collectives
# ---------------------------------------------------------------------------

def pgram(mat: Array, axis_names: AxisName) -> Array:
    """Gram matrix of a row-sharded factor: psum of the local A^T A."""
    return jax.lax.psum(mat.T @ mat, axis_names)


def pnormalize_columns(mat: Array, axis_names: AxisName, *,
                       kind: str = "2"):
    """Column-normalize a row-sharded matrix; returns ``(mat, lam)``.

    ``kind="2"``: lam = global column 2-norms (psum of squares);
    ``kind="max"``: lam = max(1, global column max-abs) — SPLATT's
    first-iteration norm.  Zero columns are left untouched (unit lam).
    """
    if kind == "max":
        lam = jax.lax.pmax(jnp.max(jnp.abs(mat), axis=0), axis_names)
        lam = jnp.maximum(lam, 1.0)
    else:
        lam = jnp.sqrt(jax.lax.psum(jnp.sum(mat * mat, axis=0), axis_names))
    safe = jnp.where(lam == 0.0, 1.0, lam)
    return mat / safe[None, :], lam


def scatter_rows(x: Array, axes: Sequence[AxisName]) -> Array:
    """Reduce-scatter ``x`` along dim 0 over each axis group in order —
    half the wire of psum + slice.  Block layout after scattering over
    ``(row, col)`` is row-major in the grid (block id = r * n_col + c),
    matching ``P(row + (col,))``."""
    for a in axes:
        x = jax.lax.psum_scatter(x, a, scatter_dimension=0, tiled=True)
    return x


def gather_rows(x: Array, axes: Sequence[AxisName]) -> Array:
    """Inverse of :func:`scatter_rows`: all-gather dim 0 over the same
    axis groups, applied in reverse order so the row-major block layout
    is reassembled exactly."""
    for a in reversed(tuple(axes)):
        x = jax.lax.all_gather(x, a, axis=0, tiled=True)
    return x
