"""rwkv6-3b [ssm] (Finch) — 32L d_model=2560 attn-free, d_ff=8960
vocab=65536; data-dependent per-channel decay; 64-dim wkv heads.
Fixed-size decode state -> runs long_500k. [arXiv:2404.05892; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b", family="ssm",
        num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40,
        head_dim=64, d_ff=8960, vocab=65_536,
        pattern=("rwkv",), rope="none", rwkv_head_dim=64,
    )
