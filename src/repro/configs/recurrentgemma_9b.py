"""recurrentgemma-9b [hybrid] (Griffin) — 38L d_model=4096 16H (MQA kv=1)
head_dim=256 d_ff=12288 vocab=256000; pattern 2x RG-LRU : 1x local attention
(window 2048); GeGLU; 38 = 12*(rec,rec,attn) + (rec,rec) tail.  Fixed-size
state -> runs long_500k. [arXiv:2402.19427; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b", family="hybrid",
        num_layers=38, d_model=4096, num_heads=16, num_kv_heads=1,
        head_dim=256, d_ff=12288, vocab=256_000,
        mlp="geglu", rope="std", rope_theta=10_000.0,
        pattern=("rec", "rec", "attn"), suffix=("rec", "rec"),
        attn_kind="local", window=2048, rglru_width=4096,
        tie_embeddings=True, scale_embed=True,
    )
