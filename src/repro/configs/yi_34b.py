"""yi-34b [dense] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000,
llama-arch GQA. [arXiv:2403.04652; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="yi-34b", family="dense",
        num_layers=60, d_model=7168, num_heads=56, num_kv_heads=8,
        head_dim=128, d_ff=20480, vocab=64_000,
        mlp="swiglu", rope="std", rope_theta=5_000_000.0,
        fsdp=True,
    )
