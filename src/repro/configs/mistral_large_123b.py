"""mistral-large-123b [dense] — 88L d_model=12288 96H (GQA kv=8) d_ff=28672
vocab=32768. FSDP (embed axis -> data) required at this size.
[hf:mistralai/Mistral-Large-Instruct-2407; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="mistral-large-123b", family="dense",
        num_layers=88, d_model=12288, num_heads=96, num_kv_heads=8,
        head_dim=128, d_ff=28672, vocab=32_768,
        mlp="swiglu", rope="std", rope_theta=1_000_000.0,
        fsdp=True,
    )
