"""llama3.2-3b [dense] — 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256, SwiGLU, rope theta 500k, tied embeddings.
[hf:meta-llama/Llama-3.2-3B; unverified]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b", family="dense",
        num_layers=28, d_model=3072, num_heads=24, num_kv_heads=8,
        head_dim=128, d_ff=8192, vocab=128_256,
        mlp="swiglu", rope="std", rope_theta=500_000.0,
        tie_embeddings=True,
    )
