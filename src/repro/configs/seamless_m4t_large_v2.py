"""seamless-m4t-large-v2 [audio] — enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206.  The speech frontend is a
stub: input_specs() provides precomputed frame embeddings for the encoder;
the decoder consumes text tokens with cross-attention. [arXiv:2308.11596; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-large-v2", family="audio",
        num_layers=24, d_model=1024, num_heads=16, num_kv_heads=16,
        head_dim=64, d_ff=8192, vocab=256_206,
        mlp="gelu", norm="layernorm", rope="std",
        encdec=True, enc_layers=24,
    )
