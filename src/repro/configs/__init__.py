"""Architecture registry: 10 assigned archs + the paper's own CP-ALS
workloads, reduced smoke variants, and per-cell input specs.

The per-arch preset modules (``gemma_7b.py`` ... ``yi_34b.py``), ``get``,
``smoke_of`` and ``batch_shapes`` are part of the LEGACY LM substrate (see
docs/architecture.md "Legacy LM substrate") — they stay for the dry-run
compile matrix and the LM launchers, and are deliberately NOT re-exported
by the public ``repro.api`` surface.  The decomposition stack only consumes
``CPALS_WORKLOADS`` / ``CPALS_DATASET`` below."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig, MoEConfig, ShapeConfig, SHAPES, cell_is_skipped

from . import (dbrx_132b, gemma_7b, kimi_k2_1t_a32b, llama3_2_3b,
               mistral_large_123b, qwen2_vl_7b, recurrentgemma_9b, rwkv6_3b,
               seamless_m4t_large_v2, yi_34b)

_MODULES = {
    "gemma-7b": gemma_7b,
    "llama3.2-3b": llama3_2_3b,
    "mistral-large-123b": mistral_large_123b,
    "yi-34b": yi_34b,
    "rwkv6-3b": rwkv6_3b,
    "qwen2-vl-7b": qwen2_vl_7b,
    "dbrx-132b": dbrx_132b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "seamless-m4t-large-v2": seamless_m4t_large_v2,
    "recurrentgemma-9b": recurrentgemma_9b,
}

ARCH_NAMES = tuple(_MODULES)


def get(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; one of {ARCH_NAMES}")
    return _MODULES[name].config()


# ---------------------------------------------------------------------------
# reduced smoke variants: same family / pattern / features, tiny dims
# ---------------------------------------------------------------------------

def smoke_of(cfg: ModelConfig) -> ModelConfig:
    """Shrink every dimension while preserving the architecture family,
    layer pattern, attention kind, MoE topology and modality plumbing."""
    n_layers = len(cfg.prefix) + 2 * len(cfg.pattern) + len(cfg.suffix)
    hd = 16
    kv = min(cfg.num_kv_heads, 2) if cfg.num_kv_heads > 1 else 1
    heads = 4
    moe = None
    if cfg.moe:
        moe = MoEConfig(num_experts=8, top_k=min(cfg.moe.top_k, 2), d_ff=32,
                        num_shared=min(cfg.moe.num_shared, 1),
                        capacity_factor=2.0)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=64, num_heads=heads, num_kv_heads=kv, head_dim=hd,
        d_ff=128, vocab=512,
        mrope_sections=(2, 3, 3) if cfg.rope == "mrope" else (),
        window=8 if cfg.attn_kind == "local" else 0,
        moe=moe,
        enc_layers=2 if cfg.encdec else 0,
        rwkv_head_dim=16,
        rglru_width=64 if cfg.rglru_width else 0,
        param_dtype="float32", compute_dtype="float32",
        remat=False,
    )


# ---------------------------------------------------------------------------
# input specs per (arch, shape) cell
# ---------------------------------------------------------------------------

def batch_shapes(cfg: ModelConfig, shape: ShapeConfig) -> dict[str, Any]:
    """Abstract input shapes/dtypes for a cell, as (shape, dtype, kind)
    where kind in {'tokens','embeds','labels','positions','src'} drives the
    sharding the launch layer attaches.  Decode cells add the KV cache via
    Model.cache_specs separately."""
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    out: dict[str, tuple] = {}
    if shape.kind in ("train", "prefill"):
        if cfg.input_mode == "embeds":
            out["embeds"] = ((b, s, d), cfg.cdtype, "act")
        else:
            out["tokens"] = ((b, s), jnp.int32, "tokens")
        if cfg.rope == "mrope":
            out["positions"] = ((3, b, s), jnp.int32, "positions")
        if cfg.encdec:
            out["src_embeds"] = ((b, src_len(cfg, shape)), None, None)
            out["src_embeds"] = ((b, src_len(cfg, shape), d), cfg.cdtype, "act")
        if shape.kind == "train":
            out["labels"] = ((b, s), jnp.int32, "tokens")
    else:  # decode: one token against a seq_len cache
        if cfg.input_mode == "embeds":
            out["tokens"] = ((b, 1), jnp.int32, "tokens")  # text generation
        else:
            out["tokens"] = ((b, 1), jnp.int32, "tokens")
        if cfg.rope == "mrope":
            out["positions"] = ((3, b, 1), jnp.int32, "positions")
    return out


def src_len(cfg: ModelConfig, shape: ShapeConfig) -> int:
    """Encoder source length for enc-dec cells (speech frames)."""
    return min(shape.seq_len, 4096)


# public (decomposition) names first; the rest is the legacy LM substrate
__all__ = ["CPALS_WORKLOADS", "CPALS_DATASET",
           # -- legacy LM substrate (dry-run matrix + LM launchers) --
           "ARCH_NAMES", "get", "smoke_of", "batch_shapes", "src_len",
           "SHAPES", "cell_is_skipped"]

# ---------------------------------------------------------------------------
# the paper's own workloads (Table I), as decomposition configs
# ---------------------------------------------------------------------------

CPALS_WORKLOADS = {
    # name: (dims, nnz, rank) — rank 35 is the paper's setting
    "cpals-yelp": ((41_000, 11_000, 75_000), 8_000_000, 35),
    "cpals-nell2": ((12_000, 9_000, 29_000), 77_000_000, 35),
    "cpals-netflix": ((480_000, 18_000, 2_000), 100_000_000, 35),
}

# workload id -> repro.core.PAPER_DATASETS key (the synthetic replica the
# launchers/planner use to materialize a scaled tensor for that workload)
CPALS_DATASET = {
    "cpals-yelp": "yelp",
    "cpals-nell2": "nell-2",
    "cpals-netflix": "netflix",
}
