"""dbrx-132b [moe] — 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352; fine-grained MoE 16 experts top-4.
[hf:databricks/dbrx-base; unverified]"""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b", family="moe",
        num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=10752, vocab=100_352,
        mlp="swiglu", norm="layernorm", rope="std", rope_theta=500_000.0,
        pattern=("moe",),
        moe=MoEConfig(num_experts=16, top_k=4, d_ff=10752),
        fsdp=True,
    )
