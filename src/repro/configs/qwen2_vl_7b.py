"""qwen2-vl-7b [vlm] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944
vocab=152064; M-RoPE (t/h/w sections 16/24/24), dynamic resolution.
Backbone only: the vision frontend is a stub — input_specs() provides
precomputed patch embeddings. [arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-7b", family="vlm",
        num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
        head_dim=128, d_ff=18944, vocab=152_064,
        mlp="swiglu", rope="mrope", rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24), input_mode="embeds",
    )
