"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) vocab=163840;
MoE 384 experts top-8 + 1 shared expert, expert d_ff=2048 (per the assigned
spec; the dense first layer uses the same d_ff — see DESIGN.md), first layer
dense. Trillion-param total, ~32B active. [arXiv:2501.kimi2; unverified]"""
from repro.models.config import ModelConfig, MoEConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
        head_dim=128, d_ff=2048, vocab=163_840,
        mlp="swiglu", rope="std", rope_theta=50_000.0,
        prefix=("attn",), pattern=("moe",),
        moe=MoEConfig(num_experts=384, top_k=8, d_ff=2048, num_shared=1),
        fsdp=True,
    )
