"""gemma-7b [dense] — 28L d_model=3072 16H (MHA kv=16) d_ff=24576 vocab=256000,
GeGLU, head_dim=256, tied + scaled embeddings. [arXiv:2403.08295; hf]"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma-7b", family="dense",
        num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
        head_dim=256, d_ff=24576, vocab=256_000,
        mlp="geglu", rope="std", rope_theta=10_000.0,
        tie_embeddings=True, scale_embed=True,
    )
