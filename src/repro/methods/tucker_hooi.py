"""Sparse Tucker decomposition via HOOI on the TTMc kernel registry.

HOOI (higher-order orthogonal iteration) alternates, for each mode n:

    Y_(n)  =  mode-n TTMc of X against every other mode's factor
              (``repro.core.ttmc`` — the Kronecker analogue of MTTKRP,
              planned per mode by ``plan_decomposition(kernel="ttmc")``)
    U_n    =  leading R_n left singular vectors of Y_(n)   (thin SVD)

and recovers the core from the *final* TTMc for free:

    G_(N-1)  =  U_{N-1}^T Y_(N-1)

(no extra pass over X — the Tucker sibling of SPLATT's inner-product trick).
With orthonormal factors ``||X - Xhat||^2 = ||X||^2 - ||G||^2``, so the fit
also falls out of the core, and ``||G||`` is non-decreasing across HOOI
sweeps (the monotone-fit property the tests assert).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.coo import SparseTensor
from repro.core.cpals import build_workspace
from repro.core.ttmc import ttmc
from repro.obs import trace as obs_trace

from .cp_als import resolve_ingested
from .iteration import IterationRecorder
from .registry import DecompState, MethodSpec, make_state, register_method

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class TuckerDecomp:
    """Result: X ~ core x_1 U_1 x_2 U_2 ... (orthonormal U_m)."""

    core: Array                 # (R_0, ..., R_{N-1})
    factors: tuple[Array, ...]  # per-mode (I_m, R_m), orthonormal columns
    fit: Array

    def tree_flatten(self):
        return (self.core, self.factors, self.fit), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        core, factors, fit = children
        return cls(core=core, factors=tuple(factors), fit=fit)

    @property
    def ranks(self) -> tuple[int, ...]:
        return tuple(int(a.shape[1]) for a in self.factors)

    def values_at(self, inds: Array) -> Array:
        """Reconstructed entries at coordinate list (n, order)."""
        order = len(self.factors)
        letters = "abcdefgh"[:order]
        eq = (letters + "," + ",".join(f"n{c}" for c in letters) + "->n")
        rows = [a[inds[:, m]] for m, a in enumerate(self.factors)]
        return jnp.einsum(eq, self.core, *rows)

    def to_dense(self) -> Array:
        """Densify (tests only)."""
        order = len(self.factors)
        letters = "abcdefgh"[:order]
        ranks = "pqrstuvw"[:order]
        eq = (ranks + "," + ",".join(f"{l}{r}" for l, r in zip(letters, ranks))
              + "->" + letters)
        return jnp.einsum(eq, self.core, *self.factors)


def _resolve_ranks(rank, dims: Sequence[int]) -> tuple[int, ...]:
    """An int broadcasts (capped at each mode length); a sequence is taken
    per mode and validated."""
    if isinstance(rank, (int, float)):
        return tuple(min(int(rank), int(d)) for d in dims)
    ranks = tuple(int(r) for r in rank)
    if len(ranks) != len(dims):
        raise ValueError(
            f"rank={ranks} names {len(ranks)} modes, tensor has {len(dims)}")
    bad = [m for m, (r, d) in enumerate(zip(ranks, dims)) if r > int(d)]
    if bad:
        raise ValueError(
            f"Tucker rank exceeds mode length in mode(s) {bad} "
            f"(ranks={ranks}, dims={tuple(dims)})")
    return ranks


def _kron_widths(ranks: Sequence[int]) -> tuple[int, ...]:
    """Per-mode TTMc output width prod_{m != n} R_m — what the planner's
    cost models score for the ``ttmc`` kernel."""
    out = []
    for n in range(len(ranks)):
        w = 1
        for m, r in enumerate(ranks):
            if m != n:
                w *= r
        out.append(w)
    return tuple(out)


def _init_orthonormal(dims, ranks, key, dtype) -> tuple[Array, ...]:
    keys = jax.random.split(key, len(dims))
    out = []
    for k, d, r in zip(keys, dims, ranks):
        q, _ = jnp.linalg.qr(jax.random.normal(k, (int(d), int(r)),
                                               dtype=dtype))
        out.append(q)
    return tuple(out)


@partial(jax.jit, static_argnames=("mode", "impl", "out_rank"))
def _hooi_mode(ws_n, factors, *, mode, impl, out_rank):
    """TTMc + thin-SVD truncation for one mode: returns (U_mode, Y_(mode))."""
    y = ttmc(ws_n, factors, mode, impl=impl)
    u, _, _ = jnp.linalg.svd(y, full_matrices=False)
    return u[:, :out_rank], y


def _core_from_last(u_last: Array, y_last: Array,
                    ranks: Sequence[int]) -> Array:
    """G from the final mode's TTMc: G_(N-1) = U^T Y, un-matricized.

    Y's columns are row-major over the other modes in ascending order, so
    the reshape puts the last mode's rank axis first and a moveaxis restores
    mode order."""
    order = len(ranks)
    core = (u_last.T @ y_last).reshape(
        (ranks[-1],) + tuple(ranks[:-1]))
    return jnp.moveaxis(core, 0, order - 1)


def tucker_hooi(
    t,
    rank,
    *,
    niters: int = 20,
    tol: float = 0.0,
    impl: str = "segment",
    plan=None,
    key: Array | None = None,
    block: int | None = None,
    row_tile: int | None = None,
    verbose: bool = False,
    state: DecompState | None = None,
    checkpoint_cb: Callable[[DecompState], None] | None = None,
    monitor=None,
) -> TuckerDecomp:
    """Sparse Tucker via HOOI.

    ``rank`` is a per-mode tuple of core ranks (an int broadcasts, capped at
    each mode length).  ``impl`` is the same planner policy as the CP
    drivers, but scored against the **ttmc** registry
    (``plan_decomposition(kernel="ttmc")``) with each mode's Kronecker
    output width prod_{m != n} R_m as the cost-model rank; the per-mode CSF
    workspaces are the very same ones CP uses (and come from the ingest
    cache for an ``Ingested`` handle).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    ing, t, block, row_tile = resolve_ingested(t, "tucker_hooi", block=block,
                                               row_tile=row_tile)
    ranks = _resolve_ranks(rank, t.dims)
    widths = _kron_widths(ranks)

    if plan is None:
        if ing is not None:
            plan = ing.plan(impl, rank=widths, kernel="ttmc",
                            factor_ranks=ranks)
        else:
            from repro.plan import plan_decomposition

            plan = plan_decomposition(t, impl, rank=widths, block=block,
                                      row_tile=row_tile, kernel="ttmc",
                                      with_stats=impl == "auto",
                                      factor_ranks=ranks)
    ws = ing.workspace(plan) if ing is not None else build_workspace(t, plan)
    impls = plan.impls

    norm_x_sq = jnp.sum(t.vals.astype(jnp.float32) ** 2)
    norm_x = jnp.sqrt(norm_x_sq)

    if state is None:
        factors = _init_orthonormal(t.dims, ranks, key, t.vals.dtype)
        fit = jnp.array(0.0, dtype=t.vals.dtype)
        fit_prev = jnp.array(0.0, dtype=t.vals.dtype)
        start_iter = 0
    else:
        factors = tuple(state.factors)
        # compare the next fit against the last COMPUTED one (see cp_als)
        fit, fit_prev = state.fit, state.fit
        start_iter = int(state.iteration)

    order = t.order
    y_last = None
    recorder = IterationRecorder("tucker_hooi", monitor=monitor,
                                 verbose=verbose)
    for it in range(start_iter, niters):
        with recorder.iteration(it):
            factors = list(factors)
            for n in range(order):
                # TTMc + thin SVD is one jitted call per mode; the span
                # times the dispatch only — no added sync
                with obs_trace.span("ttmc", mode=n, impl=impls[n]):
                    factors[n], y_last = _hooi_mode(
                        ws[n], tuple(factors), mode=n, impl=impls[n],
                        out_rank=ranks[n])
            factors = tuple(factors)
            with obs_trace.span("fit"):
                core = _core_from_last(factors[-1], y_last, ranks)
                # orthonormal factors: ||X - Xhat||^2 = ||X||^2 - ||G||^2
                resid_sq = jnp.maximum(norm_x_sq - jnp.sum(core * core), 0.0)
                fit = 1.0 - jnp.sqrt(resid_sq) / norm_x
        delta = recorder.progress(it, fit, fit_prev)
        if checkpoint_cb is not None:
            checkpoint_cb(make_state(factors, {}, fit, fit_prev, it + 1))
        if tol > 0.0 and it > 0 and abs(delta) < tol:
            fit_prev = fit
            break
        fit_prev = fit

    if y_last is None:
        # resumed at (or past) niters: recover the core with one final TTMc
        y_last = ttmc(ws[order - 1], tuple(factors), order - 1,
                      impl=impls[order - 1])
        core = _core_from_last(factors[-1], y_last, ranks)
        resid_sq = jnp.maximum(norm_x_sq - jnp.sum(core * core), 0.0)
        fit = 1.0 - jnp.sqrt(resid_sq) / norm_x

    decomp = TuckerDecomp(core=core, factors=tuple(factors), fit=fit)
    if ing is not None and ing.relabeling is not None:
        decomp = TuckerDecomp(
            core=decomp.core,
            factors=ing.restore_factors(decomp.factors),
            fit=decomp.fit)
    return decomp


register_method(MethodSpec(
    name="tucker_hooi",
    fn=tucker_hooi,
    family="tucker",
    kernel="ttmc",
    supports_dist=False,   # the shard_map body expresses MTTKRP reductions,
                           # not the Kronecker-width TTMc (yet)
    supports_streaming=False,
    nonnegative=False,
    supports_order_gt3=True,
    monotone_fit=True,
    description="sparse Tucker via HOOI: per-mode chain-of-modes TTMc + "
                "thin-SVD truncation; core recovered from the final TTMc",
))
