"""CP-ALS (the paper's Algorithm 1) behind the method registry.

The iteration machinery (fused/timed iteration bodies, the state pytrees,
the workspace builders) stays in ``repro.core.cpals`` — it is shared with
``launch/steps.make_cpals_step`` and the distributed driver.  What lives
here is the *driver loop*: plan -> sort -> iterate -> (checkpoint / early
stop), now one registered method among several instead of the hardcoded
only algorithm.  ``repro.core.cp_als`` re-exports this function, so every
historical call site keeps working.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.coo import SparseTensor
from repro.core.cpals import (CPALSState, CPDecomp, _iteration,
                              _iteration_timed, _timed, build_workspace,
                              donate_buffers, init_factors, resolve_plan)
from repro.core.gram import gram
from repro.obs import trace as obs_trace

from .iteration import IterationRecorder, record_iteration
from .registry import DecompState, MethodSpec, make_state, register_method

__all__ = ["cp_als", "cpals_state_to_decomp", "record_iteration",
           "resolve_ingested"]

Array = jax.Array


def _as_cpals_state(state) -> CPALSState:
    """Accept either the historical CPALSState or the shared DecompState."""
    if state is None or isinstance(state, CPALSState):
        return state
    if isinstance(state, DecompState):
        return CPALSState(tuple(state.factors), state.aux["lmbda"],
                          state.fit, state.fit_prev, state.iteration)
    raise TypeError(
        f"state must be a CPALSState or repro.methods.DecompState, "
        f"got {type(state).__name__}")


def cpals_state_to_decomp(state: CPALSState) -> DecompState:
    """CPALSState -> the shared protocol (lmbda rides in ``aux``)."""
    return DecompState(tuple(state.factors), {"lmbda": state.lmbda},
                       state.fit, state.fit_prev, state.iteration)


def resolve_ingested(t, name: str, *, block, row_tile):
    """Shared driver preamble: unwrap an ``Ingested`` handle (validating
    that an explicit tile request does not conflict with the ingest-time
    geometry) into ``(ingested_or_None, tensor, block, row_tile)``."""
    ing = None
    if not isinstance(t, SparseTensor):
        from repro.ingest import Ingested

        if not isinstance(t, Ingested):
            raise TypeError(
                f"{name} takes a SparseTensor or repro.ingest.Ingested, "
                f"got {type(t).__name__}")
        ing = t
        t = ing.tensor
        # the ingest-time tile geometry is authoritative; an explicit
        # conflicting request must fail loudly, not be silently ignored
        for pname, asked, have in (("block", block, ing.block),
                                   ("row_tile", row_tile, ing.row_tile)):
            if asked is not None and asked != have:
                raise ValueError(
                    f"{name} was asked for {pname}={asked} but this tensor "
                    f"was ingested with {pname}={have}; re-ingest with "
                    "tile=(block, row_tile) instead")
    return ing, t, (block if block is not None else 512), (
        row_tile if row_tile is not None else 128)


def auto_timers(timers, tracer=None):
    """The driver-side tracing switch: when an enabled tracer is active
    and the caller did not ask for timers, hand back a fresh timer dict
    so the driver takes its per-routine timed path (whose ``_timed``
    syncs give the spans honest durations) — plus whether the tracer
    wants the fused (sort/mttkrp/epilogue) or split (full Table-III)
    routine set.  Returns ``(timers_or_None, fused_override_or_None)``."""
    if tracer is None:
        tracer = obs_trace.current_tracer()
    if timers is None and tracer is not None and tracer.enabled:
        return {}, tracer.routines == "fused"
    return timers, None


def cp_als(
    t,
    rank: int,
    *,
    niters: int = 20,
    tol: float = 0.0,
    impl: str = "segment",
    plan=None,
    key: Array | None = None,
    block: int | None = None,
    row_tile: int | None = None,
    timers: dict | None = None,
    verbose: bool = False,
    first_norm: str = "max",
    with_fit: bool = True,
    fused_epilogue: bool = False,
    state: CPALSState | DecompState | None = None,
    checkpoint_cb: Callable[[CPALSState], None] | None = None,
    monitor=None,
) -> CPDecomp:
    """Run CP-ALS per Algorithm 1.

    tol == 0 reproduces the paper's fixed-20-iteration experiments; tol > 0
    stops when |fit - fit_prev| < tol (the "fit ceases to improve" branch).
    ``state``/``checkpoint_cb`` give restartable long decompositions
    (``state`` may be the historical :class:`CPALSState` or the shared
    :class:`repro.methods.DecompState`).

    Execution strategy: ``impl`` is a planner policy — ``"auto"`` selects an
    MTTKRP implementation *per mode* from measured tensor statistics (the
    paper's §V-D regime rules), any registered name pins all modes.  Pass a
    prebuilt ``plan`` (:class:`repro.plan.DecompPlan`) to skip planning.

    ``with_fit=False`` skips the fit computation entirely (it needs the
    final mode's MTTKRP and all grams — cheap but not free); the returned
    fit is then the last *computed* one (a restored state's, else NaN) —
    never a fabricated 0.0.

    ``t`` may also be a :class:`repro.ingest.Ingested` handle: planning then
    reuses the stats measured at ingest, workspaces come from the ingest
    cache when warm (skipping the sort entirely), and the returned factors
    are mapped back to the tensor's ORIGINAL labels through the handle's
    inverse relabeling.  (``state``/``checkpoint_cb`` operate in the
    relabeled space.)

    ``monitor``: optional :class:`repro.dist.StragglerMonitor`; per-iteration
    wall times are recorded so imbalance shows up at the driver.

    ``fused_epilogue`` only changes the *timed* path (``timers=``): the
    per-mode post-MTTKRP chain (ata/inverse/norm/fit) is executed — and
    timed — as ONE jitted ``fused_mode_epilogue`` call under the
    ``"epilogue"`` timer key instead of five host-synced routine calls.
    The untimed path is always fully fused (one jit per iteration).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    if not with_fit and tol > 0.0:
        raise ValueError("tol > 0 needs the fit; drop with_fit=False")
    state = _as_cpals_state(state)

    ing, t, block, row_tile = resolve_ingested(t, "cp_als", block=block,
                                               row_tile=row_tile)

    # --- Plan + Sort / CSF build (paper's pre-processing stage: the stats
    # pass and the workspace sort are both host-side, per-mode O(nnz) work,
    # timed together under the paper's "Sort" key; with an Ingested handle
    # both stages may be pure cache reads) ---
    def _plan_and_build():
        if ing is not None:
            p = plan if plan is not None else ing.plan(impl, rank=rank)
            return p, ing.workspace(p)
        p = resolve_plan(t, impl, plan, rank=rank, block=block,
                         row_tile=row_tile)
        return p, build_workspace(t, p)

    # tracing (obs enabled) implies the timed path: spans need the routine
    # boundaries.  The tracer's default "fused" routine set keeps the added
    # host syncs to two per mode — the overhead the obs benchmark gates.
    timers, fused_override = auto_timers(timers)
    if fused_override is not None:
        fused_epilogue = fused_override

    if timers is not None:
        with obs_trace.span("sort"):
            plan, ws = _timed(timers, "sort", _plan_and_build)
    else:
        plan, ws = _plan_and_build()
    impls = plan.impls

    norm_x_sq = jnp.sum(t.vals.astype(jnp.float32) ** 2)

    if state is None:
        factors = init_factors(t.dims, rank, key, dtype=t.vals.dtype)
        lmbda = jnp.ones((rank,), dtype=t.vals.dtype)
        fit = jnp.array(0.0 if with_fit else jnp.nan, dtype=t.vals.dtype)
        fit_prev = jnp.array(0.0, dtype=t.vals.dtype)
        start_iter = 0
    else:
        factors = tuple(state.factors)
        lmbda, fit = state.lmbda, state.fit
        # the next iteration's tol check compares against the last COMPUTED
        # fit — state.fit, not the stored delta record — so a tol>0 resume
        # stops at the same iteration as the uninterrupted run
        fit_prev = state.fit
        start_iter = int(state.iteration)

    donate = donate_buffers()
    if donate and state is not None:
        # the first iteration donates the factor buffers; keep the caller's
        # restored state intact by handing the loop its own copies
        factors = tuple(jnp.array(a, copy=True) for a in factors)

    grams = tuple(gram(a) for a in factors)

    recorder = IterationRecorder("cp_als", monitor=monitor, verbose=verbose)
    for it in range(start_iter, niters):
        norm_kind = first_norm if it == 0 else "2"
        with recorder.iteration(it):
            if timers is not None:
                factors, grams, lmbda, fit_new = _iteration_timed(
                    ws, factors, grams, norm_x_sq, timers, impls=impls,
                    norm_kind=norm_kind, with_fit=with_fit,
                    fused=fused_epilogue
                )
            else:
                factors, grams, lmbda, fit_new = _iteration(
                    ws, tuple(factors), grams, norm_x_sq, impls=impls,
                    norm_kind=norm_kind, with_fit=with_fit,
                    # checkpoint_cb hands factor references out of the loop,
                    # so donation would invalidate the checkpointed arrays
                    donate=donate and checkpoint_cb is None
                )
            if with_fit:
                fit = fit_new
        delta = recorder.progress(it, fit, fit_prev)
        if checkpoint_cb is not None:
            checkpoint_cb(
                CPALSState(
                    tuple(factors), lmbda, fit, fit_prev,
                    jnp.array(it + 1, dtype=jnp.int32),
                )
            )
        if tol > 0.0 and it > 0 and abs(delta) < tol:
            fit_prev = fit
            break
        fit_prev = fit

    decomp = CPDecomp(factors=tuple(factors), lmbda=lmbda, fit=fit)
    if ing is not None:
        decomp = ing.restore(decomp)
    return decomp


register_method(MethodSpec(
    name="cp_als",
    fn=cp_als,
    family="cp",
    kernel="mttkrp",
    supports_dist=True,
    supports_streaming=False,
    nonnegative=False,
    supports_order_gt3=True,
    monotone_fit=True,
    state_aux=("lmbda",),
    description="SPLATT-style CP-ALS (paper Algorithm 1): Cholesky solve "
                "per mode over the planned MTTKRP registry",
))
