"""``fit()`` — the one entry point over the decomposition-method registry.

    from repro.methods import fit

    dec = fit(ingest("data.tns"), rank=16)                      # CP-ALS
    dec = fit(t, rank=16, method="cp_nn_hals", niters=80)       # nonneg CP
    dec = fit(t, rank=(8, 8, 8), method="tucker_hooi")          # Tucker
    dec = fit("big.tnsb", rank=16, method="cp_als_streaming",
              chunk_nnz=1 << 22)                                # streaming

Every method shares the planner/ingest stack (``plan=`` skips planning,
``Ingested`` handles reuse ingest-time stats and cached workspaces, factors
come back in original labels) and the :class:`DecompState` resume protocol
(``state=`` / ``checkpoint_cb=``).  The iteration bodies are jitted; the
driver itself is a thin capability-checked dispatch.
"""
from __future__ import annotations

from typing import Callable, Optional

from .registry import DecompState, get_method


def fit(
    x,
    rank,
    *,
    method: str = "cp_als",
    niters: Optional[int] = None,
    tol: float = 0.0,
    impl: Optional[str] = None,
    plan=None,
    key=None,
    state: Optional[DecompState] = None,
    checkpoint_cb: Optional[Callable[[DecompState], None]] = None,
    monitor=None,
    verbose: bool = False,
    **method_kwargs,
):
    """Decompose ``x`` with a registered method.

    ``x``: a :class:`~repro.core.coo.SparseTensor`, a
    :class:`~repro.ingest.Ingested` handle, or — for streaming-capable
    methods — a ``.tns``/``.tnsb`` path or chunk list.
    ``rank``: int for the CP family; int or per-mode tuple for Tucker.
    ``method``: a name from :func:`repro.methods.available_methods`.
    ``checkpoint_cb`` always receives the shared :class:`DecompState`
    (method-specific state classes are converted), so one checkpointing
    path serves every method.

    Remaining keywords (``decay=``, ``chunk_nnz=``, ``first_norm=``,
    ``timers=``, ...) forward to the method implementation.
    """
    spec = get_method(method)

    is_tensorish = hasattr(x, "order")  # SparseTensor / Ingested both have it
    if not is_tensorish and not spec.supports_streaming:
        raise TypeError(
            f"method {method!r} needs a materialized tensor "
            f"(SparseTensor or Ingested), got {type(x).__name__}; only "
            "streaming-capable methods accept paths/chunk sources "
            f"(see available_methods(streaming=True))")
    if is_tensorish and x.order > 3 and not spec.supports_order_gt3:
        raise ValueError(
            f"method {method!r} does not support order-{x.order} tensors")

    ing = None
    if spec.supports_streaming and is_tensorish:
        from repro.core.coo import SparseTensor
        from repro.ingest import Ingested

        if isinstance(x, Ingested):
            # streaming folds raw chunks and never builds the handle's
            # sorted workspaces: unwrap the (relabeled) tensor here and
            # restore original labels on the way out, like the batch
            # methods do internally
            ing = x
            x = ing.tensor
        elif not isinstance(x, SparseTensor):
            raise TypeError(
                f"method {method!r} takes a SparseTensor, an Ingested "
                f"handle, a .tns/.tnsb path, or a chunk list; got "
                f"{type(x).__name__}")

    kwargs = dict(method_kwargs)
    if niters is not None:
        kwargs["niters"] = niters
    if impl is not None:
        kwargs["impl"] = impl
    if spec.name == "cp_als" and checkpoint_cb is not None:
        # cp_als natively emits the historical CPALSState; normalize to the
        # shared protocol so callers see one state type for every method
        from .cp_als import cpals_state_to_decomp

        user_cb = checkpoint_cb
        checkpoint_cb = lambda s: user_cb(cpals_state_to_decomp(s))

    from repro.obs import trace as obs_trace

    with obs_trace.span("fit.dispatch", method=spec.name):
        result = spec.fn(x, rank, tol=tol, plan=plan, key=key, state=state,
                         checkpoint_cb=checkpoint_cb, monitor=monitor,
                         verbose=verbose, **kwargs)
    if ing is not None:
        result = ing.restore(result)
    return result
