"""The decomposition-method registry: capability-declared method specs and
the shared checkpointable state protocol.

The stack under this package — per-mode planner (``repro.plan``), unified
CSF workspace + kernel registries (``repro.core``), ingest cache
(``repro.ingest``), collectives (``repro.dist``) — is algorithm-agnostic
plumbing.  This module is the seam that opens it to multiple decomposition
algorithms, mirroring the ``core/mttkrp.py`` ImplSpec design one level up:
each method is a first-class :class:`MethodSpec` that declares its family,
the sparse kernel it plans against, and the execution contexts it supports
(distributed shard_map, chunked streaming), so drivers validate capability
instead of hardcoding method names.

Registered methods (see the sibling modules):

==================  =======================================================
method              what it computes
==================  =======================================================
``cp_als``          SPLATT-style CP-ALS (the paper's Algorithm 1), moved
                    here from ``core/cpals.py`` behind the protocol.
``cp_nn_hals``      nonnegative CP via hierarchical ALS: rank-one column
                    updates with nonnegative projection, reusing the MTTKRP
                    registry and gram machinery unchanged.
``tucker_hooi``     sparse Tucker via HOOI: per-mode chain-of-modes TTMc
                    (``core/ttmc.py``) + thin-SVD truncation; the core
                    tensor is recovered by the final TTMc.
``cp_als_streaming`` online CP-ALS over chunk batches from
                    ``ingest.reader`` with exponentially weighted MTTKRP
                    accumulators — no full COO materialization.
==================  =======================================================

This table is kept in sync with ``docs/architecture.md`` ("The method
registry").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp

Array = jax.Array


# ---------------------------------------------------------------------------
# shared state protocol
# ---------------------------------------------------------------------------


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DecompState:
    """Checkpointable mid-run state shared by every registered method.

    factors:   per-mode factor matrices (the one field every method has).
    aux:       method-specific leaves as a dict pytree — ``{"lmbda": ...}``
               for the CP family, ``{}`` for Tucker (the core is a function
               of the factors and is recomputed on resume).
    fit/fit_prev: the convergence trajectory (NaN when never computed).
    iteration: int32 scalar; ``fit(..., state=s)`` resumes from here.

    The pytree round-trips through ``repro.checkpoint.manager`` (every leaf
    is an array), and (iteration, factors, aux) fully determine the rest of
    the computation for every registered method — the bit-exact-resume
    contract ``tests/test_checkpoint.py`` asserts.
    """

    factors: tuple[Array, ...]
    aux: dict[str, Array]
    fit: Array
    fit_prev: Array
    iteration: Array  # int32 scalar

    def tree_flatten(self):
        return (self.factors, self.aux, self.fit, self.fit_prev,
                self.iteration), ()

    @classmethod
    def tree_unflatten(cls, aux_data, children):
        factors, aux, fit, fit_prev, iteration = children
        return cls(tuple(factors), dict(aux), fit, fit_prev, iteration)


def make_state(factors, aux, fit, fit_prev, iteration: int) -> DecompState:
    return DecompState(tuple(factors), dict(aux), fit, fit_prev,
                       jnp.array(iteration, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MethodSpec:
    """One decomposition method and its declared capabilities.

    family:     "cp" (Kruskal result) or "tucker" (core + orthonormal
                factors).
    kernel:     the sparse kernel registry the planner scores for this
                method — "mttkrp" or "ttmc" (``repro.plan``'s ``kernel=``).
    supports_dist: whether the method can execute under the shard_map
                medium-grained driver (``core/distributed.py``); drivers
                raise a clear error for unsupported combos instead of
                silently computing something else.
    supports_streaming: whether the method consumes chunk sources (paths /
                chunk iterators from ``ingest.reader``) without a full COO
                materialization.
    nonnegative: whether the returned factors are elementwise >= 0 by
                construction.
    monotone_fit: ALS-family guarantee the tests assert (fit non-decreasing
                up to float tolerance).
    state_aux: the keys this method's checkpointed :class:`DecompState`
                carries in ``aux`` — what a resumer needs to rebuild the
                pytree STRUCTURE before the arrays are loaded (the CP
                drivers store ``lmbda``; HALS/Tucker renormalize from the
                factors and checkpoint an empty aux).
    """

    name: str
    fn: Callable[..., object]
    family: str
    kernel: str = "mttkrp"
    supports_dist: bool = False
    supports_streaming: bool = False
    nonnegative: bool = False
    supports_order_gt3: bool = True
    monotone_fit: bool = True
    state_aux: tuple[str, ...] = ()
    description: str = ""


METHODS: dict[str, MethodSpec] = {}


def register_method(spec: MethodSpec) -> MethodSpec:
    """Add (or replace) a method in the registry."""
    if spec.family not in ("cp", "tucker"):
        raise ValueError(
            f"bad family {spec.family!r} for method {spec.name!r}")
    if spec.kernel not in ("mttkrp", "ttmc"):
        raise ValueError(
            f"bad kernel {spec.kernel!r} for method {spec.name!r}")
    METHODS[spec.name] = spec
    return spec


def get_method(name: str) -> MethodSpec:
    try:
        return METHODS[name]
    except KeyError:
        raise ValueError(
            f"unknown method {name!r}; one of {tuple(METHODS)}") from None


def available_methods(*, family: Optional[str] = None,
                      dist: Optional[bool] = None,
                      streaming: Optional[bool] = None,
                      nonnegative: Optional[bool] = None,
                      order: int = 3) -> tuple[str, ...]:
    """Names of methods whose declared capabilities cover the ask.

    Each keyword is a filter (None = don't care): ``dist=True`` keeps only
    methods that run under shard_map, ``streaming=True`` only those that
    consume chunk sources, etc.  This is what the distributed/serving
    drivers consult before dispatch."""
    out = []
    for name, spec in METHODS.items():
        if family is not None and spec.family != family:
            continue
        if dist is not None and spec.supports_dist != dist:
            continue
        if streaming is not None and spec.supports_streaming != streaming:
            continue
        if nonnegative is not None and spec.nonnegative != nonnegative:
            continue
        if order > 3 and not spec.supports_order_gt3:
            continue
        out.append(name)
    return tuple(out)
