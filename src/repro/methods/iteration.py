"""The shared driver iteration loop plumbing.

Every method driver used to carry its own copy of the same block::

    t0 = time.perf_counter()
    ...one iteration...
    record_iteration(monitor, time.perf_counter() - t0)
    delta = float(fit) - float(fit_prev)
    if verbose: print(...)

with three subtly different verbose formats and two dtype-inconsistent
delta computations (``float(fit - fit_prev)`` subtracts on device in the
factor dtype while the tol check compared host floats).
:class:`IterationRecorder` is that block, once: an ``"iteration"`` span
(when tracing), the StragglerMonitor feed *plus* its escalation check
(so single-host runs see slow-iteration flags through the metrics
registry too), the fit-trajectory metrics, and the one canonical
verbose line every method now prints::

      its = 3  fit = 0.812345  delta = +1.234e-02
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Iterator, Optional

from repro.obs import trace as obs_trace
from repro.obs.metrics import get_registry
from repro.obs.recorder import record_event


def record_iteration(monitor, dt: float) -> None:
    """Feed one iteration's wall time to a StragglerMonitor (if any)."""
    if monitor is not None:
        from repro.dist.straggler import record_step_times

        record_step_times(monitor, dt)


class IterationRecorder:
    """Per-driver-call recorder for the iteration loop.

    ``iteration(it)`` is the context manager wrapping one iteration's
    work; ``progress(it, fit, fit_prev)`` computes the dtype-consistent
    delta, prints the shared verbose line, and returns the delta for the
    driver's tol check.  With observability disabled (no active tracer)
    the per-iteration cost is one perf_counter pair and an ``is None``
    check — no tracer or registry traffic at all.
    """

    __slots__ = ("method", "monitor", "verbose", "_observed")

    def __init__(self, method: str, *, monitor=None,
                 verbose: bool = False) -> None:
        self.method = method
        self.monitor = monitor
        self.verbose = verbose
        self._observed = obs_trace.tracing()

    @contextmanager
    def iteration(self, it: int) -> Iterator[None]:
        t0 = time.perf_counter()
        with obs_trace.span("iteration", method=self.method, i=int(it)):
            yield
        dt = time.perf_counter() - t0
        record_iteration(self.monitor, dt)
        if self.monitor is not None:
            # escalations land in the metrics registry inside check() —
            # visible on single hosts, not just under the dist launcher
            self.monitor.check()
        if self._observed:
            registry = get_registry()
            registry.counter("fit.iterations").inc()
            registry.histogram("fit.iteration_ms").observe(dt * 1e3)
            record_event("iteration", method=self.method, i=int(it),
                         ms=dt * 1e3)

    def progress(self, it: int, fit, fit_prev) -> float:
        """One dtype-consistent delta scalar: cast both fits to python
        float FIRST, then subtract — printing ``float(fit - fit_prev)``
        (a bf16/f32 device subtraction) while comparing
        ``abs(float(fit) - float(fit_prev))`` against tol let the
        printed delta disagree with the stop decision."""
        delta = float(fit) - float(fit_prev)
        if self.verbose:
            print(f"  its = {it + 1}  fit = {float(fit):.6f}  "
                  f"delta = {delta:+.3e}")
        if self._observed:
            get_registry().gauge("fit.fit").set(float(fit))
        return delta
