"""Nonnegative CP via hierarchical ALS (HALS) on the shared kernel stack.

HALS (Cichocki & Phan's rank-one residual scheme) replaces CP-ALS's joint
Cholesky solve per mode with R sequential column updates, each a closed-form
nonnegative projection:

    a_r  <-  [ (M[:, r] - sum_{s != r} a_s V[s, r]) / V[r, r] ]_+

where M is the very same per-mode MTTKRP the planner schedules for CP-ALS
and V the very same Hadamard-of-Grams — i.e. the sparse kernel work per
iteration is *identical* to CP-ALS; only the tiny dense (I_n x R) update
changes.  That is the Phipps & Kolda observation this subsystem is built
around: nonnegative CP rides the performance-portable kernel layer
unchanged.

The objective is monotonically non-increasing under exact column updates,
so the reported fit is non-decreasing (up to float noise) — asserted by
``tests/test_methods.py``.  Factors stay elementwise >= 0 by construction
(init is uniform-positive, every update clamps at 0); the returned
:class:`~repro.core.cpals.CPDecomp` is column-normalized at the end so
``lmbda`` is nonnegative too.
"""
from __future__ import annotations

from functools import lru_cache
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.cpals import CPDecomp, _jit_mttkrp, _timed, \
    build_workspace, donate_buffers, init_factors, resolve_plan
from repro.core.gram import gram, hadamard_grams, kruskal_fit, normalize
from repro.core.mttkrp import mttkrp
from repro.obs import trace as obs_trace

from .cp_als import auto_timers, resolve_ingested
from .iteration import IterationRecorder
from .registry import DecompState, MethodSpec, make_state, register_method

Array = jax.Array

# Floor on the column's curvature V[r, r] before dividing: a fully collapsed
# column (all-zero factor column everywhere) has V[r, r] == 0 and must stay
# zero instead of producing inf/NaN.
_HALS_EPS = 1e-12


def _hals_mode_epilogue(m_mat, factors, grams, norm_x_sq, *, mode: int,
                        with_fit: bool):
    """One mode's whole post-MTTKRP HALS update as a single traceable chain —
    the nonnegative-projection counterpart of
    :func:`repro.core.cpals._mode_epilogue` (same signature shape, same
    full-tuples-in/full-tuples-out contract so the factor buffers can be
    donated).  The rank-one column loop replaces the Cholesky solve; the
    column loop unrolls at trace time (R is static and small — paper uses
    35); the fit rides the last mode's MTTKRP with unit lambda (the HALS
    factors carry their own scale)."""
    v = hadamard_grams(grams, mode)
    a = factors[mode]
    rank = a.shape[1]
    for r in range(rank):
        # M[:, r] - A V[:, r] + a_r V[r, r]  ==  M[:, r] - sum_{s != r} ...
        resid = m_mat[:, r] - a @ v[:, r] + a[:, r] * v[r, r]
        a = a.at[:, r].set(
            jnp.maximum(resid / jnp.maximum(v[r, r], _HALS_EPS), 0.0))
    factors = tuple(a if m == mode else f for m, f in enumerate(factors))
    grams = tuple(gram(a) if m == mode else g for m, g in enumerate(grams))
    if with_fit:
        ones = jnp.ones((rank,), dtype=factors[0].dtype)
        fit = kruskal_fit(norm_x_sq, ones, grams, m_mat, factors[-1])
    else:
        fit = jnp.array(jnp.nan, dtype=factors[0].dtype)
    return factors, grams, fit


def _hals_iteration_impl(ws, factors, grams, norm_x_sq, *, impls):
    """One full HALS sweep (every mode, every column); returns the same
    (factors, grams, fit) contract as the CP-ALS iteration body."""
    factors = tuple(factors)
    grams = tuple(grams)
    order = len(factors)
    fit = jnp.array(jnp.nan, dtype=factors[0].dtype)
    for n in range(order):
        m_mat = mttkrp(ws[n], factors, n, impl=impls[n])
        factors, grams, fit = _hals_mode_epilogue(
            m_mat, factors, grams, norm_x_sq, mode=n,
            with_fit=n == order - 1)
    return factors, grams, fit


@lru_cache(maxsize=None)
def _hals_iteration_jit(donate: bool):
    return jax.jit(_hals_iteration_impl, static_argnames=("impls",),
                   donate_argnums=(1, 2) if donate else ())


def _hals_iteration(ws, factors, grams, norm_x_sq, *, impls, donate=False):
    return _hals_iteration_jit(bool(donate))(
        ws, tuple(factors), tuple(grams), norm_x_sq, impls=impls)


@lru_cache(maxsize=None)
def _hals_epilogue_jit():
    return jax.jit(_hals_mode_epilogue, static_argnames=("mode", "with_fit"))


def _hals_iteration_timed(ws, factors, grams, norm_x_sq, timers, *, impls):
    """Per-routine timed HALS sweep (the tracing / ``timers=`` path).

    HALS's post-MTTKRP chain is already one fused rank-one-update call, so
    the fused/split routine distinction collapses here: both record the
    per-mode ``mttkrp`` and ``epilogue`` split — same keys, same span
    names, as CP-ALS's fused timed path."""
    factors = tuple(factors)
    grams = tuple(grams)
    order = len(factors)
    fit = jnp.array(jnp.nan, dtype=factors[0].dtype)
    for n in range(order):
        with obs_trace.span("mttkrp", mode=n, impl=impls[n]):
            m_mat = _timed(timers, "mttkrp", _jit_mttkrp, ws[n], factors,
                           mode=n, impl=impls[n])
        with obs_trace.span("epilogue", mode=n):
            factors, grams, fit = _timed(
                timers, "epilogue", _hals_epilogue_jit(), m_mat, factors,
                grams, norm_x_sq, mode=n, with_fit=n == order - 1)
    return factors, grams, fit


def cp_nn_hals(
    t,
    rank: int,
    *,
    niters: int = 50,
    tol: float = 0.0,
    impl: str = "segment",
    plan=None,
    key: Array | None = None,
    block: int | None = None,
    row_tile: int | None = None,
    timers: dict | None = None,
    verbose: bool = False,
    state: DecompState | None = None,
    checkpoint_cb: Callable[[DecompState], None] | None = None,
    monitor=None,
) -> CPDecomp:
    """Nonnegative CP decomposition via HALS.

    Same planner interface as :func:`repro.methods.cp_als.cp_als` (``impl``
    policy / prebuilt ``plan`` / ``Ingested`` handles); the MTTKRP registry
    and gram machinery are reused unchanged.  Returns a
    :class:`~repro.core.cpals.CPDecomp` with elementwise-nonnegative factors
    and nonnegative ``lmbda`` (columns 2-normalized at the end).
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    ing, t, block, row_tile = resolve_ingested(t, "cp_nn_hals", block=block,
                                               row_tile=row_tile)

    def _plan_and_build():
        if ing is not None:
            p = plan if plan is not None else ing.plan(impl, rank=rank)
            return p, ing.workspace(p)
        p = resolve_plan(t, impl, plan, rank=rank, block=block,
                         row_tile=row_tile)
        return p, build_workspace(t, p)

    # tracing implies the timed path (see cp_als.auto_timers); the fused /
    # split distinction is moot here — HALS's epilogue is already one call
    timers, _ = auto_timers(timers)
    if timers is not None:
        with obs_trace.span("sort"):
            plan_, ws = _timed(timers, "sort", _plan_and_build)
    else:
        plan_, ws = _plan_and_build()
    impls = plan_.impls

    norm_x_sq = jnp.sum(t.vals.astype(jnp.float32) ** 2)

    if state is None:
        # uniform-positive init: nonnegative from the first iterate
        factors = init_factors(t.dims, rank, key, dtype=t.vals.dtype)
        fit = jnp.array(0.0, dtype=t.vals.dtype)
        fit_prev = jnp.array(0.0, dtype=t.vals.dtype)
        start_iter = 0
    else:
        factors = tuple(state.factors)
        # compare the next fit against the last COMPUTED one (see cp_als)
        fit, fit_prev = state.fit, state.fit
        start_iter = int(state.iteration)

    donate = donate_buffers()
    if donate and state is not None:
        # first iteration donates the factor buffers; don't consume the
        # caller's restored state in place
        factors = tuple(jnp.array(a, copy=True) for a in factors)

    grams = tuple(gram(a) for a in factors)

    recorder = IterationRecorder("cp_nn_hals", monitor=monitor,
                                 verbose=verbose)
    for it in range(start_iter, niters):
        with recorder.iteration(it):
            if timers is not None:
                factors, grams, fit = _hals_iteration_timed(
                    ws, factors, grams, norm_x_sq, timers, impls=impls)
            else:
                factors, grams, fit = _hals_iteration(
                    ws, tuple(factors), grams, norm_x_sq, impls=impls,
                    # checkpoint_cb hands factor references out of the loop
                    donate=donate and checkpoint_cb is None)
        delta = recorder.progress(it, fit, fit_prev)
        if checkpoint_cb is not None:
            checkpoint_cb(make_state(factors, {}, fit, fit_prev, it + 1))
        if tol > 0.0 and it > 0 and abs(delta) < tol:
            fit_prev = fit
            break
        fit_prev = fit

    # canonical Kruskal form: unit-2-norm nonnegative columns, scale in
    # lmbda (zero-safe: collapsed columns keep lmbda == 0)
    normed, lams = zip(*(normalize(a, kind="2") for a in factors))
    lmbda = jnp.ones((rank,), dtype=t.vals.dtype)
    for lam in lams:
        lmbda = lmbda * lam
    decomp = CPDecomp(factors=tuple(normed), lmbda=lmbda, fit=fit)
    if ing is not None:
        decomp = ing.restore(decomp)
    return decomp


register_method(MethodSpec(
    name="cp_nn_hals",
    fn=cp_nn_hals,
    family="cp",
    kernel="mttkrp",
    supports_dist=False,   # sequential column updates don't map onto the
                           # medium-grained shard_map body (yet)
    supports_streaming=False,
    nonnegative=True,
    supports_order_gt3=True,
    monotone_fit=True,
    description="nonnegative CP via hierarchical ALS: rank-one column "
                "updates with nonnegative projection over the planned "
                "MTTKRP registry",
))
