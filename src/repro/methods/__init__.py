"""repro.methods — the decomposition-method registry.

    registry.py     MethodSpec + register/get/available, DecompState pytree
    driver.py       fit(x, rank, method=...) capability-checked dispatch
    cp_als.py       SPLATT-style CP-ALS (the paper's Algorithm 1)
    cp_nn_hals.py   nonnegative CP via hierarchical ALS
    tucker_hooi.py  sparse Tucker via chain-of-modes TTMc + thin SVD
    streaming.py    online CP-ALS over ingest.reader chunk batches

Importing this package registers all four methods.  See
``docs/architecture.md`` ("The method registry") for the capability matrix.
"""
from .registry import (DecompState, MethodSpec, METHODS, available_methods,
                       get_method, make_state, register_method)
from .driver import fit
from .cp_als import cp_als, cpals_state_to_decomp
from .cp_nn_hals import cp_nn_hals
from .tucker_hooi import TuckerDecomp, tucker_hooi
from .streaming import cp_als_streaming

__all__ = [
    "DecompState", "MethodSpec", "METHODS", "available_methods",
    "get_method", "make_state", "register_method", "fit",
    "cp_als", "cpals_state_to_decomp", "cp_nn_hals",
    "TuckerDecomp", "tucker_hooi", "cp_als_streaming",
]
