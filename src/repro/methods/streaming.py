"""Streaming CP-ALS: chunked MTTKRP accumulation, no full COO in memory.

The batch drivers materialize the whole non-zero set (plus per-mode sorted
workspaces).  This method instead consumes a *chunk source*
(``repro.ingest.reader.open_chunk_source``: a ``.tnsb`` mmap, a re-streamed
``.tns``, or an in-memory split) and reconstitutes each mode's MTTKRP as a
sum of per-chunk partials:

    M_n  =  sum_chunks  MTTKRP(chunk, factors, n)

Per-chunk partials are exact (each chunk owns a disjoint subset of the
non-zeros at full dims), so with ``decay=1`` (the default) an iteration is
numerically the batch ALS iteration up to summation order — the acceptance
contract (streamed fit == batch fit within 1e-3) in
``tests/test_methods.py``.  The dense updates (Hadamard-of-Grams, Cholesky,
normalize, fit) are the very routines ``core/cpals.py`` jits, reused
unchanged.

``decay < 1`` makes the fold *exponentially weighted*: the accumulator is
``acc <- decay * acc + MTTKRP(chunk)`` as chunks arrive, so a chunk ``k``
positions from the end of the stream enters with weight ``decay**k`` — the
online-CP discounting for time-ordered streams where the newest data should
dominate (the per-mode Grams discount implicitly through the factors the
fold produces).  The fold stays within one pass, so it is stable for any
decay: no stale-scale accumulator ever meets a fresh Gram solve.

Memory: one padded chunk resident at a time; no CSF sort (chunks arrive
unsorted, so the planner's COO-consuming ``gather_scatter`` impl is the
local reduction).  I/O: ``order`` passes over the source per iteration —
the price of exact Gauss-Seidel updates.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.cpals import (CPDecomp, _jit_fit, _jit_gram, _jit_hadamard,
                              _jit_mttkrp, _jit_normalize, _jit_solve,
                              init_factors)
from repro.core.coo import SparseTensor
from repro.core.gram import gram
from repro.ingest.reader import open_chunk_source
from repro.obs import trace as obs_trace

from .iteration import IterationRecorder
from .registry import DecompState, MethodSpec, make_state, register_method

Array = jax.Array

# Chunks are padded to a multiple of this so the per-chunk jitted MTTKRP
# compiles for at most a couple of distinct shapes per source.
_CHUNK_PAD = 4096

# COO-consuming impls only: chunks arrive unsorted and are never CSF-built.
_STREAM_IMPLS = ("gather_scatter",)


def cp_als_streaming(
    source,
    rank: int,
    *,
    niters: int = 20,
    tol: float = 0.0,
    impl: str = "gather_scatter",
    plan=None,
    decay: float = 1.0,
    chunk_nnz: int = 1 << 20,
    n_chunks: Optional[int] = None,
    dims=None,
    key: Array | None = None,
    verbose: bool = False,
    first_norm: str = "max",
    state: DecompState | None = None,
    checkpoint_cb: Callable[[DecompState], None] | None = None,
    monitor=None,
) -> CPDecomp:
    """Online CP-ALS over a chunk source.

    ``source``: a ``.tns``/``.tnsb`` path, a :class:`SparseTensor` (split
    into ``n_chunks`` / ``chunk_nnz``-sized pieces), or a list of same-dims
    chunks.  ``dims`` forwards to the text reader (skips the scan pass).

    ``decay``: per-chunk exponential weight of the MTTKRP fold (1 = plain
    sum, numerically the batch iteration; <1 discounts older chunks of a
    time-ordered stream).  ``tol``/``state``/``checkpoint_cb`` as in
    :func:`repro.methods.cp_als.cp_als` — the fold lives within one pass,
    so resume needs no accumulator state.
    """
    if key is None:
        key = jax.random.PRNGKey(0)
    if not 0.0 < decay <= 1.0:
        raise ValueError(f"decay must be in (0, 1], got {decay}")
    if impl not in _STREAM_IMPLS:
        raise ValueError(
            f"cp_als_streaming executes COO chunk reductions only "
            f"({_STREAM_IMPLS}); impl {impl!r} needs a sorted workspace, "
            "which streaming never builds")
    if plan is not None and not set(plan.impls) <= set(_STREAM_IMPLS):
        raise ValueError(
            f"cp_als_streaming cannot execute plan {plan.summary()!r}: "
            f"chunk reductions express only {_STREAM_IMPLS}")

    src = open_chunk_source(source, dims=dims, chunk_nnz=chunk_nnz,
                            n_chunks=n_chunks)
    dims = src.dims
    order = len(dims)
    dtype = None

    # one accumulation pass for ||X||^2 (cheap: values only)
    norm_x_sq = 0.0
    for chunk in src:
        norm_x_sq += float(jnp.sum(chunk.vals.astype(jnp.float32) ** 2))
        dtype = chunk.vals.dtype
    norm_x_sq = jnp.asarray(norm_x_sq, dtype=jnp.float32)

    if state is None:
        factors = init_factors(dims, rank, key, dtype=dtype)
        lmbda = jnp.ones((rank,), dtype=dtype)
        fit = jnp.array(0.0, dtype=dtype)
        fit_prev = jnp.array(0.0, dtype=dtype)
        start_iter = 0
    else:
        factors = tuple(state.factors)
        lmbda = state.aux["lmbda"]
        # compare the next fit against the last COMPUTED one (see cp_als)
        fit, fit_prev = state.fit, state.fit
        start_iter = int(state.iteration)

    factors = list(factors)
    grams = [gram(a) for a in factors]

    def _mode_mttkrp(n: int) -> Array:
        """Exponentially weighted fold of per-chunk MTTKRP partials for mode
        ``n`` (one source pass): acc <- decay * acc + partial.  decay == 1
        is the plain (batch-exact) sum; padding entries scatter exact zeros,
        so padded chunks are no-ops."""
        acc = None
        for chunk in src:
            part = _jit_mttkrp(chunk.pad_to(_CHUNK_PAD), tuple(factors),
                               mode=n, impl="gather_scatter")
            if acc is None:
                acc = part
            elif decay == 1.0:
                acc = acc + part
            else:
                acc = decay * acc + part
        if acc is None:
            raise ValueError("chunk source yielded no chunks")
        return acc

    recorder = IterationRecorder("cp_als_streaming", monitor=monitor,
                                 verbose=verbose)
    for it in range(start_iter, niters):
        norm_kind = first_norm if it == 0 else "2"
        with recorder.iteration(it):
            m_last = None
            for n in range(order):
                # the chunk fold is host-driven (one source pass), so its
                # span duration is honest; the dense epilogue spans time
                # the dispatches only — no extra sync is added here
                with obs_trace.span("mttkrp", mode=n, impl="gather_scatter",
                                    chunked=True):
                    m_new = _mode_mttkrp(n)
                with obs_trace.span("epilogue", mode=n):
                    v = _jit_hadamard(tuple(grams), mode=n)
                    a_new = _jit_solve(m_new, v)
                    a_new, lmbda = _jit_normalize(a_new, kind=norm_kind)
                    grams[n] = _jit_gram(a_new)
                factors[n] = a_new
                m_last = m_new
            with obs_trace.span("fit"):
                fit = _jit_fit(norm_x_sq, lmbda, tuple(grams), m_last,
                               factors[-1])
        delta = recorder.progress(it, fit, fit_prev)
        if delta < 0.0 and it > start_iter and obs_trace.tracing():
            # a fit DROP on a streaming fold is the drift signal (the
            # evolving target moved under the factors) — surface it as a
            # gauge + counter and a flight-recorder event
            from repro.obs.metrics import get_registry
            from repro.obs.recorder import record_event

            registry = get_registry()
            registry.gauge("stream.fit_drop").set(-delta)
            registry.counter("stream.fit_drops").inc()
            record_event("stream.drift", i=int(it), drop=-delta,
                         fit=float(fit))
        if checkpoint_cb is not None:
            checkpoint_cb(make_state(factors, {"lmbda": lmbda}, fit,
                                     fit_prev, it + 1))
        if tol > 0.0 and it > 0 and abs(delta) < tol:
            fit_prev = fit
            break
        fit_prev = fit

    return CPDecomp(factors=tuple(factors), lmbda=lmbda, fit=fit)


register_method(MethodSpec(
    name="cp_als_streaming",
    fn=cp_als_streaming,
    family="cp",
    kernel="mttkrp",
    supports_dist=False,   # the shard_map body owns a static partition; a
                           # chunk stream has no stable device ownership
    supports_streaming=True,
    nonnegative=False,
    supports_order_gt3=True,
    monotone_fit=True,     # holds for the default decay == 1 (batch-exact)
                           # fold; decay < 1 tracks an evolving target and
                           # voids the guarantee
    state_aux=("lmbda",),
    description="online CP-ALS over ingest.reader chunk batches with "
                "exponentially weighted MTTKRP accumulators",
))
