"""CP-ALS driver — Algorithm 1 of the paper, faithfully.

Per iteration, for each mode n (in order, 3rd-order shown; arbitrary order
supported):

    V      = hadamard_{m != n} (A_m^T A_m)          Mat A^TA (of other modes)
    M      = MTTKRP(X, factors, n)                  MTTKRP
    A_n    = M V^{-1}  (Cholesky)                   Inverse
    A_n, l = column-normalize(A_n)                  Mat norm  (max-norm on
                                                    iter 0, 2-norm after —
                                                    SPLATT's schedule)
    G_n    = A_n^T A_n
    fit    = 1 - ||X - X_hat|| / ||X||              CPD fit (via the
                                                    work-free inner-product
                                                    trick on the last mode)

The driver runs a python loop over iterations with a fused, jitted iteration
body; with ``timers=`` it instead calls one jitted function per routine and
accumulates wall-clock per routine — reproducing the paper's Table III
per-routine breakdown.  The pre-processing "Sort" stage (CSF build) is timed
under the same key the paper uses.

State is an explicit pytree (:class:`CPALSState`) so long decompositions can
be checkpointed/restored mid-run (see repro.checkpoint) — iteration index,
factors, lambda and previous fit fully determine the computation.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .gram import (gram, hadamard_grams, solve_cholesky, normalize,
                   kruskal_fit)
from .coo import SparseTensor
from .csf import CSF, build_csf
from .mttkrp import mttkrp

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CPDecomp:
    """Result: X ~ sum_r lambda_r * outer(A_1[:,r], ..., A_N[:,r])."""

    factors: tuple[Array, ...]
    lmbda: Array
    fit: Array

    def tree_flatten(self):
        return (self.factors, self.lmbda, self.fit), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        factors, lmbda, fit = children
        return cls(factors=tuple(factors), lmbda=lmbda, fit=fit)

    @property
    def rank(self) -> int:
        return int(self.factors[0].shape[1])

    def values_at(self, inds: Array) -> Array:
        """Reconstructed entries at coordinate list (n, order)."""
        prod = jnp.broadcast_to(
            self.lmbda[None, :], (inds.shape[0], self.lmbda.shape[0])
        )
        for m, a in enumerate(self.factors):
            prod = prod * a[inds[:, m]]
        return jnp.sum(prod, axis=1)

    def to_dense(self, dims: Sequence[int] | None = None) -> Array:
        """Densify (tests only)."""
        order = len(self.factors)
        letters = "abcdefgh"[:order]
        eq = ",".join(f"{c}r" for c in letters) + ",r->" + letters
        return jnp.einsum(eq, *self.factors, self.lmbda)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CPALSState:
    """Checkpointable mid-run state of the ALS loop."""

    factors: tuple[Array, ...]
    lmbda: Array
    fit: Array
    fit_prev: Array
    iteration: Array  # int32 scalar

    def tree_flatten(self):
        return (
            self.factors,
            self.lmbda,
            self.fit,
            self.fit_prev,
            self.iteration,
        ), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        factors, lmbda, fit, fit_prev, iteration = children
        return cls(tuple(factors), lmbda, fit, fit_prev, iteration)


# ---------------------------------------------------------------------------
# workspace: per-mode prebuilt layouts (the paper's "Sort" stage)
# ---------------------------------------------------------------------------


def resolve_plan(t: SparseTensor, impl: str, plan, *, rank: int = 16,
                 block: int = 512, row_tile: int = 128):
    """Resolve the (impl=, plan=) pair every driver accepts into a DecompPlan.

    ``plan`` wins when given; otherwise the planner runs with ``impl`` as the
    policy ("auto" selects per mode from stats; a concrete name pins it with
    the stats pass skipped — the legacy zero-overhead path)."""
    if plan is not None:
        return plan
    from repro.plan import plan_decomposition

    return plan_decomposition(t, impl, rank=rank, block=block,
                              row_tile=row_tile,
                              with_stats=impl == "auto")


def build_workspace(
    t: SparseTensor,
    plan,
    *,
    block: int = 512,
    row_tile: int = 128,
):
    """One prebuilt structure per mode (SPLATT ALLMODE policy).

    ``plan`` is a :class:`repro.plan.DecompPlan` (each mode gets the layout
    its planned impl consumes: the unified CSF workspace or raw COO) or, for
    backwards compatibility, an impl-name string."""
    if isinstance(plan, str):
        from repro.plan import plan_decomposition

        plan = plan_decomposition(t, plan, block=block, row_tile=row_tile,
                                  with_stats=plan == "auto")
    return [
        build_csf(t, p.mode, block=p.block, row_tile=p.row_tile)
        if p.layout == "csf" else t
        for p in plan.modes
    ]


# ---------------------------------------------------------------------------
# single-mode update + fused iteration
# ---------------------------------------------------------------------------


def init_factors(
    dims: Sequence[int], rank: int, key: Array, dtype=jnp.float32
) -> tuple[Array, ...]:
    keys = jax.random.split(key, len(dims))
    return tuple(
        jax.random.uniform(k, (int(d), rank), dtype=dtype)
        for k, d in zip(keys, dims)
    )


def _mode_update(ws_n, factors, grams, mode: int, impl: str, norm_kind: str):
    v = hadamard_grams(grams, mode)
    m_mat = mttkrp(ws_n, factors, mode, impl=impl)
    a_new = solve_cholesky(m_mat, v)
    a_new, lam = normalize(a_new, kind=norm_kind)
    g_new = gram(a_new)
    return a_new, g_new, lam, m_mat


@partial(jax.jit, static_argnames=("impls", "norm_kind", "with_fit"))
def _iteration(ws, factors, grams, norm_x_sq, *, impls, norm_kind,
               with_fit=True):
    """One fused ALS iteration; ``impls`` is the plan's per-mode impl tuple."""
    factors = list(factors)
    grams = list(grams)
    lam = None
    m_last = None
    order = len(factors)
    for n in range(order):
        factors[n], grams[n], lam, m_last = _mode_update(
            ws[n], factors, grams, n, impls[n], norm_kind
        )
    if with_fit:
        fit = kruskal_fit(norm_x_sq, lam, grams, m_last, factors[-1])
    else:
        fit = jnp.array(0.0, dtype=factors[0].dtype)
    return tuple(factors), tuple(grams), lam, fit


# ---------------------------------------------------------------------------
# timed per-routine path (paper Table III)
# ---------------------------------------------------------------------------

ROUTINES = ("sort", "mttkrp", "ata", "inverse", "norm", "fit")


def _timed(timers, key, fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    out = jax.block_until_ready(out)
    timers[key] = timers.get(key, 0.0) + (time.perf_counter() - t0)
    return out


@partial(jax.jit, static_argnames=("mode", "impl"))
def _jit_mttkrp(ws_n, factors, *, mode, impl):
    return mttkrp(ws_n, factors, mode, impl=impl)


@partial(jax.jit, static_argnames=("mode",))
def _jit_hadamard(grams, *, mode):
    return hadamard_grams(grams, mode)


_jit_solve = jax.jit(solve_cholesky)
_jit_gram = jax.jit(gram)
_jit_normalize = jax.jit(normalize, static_argnames=("kind",))
_jit_fit = jax.jit(kruskal_fit)


def _iteration_timed(ws, factors, grams, norm_x_sq, timers, *, impls, norm_kind):
    factors = list(factors)
    grams = list(grams)
    lam = m_last = None
    for n in range(len(factors)):
        v = _timed(timers, "ata", _jit_hadamard, tuple(grams), mode=n)
        m_mat = _timed(timers, "mttkrp", _jit_mttkrp, ws[n], tuple(factors), mode=n, impl=impls[n])
        a_new = _timed(timers, "inverse", _jit_solve, m_mat, v)
        a_new, lam = _timed(timers, "norm", _jit_normalize, a_new, kind=norm_kind)
        grams[n] = _timed(timers, "ata", _jit_gram, a_new)
        factors[n] = a_new
        m_last = m_mat
    fit = _timed(
        timers, "fit", _jit_fit, norm_x_sq, lam, tuple(grams), m_last, factors[-1]
    )
    return tuple(factors), tuple(grams), lam, fit


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def cp_als(
    t: SparseTensor,
    rank: int,
    *,
    niters: int = 20,
    tol: float = 0.0,
    impl: str = "segment",
    plan=None,
    key: Array | None = None,
    block: int | None = None,
    row_tile: int | None = None,
    timers: dict | None = None,
    verbose: bool = False,
    first_norm: str = "max",
    state: CPALSState | None = None,
    checkpoint_cb: Callable[[CPALSState], None] | None = None,
) -> CPDecomp:
    """Run CP-ALS per Algorithm 1.

    tol == 0 reproduces the paper's fixed-20-iteration experiments; tol > 0
    stops when |fit - fit_prev| < tol (the "fit ceases to improve" branch).
    ``state``/``checkpoint_cb`` give restartable long decompositions.

    Execution strategy: ``impl`` is a planner policy — ``"auto"`` selects an
    MTTKRP implementation *per mode* from measured tensor statistics (the
    paper's §V-D regime rules), any registered name pins all modes.  Pass a
    prebuilt ``plan`` (:class:`repro.plan.DecompPlan`) to skip planning.

    ``t`` may also be a :class:`repro.ingest.Ingested` handle: planning then
    reuses the stats measured at ingest, workspaces come from the ingest
    cache when warm (skipping the sort entirely), and the returned factors
    are mapped back to the tensor's ORIGINAL labels through the handle's
    inverse relabeling.  (``state``/``checkpoint_cb`` operate in the
    relabeled space.)
    """
    if key is None:
        key = jax.random.PRNGKey(0)

    ing = None
    if not isinstance(t, SparseTensor):
        from repro.ingest import Ingested

        if not isinstance(t, Ingested):
            raise TypeError(
                f"cp_als takes a SparseTensor or repro.ingest.Ingested, "
                f"got {type(t).__name__}")
        ing = t
        t = ing.tensor
        # the ingest-time tile geometry is authoritative; an explicit
        # conflicting request must fail loudly, not be silently ignored
        for name, asked, have in (("block", block, ing.block),
                                  ("row_tile", row_tile, ing.row_tile)):
            if asked is not None and asked != have:
                raise ValueError(
                    f"cp_als was asked for {name}={asked} but this tensor "
                    f"was ingested with {name}={have}; re-ingest with "
                    "tile=(block, row_tile) instead")
    if block is None:
        block = 512
    if row_tile is None:
        row_tile = 128

    # --- Plan + Sort / CSF build (paper's pre-processing stage: the stats
    # pass and the workspace sort are both host-side, per-mode O(nnz) work,
    # timed together under the paper's "Sort" key; with an Ingested handle
    # both stages may be pure cache reads) ---
    def _plan_and_build():
        if ing is not None:
            p = plan if plan is not None else ing.plan(impl, rank=rank)
            return p, ing.workspace(p)
        p = resolve_plan(t, impl, plan, rank=rank, block=block,
                         row_tile=row_tile)
        return p, build_workspace(t, p)

    if timers is not None:
        plan, ws = _timed(timers, "sort", _plan_and_build)
    else:
        plan, ws = _plan_and_build()
    impls = plan.impls

    norm_x_sq = jnp.sum(t.vals.astype(jnp.float32) ** 2)

    if state is None:
        factors = init_factors(t.dims, rank, key, dtype=t.vals.dtype)
        lmbda = jnp.ones((rank,), dtype=t.vals.dtype)
        fit = jnp.array(0.0, dtype=t.vals.dtype)
        fit_prev = jnp.array(0.0, dtype=t.vals.dtype)
        start_iter = 0
    else:
        factors = tuple(state.factors)
        lmbda, fit, fit_prev = state.lmbda, state.fit, state.fit_prev
        start_iter = int(state.iteration)

    grams = tuple(gram(a) for a in factors)

    for it in range(start_iter, niters):
        norm_kind = first_norm if it == 0 else "2"
        if timers is not None:
            factors, grams, lmbda, fit = _iteration_timed(
                ws, factors, grams, norm_x_sq, timers, impls=impls, norm_kind=norm_kind
            )
        else:
            factors, grams, lmbda, fit = _iteration(
                ws, tuple(factors), grams, norm_x_sq, impls=impls, norm_kind=norm_kind
            )
        if verbose:
            print(f"  its = {it + 1}  fit = {float(fit):.6f}  "
                  f"delta = {float(fit - fit_prev):+.3e}")
        if checkpoint_cb is not None:
            checkpoint_cb(
                CPALSState(
                    tuple(factors), lmbda, fit, fit_prev,
                    jnp.array(it + 1, dtype=jnp.int32),
                )
            )
        if tol > 0.0 and it > 0 and abs(float(fit) - float(fit_prev)) < tol:
            fit_prev = fit
            break
        fit_prev = fit

    decomp = CPDecomp(factors=tuple(factors), lmbda=lmbda, fit=fit)
    if ing is not None:
        decomp = ing.restore(decomp)
    return decomp
