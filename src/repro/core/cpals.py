"""CP-ALS driver — Algorithm 1 of the paper, faithfully.

Per iteration, for each mode n (in order, 3rd-order shown; arbitrary order
supported):

    V      = hadamard_{m != n} (A_m^T A_m)          Mat A^TA (of other modes)
    M      = MTTKRP(X, factors, n)                  MTTKRP
    A_n    = M V^{-1}  (Cholesky)                   Inverse
    A_n, l = column-normalize(A_n)                  Mat norm  (max-norm on
                                                    iter 0, 2-norm after —
                                                    SPLATT's schedule)
    G_n    = A_n^T A_n
    fit    = 1 - ||X - X_hat|| / ||X||              CPD fit (via the
                                                    work-free inner-product
                                                    trick on the last mode)

The driver runs a python loop over iterations with a fused, jitted iteration
body; with ``timers=`` it instead calls one jitted function per routine and
accumulates wall-clock per routine — reproducing the paper's Table III
per-routine breakdown.  The pre-processing "Sort" stage (CSF build) is timed
under the same key the paper uses.

State is an explicit pytree (:class:`CPALSState`) so long decompositions can
be checkpointed/restored mid-run (see repro.checkpoint) — iteration index,
factors, lambda and previous fit fully determine the computation.
"""
from __future__ import annotations

import dataclasses
import time
from functools import lru_cache, partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.obs import trace as obs_trace

from .gram import (gram, hadamard_grams, solve_cholesky, solve_gram, normalize,
                   kruskal_fit)
from .coo import SparseTensor
from .csf import CSF, build_csf
from .mttkrp import mttkrp

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CPDecomp:
    """Result: X ~ sum_r lambda_r * outer(A_1[:,r], ..., A_N[:,r])."""

    factors: tuple[Array, ...]
    lmbda: Array
    fit: Array

    def tree_flatten(self):
        return (self.factors, self.lmbda, self.fit), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        factors, lmbda, fit = children
        return cls(factors=tuple(factors), lmbda=lmbda, fit=fit)

    @property
    def rank(self) -> int:
        return int(self.factors[0].shape[1])

    def values_at(self, inds: Array) -> Array:
        """Reconstructed entries at coordinate list (n, order)."""
        prod = jnp.broadcast_to(
            self.lmbda[None, :], (inds.shape[0], self.lmbda.shape[0])
        )
        for m, a in enumerate(self.factors):
            prod = prod * a[inds[:, m]]
        return jnp.sum(prod, axis=1)

    def to_dense(self, dims: Sequence[int] | None = None) -> Array:
        """Densify (tests only)."""
        order = len(self.factors)
        letters = "abcdefgh"[:order]
        eq = ",".join(f"{c}r" for c in letters) + ",r->" + letters
        return jnp.einsum(eq, *self.factors, self.lmbda)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CPALSState:
    """Checkpointable mid-run state of the ALS loop."""

    factors: tuple[Array, ...]
    lmbda: Array
    fit: Array
    fit_prev: Array
    iteration: Array  # int32 scalar

    def tree_flatten(self):
        return (
            self.factors,
            self.lmbda,
            self.fit,
            self.fit_prev,
            self.iteration,
        ), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        factors, lmbda, fit, fit_prev, iteration = children
        return cls(tuple(factors), lmbda, fit, fit_prev, iteration)


# ---------------------------------------------------------------------------
# workspace: per-mode prebuilt layouts (the paper's "Sort" stage)
# ---------------------------------------------------------------------------


def resolve_plan(t: SparseTensor, impl: str, plan, *, rank: int = 16,
                 block: int = 512, row_tile: int = 128):
    """Resolve the (impl=, plan=) pair every driver accepts into a DecompPlan.

    ``plan`` wins when given; otherwise the planner runs with ``impl`` as the
    policy ("auto" selects per mode from stats; a concrete name pins it with
    the stats pass skipped — the legacy zero-overhead path)."""
    if plan is not None:
        return plan
    from repro.plan import plan_decomposition

    return plan_decomposition(t, impl, rank=rank, block=block,
                              row_tile=row_tile,
                              with_stats=impl == "auto")


def build_workspace(
    t: SparseTensor,
    plan,
    *,
    block: int = 512,
    row_tile: int = 128,
):
    """One prebuilt structure per mode (SPLATT ALLMODE policy).

    ``plan`` is a :class:`repro.plan.DecompPlan` (each mode gets the layout
    its planned impl consumes: the unified CSF workspace, the mode-agnostic
    linearized workspace, or raw COO) or, for backwards compatibility, an
    impl-name string.  All ``"lin"`` modes share ONE
    :class:`~repro.core.linearized.Linearized` object — the format's whole
    point is a single resident buffer (and a single sort) for every mode."""
    if isinstance(plan, str):
        from repro.plan import plan_decomposition

        plan = plan_decomposition(t, plan, block=block, row_tile=row_tile,
                                  with_stats=plan == "auto")
    lin = None
    ws = []
    for p in plan.modes:
        if p.layout == "csf":
            ws.append(build_csf(t, p.mode, block=p.block,
                                row_tile=p.row_tile))
        elif p.layout == "lin":
            if lin is None:
                from .linearized import build_linearized

                lin = build_linearized(t, block=p.block,
                                       row_tile=p.row_tile)
            ws.append(lin)
        else:
            ws.append(t)
    return ws


# ---------------------------------------------------------------------------
# single-mode update + fused iteration
# ---------------------------------------------------------------------------


def init_factors(
    dims: Sequence[int], rank: int, key: Array, dtype=jnp.float32
) -> tuple[Array, ...]:
    keys = jax.random.split(key, len(dims))
    return tuple(
        jax.random.uniform(k, (int(d), rank), dtype=dtype)
        for k, d in zip(keys, dims)
    )


def _mode_update(ws_n, factors, grams, mode: int, impl: str, norm_kind: str):
    m_mat = mttkrp(ws_n, factors, mode, impl=impl)
    factors, grams, lam, _ = _mode_epilogue(
        m_mat, tuple(factors), tuple(grams),
        jnp.array(0.0, dtype=factors[0].dtype),
        mode=mode, norm_kind=norm_kind, with_fit=False)
    return factors[mode], grams[mode], lam, m_mat


def _mode_epilogue(m_mat, factors, grams, norm_x_sq, *, mode: int,
                   norm_kind: str, with_fit: bool):
    """Everything after one mode's MTTKRP, as one traceable function: the
    gram-hadamard, the Cholesky solve, the column normalization, the gram
    refresh — and, when ``with_fit`` (the last mode), the work-free fit.

    This is the chain the per-routine driver used to run as five separate
    jitted calls with a host sync between each; fused under one jit the
    intermediates (V, the un-normalized A_n, the column norms) never leave
    the device and XLA fuses the small matrix ops end-to-end.  Returns the
    *full* updated ``(factors, grams, lam, fit)`` tuples so the factor
    buffers can be donated across calls (see :func:`fused_mode_epilogue`)."""
    v = hadamard_grams(grams, mode)
    # solve_gram, not solve_cholesky: inside the fused trace the GEMM
    # formulation is what makes the collapsed chain beat the per-routine
    # driver on CPU (cho_solve with I right-hand sides is scalar there)
    a_new = solve_gram(m_mat, v)
    a_new, lam = normalize(a_new, kind=norm_kind)
    g_new = gram(a_new)
    factors = tuple(a_new if m == mode else f for m, f in enumerate(factors))
    grams = tuple(g_new if m == mode else g for m, g in enumerate(grams))
    if with_fit:
        fit = kruskal_fit(norm_x_sq, lam, grams, m_mat, factors[-1])
    else:
        # No fit was computed: return NaN, not a fake 0.0 that downstream
        # reports would read as "converged to fit 0".  The driver keeps the
        # last *computed* fit (previous iteration / restored state) instead.
        fit = jnp.array(jnp.nan, dtype=factors[0].dtype)
    return factors, grams, lam, fit


def donate_buffers() -> bool:
    """Whether factor/gram buffer donation is worth requesting: jax only
    implements input-output aliasing on TPU/GPU — on CPU it is ignored with
    a warning per call site, so we don't ask."""
    return jax.default_backend() in ("tpu", "gpu")


@lru_cache(maxsize=None)
def _fused_epilogue_jit(donate: bool):
    return jax.jit(
        _mode_epilogue,
        static_argnames=("mode", "norm_kind", "with_fit"),
        donate_argnums=(1, 2) if donate else ())


def fused_mode_epilogue(m_mat, factors, grams, norm_x_sq, *, mode: int,
                        norm_kind: str, with_fit: bool = False,
                        donate: Optional[bool] = None):
    """One jitted call for a mode's whole post-MTTKRP update.

    ``donate`` (default: backend-resolved — :func:`donate_buffers`) hands
    the incoming factor/gram buffers to XLA for in-place reuse; callers must
    treat the inputs as consumed and keep only the returned tuples."""
    if donate is None:
        donate = donate_buffers()
    return _fused_epilogue_jit(donate)(
        m_mat, tuple(factors), tuple(grams), norm_x_sq,
        mode=mode, norm_kind=norm_kind, with_fit=with_fit)


def _iteration_impl(ws, factors, grams, norm_x_sq, *, impls, norm_kind,
                    with_fit=True):
    factors = tuple(factors)
    grams = tuple(grams)
    lam = None
    fit = jnp.array(jnp.nan, dtype=factors[0].dtype)
    order = len(factors)
    for n in range(order):
        m_mat = mttkrp(ws[n], factors, n, impl=impls[n])
        factors, grams, lam, fit = _mode_epilogue(
            m_mat, factors, grams, norm_x_sq, mode=n, norm_kind=norm_kind,
            with_fit=with_fit and n == order - 1)
    return factors, grams, lam, fit


@lru_cache(maxsize=None)
def _iteration_jit(donate: bool):
    return jax.jit(
        _iteration_impl,
        static_argnames=("impls", "norm_kind", "with_fit"),
        donate_argnums=(1, 2) if donate else ())


def _iteration(ws, factors, grams, norm_x_sq, *, impls, norm_kind,
               with_fit=True, donate=False):
    """One fused ALS iteration; ``impls`` is the plan's per-mode impl tuple.

    ``donate=True`` (the method drivers pass :func:`donate_buffers`) donates
    the factor/gram buffers to the jitted body — zero-copy factor updates on
    TPU/GPU; the caller must drop its references to the inputs."""
    return _iteration_jit(bool(donate))(
        ws, tuple(factors), tuple(grams), norm_x_sq,
        impls=impls, norm_kind=norm_kind, with_fit=with_fit)


# ---------------------------------------------------------------------------
# timed per-routine path (paper Table III)
# ---------------------------------------------------------------------------

ROUTINES = ("sort", "mttkrp", "ata", "inverse", "norm", "fit")
# the fused path collapses ata/inverse/norm/fit into one jitted call, timed
# under a single key (bench_cpals_routines reports it as epilogue_s)
ROUTINES_FUSED = ("sort", "mttkrp", "epilogue")
# the routines that make up the per-mode post-MTTKRP chain — the "epilogue"
# subtotal the fused path is measured against
EPILOGUE_ROUTINES = ("ata", "inverse", "norm", "fit")


def _timed(timers, key, fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    out = jax.block_until_ready(out)
    timers[key] = timers.get(key, 0.0) + (time.perf_counter() - t0)
    return out


@partial(jax.jit, static_argnames=("mode", "impl"))
def _jit_mttkrp(ws_n, factors, *, mode, impl):
    return mttkrp(ws_n, factors, mode, impl=impl)


@partial(jax.jit, static_argnames=("mode",))
def _jit_hadamard(grams, *, mode):
    return hadamard_grams(grams, mode)


_jit_solve = jax.jit(solve_cholesky)
_jit_gram = jax.jit(gram)
_jit_normalize = jax.jit(normalize, static_argnames=("kind",))
_jit_fit = jax.jit(kruskal_fit)


def _iteration_timed(ws, factors, grams, norm_x_sq, timers, *, impls,
                     norm_kind, with_fit=True, fused=False):
    """Per-routine timed iteration (paper Table III).

    ``fused=False`` times each routine as its own jitted call with a host
    sync in between — the historical breakdown.  ``fused=True`` times the
    MTTKRP per mode and the whole post-MTTKRP chain as ONE jitted
    ``fused_mode_epilogue`` call under the ``"epilogue"`` key — what the
    fused path actually executes, so the two variants' timer totals are the
    honest before/after of the fusion."""
    if fused:
        factors = tuple(factors)
        grams = tuple(grams)
        lam = None
        fit = jnp.array(jnp.nan, dtype=factors[0].dtype)
        order = len(factors)
        for n in range(order):
            with obs_trace.span("mttkrp", mode=n, impl=impls[n]):
                m_mat = _timed(timers, "mttkrp", _jit_mttkrp, ws[n], factors,
                               mode=n, impl=impls[n])
            with obs_trace.span("epilogue", mode=n):
                factors, grams, lam, fit = _timed(
                    timers, "epilogue", fused_mode_epilogue, m_mat, factors,
                    grams, norm_x_sq, mode=n, norm_kind=norm_kind,
                    with_fit=with_fit and n == order - 1)
        return factors, grams, lam, fit
    factors = list(factors)
    grams = list(grams)
    lam = m_last = None
    for n in range(len(factors)):
        with obs_trace.span("ata", mode=n):
            v = _timed(timers, "ata", _jit_hadamard, tuple(grams), mode=n)
        with obs_trace.span("mttkrp", mode=n, impl=impls[n]):
            m_mat = _timed(timers, "mttkrp", _jit_mttkrp, ws[n],
                           tuple(factors), mode=n, impl=impls[n])
        with obs_trace.span("inverse", mode=n):
            a_new = _timed(timers, "inverse", _jit_solve, m_mat, v)
        with obs_trace.span("norm", mode=n):
            a_new, lam = _timed(timers, "norm", _jit_normalize, a_new,
                                kind=norm_kind)
        with obs_trace.span("ata", mode=n):
            grams[n] = _timed(timers, "ata", _jit_gram, a_new)
        factors[n] = a_new
        m_last = m_mat
    if with_fit:
        with obs_trace.span("fit"):
            fit = _timed(timers, "fit", _jit_fit, norm_x_sq, lam,
                         tuple(grams), m_last, factors[-1])
    else:
        # skipped entirely: no fit work done, no "fit" seconds charged
        fit = jnp.array(jnp.nan, dtype=factors[0].dtype)
    return tuple(factors), tuple(grams), lam, fit


# ---------------------------------------------------------------------------
# driver — the ALS loop itself lives behind the method registry
# (repro.methods.cp_als); this thin re-export keeps the historical
# ``repro.core.cp_als`` entry point working unchanged, with a once-per-
# process DeprecationWarning pointing at the repro.api front door.
# ---------------------------------------------------------------------------

_warned_legacy = False


def _warn_legacy_entry() -> None:
    global _warned_legacy
    if not _warned_legacy:
        import warnings

        warnings.warn(
            "repro.core.cp_als is a legacy entry point; new code should go "
            "through repro.api (Session / run(RunConfig)) or "
            "repro.methods.fit(..., method='cp_als')",
            DeprecationWarning, stacklevel=3)
        _warned_legacy = True


def cp_als(t, rank: int, **kwargs) -> CPDecomp:
    """Run CP-ALS per Algorithm 1 (see :func:`repro.methods.cp_als.cp_als`,
    which owns the iteration loop behind the decomposition-method registry).

    .. deprecated:: use :func:`repro.api.run` / ``repro.methods.fit`` —
       this wrapper stays for the historical call sites and warns once per
       process.

    Lazy import: ``repro.methods`` imports this module for the iteration
    machinery (:func:`_iteration`, the state pytrees), so the dependency is
    only taken at call time."""
    from repro.methods.cp_als import cp_als as _cp_als

    _warn_legacy_entry()
    return _cp_als(t, rank, **kwargs)
