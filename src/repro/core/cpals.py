"""CP-ALS driver — Algorithm 1 of the paper, faithfully.

Per iteration, for each mode n (in order, 3rd-order shown; arbitrary order
supported):

    V      = hadamard_{m != n} (A_m^T A_m)          Mat A^TA (of other modes)
    M      = MTTKRP(X, factors, n)                  MTTKRP
    A_n    = M V^{-1}  (Cholesky)                   Inverse
    A_n, l = column-normalize(A_n)                  Mat norm  (max-norm on
                                                    iter 0, 2-norm after —
                                                    SPLATT's schedule)
    G_n    = A_n^T A_n
    fit    = 1 - ||X - X_hat|| / ||X||              CPD fit (via the
                                                    work-free inner-product
                                                    trick on the last mode)

The driver runs a python loop over iterations with a fused, jitted iteration
body; with ``timers=`` it instead calls one jitted function per routine and
accumulates wall-clock per routine — reproducing the paper's Table III
per-routine breakdown.  The pre-processing "Sort" stage (CSF build) is timed
under the same key the paper uses.

State is an explicit pytree (:class:`CPALSState`) so long decompositions can
be checkpointed/restored mid-run (see repro.checkpoint) — iteration index,
factors, lambda and previous fit fully determine the computation.
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .gram import (gram, hadamard_grams, solve_cholesky, normalize,
                   kruskal_fit)
from .coo import SparseTensor
from .csf import CSF, build_csf
from .mttkrp import mttkrp

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CPDecomp:
    """Result: X ~ sum_r lambda_r * outer(A_1[:,r], ..., A_N[:,r])."""

    factors: tuple[Array, ...]
    lmbda: Array
    fit: Array

    def tree_flatten(self):
        return (self.factors, self.lmbda, self.fit), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        factors, lmbda, fit = children
        return cls(factors=tuple(factors), lmbda=lmbda, fit=fit)

    @property
    def rank(self) -> int:
        return int(self.factors[0].shape[1])

    def values_at(self, inds: Array) -> Array:
        """Reconstructed entries at coordinate list (n, order)."""
        prod = jnp.broadcast_to(
            self.lmbda[None, :], (inds.shape[0], self.lmbda.shape[0])
        )
        for m, a in enumerate(self.factors):
            prod = prod * a[inds[:, m]]
        return jnp.sum(prod, axis=1)

    def to_dense(self, dims: Sequence[int] | None = None) -> Array:
        """Densify (tests only)."""
        order = len(self.factors)
        letters = "abcdefgh"[:order]
        eq = ",".join(f"{c}r" for c in letters) + ",r->" + letters
        return jnp.einsum(eq, *self.factors, self.lmbda)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CPALSState:
    """Checkpointable mid-run state of the ALS loop."""

    factors: tuple[Array, ...]
    lmbda: Array
    fit: Array
    fit_prev: Array
    iteration: Array  # int32 scalar

    def tree_flatten(self):
        return (
            self.factors,
            self.lmbda,
            self.fit,
            self.fit_prev,
            self.iteration,
        ), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        factors, lmbda, fit, fit_prev, iteration = children
        return cls(tuple(factors), lmbda, fit, fit_prev, iteration)


# ---------------------------------------------------------------------------
# workspace: per-mode prebuilt layouts (the paper's "Sort" stage)
# ---------------------------------------------------------------------------


def resolve_plan(t: SparseTensor, impl: str, plan, *, rank: int = 16,
                 block: int = 512, row_tile: int = 128):
    """Resolve the (impl=, plan=) pair every driver accepts into a DecompPlan.

    ``plan`` wins when given; otherwise the planner runs with ``impl`` as the
    policy ("auto" selects per mode from stats; a concrete name pins it with
    the stats pass skipped — the legacy zero-overhead path)."""
    if plan is not None:
        return plan
    from repro.plan import plan_decomposition

    return plan_decomposition(t, impl, rank=rank, block=block,
                              row_tile=row_tile,
                              with_stats=impl == "auto")


def build_workspace(
    t: SparseTensor,
    plan,
    *,
    block: int = 512,
    row_tile: int = 128,
):
    """One prebuilt structure per mode (SPLATT ALLMODE policy).

    ``plan`` is a :class:`repro.plan.DecompPlan` (each mode gets the layout
    its planned impl consumes: the unified CSF workspace or raw COO) or, for
    backwards compatibility, an impl-name string."""
    if isinstance(plan, str):
        from repro.plan import plan_decomposition

        plan = plan_decomposition(t, plan, block=block, row_tile=row_tile,
                                  with_stats=plan == "auto")
    return [
        build_csf(t, p.mode, block=p.block, row_tile=p.row_tile)
        if p.layout == "csf" else t
        for p in plan.modes
    ]


# ---------------------------------------------------------------------------
# single-mode update + fused iteration
# ---------------------------------------------------------------------------


def init_factors(
    dims: Sequence[int], rank: int, key: Array, dtype=jnp.float32
) -> tuple[Array, ...]:
    keys = jax.random.split(key, len(dims))
    return tuple(
        jax.random.uniform(k, (int(d), rank), dtype=dtype)
        for k, d in zip(keys, dims)
    )


def _mode_update(ws_n, factors, grams, mode: int, impl: str, norm_kind: str):
    v = hadamard_grams(grams, mode)
    m_mat = mttkrp(ws_n, factors, mode, impl=impl)
    a_new = solve_cholesky(m_mat, v)
    a_new, lam = normalize(a_new, kind=norm_kind)
    g_new = gram(a_new)
    return a_new, g_new, lam, m_mat


@partial(jax.jit, static_argnames=("impls", "norm_kind", "with_fit"))
def _iteration(ws, factors, grams, norm_x_sq, *, impls, norm_kind,
               with_fit=True):
    """One fused ALS iteration; ``impls`` is the plan's per-mode impl tuple."""
    factors = list(factors)
    grams = list(grams)
    lam = None
    m_last = None
    order = len(factors)
    for n in range(order):
        factors[n], grams[n], lam, m_last = _mode_update(
            ws[n], factors, grams, n, impls[n], norm_kind
        )
    if with_fit:
        fit = kruskal_fit(norm_x_sq, lam, grams, m_last, factors[-1])
    else:
        # No fit was computed: return NaN, not a fake 0.0 that downstream
        # reports would read as "converged to fit 0".  The driver keeps the
        # last *computed* fit (previous iteration / restored state) instead.
        fit = jnp.array(jnp.nan, dtype=factors[0].dtype)
    return tuple(factors), tuple(grams), lam, fit


# ---------------------------------------------------------------------------
# timed per-routine path (paper Table III)
# ---------------------------------------------------------------------------

ROUTINES = ("sort", "mttkrp", "ata", "inverse", "norm", "fit")


def _timed(timers, key, fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    out = jax.block_until_ready(out)
    timers[key] = timers.get(key, 0.0) + (time.perf_counter() - t0)
    return out


@partial(jax.jit, static_argnames=("mode", "impl"))
def _jit_mttkrp(ws_n, factors, *, mode, impl):
    return mttkrp(ws_n, factors, mode, impl=impl)


@partial(jax.jit, static_argnames=("mode",))
def _jit_hadamard(grams, *, mode):
    return hadamard_grams(grams, mode)


_jit_solve = jax.jit(solve_cholesky)
_jit_gram = jax.jit(gram)
_jit_normalize = jax.jit(normalize, static_argnames=("kind",))
_jit_fit = jax.jit(kruskal_fit)


def _iteration_timed(ws, factors, grams, norm_x_sq, timers, *, impls,
                     norm_kind, with_fit=True):
    factors = list(factors)
    grams = list(grams)
    lam = m_last = None
    for n in range(len(factors)):
        v = _timed(timers, "ata", _jit_hadamard, tuple(grams), mode=n)
        m_mat = _timed(timers, "mttkrp", _jit_mttkrp, ws[n], tuple(factors), mode=n, impl=impls[n])
        a_new = _timed(timers, "inverse", _jit_solve, m_mat, v)
        a_new, lam = _timed(timers, "norm", _jit_normalize, a_new, kind=norm_kind)
        grams[n] = _timed(timers, "ata", _jit_gram, a_new)
        factors[n] = a_new
        m_last = m_mat
    if with_fit:
        fit = _timed(timers, "fit", _jit_fit, norm_x_sq, lam, tuple(grams),
                     m_last, factors[-1])
    else:
        # skipped entirely: no fit work done, no "fit" seconds charged
        fit = jnp.array(jnp.nan, dtype=factors[0].dtype)
    return tuple(factors), tuple(grams), lam, fit


# ---------------------------------------------------------------------------
# driver — the ALS loop itself lives behind the method registry
# (repro.methods.cp_als); this thin re-export keeps the historical
# ``repro.core.cp_als`` entry point working unchanged, with a once-per-
# process DeprecationWarning pointing at the repro.api front door.
# ---------------------------------------------------------------------------

_warned_legacy = False


def _warn_legacy_entry() -> None:
    global _warned_legacy
    if not _warned_legacy:
        import warnings

        warnings.warn(
            "repro.core.cp_als is a legacy entry point; new code should go "
            "through repro.api (Session / run(RunConfig)) or "
            "repro.methods.fit(..., method='cp_als')",
            DeprecationWarning, stacklevel=3)
        _warned_legacy = True


def cp_als(t, rank: int, **kwargs) -> CPDecomp:
    """Run CP-ALS per Algorithm 1 (see :func:`repro.methods.cp_als.cp_als`,
    which owns the iteration loop behind the decomposition-method registry).

    .. deprecated:: use :func:`repro.api.run` / ``repro.methods.fit`` —
       this wrapper stays for the historical call sites and warns once per
       process.

    Lazy import: ``repro.methods`` imports this module for the iteration
    machinery (:func:`_iteration`, the state pytrees), so the dependency is
    only taken at call time."""
    from repro.methods.cp_als import cp_als as _cp_als

    _warn_legacy_entry()
    return _cp_als(t, rank, **kwargs)
