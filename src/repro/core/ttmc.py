"""TTMc — chain-of-modes tensor-times-matrix contraction for sparse Tucker.

Where MTTKRP contracts a sparse tensor against the *Khatri-Rao* (columnwise
Hadamard) product of the other modes' factors, the Tucker/HOOI family needs
the *Kronecker* counterpart:

    Y_(n)[i, :] = sum_{nnz with i_n == i} x * kron_{m != n} U_m[i_m, :]

i.e. the mode-n matricization of ``X x_{m != n} U_m^T`` — an
(I_n, prod_{m != n} R_m) matrix whose thin SVD gives the updated HOOI factor
and whose final-mode instance recovers the core tensor (see
``repro.methods.tucker_hooi``).  Phipps & Kolda (2018) make the case that
CP and Tucker share exactly this sparse-kernel seam; structurally the TTMc
is the same semiring contraction as MTTKRP with the per-entry Hadamard
product replaced by an outer (Kronecker) product, so every reduction
strategy from ``core/mttkrp.py`` transfers:

``segment``          sorted CSF workspace + conflict-free segment-sum
                     (SPLATT's no-lock schedule).
``gather_scatter``   flat gather + scatter-add off COO or CSF (the
                     mutex/atomic regime; wins on collision-light modes).
``pallas``           the TPU one-hot segment-matmul kernel, reused verbatim:
                     the Kronecker rows are formed XLA-side and fed through
                     ``kernels.ops.ttmc`` (collisions inside a block are
                     again resolved by the MXU matmul).
``linearized``       the ALTO-style mode-agnostic workspace
                     (core/linearized.py): one bit-packed sorted stream
                     serves all modes; sort mode segment-sums, other modes
                     decode + scatter.  Pure jnp.
``linearized_pallas``  the linearized workspace on the TPU kernel with the
                     coordinate decode inside the kernel.
``dense``            dense einsum oracle (tests only).

Kronecker column order: ascending other-mode order, row-major — for a 3rd
order tensor at mode 0 the output column is ``r_1 * R_2 + r_2``.  Every impl
here and the dense oracle agree on this convention; ``repro.methods``
relies on it when reshaping the recovered core.

The impls are registered in :data:`TTMC_REGISTRY` (same :class:`ImplSpec`
shape as the MTTKRP table) with cost models in the same relative units, so
``repro.plan.plan_decomposition(..., kernel="ttmc")`` can score them per
mode exactly like it scores MTTKRP strategies.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .coo import SparseTensor
from .csf import CSF
from .linearized import Linearized
from .mttkrp import (ImplSpec, available_impls, get_impl,
                     _cost_gather_scatter, _cost_linearized,
                     _cost_linearized_pallas, _cost_pallas, _cost_segment)

Array = jax.Array


def kron_chain(rows: Sequence[Array]) -> Array:
    """Row-wise Kronecker product: [(n, R_a), (n, R_b), ...] -> (n, prod R).

    Ascending input order is the slow axis (row-major), matching the dense
    oracle's einsum output ordering.  This is THE column-order convention
    every TTMc impl (including ``kernels/ops.ttmc`` and the ``ttmc_ref``
    oracle) must share — ``repro.methods`` relies on it when un-matricizing
    the recovered Tucker core, so there is exactly one implementation."""
    out = rows[0]
    for r in rows[1:]:
        out = (out[:, :, None] * r[:, None, :]).reshape(out.shape[0], -1)
    return out


def _kron_rows_coo(t: SparseTensor, factors: Sequence[Array],
                   mode: int) -> Array:
    rows = [factors[m][t.inds[:, m]] for m in range(t.order) if m != mode]
    return t.vals[:, None].astype(factors[0].dtype) * kron_chain(rows)


def _kron_rows_csf(csf: CSF, factors: Sequence[Array]) -> Array:
    """CSF analogue (padding entries carry value 0 -> exact zero rows)."""
    rows = [factors[m][csf.other_ids[:, i]]
            for i, m in enumerate(csf.other_modes)]
    return csf.vals[:, None].astype(factors[0].dtype) * kron_chain(rows)


def ttmc_dense(t: SparseTensor, factors: Sequence[Array], mode: int) -> Array:
    """Dense oracle: densify X and contract every other mode. Tests only."""
    if isinstance(t, CSF):
        raise TypeError("dense oracle consumes COO (SparseTensor), not CSF")
    order = t.order
    letters = "abcdefgh"[:order]
    ranks = "pqrstuvw"
    others = [m for m in range(order) if m != mode]
    terms = [f"{letters[m]}{ranks[j]}" for j, m in enumerate(others)]
    eq = (f"{letters}," + ",".join(terms)
          + f"->{letters[mode]}{''.join(ranks[j] for j in range(len(others)))}")
    out = jnp.einsum(eq, t.to_dense(), *[factors[m] for m in others])
    return out.reshape(t.dims[mode], -1)


def ttmc_gather_scatter(t, factors: Sequence[Array], mode: int) -> Array:
    """Flat gather + Kronecker rows + scatter-add (COO or CSF input)."""
    if isinstance(t, CSF):
        if t.mode != mode:
            raise ValueError(f"CSF is built for mode {t.mode}, asked {mode}")
        prod = _kron_rows_csf(t, factors)
        out = jnp.zeros((t.dims[mode], prod.shape[1]), dtype=prod.dtype)
        return out.at[t.row_ids].add(prod, mode="drop")
    prod = _kron_rows_coo(t, factors, mode)
    out = jnp.zeros((t.dims[mode], prod.shape[1]), dtype=prod.dtype)
    return out.at[t.inds[:, mode]].add(prod, mode="drop")


def ttmc_segment(csf: CSF, factors: Sequence[Array],
                 mode: Optional[int] = None) -> Array:
    """Kronecker rows + sorted segment-sum over the unified CSF workspace."""
    if not isinstance(csf, CSF):
        raise TypeError("segment impl needs a CSF workspace (build_csf(t, mode))")
    if mode is not None and csf.mode != mode:
        raise ValueError(f"CSF is built for mode {csf.mode}, asked {mode}")
    prod = _kron_rows_csf(csf, factors)
    return jax.ops.segment_sum(prod, csf.row_ids, num_segments=csf.num_rows,
                               indices_are_sorted=True)


def ttmc_pallas(csf: CSF, factors: Sequence[Array],
                mode: Optional[int] = None) -> Array:
    """The TPU one-hot segment-matmul kernel over Kronecker rows
    (interpret mode off-TPU, like the MTTKRP kernel)."""
    if not isinstance(csf, CSF):
        raise TypeError("pallas impl needs a CSF workspace (build_csf(t, mode))")
    if mode is not None and csf.mode != mode:
        raise ValueError(f"CSF is built for mode {csf.mode}, asked {mode}")
    from repro.kernels import ops as kops  # local import: optional dep

    return kops.ttmc(csf, factors)


def ttmc_linearized(ws, factors: Sequence[Array], mode: int) -> Array:
    """Kronecker rows over the mode-agnostic linearized workspace (pure jnp):
    decode every mode's coordinates from the packed words, segment-sum on the
    sort mode, scatter-add elsewhere.  One resident buffer for all modes."""
    if not isinstance(ws, Linearized):
        raise TypeError(
            "linearized impls need a Linearized workspace "
            "(build_linearized(t)); got " + type(ws).__name__)
    rows_list = [factors[m][ws.decode(m)] for m in range(ws.order)
                 if m != mode]
    prod = ws.vals[:, None].astype(factors[0].dtype) * kron_chain(rows_list)
    rows = ws.decode(mode)
    if mode == ws.sort_mode:
        return jax.ops.segment_sum(prod, rows, num_segments=ws.dims[mode],
                                   indices_are_sorted=True)
    out = jnp.zeros((ws.dims[mode], prod.shape[1]), dtype=prod.dtype)
    return out.at[rows].add(prod, mode="drop")


def ttmc_linearized_pallas(ws, factors: Sequence[Array], mode: int) -> Array:
    """The linearized workspace on the TPU kernel (in-kernel decode on the
    sort mode; jnp fallback on the others; interpret mode off-TPU)."""
    if not isinstance(ws, Linearized):
        raise TypeError(
            "linearized impls need a Linearized workspace "
            "(build_linearized(t)); got " + type(ws).__name__)
    from repro.kernels import ops as kops  # local import: optional dep

    return kops.ttmc_lin(ws, factors, mode)


# ---------------------------------------------------------------------------
# the registry — scored by the planner via plan_decomposition(kernel="ttmc")
# ---------------------------------------------------------------------------
#
# Cost models are the MTTKRP ones applied at the TTMc's output width: the
# planner passes rank = prod_{m != mode} R_m, which is exactly the per-entry
# work multiplier of the Kronecker chain, so the regime constants (scatter
# serialization, padding overhead, MXU speedup) transfer unchanged.

TTMC_REGISTRY: dict[str, ImplSpec] = {}


def register_ttmc_impl(spec: ImplSpec) -> ImplSpec:
    if spec.layout not in ("csf", "coo", "lin", "any"):
        raise ValueError(f"bad layout {spec.layout!r} for impl {spec.name!r}")
    TTMC_REGISTRY[spec.name] = spec
    return spec


def get_ttmc_impl(name: str) -> ImplSpec:
    return get_impl(name, registry=TTMC_REGISTRY)


def available_ttmc_impls(**kw) -> tuple[str, ...]:
    return available_impls(registry=TTMC_REGISTRY, **kw)


register_ttmc_impl(ImplSpec(
    name="gather_scatter", fn=ttmc_gather_scatter, layout="any",
    needs_sorted=False, supports_order_gt3=True,
    cost_model=_cost_gather_scatter))
register_ttmc_impl(ImplSpec(
    name="segment", fn=ttmc_segment, layout="csf",
    needs_sorted=True, supports_order_gt3=True,
    cost_model=_cost_segment))
register_ttmc_impl(ImplSpec(
    name="pallas", fn=ttmc_pallas, layout="csf",
    needs_sorted=True, supports_order_gt3=True, backend="tpu",
    cost_model=_cost_pallas))
register_ttmc_impl(ImplSpec(
    name="linearized", fn=ttmc_linearized, layout="lin",
    needs_sorted=True, supports_order_gt3=True,
    cost_model=_cost_linearized))
register_ttmc_impl(ImplSpec(
    name="linearized_pallas", fn=ttmc_linearized_pallas, layout="lin",
    needs_sorted=True, supports_order_gt3=True, backend="tpu",
    cost_model=_cost_linearized_pallas))
register_ttmc_impl(ImplSpec(
    name="dense", fn=ttmc_dense, layout="coo",
    needs_sorted=False, supports_order_gt3=True, oracle=True))

TTMC_IMPLS = tuple(TTMC_REGISTRY)


def ttmc(x, factors: Sequence[Array], mode: int, *,
         impl: str = "segment") -> Array:
    """Dispatch a TTMc on the registry; ``x`` is a SparseTensor (COO impls)
    or the per-mode CSF workspace.  Returns (dims[mode], prod other R)."""
    if impl == "auto":
        raise ValueError(
            "impl='auto' is a planner policy; resolve it with "
            "repro.plan.plan_decomposition(kernel='ttmc') and dispatch on "
            "the per-mode plan")
    spec = get_ttmc_impl(impl)
    return spec.fn(x, factors, mode)
