"""Medium-grained distributed CP-ALS (shard_map over the production mesh).

This implements the paper's named future work — SPLATT's medium-grained
distributed algorithm [Smith & Karypis, IPDPS'16] — on the TPU mesh:

  * the (I x J x K) tensor is partitioned over the 2-D logical grid
    (rows of mode-0 over the 'data' axis x rows of mode-1 over 'model'):
    device (d, t) owns non-zeros with i in I-block_d and j in J-block_t;
  * factor A is row-sharded over 'data', B over 'model', C replicated;
  * each mode-n update does a LOCAL MTTKRP on owned non-zeros, then a psum
    over the mesh axes whose devices hold partial rows (mode-0: 'model';
    mode-1: 'data'; mode-2: both) — the all-reduce that SPLATT does with
    MPI rides the ICI torus here;
  * Gram matrices / column norms / fit are tiny (R x R, R) psums.

Multi-pod: the 'pod' axis joins 'data' as the mode-0 row axis, so the same
spec expresses reduce within the pod + all-reduce across pods over DCN.

Axis resolution and the psum/reduce-scatter phrasing live in
``repro.dist.collectives`` (shared with the LM path's ``launch/mesh.py``);
this module only contains what is CP-ALS specific: the host-side non-zero
partitioner and the shard_map iteration body.  See ``docs/architecture.md``.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.obs import trace as obs_trace
from repro.dist.collectives import (cpals_axes, gather_rows, pgram,
                                    pnormalize_columns, scatter_rows,
                                    shard_map)

from .coo import SparseTensor
from .gram import (column_norms, gram, hadamard_grams, kruskal_fit,
                   solve_cholesky, normalize)

Array = jax.Array


# the local MTTKRP reductions the shard_map iteration body can express —
# the candidate set every dist-facing planner/validator must respect
DIST_IMPLS = ("gather_scatter", "segment")


# ---------------------------------------------------------------------------
# host-side partitioner
# ---------------------------------------------------------------------------

def partition_tensor(t: SparseTensor, n_row: int, n_col: int,
                     *, pad_factor: float = 1.05):
    """Partition non-zeros over an (n_row x n_col) grid by (mode-0 block,
    mode-1 block).  Returns (inds (n_row, n_col, L, 3), vals (n_row, n_col, L),
    padded dims).  Padding entries have val 0 and point at the block's first
    local rows."""
    assert t.order == 3, "medium-grained partitioner is 3rd-order (like SPLATT)"
    inds = np.asarray(t.inds[: t.nnz])
    vals = np.asarray(t.vals[: t.nnz])
    i_p = -(-t.dims[0] // n_row) * n_row
    j_p = -(-t.dims[1] // n_col) * n_col
    bi, bj = i_p // n_row, j_p // n_col
    di = inds[:, 0] // bi
    dj = inds[:, 1] // bj

    counts = np.zeros((n_row, n_col), dtype=np.int64)
    np.add.at(counts, (di, dj), 1)
    cap = int(np.ceil(counts.max() * pad_factor)) if counts.max() else 1

    out_i = np.zeros((n_row, n_col, cap, 3), dtype=np.int32)
    out_v = np.zeros((n_row, n_col, cap), dtype=vals.dtype)
    # default padding coordinates: block-local row 0 of each mode block
    for r in range(n_row):
        out_i[r, :, :, 0] = r * bi
    for c in range(n_col):
        out_i[:, c, :, 1] = c * bj

    fill = np.zeros((n_row, n_col), dtype=np.int64)
    order = np.lexsort((dj, di))
    for idx in order:
        r, c = di[idx], dj[idx]
        k = fill[r, c]
        out_i[r, c, k] = inds[idx]
        out_v[r, c, k] = vals[idx]
        fill[r, c] += 1

    return jnp.asarray(out_i), jnp.asarray(out_v), (i_p, j_p, t.dims[2])


# ---------------------------------------------------------------------------
# one distributed ALS iteration (shard_map body)
# ---------------------------------------------------------------------------

def _local_mttkrp(inds, vals, rows_local, fa, fb, fc, num_rows: int,
                  impl: str = "scatter"):
    """Local MTTKRP over this device's non-zeros.
    rows_local: which column of inds indexes the OUTPUT rows (local ids);
    fa/fb/fc are the gather sources for the three modes (local or global).
    ``impl``: "scatter" (XLA scatter-add — the mutex/atomic analogue) or
    "segment" (segment-sum — the no-lock reduction the planner picks for
    contention-heavy modes); both are exact, the planner chooses by regime."""
    prod = vals[:, None].astype(fa.dtype)
    sources = (fa, fb, fc)
    for m in range(3):
        if m == rows_local:
            continue
        prod = prod * sources[m][inds[:, m]]
    if impl == "segment":
        return jax.ops.segment_sum(prod, inds[:, rows_local],
                                   num_segments=num_rows)
    out = jnp.zeros((num_rows, prod.shape[1]), dtype=prod.dtype)
    return out.at[inds[:, rows_local]].add(prod, mode="drop")


def _local_impls_of(plan) -> tuple[str, str, str]:
    """Map a DecompPlan's per-mode impls onto what the shard_map body can
    express (sorted workspaces don't survive the per-device partitioning, so
    'segment' means a local segment reduction, everything else scatter-add)."""
    return tuple("segment" if p.impl == "segment" else "scatter"
                 for p in plan.modes)


def make_dist_iteration(mesh: Mesh, dims_p, rank: int, *, norm_kind: str = "2",
                        shard_c: bool = False,
                        local_impls: tuple[str, str, str] = ("scatter",) * 3):
    """Builds the jitted shard_map'd single-iteration function.

    Row axes: mode-0 over ('pod','data') [or ('data',)], mode-1 over 'model'.

    ``local_impls``: the plan's per-mode local MTTKRP strategy (see
    ``_local_mttkrp``).

    ``shard_c``: the optimized mode-2 layout (EXPERIMENTS.md §Perf).  The
    baseline replicates C and its dense solve/gram on every device (faithful
    to SPLATT's medium-grained layout for the shortest mode, but ~20x
    redundant per-device dense work at 256 chips); shard_c row-shards C over
    the WHOLE mesh, replaces the mode-2 psum with a psum_scatter (half the
    wire), solves only local rows, and all-gathers C once per iteration.
    """
    ax = cpals_axes(mesh)
    row_ax, col_ax, all_ax = ax.row, ax.col, ax.all_axes
    i_p, j_p, k_dim = dims_p
    bi, bj = i_p // ax.n_row, j_p // ax.n_col
    if shard_c:
        assert k_dim % ax.n_all == 0, (k_dim, ax.n_all)

    in_specs = (
        ax.grid_spec(),          # inds (n_row, n_col, L, 3)
        ax.grid_spec(),          # vals (n_row, n_col, L)
        ax.row_spec(),           # A (i_p, R) row-sharded
        ax.col_spec(),           # B (j_p, R) row-sharded over model
        ax.all_spec() if shard_c else P(),   # C rows
        P(),                     # norm_x_sq scalar
    )
    out_specs = (ax.row_spec(), ax.col_spec(),
                 ax.all_spec() if shard_c else P(), P(), P())

    def body(inds, vals, a_blk, b_blk, c_in, norm_x_sq):
        if shard_c:
            # rebuild the full C for the mode-0/1 gathers (10s of MB):
            # the exact inverse of the reduce-scatter order below.
            c_full = gather_rows(c_in, (row_ax, col_ax))
        else:
            c_full = c_in
        inds = inds[0, 0]
        vals = vals[0, 0]
        # localize indices into the block-sharded factors
        row_id = jax.lax.axis_index(row_ax)
        col_id = jax.lax.axis_index(col_ax)
        li = inds[:, 0] - row_id * bi
        lj = inds[:, 1] - col_id * bj
        lk = inds[:, 2]
        linds = jnp.stack([li, lj, lk], axis=1)

        def grams_all(a, b, c):
            ga = pgram(a, row_ax)
            gb = pgram(b, col_ax)
            if shard_c:
                gc = pgram(c_in, all_ax)
            else:
                gc = c.T @ c
            return ga, gb, gc

        ga, gb, gc = grams_all(a_blk, b_blk, c_full)

        # ---- mode 0: partials summed over the 'model' axis ----
        v0 = gb * gc
        m0 = _local_mttkrp(linds, vals, 0, a_blk, b_blk, c_full, bi,
                           impl=local_impls[0])
        m0 = jax.lax.psum(m0, col_ax)
        a_new = solve_cholesky(m0, v0)
        a_new, lam = pnormalize_columns(a_new, row_ax, kind=norm_kind)
        ga = pgram(a_new, row_ax)

        # ---- mode 1: partials summed over the row axes ----
        v1 = ga * gc
        m1 = _local_mttkrp(linds, vals, 1, a_new, b_blk, c_full, bj,
                           impl=local_impls[1])
        m1 = jax.lax.psum(m1, row_ax)
        b_new = solve_cholesky(m1, v1)
        b_new, lam = pnormalize_columns(b_new, col_ax, kind=norm_kind)
        gb = pgram(b_new, col_ax)

        # ---- mode 2 ----
        v2 = ga * gb
        m2 = _local_mttkrp(linds, vals, 2, a_new, b_new, c_full, k_dim,
                           impl=local_impls[2])
        if shard_c:
            # optimized: half-wire reduce+scatter, local dense solve
            m2_blk = scatter_rows(m2, (row_ax, col_ax))
            c_new = solve_cholesky(m2_blk, v2)
            c_new, lam = pnormalize_columns(c_new, all_ax, kind=norm_kind)
            gc = pgram(c_new, all_ax)
            # blockwise fit: <X,Xhat> from local rows, summed over the mesh
            from .gram import kruskal_norm_sq
            inner = jax.lax.psum(
                jnp.sum(jnp.sum(m2_blk * c_new, axis=0) * lam), all_ax)
            norm_z_sq = kruskal_norm_sq(lam, (ga, gb, gc))
            resid = jnp.maximum(norm_x_sq + norm_z_sq - 2.0 * inner, 0.0)
            fit = 1.0 - jnp.sqrt(resid) / jnp.sqrt(norm_x_sq)
            return a_new, b_new, c_new, lam, fit

        m2 = jax.lax.psum(m2, row_ax + (col_ax,))
        c_new = solve_cholesky(m2, v2)
        lam_c = column_norms(c_new, kind=norm_kind)
        safe = jnp.where(lam_c == 0.0, 1.0, lam_c)
        c_new, lam = c_new / safe[None, :], lam_c
        gc = c_new.T @ c_new

        fit = kruskal_fit(norm_x_sq, lam, (ga, gb, gc), m2, c_new)
        return a_new, b_new, c_new, lam, fit

    smapped = shard_map(body, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs)
    return jax.jit(smapped)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def dist_cp_als(t: SparseTensor, rank: int, mesh: Mesh, *, niters: int = 10,
                key: Array | None = None, verbose: bool = False,
                shard_c: bool = False, init: tuple | None = None,
                mode_order: str = "natural", monitor=None,
                impl: str = "auto", plan=None, method: str = "cp_als"):
    """Distributed CP-ALS; numerically equivalent to the shared-memory path
    (modulo f32 reduction order).  Returns (factors, lmbda, fit).

    ``mode_order='auto'``: partition the two LONGEST modes over the grid and
    exchange the SHORTEST (the mode-2 scatter/gather wire is proportional to
    its length) — EXPERIMENTS.md §Perf, cpals hillclimb.

    ``impl``/``plan``: the same planner interface as :func:`cp_als` —
    ``impl="auto"`` (default) measures per-mode statistics and picks each
    mode's local MTTKRP strategy (segment reduction for contention-heavy
    modes, scatter-add for collision-light ones); a concrete name pins all
    modes; a prebuilt :class:`repro.plan.DecompPlan` skips planning.  The
    candidate set is restricted to what the shard_map body can express
    (``gather_scatter``/``segment``).

    ``monitor``: an optional :class:`repro.dist.StragglerMonitor`; each ALS
    iteration's wall time is recorded for every participating host (times
    are exchanged across processes when there are several — see
    ``repro.dist.straggler.record_step_times``), so imbalance across the
    non-zero partition becomes visible at the driver.

    ``t`` may be a :class:`repro.ingest.Ingested` handle: planning reuses
    the ingest-time stats and the returned factors are mapped back to the
    original labels through the handle's inverse relabeling.

    ``method``: a name from the decomposition-method registry
    (``repro.methods``).  The shard_map body implements the CP-ALS update;
    methods whose :class:`~repro.methods.MethodSpec` declares
    ``supports_dist=False`` (sequential HALS column updates, chunk
    streaming, the Kronecker-width TTMc) are rejected with the capability
    listing instead of silently computing something else."""
    from .cpals import init_factors
    from repro.api.executor import require_capability

    # the one capability gate (repro.api.executor): same error text here,
    # in the dry-run, and in Session.fit(executor="dist")
    require_capability(method, "dist")

    ing = None
    if not isinstance(t, SparseTensor):
        from repro.ingest import Ingested

        if not isinstance(t, Ingested):
            raise TypeError(
                f"dist_cp_als takes a SparseTensor or repro.ingest.Ingested,"
                f" got {type(t).__name__}")
        ing = t
        t = ing.tensor
    if plan is None:
        if impl != "auto" and impl not in DIST_IMPLS:
            raise ValueError(
                f"dist_cp_als cannot execute impl {impl!r}: the shard_map "
                f"body expresses only {DIST_IMPLS} as local reductions")
        if ing is not None:
            plan = ing.plan(impl, rank=rank, allow=DIST_IMPLS)
        else:
            from repro.plan import plan_decomposition

            plan = plan_decomposition(t, impl, rank=rank, allow=DIST_IMPLS,
                                      with_stats=impl == "auto")
    elif not set(plan.impls) <= set(DIST_IMPLS):
        raise ValueError(
            f"dist_cp_als cannot execute plan {plan.summary()!r}: the "
            f"shard_map body expresses only {DIST_IMPLS} as local reductions")

    if mode_order == "auto":
        # longest modes over the grid, shortest on the wire (dims are always
        # available from the tensor — no dependency on plan stats)
        perm = tuple(sorted(range(3), key=lambda m: -t.dims[m]))
        tp = SparseTensor(inds=t.inds[:, list(perm)], vals=t.vals,
                          dims=tuple(t.dims[m] for m in perm), nnz=t.nnz)
        if init is not None:
            init = tuple(init[m] for m in perm)
        pplan = dataclasses.replace(plan, modes=tuple(
            dataclasses.replace(plan.modes[m], mode=pos)
            for pos, m in enumerate(perm)))
        factors, lam, fit = dist_cp_als(
            tp, rank, mesh, niters=niters, key=key, verbose=verbose,
            shard_c=shard_c, init=init, mode_order="natural",
            monitor=monitor, impl=impl, plan=pplan, method=method)
        inv = [0] * 3
        for pos, m in enumerate(perm):
            inv[m] = pos
        factors = tuple(factors[inv[m]] for m in range(3))
        if ing is not None:
            factors = ing.restore_factors(factors)
        return factors, lam, fit

    local_impls = _local_impls_of(plan)
    ax = cpals_axes(mesh)
    n_row, n_col, n_all = ax.n_row, ax.n_col, ax.n_all

    inds, vals, dims_p = partition_tensor(t, n_row, n_col)
    i_p, j_p, k_dim = dims_p
    if shard_c:
        k_dim = -(-k_dim // n_all) * n_all
        dims_p = (i_p, j_p, k_dim)
    if key is None:
        key = jax.random.PRNGKey(0)
    if init is not None:
        full = tuple(
            jnp.zeros((dp, rank), t.vals.dtype).at[: f.shape[0]].set(f)
            for f, dp in zip(init, (i_p, j_p, k_dim)))
    else:
        full = init_factors((i_p, j_p, k_dim), rank, key, dtype=t.vals.dtype)
    # zero padded factor rows so grams match the unpadded computation
    a0 = full[0].at[t.dims[0]:].set(0.0)
    b0 = full[1].at[t.dims[1]:].set(0.0)
    c0 = full[2].at[t.dims[2]:].set(0.0)
    norm_x_sq = jnp.sum(t.vals.astype(jnp.float32) ** 2)

    it_first = make_dist_iteration(mesh, dims_p, rank, norm_kind="max",
                                   shard_c=shard_c, local_impls=local_impls)
    it_rest = make_dist_iteration(mesh, dims_p, rank, norm_kind="2",
                                  shard_c=shard_c, local_impls=local_impls)

    a, b, c = a0, b0, c0
    lam = jnp.ones((rank,), dtype=t.vals.dtype)
    fit = jnp.array(0.0)
    traced = obs_trace.tracing()
    for i in range(niters):
        fn = it_first if i == 0 else it_rest
        t0 = time.time()
        with obs_trace.span("iteration", method="dist_cp_als", i=i):
            a, b, c, lam, fit = fn(inds, vals, a, b, c, norm_x_sq)
            if traced:
                jax.block_until_ready(fit)  # honest span duration
        if monitor is not None:
            from repro.dist.straggler import record_step_times
            jax.block_until_ready(fit)
            record_step_times(monitor, time.time() - t0)
            flags = monitor.check()
            if flags and verbose:
                print(f"  dist its={i + 1} stragglers: {flags}")
        if traced:
            from repro.obs.recorder import record_event

            record_event("dist.iteration", i=int(i), fit=float(fit),
                         ms=(time.time() - t0) * 1e3)
        if verbose:
            print(f"  dist its={i + 1} fit={float(fit):.6f}")
    factors = (a[: t.dims[0]], b[: t.dims[1]], c[: t.dims[2]])
    if ing is not None:
        factors = ing.restore_factors(factors)
    return factors, lam, fit


def build_dist_cpals_lowered(workload: str, mesh: Mesh, *,
                             shard_c: bool = False,
                             mode_order: str = "natural",
                             local_impls: tuple[str, str, str] = ("scatter",) * 3):
    """Abstract (ShapeDtypeStruct) lowering of one distributed ALS iteration
    for a paper workload — the CP-ALS entry of the dry-run matrix."""
    from repro.configs import CPALS_WORKLOADS

    dims, nnz, rank = CPALS_WORKLOADS[workload]
    if mode_order == "auto":
        dims = tuple(sorted(dims, reverse=True))
    ax = cpals_axes(mesh)
    row_ax = ax.row
    n_row, n_col, n_all = ax.n_row, ax.n_col, ax.n_all
    i_p = -(-dims[0] // n_row) * n_row
    j_p = -(-dims[1] // n_col) * n_col
    cap = int(np.ceil(nnz / (n_row * n_col) * 1.2))
    k_p = -(-dims[2] // n_all) * n_all if shard_c else dims[2]
    dims_p = (i_p, j_p, k_p)

    from jax.sharding import NamedSharding
    sds = jax.ShapeDtypeStruct
    sh = lambda spec: NamedSharding(mesh, spec)
    inds = sds((n_row, n_col, cap, 3), jnp.int32, sharding=sh(ax.grid_spec()))
    vals = sds((n_row, n_col, cap), jnp.float32, sharding=sh(ax.grid_spec()))
    a = sds((i_p, rank), jnp.float32, sharding=sh(ax.row_spec()))
    b = sds((j_p, rank), jnp.float32, sharding=sh(ax.col_spec()))
    c_spec = ax.all_spec() if shard_c else P()
    c = sds((k_p, rank), jnp.float32, sharding=sh(c_spec))
    nx = sds((), jnp.float32)

    from repro.utils.roofline import CompatLowered

    fn = make_dist_iteration(mesh, dims_p, rank, shard_c=shard_c,
                             local_impls=local_impls)
    lowered = CompatLowered(fn.lower(inds, vals, a, b, c, nx))
    # MTTKRP flops: ~5 R nnz per mode (2R gather-products, R scatter-add,
    # 2R for the Khatri-Rao partial) x 3 modes, plus small dense terms.
    info = {"workload": workload, "dims": dims, "nnz": nnz, "rank": rank,
            "local_cap": cap, "shard_c": shard_c, "mode_order": mode_order,
            "local_impls": list(local_impls),
            "model_flops": 3 * 5.0 * rank * nnz}
    return lowered, info
