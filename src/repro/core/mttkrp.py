"""MTTKRP — matricized tensor times Khatri-Rao product — implementation registry.

The paper identifies MTTKRP as the critical kernel of CP-ALS (>90% of runtime,
Tab. III) and its performance study is, at heart, a study of MTTKRP
implementation strategies.  This module carries the full registry of our
analogues:

==================  =========================================================
impl                what it reproduces
==================  =========================================================
``rowloop``         the paper's *Chapel-initial* code: one output row at a
                    time via dynamic slices (the slicing-overhead regime of
                    §V-D.1, Figs 2/3).  Benchmark-only — deliberately slow.
``gather_scatter``  flat vectorized gather + scatter-add with output-row
                    collisions.  The *mutex/atomic* regime of §V-D.2: XLA's
                    scatter-add serializes colliding rows exactly where
                    SPLATT's mutex pool would contend (YELP-like tensors).
``segment``         sorted-by-output-row segment-sum over the CSF-flat
                    layout — SPLATT's *no-lock* schedule (NELL-2 path):
                    row ownership is resolved by the sort, not by locks.
``pallas``          the TPU-native kernel (kernels/mttkrp_pallas.py): blocked
                    one-hot segment-matmul on the MXU; collisions inside a
                    block are reduced by the matmul itself.
``dense``           dense einsum oracle (tests only).
==================  =========================================================

All impls support arbitrary tensor order (the paper restricts to 3rd order;
SPLATT itself and our port support order >= 3 — this is one of the paper's
"future work" items implemented here).

This table is kept in sync with ``docs/architecture.md`` ("The MTTKRP
implementation registry").
"""
from __future__ import annotations

from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from .coo import SparseTensor
from .csf import CSFFlat

Array = jax.Array

# ---------------------------------------------------------------------------
# Oracles / references
# ---------------------------------------------------------------------------


def mttkrp_dense(t: SparseTensor, factors: Sequence[Array], mode: int) -> Array:
    """Dense oracle: densify X and contract. Tests only (small tensors).

    M[i, r] = sum_{j,k,...} X[.., i, ..] * prod_{m != mode} A_m[idx_m, r]
    """
    dense = t.to_dense()
    order = t.order
    # Move `mode` axis first, contract the rest against the KRP.
    letters = "abcdefgh"[:order]
    out_l = letters[mode]
    terms = []
    for m in range(order):
        if m != mode:
            terms.append(f"{letters[m]}r")
    eq = f"{letters}," + ",".join(terms) + f"->{out_l}r"
    others = [factors[m] for m in range(order) if m != mode]
    return jnp.einsum(eq, dense, *others)


# ---------------------------------------------------------------------------
# rowloop — the deliberately naive "Chapel-initial" analogue (benchmarks only)
# ---------------------------------------------------------------------------


def mttkrp_rowloop(t: SparseTensor, factors: Sequence[Array], mode: int) -> Array:
    """One non-zero at a time with dynamic slices — the per-row-slice overhead
    regime the paper measures in §V-D.1.  O(nnz) sequential; benchmark-only."""
    order = t.order
    rank = factors[0].shape[1]
    out = jnp.zeros((t.dims[mode], rank), dtype=factors[0].dtype)

    def body(n, out):
        row = t.inds[n, mode]
        acc = t.vals[n] * jnp.ones((rank,), dtype=out.dtype)
        for m in range(order):
            if m != mode:
                # dynamic row slice of the factor — the "slicing" analogue
                frow = jax.lax.dynamic_slice_in_dim(factors[m], t.inds[n, m], 1, 0)
                acc = acc * frow[0]
        cur = jax.lax.dynamic_slice_in_dim(out, row, 1, 0)
        return jax.lax.dynamic_update_slice_in_dim(out, cur + acc[None], row, 0)

    return jax.lax.fori_loop(0, t.padded_nnz, body, out)


# ---------------------------------------------------------------------------
# gather_scatter — vectorized, unsorted, scatter-add collisions
# ---------------------------------------------------------------------------


def _krp_rows(
    inds: Array, factors: Sequence[Array], mode: int, vals: Array
) -> Array:
    """prod[n, r] = vals[n] * prod_{m != mode} A_m[inds[n, m], r]."""
    order = len(factors)
    prod = vals[:, None].astype(factors[0].dtype)
    for m in range(order):
        if m != mode:
            prod = prod * factors[m][inds[:, m]]
    return prod


def mttkrp_gather_scatter(
    t: SparseTensor, factors: Sequence[Array], mode: int
) -> Array:
    """Flat gather of factor rows, elementwise product, scatter-add.

    This is the "atomic variables" regime of the paper: colliding output rows
    are resolved by the scatter's serialized adds.  Fast when collisions are
    rare (NELL-2-like), degrades when one row is hot (YELP-like skew)."""
    rank = factors[0].shape[1]
    prod = _krp_rows(t.inds, factors, mode, t.vals)
    out = jnp.zeros((t.dims[mode], rank), dtype=prod.dtype)
    return out.at[t.inds[:, mode]].add(prod, mode="drop")


# ---------------------------------------------------------------------------
# segment — sorted CSF-flat, conflict-free segment reduction (no-lock path)
# ---------------------------------------------------------------------------


def mttkrp_segment(csf: CSFFlat, factors: Sequence[Array]) -> Array:
    """Segment-sum over the per-mode sorted layout.

    Sorting by output row is exactly SPLATT's no-lock schedule: each output
    row's contributions are contiguous, so a segment reduction needs no
    conflict resolution at all.  Padding entries carry row == dims[mode]
    (one extra segment, sliced off)."""
    mode = csf.mode
    prod = csf.vals[:, None].astype(factors[0].dtype)
    for i, m in enumerate(csf.other_modes):
        prod = prod * factors[m][csf.other_ids[:, i]]
    seg = jax.ops.segment_sum(
        prod,
        csf.row_ids,
        num_segments=csf.dims[mode] + 1,
        indices_are_sorted=True,
    )
    return seg[: csf.dims[mode]]


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------

IMPLS = ("gather_scatter", "segment", "pallas", "rowloop", "dense")


def mttkrp(
    x,
    factors: Sequence[Array],
    mode: int,
    *,
    impl: str = "segment",
) -> Array:
    """Dispatch on impl; ``x`` is a SparseTensor (gather_scatter/rowloop/dense)
    or the per-mode prebuilt layout (CSFFlat for segment, CSFTiled for pallas).
    """
    if impl == "dense":
        return mttkrp_dense(x, factors, mode)
    if impl == "rowloop":
        return mttkrp_rowloop(x, factors, mode)
    if impl == "gather_scatter":
        return mttkrp_gather_scatter(x, factors, mode)
    if impl == "segment":
        if not isinstance(x, CSFFlat):
            raise TypeError("segment impl needs a CSFFlat (build_csf(t, mode))")
        if x.mode != mode:
            raise ValueError(f"CSFFlat is sorted for mode {x.mode}, asked {mode}")
        return mttkrp_segment(x, factors)
    if impl == "pallas":
        from repro.kernels import ops as kops  # local import: optional dep

        return kops.mttkrp(x, factors)
    raise ValueError(f"unknown impl {impl!r}; one of {IMPLS}")
