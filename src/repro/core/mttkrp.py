"""MTTKRP — matricized tensor times Khatri-Rao product — implementation registry.

The paper identifies MTTKRP as the critical kernel of CP-ALS (>90% of runtime,
Tab. III) and its performance study is, at heart, a study of MTTKRP
implementation strategies.  This module carries the registry of our analogues
as first-class :class:`ImplSpec` entries — each impl declares its input
layout, capabilities (sortedness requirement, order > 3 support, backend) and
a relative cost model, which is what lets the per-mode planner
(``repro.plan``) select an implementation from tensor statistics instead of a
hardcoded string:

==================  =========================================================
impl                what it reproduces
==================  =========================================================
``rowloop``         the paper's *Chapel-initial* code: one output row at a
                    time via dynamic slices (the slicing-overhead regime of
                    §V-D.1, Figs 2/3).  Benchmark-only — deliberately slow.
``gather_scatter``  flat vectorized gather + scatter-add with output-row
                    collisions.  The *mutex/atomic* regime of §V-D.2: XLA's
                    scatter-add serializes colliding rows exactly where
                    SPLATT's mutex pool would contend (YELP-like tensors).
``segment``         sorted-by-output-row segment-sum over the unified CSF
                    workspace — SPLATT's *no-lock* schedule (NELL-2 path):
                    row ownership is resolved by the sort, not by locks.
``pallas``          the TPU-native kernel (kernels/mttkrp_pallas.py): blocked
                    one-hot segment-matmul on the MXU; collisions inside a
                    block are reduced by the matmul itself.
``linearized``      ALTO-style mode-agnostic workspace (core/linearized.py):
                    one bit-packed sorted index serves every mode.  Sort mode
                    runs the no-lock segment reduction; other modes decode
                    coordinates (shift/mask) and scatter-add.  Pure jnp.
``linearized_pallas``  the linearized workspace on the TPU kernel
                    (kernels/linearized_pallas.py): the one-hot
                    segment-matmul with the coordinate decode moved *inside*
                    the kernel; non-sort modes fall back to the jnp decode.
``dense``           dense einsum oracle (tests only).
==================  =========================================================

All impls support arbitrary tensor order (the paper restricts to 3rd order;
SPLATT itself and our port support order >= 3 — this is one of the paper's
"future work" items implemented here).

Every CSF-consuming impl (``segment``, ``pallas``, ``gather_scatter``)
accepts the single unified :class:`~repro.core.csf.CSF` layout;
``gather_scatter``/``rowloop``/``dense`` also run straight off COO; the
``linearized*`` impls consume the mode-agnostic
:class:`~repro.core.linearized.Linearized` workspace (layout ``"lin"`` —
ONE buffer for the whole decomposition instead of one CSF per mode).

This table is kept in sync with ``docs/architecture.md`` ("The MTTKRP
implementation registry").
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from .coo import SparseTensor
from .csf import CSF
from .linearized import Linearized

Array = jax.Array

# ---------------------------------------------------------------------------
# Oracles / references
# ---------------------------------------------------------------------------


def mttkrp_dense(t: SparseTensor, factors: Sequence[Array], mode: int) -> Array:
    """Dense oracle: densify X and contract. Tests only (small tensors).

    M[i, r] = sum_{j,k,...} X[.., i, ..] * prod_{m != mode} A_m[idx_m, r]
    """
    if isinstance(t, CSF):
        raise TypeError("dense oracle consumes COO (SparseTensor), not CSF")
    dense = t.to_dense()
    order = t.order
    # Move `mode` axis first, contract the rest against the KRP.
    letters = "abcdefgh"[:order]
    out_l = letters[mode]
    terms = []
    for m in range(order):
        if m != mode:
            terms.append(f"{letters[m]}r")
    eq = f"{letters}," + ",".join(terms) + f"->{out_l}r"
    others = [factors[m] for m in range(order) if m != mode]
    return jnp.einsum(eq, dense, *others)


# ---------------------------------------------------------------------------
# rowloop — the deliberately naive "Chapel-initial" analogue (benchmarks only)
# ---------------------------------------------------------------------------


def mttkrp_rowloop(t: SparseTensor, factors: Sequence[Array], mode: int) -> Array:
    """One non-zero at a time with dynamic slices — the per-row-slice overhead
    regime the paper measures in §V-D.1.  O(nnz) sequential; benchmark-only."""
    if isinstance(t, CSF):
        raise TypeError("rowloop consumes COO (SparseTensor), not CSF")
    order = t.order
    rank = factors[0].shape[1]
    out = jnp.zeros((t.dims[mode], rank), dtype=factors[0].dtype)

    def body(n, out):
        row = t.inds[n, mode]
        acc = t.vals[n] * jnp.ones((rank,), dtype=out.dtype)
        for m in range(order):
            if m != mode:
                # dynamic row slice of the factor — the "slicing" analogue
                frow = jax.lax.dynamic_slice_in_dim(factors[m], t.inds[n, m], 1, 0)
                acc = acc * frow[0]
        cur = jax.lax.dynamic_slice_in_dim(out, row, 1, 0)
        return jax.lax.dynamic_update_slice_in_dim(out, cur + acc[None], row, 0)

    return jax.lax.fori_loop(0, t.padded_nnz, body, out)


# ---------------------------------------------------------------------------
# gather_scatter — vectorized, scatter-add collisions (COO or CSF input)
# ---------------------------------------------------------------------------


def _krp_rows(
    inds: Array, factors: Sequence[Array], mode: int, vals: Array
) -> Array:
    """prod[n, r] = vals[n] * prod_{m != mode} A_m[inds[n, m], r]."""
    order = len(factors)
    prod = vals[:, None].astype(factors[0].dtype)
    for m in range(order):
        if m != mode:
            prod = prod * factors[m][inds[:, m]]
    return prod


def _krp_rows_csf(csf: CSF, factors: Sequence[Array]) -> Array:
    """The CSF-workspace analogue of :func:`_krp_rows` (padding entries carry
    value 0, so their products are exact zeros)."""
    prod = csf.vals[:, None].astype(factors[0].dtype)
    for i, m in enumerate(csf.other_modes):
        prod = prod * factors[m][csf.other_ids[:, i]]
    return prod


def mttkrp_gather_scatter(
    t, factors: Sequence[Array], mode: int
) -> Array:
    """Flat gather of factor rows, elementwise product, scatter-add.

    This is the "atomic variables" regime of the paper: colliding output rows
    are resolved by the scatter's serialized adds.  Fast when collisions are
    rare (NELL-2-like), degrades when one row is hot (YELP-like skew).

    Consumes either raw COO or the unified CSF workspace (whose padding
    entries carry value 0 and valid row ids, so they scatter exact zeros)."""
    if isinstance(t, CSF):
        if t.mode != mode:
            raise ValueError(f"CSF is built for mode {t.mode}, asked {mode}")
        prod = _krp_rows_csf(t, factors)
        out = jnp.zeros((t.dims[mode], prod.shape[1]), dtype=prod.dtype)
        return out.at[t.row_ids].add(prod, mode="drop")
    rank = factors[0].shape[1]
    prod = _krp_rows(t.inds, factors, mode, t.vals)
    out = jnp.zeros((t.dims[mode], rank), dtype=prod.dtype)
    return out.at[t.inds[:, mode]].add(prod, mode="drop")


# ---------------------------------------------------------------------------
# segment — sorted CSF, conflict-free segment reduction (no-lock path)
# ---------------------------------------------------------------------------


def mttkrp_segment(csf: CSF, factors: Sequence[Array],
                   mode: Optional[int] = None) -> Array:
    """Segment-sum over the per-mode sorted workspace.

    Sorting by output row is exactly SPLATT's no-lock schedule: each output
    row's contributions are contiguous, so a segment reduction needs no
    conflict resolution at all.  Padding entries carry value 0 and point at
    their tile's last real row, which keeps ``row_ids`` globally
    non-decreasing — the reduction keeps its ``indices_are_sorted`` fast
    path and the zeros contribute exactly nothing."""
    if not isinstance(csf, CSF):
        raise TypeError("segment impl needs a CSF workspace (build_csf(t, mode))")
    if mode is not None and csf.mode != mode:
        raise ValueError(f"CSF is built for mode {csf.mode}, asked {mode}")
    prod = _krp_rows_csf(csf, factors)
    return jax.ops.segment_sum(prod, csf.row_ids, num_segments=csf.num_rows,
                               indices_are_sorted=True)


def mttkrp_pallas(csf: CSF, factors: Sequence[Array],
                  mode: Optional[int] = None) -> Array:
    """The TPU kernel over the unified workspace (interpret mode off-TPU —
    resolved by ``kernels.ops.default_interpret``)."""
    if not isinstance(csf, CSF):
        raise TypeError("pallas impl needs a CSF workspace (build_csf(t, mode))")
    if mode is not None and csf.mode != mode:
        raise ValueError(f"CSF is built for mode {csf.mode}, asked {mode}")
    from repro.kernels import ops as kops  # local import: optional dep

    return kops.mttkrp(csf, factors)


# ---------------------------------------------------------------------------
# linearized — ALTO-style mode-agnostic bit-packed workspace (all modes from
# one resident buffer; see core/linearized.py for the format)
# ---------------------------------------------------------------------------


def _require_lin(ws) -> Linearized:
    if not isinstance(ws, Linearized):
        raise TypeError(
            "linearized impls need a Linearized workspace "
            "(build_linearized(t)); got " + type(ws).__name__)
    return ws


def mttkrp_linearized(ws, factors: Sequence[Array], mode: int) -> Array:
    """Pure-jnp reference over the linearized workspace — any mode, one buffer.

    Coordinates are recovered from the packed hi/lo words with static
    shifts/masks (``Linearized.decode``).  On the sort mode the stream is
    ordered by the output row (padding keeps it globally non-decreasing), so
    the no-lock ``segment_sum`` fast path applies; other modes take the
    scatter-add (mutex/atomic regime) — ALTO's recompute path, at zero extra
    resident memory and no re-sort."""
    lin = _require_lin(ws)
    prod = lin.vals[:, None].astype(factors[0].dtype)
    for m in range(lin.order):
        if m != mode:
            prod = prod * factors[m][lin.decode(m)]
    rows = lin.decode(mode)
    if mode == lin.sort_mode:
        return jax.ops.segment_sum(prod, rows, num_segments=lin.dims[mode],
                                   indices_are_sorted=True)
    out = jnp.zeros((lin.dims[mode], prod.shape[1]), dtype=prod.dtype)
    return out.at[rows].add(prod, mode="drop")


def mttkrp_linearized_pallas(ws, factors: Sequence[Array], mode: int) -> Array:
    """The linearized workspace on the TPU kernel: in-kernel shift/mask decode
    on the sort mode (kernels/linearized_pallas.py), jnp decode + scatter on
    the others (interpret mode off-TPU)."""
    lin = _require_lin(ws)
    from repro.kernels import ops as kops  # local import: optional dep

    return kops.mttkrp_lin(lin, factors, mode)


# ---------------------------------------------------------------------------
# cost models (relative per-iteration work; consumed by the planner)
# ---------------------------------------------------------------------------
#
# Each takes a duck-typed per-mode stats object (``repro.plan.ModeStats``:
# nnz, order, collision_rate, padding_overhead, ...) plus the CP rank and
# returns a unitless relative cost.  Constants encode the paper's regimes:
# scatter-adds serialize colliding rows (§V-D.2 mutex/atomic analogue) while
# the sorted paths pay the workspace's padding overhead instead; the MXU
# kernel turns conflict resolution into dense compute.

_SCATTER_SERIALIZATION = 8.0   # relative cost of a serialized colliding add
_MXU_SPEEDUP = 4.0             # dense one-hot matmul vs vector scatter


def _padded_nnz(stats) -> float:
    return stats.nnz / max(1e-9, 1.0 - stats.padding_overhead)


def _cost_gather_scatter(stats, rank: int) -> float:
    gather = stats.nnz * rank * (stats.order - 1)
    scatter = stats.nnz * rank * (
        1.0 + _SCATTER_SERIALIZATION * stats.collision_rate)
    return gather + scatter


def _cost_segment(stats, rank: int) -> float:
    # pays the tile-padding overhead, but the reduction is conflict-free
    return _padded_nnz(stats) * rank * stats.order


def _cost_pallas(stats, rank: int) -> float:
    return _padded_nnz(stats) * rank * stats.order / _MXU_SPEEDUP


def _cost_rowloop(stats, rank: int) -> float:
    return stats.nnz * rank * stats.order * 1e3  # sequential; never chosen


# Integer shift/mask work per coordinate decode, relative to a float
# gather+multiply unit of the models above.  Strictly positive: on predicted
# costs the linearized variants price as their sorted/scatter counterparts
# *plus* the decode, so they never displace a same-regime impl without a
# measured (calibrated) win — the single-resident-buffer advantage doesn't
# show up in flop-counting models.
_DECODE_DISCOUNT = 0.25


def _cost_decode(stats, rank: int) -> float:
    return _DECODE_DISCOUNT * stats.nnz * stats.order


def _cost_linearized(stats, rank: int) -> float:
    # the sort mode runs the segment (no-lock) regime, other modes the
    # scatter regime; scored per-mode we take whichever the mode's stats
    # favor, plus the decode
    base = min(_cost_segment(stats, rank), _cost_gather_scatter(stats, rank))
    return base + _cost_decode(stats, rank)


def _cost_linearized_pallas(stats, rank: int) -> float:
    base = min(_cost_pallas(stats, rank), _cost_gather_scatter(stats, rank))
    return base + _cost_decode(stats, rank)


# ---------------------------------------------------------------------------
# the registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ImplSpec:
    """One MTTKRP strategy and its declared capabilities.

    layout:     workspace the impl consumes — "csf" (unified CSF), "coo"
                (raw SparseTensor), or "any" (accepts both).
    needs_sorted: whether the impl relies on the workspace's row sort for
                correctness/conflict-freedom (the planner surfaces this as
                the paper's no-lock vs mutex/atomic distinction).
    backend:    "any", or a jax backend name ("tpu") the impl is *native* to;
                the auto policy only picks backend-specific impls on that
                backend (manual override still allowed anywhere).
    cost_model: (stats, rank) -> relative per-iteration cost, used by the
                auto policy's argmin.
    """

    name: str
    fn: Callable[..., Array]
    layout: str
    needs_sorted: bool
    supports_order_gt3: bool
    backend: str = "any"
    benchmark_only: bool = False
    oracle: bool = False
    cost_model: Optional[Callable[..., float]] = None


REGISTRY: dict[str, ImplSpec] = {}


def register_impl(spec: ImplSpec) -> ImplSpec:
    """Add (or replace) an implementation in the registry."""
    if spec.layout not in ("csf", "coo", "lin", "any"):
        raise ValueError(f"bad layout {spec.layout!r} for impl {spec.name!r}")
    REGISTRY[spec.name] = spec
    return spec


def get_impl(name: str, *, registry: Optional[dict] = None) -> ImplSpec:
    """Look up an :class:`ImplSpec` by name.

    ``registry`` defaults to the MTTKRP registry; other kernel families
    (``repro.core.ttmc``) pass their own table so the planner can score any
    registered sparse kernel with one code path."""
    registry = REGISTRY if registry is None else registry
    try:
        return registry[name]
    except KeyError:
        raise ValueError(
            f"unknown impl {name!r}; one of {tuple(registry)}") from None


def available_impls(*, order: int = 3, backend: Optional[str] = None,
                    include_benchmark: bool = False,
                    include_oracle: bool = False,
                    allow: Optional[Sequence[str]] = None,
                    registry: Optional[dict] = None) -> tuple[str, ...]:
    """Names of impls whose declared capabilities cover (order, backend).

    This is the planner's candidate filter: benchmark-only and oracle impls
    are excluded unless asked for, and backend-specific impls only qualify on
    their native backend.  ``registry`` selects the kernel family (MTTKRP by
    default; ``repro.core.ttmc.TTMC_REGISTRY`` for the Tucker chain).
    """
    registry = REGISTRY if registry is None else registry
    out = []
    for name, spec in registry.items():
        if allow is not None and name not in allow:
            continue
        if spec.benchmark_only and not include_benchmark:
            continue
        if spec.oracle and not include_oracle:
            continue
        if order > 3 and not spec.supports_order_gt3:
            continue
        if backend is not None and spec.backend not in ("any", backend):
            continue
        out.append(name)
    return tuple(out)


register_impl(ImplSpec(
    name="gather_scatter", fn=mttkrp_gather_scatter, layout="any",
    needs_sorted=False, supports_order_gt3=True,
    cost_model=_cost_gather_scatter))
register_impl(ImplSpec(
    name="segment", fn=mttkrp_segment, layout="csf",
    needs_sorted=True, supports_order_gt3=True,
    cost_model=_cost_segment))
register_impl(ImplSpec(
    name="pallas", fn=mttkrp_pallas, layout="csf",
    needs_sorted=True, supports_order_gt3=True, backend="tpu",
    cost_model=_cost_pallas))
register_impl(ImplSpec(
    name="linearized", fn=mttkrp_linearized, layout="lin",
    needs_sorted=True, supports_order_gt3=True,
    cost_model=_cost_linearized))
register_impl(ImplSpec(
    name="linearized_pallas", fn=mttkrp_linearized_pallas, layout="lin",
    needs_sorted=True, supports_order_gt3=True, backend="tpu",
    cost_model=_cost_linearized_pallas))
register_impl(ImplSpec(
    name="rowloop", fn=mttkrp_rowloop, layout="coo",
    needs_sorted=False, supports_order_gt3=True, benchmark_only=True,
    cost_model=_cost_rowloop))
register_impl(ImplSpec(
    name="dense", fn=mttkrp_dense, layout="coo",
    needs_sorted=False, supports_order_gt3=True, oracle=True))

IMPLS = tuple(REGISTRY)


# ---------------------------------------------------------------------------
# dispatcher
# ---------------------------------------------------------------------------


def mttkrp(
    x,
    factors: Sequence[Array],
    mode: int,
    *,
    impl: str = "segment",
) -> Array:
    """Dispatch on the registry; ``x`` is a SparseTensor (COO impls) or the
    unified per-mode CSF workspace (``build_csf(t, mode)``).  ``impl="auto"``
    is resolved by the planner (``repro.plan.plan_decomposition``) before this
    point — pass a concrete name here.
    """
    if impl == "auto":
        raise ValueError(
            "impl='auto' is a planner policy; resolve it with "
            "repro.plan.plan_decomposition (or call cp_als(impl='auto')) "
            "and dispatch on the per-mode plan")
    spec = get_impl(impl)
    return spec.fn(x, factors, mode)
