"""Dense rank-R linear algebra for CP-ALS.

These are the paper's non-MTTKRP routines from Table III:

  * ``gram``            — A^T A             ("Mat A^TA", BLAS syrk)
  * ``hadamard_grams``  — V = hadamard of other modes' Grams
  * ``solve_cholesky``  — A = M V^-1        ("Inverse", LAPACK potrf/potrs)
  * ``solve_gram``      — same solve, inverse-then-GEMM (fused epilogue)
  * ``normalize``       — column norms -> lambda ("Mat norm")
  * ``kruskal_fit``     — decomposition fit  ("CPD fit")

All matrices here are I x R or R x R with small R (paper uses R=35), so these
are jnp-native; the Pallas syrk kernel (kernels/syrk_pallas.py) is an optional
drop-in for ``gram`` on tall-skinny inputs.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

Array = jax.Array

# Ridge added to V's diagonal before Cholesky: SPLATT relies on potrf on a
# PSD-by-construction matrix; in f32 a tiny jitter keeps cho_factor stable on
# nearly-rank-deficient iterates without changing converged results.
CHOLESKY_RIDGE = 1e-12


def gram(a: Array, *, impl: str = "jnp") -> Array:
    """G = A^T A (syrk analogue). impl='pallas' uses the blocked kernel."""
    if impl == "pallas":
        from repro.kernels import ops as kops

        return kops.syrk(a)
    return a.T @ a


def hadamard_grams(grams: Sequence[Array], skip_mode: int) -> Array:
    """V = hadamard_{m != skip_mode} G_m  (lines 4/7/10 of Alg. 1)."""
    out = None
    for m, g in enumerate(grams):
        if m == skip_mode:
            continue
        out = g if out is None else out * g
    assert out is not None
    return out


def solve_cholesky(m_mat: Array, v: Array) -> Array:
    """A = M V^{-1} via Cholesky (potrf+potrs analogue, not an explicit pinv).

    V is symmetric PSD (hadamard of Gram matrices); solve V X^T = M^T.
    """
    r = v.shape[0]
    v = v + CHOLESKY_RIDGE * jnp.eye(r, dtype=v.dtype)
    c = jax.scipy.linalg.cho_factor(v, lower=False)
    return jax.scipy.linalg.cho_solve(c, m_mat.T).T


def solve_gram(m_mat: Array, v: Array) -> Array:
    """A = M V^{-1}, formulated for tall M: invert the R x R Gram hadamard
    via Cholesky, then apply it as a single GEMM.

    Mathematically identical to :func:`solve_cholesky` (V is symmetric PSD),
    but the expensive step is an (I x R)(R x R) matmul instead of a pair of
    triangular solves with I right-hand sides.  On CPU the triangular solves
    run single-threaded and scalar through LAPACK while the GEMM vectorizes,
    so for the ALS shapes (I in the thousands, R ~ 35) this is an order of
    magnitude faster; the O(R^3) explicit inverse is noise at these ranks.
    The fused epilogue uses this; :func:`solve_cholesky` remains the
    routine-by-routine "Inverse" (paper Table III) implementation.
    """
    r = v.shape[0]
    eye = jnp.eye(r, dtype=v.dtype)
    c = jax.scipy.linalg.cho_factor(v + CHOLESKY_RIDGE * eye, lower=False)
    v_inv = jax.scipy.linalg.cho_solve(c, eye)
    return m_mat @ v_inv


def column_norms(a: Array, *, kind: str) -> Array:
    """kind='max' (SPLATT's first-iteration norm) or '2' (subsequent)."""
    if kind == "max":
        return jnp.maximum(jnp.max(jnp.abs(a), axis=0), 1.0)
    if kind == "2":
        return jnp.sqrt(jnp.sum(a * a, axis=0))
    raise ValueError(f"unknown norm kind {kind!r}")


def normalize(a: Array, *, kind: str) -> tuple[Array, Array]:
    """Column-normalize; returns (A_normalized, lambda). Zero-safe."""
    lam = column_norms(a, kind=kind)
    safe = jnp.where(lam == 0.0, 1.0, lam)
    return a / safe[None, :], lam


def kruskal_norm_sq(lmbda: Array, grams: Sequence[Array]) -> Array:
    """||X_hat||^2 = sum( (lambda lambda^T) . hadamard_m G_m )."""
    had = None
    for g in grams:
        had = g if had is None else had * g
    return jnp.sum((lmbda[:, None] * lmbda[None, :]) * had)


def kruskal_inner(m_last: Array, a_last: Array, lmbda: Array) -> Array:
    """<X, X_hat> = sum_r lambda_r sum_i M_last[i,r] A_last[i,r].

    ``m_last`` is the final mode's MTTKRP output of this iteration and
    ``a_last`` the (normalized) updated factor — SPLATT's p_tt_inner trick:
    the inner product falls out of work already done, no extra pass over X.
    """
    return jnp.sum(jnp.sum(m_last * a_last, axis=0) * lmbda)


def kruskal_fit(
    norm_x_sq: Array, lmbda: Array, grams: Sequence[Array], m_last: Array, a_last: Array
) -> Array:
    """fit = 1 - sqrt(max(||X||^2 + ||X_hat||^2 - 2<X,X_hat>, 0)) / ||X||."""
    norm_z_sq = kruskal_norm_sq(lmbda, grams)
    inner = kruskal_inner(m_last, a_last, lmbda)
    resid_sq = jnp.maximum(norm_x_sq + norm_z_sq - 2.0 * inner, 0.0)
    return 1.0 - jnp.sqrt(resid_sq) / jnp.sqrt(norm_x_sq)
