"""repro.core — the paper's contribution: sparse CP-ALS (SPLATT) in JAX."""
from .coo import SparseTensor, random_sparse, from_factors, paper_dataset, read_tns, write_tns, PAPER_DATASETS, dedupe
from .csf import CSFFlat, CSFTiled, build_csf, build_csf_tiled, build_all_modes
from .mttkrp import mttkrp, mttkrp_dense, mttkrp_gather_scatter, mttkrp_segment, mttkrp_rowloop, IMPLS
from .gram import gram, hadamard_grams, solve_cholesky, normalize, kruskal_fit, kruskal_norm_sq, kruskal_inner
from .cpals import cp_als, CPDecomp, CPALSState, build_workspace, init_factors

__all__ = [
    "SparseTensor", "random_sparse", "from_factors", "paper_dataset", "read_tns",
    "write_tns", "PAPER_DATASETS", "CSFFlat", "CSFTiled", "build_csf",
    "build_csf_tiled", "build_all_modes", "mttkrp", "mttkrp_dense",
    "mttkrp_gather_scatter", "mttkrp_segment", "mttkrp_rowloop", "IMPLS",
    "gram", "hadamard_grams", "solve_cholesky", "normalize", "kruskal_fit",
    "kruskal_norm_sq", "kruskal_inner", "cp_als", "CPDecomp", "CPALSState",
    "build_workspace", "init_factors",
]
