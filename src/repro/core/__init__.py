"""repro.core — the paper's contribution: sparse CP-ALS (SPLATT) in JAX."""
from .coo import SparseTensor, random_sparse, from_factors, paper_dataset, read_tns, write_tns, PAPER_DATASETS, dedupe
from .csf import CSF, CSFFlat, CSFTiled, build_csf, build_csf_tiled, build_all_modes, build_csf_loop_reference
from .mttkrp import (mttkrp, mttkrp_dense, mttkrp_gather_scatter,
                     mttkrp_segment, mttkrp_rowloop, mttkrp_pallas, IMPLS,
                     ImplSpec, REGISTRY, register_impl, get_impl,
                     available_impls)
from .ttmc import (ttmc, ttmc_dense, ttmc_gather_scatter, ttmc_segment,
                   ttmc_pallas, TTMC_IMPLS, TTMC_REGISTRY,
                   register_ttmc_impl, get_ttmc_impl, available_ttmc_impls)
from .gram import gram, hadamard_grams, solve_cholesky, solve_gram, normalize, kruskal_fit, kruskal_norm_sq, kruskal_inner
from .cpals import (cp_als, CPDecomp, CPALSState, build_workspace,
                    resolve_plan, init_factors)

__all__ = [
    "SparseTensor", "random_sparse", "from_factors", "paper_dataset", "read_tns",
    "write_tns", "PAPER_DATASETS", "dedupe", "CSF", "CSFFlat", "CSFTiled",
    "build_csf", "build_csf_tiled", "build_all_modes",
    "build_csf_loop_reference", "mttkrp", "mttkrp_dense",
    "mttkrp_gather_scatter", "mttkrp_segment", "mttkrp_rowloop",
    "mttkrp_pallas", "IMPLS", "ImplSpec", "REGISTRY", "register_impl",
    "get_impl", "available_impls",
    "ttmc", "ttmc_dense", "ttmc_gather_scatter", "ttmc_segment",
    "ttmc_pallas", "TTMC_IMPLS", "TTMC_REGISTRY", "register_ttmc_impl",
    "get_ttmc_impl", "available_ttmc_impls",
    "gram", "hadamard_grams", "solve_cholesky", "solve_gram", "normalize",
    "kruskal_fit",
    "kruskal_norm_sq", "kruskal_inner", "cp_als", "CPDecomp", "CPALSState",
    "build_workspace", "resolve_plan", "init_factors",
]
