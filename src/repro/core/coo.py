"""COO sparse tensor container + synthetic generators + FROSTT .tns IO.

This is the framework's canonical in-memory sparse tensor format. The paper
(SPLATT-in-Chapel) reads FROSTT-style ``.tns`` text files and sorts non-zeros
into CSF as a pre-processing step; here COO is the load-time format and
:mod:`repro.core.csf` holds the per-mode sorted ("CSF-flat") layout.

All arrays are static-shape (JAX requirement): ``nnz`` may be padded to a block
multiple with explicit zero values pointing at a dummy row index so every
downstream op is shape-stable under jit.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Sequence

import numpy as np
import jax
import jax.numpy as jnp

Array = jax.Array


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparseTensor:
    """Order-N sparse tensor in coordinate format.

    inds: (nnz, order) int32 indices, one column per mode.
    vals: (nnz,) float values. Padding entries have val == 0.
    dims: static tuple of mode lengths.
    nnz:  static logical (unpadded) non-zero count.
    """

    inds: Array
    vals: Array
    dims: tuple[int, ...]
    nnz: int

    # -- pytree plumbing ---------------------------------------------------
    def tree_flatten(self):
        return (self.inds, self.vals), (self.dims, self.nnz)

    @classmethod
    def tree_unflatten(cls, aux, children):
        inds, vals = children
        dims, nnz = aux
        return cls(inds=inds, vals=vals, dims=dims, nnz=nnz)

    # -- basics ------------------------------------------------------------
    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def padded_nnz(self) -> int:
        return self.vals.shape[0]

    @property
    def density(self) -> float:
        return float(self.nnz) / float(np.prod([float(d) for d in self.dims]))

    def norm(self) -> Array:
        """Frobenius norm of the tensor (padding vals are zero)."""
        return jnp.sqrt(jnp.sum(self.vals.astype(jnp.float64) ** 2)).astype(
            self.vals.dtype
        )

    def to_dense(self) -> Array:
        """Densify (tests only — small tensors)."""
        out = jnp.zeros(self.dims, dtype=self.vals.dtype)
        return out.at[tuple(self.inds[:, m] for m in range(self.order))].add(
            self.vals
        )

    def pad_to(self, multiple: int) -> "SparseTensor":
        """Pad nnz up to a multiple; padding rows index 0 with value 0."""
        n = self.padded_nnz
        target = ((n + multiple - 1) // multiple) * multiple
        if target == n:
            return self
        pad = target - n
        inds = jnp.concatenate(
            [self.inds, jnp.zeros((pad, self.order), dtype=self.inds.dtype)]
        )
        vals = jnp.concatenate([self.vals, jnp.zeros((pad,), dtype=self.vals.dtype)])
        return SparseTensor(inds=inds, vals=vals, dims=self.dims, nnz=self.nnz)


# ---------------------------------------------------------------------------
# Synthetic generators
# ---------------------------------------------------------------------------

def random_sparse(
    dims: Sequence[int],
    nnz: int,
    key: Array,
    *,
    dtype=jnp.float32,
    skew: float = 0.0,
) -> SparseTensor:
    """Uniform (skew=0) or power-law-skewed random sparse tensor.

    ``skew`` > 0 concentrates non-zeros on low indices per mode (zipf-ish),
    reproducing the collision-heavy regime of the paper's YELP data set where
    SPLATT is forced onto its mutex-pool MTTKRP path.  skew == 0 reproduces the
    collision-light NELL-2-like regime ("no-lock" path).
    """
    dims = tuple(int(d) for d in dims)
    keys = jax.random.split(key, len(dims) + 1)
    cols = []
    for m, d in enumerate(dims):
        u = jax.random.uniform(keys[m], (nnz,), minval=1e-6, maxval=1.0)
        if skew > 0.0:
            # inverse-CDF of a truncated power law: heavier mass at low idx
            x = u ** (1.0 + skew)
        else:
            x = u
        cols.append(jnp.minimum((x * d).astype(jnp.int32), d - 1))
    inds = jnp.stack(cols, axis=1)
    vals = jax.random.uniform(keys[-1], (nnz,), dtype=dtype, minval=0.1, maxval=1.0)
    return dedupe(SparseTensor(inds=inds, vals=vals, dims=dims, nnz=nnz))


def dedupe(t: SparseTensor) -> SparseTensor:
    """Collapse duplicate coordinates (summing values) — SPLATT and the fit
    formula (sum vals^2 == ||X||_F^2) assume unique coordinates.  Host-side,
    build-time only."""
    inds = np.asarray(t.inds[: t.nnz])
    vals = np.asarray(t.vals[: t.nnz])
    lin = np.ravel_multi_index(tuple(inds[:, m] for m in range(t.order)), t.dims)
    uniq, inv = np.unique(lin, return_inverse=True)
    if uniq.shape[0] == inds.shape[0]:
        return t
    summed = np.zeros(uniq.shape[0], dtype=vals.dtype)
    np.add.at(summed, inv, vals)
    new_inds = np.stack(np.unravel_index(uniq, t.dims), axis=1).astype(np.int32)
    return SparseTensor(
        inds=jnp.asarray(new_inds),
        vals=jnp.asarray(summed),
        dims=t.dims,
        nnz=int(uniq.shape[0]),
    )


def from_factors(
    factors: Sequence[Array],
    nnz: int,
    key: Array,
    *,
    noise: float = 0.0,
) -> SparseTensor:
    """Sample ``nnz`` entries of a known low-rank CP tensor (ground truth for
    convergence tests): val = sum_r prod_m A_m[i_m, r] (+ gaussian noise)."""
    dims = tuple(int(a.shape[0]) for a in factors)
    keys = jax.random.split(key, len(dims) + 1)
    cols = [
        jax.random.randint(keys[m], (nnz,), 0, d, dtype=jnp.int32)
        for m, d in enumerate(dims)
    ]
    inds = jnp.stack(cols, axis=1)
    prod = jnp.ones((nnz, factors[0].shape[1]), dtype=factors[0].dtype)
    for m, a in enumerate(factors):
        prod = prod * a[inds[:, m]]
    vals = jnp.sum(prod, axis=1)
    if noise > 0.0:
        vals = vals + noise * jax.random.normal(keys[-1], (nnz,), dtype=vals.dtype)
    return dedupe(SparseTensor(inds=inds, vals=vals, dims=dims, nnz=nnz))


# Paper Table I shapes (dims, nnz). Used by benchmarks/configs; the synthetic
# generator reproduces shape/density, not the actual review data.
PAPER_DATASETS: dict[str, tuple[tuple[int, ...], int, float]] = {
    # name: (dims, nnz, skew)  — skew chosen so YELP-like tensors exercise the
    # collision/mutex path the paper analyzes in §V-D.2, NELL-2-like does not.
    "yelp": ((41_000, 11_000, 75_000), 8_000_000, 1.5),
    "rate-beer": ((27_000, 105_000, 262_000), 62_000_000, 1.0),
    "beer-advocate": ((31_000, 61_000, 182_000), 63_000_000, 1.0),
    "nell-2": ((12_000, 9_000, 29_000), 77_000_000, 0.0),
    "netflix": ((480_000, 18_000, 2_000), 100_000_000, 0.5),
}


def paper_dataset(name: str, key: Array, *, scale: float = 1.0) -> SparseTensor:
    """Synthetic tensor with the published shape/density of a paper data set.

    ``scale`` < 1 shrinks nnz (and dims proportionally to keep density) for
    CPU-sized benchmark runs; scale == 1.0 is the full published shape.
    """
    dims, nnz, skew = PAPER_DATASETS[name]
    if scale != 1.0:
        dims = tuple(max(8, int(d * scale ** (1 / 3))) for d in dims)
        nnz = max(64, int(nnz * scale))
    return random_sparse(dims, nnz, key, skew=skew)


# ---------------------------------------------------------------------------
# FROSTT .tns IO — thin wrappers over the streaming reader/writer in
# repro.ingest.reader (comment/blank tolerance, arity validation, explicit
# dims override, duplicate policy, vectorized formatting).  Imported lazily
# to keep the coo -> ingest dependency one-way at import time.
# ---------------------------------------------------------------------------

_warned_legacy_io = False


def _warn_legacy_io() -> None:
    global _warned_legacy_io
    if not _warned_legacy_io:
        import warnings

        warnings.warn(
            "repro.core.read_tns/write_tns are legacy re-exports; new code "
            "should use repro.ingest (reader / ingest()) or the repro.api "
            "DataConfig surface", DeprecationWarning, stacklevel=3)
        _warned_legacy_io = True


def read_tns(path: str, *, dtype=np.float32, dims=None,
             duplicates: str = "sum") -> SparseTensor:
    """Read FROSTT text (1-indexed ``i j k val`` lines).  See
    :func:`repro.ingest.reader.read_tns` — pass ``dims=`` to keep trailing
    empty slices (inference shrinks dims to max index + 1).

    .. deprecated:: use ``repro.ingest`` — warns once per process."""
    from repro.ingest import reader

    _warn_legacy_io()
    return reader.read_tns(path, dtype=dtype, dims=dims,
                           duplicates=duplicates)


def write_tns(path: str, t: SparseTensor) -> None:
    """Write FROSTT text with vectorized, round-trip-exact formatting
    (:func:`repro.ingest.reader.write_tns`).

    .. deprecated:: use ``repro.ingest`` — warns once per process."""
    from repro.ingest import reader

    _warn_legacy_io()
    reader.write_tns(path, t)
