"""CSF: the TPU adaptation of SPLATT's compressed sparse fiber layout.

SPLATT stores one CSF tree per mode (``ALLMODE``) so that the MTTKRP for mode
``n`` walks fibers rooted at mode-``n`` slices: every thread owns a range of
output rows and (on the no-lock path) never collides.  The pointer tree itself
does not map to a TPU, but the *schedule* does — and this module keeps exactly
one workspace type, :class:`CSF`, that every registered MTTKRP implementation
consumes (`segment`, `pallas`, `gather_scatter`; see ``core/mttkrp.py``):

  * non-zeros are **sorted by the output-row index** (then the remaining modes
    for fiber locality), so each output row's contributions are contiguous —
    SPLATT's "no-lock" property by construction;
  * non-zeros are additionally **row-tile aligned**: entries are grouped by
    output row-tile (``row // row_tile``) and each group is padded to a block
    multiple, so every block of ``block`` non-zeros writes exactly one
    ``row_tile x R`` output tile and the block -> tile map (``block_tile``) is
    non-decreasing.  The Pallas kernel keeps the output tile VMEM-resident
    across sequential grid steps and flushes it exactly once; collisions
    *inside* a block are resolved by a one-hot MXU matmul;
  * padding entries carry value 0 and point at their tile's last real row,
    so every impl treats them as exact no-ops without masking AND the global
    row sort survives padding (the segment impl keeps its
    ``indices_are_sorted`` no-lock reduction).

Historically the repo carried two incompatible layouts (``CSFFlat`` for the
segment path, ``CSFTiled`` for Pallas); both names now alias :class:`CSF`.

``build_csf`` is the analogue of the paper's "Sort" pre-processing stage
(Table III) and is what the sort-optimization benchmark (paper Fig. 1) times.
Layout rationale in full: ``docs/architecture.md`` ("The unified CSF
workspace").
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from .coo import SparseTensor

Array = jax.Array

# Default non-zero block: 512 nnz per block keeps the one-hot segment matrix
# (ROW_TILE x BLOCK) MXU-friendly while bounding per-tile padding waste.
DEFAULT_BLOCK = 512
# Output rows owned by one grid step of the Pallas kernel (fp32 VMEM tile is
# 8 sublanes x 128 lanes; 128 output rows is the natural MXU-aligned choice).
DEFAULT_ROW_TILE = 128


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSF:
    """Per-mode sorted, row-tile-aligned, block-padded sparse workspace.

    mode:      the output mode this replica is sorted by (static).
    row_ids:   (pnnz,) int32 output-row per entry, globally non-decreasing;
               padding entries point at their tile's last real row (value 0
               makes them no-ops).
    other_ids: (pnnz, order-1) int32 indices of the remaining modes, in
               ascending mode order (static ``other_modes`` gives the map).
    vals:      (pnnz,) values, 0 for padding.
    block_tile: (pnnz/block,) int32, non-decreasing block -> output-tile map
               (consumed by the Pallas kernel via scalar prefetch).
    """

    mode: int
    row_ids: Array
    other_ids: Array
    vals: Array
    block_tile: Array
    dims: tuple[int, ...]
    nnz: int
    block: int
    row_tile: int

    def tree_flatten(self):
        children = (self.row_ids, self.other_ids, self.vals, self.block_tile)
        aux = (self.mode, self.dims, self.nnz, self.block, self.row_tile)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        mode, dims, nnz, block, row_tile = aux
        row_ids, other_ids, vals, block_tile = children
        return cls(mode, row_ids, other_ids, vals, block_tile, dims, nnz,
                   block, row_tile)

    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def other_modes(self) -> tuple[int, ...]:
        return tuple(m for m in range(self.order) if m != self.mode)

    @property
    def num_rows(self) -> int:
        return self.dims[self.mode]

    @property
    def num_row_tiles(self) -> int:
        return -(-self.dims[self.mode] // self.row_tile)

    @property
    def padded_nnz(self) -> int:
        return int(self.vals.shape[0])

    @property
    def num_blocks(self) -> int:
        return self.padded_nnz // self.block

    @property
    def padding_overhead(self) -> float:
        """Fraction of entries that are padding (the layout's cost)."""
        return 1.0 - self.nnz / max(1, self.padded_nnz)


# Backwards-compatible aliases: the two historical layouts are now one type.
CSFFlat = CSF
CSFTiled = CSF


def _lexsort_perm(inds: np.ndarray, mode: int, other: tuple[int, ...]):
    """Sort permutation: primary key = mode index, then remaining modes for
    fiber locality.  Shared by the fast build and (as the semantics contract)
    the deliberately slow loop reference."""
    keys = tuple(inds[:, m] for m in reversed(other)) + (inds[:, mode],)
    return np.lexsort(keys)


def _finalize(rows: np.ndarray, oth: np.ndarray, v: np.ndarray,
              t: SparseTensor, mode: int, block: int, row_tile: int) -> CSF:
    """Tile-align and block-pad pre-sorted entries into a :class:`CSF`.

    Fully vectorized: per-tile counts -> blocks-per-tile -> one scatter of the
    sorted entries into their padded positions.  Empty row-tiles get one
    all-padding block so every output tile is visited (Pallas output buffers
    are not zero-initialised).
    """
    order = t.order
    n = int(v.shape[0])
    n_tiles = -(-t.dims[mode] // row_tile)
    tile_of = rows // row_tile
    counts = np.bincount(tile_of, minlength=n_tiles)
    # blocks per tile: at least 1 so every output tile is initialised
    blocks_per = np.maximum(1, -(-counts // block))
    widths = blocks_per * block
    offsets = np.concatenate([[0], np.cumsum(widths)])[:-1]
    starts = np.concatenate([[0], np.cumsum(counts)])[:-1]
    pnnz = int(widths.sum())

    tile_ids = np.arange(n_tiles, dtype=np.int32)
    # Padding rows point at their tile's LAST real row (first row for empty
    # tiles): still inside the tile for the kernel's one-hot map, and —
    # because a tile's last row precedes the next tile's first — it keeps
    # ``row_ids`` globally non-decreasing, so the segment impl retains
    # SPLATT's sorted no-lock reduction (indices_are_sorted).
    pad_row = (tile_ids * row_tile).astype(np.int32)
    if n:
        nz = counts > 0
        pad_row[nz] = rows[(starts + counts - 1)[nz]]
    out_rows = np.repeat(pad_row, widths)
    out_oth = np.zeros((pnnz, order - 1), dtype=np.int32)
    out_vals = np.zeros(pnnz, dtype=v.dtype)

    if n:
        pos = offsets[tile_of] + (np.arange(n) - starts[tile_of])
        out_rows[pos] = rows
        out_oth[pos] = oth
        out_vals[pos] = v
    block_tile = np.repeat(tile_ids, blocks_per)

    return CSF(
        mode=mode,
        row_ids=jnp.asarray(out_rows),
        other_ids=jnp.asarray(out_oth),
        vals=jnp.asarray(out_vals),
        block_tile=jnp.asarray(block_tile),
        dims=t.dims,
        nnz=t.nnz,
        block=block,
        row_tile=row_tile,
    )


def build_csf(
    t: SparseTensor,
    mode: int,
    *,
    block: int = DEFAULT_BLOCK,
    row_tile: int = DEFAULT_ROW_TILE,
) -> CSF:
    """Sort non-zeros by ``mode``, tile-align, and block-pad.

    Vectorized build: a single ``lexsort`` + flat gathers + one scatter,
    host-side numpy (pre-processing runs on the host, like SPLATT's sort).
    This is the optimized analogue of the paper's §V-C finding — the initial
    Chapel sort was slow because of per-call array allocation and slice
    copies, fixed by flat pointer-style operations (the slow path lives in
    ``build_csf_loop_reference`` / benchmarks/bench_sort_build.py for
    contrast).
    """
    order = t.order
    if not 0 <= mode < order:
        raise ValueError(f"mode {mode} out of range for order-{order} tensor")
    other = tuple(m for m in range(order) if m != mode)
    inds = np.asarray(t.inds[: t.nnz])
    in_vals = np.asarray(t.vals[: t.nnz])

    perm = _lexsort_perm(inds, mode, other)
    rows = inds[perm, mode].astype(np.int32)
    oth = inds[perm][:, list(other)].astype(np.int32)
    vals = in_vals[perm]
    return _finalize(rows, oth, vals, t, mode, block, row_tile)


def build_csf_tiled(
    t: SparseTensor,
    mode: int,
    *,
    block: int = DEFAULT_BLOCK,
    row_tile: int = DEFAULT_ROW_TILE,
) -> CSF:
    """Deprecated alias of :func:`build_csf` (the layouts are unified)."""
    return build_csf(t, mode, block=block, row_tile=row_tile)


def build_all_modes(
    t: SparseTensor, *, block: int = DEFAULT_BLOCK,
    row_tile: int = DEFAULT_ROW_TILE,
) -> list[CSF]:
    """One sorted replica per mode — SPLATT's ALLMODE storage policy."""
    return [build_csf(t, m, block=block, row_tile=row_tile)
            for m in range(t.order)]


def build_csf_loop_reference(t: SparseTensor, mode: int) -> CSF:
    """Deliberately naive build (argsort per key, python copy loops) — the
    'Chapel-initial' analogue used by the sort benchmark (paper Fig. 1).

    Supports any tensor order >= 2 (like :func:`build_csf`, whose semantics it
    must match entry-for-entry); the slow part is the permutation computation,
    the tile-align/pad plumbing is shared via ``_finalize``.
    """
    order = t.order
    if not 0 <= mode < order:
        raise ValueError(f"mode {mode} out of range for order-{order} tensor")
    inds = np.asarray(t.inds[: t.nnz])
    vals = np.asarray(t.vals[: t.nnz])
    other = tuple(m for m in range(order) if m != mode)
    # repeated stable argsorts, copying whole arrays each time (slice-copy
    # behaviour the paper calls out).
    perm = np.arange(inds.shape[0])
    for m in reversed(other):
        perm = perm[np.argsort(inds[perm, m], kind="stable")]
    perm = perm[np.argsort(inds[perm, mode], kind="stable")]
    rows, oth, v = [], [], []
    for p in perm:  # per-element copy loop (allocation-per-iteration analogue)
        rows.append(int(inds[p, mode]))
        oth.append([int(inds[p, m]) for m in other])
        v.append(float(vals[p]))
    rows = np.asarray(rows, dtype=np.int32)
    oth = (np.asarray(oth, dtype=np.int32).reshape(len(rows), order - 1)
           if rows.size else np.zeros((0, order - 1), dtype=np.int32))
    v = np.asarray(v, dtype=vals.dtype)
    # the loops above are the timed part; blocking/padding is shared plumbing.
    return _finalize(rows, oth, v, t, mode, DEFAULT_BLOCK, DEFAULT_ROW_TILE)
