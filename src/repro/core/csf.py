"""CSF-flat: the TPU adaptation of SPLATT's compressed sparse fiber layout.

SPLATT stores one CSF tree per mode (``ALLMODE``) so that the MTTKRP for mode
``n`` walks fibers rooted at mode-``n`` slices: every thread owns a range of
output rows and (on the no-lock path) never collides. The pointer tree itself
does not map to a TPU, but the *schedule* does: sorting the non-zeros by the
output-row index gives

  * contiguous output-row tiles per non-zero block (the Pallas kernel writes
    one VMEM-resident row tile per grid step),
  * SPLATT's "no-lock" property between blocks (a row never spans two tiles'
    ownership — collisions exist only *inside* a block where the kernel
    resolves them with a one-hot MXU matmul).

``build_csf`` is the analogue of the paper's "Sort" pre-processing stage
(Table III) and is what the sort-optimization benchmark (paper Fig. 1) times.
Layout rationale in full: ``docs/architecture.md`` ("The CSF-flat layout").
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from .coo import SparseTensor

Array = jax.Array

# Default non-zero block: 8 sublanes x 128 lanes is the fp32 VMEM tile; 1024
# nnz per block keeps the one-hot segment matrix (ROWS x BLOCK) MXU-friendly.
DEFAULT_BLOCK = 1024
# Output rows owned by one grid step of the Pallas kernel.
DEFAULT_ROW_TILE = 128


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSFFlat:
    """Per-mode sorted, block-padded sparse layout.

    mode:      the output mode this replica is sorted by (static).
    row_ids:   (pnnz,) int32, non-decreasing; == dims[mode] for padding.
    other_ids: (pnnz, order-1) int32 indices of the remaining modes, in
               ascending mode order (static ``other_modes`` gives the map).
    vals:      (pnnz,) values, 0 for padding.
    block_first_row / block_last_row: (pnnz/block,) int32 — first/last logical
               row touched by each block (drives the kernel's row-tile map).
    """

    mode: int
    row_ids: Array
    other_ids: Array
    vals: Array
    block_first_row: Array
    block_last_row: Array
    dims: tuple[int, ...]
    nnz: int
    block: int

    def tree_flatten(self):
        children = (
            self.row_ids,
            self.other_ids,
            self.vals,
            self.block_first_row,
            self.block_last_row,
        )
        aux = (self.mode, self.dims, self.nnz, self.block)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        mode, dims, nnz, block = aux
        row_ids, other_ids, vals, bfr, blr = children
        return cls(
            mode=mode,
            row_ids=row_ids,
            other_ids=other_ids,
            vals=vals,
            block_first_row=bfr,
            block_last_row=blr,
            dims=dims,
            nnz=nnz,
            block=block,
        )

    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def other_modes(self) -> tuple[int, ...]:
        return tuple(m for m in range(self.order) if m != self.mode)

    @property
    def num_rows(self) -> int:
        return self.dims[self.mode]

    @property
    def padded_nnz(self) -> int:
        return int(self.vals.shape[0])

    @property
    def num_blocks(self) -> int:
        return self.padded_nnz // self.block


def build_csf(
    t: SparseTensor, mode: int, *, block: int = DEFAULT_BLOCK
) -> CSFFlat:
    """Sort non-zeros by ``mode`` (then remaining modes) and block-pad.

    Vectorized build: a single ``lexsort`` + flat gathers, host-side numpy
    (pre-processing runs on the host, like SPLATT's sort).  This is the
    optimized analogue of the paper's §V-C finding — the initial Chapel sort
    was slow because of per-call array allocation and slice copies, fixed by
    flat pointer-style operations; here the whole build is a handful of
    vectorized array ops (the slow path lives in
    benchmarks/bench_sort_build.py for contrast).
    """
    order = t.order
    if not 0 <= mode < order:
        raise ValueError(f"mode {mode} out of range for order-{order} tensor")
    other = tuple(m for m in range(order) if m != mode)
    inds = np.asarray(t.inds[: t.nnz])
    in_vals = np.asarray(t.vals[: t.nnz])

    # lexsort: primary key = mode index, then other modes for fiber locality.
    keys = tuple(inds[:, m] for m in reversed(other)) + (inds[:, mode],)
    perm = np.lexsort(keys)
    row_ids = inds[perm, mode].astype(np.int32)
    other_ids = inds[perm][:, list(other)].astype(np.int32)
    vals = in_vals[perm]

    # Block padding: padding rows get row == dims[mode] (a dummy row that the
    # MTTKRP output slices off) and value 0.
    n = int(vals.shape[0])
    pnnz = ((n + block - 1) // block) * block
    pad = pnnz - n
    if pad:
        row_ids = np.concatenate(
            [row_ids, np.full((pad,), t.dims[mode], dtype=np.int32)])
        other_ids = np.concatenate(
            [other_ids, np.zeros((pad, order - 1), dtype=np.int32)])
        vals = np.concatenate([vals, np.zeros((pad,), dtype=vals.dtype)])

    blocks = row_ids.reshape(pnnz // block, block)
    # padding rows sort to the end; clamp so block row ranges stay in-bounds.
    clamped = np.minimum(blocks, t.dims[mode] - 1)
    block_first_row = clamped[:, 0].astype(np.int32)
    block_last_row = clamped[:, -1].astype(np.int32)

    return CSFFlat(
        mode=mode,
        row_ids=jnp.asarray(row_ids),
        other_ids=jnp.asarray(other_ids),
        vals=jnp.asarray(vals),
        block_first_row=jnp.asarray(block_first_row),
        block_last_row=jnp.asarray(block_last_row),
        dims=t.dims,
        nnz=t.nnz,
        block=block,
    )


def build_all_modes(
    t: SparseTensor, *, block: int = DEFAULT_BLOCK
) -> list[CSFFlat]:
    """One sorted replica per mode — SPLATT's ALLMODE storage policy."""
    return [build_csf(t, m, block=block) for m in range(t.order)]


# ---------------------------------------------------------------------------
# Tile-aligned layout for the Pallas kernel
# ---------------------------------------------------------------------------
#
# The kernel wants the stronger invariant "every non-zero block writes exactly
# one row_tile-row output tile".  We get it at build time: group non-zeros by
# output row-tile (row // row_tile) and pad each group to a block multiple.
# Empty row-tiles get one all-padding block so every output tile is visited
# (Pallas output buffers are not zero-initialised).  ``block_tile`` is the
# non-decreasing block -> output-tile map consumed via scalar prefetch.


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class CSFTiled:
    """Per-mode sorted, row-tile-aligned, block-padded sparse layout."""

    mode: int
    row_ids: Array        # (pnnz,) int32; padding rows point at their tile's
                          # first row (value 0 makes them no-ops)
    other_ids: Array      # (pnnz, order-1) int32
    vals: Array           # (pnnz,) values, 0 for padding
    block_tile: Array     # (pnnz/block,) int32, non-decreasing
    dims: tuple[int, ...]
    nnz: int
    block: int
    row_tile: int

    def tree_flatten(self):
        children = (self.row_ids, self.other_ids, self.vals, self.block_tile)
        aux = (self.mode, self.dims, self.nnz, self.block, self.row_tile)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        mode, dims, nnz, block, row_tile = aux
        row_ids, other_ids, vals, block_tile = children
        return cls(mode, row_ids, other_ids, vals, block_tile, dims, nnz, block, row_tile)

    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def other_modes(self) -> tuple[int, ...]:
        return tuple(m for m in range(self.order) if m != self.mode)

    @property
    def num_rows(self) -> int:
        return self.dims[self.mode]

    @property
    def num_row_tiles(self) -> int:
        return -(-self.dims[self.mode] // self.row_tile)

    @property
    def padded_nnz(self) -> int:
        return int(self.vals.shape[0])

    @property
    def num_blocks(self) -> int:
        return self.padded_nnz // self.block

    @property
    def padding_overhead(self) -> float:
        """Fraction of entries that are padding (the layout's cost)."""
        return 1.0 - self.nnz / max(1, self.padded_nnz)


def build_csf_tiled(
    t: SparseTensor,
    mode: int,
    *,
    block: int = 512,
    row_tile: int = 128,
) -> CSFTiled:
    """Numpy host-side build (pre-processing, like SPLATT's sort stage)."""
    order = t.order
    other = tuple(m for m in range(order) if m != mode)
    inds = np.asarray(t.inds[: t.nnz])
    vals = np.asarray(t.vals[: t.nnz])

    keys = tuple(inds[:, m] for m in reversed(other)) + (inds[:, mode],)
    perm = np.lexsort(keys)
    rows = inds[perm, mode].astype(np.int32)
    oth = inds[perm][:, list(other)].astype(np.int32)
    v = vals[perm]

    n_tiles = -(-t.dims[mode] // row_tile)
    tile_of = rows // row_tile
    counts = np.bincount(tile_of, minlength=n_tiles)
    # blocks per tile: at least 1 so every output tile is initialised
    blocks_per = np.maximum(1, -(-counts // block))
    starts = np.concatenate([[0], np.cumsum(counts)])[:-1]

    pnnz = int(blocks_per.sum()) * block
    out_rows = np.empty(pnnz, dtype=np.int32)
    out_oth = np.zeros((pnnz, order - 1), dtype=np.int32)
    out_vals = np.zeros(pnnz, dtype=v.dtype)
    block_tile = np.empty(int(blocks_per.sum()), dtype=np.int32)

    wpos = 0
    bpos = 0
    for tile in range(n_tiles):
        c = int(counts[tile])
        s = int(starts[tile])
        width = int(blocks_per[tile]) * block
        out_rows[wpos : wpos + width] = tile * row_tile  # padding default
        if c:
            out_rows[wpos : wpos + c] = rows[s : s + c]
            out_oth[wpos : wpos + c] = oth[s : s + c]
            out_vals[wpos : wpos + c] = v[s : s + c]
        block_tile[bpos : bpos + int(blocks_per[tile])] = tile
        wpos += width
        bpos += int(blocks_per[tile])

    return CSFTiled(
        mode=mode,
        row_ids=jnp.asarray(out_rows),
        other_ids=jnp.asarray(out_oth),
        vals=jnp.asarray(out_vals),
        block_tile=jnp.asarray(block_tile),
        dims=t.dims,
        nnz=t.nnz,
        block=block,
        row_tile=row_tile,
    )


def build_csf_loop_reference(t: SparseTensor, mode: int) -> CSFFlat:
    """Deliberately naive numpy build (argsort per key, python loops) —
    the 'Chapel-initial' analogue used by the sort benchmark (paper Fig. 1).
    Semantically identical to build_csf for unpadded entries."""
    inds = np.asarray(t.inds)
    vals = np.asarray(t.vals)
    order = t.order
    other = [m for m in range(order) if m != mode]
    # repeated stable argsorts, copying whole arrays each time (slice-copy
    # behaviour the paper calls out).
    perm = np.arange(inds.shape[0])
    for m in reversed(other):
        perm = perm[np.argsort(inds[perm, m], kind="stable")]
    perm = perm[np.argsort(inds[perm, mode], kind="stable")]
    rows, oth, v = [], [], []
    for p in perm:  # per-element copy loop (allocation-per-iteration analogue)
        rows.append(int(inds[p, mode]))
        oth.append([int(inds[p, m]) for m in other])
        v.append(float(vals[p]))
    # Assemble the same container the fast path produces (the loops above are
    # the timed part; the final blocking/padding is shared plumbing).
    permuted = SparseTensor(
        inds=jnp.asarray(inds[perm]), vals=jnp.asarray(vals[perm]),
        dims=t.dims, nnz=t.nnz,
    )
    return build_csf(permuted, mode)
