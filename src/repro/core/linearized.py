"""Linearized (ALTO-style) workspace: one bit-packed index serves ALL modes.

The CSF family (``core/csf.py``) keeps one sorted replica per mode — SPLATT's
ALLMODE policy.  That buys every mode a conflict-free schedule at the price of
N resident workspaces and N sorts.  Laukemann et al.'s ALTO line of work
("Accelerating Sparse Tensor Decomposition Using Adaptive Linearized
Representation", PAPERS.md 2403.06348) shows a third point in the design
space: pack every coordinate tuple into ONE integer with per-mode bit fields,

    lin(i_0, .., i_{N-1}) = sum_m  i_m << offset[m]

sort the non-zero stream ONCE by that packed value, and recover any mode's
coordinate in-kernel with a shift and a mask.  One resident buffer then
serves every mode of the decomposition:

  * the **sort mode** (the field placed most-significant; mode 0 by default)
    gets the full no-lock treatment — the stream is ordered by its output
    row, tile-aligned and block-padded exactly like a CSF replica, so both
    the sorted segment reduction and the Pallas one-hot segment-matmul
    kernel apply unchanged;
  * every **other mode** trades the per-mode re-sort for a decode (two shifts
    and a mask per coordinate — integer ALU work, cheap next to the float
    gathers it accompanies) followed by a scatter-add, i.e. the
    mutex/atomic regime of the paper at zero extra memory.

Packing layout (``field_offsets``): the sort mode occupies the MOST
significant field so the single ``argsort`` of the packed stream is exactly
a sort by that mode's output row; the remaining modes fill the lower fields
in ascending mode order (which also gives the stream fiber locality in
those modes, for free).  Fields are sized ``max(1, ceil(log2(dim)))`` bits;
the budget is :data:`PACK_BITS` = 64 total bits and (because jax arrays are
32-bit by default) at most 32 bits per field — tensors beyond that are
rejected at build time with a ``ValueError`` (``check_bit_budget``).

The packed stream is stored as TWO uint32 arrays (``hi``/``lo``) rather
than one uint64: jax disables 64-bit types by default, and the static
decode (:func:`decode_field`) never needs a 64-bit op — each field lives
entirely in one word or straddles the boundary with a known static shift.

Registered in the MTTKRP/TTMc registries as ``linearized`` (pure jnp) and
``linearized_pallas`` (in-kernel decode, ``kernels/linearized_pallas.py``);
the planner cost-models and calibrates them like any other impl, and the
ingest cache persists the build (``repro.ingest``).  Layout rationale in
``docs/architecture.md`` §2 ("The linearized workspace").
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from .coo import SparseTensor
from .csf import DEFAULT_BLOCK, DEFAULT_ROW_TILE

Array = jax.Array

# Total bit budget of the packed index (stored as two uint32 words).
PACK_BITS = 64
# Per-field budget: a field must decode with 32-bit ops (jax default dtypes).
FIELD_BITS = 32
# The mode whose field is most significant — the stream is sorted (and
# tile-aligned) by this mode's output row, so it gets the no-lock schedule.
DEFAULT_SORT_MODE = 0


def bit_widths(dims) -> tuple[int, ...]:
    """Per-mode field width: bits needed for the largest index (dim - 1),
    at least 1 so every mode owns a field even at dim == 1."""
    return tuple(max(1, int(int(d) - 1).bit_length()) for d in dims)


def check_bit_budget(dims) -> tuple[int, ...]:
    """Validate that ``dims`` fit the packed layout; returns the widths.

    Raises ``ValueError`` when the fields exceed :data:`PACK_BITS` total
    bits (the linearized format simply does not apply — the planner's
    candidate set falls back to CSF/COO impls) or any single field exceeds
    :data:`FIELD_BITS` (the 32-bit decode budget)."""
    widths = bit_widths(dims)
    total = sum(widths)
    if total > PACK_BITS:
        raise ValueError(
            f"dims {tuple(dims)} need {total} packed bits "
            f"({'+'.join(str(w) for w in widths)}), over the {PACK_BITS}-bit "
            "linearized-index budget")
    if max(widths) > FIELD_BITS:
        raise ValueError(
            f"dims {tuple(dims)} need a {max(widths)}-bit field, over the "
            f"{FIELD_BITS}-bit per-mode decode budget")
    return widths


def field_offsets(dims, sort_mode: int = DEFAULT_SORT_MODE
                  ) -> tuple[int, ...]:
    """Bit offset of each mode's field inside the packed index.

    ``sort_mode`` is most significant (so sorting the packed stream sorts by
    that mode's row); the remaining modes fill the lower fields in ascending
    mode order."""
    widths = bit_widths(dims)
    offsets = [0] * len(widths)
    shift = sum(widths)
    for m in (sort_mode, *(m for m in range(len(widths)) if m != sort_mode)):
        shift -= widths[m]
        offsets[m] = shift
    return tuple(offsets)


def linearize_coords(inds: np.ndarray, dims,
                     sort_mode: int = DEFAULT_SORT_MODE) -> np.ndarray:
    """Pack an (n, order) int coordinate array into (n,) uint64 (host-side)."""
    check_bit_budget(dims)
    offsets = field_offsets(dims, sort_mode)
    inds = np.asarray(inds).astype(np.uint64)
    lin = np.zeros(inds.shape[0], dtype=np.uint64)
    for m, off in enumerate(offsets):
        lin |= inds[:, m] << np.uint64(off)
    return lin


def delinearize_coords(lin: np.ndarray, dims,
                       sort_mode: int = DEFAULT_SORT_MODE) -> np.ndarray:
    """Inverse of :func:`linearize_coords`: (n,) uint64 -> (n, order) int64."""
    widths = check_bit_budget(dims)
    offsets = field_offsets(dims, sort_mode)
    lin = np.asarray(lin, dtype=np.uint64)
    out = np.empty((lin.shape[0], len(widths)), dtype=np.int64)
    for m, (off, w) in enumerate(zip(offsets, widths)):
        mask = np.uint64((1 << w) - 1)
        out[:, m] = ((lin >> np.uint64(off)) & mask).astype(np.int64)
    return out


def decode_field(hi: Array, lo: Array, offset: int, width: int) -> Array:
    """Extract one static (offset, width) bit field from the hi/lo word pair.

    All shifts and masks are static python ints, so this lowers to two or
    three integer vector ops — usable both in jnp impls and inside the
    Pallas kernel body (``kernels/linearized_pallas.py``)."""
    mask = np.uint32((1 << width) - 1) if width < 32 else np.uint32(0xFFFFFFFF)
    if offset >= 32:
        word = hi >> np.uint32(offset - 32) if offset > 32 else hi
        return (word & mask).astype(jnp.int32)
    if offset + width <= 32:
        word = lo >> np.uint32(offset) if offset else lo
        return (word & mask).astype(jnp.int32)
    # field straddles the 32-bit boundary: low part from lo, rest from hi
    word = (lo >> np.uint32(offset)) | (hi << np.uint32(32 - offset))
    return (word & mask).astype(jnp.int32)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Linearized:
    """The mode-agnostic linearized workspace (one per tensor, not per mode).

    hi/lo:      (pnnz,) uint32 — high/low words of the packed 64-bit index,
                sorted ascending (== sorted by the sort mode's output row),
                tile-aligned and block-padded for that mode like a CSF.
    vals:       (pnnz,) values, 0 for padding (padding packs to the tile's
                last real sort-mode row with all other fields 0, so every
                impl treats padding as exact no-ops without masking).
    block_tile: (pnnz/block,) int32 non-decreasing block -> sort-mode output
                tile map (Pallas scalar prefetch, like ``CSF.block_tile``).
    """

    hi: Array
    lo: Array
    vals: Array
    block_tile: Array
    dims: tuple[int, ...]
    nnz: int
    block: int
    row_tile: int
    sort_mode: int = DEFAULT_SORT_MODE

    def tree_flatten(self):
        children = (self.hi, self.lo, self.vals, self.block_tile)
        aux = (self.dims, self.nnz, self.block, self.row_tile, self.sort_mode)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        dims, nnz, block, row_tile, sort_mode = aux
        hi, lo, vals, block_tile = children
        return cls(hi, lo, vals, block_tile, dims, nnz, block, row_tile,
                   sort_mode)

    @property
    def order(self) -> int:
        return len(self.dims)

    @property
    def widths(self) -> tuple[int, ...]:
        return bit_widths(self.dims)

    @property
    def offsets(self) -> tuple[int, ...]:
        return field_offsets(self.dims, self.sort_mode)

    @property
    def num_rows(self) -> int:
        return self.dims[self.sort_mode]

    @property
    def num_row_tiles(self) -> int:
        return -(-self.dims[self.sort_mode] // self.row_tile)

    @property
    def padded_nnz(self) -> int:
        return int(self.vals.shape[0])

    @property
    def num_blocks(self) -> int:
        return self.padded_nnz // self.block

    @property
    def padding_overhead(self) -> float:
        return 1.0 - self.nnz / max(1, self.padded_nnz)

    def decode(self, mode: int) -> Array:
        """The mode's (pnnz,) int32 coordinates, two shifts and a mask away."""
        return decode_field(self.hi, self.lo, self.offsets[mode],
                            self.widths[mode])


def build_linearized(
    t: SparseTensor,
    *,
    block: int = DEFAULT_BLOCK,
    row_tile: int = DEFAULT_ROW_TILE,
    sort_mode: int = DEFAULT_SORT_MODE,
) -> Linearized:
    """Pack, sort ONCE, tile-align and pad — the whole-tensor analogue of
    ``build_csf`` that every mode shares.

    Host-side numpy like the CSF build (pre-processing runs on the host);
    one uint64 argsort replaces the per-mode lexsorts.  Padding entries pack
    the tile's last real sort-mode row with every other field 0 and value 0:
    they decode to in-range coordinates and contribute exact zeros on every
    mode's reduction, and the packed stream stays globally non-decreasing so
    the sort mode keeps its ``indices_are_sorted`` no-lock reduction."""
    order = t.order
    if not 0 <= sort_mode < order:
        raise ValueError(
            f"sort_mode {sort_mode} out of range for order-{order} tensor")
    check_bit_budget(t.dims)
    offsets = field_offsets(t.dims, sort_mode)

    inds = np.asarray(t.inds[: t.nnz])
    in_vals = np.asarray(t.vals[: t.nnz])
    lin = linearize_coords(inds, t.dims, sort_mode)
    perm = np.argsort(lin, kind="stable")
    lin = lin[perm]
    v = in_vals[perm]
    rows = inds[perm, sort_mode].astype(np.int64)

    # tile-align + block-pad against the sort mode's row tiles (the same
    # vectorized counts -> blocks -> scatter scheme as csf._finalize)
    n = int(v.shape[0])
    n_tiles = -(-t.dims[sort_mode] // row_tile)
    tile_of = rows // row_tile
    counts = np.bincount(tile_of, minlength=n_tiles)
    blocks_per = np.maximum(1, -(-counts // block))
    tile_widths = blocks_per * block
    offs = np.concatenate([[0], np.cumsum(tile_widths)])[:-1]
    starts = np.concatenate([[0], np.cumsum(counts)])[:-1]
    pnnz = int(tile_widths.sum())

    tile_ids = np.arange(n_tiles, dtype=np.int64)
    pad_row = tile_ids * row_tile
    if n:
        nz = counts > 0
        pad_row[nz] = rows[(starts + counts - 1)[nz]]
    out_lin = np.repeat(
        pad_row.astype(np.uint64) << np.uint64(offsets[sort_mode]),
        tile_widths)
    out_vals = np.zeros(pnnz, dtype=in_vals.dtype)
    if n:
        pos = offs[tile_of] + (np.arange(n) - starts[tile_of])
        out_lin[pos] = lin
        out_vals[pos] = v
    block_tile = np.repeat(tile_ids.astype(np.int32), blocks_per)

    return Linearized(
        hi=jnp.asarray((out_lin >> np.uint64(32)).astype(np.uint32)),
        lo=jnp.asarray((out_lin & np.uint64(0xFFFFFFFF)).astype(np.uint32)),
        vals=jnp.asarray(out_vals),
        block_tile=jnp.asarray(block_tile),
        dims=t.dims,
        nnz=t.nnz,
        block=block,
        row_tile=row_tile,
        sort_mode=sort_mode,
    )
