from .mesh import make_production_mesh, rules_for, sharding_fn

__all__ = ["make_production_mesh", "rules_for", "sharding_fn"]
