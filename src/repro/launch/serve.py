"""Serving launcher: batched prefill + decode with the per-arch KV/state
caches.  CPU-sized with --smoke; the production shapes are proven by the
dry-run's serve_step cells.

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import Model


def serve(arch: str, *, smoke: bool, batch: int, prompt_len: int, gen: int,
          seed: int = 0, greedy: bool = True) -> dict:
    cfg = configs.get(arch)
    if smoke:
        cfg = configs.smoke_of(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len),
                                       dtype=np.int32))
    pre_batch = {}
    if cfg.input_mode == "embeds":
        from repro.models import layers as L
        pre_batch["embeds"] = L.embed({"table": params["embed"]["table"]},
                                      cfg, prompts)
    else:
        pre_batch["tokens"] = prompts
    if cfg.rope == "mrope":
        pre_batch["positions"] = jnp.broadcast_to(
            jnp.arange(prompt_len)[None, None],
            (3, batch, prompt_len)).astype(jnp.int32)
    if cfg.encdec:
        pre_batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((batch, 16, cfg.d_model), dtype=np.float32))

    cache = model.init_cache(batch, prompt_len + gen,
                             src_len=16 if cfg.encdec else 0)
    prefill = jax.jit(make_prefill_step(model))
    step = jax.jit(make_serve_step(model), donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, pre_batch, cache)
    t_prefill = time.time() - t0

    toks = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
    t0 = time.time()
    for i in range(gen - 1):
        pos = jnp.array(prompt_len + i, dtype=jnp.int32)
        positions = None
        if cfg.rope == "mrope":
            positions = jnp.full((3, batch, 1), prompt_len + i, jnp.int32)
        logits, cache = step(params, toks[-1][:, None], cache, pos, positions)
        toks.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
    jax.block_until_ready(toks[-1])
    t_decode = time.time() - t0

    out = jnp.stack(toks, axis=1)
    return {"tokens": np.asarray(out), "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_s": batch * (gen - 1) / max(t_decode, 1e-9)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    out = serve(args.arch, smoke=args.smoke, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen)
    print(f"[serve] prefill {out['prefill_s']:.2f}s  decode "
          f"{out['decode_s']:.2f}s  ({out['decode_tok_s']:,.0f} tok/s)")
    print(f"[serve] sample tokens: {out['tokens'][0][:12].tolist()}")


if __name__ == "__main__":
    main()
