"""Serving launcher: batched prefill + decode with the per-arch KV/state
caches, plus the decomposition-serving path for the paper's own CP-ALS
workloads (plan-driven decompose, then batched reconstruction queries).

  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
      --batch 4 --prompt-len 32 --gen 16
  PYTHONPATH=src python -m repro.launch.serve --arch cpals-yelp --smoke \
      --batch 256 --queries 2048

CPU-sized with --smoke; the production shapes are proven by the dry-run's
serve_step / cpals cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs import CPALS_DATASET
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import Model


def serve(arch: str, *, smoke: bool, batch: int, prompt_len: int, gen: int,
          seed: int = 0, greedy: bool = True) -> dict:
    cfg = configs.get(arch)
    if smoke:
        cfg = configs.smoke_of(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len),
                                       dtype=np.int32))
    pre_batch = {}
    if cfg.input_mode == "embeds":
        from repro.models import layers as L
        pre_batch["embeds"] = L.embed({"table": params["embed"]["table"]},
                                      cfg, prompts)
    else:
        pre_batch["tokens"] = prompts
    if cfg.rope == "mrope":
        pre_batch["positions"] = jnp.broadcast_to(
            jnp.arange(prompt_len)[None, None],
            (3, batch, prompt_len)).astype(jnp.int32)
    if cfg.encdec:
        pre_batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((batch, 16, cfg.d_model), dtype=np.float32))

    cache = model.init_cache(batch, prompt_len + gen,
                             src_len=16 if cfg.encdec else 0)
    prefill = jax.jit(make_prefill_step(model))
    step = jax.jit(make_serve_step(model), donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, pre_batch, cache)
    t_prefill = time.time() - t0

    toks = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
    t0 = time.time()
    for i in range(gen - 1):
        pos = jnp.array(prompt_len + i, dtype=jnp.int32)
        positions = None
        if cfg.rope == "mrope":
            positions = jnp.full((3, batch, 1), prompt_len + i, jnp.int32)
        logits, cache = step(params, toks[-1][:, None], cache, pos, positions)
        toks.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
    jax.block_until_ready(toks[-1])
    t_decode = time.time() - t0

    out = jnp.stack(toks, axis=1)
    return {"tokens": np.asarray(out), "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_s": batch * (gen - 1) / max(t_decode, 1e-9)}


def serve_cpd(workload: str, *, smoke: bool, batch: int, queries: int,
              rank: int = 16, niters: int = 10, policy: str = "auto",
              seed: int = 0, reorder: str = "identity",
              cache: str | None = None, method: str = "cp_als") -> dict:
    """Decompose a paper workload under a per-mode plan, then serve batched
    reconstruction queries (``values_at``) from the factor model.

    This is the decomposition-serving scenario: the decomposition is the
    compressed representation; a query is a coordinate batch and the answer
    is the reconstructed values.  ``--smoke`` scales the tensor to CPU size;
    the plan (and its report) is printed so the per-mode impl choice is
    visible at launch.

    ``--method`` selects from the decomposition-method registry
    (``repro.methods``): ``cp_als`` (default), ``cp_nn_hals``,
    ``tucker_hooi`` (planned against the ttmc kernel; ``--rank`` broadcasts
    to every mode), or ``cp_als_streaming`` (folds the tensor in as chunk
    batches).  Every method serves queries through the same ``values_at``
    interface, so the serving loop below is method-agnostic.

    The tensor goes through ``repro.ingest``: ``--reorder`` applies a
    locality-aware reordering (queries/factors stay in original labels —
    the handle inverts the relabeling on the way out) and ``--cache`` makes
    a repeat launch on the same tensor skip sort + stats entirely."""
    from repro.core import paper_dataset
    from repro.ingest import ingest
    from repro.methods import fit as fit_method, get_method
    from repro.utils.report import plan_report

    spec = get_method(method)  # raises with the registry listing if unknown
    key = jax.random.PRNGKey(seed)
    scale = 0.002 if smoke else 1.0
    t = paper_dataset(CPALS_DATASET[workload], key, scale=scale)
    t0 = time.time()
    ing = ingest(t, reorder=reorder, cache=cache)
    t_ingest = time.time() - t0

    # decompose via the registry's fit() (make_cpals_step in
    # launch/steps.py is the per-iteration entry for callers that need to
    # own the loop themselves)
    if spec.supports_streaming:
        # streaming folds chunk batches through COO reductions and never
        # executes a per-mode plan — don't print one it won't run
        print(f"# method={method}: chunked gather_scatter fold, "
              "no per-mode plan")
        plan_summary = "streaming:gather_scatter"
        t0 = time.time()
        dec = fit_method(ing, rank, method=method, niters=niters, key=key,
                         n_chunks=8)
    else:
        if spec.kernel == "ttmc":
            from repro.methods.tucker_hooi import _kron_widths, _resolve_ranks

            widths = _kron_widths(_resolve_ranks(rank, ing.dims))
            plan = ing.plan(policy, rank=widths, kernel="ttmc")
        else:
            plan = ing.plan(policy, rank=rank)
        print(plan_report(plan, reorder_deltas=ing.reorder_deltas(),
                          method=method))
        plan_summary = plan.summary()
        t0 = time.time()
        dec = fit_method(ing, rank, method=method, niters=niters, plan=plan,
                         key=key)
    jax.block_until_ready(dec.fit)
    t_decomp = time.time() - t0

    # serve: batched coordinate -> reconstructed-value queries, in the
    # tensor's ORIGINAL label space (cp_als restored the factors)
    rng = np.random.default_rng(seed)
    qfn = jax.jit(dec.values_at)
    n_batches = max(1, queries // batch)
    coords = jnp.asarray(np.stack(
        [rng.integers(0, d, (n_batches, batch)) for d in ing.original_dims],
        axis=-1).astype(np.int32))
    jax.block_until_ready(qfn(coords[0]))  # warmup/compile
    t0 = time.time()
    for b in range(n_batches):
        out = qfn(coords[b])
    jax.block_until_ready(out)
    t_serve = time.time() - t0

    return {"fit": float(dec.fit), "decompose_s": t_decomp,
            "serve_s": t_serve, "plan": plan_summary, "method": method,
            "ingest_s": t_ingest, "cache_hit": ing.cache_hit,
            "qps": n_batches * batch / max(t_serve, 1e-9)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=tuple(configs.ARCH_NAMES) + tuple(CPALS_DATASET))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--queries", type=int, default=2048,
                    help="cpals serving: total reconstruction queries")
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--impl", default="auto",
                    help="cpals serving: planner policy (auto or impl name)")
    ap.add_argument("--method", default="cp_als",
                    help="cpals serving: decomposition method "
                    "(repro.methods registry: cp_als/cp_nn_hals/"
                    "tucker_hooi/cp_als_streaming)")
    ap.add_argument("--reorder", default="identity",
                    help="cpals serving: ingest reordering "
                    "(identity/degree_sort/random_block)")
    ap.add_argument("--cache", default=None,
                    help="cpals serving: ingest cache root (warm relaunch "
                    "skips sort+stats)")
    args = ap.parse_args()
    if args.arch in CPALS_DATASET:
        out = serve_cpd(args.arch, smoke=args.smoke,
                        batch=args.batch, queries=args.queries,
                        rank=args.rank, niters=args.iters, policy=args.impl,
                        reorder=args.reorder, cache=args.cache,
                        method=args.method)
        print(f"[serve] method {out['method']}  plan {out['plan']}  "
              f"fit {out['fit']:.4f}  "
              f"ingest {out['ingest_s']:.2f}s"
              f"{' (cache hit)' if out['cache_hit'] else ''}  "
              f"decompose {out['decompose_s']:.2f}s  "
              f"serve {out['serve_s']:.2f}s ({out['qps']:,.0f} vals/s)")
        return
    out = serve(args.arch, smoke=args.smoke, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen)
    print(f"[serve] prefill {out['prefill_s']:.2f}s  decode "
          f"{out['decode_s']:.2f}s  ({out['decode_tok_s']:,.0f} tok/s)")
    print(f"[serve] sample tokens: {out['tokens'][0][:12].tolist()}")


if __name__ == "__main__":
    main()
