"""Serving launcher: the decomposition-serving path for the paper's own
CP-ALS workloads (plan-driven decompose, then batched reconstruction
queries), plus the **Legacy LM substrate**'s token-serving loop (batched
prefill + decode with the per-arch KV/state caches — kept for back-compat
with the seed's LM archs, like ``repro.models``/``repro.optim``; see
docs/architecture.md "Legacy LM substrate").

  PYTHONPATH=src python -m repro.launch.serve --arch cpals-yelp --smoke \
      --batch 256 --queries 2048
  PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --smoke \
      --batch 4 --prompt-len 32 --gen 16        # legacy LM path

The decomposition path is the supported one — it drives
:class:`repro.api.Session`, shares its RunConfig with ``python -m repro
serve``, and the production serving layer on top of it is
``repro.serve`` (``python -m repro serve-daemon``).  CPU-sized with
--smoke; the production shapes are proven by the dry-run's serve_step /
cpals cells.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.configs import CPALS_DATASET
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import Model


def serve(arch: str, *, smoke: bool, batch: int, prompt_len: int, gen: int,
          seed: int = 0, greedy: bool = True) -> dict:
    """**Legacy LM substrate**: token serving (prefill + decode) for the
    seed's LM archs.  Not the decomposition path — that is
    :func:`serve_cpd` here and ``repro.serve`` in production."""
    cfg = configs.get(arch)
    if smoke:
        cfg = configs.smoke_of(cfg)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(seed))

    rng = np.random.default_rng(seed)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab, (batch, prompt_len),
                                       dtype=np.int32))
    pre_batch = {}
    if cfg.input_mode == "embeds":
        from repro.models import layers as L
        pre_batch["embeds"] = L.embed({"table": params["embed"]["table"]},
                                      cfg, prompts)
    else:
        pre_batch["tokens"] = prompts
    if cfg.rope == "mrope":
        pre_batch["positions"] = jnp.broadcast_to(
            jnp.arange(prompt_len)[None, None],
            (3, batch, prompt_len)).astype(jnp.int32)
    if cfg.encdec:
        pre_batch["src_embeds"] = jnp.asarray(
            rng.standard_normal((batch, 16, cfg.d_model), dtype=np.float32))

    cache = model.init_cache(batch, prompt_len + gen,
                             src_len=16 if cfg.encdec else 0)
    prefill = jax.jit(make_prefill_step(model))
    step = jax.jit(make_serve_step(model), donate_argnums=(2,))

    t0 = time.time()
    logits, cache = prefill(params, pre_batch, cache)
    t_prefill = time.time() - t0

    toks = [jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)]
    t0 = time.time()
    for i in range(gen - 1):
        pos = jnp.array(prompt_len + i, dtype=jnp.int32)
        positions = None
        if cfg.rope == "mrope":
            positions = jnp.full((3, batch, 1), prompt_len + i, jnp.int32)
        logits, cache = step(params, toks[-1][:, None], cache, pos, positions)
        toks.append(jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32))
    jax.block_until_ready(toks[-1])
    t_decode = time.time() - t0

    out = jnp.stack(toks, axis=1)
    return {"tokens": np.asarray(out), "prefill_s": t_prefill,
            "decode_s": t_decode,
            "decode_tok_s": batch * (gen - 1) / max(t_decode, 1e-9)}


def cpd_config(workload: str, *, smoke: bool, rank: int, niters: int,
               policy: str, seed: int, reorder: str, cache: str | None,
               method: str):
    """The launcher's declarative description: one RunConfig, shared with
    ``python -m repro serve`` and the dry-run planner."""
    from repro.api import (DataConfig, ExecConfig, MethodConfig, PlanConfig,
                           RunConfig, require_capability)

    # the one capability gate (raises with the registry listing if unknown)
    spec = require_capability(method, "local")
    return RunConfig(
        data=DataConfig(dataset=CPALS_DATASET[workload],
                        scale=0.002 if smoke else 1.0, seed=seed,
                        reorder=reorder, cache=cache),
        plan=PlanConfig(policy=policy),
        method=MethodConfig(name=method, rank=rank, niters=niters, seed=seed),
        exec=ExecConfig(executor="local",
                        n_chunks=8 if spec.supports_streaming else None),
    )


def serve_cpd(workload: str, *, smoke: bool, batch: int, queries: int,
              rank: int = 16, niters: int = 10, policy: str = "auto",
              seed: int = 0, reorder: str = "identity",
              cache: str | None = None, method: str = "cp_als") -> dict:
    """Decompose a paper workload under a per-mode plan, then serve batched
    reconstruction queries (``values_at``) from the factor model.

    This is the decomposition-serving scenario: the decomposition is the
    compressed representation; a query is a coordinate batch and the answer
    is the reconstructed values.  ``--smoke`` scales the tensor to CPU size;
    the plan (and its report) is printed so the per-mode impl choice is
    visible at launch.

    Everything below is a thin wrapper over :class:`repro.api.Session` —
    ingest/plan/fit/serve_handle are the Session's cached stages, every
    method serves queries through the same ``values_at`` interface, and the
    same RunConfig drives ``python -m repro serve``.  ``--reorder`` /
    ``--cache`` are the ingest options; queries and factors stay in the
    tensor's ORIGINAL labels."""
    from repro.api import Session

    cfg = cpd_config(workload, smoke=smoke, rank=rank, niters=niters,
                     policy=policy, seed=seed, reorder=reorder, cache=cache,
                     method=method)
    sess = Session.from_config(cfg)
    # materialize the synthetic replica OUTSIDE the timed window so
    # ingest_s measures ingestion (and shows the cache win), not generation
    sess.load_tensor()
    t0 = time.time()
    ing = sess.ingest()
    t_ingest = time.time() - t0

    print(sess.plan_report())
    plan = sess.plan()
    plan_summary = plan.summary() if plan is not None \
        else "streaming:gather_scatter"
    t0 = time.time()
    dec = sess.fit()
    jax.block_until_ready(dec.fit)
    t_decomp = time.time() - t0

    # serve: batched coordinate -> reconstructed-value queries (the shared
    # ServeHandle benchmark loop — same numbers as `python -m repro serve`)
    bench = sess.serve_handle().benchmark(queries=queries, batch=batch,
                                          seed=seed)
    return {"fit": float(dec.fit), "decompose_s": t_decomp,
            "serve_s": bench["serve_s"], "plan": plan_summary,
            "method": method, "ingest_s": t_ingest,
            "cache_hit": ing.cache_hit, "qps": bench["qps"],
            "latency_ms": bench["latency_ms"]}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True,
                    choices=tuple(configs.ARCH_NAMES) + tuple(CPALS_DATASET),
                    help="cpals-<workload> = decomposition serving (the "
                         "supported path); LM arch names = Legacy LM "
                         "substrate token serving")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--queries", type=int, default=2048,
                    help="cpals serving: total reconstruction queries")
    ap.add_argument("--rank", type=int, default=16)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--impl", default="auto",
                    help="cpals serving: planner policy (auto or impl name)")
    ap.add_argument("--method", default="cp_als",
                    help="cpals serving: decomposition method "
                    "(repro.methods registry: cp_als/cp_nn_hals/"
                    "tucker_hooi/cp_als_streaming)")
    ap.add_argument("--reorder", default="identity",
                    help="cpals serving: ingest reordering "
                    "(identity/degree_sort/random_block)")
    ap.add_argument("--cache", default=None,
                    help="cpals serving: ingest cache root (warm relaunch "
                    "skips sort+stats)")
    args = ap.parse_args()
    if args.arch in CPALS_DATASET:
        out = serve_cpd(args.arch, smoke=args.smoke,
                        batch=args.batch, queries=args.queries,
                        rank=args.rank, niters=args.iters, policy=args.impl,
                        reorder=args.reorder, cache=args.cache,
                        method=args.method)
        print(f"[serve] method {out['method']}  plan {out['plan']}  "
              f"fit {out['fit']:.4f}  "
              f"ingest {out['ingest_s']:.2f}s"
              f"{' (cache hit)' if out['cache_hit'] else ''}  "
              f"decompose {out['decompose_s']:.2f}s  "
              f"serve {out['serve_s']:.2f}s ({out['qps']:,.0f} vals/s, "
              f"p50 {out['latency_ms']['p50']:.2f}ms "
              f"p99 {out['latency_ms']['p99']:.2f}ms)")
        return
    out = serve(args.arch, smoke=args.smoke, batch=args.batch,
                prompt_len=args.prompt_len, gen=args.gen)
    print(f"[serve] prefill {out['prefill_s']:.2f}s  decode "
          f"{out['decode_s']:.2f}s  ({out['decode_tok_s']:,.0f} tok/s)")
    print(f"[serve] sample tokens: {out['tokens'][0][:12].tolist()}")


if __name__ == "__main__":
    main()
