"""Training launcher: real execution on whatever devices exist (CPU here,
the production mesh on TPU), with checkpointing, restart, straggler
monitoring, and optional gradient compression.

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real cluster the same flags run the full config on the production mesh
(``--mesh single|multi``); the dry-run proves those lower+compile.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import TokenPipeline
from repro.dist import StragglerMonitor
from repro.dist.compress import init_error_feedback
from repro.dist.straggler import record_step_times
from repro.launch.steps import make_train_step
from repro.models import Model
from repro.optim import OPTIMIZERS


def train(arch: str, *, smoke: bool, steps: int, batch: int, seq: int,
          ckpt_dir: str | None, ckpt_every: int = 25, lr: float = 3e-4,
          optimizer: str = "adamw", grad_compress: bool = False,
          seed: int = 0, log_every: int = 10, resume: bool = True) -> dict:
    cfg = configs.get(arch)
    if smoke:
        cfg = configs.smoke_of(cfg)
    model = Model(cfg)
    opt = OPTIMIZERS[optimizer](lr=lr)

    params = model.init(jax.random.PRNGKey(seed))
    opt_state = opt.init(params)
    if grad_compress:
        opt_state = dict(opt_state, ef=init_error_feedback(params))
    step0 = 0

    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    if mgr is not None and resume and mgr.latest_step() is not None:
        (params, opt_state), extra = mgr.restore((params, opt_state))
        params = jax.tree.map(jnp.asarray, params)
        opt_state = jax.tree.map(jnp.asarray, opt_state)
        step0 = int(extra["step"])
        print(f"[train] resumed from step {step0}")

    train_step = jax.jit(make_train_step(model, opt,
                                         grad_compress=grad_compress),
                         donate_argnums=(0, 1))
    pipe = TokenPipeline(cfg, batch, seq, seed=seed)
    mon = StragglerMonitor()

    losses = []
    t_start = time.time()
    for step in range(step0, steps):
        t0 = time.time()
        batch_data = pipe.batch_at(step)
        params, opt_state, metrics = train_step(
            params, opt_state, batch_data, jnp.array(step, dtype=jnp.int32))
        loss = float(metrics["loss"])
        losses.append(loss)
        record_step_times(mon, time.time() - t0)
        straggler_flags = mon.check()
        if step % log_every == 0 or step == steps - 1:
            tok_s = batch * seq / max(time.time() - t0, 1e-9)
            print(f"[train] step {step:5d}  loss {loss:.4f}  "
                  f"{tok_s:,.0f} tok/s")
            if straggler_flags:
                print("[train] stragglers: " + ", ".join(
                    f"host{h}:{kind}"
                    for h, kind in sorted(straggler_flags.items())))
        if mgr is not None and (step + 1) % ckpt_every == 0:
            mgr.save(step + 1, (params, opt_state))
    if mgr is not None:
        mgr.save(steps, (params, opt_state))
        mgr.wait()
    wall = time.time() - t_start
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "steps": steps - step0, "wall_s": wall, "losses": losses}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_NAMES)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw", choices=list(OPTIMIZERS))
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    args = ap.parse_args()
    out = train(args.arch, smoke=args.smoke, steps=args.steps,
                batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, lr=args.lr,
                optimizer=args.optimizer, grad_compress=args.grad_compress)
    print(f"[train] done: loss {out['first_loss']:.4f} -> "
          f"{out['final_loss']:.4f} in {out['wall_s']:.1f}s")


if __name__ == "__main__":
    main()
