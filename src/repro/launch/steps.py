"""Step builders: train_step / prefill_step / serve_step for any arch config,
plus the CP-ALS iteration step for decomposition workloads.

These are the functions the dry-run lowers and the real launcher executes.
Gradient compression (the ``grad_compress`` flag) is implemented by
``repro.dist.compress`` — int8 quantization with error-feedback residuals;
the CP-ALS step executes a per-mode :class:`repro.plan.DecompPlan`; see
``docs/architecture.md``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.models.config import ModelConfig
from repro.optim import Optimizer
from repro.dist.compress import compress_grads_int8, decompress_grads_int8

Array = jax.Array


def _split_micro(batch: dict, m: int) -> dict:
    """Reshape every batch leaf to a leading microbatch axis of length m."""
    out = {}
    for k, v in batch.items():
        if k == "positions":  # (3, B, S) -> (m, 3, B/m, S)
            b = v.shape[1]
            out[k] = jnp.moveaxis(
                v.reshape(v.shape[0], m, b // m, *v.shape[2:]), 1, 0)
        else:                 # (B, ...) -> (m, B/m, ...)
            out[k] = v.reshape(m, v.shape[0] // m, *v.shape[1:])
    return out


def make_train_step(model: Model, optimizer: Optimizer,
                    *, grad_compress: bool = False, micro_batches: int = 1):
    """(params, opt_state, batch, step) -> (params, opt_state, metrics).

    ``grad_compress`` applies int8 quantization with error feedback to the
    gradients, carrying the quantization residual in opt_state['ef'].  In
    this jit path XLA inserts the data-parallel all-reduce implicitly, so
    the flag exercises the full quantize->dequantize fidelity loop (what
    convergence depends on) but the reduce itself still moves f32; wiring
    the int8 payload through the collective needs an explicit shard_map'd
    psum of (q, scales) and is the planned follow-up (docs/architecture.md).

    ``micro_batches`` > 1 accumulates gradients over batch splits (same
    optimizer math, ~1/m peak activation memory — what lets the big train
    cells fit a 16 GiB v5e)."""

    def grads_of(params, batch):
        return jax.value_and_grad(model.loss, has_aux=True)(params, batch)

    def train_step(params, opt_state, batch, step):
        if micro_batches > 1:
            micro = _split_micro(batch, micro_batches)

            def mb(carry, mbatch):
                (loss_m, metrics), grads = grads_of(params, mbatch)
                carry = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / micro_batches,
                    carry, grads)
                return carry, metrics

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics_all = jax.lax.scan(
                mb, zero, micro,
                unroll=True if model.cfg.unroll_loops else 1)
            metrics = {k: jnp.mean(v) for k, v in metrics_all.items()}
        else:
            (loss, metrics), grads = grads_of(params, batch)
        if grad_compress:
            ef = opt_state.get("ef")
            q, scales, ef = compress_grads_int8(grads, ef)
            grads = decompress_grads_int8(q, scales)
        new_params, new_opt = optimizer.update(
            grads, {k: v for k, v in opt_state.items() if k != "ef"},
            params, step)
        if grad_compress:
            new_opt = dict(new_opt, ef=ef)
        metrics = dict(metrics, step=step + 1)
        return new_params, new_opt, metrics

    return train_step


def make_cpals_step(plan):
    """One CP-ALS iteration executing a :class:`repro.plan.DecompPlan`.

    Returns ``(ws, factors, grams, norm_x_sq, norm_kind) -> (factors, grams,
    lmbda, fit)`` where ``ws`` is ``repro.core.build_workspace(t, plan)`` —
    the launch-layer entry the serving loop (and ad-hoc drivers) use, so the
    per-mode impl selection is decided once at plan time, not per step."""
    from repro.core.cpals import _iteration

    impls = plan.impls

    def cpals_step(ws, factors, grams, norm_x_sq, *, norm_kind="2"):
        return _iteration(ws, tuple(factors), tuple(grams), norm_x_sq,
                          impls=impls, norm_kind=norm_kind)

    return cpals_step


def make_prefill_step(model: Model):
    def prefill_step(params, batch, cache):
        logits, new_cache = model.prefill(params, batch, cache)
        return logits, new_cache
    return prefill_step


def make_serve_step(model: Model):
    """One decode step: (params, tokens (B,1), cache, pos) -> (logits, cache)."""
    cfg = model.cfg

    def serve_step(params, tokens, cache, pos, positions=None):
        return model.decode_step(params, tokens, cache, pos,
                                 positions=positions)
    return serve_step
