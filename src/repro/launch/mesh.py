"""Production mesh + logical-axis sharding rules (MaxText-style).

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state.  The dry-run target is
  single-pod:  (data=16, model=16)          = 256 chips (TPU v5e pod)
  multi-pod:   (pod=2, data=16, model=16)   = 512 chips

Axis *names* and pod-aware batch rules come from
``repro.dist.collectives`` — the same vocabulary the distributed CP-ALS
path resolves its row/column grid from — so the LM and tensor-
decomposition paths cannot drift apart.  See ``docs/architecture.md``.
"""
from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.dist.collectives import (DATA_AXIS, MODEL_AXIS, POD_AXIS,
                                    axis_product, batch_axes, make_mesh)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ((POD_AXIS, DATA_AXIS, MODEL_AXIS) if multi_pod
            else (DATA_AXIS, MODEL_AXIS))
    return make_mesh(shape, axes)


# ---------------------------------------------------------------------------
# logical axis -> mesh axis rules
# ---------------------------------------------------------------------------

# baseline rules; `embed` flips to the FSDP axis for cfg.fsdp archs
BASE_RULES: dict[str, Optional[str]] = {
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "experts_r": None,
    "embed": None,
    "embed_out": "model",
    "rnn": "model",
    "rnn_out": None,
    "layers": None,
    "norm": None,
    "conv": None,
    "lora": None,
    "five": None,
    # caches / activations
    "cache_batch": "data",
    "cache_seq": None,
    "act_batch": "data",
    # context-parallel flash attention: shard q blocks over 'model' for
    # archs whose head count does not divide the mesh (yi/llama3.2/qwen2-vl)
    "flash_q": None,
}


def rules_for(cfg=None, *, multi_pod: bool = False,
              overrides: dict | None = None) -> dict:
    rules = dict(BASE_RULES)
    if cfg is not None and getattr(cfg, "fsdp", False):
        rules["embed"] = "data"
    if multi_pod:
        # batch dims extend over the pod axis (pure DP across pods) —
        # the same pod-aware rule the CP-ALS row partition uses
        rules["cache_batch"] = batch_axes(multi_pod=True)
        rules["act_batch"] = batch_axes(multi_pod=True)
    if overrides:
        rules.update(overrides)
    return rules


def spec_for(axes: tuple, shape: tuple, mesh: Mesh, rules: dict, *,
             allow_uneven: bool = False) -> P:
    """PartitionSpec for a leaf with logical ``axes``.

    A dim is sharded only when divisible by the mesh axis (JAX rejects
    uneven input shardings).  Non-divisible head counts are handled by the
    context-parallel flash path instead (rules override ``flash_q``)."""
    parts = []
    used = set()
    for dim, ax in zip(shape, axes):
        rule = rules.get(ax) if ax is not None else None
        if rule is None:
            parts.append(None)
            continue
        mesh_axes = rule if isinstance(rule, tuple) else (rule,)
        size = axis_product(mesh, mesh_axes)
        ok = (dim % size == 0) or (allow_uneven and dim >= size)
        if ok and not (set(mesh_axes) & used):
            parts.append(rule)
            used.update(mesh_axes)
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def sharding_fn(mesh: Mesh, rules: dict):
    def f(axes: tuple, shape: tuple) -> NamedSharding:
        return NamedSharding(mesh, spec_for(axes, shape, mesh, rules))
    return f


def batch_sharding(mesh: Mesh, rules: dict, kind: str, shape: tuple) -> NamedSharding:
    """Sharding for an input-batch leaf: batch dim -> act_batch rule."""
    brule = rules.get("act_batch", DATA_AXIS)
    baxes = brule if isinstance(brule, tuple) else (brule,)
    size = axis_product(mesh, baxes)
    if kind == "positions":       # (3, B, S)
        b = shape[1]
        spec = P(None, brule, None) if b % size == 0 else P()
    elif kind in ("tokens",):     # (B, S)
        spec = P(brule, None) if shape[0] % size == 0 else P()
    elif kind == "act":           # (B, S, D)
        spec = P(brule, None, None) if shape[0] % size == 0 else P()
    else:
        spec = P()
    return NamedSharding(mesh, spec)
