import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell with
512 placeholder host devices, record memory/cost/collective analysis and the
three-term roofline.  MUST set XLA_FLAGS before any other import (jax locks
the device count on first init) — hence the two lines above.

  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all          # full matrix
  PYTHONPATH=src python -m repro.launch.dryrun --arch cpals-nell2  # paper's own workload
"""
import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.mesh import (batch_sharding, make_production_mesh, rules_for,
                               sharding_fn, spec_for)
from repro.launch.steps import make_prefill_step, make_serve_step, make_train_step
from repro.models import Model
from repro.models.config import SHAPES, cell_is_skipped
from repro.models.params import ParamSpec, axes_tree
from repro.optim import OPTIMIZERS
from repro.utils import roofline as RL

ARTIFACTS = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# per-arch optimizer (Adafactor where AdamW state cannot fit the mesh)
ARCH_OPT = {"kimi-k2-1t-a32b": "adafactor"}


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _map_axes(shape_tree, axes_tree_, fn):
    """map fn(SDS_leaf, axes_tuple) over parallel trees (axes leaves are
    tuples, which are themselves pytrees — flatten explicitly)."""
    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    s_leaves, td = jax.tree.flatten(shape_tree)
    a_leaves = jax.tree.flatten(axes_tree_, is_leaf=is_axes_leaf)[0]
    assert len(s_leaves) == len(a_leaves), (len(s_leaves), len(a_leaves))
    return jax.tree.unflatten(td, [fn(s, a) for s, a in zip(s_leaves, a_leaves)])


def abstract_cache(model: Model, mesh, rules, batch, cache_len, *, src_len=0,
                   cdtype):
    specs = model.cache_specs(batch, cache_len, src_len=src_len)

    def leaf(path, s: ParamSpec):
        name = path[-1].key if hasattr(path[-1], "key") else ""
        if name == "slot_pos":
            dt = jnp.int32
        elif name in ("state", "h"):
            dt = jnp.float32
        else:
            dt = cdtype
        sh = jax.sharding.NamedSharding(mesh, spec_for(s.axes, s.shape, mesh, rules))
        return _sds(s.shape, dt, sh)

    return jax.tree_util.tree_map_with_path(
        leaf, specs, is_leaf=lambda x: isinstance(x, ParamSpec))


def build_cell(arch: str, shape_name: str, *, multi_pod: bool,
               overrides: dict | None = None, mesh=None):
    """Returns (lowered, meta) for one cell.  Override keys starting with
    'rules:' go to the sharding rules, the rest to the ModelConfig."""
    cfg = configs.get(arch)
    rule_ov = {}
    step_kw = {}
    if overrides:
        import dataclasses
        cfg_ov = {k: v for k, v in overrides.items()
                  if not k.startswith(("rules:", "steps:"))}
        rule_ov = {k[6:]: v for k, v in overrides.items() if k.startswith("rules:")}
        step_kw = {k[6:]: v for k, v in overrides.items() if k.startswith("steps:")}
        if cfg_ov:
            cfg = dataclasses.replace(cfg, **cfg_ov)
    shape = SHAPES[shape_name]
    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    rules = rules_for(cfg, multi_pod=multi_pod, overrides=rule_ov or None)
    sfn = sharding_fn(mesh, rules)
    model = Model(cfg)

    # activation sharding constraints (keeps flash/MoE internals sharded)
    from repro.models.layers import set_sharding_hook

    def _hook(x, axes):
        spec = spec_for(axes, x.shape, mesh, rules)
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(mesh, spec))

    set_sharding_hook(_hook, mesh)

    params_abs = model.abstract(sfn)
    bshapes = configs.batch_shapes(cfg, shape)
    batch_abs = {k: _sds(sh, dt, batch_sharding(mesh, rules, kind, sh))
                 for k, (sh, dt, kind) in bshapes.items()}

    meta = {"arch": arch, "shape": shape_name,
            "mesh": dict(mesh.shape), "n_chips": mesh.devices.size,
            "fsdp": cfg.fsdp, "optimizer": None}

    if shape.kind == "train":
        opt_name = ARCH_OPT.get(arch, "adamw")
        meta["optimizer"] = opt_name
        optimizer = OPTIMIZERS[opt_name]()
        opt_shapes = jax.eval_shape(optimizer.init, params_abs)
        axes = axes_tree(model.param_specs())
        opt_axes = optimizer.state_axes(axes)
        opt_abs = _map_axes(opt_shapes, opt_axes,
                            lambda s, a: _sds(s.shape, s.dtype, sfn(a, s.shape)))
        step_abs = _sds((), jnp.int32)
        fn = make_train_step(model, optimizer, **step_kw)
        lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(
            params_abs, opt_abs, batch_abs, step_abs)
        return lowered, meta

    src = configs.src_len(cfg, shape) if cfg.encdec else 0
    if shape.kind == "prefill":
        cache_abs = abstract_cache(model, mesh, rules, shape.global_batch,
                                   shape.seq_len, src_len=src, cdtype=cfg.cdtype)
        fn = make_prefill_step(model)
        lowered = jax.jit(fn, donate_argnums=(2,)).lower(
            params_abs, batch_abs, cache_abs)
        return lowered, meta

    # decode
    cache_abs = abstract_cache(model, mesh, rules, shape.global_batch,
                               shape.seq_len, src_len=src, cdtype=cfg.cdtype)
    tokens_abs = batch_abs["tokens"]
    pos_abs = _sds((), jnp.int32)
    positions_abs = batch_abs.get("positions")
    fn = make_serve_step(model)
    lowered = jax.jit(fn, donate_argnums=(2,)).lower(
        params_abs, tokens_abs, cache_abs, pos_abs, positions_abs)
    return lowered, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             overrides: dict | None = None, out_dir: Path = ARTIFACTS,
             tag: str = "") -> dict:
    skip = cell_is_skipped(arch, shape_name)
    cell_id = f"{arch}__{shape_name}__{'multi' if multi_pod else 'single'}"
    if tag:
        cell_id += f"__{tag}"
    if skip:
        art = {"cell": cell_id, "skipped": skip}
        _write(out_dir, cell_id, art)
        print(f"[dryrun] {cell_id}: SKIP ({skip})")
        return art

    t0 = time.time()
    lowered, meta = build_cell(arch, shape_name, multi_pod=multi_pod,
                               overrides=overrides)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]

    # Roofline cost probes (see DESIGN.md section 6)
    probe = _probe_costs(arch, shape_name, multi_pod=multi_pod,
                         overrides=overrides, cfg=cfg)
    rl = RL.analyze_values(
        flops=probe["flops"], bytes_accessed=probe["bytes"],
        wire_bytes=probe["wire"], collectives=probe["collectives"],
        n_chips=meta["n_chips"],
        model_flops=RL.model_flops_estimate(cfg, shape))

    art = {
        "cell": cell_id, **meta,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_gib": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
        },
        "roofline": rl.to_json(),
        "probe": {k: probe[k] for k in ("reps", "probe_compile_s")},
        "overrides": overrides or {},
    }
    _write(out_dir, cell_id, art)
    print(f"[dryrun] {cell_id}: ok  compile={t_compile:.1f}s  "
          f"dominant={rl.dominant}  bound={rl.bound_s*1e3:.2f}ms  "
          f"peak={art['memory']['peak_estimate_gib']}GiB")
    return art


def _probe_costs(arch: str, shape_name: str, *, multi_pod: bool,
                 overrides: dict | None, cfg) -> dict:
    """Compile k=1 / k=2 unrolled probes; extrapolate costs to full depth."""
    import dataclasses

    prefix, reps, suffix = cfg.layer_plan
    t0 = time.time()
    results = []
    for k in (1, 2):
        ov = dict(overrides or {})
        ov.update(
            num_layers=len(prefix) + k * len(cfg.pattern) + len(suffix),
            enc_layers=(k if cfg.encdec else 0),
            unroll_loops=True,
        )
        lowered, _ = build_cell(arch, shape_name, multi_pod=multi_pod,
                                overrides=ov)
        comp = lowered.compile()
        cost = RL.normalize_cost(comp.cost_analysis())
        colls = RL.parse_collectives(comp.as_text())
        results.append({
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
            "wire": sum(c["wire"] for c in colls),
            "summary": RL.collective_summary(colls),
        })
    r1, r2 = results

    def extrap(a, b):
        return a + (reps - 1) * (b - a)

    # per-kind collective extrapolation
    kinds = set(r1["summary"]) | set(r2["summary"])
    summary = {}
    for kind in kinds:
        s1 = r1["summary"].get(kind, {"count": 0, "bytes": 0.0, "wire": 0.0})
        s2 = r2["summary"].get(kind, {"count": 0, "bytes": 0.0, "wire": 0.0})
        summary[kind] = {f: extrap(s1[f], s2[f]) for f in ("count", "bytes", "wire")}

    return {
        "flops": extrap(r1["flops"], r2["flops"]),
        "bytes": extrap(r1["bytes"], r2["bytes"]),
        "wire": extrap(r1["wire"], r2["wire"]),
        "collectives": summary,
        "reps": reps,
        "probe_compile_s": round(time.time() - t0, 2),
    }


def plan_cpals_workload(workload: str, *, policy: str = "auto",
                        nnz_cap: int = 200_000, cache: str | None = None,
                        method: str = "cp_als"):
    """Plan a paper decomposition workload from a scaled synthetic replica.

    The dry-run never materializes the full tensor; per-mode statistics are
    shape/skew properties, so a scaled-density replica (capped at ``nnz_cap``
    non-zeros) is enough evidence for the planner's regime rules.  The
    replica goes through ``repro.ingest`` so stats are measured once (and,
    with ``cache=``, persist across dry-run invocations).

    ``method`` selects the registry entry whose kernel family is planned:
    the CP methods score the mttkrp registry at the workload's rank, Tucker
    scores the ttmc registry at each mode's Kronecker width (the
    kernel/width resolution lives in ``Session.plan`` — one place)."""
    from repro import configs
    from repro.api import (DataConfig, MethodConfig, PlanConfig, RunConfig,
                           Session)

    dims, nnz, rank = configs.CPALS_WORKLOADS[workload]
    scale = min(1.0, nnz_cap / nnz)
    cfg = RunConfig(
        data=DataConfig(dataset=configs.CPALS_DATASET[workload], scale=scale,
                        cache=cache),
        plan=PlanConfig(policy=policy),
        method=MethodConfig(name=method, rank=rank))
    return Session.from_config(cfg).plan()


def run_cpals(workload: str, *, multi_pod: bool, out_dir: Path = ARTIFACTS,
              shard_c: bool = False, mode_order: str = "natural",
              impl: str = "auto", tag: str = "",
              method: str = "cp_als") -> dict:
    """Dry-run the paper's own CP-ALS workload (distributed, medium-grained).

    The per-mode plan is derived from a scaled synthetic replica and threads
    into the lowered iteration (each mode's local MTTKRP strategy).  The
    lowered iteration is the shard_map CP-ALS body, so ``method`` must be
    distributed-capable (``MethodSpec.supports_dist``) — others are rejected
    up front with the capability listing, same as ``dist_cp_als``."""
    from repro.api import require_capability
    from repro.core.distributed import _local_impls_of, build_dist_cpals_lowered
    from repro.utils.report import plan_report

    # the one capability gate (repro.api.executor) — same error text as
    # Session.fit(executor="dist") and dist_cp_als
    require_capability(method, "dist")
    plan = plan_cpals_workload(workload, policy=impl, method=method)
    print(plan_report(plan, method=method))
    local_impls = _local_impls_of(plan)
    if mode_order == "auto":
        # the lowering sorts modes longest-first; realign the per-mode impls
        dims = configs.CPALS_WORKLOADS[workload][0]
        perm = sorted(range(3), key=lambda m: -dims[m])
        local_impls = tuple(local_impls[m] for m in perm)
    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    lowered, info = build_dist_cpals_lowered(workload, mesh, shard_c=shard_c,
                                             mode_order=mode_order,
                                             local_impls=local_impls)
    info["plan"] = {f"mode{p.mode}": p.impl for p in plan.modes}
    info["method"] = method
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    cost = compiled.cost_analysis()
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    rl = RL.analyze(cost, hlo, n_chips=mesh.devices.size,
                    model_flops=info["model_flops"])
    cell_id = f"{workload}__iteration__{'multi' if multi_pod else 'single'}"
    if tag:
        cell_id += f"__{tag}"
    art = {
        "cell": cell_id, "arch": workload, "shape": "iteration",
        "mesh": dict(mesh.shape), "n_chips": mesh.devices.size,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_estimate_gib": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
        },
        "roofline": rl.to_json(), "info": {k: v for k, v in info.items()
                                           if k != "model_flops"},
    }
    _write(out_dir, cell_id, art)
    print(f"[dryrun] {cell_id}: ok  compile={t_compile:.1f}s  "
          f"dominant={rl.dominant}  bound={rl.bound_s*1e3:.2f}ms")
    return art


def _write(out_dir: Path, cell_id: str, art: dict) -> None:
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{cell_id}.json").write_text(json.dumps(art, indent=1))


def run_all(out_dir: Path, *, resume: bool = True, jobs: int = 1) -> None:
    """Full matrix via one subprocess per cell (fresh XLA state, resumable)."""
    cells = []
    for arch in configs.ARCH_NAMES:
        for shape in SHAPES:
            for mp in (False, True):
                cells.append((arch, shape, mp))
    for wl in configs.CPALS_WORKLOADS:
        for mp in (False, True):
            cells.append((wl, "cpals", mp))

    todo = []
    for arch, shape, mp in cells:
        suffix = "multi" if mp else "single"
        name = (f"{arch}__{shape}__{suffix}" if shape != "cpals"
                else f"{arch}__iteration__{suffix}")
        if resume and (out_dir / f"{name}.json").exists():
            continue
        todo.append((arch, shape, mp))
    print(f"[dryrun] {len(todo)} cells to run ({len(cells) - len(todo)} cached)")

    procs: list[tuple[subprocess.Popen, str]] = []
    for arch, shape, mp in todo:
        args = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch]
        if shape != "cpals":
            args += ["--shape", shape]
        args += ["--mesh", "multi" if mp else "single", "--out", str(out_dir)]
        while len(procs) >= jobs:
            procs = _reap(procs)
            time.sleep(0.5)
        p = subprocess.Popen(args, stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT, text=True)
        procs.append((p, f"{arch}/{shape}/{mp}"))
    while procs:
        procs = _reap(procs)
        time.sleep(0.5)


def _reap(procs):
    alive = []
    for p, name in procs:
        if p.poll() is None:
            alive.append((p, name))
        else:
            out = p.stdout.read() if p.stdout else ""
            status = "ok" if p.returncode == 0 else f"FAIL rc={p.returncode}"
            print(f"[dryrun/all] {name}: {status}")
            if p.returncode != 0:
                print(out[-3000:])
    return alive


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", help="arch id or cpals-<workload>")
    ap.add_argument("--shape", choices=list(SHAPES), default=None)
    ap.add_argument("--mesh", choices=["single", "multi"], default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=1)
    ap.add_argument("--out", type=Path, default=ARTIFACTS)
    ap.add_argument("--tag", default="")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg overrides key=value (perf pass)")
    args = ap.parse_args()

    if args.all:
        run_all(args.out, jobs=args.jobs)
        return

    overrides = {}
    for ov in args.override:
        k, v = ov.split("=", 1)
        overrides[k] = json.loads(v)

    mp = args.mesh == "multi"
    if args.arch.startswith("cpals-"):
        run_cpals(args.arch, multi_pod=mp, out_dir=args.out,
                  shard_c=bool(overrides.get("shard_c")),
                  mode_order=overrides.get("mode_order", "natural"),
                  impl=overrides.get("impl", "auto"),
                  method=overrides.get("method", "cp_als"),
                  tag=args.tag)
    else:
        run_cell(args.arch, args.shape, multi_pod=mp,
                 overrides=overrides or None, out_dir=args.out, tag=args.tag)


if __name__ == "__main__":
    main()
