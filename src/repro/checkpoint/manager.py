"""Fault-tolerant checkpointing: atomic npz shards, keep-k, async writes,
elastic restore.

Design (multi-host ready, single-host exercised here):
  * every leaf is gathered to host (np.asarray pulls across shards) and
    written as one entry of an .npz; the pytree structure is stored as a
    JSON treedef with dtype/shape metadata;
  * writes go to ``<dir>/step_<n>.tmp/`` then os.rename to ``step_<n>/`` —
    a crashed write never corrupts the latest good checkpoint (restart
    scans for the newest COMPLETE step);
  * ``keep`` bounds disk usage (older steps garbage-collected after a
    successful save);
  * ``async_save`` runs serialization on a worker thread so the train loop
    only blocks on the previous save (double-buffered fault tolerance);
  * restore is mesh-agnostic: arrays come back as host numpy and are placed
    by repro.dist.elastic.reshard_tree under whatever mesh the restarted
    job has — the elastic-scaling path (lose/gain a pod, resume).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import numpy as np
import jax


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
            for path, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save_pytree(path: Path, tree: Any, *, extra: dict | None = None) -> None:
    """Atomic save of a pytree of arrays to ``path`` (a directory)."""
    path = Path(path)
    tmp = path.with_suffix(".tmp")
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    keys, vals, _ = _flatten_with_paths(tree)
    arrays = {f"a{i}": np.asarray(v) for i, v in enumerate(vals)}
    np.savez(tmp / "shard0.npz", **arrays)
    meta = {
        "keys": keys,
        "dtypes": [str(np.asarray(v).dtype) for v in vals],
        "shapes": [list(np.asarray(v).shape) for v in vals],
        "extra": extra or {},
        "complete": True,
    }
    (tmp / "meta.json").write_text(json.dumps(meta))
    if path.exists():
        shutil.rmtree(path)
    os.rename(tmp, path)


def load_pytree(path: Path, like: Any | None = None):
    """Load; if ``like`` (a pytree with the same structure) is given, arrays
    are unflattened into it, else returns (keys, arrays, extra)."""
    path = Path(path)
    meta = json.loads((path / "meta.json").read_text())
    if not meta.get("complete"):
        raise IOError(f"incomplete checkpoint at {path}")
    data = np.load(path / "shard0.npz")
    arrays = [data[f"a{i}"] for i in range(len(meta["keys"]))]
    if like is not None:
        flat, treedef = jax.tree_util.tree_flatten(like)
        assert len(flat) == len(arrays), (len(flat), len(arrays))
        return jax.tree_util.tree_unflatten(treedef, arrays), meta["extra"]
    return meta["keys"], arrays, meta["extra"]


class CheckpointManager:
    """keep-k, async, restart-scanning checkpoint manager."""

    def __init__(self, directory: str | Path, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._worker: Optional[threading.Thread] = None
        self._save_error: Optional[BaseException] = None

    # -- writing -------------------------------------------------------------
    def save(self, step: int, tree: Any, *, extra: dict | None = None) -> None:
        self.wait()  # block on the previous async save
        extra = dict(extra or {}, step=step)
        # materialize on host BEFORE handing to the thread (snapshot)
        keys, vals, treedef = _flatten_with_paths(tree)
        host_vals = [np.asarray(v) for v in vals]
        snapshot = jax.tree_util.tree_unflatten(treedef, host_vals)

        def work():
            try:
                save_pytree(self.dir / f"step_{step:08d}", snapshot, extra=extra)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._save_error = e

        if self.async_save:
            self._worker = threading.Thread(target=work, daemon=True)
            self._worker.start()
        else:
            work()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._worker is not None:
            self._worker.join()
            self._worker = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._save_error is not None:
            e, self._save_error = self._save_error, None
            raise e

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # -- reading -------------------------------------------------------------
    def steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.suffix == ".tmp" or not (p / "meta.json").exists():
                continue
            try:
                meta = json.loads((p / "meta.json").read_text())
                if meta.get("complete"):
                    out.append(int(p.name.split("_")[1]))
            except Exception:
                continue
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def read_extra(self, step: int) -> dict:
        """The ``extra`` metadata of one checkpoint WITHOUT loading its
        arrays — provenance checks (who wrote this, which method) belong
        before a structural restore, and only this module knows the
        on-disk layout."""
        meta = json.loads(
            (self.dir / f"step_{step:08d}" / "meta.json").read_text())
        return meta.get("extra", {})

    def restore(self, like: Any, *, step: int | None = None):
        """Restore newest complete checkpoint (or ``step``) into ``like``'s
        structure.  Returns (tree, extra)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint under {self.dir}")
        return load_pytree(self.dir / f"step_{step:08d}", like=like)
