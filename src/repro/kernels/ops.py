"""Public jit'd wrappers around the Pallas kernels.

Each wrapper handles the shape plumbing the kernel requires (rank padding to
the 128-lane width, block reshapes, gathers of factor rows) and slices the
result back to logical shapes.  ``interpret`` defaults to *backend detection*
(:func:`default_interpret`): on a real TPU the kernels compile, anywhere else
(CPU containers, GPU hosts) they run in interpret mode — overridable per
call for e.g. debugging compiled lowering from a CPU host.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.csf import CSF

from .mttkrp_pallas import LANE, mttkrp_pallas_call
from .syrk_pallas import syrk_pallas_call

Array = jax.Array


def default_interpret() -> bool:
    """True unless running on a TPU backend (where the kernels compile)."""
    return jax.default_backend() != "tpu"


def _pad_lanes(a: Array) -> Array:
    r = a.shape[-1]
    rp = -(-r // LANE) * LANE
    if rp == r:
        return a
    pad = [(0, 0)] * (a.ndim - 1) + [(0, rp - r)]
    return jnp.pad(a, pad)


@partial(jax.jit, static_argnames=("interpret",))
def mttkrp(csf: CSF, factors: Sequence[Array], *,
           interpret: Optional[bool] = None) -> Array:
    """MTTKRP for the mode ``csf`` was built for.  Returns (num_rows, R).

    The factor-row gathers stay in XLA (HBM-bandwidth work XLA does well);
    the kernel fuses the Khatri-Rao multiply and the conflict-resolving
    one-hot matmul.  For order > 3 the extra factors' rows are pre-multiplied
    into the second operand (associativity of the elementwise product).
    """
    if interpret is None:
        interpret = default_interpret()
    rank = factors[0].shape[1]
    om = csf.other_modes
    brows = _pad_lanes(factors[om[0]][csf.other_ids[:, 0]])
    crows = _pad_lanes(factors[om[1]][csf.other_ids[:, 1]])
    for i in range(2, len(om)):
        crows = crows * _pad_lanes(factors[om[i]][csf.other_ids[:, i]])

    nblocks, block = csf.num_blocks, csf.block
    rp = brows.shape[-1]
    out = mttkrp_pallas_call(
        csf.row_ids.reshape(nblocks, block),
        csf.vals.reshape(nblocks, block),
        brows.reshape(nblocks, block, rp),
        crows.reshape(nblocks, block, rp),
        csf.block_tile,
        num_row_tiles=csf.num_row_tiles,
        row_tile=csf.row_tile,
        interpret=interpret,
    )
    return out[: csf.num_rows, :rank].astype(factors[0].dtype)


@partial(jax.jit, static_argnames=("interpret",))
def ttmc(csf: CSF, factors: Sequence[Array], *,
         interpret: Optional[bool] = None) -> Array:
    """Chain-of-modes TTMc for the mode ``csf`` was built for.

    Returns (num_rows, prod_{m != mode} R_m).  The kernel is the MTTKRP
    one-hot segment-matmul reused verbatim: the row-wise Kronecker chain of
    the other modes' factor rows is formed XLA-side (it is just a reshaped
    outer product — HBM-bandwidth work, like the factor gathers) and fed in
    as the first operand with an all-ones second operand, so the fused
    ``vals * brows * crows`` multiply and the conflict-resolving one-hot
    matmul run unchanged at the wider Kronecker rank.
    """
    if interpret is None:
        interpret = default_interpret()
    from repro.core.ttmc import kron_chain  # one column-order convention

    kron = kron_chain([factors[m][csf.other_ids[:, i]]
                       for i, m in enumerate(csf.other_modes)])
    width = kron.shape[-1]
    kron = _pad_lanes(kron)

    nblocks, block = csf.num_blocks, csf.block
    rp = kron.shape[-1]
    out = mttkrp_pallas_call(
        csf.row_ids.reshape(nblocks, block),
        csf.vals.reshape(nblocks, block),
        kron.reshape(nblocks, block, rp),
        jnp.ones((nblocks, block, rp), dtype=kron.dtype),
        csf.block_tile,
        num_row_tiles=csf.num_row_tiles,
        row_tile=csf.row_tile,
        interpret=interpret,
    )
    return out[: csf.num_rows, :width].astype(factors[0].dtype)


@partial(jax.jit, static_argnames=("blk", "interpret"))
def syrk(a: Array, *, blk: int = 512,
         interpret: Optional[bool] = None) -> Array:
    """G = A^T A via the blocked Pallas kernel.  Returns (R, R)."""
    if interpret is None:
        interpret = default_interpret()
    rows, rank = a.shape
    ap = _pad_lanes(a)
    rows_p = -(-rows // blk) * blk
    if rows_p != rows:
        ap = jnp.pad(ap, ((0, rows_p - rows), (0, 0)))
    g = syrk_pallas_call(ap, blk=blk, interpret=interpret)
    return g[:rank, :rank].astype(a.dtype)
