"""Public jit'd wrappers around the Pallas kernels.

Each wrapper handles the shape plumbing the kernel requires (rank padding to
the 128-lane width, block reshapes, gathers of factor rows) and slices the
result back to logical shapes.  ``interpret`` defaults to *backend detection*
(:func:`default_interpret`): on a real TPU the kernels compile, anywhere else
(CPU containers, GPU hosts) they run in interpret mode — overridable per
call for e.g. debugging compiled lowering from a CPU host.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.csf import CSF
from repro.core.linearized import Linearized

from .linearized_pallas import mttkrp_lin_pallas_call
from .mttkrp_pallas import LANE, mttkrp_pallas_call
from .syrk_pallas import syrk_pallas_call

Array = jax.Array


def default_interpret() -> bool:
    """True unless running on a TPU backend (where the kernels compile)."""
    return jax.default_backend() != "tpu"


def _pad_lanes(a: Array) -> Array:
    r = a.shape[-1]
    rp = -(-r // LANE) * LANE
    if rp == r:
        return a
    pad = [(0, 0)] * (a.ndim - 1) + [(0, rp - r)]
    return jnp.pad(a, pad)


@partial(jax.jit, static_argnames=("interpret",))
def mttkrp(csf: CSF, factors: Sequence[Array], *,
           interpret: Optional[bool] = None) -> Array:
    """MTTKRP for the mode ``csf`` was built for.  Returns (num_rows, R).

    The factor-row gathers stay in XLA (HBM-bandwidth work XLA does well);
    the kernel fuses the Khatri-Rao multiply and the conflict-resolving
    one-hot matmul.  For order > 3 the extra factors' rows are pre-multiplied
    into the second operand (associativity of the elementwise product).
    """
    if interpret is None:
        interpret = default_interpret()
    rank = factors[0].shape[1]
    om = csf.other_modes
    brows = _pad_lanes(factors[om[0]][csf.other_ids[:, 0]])
    crows = _pad_lanes(factors[om[1]][csf.other_ids[:, 1]])
    for i in range(2, len(om)):
        crows = crows * _pad_lanes(factors[om[i]][csf.other_ids[:, i]])

    nblocks, block = csf.num_blocks, csf.block
    rp = brows.shape[-1]
    out = mttkrp_pallas_call(
        csf.row_ids.reshape(nblocks, block),
        csf.vals.reshape(nblocks, block),
        brows.reshape(nblocks, block, rp),
        crows.reshape(nblocks, block, rp),
        csf.block_tile,
        num_row_tiles=csf.num_row_tiles,
        row_tile=csf.row_tile,
        interpret=interpret,
    )
    return out[: csf.num_rows, :rank].astype(factors[0].dtype)


@partial(jax.jit, static_argnames=("interpret",))
def ttmc(csf: CSF, factors: Sequence[Array], *,
         interpret: Optional[bool] = None) -> Array:
    """Chain-of-modes TTMc for the mode ``csf`` was built for.

    Returns (num_rows, prod_{m != mode} R_m).  The kernel is the MTTKRP
    one-hot segment-matmul reused verbatim: the row-wise Kronecker chain of
    the other modes' factor rows is formed XLA-side (it is just a reshaped
    outer product — HBM-bandwidth work, like the factor gathers) and fed in
    as the first operand with an all-ones second operand, so the fused
    ``vals * brows * crows`` multiply and the conflict-resolving one-hot
    matmul run unchanged at the wider Kronecker rank.
    """
    if interpret is None:
        interpret = default_interpret()
    from repro.core.ttmc import kron_chain  # one column-order convention

    kron = kron_chain([factors[m][csf.other_ids[:, i]]
                       for i, m in enumerate(csf.other_modes)])
    width = kron.shape[-1]
    kron = _pad_lanes(kron)

    nblocks, block = csf.num_blocks, csf.block
    rp = kron.shape[-1]
    out = mttkrp_pallas_call(
        csf.row_ids.reshape(nblocks, block),
        csf.vals.reshape(nblocks, block),
        kron.reshape(nblocks, block, rp),
        jnp.ones((nblocks, block, rp), dtype=kron.dtype),
        csf.block_tile,
        num_row_tiles=csf.num_row_tiles,
        row_tile=csf.row_tile,
        interpret=interpret,
    )
    return out[: csf.num_rows, :width].astype(factors[0].dtype)


@partial(jax.jit, static_argnames=("mode", "interpret"))
def mttkrp_lin(lin: Linearized, factors: Sequence[Array], mode: int, *,
               interpret: Optional[bool] = None) -> Array:
    """MTTKRP for any mode from the single linearized workspace.

    On the sort mode the stream is already ordered and tile-aligned by the
    output row, so the Pallas one-hot segment-matmul kernel applies with the
    row decode moved *inside* the kernel (shift/mask on the packed hi/lo
    words); the factor-row gathers — which need the other modes' decoded
    coordinates — stay XLA-side like the CSF path.  On non-sort modes there
    is no block -> output-tile structure to exploit, so this follows ALTO's
    recompute path: decode + scatter-add in plain jnp (the pure reference
    impl), still from the same resident buffer with no re-sort.
    """
    if interpret is None:
        interpret = default_interpret()
    if mode != lin.sort_mode:  # static: sort_mode is pytree aux data
        from repro.core.mttkrp import mttkrp_linearized
        return mttkrp_linearized(lin, factors, mode)
    rank = factors[0].shape[1]
    om = [m for m in range(lin.order) if m != mode]
    brows = _pad_lanes(factors[om[0]][lin.decode(om[0])])
    crows = _pad_lanes(factors[om[1]][lin.decode(om[1])])
    for m in om[2:]:
        crows = crows * _pad_lanes(factors[m][lin.decode(m)])

    nblocks, block = lin.num_blocks, lin.block
    rp = brows.shape[-1]
    out = mttkrp_lin_pallas_call(
        lin.hi.reshape(nblocks, block),
        lin.lo.reshape(nblocks, block),
        lin.vals.reshape(nblocks, block),
        brows.reshape(nblocks, block, rp),
        crows.reshape(nblocks, block, rp),
        lin.block_tile,
        num_row_tiles=lin.num_row_tiles,
        row_tile=lin.row_tile,
        offset=lin.offsets[mode],
        width=lin.widths[mode],
        interpret=interpret,
    )
    return out[: lin.dims[mode], :rank].astype(factors[0].dtype)


@partial(jax.jit, static_argnames=("mode", "interpret"))
def ttmc_lin(lin: Linearized, factors: Sequence[Array], mode: int, *,
             interpret: Optional[bool] = None) -> Array:
    """Chain-of-modes TTMc from the linearized workspace (cf. ``ttmc``).

    Sort mode: the Kronecker chain of the other modes' factor rows is formed
    XLA-side and fed to the in-kernel-decode kernel with an all-ones second
    operand.  Non-sort modes fall back to the jnp decode + scatter reference.
    """
    if interpret is None:
        interpret = default_interpret()
    from repro.core.ttmc import kron_chain, ttmc_linearized
    if mode != lin.sort_mode:
        return ttmc_linearized(lin, factors, mode)

    om = [m for m in range(lin.order) if m != mode]
    kron = kron_chain([factors[m][lin.decode(m)] for m in om])
    width = kron.shape[-1]
    kron = _pad_lanes(kron)

    nblocks, block = lin.num_blocks, lin.block
    rp = kron.shape[-1]
    out = mttkrp_lin_pallas_call(
        lin.hi.reshape(nblocks, block),
        lin.lo.reshape(nblocks, block),
        lin.vals.reshape(nblocks, block),
        kron.reshape(nblocks, block, rp),
        jnp.ones((nblocks, block, rp), dtype=kron.dtype),
        lin.block_tile,
        num_row_tiles=lin.num_row_tiles,
        row_tile=lin.row_tile,
        offset=lin.offsets[mode],
        width=lin.widths[mode],
        interpret=interpret,
    )
    return out[: lin.dims[mode], :width].astype(factors[0].dtype)


@partial(jax.jit, static_argnames=("blk", "interpret"))
def syrk(a: Array, *, blk: int = 512,
         interpret: Optional[bool] = None) -> Array:
    """G = A^T A via the blocked Pallas kernel.  Returns (R, R)."""
    if interpret is None:
        interpret = default_interpret()
    rows, rank = a.shape
    ap = _pad_lanes(a)
    rows_p = -(-rows // blk) * blk
    if rows_p != rows:
        ap = jnp.pad(ap, ((0, rows_p - rows), (0, 0)))
    g = syrk_pallas_call(ap, blk=blk, interpret=interpret)
    return g[:rank, :rank].astype(a.dtype)
