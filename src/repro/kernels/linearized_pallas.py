"""Pallas TPU kernel: MTTKRP over the linearized workspace, in-kernel decode.

Same blocked one-hot segment-matmul as ``mttkrp_pallas.py`` — the stream is
sorted and tile-aligned by the sort mode's output row, so the output tile
stays VMEM-resident across consecutive grid steps and collisions inside a
block are resolved by the MXU matmul.  The one structural difference is the
row operand: instead of a pre-extracted ``rows`` array the kernel receives
the packed index's hi/lo uint32 words and recovers the output row *inside
the kernel* with the static shift/mask decode (``decode_field``) — the
ALTO move.  The decode is two or three integer vector ops per block on the
VPU, fully overlapped with the MXU matmul of the previous block, so the
mode-agnostic format costs essentially nothing on its sort mode.

(For non-sort modes the stream is not ordered by the output row and the
block -> tile map does not exist; those fall back to the jnp scatter impl —
see ``kernels/ops.mttkrp_lin``.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.linearized import decode_field

from .mttkrp_pallas import LANE, _compiler_params

Array = jax.Array


def _kernel(tile_map_ref, hi_ref, lo_ref, vals_ref, brows_ref, crows_ref,
            out_ref, *, row_tile: int, block: int, offset: int, width: int):
    b = pl.program_id(0)
    tile = tile_map_ref[b]
    prev_tile = tile_map_ref[jnp.maximum(b - 1, 0)]
    is_first_visit = jnp.logical_or(b == 0, tile != prev_tile)

    @pl.when(is_first_visit)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # in-kernel coordinate decode: static shift + mask on the packed words
    rows = decode_field(hi_ref[0], lo_ref[0], offset, width)  # (BLOCK,) int32

    # fused Khatri-Rao partial product: (BLOCK, R)
    prod = (
        vals_ref[0][:, None].astype(jnp.float32)
        * brows_ref[0].astype(jnp.float32)
        * crows_ref[0].astype(jnp.float32)
    )
    # one-hot segment matrix: S[m, n] = (rows[n] == tile*row_tile + m)
    local = rows - tile * row_tile  # (BLOCK,), in [0, row_tile)
    sel = (
        jax.lax.broadcasted_iota(jnp.int32, (row_tile, block), 0)
        == local[None, :]
    )
    out_ref[...] += jax.lax.dot(
        sel.astype(jnp.float32), prod, preferred_element_type=jnp.float32
    )


def mttkrp_lin_pallas_call(
    hi: Array,          # (nblocks, BLOCK) uint32 high words, sorted stream
    lo: Array,          # (nblocks, BLOCK) uint32 low words
    vals: Array,        # (nblocks, BLOCK)
    brows: Array,       # (nblocks, BLOCK, RP) gathered factor rows
    crows: Array,       # (nblocks, BLOCK, RP) gathered (pre-multiplied for
                        #  order > 3) remaining factor rows
    block_tile: Array,  # (nblocks,) int32 non-decreasing block -> tile map
    *,
    num_row_tiles: int,
    row_tile: int,
    offset: int,        # sort mode's bit field position in the packed index
    width: int,
    interpret: bool = True,
) -> Array:
    nblocks, block = hi.shape
    rp = brows.shape[-1]
    if rp % LANE:
        raise ValueError(f"rank must be padded to {LANE}, got {rp}")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda b, tm: (b, 0)),
            pl.BlockSpec((1, block), lambda b, tm: (b, 0)),
            pl.BlockSpec((1, block), lambda b, tm: (b, 0)),
            pl.BlockSpec((1, block, rp), lambda b, tm: (b, 0, 0)),
            pl.BlockSpec((1, block, rp), lambda b, tm: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, rp), lambda b, tm: (tm[b], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, row_tile=row_tile, block=block,
                          offset=offset, width=width),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_row_tiles * row_tile, rp),
                                       jnp.float32),
        compiler_params=_compiler_params(
            dimension_semantics=("arbitrary",),  # sequential: accumulation
        ),
        interpret=interpret,
    )(block_tile, hi, lo, vals, brows, crows)
    return out
