"""Pallas TPU kernel: blocked one-hot segment-matmul MTTKRP.

This is the TPU-native re-design of SPLATT's parallel MTTKRP (the paper's
critical kernel).  The CPU algorithm walks a CSF pointer tree with per-row
mutexes; on a TPU we instead exploit the MXU:

  * non-zeros arrive pre-sorted and *tile-aligned* (the unified ``CSF``
    workspace): every
    block of ``BLOCK`` non-zeros writes exactly one ``ROW_TILE x R`` output
    tile, and the block -> tile map is non-decreasing, so the output tile
    stays resident in VMEM across consecutive grid steps (sequential TPU
    grid) and is flushed exactly once;
  * output-row collisions *inside* a block are resolved by a one-hot
    "segment matrix" ``S[m, b] = (row[b] == tile_start + m)`` matmul:
    ``out_tile += S @ (vals * Brows * Crows)`` — the MXU's sum reduction
    performs, in hardware, what SPLATT's mutex pool / atomics serialize.
    This is the paper's sync-vs-atomic finding taken to its TPU conclusion:
    conflict resolution as dense compute instead of synchronization;
  * the elementwise Khatri-Rao product (vals x Brows x Crows) is fused into
    the kernel so the (nnz x R) partial-product tensor never round-trips
    HBM — only the gathered factor rows stream in.

VMEM budget per grid step (defaults BLOCK=512, ROW_TILE=128, R padded 128):
  brows + crows: 2 x 512 x 128 x 4B = 512 KiB
  one-hot + prod + out tile:   (128x512 + 512x128 + 128x128) x 4B = 576 KiB
comfortably inside a v5e core's ~16 MiB VMEM with double buffering.

The MXU work per step is a (128 x 512) @ (512 x 128) matmul — both dims
hardware-aligned (multiples of 128 / 8 sublanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells CompilerParams "TPUCompilerParams"
_compiler_params = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))

Array = jax.Array

LANE = 128  # TPU lane width: rank is padded to a multiple of this


def _kernel(tile_map_ref, rows_ref, vals_ref, brows_ref, crows_ref, out_ref,
            *, row_tile: int, block: int):
    b = pl.program_id(0)
    tile = tile_map_ref[b]
    prev_tile = tile_map_ref[jnp.maximum(b - 1, 0)]
    is_first_visit = jnp.logical_or(b == 0, tile != prev_tile)

    @pl.when(is_first_visit)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    # fused Khatri-Rao partial product: (BLOCK, R)
    prod = (
        vals_ref[0][:, None].astype(jnp.float32)
        * brows_ref[0].astype(jnp.float32)
        * crows_ref[0].astype(jnp.float32)
    )
    # one-hot segment matrix: S[m, n] = (rows[n] == tile*row_tile + m)
    local = rows_ref[0] - tile * row_tile  # (BLOCK,), in [0, row_tile)
    sel = (
        jax.lax.broadcasted_iota(jnp.int32, (row_tile, block), 0)
        == local[None, :]
    )
    # MXU: collisions inside the block are summed by the matmul itself.
    out_ref[...] += jax.lax.dot(
        sel.astype(jnp.float32), prod, preferred_element_type=jnp.float32
    )


def mttkrp_pallas_call(
    rows: Array,        # (nblocks, BLOCK) int32, tile-aligned sorted rows
    vals: Array,        # (nblocks, BLOCK)
    brows: Array,       # (nblocks, BLOCK, RP) gathered factor rows
    crows: Array,       # (nblocks, BLOCK, RP) gathered (and pre-multiplied
                        #  for order > 3) remaining factor rows
    block_tile: Array,  # (nblocks,) int32 non-decreasing block -> tile map
    *,
    num_row_tiles: int,
    row_tile: int,
    interpret: bool = True,  # CPU container: interpret by default
) -> Array:
    nblocks, block = rows.shape
    rp = brows.shape[-1]
    if rp % LANE:
        raise ValueError(f"rank must be padded to {LANE}, got {rp}")

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nblocks,),
        in_specs=[
            pl.BlockSpec((1, block), lambda b, tm: (b, 0)),
            pl.BlockSpec((1, block), lambda b, tm: (b, 0)),
            pl.BlockSpec((1, block, rp), lambda b, tm: (b, 0, 0)),
            pl.BlockSpec((1, block, rp), lambda b, tm: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((row_tile, rp), lambda b, tm: (tm[b], 0)),
    )
    out = pl.pallas_call(
        functools.partial(_kernel, row_tile=row_tile, block=block),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((num_row_tiles * row_tile, rp), jnp.float32),
        compiler_params=_compiler_params(
            dimension_semantics=("arbitrary",),  # sequential: accumulation
        ),
        interpret=interpret,
    )(block_tile, rows, vals, brows, crows)
    return out
