"""Pallas TPU kernels for the paper's compute hot-spots (MTTKRP, syrk).

Kernels target TPU (pl.pallas_call + BlockSpec VMEM tiling) and are validated
in interpret mode on CPU against the pure-jnp oracles in ref.py.
"""
from . import ops, ref
from .mttkrp_pallas import mttkrp_pallas_call, LANE
from .syrk_pallas import syrk_pallas_call

__all__ = ["ops", "ref", "mttkrp_pallas_call", "syrk_pallas_call", "LANE"]
