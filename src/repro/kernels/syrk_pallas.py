"""Pallas TPU kernel: blocked syrk (G = A^T A) for tall-skinny factors.

The paper's "Mat A^TA" routine is BLAS syrk via OpenBLAS; on TPU the
tall-skinny (I x R, R <= a few hundred) Gram product is a reduction over row
blocks that fits the MXU directly.  Grid is the row-block index; the single
R x R output tile stays in VMEM across all steps and accumulates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax < 0.5 spells CompilerParams "TPUCompilerParams"
_compiler_params = getattr(pltpu, "CompilerParams",
                           getattr(pltpu, "TPUCompilerParams", None))

Array = jax.Array


def _kernel(a_ref, out_ref):
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    blk = a_ref[...].astype(jnp.float32)  # (BLK, RP)
    out_ref[...] += jax.lax.dot(
        blk.T, blk, preferred_element_type=jnp.float32
    )


def syrk_pallas_call(a: Array, *, blk: int = 512, interpret: bool = True) -> Array:
    rows, rp = a.shape
    if rows % blk:
        raise ValueError(f"rows ({rows}) must be padded to blk ({blk})")
    nblocks = rows // blk
    out = pl.pallas_call(
        _kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((blk, rp), lambda k: (k, 0))],
        out_specs=pl.BlockSpec((rp, rp), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, rp), jnp.float32),
        compiler_params=_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(a)
    return out
