"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.csf import CSF

Array = jax.Array


def mttkrp_ref(csf: CSF, factors: Sequence[Array]) -> Array:
    """Segment-sum oracle over the unified workspace.

    Padding entries carry val == 0 and point at a valid row inside their
    tile, so they contribute exact zeros — the oracle needs no masking.
    (The layout guarantees globally sorted row_ids, but the oracle
    deliberately does not rely on that invariant.)
    """
    prod = csf.vals[:, None].astype(jnp.float32)
    for i, m in enumerate(csf.other_modes):
        prod = prod * factors[m][csf.other_ids[:, i]].astype(jnp.float32)
    seg = jax.ops.segment_sum(prod, csf.row_ids, num_segments=csf.num_rows)
    return seg


def ttmc_ref(csf: CSF, factors: Sequence[Array]) -> Array:
    """Segment-sum oracle for the TTMc kernel (Kronecker-chain analogue of
    :func:`mttkrp_ref`; same no-masking padding argument)."""
    from repro.core.ttmc import kron_chain  # one column-order convention

    kron = kron_chain([factors[m][csf.other_ids[:, i]].astype(jnp.float32)
                       for i, m in enumerate(csf.other_modes)])
    prod = csf.vals[:, None].astype(jnp.float32) * kron
    return jax.ops.segment_sum(prod, csf.row_ids, num_segments=csf.num_rows)


def syrk_ref(a: Array) -> Array:
    af = a.astype(jnp.float32)
    return af.T @ af
