"""Continuous-batching request queue: coalesce, pad, execute, resolve.

The contract with callers: ``submit`` returns a ``concurrent.futures.
Future`` immediately; worker threads drain the queue, coalesce requests
into batches, and resolve the futures.  Coalescing is what buys the
throughput — one jitted dispatch over a padded bucket instead of N tiny
dispatches — so the batching rules matter:

* Requests coalesce only within a **(tenant, kind, k)** group: mixing
  tenants would mix models, mixing kinds would mix output shapes, and k
  is a static jit argument.

* A batch closes when it **fills the largest bucket** or the **coalescing
  window expires** — ``max_wait_ms`` measured from the FIRST request in
  the batch, so the first caller's latency bounds everyone's wait and a
  trickle of singleton queries never stalls longer than the window.

* The registry entry is resolved **at execution time**, not submit time.
  That is the hot-swap guarantee: a ``publish`` between submit and
  execute means the batch runs on the NEW model; a publish DURING
  execution doesn't touch the already-resolved handle.  Either way no
  in-flight future is dropped.

Results are materialized host-side (numpy) before futures resolve, so
the ``serve.<tenant>.query_ms`` histogram records honest device-complete
latency (enqueue -> result materialized), not dispatch time.  Payloads
and result slicing stay in numpy for the same reason padding does (see
``queries.pad_rows``): batch-dependent shapes must never become eager
device ops, or every novel coalesced size pays a one-off XLA compile.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Optional, Sequence

import numpy as np

from repro.obs.metrics import get_registry

from .queries import QUERY_KINDS
from .registry import DEFAULT_BUCKETS, ModelRegistry


@dataclass
class _Request:
    """One submitted query awaiting a batch slot."""

    tenant: str
    kind: str  # one of QUERY_KINDS
    payload: np.ndarray  # (n, order) coords or (n,) user ids
    n: int
    future: Future = field(default_factory=Future)
    k: int = 0  # static top_k width; 0 for values_at
    t_enqueue: float = field(default_factory=time.monotonic)

    @property
    def key(self) -> tuple:
        return (self.tenant, self.kind, self.k)


class BatchQueue:
    """Request queue + coalescing worker threads over a ModelRegistry."""

    def __init__(self, registry: ModelRegistry, *,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_wait_ms: float = 2.0, workers: int = 1):
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        self.registry = registry
        self.buckets = tuple(sorted(int(b) for b in buckets))
        self.max_wait_s = float(max_wait_ms) / 1e3
        self._pending: deque[_Request] = deque()
        self._cond = threading.Condition()
        self._stopping = False
        self.batches_executed = 0
        self._threads = [
            threading.Thread(target=self._worker_loop,
                             name=f"repro-serve-worker-{i}", daemon=True)
            for i in range(workers)]
        for t in self._threads:
            t.start()

    # -- submit ------------------------------------------------------------
    def submit(self, tenant: str, kind: str, payload, *,
               k: int = 0) -> Future:
        if kind not in QUERY_KINDS:
            raise ValueError(f"unknown query kind {kind!r}; "
                             f"expected one of {QUERY_KINDS}")
        payload = np.asarray(payload, dtype=np.int32)
        if kind == "values_at" and payload.ndim != 2:
            raise ValueError(
                f"values_at expects (n, order) coords, got {payload.shape}")
        if kind == "top_k":
            if payload.ndim != 1:
                raise ValueError(
                    f"top_k expects a 1-d user batch, got {payload.shape}")
            if k < 1:
                raise ValueError(f"top_k needs k >= 1, got {k}")
        req = _Request(tenant=tenant, kind=kind, payload=payload,
                       n=int(payload.shape[0]), k=int(k))
        with self._cond:
            if self._stopping:
                raise RuntimeError("BatchQueue is stopped")
            self._pending.append(req)
            get_registry().gauge("serve.queue.depth").set(len(self._pending))
            self._cond.notify()
        return req.future

    # -- worker ------------------------------------------------------------
    def _worker_loop(self) -> None:
        while True:
            batch = self._next_batch()
            if batch is None:
                return
            self._execute(batch)

    def _next_batch(self) -> Optional[list[_Request]]:
        """Block for a first request, then coalesce same-key requests until
        the largest bucket fills or the first request's window expires."""
        with self._cond:
            while not self._pending:
                if self._stopping:
                    return None
                self._cond.wait()
            first = self._pending.popleft()
            batch = [first]
            budget = self.buckets[-1] - first.n
            deadline = first.t_enqueue + self.max_wait_s
            while budget > 0:
                self._collect(batch, first.key, budget)
                budget = self.buckets[-1] - sum(r.n for r in batch)
                if budget <= 0 or self._stopping:
                    break
                if self._pending:
                    # only OTHER-key work is queued (matching requests were
                    # just collected) — idling the device through the
                    # window would starve it, so execute what we have
                    break
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                self._cond.wait(timeout=remaining)
            get_registry().gauge("serve.queue.depth").set(len(self._pending))
            return batch

    def _collect(self, batch: list[_Request], key: tuple,
                 budget: int) -> None:
        """Pull every pending same-key request that still fits (called with
        the lock held)."""
        kept: deque[_Request] = deque()
        while self._pending:
            req = self._pending.popleft()
            if req.key == key and req.n <= budget:
                batch.append(req)
                budget -= req.n
            else:
                kept.append(req)
        self._pending.extend(kept)

    def _execute(self, batch: list[_Request]) -> None:
        first = batch[0]
        reg = get_registry()
        try:
            # resolve the tenant NOW: a hot-swap before this point serves
            # the new model, one after it finishes on this handle
            model = self.registry.get(first.tenant).model
            merged = batch[0].payload if len(batch) == 1 else \
                np.concatenate([r.payload for r in batch], axis=0)
            # TenantModel returns synced numpy (it materializes results
            # host-side), so resolved futures hold device-complete values
            if first.kind == "values_at":
                out = model.values_at(merged)
            else:
                out = model.top_k(merged, first.k)
        except BaseException as exc:  # noqa: BLE001 — delivered via futures
            for req in batch:
                if not req.future.set_running_or_notify_cancel():
                    continue
                req.future.set_exception(exc)
            return
        done = time.monotonic()
        self.batches_executed += 1
        lat = reg.histogram(f"serve.{first.tenant}.query_ms")
        queries = reg.counter(f"serve.{first.tenant}.queries")
        off = 0
        for req in batch:
            if len(batch) == 1:
                res = out
            elif first.kind == "values_at":
                res = out[off:off + req.n]
            else:
                res = (out[0][off:off + req.n], out[1][off:off + req.n])
            off += req.n
            lat.observe((done - req.t_enqueue) * 1e3)
            queries.inc()
            if req.future.set_running_or_notify_cancel():
                req.future.set_result(res)

    # -- lifecycle ---------------------------------------------------------
    def stop(self, *, drain: bool = True) -> None:
        """Stop the workers.  With ``drain`` (default) every already-
        submitted future still resolves before the threads exit; without
        it, pending requests get a RuntimeError."""
        with self._cond:
            self._stopping = True
            if not drain:
                while self._pending:
                    req = self._pending.popleft()
                    if req.future.set_running_or_notify_cancel():
                        req.future.set_exception(
                            RuntimeError("BatchQueue stopped before "
                                         "this request was served"))
            self._cond.notify_all()
        for t in self._threads:
            t.join(timeout=30.0)

    def depth(self) -> int:
        with self._cond:
            return len(self._pending)
