"""The serving query vocabulary: batched ``values_at`` + ``top_k_for_user``.

A fitted decomposition answers two kinds of production queries:

* **values_at** — reconstruct the tensor at a coordinate batch (the query
  ``ServeHandle`` has always served).  This module adds the bucketed-
  padding helper (:func:`pad_rows`, :func:`bucket_for`) the server uses so
  every call lands on one of a fixed set of batch shapes and each shape
  jits exactly once.

* **top_k_for_user** — the flagship recommendation query: score ONE user
  row against ALL items and return the k best.  For a rank-R CP model the
  whole non-user/non-item structure collapses into a single per-rank
  weight vector (lambda Hadamard the column sums of every remaining
  factor), so a batch of users is one GEMM against the item factor:

      score[u, i] = sum_r (A_user[u, r] * w_r) * A_item[i, r]
      w_r         = lambda_r * prod_{m not in {user, item}} sum_j A_m[j, r]

  i.e. the reconstruction summed (marginalized) over every remaining
  mode.  For Tucker the same marginalization contracts the core with the
  other factors' column sums down to an (R_user, R_item) matrix ``B`` and
  scores are ``(U_user[users] @ B) @ U_item.T``.  Either way: one small
  GEMM over the Khatri-Rao-collapsed non-user factors, then
  ``jax.lax.top_k`` — jitted once per (user-batch bucket, k) shape.

Factors on a served decomposition live in the tensor's ORIGINAL label
space (``Ingested.restore`` maps them back after a reordered fit), so the
item ids returned here are original labels; rows compaction dropped come
back as zero factor rows and rank last.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

QUERY_KINDS = ("values_at", "top_k")


# ---------------------------------------------------------------------------
# bucketed padding
# ---------------------------------------------------------------------------


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """The smallest bucket >= n (callers chunk anything beyond the largest
    bucket, so asking for more is a bug here, not a silent spill)."""
    for b in buckets:
        if n <= b:
            return int(b)
    raise ValueError(f"batch of {n} exceeds the largest bucket "
                     f"{max(buckets)}; chunk before bucketing")


def pad_rows(x, n_rows: int):
    """Zero-pad the leading axis up to ``n_rows`` (a no-op at size).
    Zeros are valid padding for both query kinds: coordinate (0, ..., 0)
    reconstructs fine and user 0 scores fine — padded results are sliced
    away before anyone sees them.

    Padding is HOST-side numpy on purpose: every novel (n, pad) shape
    combination fed to ``jnp.concatenate`` costs a one-off eager-op XLA
    compile (~15ms), which is exactly the tail spike bucketing exists to
    avoid.  Only the fixed bucket shapes should ever reach the device."""
    x = np.asarray(x)
    pad = n_rows - x.shape[0]
    if pad <= 0:
        return x
    return np.concatenate(
        [x, np.zeros((pad,) + x.shape[1:], dtype=x.dtype)], axis=0)


# ---------------------------------------------------------------------------
# top-k scoring
# ---------------------------------------------------------------------------


def make_score_fn(decomp, *, user_mode: int = 0,
                  item_mode: int = 1) -> Callable[[Array], Array]:
    """``score(users) -> (n_users, n_items)`` marginal scores for a CP
    (``lmbda``) or Tucker (``core``) decomposition.  Everything that does
    not depend on the user batch — the weight vector / the contracted core
    — is computed once here, outside the per-query jit."""
    if not hasattr(decomp, "factors") or not (
            hasattr(decomp, "lmbda") or hasattr(decomp, "core")):
        raise TypeError(
            f"top_k needs a CP (lmbda) or Tucker (core) decomposition, got "
            f"{type(decomp).__name__}")
    order = len(decomp.factors)
    if user_mode == item_mode or not (0 <= user_mode < order
                                      and 0 <= item_mode < order):
        raise ValueError(
            f"user_mode={user_mode} / item_mode={item_mode} must be two "
            f"distinct modes of an order-{order} decomposition")
    user_f = decomp.factors[user_mode]
    item_f = decomp.factors[item_mode]
    others = [m for m in range(order) if m not in (user_mode, item_mode)]

    if hasattr(decomp, "lmbda"):  # CP family
        weights = decomp.lmbda
        for m in others:
            weights = weights * jnp.sum(decomp.factors[m], axis=0)

        def score(users: Array) -> Array:
            return (user_f[users] * weights[None, :]) @ item_f.T

        return score

    if hasattr(decomp, "core"):  # Tucker
        letters = "abcdefgh"[:order]
        operands = [decomp.core]
        terms = [letters]
        for m in others:
            operands.append(jnp.sum(decomp.factors[m], axis=0))
            terms.append(letters[m])
        eq = (",".join(terms) + "->"
              + letters[user_mode] + letters[item_mode])
        b_mat = jnp.einsum(eq, *operands)  # (R_user, R_item)

        def score(users: Array) -> Array:
            return (user_f[users] @ b_mat) @ item_f.T

        return score

    raise TypeError(  # unreachable: the guard above covers both families
        f"top_k needs a CP (lmbda) or Tucker (core) decomposition, got "
        f"{type(decomp).__name__}")


def make_top_k_fn(decomp, *, user_mode: int = 0, item_mode: int = 1):
    """``top_k(users, k) -> (scores (n, k), items (n, k))`` over a user
    batch; ``k`` must be static under jit (``jax.jit(fn,
    static_argnums=1)`` — the registry's :class:`TenantModel` owns that
    cache so each (bucket, k) shape compiles once)."""
    score = make_score_fn(decomp, user_mode=user_mode, item_mode=item_mode)
    n_items = int(decomp.factors[item_mode].shape[0])

    def top_k(users: Array, k: int):
        return jax.lax.top_k(score(users), min(int(k), n_items))

    return top_k


def resident_bytes(decomp) -> int:
    """The decomposition's resident-memory footprint: factor matrices plus
    the CP weight vector / Tucker core — what the registry's eviction
    budget accounts."""
    total = sum(f.size * f.dtype.itemsize for f in decomp.factors)
    for attr in ("lmbda", "core"):
        arr = getattr(decomp, attr, None)
        if arr is not None:
            total += arr.size * arr.dtype.itemsize
    return int(total)
