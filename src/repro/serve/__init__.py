"""repro.serve — async continuous-batching, multi-tenant decomposition
serving.

The production-consumption half of the reproduction: fitted CP/Tucker
decompositions become queryable models behind a batching server.

    queries.py   query vocabulary (values_at, top_k) + bucketed padding
    registry.py  multi-tenant residency: hot-swap, LRU byte-budget eviction
    queue.py     request queue + coalescing worker threads (futures out)
    server.py    DecompServer front door + ServeDaemon HTTP frontend
"""
from .queries import (QUERY_KINDS, bucket_for, make_score_fn, make_top_k_fn,
                      pad_rows, resident_bytes)
from .queue import BatchQueue
from .registry import DEFAULT_BUCKETS, ModelRegistry, TenantEntry, TenantModel
from .server import DecompServer, ServeDaemon

__all__ = [
    "QUERY_KINDS", "DEFAULT_BUCKETS",
    "bucket_for", "pad_rows", "make_score_fn", "make_top_k_fn",
    "resident_bytes",
    "ModelRegistry", "TenantModel", "TenantEntry",
    "BatchQueue", "DecompServer", "ServeDaemon",
]
