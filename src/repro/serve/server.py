"""`DecompServer`: the serving front door, plus the HTTP daemon.

``DecompServer`` composes a :class:`ModelRegistry` (resident models,
hot-swap, eviction) with a :class:`BatchQueue` (coalescing workers) and
speaks the query vocabulary:

    server = DecompServer.from_config(cfg.serve)
    server.publish("default", session.fit())
    vals = server.values_at("default", coords)            # blocking
    fut = server.submit_top_k("default", users, k=10)     # async
    scores, items = server.top_k_for_user("default", user=3, k=10)

``ServeDaemon`` puts that behind HTTP for the CLI (`python -m repro
serve-daemon`) and CI smoke: ``/healthz``, ``/metrics`` (Prometheus,
same renderer the live-fit exposition uses), ``/v1/tenants``,
``/v1/top_k?tenant=&user=&k=``, ``/v1/values_at`` (POST), and
``/v1/shutdown`` (POST) for clean scripted teardown.

Throughput is tracked as a trailing-window ``serve.qps`` gauge updated
on every completed call, so a scrape mid-load sees the live rate.
"""
from __future__ import annotations

import json
import threading
import time
from collections import deque
from concurrent.futures import Future
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional, Sequence
from urllib.parse import parse_qs, urlparse

import jax.numpy as jnp

from repro.obs.exposition import render_prometheus
from repro.obs.metrics import get_registry

from .queue import BatchQueue
from .registry import DEFAULT_BUCKETS, ModelRegistry

_QPS_WINDOW_S = 5.0


class _QpsMeter:
    """Trailing-window completions-per-second, published as a gauge."""

    def __init__(self, window_s: float = _QPS_WINDOW_S):
        self.window_s = window_s
        self._lock = threading.Lock()
        self._ticks: deque[float] = deque()

    def tick(self, n: int = 1) -> None:
        now = time.monotonic()
        with self._lock:
            self._ticks.extend([now] * n)
            cut = now - self.window_s
            while self._ticks and self._ticks[0] < cut:
                self._ticks.popleft()
            span = max(now - self._ticks[0], 1e-9) if self._ticks else 1.0
            get_registry().gauge("serve.qps").set(len(self._ticks) / span)


class DecompServer:
    """Multi-tenant continuous-batching server over fitted decompositions."""

    def __init__(self, *, buckets: Sequence[int] = DEFAULT_BUCKETS,
                 max_wait_ms: float = 2.0, workers: int = 1,
                 budget_bytes: Optional[int] = None):
        self.registry = ModelRegistry(budget_bytes=budget_bytes,
                                      buckets=buckets)
        self.queue = BatchQueue(self.registry, buckets=buckets,
                                max_wait_ms=max_wait_ms, workers=workers)
        self._qps = _QpsMeter()
        self._closed = False

    @classmethod
    def from_config(cls, serve_cfg) -> "DecompServer":
        return cls(buckets=serve_cfg.buckets,
                   max_wait_ms=serve_cfg.max_wait_ms,
                   workers=serve_cfg.workers,
                   budget_bytes=int(serve_cfg.max_resident_mb * 2**20))

    # -- tenancy -----------------------------------------------------------
    def publish(self, tenant: str, decomp,
                dims: Optional[Sequence[int]] = None, *,
                warmup: bool = True):
        """(Re)publish a tenant's model; in-flight queries on the old model
        finish on the old handle."""
        entry = self.registry.publish(tenant, decomp, dims)
        if warmup:
            entry.model.warmup()
        return entry

    def tenants(self) -> dict[str, dict]:
        return self.registry.tenants()

    # -- queries -----------------------------------------------------------
    def submit_values_at(self, tenant: str, coords) -> Future:
        return self.queue.submit(tenant, "values_at", coords)

    def values_at(self, tenant: str, coords):
        out = self.submit_values_at(tenant, coords).result()
        self._qps.tick()
        return out

    def submit_top_k(self, tenant: str, users, *, k: int) -> Future:
        return self.queue.submit(tenant, "top_k", users, k=k)

    def top_k(self, tenant: str, users, *, k: int):
        out = self.submit_top_k(tenant, users, k=k).result()
        self._qps.tick()
        return out

    def top_k_for_user(self, tenant: str, user: int, *, k: int):
        """The flagship recommendation query: ``(scores (k,), items (k,))``
        for one user, item ids in ORIGINAL labels."""
        scores, items = self.top_k(tenant, jnp.asarray([int(user)]), k=k)
        return scores[0], items[0]

    # -- introspection / lifecycle ----------------------------------------
    def stats(self) -> dict:
        return {"tenants": self.tenants(),
                "queue_depth": self.queue.depth(),
                "batches_executed": self.queue.batches_executed,
                "resident_bytes": self.registry.resident_bytes()}

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self.queue.stop(drain=True)

    def __enter__(self) -> "DecompServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class ServeDaemon:
    """HTTP frontend around a :class:`DecompServer` (stdlib-only, same
    ThreadingHTTPServer pattern as ``repro.obs`` exposition)."""

    def __init__(self, server: DecompServer, *, port: int = 0,
                 host: str = "127.0.0.1"):
        self.decomp = server
        self.shutdown_requested = threading.Event()
        daemon = self

        class _Handler(BaseHTTPRequestHandler):
            def log_message(self, *args):  # quiet
                pass

            def _send(self, status: int, content_type: str,
                      body: bytes) -> None:
                self.send_response(status)
                self.send_header("Content-Type", content_type)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def _json(self, status: int, obj) -> None:
                self._send(status, "application/json",
                           (json.dumps(obj) + "\n").encode())

            def do_GET(self):
                url = urlparse(self.path)
                try:
                    if url.path == "/healthz":
                        self._json(200, {"status": "serving",
                                         **daemon.decomp.stats()})
                    elif url.path == "/metrics":
                        body = render_prometheus(get_registry().snapshot())
                        self._send(200, "text/plain; version=0.0.4",
                                   body.encode())
                    elif url.path == "/v1/tenants":
                        self._json(200, daemon.decomp.tenants())
                    elif url.path == "/v1/top_k":
                        q = parse_qs(url.query)
                        tenant = q.get("tenant", ["default"])[0]
                        user = int(q["user"][0])
                        k = int(q.get("k", ["10"])[0])
                        scores, items = daemon.decomp.top_k_for_user(
                            tenant, user, k=k)
                        self._json(200, {
                            "tenant": tenant, "user": user, "k": k,
                            "items": [int(i) for i in items],
                            "scores": [float(s) for s in scores]})
                    else:
                        self._json(404, {"error": f"no route {url.path}"})
                except KeyError as exc:
                    self._json(404, {"error": str(exc)})
                except (ValueError, TypeError) as exc:
                    self._json(400, {"error": str(exc)})

            def do_POST(self):
                url = urlparse(self.path)
                try:
                    if url.path == "/v1/shutdown":
                        daemon.shutdown_requested.set()
                        self._json(200, {"status": "shutting down"})
                    elif url.path == "/v1/values_at":
                        n = int(self.headers.get("Content-Length", 0))
                        req = json.loads(self.rfile.read(n) or b"{}")
                        tenant = req.get("tenant", "default")
                        coords = req["coords"]
                        vals = daemon.decomp.values_at(tenant, coords)
                        self._json(200, {
                            "tenant": tenant,
                            "values": [float(v) for v in vals]})
                    else:
                        self._json(404, {"error": f"no route {url.path}"})
                except KeyError as exc:
                    self._json(404, {"error": str(exc)})
                except (ValueError, TypeError,
                        json.JSONDecodeError) as exc:
                    self._json(400, {"error": str(exc)})

        self._http = ThreadingHTTPServer((host, port), _Handler)
        self._http.daemon_threads = True
        self.host, self.port = self._http.server_address[:2]
        self._thread = threading.Thread(target=self._http.serve_forever,
                                        name="repro-serve-daemon",
                                        daemon=True)

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ServeDaemon":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._http.shutdown()
        self._http.server_close()
        self._thread.join(timeout=10.0)

    def serve_until_shutdown(self, *, duration_s: Optional[float] = None,
                             poll_s: float = 0.2) -> None:
        """Block until ``POST /v1/shutdown`` (or the optional duration)."""
        deadline = (time.monotonic() + duration_s
                    if duration_s is not None else None)
        while not self.shutdown_requested.is_set():
            if deadline is not None and time.monotonic() >= deadline:
                return
            self.shutdown_requested.wait(timeout=poll_s)

    def __enter__(self) -> "ServeDaemon":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()
