"""Multi-tenant model registry: resident decompositions, hot-swap, LRU.

A serving process holds MANY fitted decompositions — one per tenant — and
re-fits replace them while queries are in flight.  The registry makes the
three hard parts explicit:

* **Hot swap is atomic handle replacement.**  ``publish(tenant, decomp)``
  builds the new :class:`TenantModel` (jit caches and all) OUTSIDE the
  registry lock, then swaps the entry in one dict assignment.  A worker
  that already resolved the old entry keeps its reference and finishes on
  the old handle; the next batch resolves the new one.  Nothing is ever
  mutated in place.

* **Per-bucket jit caches live with the model.**  ``TenantModel`` owns one
  jitted ``values_at`` (compiled once per bucket shape) and one jitted
  ``top_k`` per static k (compiled once per (bucket, k)).  The model
  counts its own trace events (``compile_count`` — the wrapped function
  body only runs while jax traces), which is how the tests pin
  "never more than one variant per bucket" without monkeypatching jax.

* **Eviction is an explicit byte budget.**  Every model's resident bytes
  (factors + lambda/core) are accounted; when a publish pushes the total
  over ``budget_bytes`` the least-recently-USED tenants are evicted until
  it fits (the tenant just published is never the victim — publishing is
  a use).  A single model larger than the whole budget stays resident:
  serving nothing is worse than over-budget, and the metrics say so.

Metrics (``repro.obs``): ``serve.registry.models`` /
``serve.registry.resident_bytes`` gauges, ``serve.registry.swaps`` /
``serve.registry.evictions`` counters.
"""
from __future__ import annotations

import dataclasses
import itertools
import threading
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs.metrics import get_registry

from .queries import (bucket_for, make_top_k_fn, pad_rows, resident_bytes)

Array = jax.Array

DEFAULT_BUCKETS = (16, 64, 256)


class TenantModel:
    """One resident decomposition with its per-bucket jit caches.

    ``values_at(coords)`` and ``top_k(users, k)`` accept ANY batch size:
    the batch is chunked at the largest bucket, each chunk zero-padded up
    to its bucket, and results are sliced back — so the jitted functions
    only ever see bucket shapes and each shape compiles exactly once
    (``compile_count`` proves it).  Immutable once built: a re-fit builds
    a new model and the registry swaps handles."""

    def __init__(self, decomp, dims: tuple[int, ...], *,
                 buckets: Sequence[int] = DEFAULT_BUCKETS,
                 user_mode: int = 0, item_mode: int = 1):
        self.decomp = decomp
        self.dims = tuple(int(d) for d in dims)
        self.buckets = tuple(sorted(int(b) for b in buckets))
        self.user_mode = user_mode
        self.item_mode = item_mode
        self.nbytes = resident_bytes(decomp)
        self.compile_count = {"values_at": 0, "top_k": 0}
        # the scoring closure is built OUTSIDE any trace: its per-rank
        # weights / contracted core must be concrete constants, not
        # tracers staged by a jit in progress
        self._top_k_raw = make_top_k_fn(decomp, user_mode=user_mode,
                                        item_mode=item_mode)
        self._values_fn = jax.jit(self._traced_values)
        self._top_k_fn = jax.jit(self._traced_top_k, static_argnums=1)

    # the wrapped bodies run in Python only while jax traces a new input
    # signature, so these counters ARE the per-model compile counts
    def _traced_values(self, coords: Array) -> Array:
        self.compile_count["values_at"] += 1
        return self.decomp.values_at(coords)

    def _traced_top_k(self, users: Array, k: int):
        self.compile_count["top_k"] += 1
        return self._top_k_raw(users, k)

    def _bucketed(self, x, fn, *fn_args):
        """Chunk-at-max-bucket -> pad-to-bucket -> call -> slice; records
        the real/padded fill ratio per jitted call.

        All batching logistics — chunking, padding, result slicing and
        re-assembly — happen HOST-side in numpy.  Only the fixed bucket
        shapes ever reach the jitted functions: eager device ops on
        batch-dependent shapes (``o[:take]``, odd-size concatenates) each
        cost a one-off XLA compile, which is the tail latency bucketing
        exists to kill.  Results come back as (synced) numpy arrays."""
        n = int(x.shape[0])
        fill = get_registry().histogram("serve.batch_fill")
        if n in self.buckets:
            # exact-bucket fast path (the common case under continuous
            # batching): no pad, no slice
            fill.observe(1.0)
            return jax.tree_util.tree_map(np.asarray, fn(x, *fn_args))
        outs = []
        off = 0
        while off < n:
            take = min(n - off, self.buckets[-1])
            b = bucket_for(take, self.buckets)
            out = fn(pad_rows(x[off:off + take], b), *fn_args)
            fill.observe(take / b)
            outs.append(jax.tree_util.tree_map(
                lambda o: np.asarray(o)[:take], out))
            off += take
        if len(outs) == 1:
            return outs[0]
        return jax.tree_util.tree_map(
            lambda *xs: np.concatenate(xs, axis=0), *outs)

    def values_at(self, coords):
        """Reconstructed values (n,) as a numpy array."""
        coords = np.asarray(coords, dtype=np.int32)
        return self._bucketed(coords, self._values_fn)

    def top_k(self, users, k: int):
        """(scores (n, k), items (n, k)) — item ids in ORIGINAL labels."""
        users = np.asarray(users, dtype=np.int32)
        return self._bucketed(users, self._top_k_fn, int(k))

    def warmup(self) -> None:
        """Compile the smallest values_at bucket up front so the first
        real query pays dispatch, not tracing."""
        b = self.buckets[0]
        order = len(self.dims)
        jax.block_until_ready(
            self._values_fn(jnp.zeros((b, order), dtype=jnp.int32)))


@dataclasses.dataclass
class TenantEntry:
    """Registry slot: the immutable model plus the mutable bookkeeping the
    registry updates under its own lock."""

    tenant: str
    model: TenantModel
    generation: int
    last_used: int = 0


class ModelRegistry:
    """Named resident :class:`TenantModel` handles with atomic hot-swap
    and LRU byte-budget eviction."""

    def __init__(self, *, budget_bytes: Optional[int] = None,
                 buckets: Sequence[int] = DEFAULT_BUCKETS):
        self.budget_bytes = budget_bytes
        self.buckets = tuple(sorted(int(b) for b in buckets))
        self._lock = threading.Lock()
        self._entries: dict[str, TenantEntry] = {}
        self._clock = itertools.count(1)
        self.evicted: list[str] = []  # names only, for error messages

    # -- publish / resolve -------------------------------------------------
    def publish(self, tenant: str, decomp, dims: Optional[Sequence[int]] = None,
                *, user_mode: int = 0, item_mode: int = 1) -> TenantEntry:
        """Make ``decomp`` the tenant's serving model.  The model (and its
        jit caches) is built before the lock is taken; the swap itself is
        one assignment, so readers see either the old complete entry or
        the new complete entry, never a half-built one."""
        if dims is None:
            dims = tuple(int(f.shape[0]) for f in decomp.factors)
        model = TenantModel(decomp, tuple(dims), buckets=self.buckets,
                            user_mode=user_mode, item_mode=item_mode)
        with self._lock:
            old = self._entries.get(tenant)
            entry = TenantEntry(tenant=tenant, model=model,
                                generation=(old.generation + 1) if old else 1,
                                last_used=next(self._clock))
            self._entries[tenant] = entry
            if tenant in self.evicted:
                self.evicted.remove(tenant)
            if old is not None:
                get_registry().counter("serve.registry.swaps").inc()
            self._evict_over_budget(keep=tenant)
            self._record_gauges()
        return entry

    def get(self, tenant: str) -> TenantEntry:
        """Resolve a tenant (bumps its LRU clock).  Raises ``KeyError``
        naming the resident set — and whether the tenant was evicted —
        when absent."""
        with self._lock:
            entry = self._entries.get(tenant)
            if entry is None:
                state = ("evicted (over the resident-bytes budget)"
                         if tenant in self.evicted else "not published")
                raise KeyError(
                    f"tenant {tenant!r} is {state}; resident: "
                    f"{sorted(self._entries)}")
            entry.last_used = next(self._clock)
            return entry

    def drop(self, tenant: str) -> bool:
        with self._lock:
            removed = self._entries.pop(tenant, None) is not None
            self._record_gauges()
        return removed

    # -- accounting --------------------------------------------------------
    def resident_bytes(self) -> int:
        with self._lock:
            return self._resident_bytes_locked()

    def _resident_bytes_locked(self) -> int:
        return sum(e.model.nbytes for e in self._entries.values())

    def _evict_over_budget(self, *, keep: str) -> None:
        if self.budget_bytes is None:
            return
        evictions = get_registry().counter("serve.registry.evictions")
        while self._resident_bytes_locked() > self.budget_bytes:
            victims = [e for t, e in self._entries.items() if t != keep]
            if not victims:
                # the kept model alone exceeds the budget: stay resident
                # (serving nothing is worse); the resident_bytes gauge
                # shows the overrun
                return
            victim = min(victims, key=lambda e: e.last_used)
            del self._entries[victim.tenant]
            self.evicted.append(victim.tenant)
            evictions.inc()

    def _record_gauges(self) -> None:
        reg = get_registry()
        reg.gauge("serve.registry.models").set(len(self._entries))
        reg.gauge("serve.registry.resident_bytes").set(
            self._resident_bytes_locked())

    def tenants(self) -> dict[str, dict]:
        """JSON-ready summary per resident tenant (the daemon's
        ``/v1/tenants`` payload)."""
        with self._lock:
            return {t: {"generation": e.generation,
                        "resident_bytes": e.model.nbytes,
                        "dims": list(e.model.dims),
                        "fit": float(getattr(e.model.decomp, "fit", float("nan"))),
                        "buckets": list(e.model.buckets)}
                    for t, e in sorted(self._entries.items())}

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, tenant: str) -> bool:
        with self._lock:
            return tenant in self._entries
