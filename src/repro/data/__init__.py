"""Synthetic token pipeline for the LM substrate.

LEGACY SEED MODULE: LM-training plumbing only; tensor data enters the
decomposition stack through ``repro.ingest`` / ``repro.api.DataConfig``.
See docs/architecture.md ("Legacy LM substrate")."""
from .pipeline import TokenPipeline, make_batch_iterator

__all__ = ["TokenPipeline", "make_batch_iterator"]
