from .pipeline import TokenPipeline, make_batch_iterator

__all__ = ["TokenPipeline", "make_batch_iterator"]
