"""Deterministic synthetic data pipeline for LM training.

Properties a production pipeline needs and this one has:
  * deterministic & seekable: batch ``i`` is a pure function of (seed, i) —
    restart from a checkpoint at step N reproduces the exact stream without
    replaying N batches;
  * sharded: each data-parallel host materializes only its local slice
    (``host_slice``);
  * next-token labels, modality stubs (embeds/positions/src frames) per the
    arch config, padding-free.

The generator is a structured Markov-ish token stream (not iid uniform) so
losses are learnable in examples/train_lm.py.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass
class TokenPipeline:
    cfg: ModelConfig
    batch: int
    seq: int
    seed: int = 0

    def batch_at(self, index: int, *, host_slice: slice | None = None) -> dict:
        """The full (or host-local) batch for step ``index``."""
        rng = np.random.default_rng((self.seed, index))
        b = self.batch
        # Markov stream: next token = (a * tok + noise) % vocab, segment resets
        v = self.cfg.vocab
        toks = np.empty((b, self.seq + 1), dtype=np.int32)
        toks[:, 0] = rng.integers(0, v, size=b)
        mult = rng.integers(1, 17, size=(b, 1))
        for t in range(1, self.seq + 1):
            noise = rng.integers(0, 7, size=b)
            toks[:, t] = (toks[:, t - 1] * mult[:, 0] + noise) % v
        batch = {"tokens": jnp.asarray(toks[:, :-1]),
                 "labels": jnp.asarray(toks[:, 1:])}
        if self.cfg.input_mode == "embeds":
            emb = rng.standard_normal((b, self.seq, self.cfg.d_model),
                                      dtype=np.float32)
            batch["embeds"] = jnp.asarray(emb)
            del batch["tokens"]
        if self.cfg.rope == "mrope":
            pos = np.broadcast_to(np.arange(self.seq, dtype=np.int32),
                                  (3, b, self.seq)).copy()
            batch["positions"] = jnp.asarray(pos)
        if self.cfg.encdec:
            src = rng.standard_normal((b, min(self.seq, 512), self.cfg.d_model),
                                      dtype=np.float32)
            batch["src_embeds"] = jnp.asarray(src)
        if host_slice is not None:
            batch = {k: v[host_slice] if k != "positions" else v[:, host_slice]
                     for k, v in batch.items()}
        return batch


def make_batch_iterator(cfg: ModelConfig, batch: int, seq: int, *,
                        seed: int = 0, start_step: int = 0) -> Iterator[dict]:
    pipe = TokenPipeline(cfg, batch, seq, seed)
    i = start_step
    while True:
        yield pipe.batch_at(i)
        i += 1
