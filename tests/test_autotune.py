"""Persistent autotune store: warm ``plan(calibrate=True)`` performs ZERO
timing runs, keys separate every configuration axis, a changed impl
registry invalidates implicitly, and ``recalibrate`` forces a fresh pass.

The monkeypatch target is ``repro.plan.planner._measure_ms`` — the single
timing primitive every calibration measurement goes through — so "no
timing runs happened" is a counted fact, not an inference from wall time.
"""
import dataclasses
import json

import jax
import pytest

from repro.core import random_sparse
from repro.core.mttkrp import REGISTRY, ImplSpec, register_impl
from repro.ingest import IngestCache, ingest
from repro.plan import (AutotuneStore, calibration_key, plan_decomposition,
                        registry_fingerprint)
from repro.plan import planner as planner_mod

KEY = jax.random.PRNGKey(7)
DIMS = (20, 30, 25)


def small_tensor(key=KEY, dims=DIMS, nnz=600):
    return random_sparse(dims, nnz, key)


@pytest.fixture
def measure_counter(monkeypatch):
    """Counts (and still performs) every calibration timing run."""
    calls = {"n": 0}
    real = planner_mod._measure_ms

    def counting(fn, *args, **kwargs):
        calls["n"] += 1
        return real(fn, *args, **kwargs)

    monkeypatch.setattr(planner_mod, "_measure_ms", counting)
    return calls


# ---------------------------------------------------------------------------
# the tentpole property: warm hit -> zero timing runs
# ---------------------------------------------------------------------------

def test_warm_calibration_skips_measurement(tmp_path, measure_counter):
    t = small_tensor()
    ing = ingest(t, cache=tmp_path)
    p1 = ing.plan("auto", rank=8, calibrate=True)
    cold = measure_counter["n"]
    assert cold > 0
    assert all(m.source == "measured-fresh" for m in p1.modes)

    # a NEW handle over the same cache (fresh process simulation): the
    # second calibrated plan must touch the store, not the clock
    ing2 = ingest(t, cache=tmp_path)
    p2 = ing2.plan("auto", rank=8, calibrate=True)
    assert measure_counter["n"] == cold, \
        "warm calibrated plan performed timing runs"
    assert all(m.source == "measured-cached" for m in p2.modes)
    assert [m.impl for m in p2.modes] == [m.impl for m in p1.modes]
    assert ing2.cache.autotune.hits > 0


def test_recalibrate_forces_fresh_measurement(tmp_path, measure_counter):
    t = small_tensor()
    ing = ingest(t, cache=tmp_path)
    ing.plan("auto", rank=8, calibrate=True)
    cold = measure_counter["n"]
    p = ing.plan("auto", rank=8, calibrate=True, recalibrate=True)
    assert measure_counter["n"] > cold
    assert all(m.source == "measured-fresh" for m in p.modes)
    # the overwrite sticks: the next warm plan replays the recalibration
    p2 = ing.plan("auto", rank=8, calibrate=True)
    assert all(m.source == "measured-cached" for m in p2.modes)


def test_fixed_policy_calibration_is_cached_too(tmp_path, measure_counter):
    t = small_tensor()
    ing = ingest(t, cache=tmp_path)
    p1 = ing.plan("segment", rank=8, calibrate=True)
    cold = measure_counter["n"]
    assert cold > 0 and all(m.impl == "segment" for m in p1.modes)
    p2 = ingest(t, cache=tmp_path).plan("segment", rank=8, calibrate=True)
    assert measure_counter["n"] == cold
    assert all(m.source == "measured-cached" for m in p2.modes)


def test_plan_without_cache_measures_every_time(tmp_path, measure_counter):
    t = small_tensor()
    ing = ingest(t)  # no cache -> no store -> no persistence
    ing.plan("auto", rank=8, calibrate=True)
    first = measure_counter["n"]
    ing.plan("auto", rank=8, calibrate=True)
    assert measure_counter["n"] == 2 * first


# ---------------------------------------------------------------------------
# key separation
# ---------------------------------------------------------------------------

def test_calibration_key_separates_every_axis():
    base = dict(mode=0, names=("segment", "dense"), backend="cpu", rank=8,
                kernel="mttkrp", block=512, row_tile=128, stats_digest="ab")
    k0 = calibration_key("tensor-a", **base)
    assert k0 == calibration_key("tensor-a", **base)  # deterministic
    # impl-name ORDER must not matter (sets, not sequences)
    assert k0 == calibration_key(
        "tensor-a", **{**base, "names": ("dense", "segment")})
    for axis, val in [("mode", 1), ("backend", "tpu"), ("rank", 16),
                      ("kernel", "ttmc"), ("block", 256), ("row_tile", 64),
                      ("names", ("segment",)), ("stats_digest", "cd")]:
        assert calibration_key("tensor-a", **{**base, axis: val}) != k0, axis
    assert calibration_key("tensor-b", **base) != k0


def test_different_rank_and_backend_calibrate_separately(tmp_path,
                                                         measure_counter):
    t = small_tensor()
    ing = ingest(t, cache=tmp_path)
    ing.plan("auto", rank=8, calibrate=True)
    n1 = measure_counter["n"]
    ing.plan("auto", rank=4, calibrate=True)     # different rank -> miss
    assert measure_counter["n"] > n1
    n2 = measure_counter["n"]
    ing.plan("auto", rank=8, calibrate=True)     # rank 8 again -> hit
    ing.plan("auto", rank=4, calibrate=True)     # rank 4 again -> hit
    assert measure_counter["n"] == n2


def test_allow_set_calibrates_separately(tmp_path, measure_counter):
    t = small_tensor()
    ing = ingest(t, cache=tmp_path)
    ing.plan("auto", rank=8, calibrate=True)
    n1 = measure_counter["n"]
    p = ing.plan("auto", rank=8, calibrate=True, allow=("segment",))
    assert measure_counter["n"] > n1, "narrower allow set must re-measure"
    assert all(m.impl == "segment" for m in p.modes)


# ---------------------------------------------------------------------------
# registry invalidation
# ---------------------------------------------------------------------------

def test_registry_change_invalidates_calibration(tmp_path, measure_counter):
    t = small_tensor()
    ing = ingest(t, cache=tmp_path)
    ing.plan("auto", rank=8, calibrate=True)
    warm = measure_counter["n"]
    fp_before = registry_fingerprint("mttkrp")

    # registering a new impl changes the fingerprint -> every stored entry
    # is implicitly stale (its key can never be addressed again)
    dummy = ImplSpec(name="_autotune_test_dummy",
                     fn=REGISTRY["segment"].fn, layout="csf",
                     needs_sorted=True, supports_order_gt3=True,
                     benchmark_only=True)
    register_impl(dummy)
    try:
        assert registry_fingerprint("mttkrp") != fp_before
        ing.plan("auto", rank=8, calibrate=True)
        assert measure_counter["n"] > warm, \
            "stale registry entry was replayed"
    finally:
        REGISTRY.pop("_autotune_test_dummy", None)
    assert registry_fingerprint("mttkrp") == fp_before


def test_store_version_bump_evicts(tmp_path):
    store = AutotuneStore(tmp_path)
    store.store("ab" * 32, {"segment": 1.0})
    path = store._path("ab" * 32)
    payload = json.loads(path.read_text())
    payload["version"] = -1
    path.write_text(json.dumps(payload))
    assert store.load("ab" * 32) is None
    assert not path.exists(), "stale-version entry must self-evict"


def test_store_roundtrip_and_counters(tmp_path):
    store = AutotuneStore(tmp_path)
    key = "cd" * 32
    assert store.load(key) is None and store.misses == 1
    store.store(key, {"segment": 1.25, "dense": 3.5}, meta={"mode": 2})
    got = store.load(key)
    assert got["costs"] == {"segment": 1.25, "dense": 3.5}
    assert got["meta"] == {"mode": 2}
    assert store.hits == 1 and store.misses == 1


# ---------------------------------------------------------------------------
# ttmc calibration (the planner.py:290 fix)
# ---------------------------------------------------------------------------

def test_ttmc_calibrate_works_with_factor_ranks(measure_counter):
    t = small_tensor()
    p = plan_decomposition(t, "auto", rank=16, kernel="ttmc",
                           calibrate=True, factor_ranks=(4, 4, 4))
    assert measure_counter["n"] > 0
    assert all(m.source == "measured-fresh" for m in p.modes)
    assert all(m.kernel == "ttmc" for m in p.modes)


def test_ttmc_calibrate_without_factor_ranks_raises():
    t = small_tensor()
    with pytest.raises(ValueError, match="factor_ranks"):
        plan_decomposition(t, "auto", rank=16, kernel="ttmc", calibrate=True)


def test_tucker_hooi_calibrated_plan_end_to_end(tmp_path, measure_counter):
    """The regression test for the old 'calibrate=True is implemented for
    the mttkrp kernel only' raise: a calibrated Tucker plan now works, and
    its calibration persists like any other."""
    from repro.methods import fit

    t = small_tensor()
    ing = ingest(t, cache=tmp_path)
    dec = fit(ing, (3, 3, 3), method="tucker_hooi", niters=2)
    assert dec.core.shape == (3, 3, 3)
    p1 = ing.plan("auto", rank=9, kernel="ttmc", calibrate=True,
                  factor_ranks=(3, 3, 3))
    cold = measure_counter["n"]
    assert cold > 0
    p2 = ing.plan("auto", rank=9, kernel="ttmc", calibrate=True,
                  factor_ranks=(3, 3, 3))
    assert measure_counter["n"] == cold
    assert all(m.source == "measured-cached" for m in p2.modes)
    assert [m.impl for m in p2.modes] == [m.impl for m in p1.modes]


# ---------------------------------------------------------------------------
# config / CLI surface
# ---------------------------------------------------------------------------

def test_planconfig_recalibrate_requires_calibrate():
    from repro.api import ConfigError, PlanConfig, RunConfig

    with pytest.raises(ConfigError, match="plan.recalibrate"):
        PlanConfig(recalibrate=True)
    cfg = RunConfig.from_dict(
        {"plan": {"calibrate": True, "recalibrate": True}})
    assert cfg.plan.recalibrate
    # round-trips bit-exactly like every other field
    import json as _json
    assert RunConfig.from_dict(_json.loads(cfg.to_json())) == cfg


def test_session_calibrated_plan_uses_store(tmp_path, measure_counter):
    from repro.api import (DataConfig, MethodConfig, PlanConfig, RunConfig,
                          Session)

    t = small_tensor()
    cfg = RunConfig(data=DataConfig(cache=str(tmp_path)),
                    plan=PlanConfig(calibrate=True),
                    method=MethodConfig(rank=8, niters=1))
    p1 = Session.from_config(cfg, tensor=t).plan()
    cold = measure_counter["n"]
    assert cold > 0 and all(m.source == "measured-fresh" for m in p1.modes)
    p2 = Session.from_config(cfg, tensor=t).plan()
    assert measure_counter["n"] == cold
    assert all(m.source == "measured-cached" for m in p2.modes)
    # --recalibrate escape hatch, via the validated config path
    cfg3 = RunConfig(data=DataConfig(cache=str(tmp_path)),
                     plan=PlanConfig(calibrate=True, recalibrate=True),
                     method=MethodConfig(rank=8, niters=1))
    p3 = Session.from_config(cfg3, tensor=t).plan()
    assert measure_counter["n"] > cold
    assert all(m.source == "measured-fresh" for m in p3.modes)


def test_cli_recalibrate_flag_implies_calibrate():
    from repro.api.cli import config_from_args, main

    import argparse
    ns = argparse.Namespace(
        config=None, source=None, dataset=None, scale=None, data_seed=None,
        reorder=None, compact=None, cache=None, impl=None, calibrate=None,
        recalibrate=True, method=None, rank=None, iters=None, tol=None,
        seed=None, option=[], executor=None, checkpoint_dir=None,
        checkpoint_every=None, monitor=None, n_chunks=None, chunk_nnz=None)
    cfg = config_from_args(ns)
    assert cfg.plan.calibrate and cfg.plan.recalibrate
    # and the parser itself accepts the flag (full arg surface)
    rc = main(["plan", "--dataset", "yelp", "--scale", "0.0005",
               "--rank", "4", "--calibrate", "--recalibrate"])
    assert rc == 0


# ---------------------------------------------------------------------------
# canonical candidate ordering — ONE helper feeds the key AND the report
# ---------------------------------------------------------------------------

def test_canonical_candidates_is_the_single_ordering():
    """Regression for the double-bookkeeping bug: the calibration key and
    plan_report's costs column must consume the SAME ordering helper, so a
    registry re-ordering can never split the cache or desync the report."""
    from repro.plan.autotune import canonical_candidates

    names = ("segment", "gather_scatter", "dense", "linearized")
    canon = canonical_candidates(names)
    assert canon == tuple(sorted(names))
    # any permutation / container maps to the one canonical tuple
    assert canonical_candidates(tuple(reversed(names))) == canon
    assert canonical_candidates(set(names)) == canon
    # and the key consumes exactly that ordering
    base = dict(mode=0, backend="cpu", rank=8, kernel="mttkrp",
                block=512, row_tile=128, stats_digest="ab")
    assert (calibration_key("t", names=names, **base)
            == calibration_key("t", names=canon, **base))


def test_plan_report_costs_follow_canonical_order():
    """The costs column lists every candidate in canonical order — the same
    order the calibration key hashes (``canonical_candidates``)."""
    from repro.plan.autotune import canonical_candidates
    from repro.utils.report import plan_report

    t = small_tensor()
    p = plan_decomposition(t, "auto", rank=8, backend="cpu")
    rep = plan_report(p)
    for m in p.modes:
        assert m.costs, "auto plan must carry the per-candidate cost table"
        assert tuple(sorted(m.costs)) == canonical_candidates(m.costs)
        row = next(line for line in rep.splitlines()
                   if line.startswith(f"| {m.mode} |"))
        pos = [row.index(f" {name}=") for name in canonical_candidates(m.costs)]
        assert pos == sorted(pos), "report order != canonical order"


def test_plan_report_shows_cost_source(tmp_path):
    from repro.utils.report import plan_report

    t = small_tensor()
    ing = ingest(t, cache=tmp_path)
    rep = plan_report(ing.plan("auto", rank=8, calibrate=True))
    assert "| costs |" in rep and "measured-fresh" in rep
    rep2 = plan_report(ing.plan("auto", rank=8, calibrate=True))
    assert "measured-cached" in rep2
    rep3 = plan_report(ing.plan("auto", rank=8))
    assert "predicted" in rep3
