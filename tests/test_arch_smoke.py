"""Per-arch smoke tests: reduced config of the same family runs one forward +
train-step gradient + a prefill/decode step on CPU, asserting shapes + no NaNs.
Full configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import Model
from repro.models.config import SHAPES, cell_is_skipped

KEY = jax.random.PRNGKey(11)


def _smoke_batch(cfg, b=2, s=16):
    batch = {}
    if cfg.input_mode == "embeds":
        batch["embeds"] = jax.random.normal(KEY, (b, s, cfg.d_model),
                                            dtype=jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    batch["labels"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    if cfg.rope == "mrope":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32)
    if cfg.encdec:
        batch["src_embeds"] = jax.random.normal(
            jax.random.fold_in(KEY, 9), (b, 8, cfg.d_model), dtype=jnp.float32)
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_arch_smoke_train_step(arch):
    cfg = configs.smoke_of(configs.get(arch))
    m = Model(cfg)
    params = m.init(KEY)
    batch = _smoke_batch(cfg)
    (loss, mets), grads = jax.value_and_grad(m.loss, has_aux=True)(params, batch)
    assert np.isfinite(float(loss)), (arch, loss)
    gsum = jax.tree.reduce(
        lambda a, g: a + jnp.sum(jnp.abs(g.astype(jnp.float32))), grads, 0.0)
    assert np.isfinite(float(gsum)), arch
    assert float(gsum) > 0.0, f"{arch}: zero gradients"


@pytest.mark.parametrize("arch", configs.ARCH_NAMES)
def test_arch_smoke_prefill_decode(arch):
    cfg = configs.smoke_of(configs.get(arch))
    m = Model(cfg)
    params = m.init(KEY)
    b, s = 2, 16
    batch = _smoke_batch(cfg, b, s)
    batch.pop("labels")
    cache = m.init_cache(b, s + 4, src_len=8 if cfg.encdec else 0)
    logits, cache = m.prefill(params, batch, cache)
    assert logits.shape == (b, 1, cfg.vocab), (arch, logits.shape)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32)))), arch
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    kw = {}
    if cfg.rope == "mrope":
        kw["positions"] = jnp.full((3, b, 1), s, dtype=jnp.int32)
    lg, _ = m.decode_step(params, tok, cache, jnp.array(s, jnp.int32), **kw)
    assert lg.shape == (b, 1, cfg.vocab), (arch, lg.shape)
    assert bool(jnp.all(jnp.isfinite(lg.astype(jnp.float32)))), arch


def test_full_configs_match_assigned_dims():
    """The full (non-smoke) configs carry the exact published dims."""
    expect = {
        "gemma-7b": (28, 3072, 16, 16, 24576, 256_000),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128_256),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32_768),
        "yi-34b": (60, 7168, 56, 8, 20480, 64_000),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65_536),
        "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152_064),
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100_352),
        "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163_840),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256_206),
        "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256_000),
    }
    for arch, (nl, d, h, kv, ff, v) in expect.items():
        cfg = configs.get(arch)
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab)
        assert got == (nl, d, h, kv, ff, v), (arch, got)


def test_layer_plans_decompose():
    for arch in configs.ARCH_NAMES:
        cfg = configs.get(arch)
        prefix, reps, suffix = cfg.layer_plan  # raises if inconsistent
        assert len(prefix) + reps * len(cfg.pattern) + len(suffix) == cfg.num_layers


def test_param_counts_plausible():
    """Sanity-check total parameter counts against the published sizes."""
    expect_b = {  # billions, loose bounds
        "gemma-7b": (7, 10), "llama3.2-3b": (2.5, 4.5),
        "mistral-large-123b": (110, 135), "yi-34b": (30, 38),
        "rwkv6-3b": (2.5, 4), "qwen2-vl-7b": (6, 9),
        "dbrx-132b": (120, 140), "kimi-k2-1t-a32b": (850, 1150),
        "seamless-m4t-large-v2": (0.8, 2.5), "recurrentgemma-9b": (7.5, 11),
    }
    for arch, (lo, hi) in expect_b.items():
        n = configs.get(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.1f}B not in [{lo},{hi}]"


def test_moe_active_params():
    kimi = configs.get("kimi-k2-1t-a32b")
    active = kimi.active_param_count() / 1e9
    assert 20 <= active <= 45, active  # "a32b"


def test_cell_skips_match_design():
    skipped = [(a, s) for a in configs.ARCH_NAMES for s in SHAPES
               if cell_is_skipped(a, s)]
    assert len(skipped) == 8  # long_500k on the 8 full-attention archs
    assert all(s == "long_500k" for _, s in skipped)
    assert not cell_is_skipped("rwkv6-3b", "long_500k")
    assert not cell_is_skipped("recurrentgemma-9b", "long_500k")
