"""Per-mode planning: stats regimes, auto impl selection, registry
capabilities, the unified CSF workspace feeding every impl, and interface
parity between cp_als and dist_cp_als (the paper's §V-D finding as code)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SparseTensor, available_impls, build_csf,
                        build_workspace, cp_als, get_impl, init_factors,
                        mttkrp, random_sparse)
from repro.core.csf import CSF, build_csf_loop_reference
from repro.plan import (CONTENTION_THRESHOLD, DecompPlan, mode_stats,
                        plan_decomposition)
from repro.utils.report import plan_report

KEY = jax.random.PRNGKey(3)

# mode 0: 8 rows (hot -> contention); mode 1: 5000 rows hit ~once each
# (collision-light but tile-padding-heavy); mode 2: in between.
SKEWED_DIMS = (8, 5000, 64)


def skewed_tensor(nnz=2000):
    return random_sparse(SKEWED_DIMS, nnz, KEY)


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------

def test_mode_stats_regimes():
    t = skewed_tensor()
    s0 = mode_stats(t, 0, block=512, row_tile=128)
    s1 = mode_stats(t, 1, block=512, row_tile=128)
    assert s0.collision_rate > CONTENTION_THRESHOLD
    assert s0.regime == "contention"
    assert s1.collision_rate < CONTENTION_THRESHOLD
    assert s1.regime == "no-lock"
    # the long uniform mode pays heavy tile padding; the hot mode almost none
    assert s1.padding_overhead > 0.5 > s0.padding_overhead


def test_mode_stats_bounds():
    t = skewed_tensor()
    for m in range(3):
        s = mode_stats(t, m, block=256, row_tile=64)
        assert 0.0 <= s.collision_rate <= 1.0
        assert 0.0 <= s.padding_overhead < 1.0
        assert s.rows == t.dims[m] and s.nnz == t.nnz
    with pytest.raises(ValueError):
        mode_stats(t, 3, block=256, row_tile=64)


# ---------------------------------------------------------------------------
# auto planning
# ---------------------------------------------------------------------------

def test_auto_picks_different_impls_per_mode():
    """The tentpole property: on a skewed tensor the auto policy provably
    selects different impls for different modes (contention -> sorted
    no-lock segment; collision-light/padding-heavy -> gather_scatter)."""
    t = skewed_tensor()
    plan = plan_decomposition(t, "auto", rank=8, backend="cpu")
    assert plan.impls[0] == "segment", plan.summary()
    assert plan.impls[1] == "gather_scatter", plan.summary()
    assert len(set(plan.impls)) > 1


def test_fixed_policy_pins_all_modes():
    t = skewed_tensor()
    plan = plan_decomposition(t, "segment", rank=4)
    assert plan.impls == ("segment",) * 3
    assert all(p.layout == "csf" for p in plan.modes)
    # longest-first mode order (what the distributed partitioner wants)
    assert plan.mode_order_by_length() == (1, 2, 0)
    # zero-overhead fixed planning skips the stats pass but keeps the report
    lean = plan_decomposition(t, "segment", rank=4, with_stats=False)
    assert all(p.stats is None for p in lean.modes)
    assert "**segment**" in plan_report(lean)
    with pytest.raises(ValueError, match="with_stats=False"):
        lean.mode_order_by_length()


def test_unknown_policy_lists_registry():
    with pytest.raises(ValueError, match="unknown impl"):
        plan_decomposition(skewed_tensor(), "nope")


def test_auto_candidates_respect_capabilities():
    names = available_impls(order=3, backend="cpu")
    assert "rowloop" not in names    # benchmark_only
    assert "dense" not in names      # oracle
    assert "pallas" not in names     # tpu-native, cpu backend
    assert set(names) >= {"gather_scatter", "segment"}
    assert "pallas" in available_impls(order=3, backend="tpu")


def test_calibrated_planning_measures_ms():
    t = skewed_tensor(nnz=600)
    plan = plan_decomposition(t, "auto", rank=4, backend="cpu",
                              calibrate=True)
    for p in plan.modes:
        assert all(v > 0.0 for v in p.costs.values())
        assert "measured" in p.reason


def test_plan_report_renders_modes():
    t = skewed_tensor()
    rep = plan_report(plan_decomposition(t, "auto", rank=8, backend="cpu"))
    assert "| mode |" in rep and "regime" in rep
    for p in plan_decomposition(t, "auto", rank=8, backend="cpu").modes:
        assert p.impl in rep


# ---------------------------------------------------------------------------
# unified workspace: every registered impl, one layout, dense parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", [3, 4])
def test_registered_impls_match_dense_on_unified_workspace(order):
    """All registered (non-oracle) impls consume the same CSF workspace —
    or the one shared linearized workspace for lin-layout impls — and
    agree with the dense oracle, at order 3 and 4."""
    from repro.core.linearized import build_linearized

    dims = (23, 17, 31, 11)[:order]
    t = random_sparse(dims, 400, KEY)
    factors = init_factors(t.dims, 6, KEY)
    names = available_impls(order=order)  # backend=None: includes pallas
    assert set(names) >= {"gather_scatter", "segment", "pallas", "linearized"}
    lin = build_linearized(t, block=64, row_tile=32)
    for mode in range(order):
        want = mttkrp(t, factors, mode, impl="dense")
        ws = build_csf(t, mode, block=64, row_tile=32)
        for name in names:
            layout = get_impl(name).layout
            x = lin if layout == "lin" else (ws if layout != "coo" else t)
            got = mttkrp(x, factors, mode, impl=name)
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4,
                err_msg=f"impl={name} mode={mode} order={order}")


def test_csf_row_ids_globally_sorted():
    """Padding points at each tile's last real row, preserving the global
    sort the segment impl's indices_are_sorted fast path relies on."""
    t = random_sparse((200, 13, 77), 2000, KEY)
    for mode in range(3):
        csf = build_csf(t, mode, block=128, row_tile=64)
        rows = np.asarray(csf.row_ids)
        assert np.all(np.diff(rows) >= 0), f"mode {mode} not sorted"
        assert rows.max() < t.dims[mode]


def test_build_workspace_follows_plan_layouts():
    t = skewed_tensor()
    plan = plan_decomposition(t, "auto", rank=8, backend="cpu")
    ws = build_workspace(t, plan)
    for p, w in zip(plan.modes, ws):
        if p.layout == "csf":
            assert isinstance(w, CSF) and w.mode == p.mode
        else:
            assert w is t
    # legacy string interface still builds CSF replicas
    ws_legacy = build_workspace(t, "segment", block=128)
    assert all(isinstance(w, CSF) for w in ws_legacy)


# ---------------------------------------------------------------------------
# loop-reference build (order > 3 + shared assembly)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("order", [3, 4])
def test_loop_reference_matches_fast_build(order):
    dims = (14, 9, 11, 7)[:order]
    t = random_sparse(dims, 120, KEY)
    for mode in (0, order - 1):
        slow = build_csf_loop_reference(t, mode)
        fast = build_csf(t, mode)
        np.testing.assert_array_equal(np.asarray(slow.row_ids),
                                      np.asarray(fast.row_ids))
        np.testing.assert_array_equal(np.asarray(slow.other_ids),
                                      np.asarray(fast.other_ids))
        np.testing.assert_allclose(np.asarray(slow.vals),
                                   np.asarray(fast.vals))


def test_loop_reference_mode_out_of_range():
    with pytest.raises(ValueError, match="out of range"):
        build_csf_loop_reference(skewed_tensor(nnz=50), 5)


# ---------------------------------------------------------------------------
# drivers: cp_als / dist_cp_als share the plan interface
# ---------------------------------------------------------------------------

def test_cpals_auto_equals_explicit_plan():
    t = skewed_tensor(nnz=900)
    plan = plan_decomposition(t, "auto", rank=4, backend="cpu")
    d1 = cp_als(t, rank=4, niters=4, impl="auto", key=KEY)
    d2 = cp_als(t, rank=4, niters=4, plan=plan, key=KEY)
    np.testing.assert_array_equal(np.asarray(d1.fit), np.asarray(d2.fit))
    for a, b in zip(d1.factors, d2.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cpals_auto_agrees_with_fixed_impls():
    """auto's mixed per-mode execution is numerically equivalent to the
    fixed impls (same ALS math, different schedules)."""
    t = skewed_tensor(nnz=900)
    d_auto = cp_als(t, rank=4, niters=5, impl="auto", key=KEY)
    d_seg = cp_als(t, rank=4, niters=5, impl="segment", key=KEY)
    np.testing.assert_allclose(float(d_auto.fit), float(d_seg.fit),
                               rtol=0, atol=1e-4)


def test_dist_rejects_unsupported_impl():
    """dist_cp_als must refuse impls its shard_map body cannot express
    rather than silently substituting scatter-add."""
    from repro.core.distributed import dist_cp_als

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="shard_map body"):
        dist_cp_als(skewed_tensor(nnz=50), 3, mesh, impl="pallas")


def test_default_interpret_matches_backend():
    from repro.kernels import ops

    want = jax.default_backend() != "tpu"
    assert ops.default_interpret() is want


def test_cpals_step_builder_executes_plan():
    from repro.core.gram import gram
    from repro.launch.steps import make_cpals_step

    t = skewed_tensor(nnz=600)
    plan = plan_decomposition(t, "auto", rank=4, backend="cpu")
    ws = build_workspace(t, plan)
    step = make_cpals_step(plan)
    factors = init_factors(t.dims, 4, KEY, dtype=t.vals.dtype)
    grams = tuple(gram(a) for a in factors)
    norm_x_sq = jnp.sum(t.vals.astype(jnp.float32) ** 2)
    factors, grams, lam, fit = step(ws, factors, grams, norm_x_sq,
                                    norm_kind="max")
    assert all(bool(jnp.all(jnp.isfinite(f))) for f in factors)
    assert 0.0 <= float(fit) <= 1.0
