"""The ALTO-style linearized workspace (core/linearized.py): bit packing,
the one-sort build, dense parity of both registered impls (jnp + Pallas
in-kernel decode) across every mode at order 3 and 4, planner/calibration
integration, workspace sharing, and the ingest-cache ride-along."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SparseTensor, available_impls, build_workspace,
                        cp_als, init_factors, mttkrp, random_sparse)
from repro.core.linearized import (Linearized, bit_widths, build_linearized,
                                   check_bit_budget, delinearize_coords,
                                   field_offsets, linearize_coords)
from repro.core.ttmc import available_ttmc_impls, ttmc
from repro.plan import plan_decomposition

KEY = jax.random.PRNGKey(11)


def small_tensor(order=3, nnz=500, key=KEY):
    dims = (23, 17, 31, 11)[:order]
    return random_sparse(dims, nnz, key)


# ---------------------------------------------------------------------------
# packing layout
# ---------------------------------------------------------------------------

def test_bit_widths_and_offsets():
    dims = (23, 17, 31)          # widths 5, 5, 5
    assert bit_widths(dims) == (5, 5, 5)
    assert bit_widths((1, 2, 1024)) == (1, 1, 10)
    # sort mode owns the most-significant field; others ascend below it
    assert field_offsets(dims, 0) == (10, 5, 0)
    assert field_offsets(dims, 1) == (5, 10, 0)
    assert field_offsets(dims, 2) == (5, 0, 10)


def test_linearize_roundtrip_order_3_and_4():
    for order in (3, 4):
        t = small_tensor(order=order)
        inds = np.asarray(t.inds[: t.nnz])
        for sm in range(order):
            lin = linearize_coords(inds, t.dims, sm)
            back = delinearize_coords(lin, t.dims, sm)
            np.testing.assert_array_equal(back, inds.astype(np.int64))


def test_overflow_rejected_everywhere():
    """Over-budget dims fail at check, at pack, and at build — with the
    per-mode widths named in the error."""
    dims = (2**40, 2**31, 4)
    with pytest.raises(ValueError, match=r"73 packed bits \(40\+31\+2\)"):
        check_bit_budget(dims)
    t = SparseTensor(inds=jnp.zeros((3, 3), dtype=jnp.int32),
                     vals=jnp.ones(3, dtype=jnp.float32), dims=dims, nnz=3)
    with pytest.raises(ValueError, match="64-bit"):
        build_linearized(t)
    # a single >32-bit field is rejected too (the per-field decode budget)
    with pytest.raises(ValueError, match="per-mode decode budget"):
        check_bit_budget((2**33, 2, 2))


# ---------------------------------------------------------------------------
# the build: one sort, csf-style padding, lossless
# ---------------------------------------------------------------------------

def test_build_preserves_multiset_and_sort():
    t = small_tensor(nnz=800)
    lin = build_linearized(t, block=64, row_tile=16)
    assert isinstance(lin, Linearized)
    assert lin.padded_nnz % lin.block == 0
    assert lin.num_blocks == lin.block_tile.shape[0]
    # decoded entries with nonzero value == the original nonzero multiset
    decoded = np.stack([np.asarray(lin.decode(m)) for m in range(3)], 1)
    vals = np.asarray(lin.vals)
    built = sorted((tuple(decoded[n]), float(vals[n]))
                   for n in range(lin.padded_nnz) if vals[n] != 0.0)
    orig = sorted((tuple(int(v) for v in np.asarray(t.inds)[n]),
                   float(t.vals[n])) for n in range(t.nnz))
    assert built == orig
    # the stream is globally sorted by the sort mode's row (padding included)
    rows = np.asarray(lin.decode(lin.sort_mode))
    assert (np.diff(rows) >= 0).all()
    # block_tile is non-decreasing and consistent with the rows it covers
    bt = np.asarray(lin.block_tile)
    assert (np.diff(bt) >= 0).all()
    per_block = rows.reshape(lin.num_blocks, lin.block) // lin.row_tile
    np.testing.assert_array_equal(per_block.min(1), bt)
    np.testing.assert_array_equal(per_block.max(1), bt)


def test_one_workspace_serves_every_mode():
    """The format's whole point: ONE buffer, no per-mode re-sort — a single
    build answers MTTKRP and TTMc on every mode."""
    t = small_tensor(order=4)
    lin = build_linearized(t)
    factors = init_factors(t.dims, 5, KEY)
    for mode in range(4):
        want = mttkrp(t, factors, mode, impl="dense")
        got = mttkrp(lin, factors, mode, impl="linearized")
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# registry + dense parity (both impls, both kernels, order 3 and 4)
# ---------------------------------------------------------------------------

def test_linearized_registered_for_both_kernel_families():
    for avail in (available_impls, available_ttmc_impls):
        names = avail(order=4)  # backend=None -> includes pallas variants
        assert "linearized" in names
        assert "linearized_pallas" in names
    # but not on an explicit cpu backend (pallas variant is tpu-only)
    assert "linearized" in available_impls(order=3, backend="cpu")
    assert "linearized_pallas" not in available_impls(order=3, backend="cpu")


@pytest.mark.parametrize("order", [3, 4])
@pytest.mark.parametrize("impl", ["linearized", "linearized_pallas"])
def test_mttkrp_parity_all_modes(order, impl):
    t = small_tensor(order=order)
    lin = build_linearized(t, block=64, row_tile=16)
    factors = init_factors(t.dims, 6, KEY)
    for mode in range(order):
        want = mttkrp(t, factors, mode, impl="dense")
        got = mttkrp(lin, factors, mode, impl=impl)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4,
            err_msg=f"impl={impl} mode={mode} order={order}")


@pytest.mark.parametrize("order", [3, 4])
@pytest.mark.parametrize("impl", ["linearized", "linearized_pallas"])
def test_ttmc_parity_all_modes(order, impl):
    t = small_tensor(order=order, nnz=300)
    lin = build_linearized(t, block=64, row_tile=16)
    keys = jax.random.split(KEY, order)
    factors = tuple(jax.random.normal(k, (d, 3))
                    for k, d in zip(keys, t.dims))
    for mode in range(order):
        want = ttmc(t, factors, mode, impl="dense")
        got = ttmc(lin, factors, mode, impl=impl)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4,
            err_msg=f"impl={impl} mode={mode} order={order}")


def test_linearized_impls_reject_wrong_workspace():
    t = small_tensor()
    factors = init_factors(t.dims, 4, KEY)
    with pytest.raises(TypeError, match="Linearized workspace"):
        mttkrp(t, factors, 0, impl="linearized")
    with pytest.raises(TypeError, match="Linearized workspace"):
        ttmc(t, factors, 0, impl="linearized")


# ---------------------------------------------------------------------------
# planner integration: cost-modeled, budget-gated, calibratable
# ---------------------------------------------------------------------------

def test_auto_plan_scores_linearized():
    t = small_tensor()
    plan = plan_decomposition(t, "auto", rank=8, backend="cpu")
    for p in plan.modes:
        assert "linearized" in p.costs
        assert np.isfinite(p.costs["linearized"])


def test_fixed_linearized_plan_and_shared_workspace():
    t = small_tensor()
    plan = plan_decomposition(t, "linearized", rank=8)
    assert all(p.layout == "lin" for p in plan.modes)
    ws = build_workspace(t, plan)
    assert all(isinstance(w, Linearized) for w in ws)
    # ONE resident buffer: every mode gets the same object, not a copy
    assert all(w is ws[0] for w in ws)


def test_budget_gate_drops_lin_candidates():
    from repro.plan.planner import _fits_lin_budget

    names = available_impls(order=3)
    assert "linearized" in names
    huge = SparseTensor(inds=jnp.zeros((3, 3), dtype=jnp.int32),
                        vals=jnp.ones(3, dtype=jnp.float32),
                        dims=(2**40, 2**31, 4), nnz=3)
    kept = _fits_lin_budget(huge, names)
    assert "linearized" not in kept and "linearized_pallas" not in kept
    assert set(kept) == {n for n in names if "linearized" not in n}
    # an in-budget tensor keeps the full candidate set
    assert _fits_lin_budget(small_tensor(), names) == names


def test_calibration_times_linearized():
    t = small_tensor()
    plan = plan_decomposition(
        t, "auto", rank=6, backend="cpu", calibrate=True,
        allow=("segment", "gather_scatter", "linearized"))
    for p in plan.modes:
        assert p.source == "measured-fresh"
        assert set(p.costs) == {"segment", "gather_scatter", "linearized"}
        assert all(c > 0 for c in p.costs.values())


# ---------------------------------------------------------------------------
# end to end + ingest cache ride-along
# ---------------------------------------------------------------------------

def test_cp_als_on_linearized_matches_reference():
    t = small_tensor(nnz=700)
    key = jax.random.PRNGKey(0)
    ref = cp_als(t, rank=6, niters=8, impl="gather_scatter", key=key)
    got = cp_als(t, rank=6, niters=8, impl="linearized", key=key)
    np.testing.assert_allclose(float(got.fit), float(ref.fit), atol=2e-4)


def test_ingest_cache_roundtrips_linearized(tmp_path, monkeypatch):
    from repro.core import linearized as lin_mod
    from repro.ingest import ingest

    t = small_tensor()
    ing = ingest(t, cache=tmp_path)
    assert not ing.cache_hit
    cold = ing.lin()
    assert isinstance(cold, Linearized)

    # warm hit: the linearized workspace comes back from the cache with
    # ZERO builds (the module attribute is the monkeypatch seam)
    def boom(*a, **k):
        raise AssertionError("warm cache hit must not rebuild linearized")

    monkeypatch.setattr(lin_mod, "build_linearized", boom)
    ing2 = ingest(t, cache=tmp_path)
    assert ing2.cache_hit
    warm = ing2.lin()
    assert isinstance(warm, Linearized)
    np.testing.assert_array_equal(np.asarray(warm.hi), np.asarray(cold.hi))
    np.testing.assert_array_equal(np.asarray(warm.lo), np.asarray(cold.lo))
    np.testing.assert_array_equal(np.asarray(warm.vals),
                                  np.asarray(cold.vals))
    np.testing.assert_array_equal(np.asarray(warm.block_tile),
                                  np.asarray(cold.block_tile))
    assert (warm.dims, warm.nnz, warm.block, warm.row_tile, warm.sort_mode) \
        == (cold.dims, cold.nnz, cold.block, cold.row_tile, cold.sort_mode)
    # and a lin-layout plan's workspace comes straight off the handle
    plan = plan_decomposition(t, "linearized", rank=4,
                              block=ing2.block, row_tile=ing2.row_tile)
    ws = ing2.workspace(plan)
    assert all(w is warm for w in ws)


def test_ingest_skips_linearized_when_over_budget(tmp_path):
    """A tensor over the packed-bit budget still ingests (CSF path) — the
    linearized ride-along is simply absent, never an error."""
    from repro.ingest import ingest

    # 22+22+22 = 66 packed bits: over budget, but each mode stays small
    # enough for the CSF build and the stats pass to run normally
    huge = SparseTensor(
        inds=jnp.asarray(np.array([[0, 1, 0], [1, 0, 1], [2, 2, 2]],
                                  dtype=np.int32)),
        vals=jnp.ones(3, dtype=jnp.float32),
        dims=(2**22, 2**22, 2**22), nnz=3)
    ing = ingest(huge, cache=tmp_path)
    assert ing._lin is None
    with pytest.raises(ValueError, match="64-bit"):
        ing.lin()
