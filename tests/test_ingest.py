"""repro.ingest: streaming readers, invertible relabelings, the
content-addressed workspace cache, and the drivers' Ingested interface."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SparseTensor, cp_als, init_factors, random_sparse
from repro.core.cpals import CPALSState
from repro.ingest import (IngestCache, Ingested, Relabeling, compact,
                          content_key, convert_tns, degree_sort, ingest,
                          random_block, read_tns, read_tnsb, write_tns,
                          write_tnsb)
from repro.plan import plan_decomposition
from repro.plan.stats import measured_block_collision, tensor_stats
from repro.utils.report import plan_report

KEY = jax.random.PRNGKey(3)
# the skewed shape test_plan.py uses: mode 0 hot, mode 1 long/uniform
SKEWED_DIMS = (8, 5000, 64)


def skewed_tensor(nnz=2000):
    return random_sparse(SKEWED_DIMS, nnz, KEY)


def small_tensor(nnz=300, dims=(17, 23, 9)):
    return random_sparse(dims, nnz, KEY)


# ---------------------------------------------------------------------------
# reader: .tns text
# ---------------------------------------------------------------------------

def test_read_tns_tolerates_comments_and_blanks(tmp_path):
    p = tmp_path / "x.tns"
    p.write_text(
        "# a FROSTT comment\n"
        "\n"
        "1 1 1 2.5\n"
        "% matrix-market-style comment\n"
        "  \t \n"
        "2 3 1 -1.0\n")
    t = read_tns(p)
    assert t.dims == (2, 3, 1) and t.nnz == 2
    assert np.allclose(np.asarray(t.vals), [2.5, -1.0])


def test_read_tns_rejects_ragged_arity(tmp_path):
    p = tmp_path / "x.tns"
    p.write_text("1 1 1 2.5\n1 2 0.5\n")
    with pytest.raises(ValueError, match="x.tns:2.*expected 4 fields"):
        read_tns(p)


def test_read_tns_rejects_non_numeric_and_zero_index(tmp_path):
    p = tmp_path / "x.tns"
    p.write_text("1 1 1 abc\n")
    with pytest.raises(ValueError, match="non-numeric"):
        read_tns(p)
    p.write_text("0 1 1 2.0\n")
    with pytest.raises(ValueError, match="1-based"):
        read_tns(p)


def test_read_tns_explicit_dims_keeps_empty_slices(tmp_path):
    p = tmp_path / "x.tns"
    p.write_text("1 1 1 1.0\n2 2 2 2.0\n")
    assert read_tns(p).dims == (2, 2, 2)  # inferred: shrinks
    t = read_tns(p, dims=(5, 2, 7))       # explicit: kept
    assert t.dims == (5, 2, 7)
    with pytest.raises(ValueError, match="out of range"):
        read_tns(p, dims=(1, 2, 2))
    with pytest.raises(ValueError, match="has 2 modes"):
        read_tns(p, dims=(2, 2))


def test_read_tns_duplicate_policies(tmp_path):
    p = tmp_path / "x.tns"
    p.write_text("1 1 1 1.0\n1 1 1 2.0\n2 1 1 4.0\n")
    t_sum = read_tns(p)  # default "sum"
    assert t_sum.nnz == 2
    assert np.isclose(float(t_sum.to_dense()[0, 0, 0]), 3.0)
    t_keep = read_tns(p, duplicates="keep")
    assert t_keep.nnz == 3
    with pytest.raises(ValueError, match="duplicate"):
        read_tns(p, duplicates="error")
    with pytest.raises(ValueError, match="policy"):
        read_tns(p, duplicates="nope")


def test_read_tns_streams_in_chunks(tmp_path):
    t = small_tensor()
    p = tmp_path / "x.tns"
    write_tns(p, t)
    t2 = read_tns(p, dims=t.dims, chunk_lines=7)  # many tiny chunks
    np.testing.assert_allclose(np.asarray(t2.to_dense()),
                               np.asarray(t.to_dense()), rtol=1e-6)


def test_write_read_tns_roundtrip_bit_exact(tmp_path):
    """The vectorized writer emits enough digits that every float32 value
    survives the text roundtrip bit-exactly."""
    t = small_tensor(nnz=500)
    p = tmp_path / "x.tns"
    write_tns(p, t)
    t2 = read_tns(p, dims=t.dims, duplicates="keep")
    assert t2.nnz == t.nnz
    lin = lambda x: np.ravel_multi_index(
        tuple(np.asarray(x.inds)[:, m] for m in range(3)), t.dims)
    a = np.asarray(t.vals)[np.argsort(lin(t))]
    b = np.asarray(t2.vals)[np.argsort(lin(t2))]
    np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# reader: .tnsb binary
# ---------------------------------------------------------------------------

def test_tnsb_roundtrip_and_convert(tmp_path):
    t = small_tensor()
    pb = tmp_path / "x.tnsb"
    write_tnsb(pb, t)
    for mmap in (True, False):
        t2 = read_tnsb(pb, mmap=mmap)
        assert t2.dims == t.dims and t2.nnz == t.nnz
        np.testing.assert_array_equal(np.asarray(t2.inds),
                                      np.asarray(t.inds[: t.nnz]))
        np.testing.assert_array_equal(np.asarray(t2.vals),
                                      np.asarray(t.vals[: t.nnz]))
    # text -> binary conversion
    pt = tmp_path / "x.tns"
    write_tns(pt, t)
    t3 = convert_tns(pt, tmp_path / "c.tnsb", dims=t.dims)
    t4 = read_tnsb(tmp_path / "c.tnsb")
    np.testing.assert_allclose(np.asarray(t4.to_dense()),
                               np.asarray(t.to_dense()), rtol=1e-6)
    assert t3.dims == t.dims


def test_tnsb_rejects_garbage(tmp_path):
    p = tmp_path / "bad.tnsb"
    p.write_bytes(b"not a tensor at all, but long enough for a header")
    with pytest.raises(ValueError, match="magic"):
        read_tnsb(p)
    p.write_bytes(b"shrt")
    with pytest.raises(ValueError, match="truncated"):
        read_tnsb(p)


# ---------------------------------------------------------------------------
# relabel: invertibility, composition, factor mapping
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("maker", [degree_sort, random_block, compact],
                         ids=["degree_sort", "random_block", "compact"])
def test_relabel_inverse_roundtrip(maker):
    t = skewed_tensor(nnz=800)
    rel = maker(t)
    t2 = rel.apply(t)
    t3 = rel.invert().apply(t2)
    np.testing.assert_array_equal(np.asarray(t3.inds),
                                  np.asarray(t.inds[: t.nnz]))
    np.testing.assert_array_equal(np.asarray(t3.vals),
                                  np.asarray(t.vals[: t.nnz]))
    # the relabeled tensor is the same tensor under a row bijection
    assert t2.nnz == t.nnz
    assert float(t2.norm()) == pytest.approx(float(t.norm()), rel=1e-6)


def test_compact_drops_empty_slices():
    t = skewed_tensor()
    rel = compact(t)
    t2 = rel.apply(t)
    assert t2.dims[1] < t.dims[1]  # 5000 rows, 2000 nnz -> empties dropped
    counts = np.bincount(np.asarray(t2.inds)[:, 1], minlength=t2.dims[1])
    assert counts.min() > 0


def test_relabel_compose_matches_sequential():
    t = skewed_tensor(nnz=600)
    r1 = compact(t)
    t_mid = r1.apply(t)
    r2 = degree_sort(t_mid)
    combined = r1.then(r2)
    a = r2.apply(r1.apply(t))
    b = combined.apply(t)
    np.testing.assert_array_equal(np.asarray(a.inds), np.asarray(b.inds))
    np.testing.assert_array_equal(np.asarray(a.vals), np.asarray(b.vals))
    # and the composite still inverts exactly
    t3 = combined.invert().apply(b)
    np.testing.assert_array_equal(np.asarray(t3.inds),
                                  np.asarray(t.inds[: t.nnz]))


def test_factor_map_roundtrip():
    t = skewed_tensor(nnz=600)
    rel = degree_sort(t)
    factors = init_factors(t.dims, 5, KEY)
    back = rel.restore_factors(rel.apply_factors(factors))
    for a, b in zip(factors, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_factors_zero_fills_dropped_slices():
    t = skewed_tensor()
    rel = compact(t)
    f2 = init_factors(rel.dims_new, 4, KEY)
    restored = rel.restore_factors(f2)
    assert restored[1].shape[0] == t.dims[1]
    empty = np.setdiff1d(np.arange(t.dims[1]),
                         np.asarray(rel.old_of_new[1]))
    assert np.all(np.asarray(restored[1])[empty] == 0.0)


# ---------------------------------------------------------------------------
# degree_sort reduces the measured intra-block collision (acceptance)
# ---------------------------------------------------------------------------

def test_degree_sort_reduces_measured_collision():
    """On the skewed tensor the contention-aware relinearization strictly
    reduces the planner's measured intra-block collision rate — on the mode
    it targets and in the cross-mode mean."""
    t = skewed_tensor()
    before = tensor_stats(t, block=512, row_tile=128)
    rel = degree_sort(t)
    after = tensor_stats(rel.apply(t), block=512, row_tile=128)
    m = rel.linearized_mode
    assert m is not None
    assert (after[m].block_collision_rate
            < before[m].block_collision_rate), (m, before[m], after[m])
    mean_b = np.mean([s.block_collision_rate for s in before])
    mean_a = np.mean([s.block_collision_rate for s in after])
    assert mean_a < mean_b
    # the histogram *expectation* is relabeling-invariant — sanity-check the
    # two stats really are different quantities
    for b, a in zip(before, after):
        assert a.collision_rate == pytest.approx(b.collision_rate, abs=1e-9)


def test_measured_block_collision_bounds():
    assert measured_block_collision(np.array([], dtype=np.int64), 8) == 0.0
    assert measured_block_collision(np.zeros(64, dtype=np.int64), 8) == \
        pytest.approx(1.0 - 8 / 64)
    distinct = np.arange(64)
    assert measured_block_collision(distinct, 8) == 0.0


# ---------------------------------------------------------------------------
# cache: content addressing, warm hits skip the build
# ---------------------------------------------------------------------------

def test_cache_warm_hit_skips_build_and_stats(tmp_path, monkeypatch):
    t = skewed_tensor()
    cold = ingest(t, reorder="degree_sort", cache=tmp_path / "c")
    assert not cold.cache_hit and cold.cache.misses == 1
    assert sorted(cold._csf) == [0, 1, 2]  # ALLMODE prebuild

    # a warm ingest must perform ZERO workspace builds
    import repro.core.csf as csf_mod
    calls = []
    real = csf_mod.build_csf
    monkeypatch.setattr(csf_mod, "build_csf",
                        lambda *a, **k: calls.append(a) or real(*a, **k))
    warm = ingest(t, reorder="degree_sort", cache=tmp_path / "c")
    assert warm.cache_hit and warm.cache.hits == 1
    assert calls == []

    # and the cached state is bit-identical to the cold one
    np.testing.assert_array_equal(np.asarray(warm.tensor.inds),
                                  np.asarray(cold.tensor.inds))
    assert warm.stats == cold.stats
    assert warm.stats_before == cold.stats_before
    assert warm.relabeling is not None
    for m in range(3):
        np.testing.assert_array_equal(
            np.asarray(warm._csf[m].row_ids),
            np.asarray(cold._csf[m].row_ids))


def test_cache_key_separates_options(tmp_path):
    t = skewed_tensor(nnz=200)
    k1 = content_key(t, block=512, row_tile=128)
    k2 = content_key(t, block=256, row_tile=128)
    k3 = content_key(t, block=512, row_tile=128, reorder="degree_sort")
    assert len({k1, k2, k3}) == 3
    t2 = SparseTensor(inds=t.inds, vals=t.vals * 2.0, dims=t.dims, nnz=t.nnz)
    assert content_key(t2, block=512, row_tile=128) != k1


def test_cache_key_of_file_matches_warm_path(tmp_path):
    t = small_tensor()
    p = tmp_path / "x.tnsb"
    write_tnsb(p, t)
    c = tmp_path / "c"
    cold = ingest(p, cache=c)
    warm = ingest(p, cache=c)
    assert not cold.cache_hit and warm.cache_hit
    assert warm.source == str(p)
    np.testing.assert_array_equal(np.asarray(warm.tensor.inds),
                                  np.asarray(t.inds[: t.nnz]))


def test_cpals_same_result_cold_and_warm(tmp_path):
    t = skewed_tensor(nnz=600)
    d1 = cp_als(ingest(t, cache=tmp_path / "c"), rank=4, niters=3, key=KEY)
    d2 = cp_als(ingest(t, cache=tmp_path / "c"), rank=4, niters=3, key=KEY)
    np.testing.assert_array_equal(np.asarray(d1.fit), np.asarray(d2.fit))
    for a, b in zip(d1.factors, d2.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# planner integration: ingest-time stats are reused
# ---------------------------------------------------------------------------

def test_plan_reuses_ingest_stats(monkeypatch):
    t = skewed_tensor()
    ing = ingest(t)
    ref = plan_decomposition(t, "auto", rank=8, backend="cpu")
    import repro.plan.planner as planner_mod
    monkeypatch.setattr(
        planner_mod, "mode_stats",
        lambda *a, **k: pytest.fail("planner re-measured stats"))
    plan = ing.plan("auto", rank=8, backend="cpu")
    assert plan.impls == ref.impls


def test_plan_rejects_mismatched_stats_geometry():
    t = skewed_tensor()
    stats = tuple(tensor_stats(t, block=256, row_tile=64))
    with pytest.raises(ValueError, match="block=256"):
        plan_decomposition(t, "auto", backend="cpu", stats=stats,
                           block=512, row_tile=128)
    with pytest.raises(ValueError, match="cover"):
        plan_decomposition(t, "auto", backend="cpu", stats=stats[:2])


def test_ingested_workspace_follows_plan():
    from repro.core.csf import CSF

    t = skewed_tensor()
    ing = ingest(t)
    plan = ing.plan("auto", rank=8, backend="cpu")
    ws = ing.workspace(plan)
    for p, w in zip(plan.modes, ws):
        if p.layout == "csf":
            assert isinstance(w, CSF) and w.mode == p.mode
        else:
            assert w is ing.tensor
    with pytest.raises(ValueError, match="tile"):
        bad = plan_decomposition(t, "segment", block=64, row_tile=32)
        ing.workspace(bad)


def test_plan_report_shows_reorder_deltas():
    t = skewed_tensor()
    ing = ingest(t, reorder="degree_sort")
    rep = plan_report(ing.plan("auto", rank=8, backend="cpu"),
                      reorder_deltas=ing.reorder_deltas())
    assert "reorder" in rep and "coll" in rep
    # identity ingest has no deltas; column renders as "-"
    rep2 = plan_report(ingest(t).plan("auto", rank=8, backend="cpu"))
    assert "reorder" in rep2


# ---------------------------------------------------------------------------
# end-to-end: reordered decomposition == natural, in original labels
# ---------------------------------------------------------------------------

def test_cpals_reordered_matches_natural_e2e():
    """CP-ALS on a degree_sort-reordered tensor, with factors mapped back
    through the inverse relabeling, matches the natural-order run: fit to
    1e-5 and factors elementwise (the ALS update is equivariant under row
    relabelings; only f32 reduction order differs)."""
    t = skewed_tensor(nnz=900)
    rank, niters = 4, 4
    f0 = init_factors(t.dims, rank, KEY, dtype=t.vals.dtype)

    def state_of(factors):
        r = jnp.ones((rank,), dtype=t.vals.dtype)
        z = jnp.array(0.0, dtype=t.vals.dtype)
        return CPALSState(tuple(factors), r, z, z,
                          jnp.array(0, dtype=jnp.int32))

    d_nat = cp_als(t, rank, niters=niters, impl="segment", key=KEY,
                   state=state_of(f0))

    ing = ingest(t, reorder="degree_sort")
    d_re = cp_als(ing, rank, niters=niters, impl="segment", key=KEY,
                  state=state_of(ing.relabeling.apply_factors(f0)))

    assert abs(float(d_nat.fit) - float(d_re.fit)) < 1e-5
    for a, b in zip(d_nat.factors, d_re.factors):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


def test_cpals_compacted_restores_original_labels():
    t = skewed_tensor(nnz=600)
    ing = ingest(t, compact=True)
    assert ing.dims[1] < t.dims[1]
    dec = cp_als(ing, rank=4, niters=3, key=KEY)
    # factors come back in the ORIGINAL label space
    assert ing.original_dims == t.dims
    for m, f in enumerate(dec.factors):
        assert f.shape[0] == t.dims[m]
    # empty slices reconstruct to zero
    empty = np.setdiff1d(np.arange(t.dims[1]),
                         np.asarray(t.inds[: t.nnz, 1]))
    coords = np.zeros((len(empty), 3), dtype=np.int32)
    coords[:, 1] = empty
    np.testing.assert_allclose(np.asarray(dec.values_at(jnp.asarray(coords))),
                               0.0, atol=1e-6)


def test_dist_cpals_accepts_ingested():
    from repro.core.distributed import dist_cp_als

    t = skewed_tensor(nnz=400)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    f_nat, lam_nat, fit_nat = dist_cp_als(t, 3, mesh, niters=2, key=KEY)
    ing = ingest(t, reorder="degree_sort")
    f_re, lam_re, fit_re = dist_cp_als(ing, 3, mesh, niters=2, key=KEY)
    assert f_re[0].shape == f_nat[0].shape  # original label space
    for f, d in zip(f_re, t.dims):
        assert f.shape[0] == d
    assert np.isfinite(float(fit_re))


def test_ingest_rejects_unknown_reorder():
    with pytest.raises(ValueError, match="unknown reorder"):
        ingest(skewed_tensor(nnz=50), reorder="nope")
    with pytest.raises(TypeError, match="SparseTensor or repro.ingest"):
        cp_als([1, 2, 3], rank=2)


def test_cache_key_includes_reader_options(tmp_path):
    """Different dims=/duplicates= reader settings must not share a cache
    entry (a warm hit would silently return the wrong tensor)."""
    t = small_tensor()
    p = tmp_path / "x.tns"
    write_tns(p, t)
    c = tmp_path / "c"
    a = ingest(p, cache=c)
    b = ingest(p, cache=c, dims=(40, 40, 40))
    assert not b.cache_hit and b.tensor.dims == (40, 40, 40)
    k = ingest(p, cache=c, duplicates="keep")
    assert not k.cache_hit


def test_read_any_tnsb_honors_dims_and_duplicates(tmp_path):
    from repro.ingest import read_any

    t = small_tensor()
    p = tmp_path / "x.tnsb"
    write_tnsb(p, t)
    with pytest.raises(ValueError, match="header says dims"):
        read_any(p, dims=(40, 40, 40))
    # a tnsb with duplicate coordinates trips the error policy
    dup = SparseTensor(
        inds=jnp.zeros((3, 3), dtype=jnp.int32),
        vals=jnp.ones((3,)), dims=(2, 2, 2), nnz=3)
    pd = tmp_path / "dup.tnsb"
    write_tnsb(pd, dup)
    with pytest.raises(ValueError, match="duplicate"):
        read_any(pd, duplicates="error")
    assert read_any(pd).nnz == 1          # "sum" collapses
    assert read_any(pd, duplicates="keep").nnz == 3


def test_cache_stale_version_self_heals(tmp_path, monkeypatch):
    import json as json_mod

    t = small_tensor()
    c = IngestCache(tmp_path / "c")
    cold = ingest(t, cache=c)
    key = cold.key
    # corrupt the entry's version on disk
    meta_path = c._dir(key) / "meta.json"
    meta = json_mod.loads(meta_path.read_text())
    meta["version"] = -1
    meta_path.write_text(json_mod.dumps(meta))
    again = ingest(t, cache=c)
    assert not again.cache_hit            # stale entry is a miss...
    third = ingest(t, cache=c)
    assert third.cache_hit                # ...and was rebuilt, not wedged


def test_cpals_rejects_conflicting_tile_with_ingested():
    t = skewed_tensor(nnz=200)
    ing = ingest(t, tile=(256, 64))
    with pytest.raises(ValueError, match="ingested with block=256"):
        cp_als(ing, rank=3, niters=1, block=512)
    # defaults follow the handle's geometry
    dec = cp_als(ing, rank=3, niters=1, key=KEY)
    assert np.isfinite(float(dec.fit))
