"""Roofline machinery: HLO collective parsing, wire-byte model, sharding
rules, and the flash-attention path (vs the exact sdpa reference)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.utils import roofline as RL


HLO_SAMPLE = """
ENTRY %main {
  %p0 = f32[16,1024]{1,0} parameter(0)
  %all-reduce.32 = f32[16,1024,1024]{2,1,0} all-reduce(%x), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%add
  %ag = bf16[2048,512]{1,0} all-gather(%y), channel_id=2, replica_groups=[32,8]<=[256], dimensions={0}
  %rs = f32[128,64]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[16,16]<=[256], to_apply=%add
  %a2a = bf16[64,64]{1,0} all-to-all(%w), channel_id=4, replica_groups=[16,16]<=[256]
  %cp = f32[256]{0} collective-permute(%v), channel_id=5, source_target_pairs={{0,1}}
  %ars = (f32[128]{0}, f32[256]{0}) all-reduce-start(%a, %b), channel_id=6, replica_groups=[2,128]<=[256], to_apply=%add
  %ard = (f32[128]{0}, f32[256]{0}) all-reduce-done(%ars)
  %fus = f32[16,1024]{1,0} fusion(%p0), kind=kLoop
}
"""


def test_parse_collectives_kinds_and_groups():
    colls = RL.parse_collectives(HLO_SAMPLE)
    kinds = sorted(c["kind"] for c in colls)
    assert kinds == ["all-gather", "all-reduce", "all-reduce", "all-to-all",
                     "collective-permute", "reduce-scatter"]
    by_kind = {c["kind"]: c for c in colls if c["kind"] != "all-reduce"}
    # all-gather: 2048*512*2 bytes result, group 8
    ag = by_kind["all-gather"]
    assert ag["bytes"] == 2048 * 512 * 2 and ag["group"] == 8
    assert ag["wire"] == pytest.approx(ag["bytes"] * 7 / 8)
    # reduce-scatter: result bytes * (g-1)
    rs = by_kind["reduce-scatter"]
    assert rs["wire"] == pytest.approx(128 * 64 * 4 * 15)
    # collective-permute: result bytes
    assert by_kind["collective-permute"]["wire"] == 256 * 4


def test_parse_async_start_not_done():
    colls = [c for c in RL.parse_collectives(HLO_SAMPLE)
             if c["kind"] == "all-reduce"]
    # one sync all-reduce + one -start (the -done is skipped)
    assert len(colls) == 2
    tup = [c for c in colls if c["group"] == 128][0]
    assert tup["bytes"] == (128 + 256) * 4


def test_allreduce_wire_model():
    colls = RL.parse_collectives(HLO_SAMPLE)
    ar = [c for c in colls if c["kind"] == "all-reduce" and c["group"] == 16][0]
    b = 16 * 1024 * 1024 * 4
    assert ar["wire"] == pytest.approx(2 * b * 15 / 16)


def test_analyze_dominant_term():
    r = RL.analyze_values(flops=197e12, bytes_accessed=819e9 * 2,
                          wire_bytes=0, collectives={}, n_chips=4,
                          model_flops=197e12 * 2)
    assert r.dominant == "memory"
    assert r.compute_s == pytest.approx(1.0)
    assert r.memory_s == pytest.approx(2.0)
    assert r.useful_ratio == pytest.approx(0.5)


def test_model_flops_estimate_kinds():
    from repro import configs
    from repro.models.config import SHAPES
    cfg = configs.get("llama3.2-3b")
    tr = RL.model_flops_estimate(cfg, SHAPES["train_4k"])
    pf = RL.model_flops_estimate(cfg, SHAPES["prefill_32k"])
    de = RL.model_flops_estimate(cfg, SHAPES["decode_32k"])
    assert tr == pytest.approx(6 * cfg.param_count() * 256 * 4096)
    assert pf == pytest.approx(2 * cfg.param_count() * 32 * 32768)
    assert de == pytest.approx(2 * cfg.param_count() * 128)
    # MoE: active params, not total
    kimi = configs.get("kimi-k2-1t-a32b")
    assert (RL.model_flops_estimate(kimi, SHAPES["train_4k"])
            < 6 * kimi.param_count() * 256 * 4096 * 0.1)


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_spec_for_divisibility_guard():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import spec_for, rules_for

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    rules = rules_for(None)
    # divisible vocab shards; non-divisible kv_heads stays replicated
    assert spec_for(("vocab", "embed"), (256000, 3072), FakeMesh(), rules) \
        == P("model")
    assert spec_for(("embed", "kv_heads", "head_dim"), (4096, 8, 128),
                    FakeMesh(), rules) == P()
    assert spec_for(("embed", "heads", "head_dim"), (4096, 64, 128),
                    FakeMesh(), rules) == P(None, "model")


def test_spec_for_no_double_axis_use():
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import spec_for

    class FakeMesh:
        shape = {"data": 4, "model": 4}

    rules = {"a": "model", "b": "model"}
    # second dim wanting 'model' must stay unsharded (axis already used)
    assert spec_for(("a", "b"), (16, 16), FakeMesh(), rules) == P("model")


# ---------------------------------------------------------------------------
# flash attention (exactness vs sdpa)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["causal", "bidir", "local"])
@pytest.mark.parametrize("skip", [False, True])
def test_flash_matches_sdpa(kind, skip):
    from repro.models import layers as L
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="t", family="dense", num_layers=1, d_model=64,
                      num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
                      vocab=64, window=48, param_dtype="float32",
                      compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    B, S = 2, 128
    q = jax.random.normal(key, (B, S, 4, 16)) * 0.5
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, 2, 16)) * 0.5
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, 2, 16))
    mask = L._train_mask(kind, S, cfg.window)[None, None, None]
    want = L._sdpa(cfg, q, k, v, mask)
    got = L._flash_attention(cfg, q, k, v, kind, qb=32, kb=32,
                             block_skip=skip)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=2e-5)


def test_chunked_loss_matches_full():
    import dataclasses
    from repro import configs
    from repro.models import Model
    cfg = configs.smoke_of(configs.get("llama3.2-3b"))
    m_full = Model(cfg)
    m_chunk = Model(dataclasses.replace(cfg, chunked_loss=8))
    params = m_full.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, cfg.vocab)
    batch = {"tokens": tok, "labels": tok}
    l1, _ = m_full.loss(params, batch)
    l2, _ = m_chunk.loss(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
