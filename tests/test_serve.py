"""repro.serve: bucketed padding, top-k scoring math (CP + Tucker),
registry hot-swap/eviction, continuous-batching queue semantics,
compile-once-per-bucket, concurrent correctness under load, DecompServer
front door, and the ServeDaemon HTTP surface."""
import threading
import time
from concurrent.futures import wait

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import MethodConfig, RunConfig, ServeConfig, Session
from repro.methods import fit as methods_fit
from repro.obs.metrics import MetricsRegistry, scoped_registry
from repro.serve import (BatchQueue, DecompServer, ModelRegistry, ServeDaemon,
                         TenantModel, bucket_for, make_score_fn, pad_rows,
                         resident_bytes)
from conftest import exact_lowrank_tensor

KEY = jax.random.PRNGKey(0)


def lowrank(dims=(10, 9, 8), rank=3, key=KEY):
    return exact_lowrank_tensor(dims, rank, key)


@pytest.fixture(scope="module")
def cp():
    """One fitted CP decomposition shared by the module (fits are the
    slow part; every consumer treats it as immutable)."""
    return methods_fit(lowrank(), 4, niters=15, key=KEY)


@pytest.fixture(scope="module")
def tucker():
    return methods_fit(lowrank(), 3, method="tucker_hooi", niters=10,
                       key=KEY)


# ---------------------------------------------------------------------------
# bucketed padding
# ---------------------------------------------------------------------------

def test_bucket_for_picks_smallest_fitting():
    assert bucket_for(1, (16, 64, 256)) == 16
    assert bucket_for(16, (16, 64, 256)) == 16
    assert bucket_for(17, (16, 64, 256)) == 64
    assert bucket_for(256, (16, 64, 256)) == 256
    with pytest.raises(ValueError, match="chunk before bucketing"):
        bucket_for(257, (16, 64, 256))


def test_pad_rows_zero_pads_and_noops_at_size():
    x = np.ones((3, 2), dtype=np.float32)
    padded = pad_rows(x, 8)
    assert padded.shape == (8, 2)
    assert isinstance(padded, np.ndarray)  # host-side: no eager device op
    np.testing.assert_array_equal(padded[3:], 0.0)
    assert pad_rows(x, 3) is x


# ---------------------------------------------------------------------------
# top-k scoring math vs dense reconstruction
# ---------------------------------------------------------------------------

def _dense_scores(dec, user_mode=0, item_mode=1):
    """Reference: reconstruct the FULL tensor, sum out every mode except
    user/item, read the matrix."""
    order = len(dec.factors)
    dims = [f.shape[0] for f in dec.factors]
    grids = jnp.meshgrid(*[jnp.arange(d) for d in dims], indexing="ij")
    inds = jnp.stack([g.reshape(-1) for g in grids], 1).astype(jnp.int32)
    full = dec.values_at(inds).reshape(dims)
    axes = tuple(m for m in range(order) if m not in (user_mode, item_mode))
    mat = jnp.sum(full, axis=axes)
    if user_mode > item_mode:
        mat = mat.T
    return np.asarray(mat)


@pytest.mark.parametrize("kind", ["cp", "tucker"])
def test_score_fn_matches_dense_marginal(kind, cp, tucker):
    dec = cp if kind == "cp" else tucker
    ref = _dense_scores(dec)
    got = np.asarray(make_score_fn(dec)(jnp.arange(10)))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_score_fn_nondefault_modes(cp):
    ref = _dense_scores(cp, user_mode=2, item_mode=0)
    got = np.asarray(make_score_fn(cp, user_mode=2, item_mode=0)(
        jnp.arange(8)))
    np.testing.assert_allclose(got, ref, rtol=1e-4, atol=1e-4)


def test_score_fn_rejects_bad_modes_and_types(cp):
    with pytest.raises(ValueError, match="distinct modes"):
        make_score_fn(cp, user_mode=1, item_mode=1)
    with pytest.raises(ValueError, match="distinct modes"):
        make_score_fn(cp, user_mode=0, item_mode=3)
    with pytest.raises(TypeError, match="CP .* or Tucker"):
        make_score_fn(object())


def test_top_k_clamps_k_to_items(cp):
    model = TenantModel(cp, (10, 9, 8), buckets=(4,))
    scores, items = model.top_k(jnp.arange(2), 99)
    assert scores.shape == (2, 9) and items.shape == (2, 9)


def test_resident_bytes_counts_factors_and_aux(cp, tucker):
    want = sum(np.asarray(f).nbytes for f in cp.factors) \
        + np.asarray(cp.lmbda).nbytes
    assert resident_bytes(cp) == want
    want_t = sum(np.asarray(f).nbytes for f in tucker.factors) \
        + np.asarray(tucker.core).nbytes
    assert resident_bytes(tucker) == want_t


# ---------------------------------------------------------------------------
# TenantModel: compile-once-per-bucket
# ---------------------------------------------------------------------------

def test_values_at_compiles_once_per_bucket(cp):
    t = lowrank()
    model = TenantModel(cp, t.dims, buckets=(4, 16))
    rng = np.random.default_rng(0)
    for n in (1, 3, 4, 5, 16, 2, 40, 16, 7):
        coords = np.stack([rng.integers(0, d, n) for d in t.dims],
                          -1).astype(np.int32)
        got = model.values_at(coords)
        ref = cp.values_at(jnp.asarray(coords))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-5, atol=1e-6)
    # sizes hit both buckets many times over; each jits exactly once
    assert model.compile_count["values_at"] == 2


def test_top_k_compiles_once_per_bucket_and_k(cp):
    model = TenantModel(cp, (10, 9, 8), buckets=(4, 16))
    for n in (1, 2, 4, 9, 16, 3):
        model.top_k(jnp.arange(n), 3)
    assert model.compile_count["top_k"] == 2  # buckets 4 and 16, one k
    model.top_k(jnp.arange(2), 5)  # new static k -> one more variant
    assert model.compile_count["top_k"] == 3


def test_oversize_batch_chunks_at_largest_bucket(cp):
    t = lowrank()
    model = TenantModel(cp, t.dims, buckets=(4, 8))
    coords = np.asarray(t.inds[:30])
    got = model.values_at(coords)
    assert got.shape == (30,)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(cp.values_at(t.inds[:30])),
                               rtol=1e-5, atol=1e-6)
    scores, items = model.top_k(jnp.arange(10) % 10, 3)
    assert scores.shape == (10, 3)
    assert model.compile_count["values_at"] <= 2


# ---------------------------------------------------------------------------
# ModelRegistry: hot-swap + LRU eviction
# ---------------------------------------------------------------------------

def test_registry_swap_is_atomic_handle_replacement(cp, tucker):
    reg = ModelRegistry(buckets=(4,))
    e1 = reg.publish("t", cp)
    old_model = reg.get("t").model
    e2 = reg.publish("t", tucker)
    assert e2.generation == e1.generation + 1
    assert reg.get("t").model is not old_model
    # the old handle still answers — in-flight batches finish on it
    assert old_model.values_at(np.zeros((1, 3), np.int32)).shape == (1,)


def test_registry_unknown_tenant_names_residents(cp):
    reg = ModelRegistry()
    reg.publish("a", cp)
    with pytest.raises(KeyError, match=r"not published.*'a'"):
        reg.get("b")


def test_registry_lru_eviction_respects_budget(cp):
    one = resident_bytes(cp)
    with scoped_registry():
        reg = ModelRegistry(budget_bytes=2 * one, buckets=(4,))
        reg.publish("a", cp)
        reg.publish("b", cp)
        reg.get("a")  # a is now more recently used than b
        reg.publish("c", cp)  # over budget -> evict LRU = b
        assert "a" in reg and "c" in reg and "b" not in reg
        with pytest.raises(KeyError, match="evicted"):
            reg.get("b")
        assert reg.resident_bytes() == 2 * one
        # the tenant just published is never the victim, even over budget
        reg2 = ModelRegistry(budget_bytes=one // 2, buckets=(4,))
        reg2.publish("only", cp)
        assert "only" in reg2


def test_registry_republish_after_eviction_clears_state(cp):
    one = resident_bytes(cp)
    reg = ModelRegistry(budget_bytes=one, buckets=(4,))
    reg.publish("a", cp)
    reg.publish("b", cp)  # evicts a
    assert "a" not in reg
    reg.publish("a", cp)  # back in (evicts b)
    # eviction cleared a's slot, so this is a fresh publish, not a swap
    assert "a" in reg and reg.get("a").generation == 1


# ---------------------------------------------------------------------------
# BatchQueue: coalescing, futures, failure delivery
# ---------------------------------------------------------------------------

def _queue(cp, **kw):
    reg = ModelRegistry(buckets=kw.pop("buckets", (4, 16)))
    reg.publish("t", cp)
    return reg, BatchQueue(reg, buckets=reg.buckets, **kw)


def test_queue_resolves_futures_with_correct_slices(cp):
    t = lowrank()
    reg, q = _queue(cp, max_wait_ms=5.0)
    try:
        futs = [q.submit("t", "values_at", np.asarray(t.inds[i:i + 3]))
                for i in range(0, 30, 3)]
        for i, f in enumerate(futs):
            ref = cp.values_at(t.inds[3 * i:3 * i + 3])
            np.testing.assert_allclose(np.asarray(f.result(timeout=10)),
                                       np.asarray(ref), rtol=1e-5, atol=1e-6)
    finally:
        q.stop()


def test_queue_coalesces_within_window(cp):
    """Requests submitted while a worker waits out the window land in ONE
    batch (fewer executed batches than submissions)."""
    t = lowrank()
    reg, q = _queue(cp, max_wait_ms=200.0)
    try:
        futs = [q.submit("t", "values_at", np.asarray(t.inds[i:i + 1]))
                for i in range(8)]
        wait(futs, timeout=10)
        assert q.batches_executed < 8
    finally:
        q.stop()


def test_queue_mixed_kinds_and_tenants_do_not_comingle(cp, tucker):
    reg = ModelRegistry(buckets=(16,))
    reg.publish("x", cp)
    reg.publish("y", tucker)
    q = BatchQueue(reg, buckets=(16,), max_wait_ms=50.0, workers=2)
    try:
        fv = q.submit("x", "values_at", np.zeros((2, 3), np.int32))
        fk = q.submit("x", "top_k", np.arange(2), k=3)
        fy = q.submit("y", "top_k", np.arange(2), k=3)
        assert fv.result(timeout=10).shape == (2,)
        sx, ix = fk.result(timeout=10)
        sy, iy = fy.result(timeout=10)
        assert ix.shape == (2, 3) and iy.shape == (2, 3)
        # different models genuinely answered
        assert not np.allclose(np.asarray(sx), np.asarray(sy))
    finally:
        q.stop()


def test_queue_delivers_failures_via_futures(cp):
    reg, q = _queue(cp, max_wait_ms=1.0)
    try:
        f = q.submit("nobody", "values_at", np.zeros((1, 3), np.int32))
        with pytest.raises(KeyError, match="not published"):
            f.result(timeout=10)
    finally:
        q.stop()


def test_queue_submit_validation(cp):
    reg, q = _queue(cp)
    try:
        with pytest.raises(ValueError, match="unknown query kind"):
            q.submit("t", "frobnicate", np.zeros((1, 3), np.int32))
        with pytest.raises(ValueError, match=r"\(n, order\)"):
            q.submit("t", "values_at", np.zeros(3, np.int32))
        with pytest.raises(ValueError, match="k >= 1"):
            q.submit("t", "top_k", np.arange(2))
    finally:
        q.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        q.submit("t", "values_at", np.zeros((1, 3), np.int32))


def test_queue_stop_drains_pending(cp):
    t = lowrank()
    reg, q = _queue(cp, max_wait_ms=500.0)
    futs = [q.submit("t", "values_at", np.asarray(t.inds[i:i + 2]))
            for i in range(0, 20, 2)]
    q.stop()  # must not strand the already-submitted futures
    for f in futs:
        assert f.result(timeout=1) is not None


# ---------------------------------------------------------------------------
# DecompServer: concurrency, hot-swap under load, metrics
# ---------------------------------------------------------------------------

def test_server_concurrent_clients_compile_once_per_bucket(cp):
    """4 threads x mixed values_at/top_k: every result exact, and the
    models never jit more than one variant per (bucket[, k]) shape."""
    t = lowrank()
    with scoped_registry():
        with DecompServer(buckets=(4, 16), max_wait_ms=2.0,
                          workers=2) as srv:
            srv.publish("t", cp, t.dims)
            ref_vals = np.asarray(cp.values_at(t.inds))
            ref_scores, ref_items = (np.asarray(a) for a in
                                     make_topk_ref(cp, 10, 3))
            errors = []

            def client(seed):
                rng = np.random.default_rng(seed)
                try:
                    for _ in range(12):
                        if rng.random() < 0.5:
                            idx = rng.integers(0, t.nnz, rng.integers(1, 9))
                            got = srv.values_at("t", np.asarray(t.inds)[idx])
                            np.testing.assert_allclose(
                                np.asarray(got), ref_vals[idx],
                                rtol=1e-5, atol=1e-6)
                        else:
                            u = int(rng.integers(0, 10))
                            scores, items = srv.top_k_for_user("t", u, k=3)
                            np.testing.assert_array_equal(
                                np.asarray(items), ref_items[u])
                except Exception as e:  # surfaced below
                    errors.append(e)

            threads = [threading.Thread(target=client, args=(s,))
                       for s in range(4)]
            for th in threads:
                th.start()
            for th in threads:
                th.join(timeout=60)
            assert not errors, errors
            model = srv.registry.get("t").model
            assert model.compile_count["values_at"] <= 2  # one per bucket
            assert model.compile_count["top_k"] <= 2      # one per bucket @ k=3


def make_topk_ref(dec, n_users, k):
    scores = make_score_fn(dec)(jnp.arange(n_users))
    return jax.lax.top_k(scores, k)


def test_server_hot_swap_drops_zero_inflight_queries(cp, tucker):
    """Re-publishing a tenant while clients hammer it: every future
    resolves (no drops, no exceptions), and results always come from one
    complete model or the other."""
    t = lowrank()
    with DecompServer(buckets=(4, 16), max_wait_ms=1.0, workers=2) as srv:
        srv.publish("t", cp, t.dims)
        stop = threading.Event()
        futs, errors = [], []

        def client():
            while not stop.is_set():
                futs.append(srv.submit_values_at(
                    "t", np.asarray(t.inds[:5])))
                time.sleep(0.001)

        threads = [threading.Thread(target=client) for _ in range(3)]
        for th in threads:
            th.start()
        for swap_to in (tucker, cp, tucker):
            time.sleep(0.05)
            srv.publish("t", swap_to, t.dims)
        time.sleep(0.05)
        stop.set()
        for th in threads:
            th.join(timeout=30)
        srv.close()  # drain
        ref_a = np.asarray(cp.values_at(t.inds[:5]))
        ref_b = np.asarray(tucker.values_at(t.inds[:5]))
        assert len(futs) > 0
        for f in futs:
            got = np.asarray(f.result(timeout=10))  # zero drops
            assert (np.allclose(got, ref_a, rtol=1e-4, atol=1e-5)
                    or np.allclose(got, ref_b, rtol=1e-4, atol=1e-5))
        assert srv.registry.get("t").generation == 4


def test_server_emits_per_tenant_metrics(cp):
    with scoped_registry() as reg:
        with DecompServer(buckets=(4,), max_wait_ms=0.5) as srv:
            srv.publish("acme", cp)
            srv.values_at("acme", np.zeros((2, 3), np.int32))
            srv.top_k("acme", np.arange(2), k=2)
        snap = reg.snapshot()
        assert snap["serve.acme.queries"]["value"] == 2.0
        assert snap["serve.acme.query_ms"]["count"] == 2
        assert snap["serve.batch_fill"]["count"] >= 2
        assert 0.0 < snap["serve.batch_fill"]["mean"] <= 1.0
        assert snap["serve.registry.models"]["value"] == 1.0
        assert snap["serve.registry.resident_bytes"]["value"] \
            == resident_bytes(cp)
        assert snap["serve.qps"]["value"] > 0.0
        assert "serve.queue.depth" in snap


def test_server_from_config_and_session_integration():
    t = lowrank()
    cfg = RunConfig(method=MethodConfig(rank=4, niters=3),
                    serve=ServeConfig(buckets=(8,), max_wait_ms=0.5,
                                      tenants=("a", "b"),
                                      max_resident_mb=64.0))
    sess = Session.from_config(cfg, tensor=t)
    try:
        srv = sess.decomp_server()
        assert sess.decomp_server() is srv  # cached like other stages
        assert sorted(srv.tenants()) == ["a", "b"]
        got = srv.values_at("b", np.asarray(t.inds[:6]))
        ref = sess.serve_handle().query(np.asarray(t.inds[:6]))
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-6)
        stats = srv.stats()
        assert stats["batches_executed"] >= 1
    finally:
        sess.close()
    with pytest.raises(RuntimeError, match="stopped"):
        srv.submit_values_at("a", np.zeros((1, 3), np.int32))


# ---------------------------------------------------------------------------
# ServeDaemon HTTP surface
# ---------------------------------------------------------------------------

def test_daemon_http_endpoints(cp):
    import json
    import urllib.request

    t = lowrank()
    with scoped_registry():
        with DecompServer(buckets=(4,), max_wait_ms=0.5) as srv:
            srv.publish("web", cp, t.dims)
            with ServeDaemon(srv, port=0) as daemon:
                def get(path):
                    return json.loads(urllib.request.urlopen(
                        daemon.url + path, timeout=10).read())

                health = get("/healthz")
                assert health["status"] == "serving"
                assert "web" in health["tenants"]
                tenants = get("/v1/tenants")
                assert tenants["web"]["dims"] == list(t.dims)
                topk = get("/v1/top_k?tenant=web&user=1&k=3")
                ref_s, ref_i = make_topk_ref(cp, 10, 3)
                assert topk["items"] == [int(i) for i in ref_i[1]]
                req = urllib.request.Request(
                    daemon.url + "/v1/values_at",
                    data=json.dumps(
                        {"tenant": "web",
                         "coords": np.asarray(t.inds[:3]).tolist()}).encode())
                vals = json.loads(urllib.request.urlopen(
                    req, timeout=10).read())
                np.testing.assert_allclose(
                    vals["values"], np.asarray(cp.values_at(t.inds[:3])),
                    rtol=1e-5, atol=1e-6)
                # prometheus exposition carries the per-tenant metrics
                prom = urllib.request.urlopen(
                    daemon.url + "/metrics", timeout=10).read().decode()
                assert "serve_web_query_ms" in prom
                assert "serve_registry_models" in prom
                # unknown tenant -> 404 with the resident set named
                with pytest.raises(urllib.error.HTTPError) as ei:
                    urllib.request.urlopen(
                        daemon.url + "/v1/top_k?tenant=ghost&user=0&k=2",
                        timeout=10)
                assert ei.value.code == 404
                # clean scripted shutdown
                sreq = urllib.request.Request(
                    daemon.url + "/v1/shutdown", data=b"")
                urllib.request.urlopen(sreq, timeout=10)
                assert daemon.shutdown_requested.wait(timeout=5)
