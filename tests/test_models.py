"""Model-substrate correctness: attention semantics (GQA / causal / local /
rope), decode-vs-forward consistency per family, RWKV chunked == scan,
RG-LRU associative scan == sequential loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models import rwkv as RW
from repro.models import rglru as RG
from repro.models.config import ModelConfig, MoEConfig
from repro.models.transformer import Model

KEY = jax.random.PRNGKey(3)

BASE = dict(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2, head_dim=16,
            d_ff=128, vocab=128, param_dtype="float32", compute_dtype="float32")


def dense_cfg(**kw):
    d = dict(BASE, name="t", family="dense")
    d.update(kw)
    return ModelConfig(**d)


# ---------------------------------------------------------------------------
# attention semantics
# ---------------------------------------------------------------------------

def test_gqa_equals_repeated_mha():
    """GQA with kv=2 == MHA where each kv head is repeated q_per_kv times."""
    cfg = dense_cfg()
    p = {k: jax.random.normal(jax.random.fold_in(KEY, i), v.shape) * 0.1
         for i, (k, v) in enumerate(
             jax.tree.map(lambda s: s, L.attn_specs(cfg),
                          is_leaf=lambda x: hasattr(x, "shape")).items())}
    x = jax.random.normal(KEY, (2, 8, cfg.d_model)) * 0.5
    out, _ = L.attention(p, cfg, x, mask_kind="causal")

    # expand kv heads to full MHA
    cfg_mha = dense_cfg(num_kv_heads=4)
    g = cfg.num_heads // cfg.num_kv_heads
    p_mha = dict(p)
    p_mha["wk"] = jnp.repeat(p["wk"], g, axis=1)
    p_mha["wv"] = jnp.repeat(p["wv"], g, axis=1)
    out_mha, _ = L.attention(p_mha, cfg_mha, x, mask_kind="causal")
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_mha),
                               rtol=1e-4, atol=1e-5)


def test_causal_mask_no_future_leak():
    """Changing future tokens must not change past outputs."""
    cfg = dense_cfg()
    m = Model(cfg)
    params = m.init(KEY)
    tok = jax.random.randint(KEY, (1, 10), 0, cfg.vocab)
    tok2 = tok.at[0, -1].set((tok[0, -1] + 1) % cfg.vocab)
    lg1, _, _ = m.forward(params, {"tokens": tok}, mode="train")
    lg2, _, _ = m.forward(params, {"tokens": tok2}, mode="train")
    np.testing.assert_allclose(np.asarray(lg1[:, :-1]), np.asarray(lg2[:, :-1]),
                               rtol=1e-4, atol=1e-5)


def test_local_window_attention_ignores_distant_tokens():
    cfg = dense_cfg(attn_kind="local", window=4)
    p = jax.tree.map(lambda s: 0.1 * jax.random.normal(KEY, s.shape),
                     L.attn_specs(cfg), is_leaf=lambda x: hasattr(x, "shape"))
    x = jax.random.normal(KEY, (1, 12, cfg.d_model))
    out, _ = L.attention(p, cfg, x, mask_kind="local")
    # perturb a token > window away from the last position
    x2 = x.at[0, 2].set(x[0, 2] + 5.0)
    out2, _ = L.attention(p, cfg, x2, mask_kind="local")
    np.testing.assert_allclose(np.asarray(out[0, -1]), np.asarray(out2[0, -1]),
                               rtol=1e-4, atol=1e-5)


def test_rope_relative_shift_invariance():
    """RoPE scores depend only on relative positions."""
    cfg = dense_cfg()
    q = jax.random.normal(KEY, (1, 6, 4, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 6, 2, 16))
    pos = jnp.arange(6)[None, :]
    q1, k1 = L.apply_rope(cfg, q, k, pos)
    q2, k2 = L.apply_rope(cfg, q, k, pos + 37)
    s1 = jnp.einsum("bshd,bthd->bst", q1[:, :, :2], k1)
    s2 = jnp.einsum("bshd,bthd->bst", q2[:, :, :2], k2)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3, atol=1e-4)


def test_mrope_sections_use_their_position_stream():
    cfg = dense_cfg(rope="mrope", mrope_sections=(3, 3, 2))
    q = jax.random.normal(KEY, (1, 4, 2, 16))
    k = jax.random.normal(KEY, (1, 4, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(4)[None, None], (3, 1, 4)).astype(jnp.int32)
    q1, _ = L.apply_rope(cfg, q, k, pos)
    # change only the w-stream: t/h sections of the rotation must not move
    pos2 = pos.at[2].add(11)
    q2, _ = L.apply_rope(cfg, q, k, pos2)
    # first 3 (t) freq slots unchanged in both rotated halves
    np.testing.assert_allclose(np.asarray(q1[..., :3]), np.asarray(q2[..., :3]),
                               rtol=1e-4, atol=1e-5)
    assert not np.allclose(np.asarray(q1), np.asarray(q2))


# ---------------------------------------------------------------------------
# decode == forward consistency (per family)
# ---------------------------------------------------------------------------

def _decode_consistency(cfg, *, src=False, mrope=False, atol=2e-2):
    """prefill(S tokens) then decode S+1'th == forward over S+1 tokens."""
    m = Model(cfg)
    params = m.init(KEY)
    B, S = 2, 12
    tok = jax.random.randint(KEY, (B, S + 1), 0, cfg.vocab)
    batch_full = {"tokens": tok}
    batch_pre = {"tokens": tok[:, :S]}
    if cfg.input_mode == "embeds":
        emb = L.embed({"table": params["embed"]["table"]}, cfg, tok)
        batch_full = {"embeds": emb}
        batch_pre = {"embeds": emb[:, :S]}
    if mrope:
        pos = jnp.broadcast_to(jnp.arange(S + 1)[None, None],
                               (3, B, S + 1)).astype(jnp.int32)
        batch_full["positions"] = pos
        batch_pre["positions"] = pos[:, :, :S]
    if src:
        se = jax.random.normal(KEY, (B, 8, cfg.d_model), dtype=jnp.float32)
        batch_full["src_embeds"] = se
        batch_pre["src_embeds"] = se

    lg_full, _, _ = m.forward(params, batch_full, mode="train")
    want = lg_full[:, -1]

    cache = m.init_cache(B, S + 4, src_len=8 if src else 0)
    _, cache = m.prefill(params, batch_pre, cache)
    kw = {}
    if mrope:
        kw["positions"] = jnp.full((3, B, 1), S, dtype=jnp.int32)
    got, _ = m.decode_step(params, tok[:, S:S + 1], cache,
                           jnp.array(S, dtype=jnp.int32), **kw)
    np.testing.assert_allclose(np.asarray(got[:, 0]), np.asarray(want),
                               rtol=1e-2, atol=atol)


def test_decode_consistency_dense():
    _decode_consistency(dense_cfg())


def test_decode_consistency_moe():
    cfg = ModelConfig(name="m", family="moe", pattern=("moe",),
                      num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                      head_dim=16, d_ff=128, vocab=128,
                      moe=MoEConfig(num_experts=4, top_k=2, d_ff=32,
                                    capacity_factor=4.0),
                      param_dtype="float32", compute_dtype="float32")
    _decode_consistency(cfg)


def test_decode_consistency_rwkv():
    cfg = ModelConfig(name="r", family="ssm", pattern=("rwkv",), rope="none",
                      num_layers=3, d_model=64, num_heads=4, num_kv_heads=4,
                      head_dim=16, d_ff=128, vocab=128, rwkv_head_dim=16,
                      param_dtype="float32", compute_dtype="float32")
    _decode_consistency(cfg)


def test_decode_consistency_hybrid_local():
    cfg = ModelConfig(name="h", family="hybrid", pattern=("rec", "rec", "attn"),
                      attn_kind="local", window=6,
                      num_layers=3, d_model=64, num_heads=4, num_kv_heads=1,
                      head_dim=16, d_ff=128, vocab=128, rglru_width=64,
                      param_dtype="float32", compute_dtype="float32")
    _decode_consistency(cfg)


def test_decode_consistency_encdec():
    cfg = ModelConfig(name="e", family="audio", encdec=True, enc_layers=2,
                      **BASE)
    _decode_consistency(cfg, src=True)


def test_decode_consistency_mrope_embeds():
    cfg = dense_cfg(rope="mrope", mrope_sections=(3, 3, 2),
                    input_mode="embeds", family="vlm")
    _decode_consistency(cfg, mrope=True)


# ---------------------------------------------------------------------------
# recurrent kernels
# ---------------------------------------------------------------------------

def test_rwkv_chunked_matches_scan():
    b, s, h, n = 2, 64, 3, 8
    ks = jax.random.split(KEY, 5)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, n)) * 0.5 for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, n)) * 0.5))
    u = jax.random.normal(ks[4], (h, n)) * 0.5
    o1, s1 = RW.wkv_scan(r, k, v, w, u)
    o2, s2 = RW.wkv_chunked(r, k, v, w, u, chunk=16)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3, atol=1e-4)


def test_rwkv_chunked_with_carried_state():
    b, s, h, n = 1, 32, 2, 8
    ks = jax.random.split(KEY, 6)
    r, k, v = (jax.random.normal(ks[i], (b, s, h, n)) * 0.5 for i in range(3))
    w = jnp.exp(-jnp.exp(jax.random.normal(ks[3], (b, s, h, n))))
    u = jax.random.normal(ks[4], (h, n)) * 0.5
    st0 = jax.random.normal(ks[5], (b, h, n, n)).astype(jnp.float32)
    o1, s1 = RW.wkv_scan(r, k, v, w, u, st0)
    o2, s2 = RW.wkv_chunked(r, k, v, w, u, st0, chunk=8)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-3, atol=1e-4)


def test_rglru_assoc_scan_matches_loop():
    b, s, w = 2, 16, 8
    a = jax.nn.sigmoid(jax.random.normal(KEY, (b, s, w)))
    bb = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, w))
    h0 = jax.random.normal(jax.random.fold_in(KEY, 2), (b, w))
    got = RG._rglru_scan(a, bb.copy(), h0)
    # sequential reference
    hs = []
    h = h0
    for t in range(s):
        h = a[:, t] * h + bb[:, t]
        hs.append(h)
    want = jnp.stack(hs, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4,
                               atol=1e-5)


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 and skewed routing some tokens drop; metric must report it."""
    cfg = ModelConfig(name="m", family="moe", pattern=("moe",),
                      num_layers=1, d_model=32, num_heads=2, num_kv_heads=2,
                      head_dim=16, d_ff=64, vocab=64,
                      moe=MoEConfig(num_experts=4, top_k=1, d_ff=32,
                                    capacity_factor=1.0),
                      param_dtype="float32", compute_dtype="float32")
    from repro.models.moe import moe_ffn, moe_specs
    from repro.models.params import init_params
    p = init_params(moe_specs(cfg), KEY, jnp.float32)
    x = jax.random.normal(KEY, (4, 16, 32))
    out, mets = moe_ffn(p, cfg, x)
    assert out.shape == x.shape
    assert 0.0 <= float(mets["moe_drop_frac"]) <= 0.9


def test_param_count_sane_dense():
    cfg = dense_cfg()
    m = Model(cfg)
    params = m.init(KEY)
    n_actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    n_est = cfg.param_count()
    assert abs(n_actual - n_est) / n_est < 0.25, (n_actual, n_est)
