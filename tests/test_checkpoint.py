"""Checkpoint manager: roundtrip, atomicity, keep-k, async, restart-resume,
optimizer correctness, data-pipeline determinism, straggler monitor."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, save_pytree, load_pytree
from repro.dist import StragglerMonitor
from repro.optim import adamw, adafactor

KEY = jax.random.PRNGKey(0)


def tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32),
                  "d": (jnp.zeros((2, 2)), jnp.full((3,), 2.5))}}


def test_roundtrip(tmp_path):
    t = tree()
    save_pytree(tmp_path / "ck", t, extra={"step": 7})
    restored, extra = load_pytree(tmp_path / "ck", like=t)
    assert extra["step"] == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_rename_never_leaves_partial(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, tree())
    # simulate a crashed write: stale .tmp next to a good checkpoint
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "garbage").write_text("x")
    assert mgr.latest_step() == 1
    restored, extra = mgr.restore(tree())
    assert extra["step"] == 1


def test_incomplete_checkpoint_is_skipped(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(1, tree())
    mgr.save(2, tree())
    # corrupt the newest: mark incomplete
    meta = tmp_path / "step_00000002" / "meta.json"
    m = json.loads(meta.read_text())
    m["complete"] = False
    meta.write_text(json.dumps(m))
    assert mgr.latest_step() == 1


def test_keep_k_garbage_collection(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2, async_save=False)
    for s in (1, 2, 3, 4, 5):
        mgr.save(s, tree())
    assert mgr.steps() == [4, 5]


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    t = tree()
    mgr.save(3, t)
    mgr.wait()
    restored, extra = mgr.restore(t)
    assert extra["step"] == 3


def test_train_restart_resumes_exactly(tmp_path):
    """Kill-and-restart produces the same params as an uninterrupted run."""
    from repro.launch.train import train
    r_full = train("llama3.2-3b", smoke=True, steps=6, batch=2, seq=16,
                   ckpt_dir=None, log_every=100)
    # interrupted: 3 steps -> checkpoint -> new process resumes to 6
    d = tmp_path / "ck"
    train("llama3.2-3b", smoke=True, steps=3, batch=2, seq=16,
          ckpt_dir=str(d), ckpt_every=100, log_every=100)
    r_resumed = train("llama3.2-3b", smoke=True, steps=6, batch=2, seq=16,
                      ckpt_dir=str(d), ckpt_every=100, log_every=100)
    assert abs(r_full["final_loss"] - r_resumed["final_loss"]) < 2e-3, \
        (r_full["final_loss"], r_resumed["final_loss"])


# ---------------------------------------------------------------------------
# decomposition resume: DecompState / CPALSState through the manager
# ---------------------------------------------------------------------------

def lowrank_tensor():
    from conftest import exact_lowrank_tensor
    return exact_lowrank_tensor((10, 9, 8), 3, KEY)


@pytest.mark.parametrize("method", ["cp_als", "cp_nn_hals", "tucker_hooi",
                                    "cp_als_streaming"])
def test_decomp_state_roundtrip_resumes_bit_exactly(tmp_path, method):
    """DecompState survives a save/load through checkpoint.manager and
    fit(..., state=restored) continues BIT-EXACTLY: the resumed run's final
    factors equal the uninterrupted run's."""
    from repro.methods import DecompState, fit, get_method

    t = lowrank_tensor()
    rank = (3, 3, 3) if method == "tucker_hooi" else 4
    kw = {"n_chunks": 3} if get_method(method).supports_streaming else {}

    states = []
    full = fit(t, rank, method=method, niters=8, key=KEY,
               checkpoint_cb=states.append, **kw)
    mid = states[3]  # the shared protocol state after iteration 4
    assert isinstance(mid, DecompState) and int(mid.iteration) == 4

    # through the manager: host npz + atomic rename + restore into the
    # pytree structure
    mgr = CheckpointManager(tmp_path / method, async_save=False)
    mgr.save(int(mid.iteration), mid)
    restored, extra = mgr.restore(mid)
    assert extra["step"] == 4
    assert isinstance(restored, DecompState)

    resumed = fit(t, rank, method=method, niters=8, key=KEY, state=restored,
                  **kw)
    for a, b in zip(full.factors, resumed.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(full.fit),
                                  np.asarray(resumed.fit))


def test_cpals_state_roundtrip_through_manager(tmp_path):
    """The historical CPALSState pytree also round-trips through the manager
    and resumes the core driver exactly (back-compat contract)."""
    from repro.core import cp_als
    from repro.core.cpals import CPALSState

    t = lowrank_tensor()
    states = []
    full = cp_als(t, rank=4, niters=6, key=KEY, checkpoint_cb=states.append)
    mid = states[2]
    assert isinstance(mid, CPALSState)

    mgr = CheckpointManager(tmp_path, async_save=False)
    mgr.save(int(mid.iteration), mid)
    restored, _ = mgr.restore(mid)
    resumed = cp_als(t, rank=4, niters=6, key=KEY, state=restored)
    for a, b in zip(full.factors, resumed.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def test_adamw_matches_reference_math():
    opt = adamw(lr=0.1, b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                clip_norm=0.0)
    p = {"w": jnp.array([1.0, -2.0])}
    g = {"w": jnp.array([0.5, 0.25])}
    st = opt.init(p)
    newp, st = opt.update(g, st, p, jnp.array(0))
    # step 1: m=0.1g v=0.01g^2; mhat=g, vhat=g^2 -> update ~ lr*sign-ish
    expect = 1.0 - 0.1 * (0.5 / (np.sqrt(0.25) + 1e-8))
    np.testing.assert_allclose(float(newp["w"][0]), expect, rtol=1e-5)


def test_adamw_descends_quadratic():
    opt = adamw(lr=0.05)
    w = jnp.array([3.0, -4.0])
    st = opt.init(w)
    for i in range(200):
        g = 2 * w
        w, st = opt.update(g, st, w, jnp.array(i))
    assert float(jnp.abs(w).max()) < 0.05


def test_adafactor_descends_and_factored_state_small():
    # Adafactor's RMS-clipped updates behave sign-SGD-like: it converges to
    # an lr-scale ball around the optimum, so test with a small lr.
    opt = adafactor(lr=0.02)
    w = jax.random.normal(KEY, (16, 8))
    st = opt.init(w)
    assert st["stats"]["vr"].shape == (16,)
    assert st["stats"]["vc"].shape == (8,)
    start = float(jnp.abs(w).max())
    for i in range(300):
        g = 2 * w
        w, st = opt.update(g, st, w, jnp.array(i))
    assert float(jnp.abs(w).max()) < 0.15 < start


def test_adafactor_state_is_sublinear():
    from repro.models.params import param_bytes
    opt = adafactor()
    p = {"big": jnp.zeros((1024, 1024))}
    st = opt.init(p)
    state_elems = sum(np.prod(x.shape) for x in jax.tree.leaves(st))
    assert state_elems < 1024 * 1024 / 100  # O(n+m), not O(nm)


# ---------------------------------------------------------------------------
# data pipeline / straggler
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_seekable():
    from repro import configs
    from repro.data import TokenPipeline
    cfg = configs.smoke_of(configs.get("llama3.2-3b"))
    p1 = TokenPipeline(cfg, 4, 32, seed=3)
    p2 = TokenPipeline(cfg, 4, 32, seed=3)
    b17a = p1.batch_at(17)
    b17b = p2.batch_at(17)  # no need to replay 0..16
    np.testing.assert_array_equal(np.asarray(b17a["tokens"]),
                                  np.asarray(b17b["tokens"]))
    b18 = p1.batch_at(18)
    assert not np.array_equal(np.asarray(b17a["tokens"]),
                              np.asarray(b18["tokens"]))
    # labels are next-token shifted
    np.testing.assert_array_equal(np.asarray(b17a["tokens"][:, 1:]),
                                  np.asarray(b17a["labels"][:, :-1]))


def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(window=10, threshold=1.5, patience=2)
    for step in range(8):
        for host in range(4):
            mon.record(host, 1.0 if host != 2 else 3.0)
        flags = mon.check()
    assert 2 in flags and flags[2] == "persistent"
    assert all(h == 2 for h in flags)
