"""CP-ALS core correctness: MTTKRP variants vs dense oracle, Alg. 1 semantics,
convergence on synthetic low-rank tensors (the paper's correctness floor)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    random_sparse, from_factors, build_csf, build_csf_tiled,
    mttkrp, cp_als, init_factors, gram, hadamard_grams, solve_cholesky,
    solve_gram, normalize, kruskal_fit,
)

KEY = jax.random.PRNGKey(42)


def small_tensor(order=3, skew=0.0, nnz=500, key=KEY):
    dims = (23, 17, 31, 11)[:order]
    return random_sparse(dims, nnz, key, skew=skew)


# ---------------------------------------------------------------------------
# MTTKRP variants vs the dense oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("impl", ["gather_scatter", "segment", "rowloop"])
@pytest.mark.parametrize("mode", [0, 1, 2])
@pytest.mark.parametrize("skew", [0.0, 1.5])
def test_mttkrp_matches_dense(impl, mode, skew):
    t = small_tensor(skew=skew)
    factors = init_factors(t.dims, 8, KEY)
    want = mttkrp(t, factors, mode, impl="dense")
    x = build_csf(t, mode, block=64) if impl == "segment" else t
    got = mttkrp(x, factors, mode, impl=impl)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("mode", [0, 1, 2, 3])
def test_mttkrp_order4(mode):
    """The paper limits itself to 3rd order; arbitrary order is our extension."""
    t = small_tensor(order=4, nnz=300)
    factors = init_factors(t.dims, 5, KEY)
    want = mttkrp(t, factors, mode, impl="dense")
    got = mttkrp(build_csf(t, mode, block=64), factors, mode, impl="segment")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_mttkrp_padding_is_noop():
    t = small_tensor()
    factors = init_factors(t.dims, 8, KEY)
    base = mttkrp(t, factors, 0, impl="gather_scatter")
    padded = t.pad_to(256)
    got = mttkrp(padded, factors, 0, impl="gather_scatter")
    np.testing.assert_allclose(np.asarray(got), np.asarray(base), rtol=1e-5)


# ---------------------------------------------------------------------------
# dense linear algebra pieces
# ---------------------------------------------------------------------------

def test_solve_cholesky_matches_lstsq():
    k1, k2 = jax.random.split(KEY)
    a = jax.random.normal(k1, (40, 8))
    v = a.T @ a + 0.1 * jnp.eye(8)
    m = jax.random.normal(k2, (30, 8))
    got = solve_cholesky(m, v)
    want = m @ jnp.linalg.inv(v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-4)


def test_solve_gram_matches_solve_cholesky():
    """The fused epilogue's inverse-then-GEMM solve agrees with the
    triangular-solve formulation on tall right-hand sides."""
    k1, k2 = jax.random.split(KEY, 2)
    a = jax.random.normal(k1, (60, 12))
    v = a.T @ a + 0.1 * jnp.eye(12)
    m = jax.random.normal(k2, (500, 12))
    got = solve_gram(m, v)
    want = solve_cholesky(m, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("kind", ["max", "2"])
def test_normalize_reconstruction_invariant(kind):
    """normalize() must not change lambda-weighted reconstruction."""
    a = jax.random.uniform(KEY, (20, 6)) + 0.1
    an, lam = normalize(a, kind=kind)
    np.testing.assert_allclose(np.asarray(an * lam[None, :]), np.asarray(a), rtol=1e-5)


def test_hadamard_grams_skips_mode():
    gs = [jnp.full((3, 3), float(i + 2)) for i in range(3)]
    v = hadamard_grams(gs, 1)
    np.testing.assert_allclose(np.asarray(v), np.full((3, 3), 2.0 * 4.0))


# ---------------------------------------------------------------------------
# CP-ALS end to end
# ---------------------------------------------------------------------------

from conftest import exact_lowrank_tensor  # noqa: E402 — shared construction


@pytest.mark.parametrize("impl", ["gather_scatter", "segment"])
def test_cpals_converges_on_exact_lowrank(impl):
    """fit -> ~1 on a fully-observed rank-4 tensor decomposed at rank 6."""
    kt, ki = jax.random.split(KEY)
    t = exact_lowrank_tensor((12, 10, 8), 4, kt)
    dec = cp_als(t, rank=6, niters=60, impl=impl, key=ki)
    assert float(dec.fit) > 0.98, f"fit {float(dec.fit)} too low"


def test_cpals_fit_monotone_tail():
    """ALS fit should be (weakly) increasing after the first iterations."""
    t = small_tensor(nnz=800)
    fits = []
    for n in (3, 6, 9):
        dec = cp_als(t, rank=4, niters=n, key=KEY)
        fits.append(float(dec.fit))
    assert fits[0] <= fits[1] + 1e-4 and fits[1] <= fits[2] + 1e-4, fits


def test_cpals_reconstruction_error_matches_fit():
    """fit reported by the inner-product trick == fit computed from a dense
    reconstruction (validates SPLATT's work-free fit formula)."""
    t = small_tensor(nnz=700)
    dec = cp_als(t, rank=5, niters=10, key=KEY)
    dense_x = np.asarray(t.to_dense())
    dense_hat = np.asarray(dec.to_dense())
    fro = np.linalg.norm(dense_x - dense_hat)
    fit_direct = 1.0 - fro / np.linalg.norm(dense_x)
    assert abs(float(dec.fit) - fit_direct) < 1e-3


def test_cpals_timers_cover_routines():
    t = small_tensor(nnz=400)
    timers = {}
    cp_als(t, rank=4, niters=3, key=KEY, timers=timers)
    for k in ("sort", "mttkrp", "ata", "inverse", "norm", "fit"):
        assert k in timers and timers[k] >= 0.0, (k, timers)


def test_cpals_state_restart_is_deterministic():
    """Fault-tolerance contract: restarting from a checkpointed CPALSState
    reproduces the uninterrupted run exactly (same iterates)."""
    t = small_tensor(nnz=600)
    states = []
    full = cp_als(t, rank=4, niters=8, key=KEY, checkpoint_cb=states.append)
    mid = states[3]  # state after iteration 4
    resumed = cp_als(t, rank=4, niters=8, key=KEY, state=mid)
    for a, b in zip(full.factors, resumed.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(full.lmbda), np.asarray(resumed.lmbda))


def test_cpals_tolerance_early_stop():
    t = small_tensor(nnz=500)
    dec = cp_als(t, rank=4, niters=100, tol=1e-3, key=KEY)
    # must have stopped early and still produce a sane fit
    assert 0.0 <= float(dec.fit) <= 1.0


def test_values_at_matches_dense():
    t = small_tensor(nnz=300)
    dec = cp_als(t, rank=4, niters=5, key=KEY)
    dense = np.asarray(dec.to_dense())
    inds = np.asarray(t.inds[:50])
    got = np.asarray(dec.values_at(t.inds[:50]))
    want = dense[inds[:, 0], inds[:, 1], inds[:, 2]]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
