"""Direct unit coverage for repro.dist: StragglerMonitor edge cases,
int8+error-feedback round trips on adversarial pytrees, and the shared
collectives vocabulary."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist import (StragglerMonitor, axis_product, batch_axes,
                        cpals_axes)
from repro.dist.compress import (compress_grads_int8, compression_ratio,
                                 decompress_grads_int8, init_error_feedback)


# ---------------------------------------------------------------------------
# StragglerMonitor
# ---------------------------------------------------------------------------

def test_straggler_warmup_window_is_silent():
    """No flags until every seen host has `warmup` samples."""
    mon = StragglerMonitor(window=8, threshold=1.5, patience=1, warmup=3)
    for host in range(3):
        mon.record(host, 10.0 if host == 1 else 1.0)
    assert mon.check() == {}          # 1 sample each < warmup
    for host in range(3):
        mon.record(host, 10.0 if host == 1 else 1.0)
    assert mon.check() == {}          # 2 samples each, still warming up
    for host in range(3):
        mon.record(host, 10.0 if host == 1 else 1.0)
    assert mon.check() == {1: "persistent"}   # patience=1 escalates at once


def test_straggler_patience_escalation_and_reset():
    """A host recovering below threshold resets its patience counter."""
    mon = StragglerMonitor(window=2, threshold=1.5, patience=2, warmup=1)
    for host in (0, 1, 2):
        mon.record(host, 1.0)
    mon.record(3, 4.0)
    assert mon.check() == {3: "slow"}          # first strike
    # recovery: window=2 mean becomes (4.0 + 0.1)/2 = 2.05 ... still slow?
    # push two fast steps so the rolling mean drops under 1.5x median
    for _ in range(2):
        for host in (0, 1, 2, 3):
            mon.record(host, 1.0)
    assert mon.check() == {}                   # counter reset on recovery
    # slow again: needs `patience` consecutive strikes to escalate
    for host in (0, 1, 2):
        mon.record(host, 1.0)
    mon.record(3, 9.0)
    mon.record(3, 9.0)
    assert mon.check() == {3: "slow"}          # strike 1 (post-reset)
    assert mon.check()[3] == "persistent"      # strike 2 == patience


def test_straggler_single_host_never_flags():
    """The smoke launcher records only host 0; median == own mean."""
    mon = StragglerMonitor(window=4, threshold=1.5, patience=1, warmup=1)
    for t in (1.0, 5.0, 0.1, 3.0):
        mon.record(0, t)
        assert mon.check() == {}


def test_straggler_validates_args():
    with pytest.raises(ValueError):
        StragglerMonitor(window=0)
    with pytest.raises(ValueError):
        StragglerMonitor(threshold=1.0)
    with pytest.raises(ValueError):
        StragglerMonitor(window=2, warmup=3)   # window could never fill


def test_record_step_times_single_process():
    from repro.dist.straggler import record_step_times
    mon = StragglerMonitor(window=4, threshold=1.5, patience=1, warmup=1)
    record_step_times(mon, 0.25)
    record_step_times(mon, 0.75)
    assert mon.means() == {0: 0.5}


def test_straggler_reset_clears_history():
    mon = StragglerMonitor(window=4, threshold=1.5, patience=1, warmup=1)
    mon.record(0, 1.0)
    mon.record(1, 50.0)
    assert mon.check() != {}
    mon.reset()
    assert mon.check() == {}
    assert mon.means() == {}


# ---------------------------------------------------------------------------
# int8 + error-feedback compression
# ---------------------------------------------------------------------------

def _adversarial_tree():
    return {
        "zeros": jnp.zeros((7, 3)),                       # scale == 0 path
        "range": jnp.array([1e-8, 1.0, -1e8, 3e7]),       # huge dynamic range
        "step": jnp.array(42, dtype=jnp.int32),           # int leaf
        "nested": {"w": jnp.linspace(-2.0, 2.0, 33),
                   "mask": jnp.ones((4,), jnp.int32)},
    }


def test_int8_roundtrip_error_bound():
    """|decompressed - original| <= scale/2 = max|g| / 254 per leaf."""
    tree = _adversarial_tree()
    ef = init_error_feedback(tree)
    q, scales, new_ef = compress_grads_int8(tree, ef)
    deq = decompress_grads_int8(q, scales)
    for key in ("zeros", "range"):
        g = np.asarray(tree[key], np.float32)
        d = np.asarray(deq[key])
        bound = np.max(np.abs(g)) / 254.0 + 1e-12
        np.testing.assert_array_less(np.abs(d - g), bound + 1e-6 * np.abs(g))


def test_int8_zero_tree_is_exact():
    tree = {"a": jnp.zeros((5, 5)), "b": (jnp.zeros((3,)),)}
    q, s, ef = compress_grads_int8(tree, init_error_feedback(tree))
    deq = decompress_grads_int8(q, s)
    for leaf in jax.tree.leaves(deq):
        assert float(jnp.max(jnp.abs(leaf))) == 0.0
    for leaf in jax.tree.leaves(ef):
        assert float(jnp.max(jnp.abs(leaf))) == 0.0


def test_int8_int_leaves_pass_through():
    tree = _adversarial_tree()
    q, s, _ = compress_grads_int8(tree, init_error_feedback(tree))
    assert q["step"].dtype == jnp.int32
    assert int(q["step"]) == 42
    deq = decompress_grads_int8(q, s)
    assert deq["step"].dtype == jnp.int32          # untouched on the way back
    np.testing.assert_array_equal(np.asarray(deq["nested"]["mask"]),
                                  np.ones((4,), np.int32))


def test_int8_error_feedback_identity():
    """a = f32(g) + e decomposes exactly as q*scale + e' (float assoc.)."""
    key = jax.random.PRNGKey(7)
    g = {"w": 10.0 ** jax.random.uniform(key, (256,), minval=-6, maxval=6)}
    ef0 = {"w": jax.random.normal(jax.random.fold_in(key, 1), (256,)) * 1e-3}
    q, s, ef1 = compress_grads_int8(g, ef0)
    deq = decompress_grads_int8(q, s)
    lhs = np.asarray(g["w"], np.float32) + np.asarray(ef0["w"], np.float32)
    rhs = np.asarray(deq["w"]) + np.asarray(ef1["w"])
    np.testing.assert_allclose(lhs, rhs, rtol=1e-6)


def test_int8_error_feedback_drives_mean_error_down():
    """With EF, quantization error does not accumulate over repeated steps:
    the sum of decompressed grads tracks the sum of true grads."""
    key = jax.random.PRNGKey(3)
    true_sum = np.zeros((64,), np.float32)
    deq_sum = np.zeros((64,), np.float32)
    ef = init_error_feedback({"w": jnp.zeros((64,))})
    for i in range(50):
        g = jax.random.normal(jax.random.fold_in(key, i), (64,))
        q, s, ef = compress_grads_int8({"w": g}, ef)
        deq_sum += np.asarray(decompress_grads_int8(q, s)["w"])
        true_sum += np.asarray(g)
    # residual never exceeds one quantization step of the running scale
    assert np.max(np.abs(deq_sum - true_sum)) < 0.1


def test_int8_structure_preserved_under_jit():
    tree = {"a": jnp.ones((8, 8)), "b": (jnp.full((4,), -3.0),
                                         jnp.array(1, jnp.int32))}
    ef = init_error_feedback(tree)

    @jax.jit
    def roundtrip(t, e):
        q, s, ne = compress_grads_int8(t, e)
        return decompress_grads_int8(q, s), ne

    deq, ne = roundtrip(tree, ef)
    assert jax.tree.structure(deq) == jax.tree.structure(tree)
    assert jax.tree.structure(ne) == jax.tree.structure(tree)
    np.testing.assert_allclose(np.asarray(deq["a"]), np.ones((8, 8)),
                               rtol=1e-2)


def test_int8_mismatched_ef_raises():
    with pytest.raises(ValueError):
        compress_grads_int8({"a": jnp.ones((3,)), "b": jnp.ones((3,))},
                            {"a": jnp.zeros((3,))})


def test_compression_ratio_counts_wire_bytes():
    tree = {"w": jnp.zeros((1000,), jnp.float32)}     # 4000B -> 1004B
    r = compression_ratio(tree)
    assert 3.9 < r < 4.0
    assert compression_ratio({"i": jnp.zeros((10,), jnp.int32)}) == 1.0


# ---------------------------------------------------------------------------
# collectives vocabulary (host-side helpers; no shard_map needed)
# ---------------------------------------------------------------------------

def test_cpals_axes_single_and_multipod():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ax = cpals_axes(mesh)
    assert ax.row == ("data",) and ax.col == "model"
    assert ax.n_row == 1 and ax.n_col == 1 and ax.n_all == 1
    assert ax.all_axes == ("data", "model")
    assert tuple(ax.grid_spec()) == (("data",), "model")
    assert axis_product(mesh, ("data", "model")) == 1
    assert axis_product(mesh, ()) == 1


def test_batch_axes_pod_rule():
    assert batch_axes() == "data"
    assert batch_axes(multi_pod=True) == ("pod", "data")


def test_cpals_axes_requires_model_axis():
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError):
        cpals_axes(mesh)
