"""Benchmark-history perf ratchet: trajectory append/load, baseline
selection, regression detection, anchor promotion, and the CLI exit codes
CI keys off (``benchmarks/ratchet.py``)."""
import json
import math

import pytest

from benchmarks import history as H
from benchmarks.ratchet import main as ratchet_main


def cpals_summary(total_s=1.0, mttkrp_s=0.5):
    return {"bench": "cpals_routines",
            "cells": {"yelp/auto": {
                "nnz": 1000, "fit": 0.9,
                "routines_s": {"mttkrp": mttkrp_s, "solve": 0.1},
                "total_s": total_s}}}


def serve_summary(serve_s=0.2, latency=1.5):
    return {"bench": "serve", "dataset": "yelp", "qps": 1e5,
            "serve_s": serve_s, "latency_ms_per_batch": latency}


# ---------------------------------------------------------------------------
# trajectory store
# ---------------------------------------------------------------------------

def test_append_and_load_roundtrip(tmp_path):
    rec = H.append_record("cpals", cpals_summary(), history_dir=tmp_path,
                          sha="abc1234")
    assert rec["git_sha"] == "abc1234" and rec["anchor"] is False
    H.append_record("cpals", cpals_summary(1.1), history_dir=tmp_path)
    records = H.load_history("cpals", history_dir=tmp_path)
    assert len(records) == 2
    assert records[0]["summary"] == cpals_summary()
    # one JSON object per line, append-only
    lines = (tmp_path / "cpals.jsonl").read_text().splitlines()
    assert len(lines) == 2 and all(json.loads(l) for l in lines)


def test_load_tolerates_corrupt_lines(tmp_path):
    H.append_record("cpals", cpals_summary(), history_dir=tmp_path)
    with open(tmp_path / "cpals.jsonl", "a") as f:
        f.write("{torn json\n\n[1,2,3]\n")
    H.append_record("cpals", cpals_summary(1.05), history_dir=tmp_path)
    records = H.load_history("cpals", history_dir=tmp_path)
    assert len(records) == 2


def test_baseline_is_last_anchor_else_first(tmp_path):
    H.append_record("serve", serve_summary(0.1), history_dir=tmp_path)
    H.append_record("serve", serve_summary(0.2), history_dir=tmp_path)
    records = H.load_history("serve", history_dir=tmp_path)
    assert H.baseline_record(records)["summary"]["serve_s"] == 0.1
    H.append_record("serve", serve_summary(0.15), history_dir=tmp_path,
                    anchor=True)
    H.append_record("serve", serve_summary(0.3), history_dir=tmp_path)
    records = H.load_history("serve", history_dir=tmp_path)
    assert H.baseline_record(records)["summary"]["serve_s"] == 0.15


# ---------------------------------------------------------------------------
# metric extraction + comparison
# ---------------------------------------------------------------------------

def test_extract_metrics_drops_nonfinite_and_nonpositive():
    s = cpals_summary(total_s=float("nan"), mttkrp_s=0.5)
    s["cells"]["bad/auto"] = {"total_s": -1.0,
                              "routines_s": {"mttkrp": None}}
    m = H.extract_metrics("cpals", s)
    assert m == {"yelp/auto.mttkrp_s": 0.5}
    assert all(math.isfinite(v) for v in m.values())


def test_cpals_epilogue_metric_is_registered():
    """The fused-epilogue win is ratcheted: a cpals summary carrying an
    ``epilogue_s`` subtotal must yield a ``{cell}.epilogue_s`` metric via
    the SECTIONS table (so ``make ratchet`` guards it automatically)."""
    s = cpals_summary()
    s["cells"]["yelp/auto"]["epilogue_s"] = 0.25
    s["cells"]["yelp/segment+fused"] = {
        "nnz": 1000, "fit": 0.9,
        "routines_s": {"mttkrp": 0.4, "epilogue": 0.1},
        "epilogue_s": 0.1, "total_s": 0.9}
    m = H.extract_metrics("cpals", s)
    assert m["yelp/auto.epilogue_s"] == pytest.approx(0.25)
    assert m["yelp/segment+fused.epilogue_s"] == pytest.approx(0.1)
    assert m["yelp/segment+fused.total_s"] == pytest.approx(0.9)
    # cells without the subtotal (older records) simply lack the metric
    assert "yelp/auto.epilogue_s" not in H.extract_metrics(
        "cpals", cpals_summary())


def test_compare_metrics_flags_only_beyond_tolerance():
    base = {"a.total_s": 1.0, "b.total_s": 2.0, "only_base": 1.0}
    new = {"a.total_s": 1.09, "b.total_s": 2.5, "only_new": 9.9}
    regs = H.compare_metrics(base, new, tolerance=0.10)
    assert [r["metric"] for r in regs] == ["b.total_s"]
    assert regs[0]["ratio"] == pytest.approx(1.25)
    # improvements never flag
    assert H.compare_metrics(base, {"a.total_s": 0.5, "b.total_s": 1.0}) == []


def test_ratchet_passes_on_flat_history(tmp_path):
    for s in (1.0, 1.02, 0.98, 1.05):
        H.append_record("cpals", cpals_summary(s, s / 2),
                        history_dir=tmp_path)
    res = H.ratchet_section("cpals", history_dir=tmp_path)
    assert res["status"] == "ok" and res["regressions"] == []


def test_ratchet_fails_on_15pct_mttkrp_regression(tmp_path):
    H.append_record("cpals", cpals_summary(1.0, 0.5), history_dir=tmp_path)
    H.append_record("cpals", cpals_summary(1.0, 0.575),  # +15% mttkrp
                    history_dir=tmp_path)
    res = H.ratchet_section("cpals", history_dir=tmp_path)
    assert res["status"] == "regressed"
    assert [r["metric"] for r in res["regressions"]] \
        == ["yelp/auto.mttkrp_s"]
    assert res["regressions"][0]["ratio"] == pytest.approx(1.15)


def test_ratchet_edge_cases_do_not_crash(tmp_path):
    # missing section: no file at all
    assert H.ratchet_section("serve",
                             history_dir=tmp_path)["status"] == "missing"
    # empty file
    (tmp_path / "plan.jsonl").write_text("")
    assert H.ratchet_section("plan",
                             history_dir=tmp_path)["status"] == "missing"
    # NaN-only metrics on both sides
    H.append_record("api", {"direct_s": float("nan"), "session_s": None},
                    history_dir=tmp_path)
    H.append_record("api", {"direct_s": float("nan"), "session_s": None},
                    history_dir=tmp_path)
    assert H.ratchet_section("api",
                             history_dir=tmp_path)["status"] == "no-metrics"


def test_anchor_promotion_updates_baseline(tmp_path):
    H.append_record("cpals", cpals_summary(1.0), history_dir=tmp_path)
    H.append_record("cpals", cpals_summary(1.5), history_dir=tmp_path)
    assert H.ratchet_section("cpals",
                             history_dir=tmp_path)["status"] == "regressed"
    rec = H.promote_anchor("cpals", history_dir=tmp_path)
    assert rec["anchor"] is True
    res = H.ratchet_section("cpals", history_dir=tmp_path)
    assert res["status"] == "ok" and res["base"]["anchor"]
    # the 1.5s floor is the new accepted baseline: +10% of IT now fails
    H.append_record("cpals", cpals_summary(1.7), history_dir=tmp_path)
    assert H.ratchet_section("cpals",
                             history_dir=tmp_path)["status"] == "regressed"
    # promotion appends, never rewrites
    assert len(H.load_history("cpals", history_dir=tmp_path)) == 4


def test_promote_anchor_without_history_returns_none(tmp_path):
    assert H.promote_anchor("cpals", history_dir=tmp_path) is None


# ---------------------------------------------------------------------------
# CLI exit codes (what CI keys off)
# ---------------------------------------------------------------------------

def test_cli_exit_codes(tmp_path, capsys):
    H.append_record("cpals", cpals_summary(1.0), history_dir=tmp_path)
    assert ratchet_main(["--history", str(tmp_path)]) == 0
    H.append_record("cpals", cpals_summary(1.2), history_dir=tmp_path)
    assert ratchet_main(["--history", str(tmp_path)]) == 1
    out = capsys.readouterr().out
    assert "RATCHET FAILED" in out and "yelp/auto.total_s" in out
    # wider tolerance passes the same history
    assert ratchet_main(["--history", str(tmp_path),
                         "--tolerance", "0.5"]) == 0
    # --anchor promotes and the check goes green again
    assert ratchet_main(["--history", str(tmp_path), "--anchor",
                         "--section", "cpals"]) == 0
    assert ratchet_main(["--history", str(tmp_path)]) == 0


def test_cli_strict_fails_on_missing(tmp_path):
    assert ratchet_main(["--history", str(tmp_path),
                         "--section", "serve"]) == 0
    assert ratchet_main(["--history", str(tmp_path),
                         "--section", "serve", "--strict"]) == 1


def test_cli_json_verdicts(tmp_path):
    H.append_record("serve", serve_summary(0.2), history_dir=tmp_path)
    H.append_record("serve", serve_summary(0.4), history_dir=tmp_path)
    out = tmp_path / "verdicts.json"
    assert ratchet_main(["--history", str(tmp_path), "--section", "serve",
                         "--json", str(out)]) == 1
    verdicts = json.loads(out.read_text())
    assert verdicts[0]["status"] == "regressed"
    metrics = {r["metric"] for r in verdicts[0]["regressions"]}
    assert "serve_s" in metrics


def test_sections_registry_consistency():
    """run.py's summarizer table and the ratchet's section table must name
    the same sections (the assert in run.py import-checks this too)."""
    import benchmarks.run as run_mod

    assert set(run_mod._SUMMARIZERS) == set(H.SECTIONS)
    for s in H.SECTIONS.values():
        assert s.legacy_json == f"BENCH_{s.name}.json"
