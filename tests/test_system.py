"""End-to-end system behaviour: the paper's pipeline from tensor to
decomposition through the public API, the training/serving drivers, and the
CP-ALS <-> LM contact point (factorized embeddings)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cp_als, paper_dataset, random_sparse


def test_paper_pipeline_end_to_end():
    """Synthetic YELP-shaped tensor -> 20 ALS iterations at rank 35 with
    per-routine timers (the paper's Table III protocol, CPU-scaled)."""
    t = paper_dataset("yelp", jax.random.PRNGKey(0), scale=0.003)
    # warm the jit caches so timers measure execution, not compilation
    cp_als(t, rank=35, niters=2, impl="segment", key=jax.random.PRNGKey(1),
           timers={})
    timers = {}
    dec = cp_als(t, rank=35, niters=20, impl="segment",
                 key=jax.random.PRNGKey(1), timers=timers)
    assert 0.0 < float(dec.fit) <= 1.0
    assert all(k in timers for k in ("sort", "mttkrp", "ata", "inverse",
                                     "norm", "fit"))
    # MTTKRP must dominate the dense-algebra routines (the paper's core
    # claim).  norm is excluded from the comparison: at CPU bench scale its
    # wall time is scheduler-noise-sensitive on a loaded 1-core box; the
    # full breakdown lives in bench_output.txt (bench_cpals_routines).
    assert timers["mttkrp"] > timers["ata"], timers
    assert timers["mttkrp"] > timers["fit"], timers


def test_train_driver_learns():
    from repro.launch.train import train
    out = train("llama3.2-3b", smoke=True, steps=25, batch=8, seq=64,
                ckpt_dir=None, lr=1e-3, log_every=100)
    assert out["final_loss"] < out["first_loss"], out


def test_serve_driver_all_cache_families():
    from repro.launch.serve import serve
    for arch in ("llama3.2-3b", "rwkv6-3b", "recurrentgemma-9b"):
        out = serve(arch, smoke=True, batch=2, prompt_len=16, gen=4)
        assert out["tokens"].shape == (2, 4)
        assert np.all(out["tokens"] >= 0)


def test_grad_compressed_training_converges():
    from repro.launch.train import train
    out = train("llama3.2-3b", smoke=True, steps=25, batch=8, seq=64,
                ckpt_dir=None, lr=1e-3, grad_compress=True, log_every=100)
    assert out["final_loss"] < out["first_loss"] + 0.05, out


def test_factorized_embedding_contact_point():
    """CP-ALS compresses a Kronecker-structured embedding (the one genuine
    paper-technique <-> LM substrate integration)."""
    key = jax.random.PRNGKey(0)
    v1, v2, d, r = 16, 16, 32, 12
    a = jax.random.normal(jax.random.fold_in(key, 1), (v1, 6))
    b = jax.random.normal(jax.random.fold_in(key, 2), (v2, 6))
    w = jax.random.normal(jax.random.fold_in(key, 3), (6, d))
    t3 = np.asarray(jnp.einsum("ir,jr,rd->ijd", a, b, w))
    ii, jj, kk = np.meshgrid(np.arange(v1), np.arange(v2), np.arange(d),
                             indexing="ij")
    from repro.core import SparseTensor
    tensor = SparseTensor(
        inds=jnp.asarray(np.stack([ii.ravel(), jj.ravel(), kk.ravel()], 1)
                         .astype(np.int32)),
        vals=jnp.asarray(t3.ravel().astype(np.float32)),
        dims=(v1, v2, d), nnz=t3.size)
    dec = cp_als(tensor, rank=r, niters=25, key=key)
    assert float(dec.fit) > 0.95, float(dec.fit)
    # compression is real
    assert (v1 + v2 + d) * r + r < v1 * v2 * d / 4


def test_multi_order_support():
    """Order-4 decomposition (beyond the paper's 3rd-order restriction)."""
    t = random_sparse((10, 9, 8, 7), 600, jax.random.PRNGKey(4))
    dec = cp_als(t, rank=4, niters=5, impl="gather_scatter",
                 key=jax.random.PRNGKey(5))
    assert len(dec.factors) == 4
    assert 0.0 <= float(dec.fit) <= 1.0
