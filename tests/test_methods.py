"""The decomposition-method registry (repro.methods): registry semantics,
convergence floors for all four methods, nonnegativity, the dense HOOI
reference, streaming-vs-batch equivalence, monotone fits, and the
capability gates on the distributed/streaming drivers."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import exact_lowrank_tensor
from repro.core import cp_als, paper_dataset, random_sparse
from repro.methods import (DecompState, MethodSpec, available_methods,
                           cp_als_streaming, cp_nn_hals, fit, get_method,
                           register_method, tucker_hooi)

KEY = jax.random.PRNGKey(42)

ALS_FAMILY = ("cp_als", "cp_nn_hals", "tucker_hooi", "cp_als_streaming")


@pytest.fixture(scope="module")
def lowrank():
    kt, _ = jax.random.split(KEY)
    return exact_lowrank_tensor((12, 10, 8), 4, kt)


def _fit_kwargs(method):
    spec = get_method(method)
    kw = {"niters": {"cp_als": 60, "cp_als_streaming": 60,
                     "cp_nn_hals": 150, "tucker_hooi": 10}[method]}
    if spec.supports_streaming:
        kw["n_chunks"] = 4
    return kw


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_all_four_methods_registered():
    names = available_methods()
    for want in ALS_FAMILY:
        assert want in names, names


def test_available_methods_filters():
    assert available_methods(dist=True) == ("cp_als",)
    assert available_methods(streaming=True) == ("cp_als_streaming",)
    assert available_methods(nonnegative=True) == ("cp_nn_hals",)
    assert available_methods(family="tucker") == ("tucker_hooi",)


def test_get_method_unknown_lists_registry():
    with pytest.raises(ValueError, match="cp_als"):
        get_method("nope")


def test_register_method_validates():
    with pytest.raises(ValueError, match="family"):
        register_method(MethodSpec(name="x", fn=lambda: None, family="bad"))
    with pytest.raises(ValueError, match="kernel"):
        register_method(MethodSpec(name="x", fn=lambda: None, family="cp",
                                   kernel="bad"))


def test_fit_rejects_path_for_non_streaming_method():
    with pytest.raises(TypeError, match="streaming"):
        fit("nonexistent.tns", 4, method="cp_als")


# ---------------------------------------------------------------------------
# acceptance: every method reconstructs a dense-reconstructible tensor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ALS_FAMILY)
def test_methods_reach_fit_at_full_rank(lowrank, method):
    """fit >= 0.99 at full rank on a fully-observed rank-4 tensor."""
    _, ki = jax.random.split(KEY)
    rank = (4, 4, 4) if method == "tucker_hooi" else 6
    dec = fit(lowrank, rank, method=method, key=ki, **_fit_kwargs(method))
    assert float(dec.fit) >= 0.99, (method, float(dec.fit))


def test_cp_nn_hals_factors_are_nonnegative(lowrank):
    _, ki = jax.random.split(KEY)
    dec = fit(lowrank, 6, method="cp_nn_hals", niters=30, key=ki)
    for m, a in enumerate(dec.factors):
        assert float(jnp.min(a)) >= 0.0, (m, float(jnp.min(a)))
    assert float(jnp.min(dec.lmbda)) >= 0.0


@pytest.mark.parametrize("method", ["cp_als", "cp_nn_hals",
                                    "cp_als_streaming"])
def test_monotone_nondecreasing_fit(lowrank, method):
    """ALS-family sweeps never decrease the fit (within 1e-6 tolerance):
    every block update exactly minimizes the objective over its block."""
    _, ki = jax.random.split(KEY)
    fits = []
    kw = {"n_chunks": 4} if get_method(method).supports_streaming else {}
    fit(lowrank, 4, method=method, niters=15, key=ki,
        checkpoint_cb=lambda s: fits.append(float(s.fit)), **kw)
    assert len(fits) == 15
    for a, b in zip(fits, fits[1:]):
        assert b >= a - 1e-6, fits


def test_monotone_nondecreasing_fit_hooi(lowrank):
    """HOOI is monotone in ||core|| too, but it is orthogonal iteration, not
    ALS: at the truncated-rank plateau the thin SVD's rotation wiggle puts
    ~1e-6-scale f32 noise on ||core||^2, so the tolerance is one decade
    looser than the ALS-family bound."""
    _, ki = jax.random.split(KEY)
    fits = []
    fit(lowrank, (3, 3, 3), method="tucker_hooi", niters=15, key=ki,
        checkpoint_cb=lambda s: fits.append(float(s.fit)))
    assert len(fits) == 15
    for a, b in zip(fits, fits[1:]):
        assert b >= a - 1e-5, fits


# ---------------------------------------------------------------------------
# tucker_hooi vs a dense HOOI reference
# ---------------------------------------------------------------------------

def dense_hooi_reference(x: np.ndarray, ranks, factors, niters: int):
    """Textbook dense HOOI with the same init/iteration order as the sparse
    driver (numpy throughout)."""
    order = x.ndim
    factors = [np.asarray(a) for a in factors]
    for _ in range(niters):
        for n in range(order):
            # mode-n TTMc: contract every other mode with U_m^T
            y = x
            for m in range(order - 1, -1, -1):
                if m == n:
                    continue
                y = np.moveaxis(
                    np.tensordot(factors[m].T, y, axes=(1, m)), 0, m)
            y_mat = np.moveaxis(y, n, 0).reshape(y.shape[n], -1)
            u, _, _ = np.linalg.svd(y_mat, full_matrices=False)
            factors[n] = u[:, : ranks[n]]
    # core from the final factors
    g = x
    for m in range(order - 1, -1, -1):
        g = np.moveaxis(np.tensordot(factors[m].T, g, axes=(1, m)), 0, m)
    return g, factors


def test_tucker_hooi_matches_dense_reference(lowrank):
    """Sparse (TTMc-kernel) HOOI and a dense numpy HOOI from the same init
    must agree on the core+factors reconstruction to 1e-4."""
    _, ki = jax.random.split(KEY)
    ranks = (4, 4, 4)
    dec = tucker_hooi(lowrank, ranks, niters=6, key=ki)

    # same init: replicate the driver's QR-of-normal seeding
    from repro.methods.tucker_hooi import _init_orthonormal

    init = _init_orthonormal(lowrank.dims, ranks, ki, jnp.float32)
    x = np.asarray(lowrank.to_dense())
    core_ref, factors_ref = dense_hooi_reference(x, ranks, init, niters=6)

    recon = np.asarray(dec.to_dense())
    recon_ref = core_ref
    for m, u in enumerate(factors_ref):
        recon_ref = np.moveaxis(
            np.tensordot(u, recon_ref, axes=(1, m)), 0, m)
    np.testing.assert_allclose(recon, recon_ref, rtol=1e-4, atol=1e-4)


def test_tucker_values_at_matches_dense(lowrank):
    dec = tucker_hooi(lowrank, (4, 4, 4), niters=6, key=KEY)
    dense = np.asarray(dec.to_dense())
    inds = np.asarray(lowrank.inds[:64])
    got = np.asarray(dec.values_at(lowrank.inds[:64]))
    want = dense[inds[:, 0], inds[:, 1], inds[:, 2]]
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_tucker_rank_validation(lowrank):
    with pytest.raises(ValueError, match="exceeds mode length"):
        tucker_hooi(lowrank, (99, 4, 4), niters=1)
    with pytest.raises(ValueError, match="modes"):
        tucker_hooi(lowrank, (4, 4), niters=1)


def test_tucker_order4():
    t = random_sparse((9, 8, 7, 6), 400, KEY)
    dec = tucker_hooi(t, 3, niters=3, key=KEY)
    assert dec.core.shape == (3, 3, 3, 3)
    assert [a.shape for a in dec.factors] == [(9, 3), (8, 3), (7, 3), (6, 3)]
    assert 0.0 <= float(dec.fit) <= 1.0


# ---------------------------------------------------------------------------
# streaming vs batch
# ---------------------------------------------------------------------------

def test_streaming_matches_batch_on_paper_tensor():
    """cp_als_streaming over 4 chunks == batch cp_als fit within 1e-3 on the
    scaled paper tensor (the acceptance contract)."""
    key = jax.random.PRNGKey(3)
    t = paper_dataset("yelp", key, scale=0.002)
    batch = cp_als(t, rank=8, niters=10, impl="gather_scatter", key=key)
    streamed = cp_als_streaming(t, 8, niters=10, n_chunks=4, key=key)
    assert abs(float(streamed.fit) - float(batch.fit)) < 1e-3, (
        float(streamed.fit), float(batch.fit))


def test_streaming_from_tns_path(tmp_path, lowrank):
    """A .tns path streams chunk batches without a full-read materialization
    and reaches the same fit class as the in-memory split."""
    from repro.ingest import write_tns

    p = tmp_path / "t.tns"
    write_tns(p, lowrank)
    dec = cp_als_streaming(str(p), 6, niters=40, chunk_nnz=257, key=KEY)
    assert float(dec.fit) > 0.98, float(dec.fit)


def test_streaming_rejects_sorted_impls(lowrank):
    with pytest.raises(ValueError, match="sorted workspace"):
        cp_als_streaming(lowrank, 4, impl="segment")


def test_streaming_decay_validates(lowrank):
    with pytest.raises(ValueError, match="decay"):
        cp_als_streaming(lowrank, 4, decay=1.5)
    with pytest.raises(ValueError, match="decay"):
        cp_als_streaming(lowrank, 4, decay=0.0)


def test_streaming_decay_fold_discounts_old_chunks(lowrank):
    """decay < 1 decomposes the discounted stream: the fold stays stable
    and converges, and with a mild discount the fit stays near batch."""
    dec = cp_als_streaming(lowrank, 6, niters=40, n_chunks=4, decay=0.99,
                           key=KEY)
    assert np.isfinite(float(dec.fit))
    assert float(dec.fit) > 0.7, float(dec.fit)


# ---------------------------------------------------------------------------
# capability gates on the drivers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["cp_nn_hals", "tucker_hooi",
                                    "cp_als_streaming"])
def test_dist_rejects_non_dist_methods(method):
    from jax.sharding import Mesh
    from repro.core.distributed import dist_cp_als

    t = random_sparse((12, 10, 8), 200, KEY)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    with pytest.raises(ValueError, match="supports_dist"):
        dist_cp_als(t, 4, mesh, method=method)


def test_dryrun_rejects_non_dist_methods():
    import os

    # importing dryrun sets XLA_FLAGS for its own subprocess fan-out; jax is
    # already initialized here, so snapshot/restore to keep the env clean
    saved = os.environ.get("XLA_FLAGS")
    try:
        from repro.launch.dryrun import run_cpals

        with pytest.raises(ValueError, match="supports_dist"):
            run_cpals("cpals-yelp", multi_pod=False, method="tucker_hooi")
    finally:
        if saved is None:
            os.environ.pop("XLA_FLAGS", None)
        else:
            os.environ["XLA_FLAGS"] = saved


# ---------------------------------------------------------------------------
# planner integration (ttmc kernel) + report
# ---------------------------------------------------------------------------

def test_plan_ttmc_kernel(lowrank):
    from repro.plan import plan_decomposition

    plan = plan_decomposition(lowrank, "auto", rank=(16, 12, 12),
                              backend="cpu", kernel="ttmc")
    assert all(p.kernel == "ttmc" for p in plan.modes)
    assert all(p.impl in ("segment", "gather_scatter") for p in plan.modes)


def test_plan_report_method_column(lowrank):
    from repro.plan import plan_decomposition
    from repro.utils.report import plan_report

    plan = plan_decomposition(lowrank, "auto", rank=8, backend="cpu",
                              kernel="ttmc")
    rep = plan_report(plan, method="tucker_hooi")
    assert "method=tucker_hooi" in rep
    assert "tucker_hooi:ttmc" in rep


def test_ingested_roundtrip_through_fit(lowrank):
    """Ingested handles flow through fit() for every non-streaming method,
    and factors come back in original labels under a reordering."""
    from repro.ingest import ingest

    ing = ingest(lowrank, reorder="degree_sort")
    for method in ("cp_als", "cp_nn_hals", "tucker_hooi"):
        rank = (3, 3, 3) if method == "tucker_hooi" else 4
        dec = fit(ing, rank, method=method, niters=3, key=KEY)
        assert dec.factors[0].shape[0] == lowrank.dims[0]
        # reconstruction is queried in ORIGINAL coordinates
        vals = np.asarray(dec.values_at(lowrank.inds[:8]))
        assert np.all(np.isfinite(vals))


# ---------------------------------------------------------------------------
# with_fit regression (satellite): no fabricated 0.0 fit
# ---------------------------------------------------------------------------

def test_cp_als_with_fit_false_returns_nan_not_zero(lowrank):
    dec = cp_als(lowrank, rank=4, niters=3, key=KEY, with_fit=False)
    assert math.isnan(float(dec.fit)), (
        "with_fit=False must not report a fabricated fit of 0.0")


def test_cp_als_with_fit_false_keeps_restored_fit(lowrank):
    states = []
    cp_als(lowrank, rank=4, niters=4, key=KEY, checkpoint_cb=states.append)
    restored = states[-1]
    dec = cp_als(lowrank, rank=4, niters=6, key=KEY, state=restored,
                 with_fit=False)
    # the last *computed* fit (the restored one), not NaN and not 0.0
    assert float(dec.fit) == pytest.approx(float(restored.fit))


def test_cp_als_with_fit_false_rejects_tol(lowrank):
    with pytest.raises(ValueError, match="with_fit"):
        cp_als(lowrank, rank=4, niters=3, tol=1e-3, with_fit=False)


# ---------------------------------------------------------------------------
# repo hygiene (satellite): generated artifacts stay out of git
# ---------------------------------------------------------------------------

def test_gitignore_covers_generated_artifacts():
    """__pycache__ (src/tests/benchmarks/examples alike), benchmark JSONs
    and the ingest cache must all be gitignored, and `make clean` must
    exist to sweep them locally."""
    from pathlib import Path

    root = Path(__file__).resolve().parents[1]
    ignored = (root / ".gitignore").read_text().split()
    for pattern in ("__pycache__/", "BENCH_*.json", ".cache/", ".pytest_cache/"):
        assert pattern in ignored, f"{pattern} missing from .gitignore"
    makefile = (root / "Makefile").read_text()
    assert "\nclean:" in makefile, "Makefile needs a clean target"
    for sweep in ("__pycache__", "BENCH_*.json"):
        assert sweep in makefile.split("\nclean:")[1], (
            f"make clean must remove {sweep}")


def test_make_cpals_step_with_fit_false_is_nan():
    from repro.core import build_workspace, gram, init_factors, resolve_plan
    from repro.core.cpals import _iteration

    t = random_sparse((10, 9, 8), 300, KEY)
    plan = resolve_plan(t, "segment", None, rank=4)
    ws = build_workspace(t, plan)
    factors = init_factors(t.dims, 4, KEY)
    grams = tuple(gram(a) for a in factors)
    nxs = jnp.sum(t.vals ** 2)
    *_, fit_val = _iteration(ws, factors, grams, nxs, impls=plan.impls,
                             norm_kind="max", with_fit=False)
    assert math.isnan(float(fit_val))
