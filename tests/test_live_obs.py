"""repro.obs phase 2 — the live half: Prometheus exposition, the flight
recorder (heartbeats + crash dumps), cross-host aggregation, and ratchet
regression attribution.

The acceptance contract pinned hardest here: counters scraped from the
live ``/metrics`` endpoint during a fit must match the final
``metrics.json`` the session exports — the live view and the postmortem
view are the same registry.
"""
import dataclasses
import json
import threading
import urllib.request
import urllib.error

import jax
import pytest

from conftest import exact_lowrank_tensor
from repro.api import ConfigError, MethodConfig, ObsConfig, RunConfig, Session
from repro.api.executor import EXECUTORS
from repro.obs import MetricsRegistry, scoped_registry
from repro.obs.aggregate import (AGGREGATED_FILENAME, aggregate_dir,
                                 merge_files, merge_snapshots,
                                 write_host_metrics)
from repro.obs.exposition import (ExpositionServer, render_prometheus,
                                  sanitize_metric_name)
from repro.obs.metrics import Histogram, window_percentile
from repro.obs.recorder import (CRASH_FILENAME, EVENTS_FILENAME,
                                HEARTBEAT_FILENAME, FlightRecorder,
                                Heartbeat, current_recorder, record_event,
                                write_crash_dump)

KEY = jax.random.PRNGKey(0)


def lowrank():
    return exact_lowrank_tensor((10, 9, 8), 3, KEY)


def live_session(tmp_path, **obs_kw):
    obs_kw.setdefault("enabled", True)
    obs_kw.setdefault("trace_dir", str(tmp_path / "trace"))
    cfg = RunConfig(method=MethodConfig(rank=4, niters=3, seed=0),
                    obs=ObsConfig(**obs_kw))
    return Session.from_config(cfg, tensor=lowrank())


def http_json(url):
    return json.loads(urllib.request.urlopen(url, timeout=10).read())


# ---------------------------------------------------------------------------
# Prometheus rendering
# ---------------------------------------------------------------------------

def test_render_prometheus_all_instrument_kinds():
    reg = MetricsRegistry()
    reg.counter("fit.iterations").inc(3)
    reg.gauge("serve.qps").set(1500.5)
    h = reg.histogram("fit.iteration_ms")
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    text = render_prometheus(registry=reg)
    assert "# TYPE fit_iterations counter" in text
    assert "fit_iterations 3.0" in text
    assert "# TYPE serve_qps gauge" in text
    assert "serve_qps 1500.5" in text
    # histograms render as summaries: quantile samples + exact sum/count
    assert "# TYPE fit_iteration_ms summary" in text
    assert 'fit_iteration_ms{quantile="0.5"} 2.0' in text
    assert "fit_iteration_ms_sum 10.0" in text
    assert "fit_iteration_ms_count 4" in text
    # original (dotted) names survive in HELP lines
    assert "# HELP fit_iterations repro metric 'fit.iterations'" in text


def test_metric_name_sanitization():
    assert sanitize_metric_name("fit.iteration_ms") == "fit_iteration_ms"
    assert sanitize_metric_name("a-b c/d") == "a_b_c_d"
    assert sanitize_metric_name("9lives")[0] == "_"  # no leading digit


def test_render_prometheus_none_gauge_is_nan():
    reg = MetricsRegistry()
    reg.gauge("fit.fit")  # created, never set
    assert "fit_fit NaN" in render_prometheus(registry=reg)


# ---------------------------------------------------------------------------
# ExpositionServer
# ---------------------------------------------------------------------------

def test_exposition_endpoints():
    reg = MetricsRegistry()
    reg.counter("c").inc(2)
    with ExpositionServer(0, registry_fn=lambda: reg,
                          info_fn=lambda: {"stage": "fit"}) as srv:
        assert srv.port != 0  # ephemeral port resolved at bind
        body = urllib.request.urlopen(f"{srv.url}/metrics",
                                      timeout=10).read().decode()
        assert "c 2.0" in body
        hz = http_json(f"{srv.url}/healthz")
        assert hz["status"] == "ok" and hz["stage"] == "fit"
        tr = http_json(f"{srv.url}/trace")
        assert tr["events"] == 0
        with pytest.raises(urllib.error.HTTPError) as ei:
            urllib.request.urlopen(f"{srv.url}/nope", timeout=10)
        assert ei.value.code == 404
    # after stop() the socket is closed
    with pytest.raises(urllib.error.URLError):
        urllib.request.urlopen(f"{srv.url}/healthz", timeout=1)


def test_exposition_tracks_scoped_registry_swaps():
    # the server resolves the registry per request, so tests/benchmarks
    # that scope a fresh registry see THEIR metrics on the endpoint
    with ExpositionServer(0) as srv:
        with scoped_registry() as reg:
            reg.counter("scoped.only").inc()
            body = urllib.request.urlopen(f"{srv.url}/metrics",
                                          timeout=10).read().decode()
        assert "scoped_only 1.0" in body


# ---------------------------------------------------------------------------
# FlightRecorder + record_event
# ---------------------------------------------------------------------------

def test_recorder_ring_drops_oldest():
    rec = FlightRecorder(capacity=3)
    for i in range(5):
        rec.record("iteration", i=i)
    snap = rec.snapshot()
    assert snap["capacity"] == 3
    assert snap["recorded"] == 5 and snap["dropped"] == 2
    assert [e["i"] for e in snap["events"]] == [2, 3, 4]
    assert [e["seq"] for e in snap["events"]] == [2, 3, 4]
    assert rec.events(kind="nope") == []


def test_record_event_inert_without_active_recorder():
    assert current_recorder() is None
    record_event("iteration", i=0)  # no recorder: dropped for free
    rec = FlightRecorder(capacity=4)
    with rec.activate():
        assert current_recorder() is rec
        record_event("iteration", i=1)
    assert current_recorder() is None
    assert [e["i"] for e in rec.events()] == [1]


def test_recorder_capacity_validation():
    with pytest.raises(ValueError, match="capacity"):
        FlightRecorder(capacity=0)


def test_recorder_export_jsonl_roundtrip(tmp_path):
    rec = FlightRecorder(capacity=8)
    rec.record("cache", store="ingest", hit=True)
    rec.record("straggler", host=1, flag="slow")
    path = rec.export_jsonl(tmp_path / "events.jsonl")
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert [e["kind"] for e in lines] == ["cache", "straggler"]
    assert lines[1]["host"] == 1


def test_recorder_thread_safety():
    rec = FlightRecorder(capacity=64)

    def spam(k):
        for i in range(100):
            rec.record("t", worker=k, i=i)

    threads = [threading.Thread(target=spam, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    snap = rec.snapshot()
    assert snap["recorded"] == 400
    assert len(snap["events"]) == 64
    # seq is a total order even under concurrent appends
    seqs = [e["seq"] for e in snap["events"]]
    assert seqs == sorted(seqs)


# ---------------------------------------------------------------------------
# Heartbeat
# ---------------------------------------------------------------------------

def test_heartbeat_start_stop_writes(tmp_path):
    reg = MetricsRegistry()
    reg.counter("beats.seen").inc(7)
    rec = FlightRecorder(capacity=4)
    rec.record("iteration", i=0)
    hb = Heartbeat(tmp_path, 30.0, registry_fn=reg.snapshot, recorder=rec,
                   info_fn=lambda: {"stage": "fit"})
    hb.start()  # one immediate beat, even though the interval is long
    first = json.loads((tmp_path / HEARTBEAT_FILENAME).read_text())
    assert first["seq"] == 0 and first["stage"] == "fit"
    assert first["metrics"]["beats.seen"]["value"] == 7.0
    assert first["events"]["events"][0]["kind"] == "iteration"
    hb.stop()  # final flush bumps seq
    final = json.loads((tmp_path / HEARTBEAT_FILENAME).read_text())
    assert final["seq"] >= 1
    assert hb.beats == final["seq"] + 1


def test_heartbeat_interval_validation(tmp_path):
    with pytest.raises(ValueError, match="interval"):
        Heartbeat(tmp_path, 0.0)


def test_heartbeat_survives_info_fn_failure(tmp_path):
    def broken():
        raise RuntimeError("advisory info must not kill the beat")

    hb = Heartbeat(tmp_path, 30.0, info_fn=broken)
    hb.beat()
    assert json.loads((tmp_path / HEARTBEAT_FILENAME).read_text())["seq"] == 0


# ---------------------------------------------------------------------------
# crash dumps
# ---------------------------------------------------------------------------

def test_write_crash_dump_payload(tmp_path):
    rec = FlightRecorder(capacity=4)
    rec.record("iteration", i=2)
    try:
        raise RuntimeError("boom")
    except RuntimeError as exc:
        path = write_crash_dump(tmp_path, exc, recorder=rec,
                                metrics={"m": {"type": "counter",
                                               "value": 1.0}},
                                config={"method": {"name": "cp_als"}},
                                stage="fit")
    dump = json.loads(path.read_text())
    assert dump["error"]["type"] == "RuntimeError"
    assert dump["error"]["message"] == "boom"
    assert any("boom" in line for line in dump["error"]["traceback"])
    assert dump["stage"] == "fit"
    assert dump["config"]["method"]["name"] == "cp_als"
    assert dump["events"]["events"][0]["i"] == 2


def test_session_fit_writes_crash_dump(tmp_path, monkeypatch):
    def boom(session):
        raise RuntimeError("synthetic executor failure")

    monkeypatch.setitem(EXECUTORS, "local",
                        dataclasses.replace(EXECUTORS["local"], fn=boom))
    with scoped_registry():
        sess = live_session(tmp_path)
        with pytest.raises(RuntimeError, match="synthetic"):
            sess.fit()
    dump = json.loads((tmp_path / "trace" / CRASH_FILENAME).read_text())
    assert dump["error"]["type"] == "RuntimeError"
    assert dump["stage"] == "fit"
    assert dump["config"]["method"]["rank"] == 4
    assert "metrics" in dump and "events" in dump


# ---------------------------------------------------------------------------
# the live session: acceptance — live /metrics matches final metrics.json
# ---------------------------------------------------------------------------

def test_live_fit_metrics_match_final_export(tmp_path):
    with scoped_registry():
        sess = live_session(tmp_path, http_port=0, heartbeat_s=30.0,
                            events_buffer=64)
        sess.fit()
        srv = sess.exposition()
        assert srv is sess.exposition()  # started once, cached
        live = urllib.request.urlopen(f"{srv.url}/metrics",
                                      timeout=10).read().decode()
        hz = http_json(f"{srv.url}/healthz")
        tr = http_json(f"{srv.url}/trace")
        sess.close()
    assert hz["status"] == "ok"
    assert {"mttkrp", "epilogue"} <= set(tr["routines"]["routines"])
    final = json.loads(
        (tmp_path / "trace" / "metrics.json").read_text())
    # THE acceptance check: the live scrape and the exported snapshot
    # agree on the fit counters
    iters = final["fit.iterations"]["value"]
    assert iters == 3.0
    assert f"fit_iterations {iters}" in live
    count = final["fit.iteration_ms"]["count"]
    assert f"fit_iteration_ms_count {count}" in live
    # heartbeat + flight-recorder artifacts landed next to the trace
    hb = json.loads((tmp_path / "trace" / HEARTBEAT_FILENAME).read_text())
    assert hb["metrics"]["fit.iterations"]["value"] == 3.0
    kinds = {json.loads(l)["kind"] for l in
             (tmp_path / "trace" / EVENTS_FILENAME).read_text().splitlines()}
    assert {"iteration", "plan"} <= kinds
    # close() is idempotent and tears the endpoint down
    sess.close()
    with pytest.raises(urllib.error.URLError):
        urllib.request.urlopen(f"{srv.url}/healthz", timeout=1)


def test_session_without_live_config_has_no_surfaces(tmp_path):
    with scoped_registry():
        sess = live_session(tmp_path)  # trace_dir only
        sess.fit()
        assert sess.exposition() is None
        sess.close()  # no-op
    d = tmp_path / "trace"
    assert not (d / HEARTBEAT_FILENAME).exists()
    assert not (d / CRASH_FILENAME).exists()


def test_serve_benchmark_records_qps_gauge(tmp_path):
    with scoped_registry() as registry:
        sess = live_session(tmp_path)
        sess.fit()
        bench = sess.serve_handle().benchmark(queries=64, batch=16)
        qps = registry.gauge("serve.qps").value
        assert qps is not None and qps == pytest.approx(bench["qps"])
        assert registry.histogram("serve.query_ms").count > 0


# ---------------------------------------------------------------------------
# Histogram edge cases (merge prerequisites)
# ---------------------------------------------------------------------------

def test_percentile_on_empty_window():
    h = Histogram()
    assert h.percentile(50) is None
    assert h.summary()["p50"] is None
    assert window_percentile([], 99) is None
    state = h.state()
    assert state["window"] == [] and state["count"] == 0


def test_histogram_state_carries_window_and_bound():
    h = Histogram(window=4)
    for v in (1.0, 2.0, 3.0, 4.0, 5.0):
        h.observe(v)
    s = h.state()
    assert s["window"] == [2.0, 3.0, 4.0, 5.0]  # oldest dropped
    assert s["window_size"] == 4
    assert s["count"] == 5 and s["total"] == 15.0  # exact over ALL obs


def test_merge_two_windowed_histograms_preserves_window_bound():
    a, b = Histogram(window=4), Histogram(window=8)
    for v in (1.0, 2.0, 3.0, 4.0, 100.0):  # 1.0 falls out of a's window
        a.observe(v)
    for v in (5.0, 6.0):
        b.observe(v)
    ra, rb = MetricsRegistry(), MetricsRegistry()
    ra._instruments["h"], rb._instruments["h"] = a, b
    merged = merge_snapshots({"h0": ra.snapshot(with_window=True),
                              "h1": rb.snapshot(with_window=True)})["h"]
    assert merged["count"] == 7  # exact counts sum across hosts
    assert merged["total"] == pytest.approx(121.0)
    assert merged["min"] == 1.0 and merged["max"] == 100.0
    # merged retention = the LARGEST per-host bound, most recent kept
    assert merged["window_size"] == 8
    assert merged["p50"] is not None
    assert merged["hosts"]["h0"]["count"] == 5


def test_counter_and_gauge_merge_across_host_labels():
    ra, rb = MetricsRegistry(), MetricsRegistry()
    ra.counter("hits").inc(3)
    rb.counter("hits").inc(7)
    ra.gauge("fit.fit").set(0.5)
    rb.gauge("fit.fit").set(0.9)
    merged = merge_snapshots({"a": ra.snapshot(with_window=True),
                              "b": rb.snapshot(with_window=True)})
    # counters SUM and keep the per-host breakdown
    assert merged["hits"]["value"] == 10.0
    assert merged["hits"]["hosts"] == {"a": 3.0, "b": 7.0}
    # gauges never sum: per-host labels, last (sorted) host's value on top
    assert merged["fit.fit"]["hosts"] == {"a": 0.5, "b": 0.9}
    assert merged["fit.fit"]["value"] == 0.9


def test_merge_type_conflict_raises():
    ra, rb = MetricsRegistry(), MetricsRegistry()
    ra.counter("x").inc()
    rb.gauge("x").set(1.0)
    with pytest.raises(ValueError, match="refusing to merge"):
        merge_snapshots({"a": ra.snapshot(), "b": rb.snapshot()})


# ---------------------------------------------------------------------------
# per-host files + directory aggregation
# ---------------------------------------------------------------------------

def test_write_host_metrics_and_aggregate_dir(tmp_path):
    for host, n in (("host0-p0", 2), ("host1-p0", 5)):
        reg = MetricsRegistry()
        reg.counter("fit.iterations").inc(n)
        reg.histogram("fit.iteration_ms").observe(float(n))
        write_host_metrics(tmp_path, host, registry=reg)
    agg = aggregate_dir(tmp_path, write=True)
    assert agg["hosts"] == ["host0-p0", "host1-p0"]
    assert agg["metrics"]["fit.iterations"]["value"] == 7.0
    assert agg["metrics"]["fit.iteration_ms"]["count"] == 2
    on_disk = json.loads((tmp_path / AGGREGATED_FILENAME).read_text())
    assert on_disk == agg
    # re-aggregating must not ingest its own output as a host file
    assert aggregate_dir(tmp_path)["hosts"] == ["host0-p0", "host1-p0"]


def test_aggregate_dir_empty_is_none(tmp_path):
    assert aggregate_dir(tmp_path) is None


def test_merge_files_explicit_list(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc()
    p = write_host_metrics(tmp_path, "solo", registry=reg)
    merged = merge_files([p])
    assert merged["hosts"] == ["solo"]
    assert merged["metrics"]["c"]["value"] == 1.0


def test_export_obs_aggregates_host_files(tmp_path):
    with scoped_registry():
        sess = live_session(tmp_path)
        sess.fit()
        # simulate a second host having dropped its snapshot in the dir
        other = MetricsRegistry()
        other.counter("fit.iterations").inc(3)
        write_host_metrics(tmp_path / "trace", "peer-p1", registry=other)
        sess.export_obs()
    agg = json.loads(
        (tmp_path / "trace" / AGGREGATED_FILENAME).read_text())
    assert "peer-p1" in agg["hosts"]
    assert agg["metrics"]["fit.iterations"]["value"] == 3.0


# ---------------------------------------------------------------------------
# ObsConfig phase-2 fields
# ---------------------------------------------------------------------------

def test_obs_config_live_field_validation():
    with pytest.raises(ConfigError, match="obs.http_port"):
        ObsConfig(enabled=True, http_port=70000)
    with pytest.raises(ConfigError, match="obs.http_port"):
        ObsConfig(enabled=False, http_port=9100)  # needs enabled
    with pytest.raises(ConfigError, match="obs.heartbeat_s"):
        ObsConfig(enabled=True, heartbeat_s=-1.0)
    with pytest.raises(ConfigError, match="obs.heartbeat_s"):
        ObsConfig(enabled=True, heartbeat_s=5.0)  # needs trace_dir
    with pytest.raises(ConfigError, match="obs.events_buffer"):
        ObsConfig(events_buffer=0)
    ok = ObsConfig(enabled=True, trace_dir="t", http_port=0,
                   heartbeat_s=0.5, events_buffer=16)
    assert ok.http_port == 0


def test_obs_config_live_fields_roundtrip():
    cfg = RunConfig(obs=ObsConfig(enabled=True, trace_dir="t",
                                  http_port=9100, heartbeat_s=2.0,
                                  events_buffer=256))
    back = RunConfig.from_json(cfg.to_json())
    assert back == cfg
    assert back.obs.http_port == 9100
    # defaults stay default (golden tripwire covers the file itself)
    d = RunConfig().to_dict()["obs"]
    assert d["http_port"] is None
    assert d["heartbeat_s"] == 0.0
    assert d["events_buffer"] == 1024


def test_cli_live_flags_map_to_obs_config(tmp_path):
    import argparse

    from repro.api.cli import config_from_args

    base = dict(config=None, source=None, dataset="yelp", scale=None,
                data_seed=None, reorder=None, compact=None, cache=None,
                impl=None, calibrate=None, method=None, rank=[4], iters=None,
                tol=None, seed=None, option=None, executor=None,
                checkpoint_dir=None, checkpoint_every=None, monitor=None,
                n_chunks=None, chunk_nnz=None)
    ns = argparse.Namespace(**base, trace_dir=str(tmp_path / "t"),
                            trace_split=None, http_port=0, heartbeat_s=1.5,
                            events_buffer=32)
    cfg = config_from_args(ns)
    assert cfg.obs.enabled and cfg.obs.http_port == 0
    assert cfg.obs.heartbeat_s == 1.5 and cfg.obs.events_buffer == 32
    # --http-port alone implies obs.enabled (like --trace-dir)
    ns = argparse.Namespace(**base, trace_dir=None, trace_split=None,
                            http_port=9100, heartbeat_s=None,
                            events_buffer=None)
    assert config_from_args(ns).obs.enabled


def test_cli_metrics_subcommand(tmp_path, capsys):
    from repro.api.cli import main

    with scoped_registry():
        sess = live_session(tmp_path)
        sess.fit()
    assert main(["metrics", str(tmp_path / "trace")]) == 0
    out = capsys.readouterr().out
    assert "# metrics" in out and "fit.iterations" in out
    # exit 2 on a dir with no metrics.json, matching the trace CLI
    assert main(["metrics", str(tmp_path / "nope")]) == 2
    assert "metrics.json" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# event feeds: instrumented modules -> the active recorder
# ---------------------------------------------------------------------------

def test_straggler_escalation_records_event():
    from repro.dist import StragglerMonitor

    rec = FlightRecorder(capacity=16)
    mon = StragglerMonitor(window=4, threshold=1.5, patience=2, warmup=2)
    with rec.activate(), scoped_registry():
        for _ in range(4):
            mon.record(0, 1.0)
            mon.record(1, 10.0)
        mon.check()
    events = rec.events(kind="straggler")
    assert events and events[0]["host"] == 1
    assert events[0]["flag"] in ("slow", "persistent")


def test_ingest_cache_records_events(tmp_path):
    from repro.ingest import ingest

    rec = FlightRecorder(capacity=16)
    with rec.activate(), scoped_registry():
        ingest(lowrank(), cache=str(tmp_path / "cache"))  # miss
        ingest(lowrank(), cache=str(tmp_path / "cache"))  # hit
    events = rec.events(kind="cache")
    hits = [e["hit"] for e in events if e["store"] == "ingest"]
    assert False in hits and True in hits


# ---------------------------------------------------------------------------
# ratchet regression attribution
# ---------------------------------------------------------------------------

def _cpals_cell(mttkrp=0.05, sort=0.01, epilogue=0.03):
    total = sort + mttkrp + epilogue + 0.01
    return {"total_s": total, "epilogue_s": epilogue,
            "routines_s": {"sort": sort, "mttkrp": mttkrp, "ata": 0.004,
                           "inverse": 0.003, "norm": 0.002, "fit": 0.001}}


def test_attribute_cells_names_regressed_routine():
    benchmarks = pytest.importorskip("benchmarks.attribute")
    base = {"cells": {"yelp/segment": _cpals_cell()}}
    head = {"cells": {"yelp/segment": _cpals_cell(mttkrp=0.15)}}
    out = benchmarks.attribute_cells(base, head)
    cell = out["yelp/segment"]
    assert cell["culprit"] == "mttkrp"
    top = cell["routines"][0]
    assert top["routine"] == "mttkrp"
    assert top["share"] == pytest.approx(1.0)
    # a within-tolerance cell is not attributed
    assert benchmarks.attribute_cells(base, base) == {}


def test_attribute_section_and_ratchet_flag(tmp_path, capsys):
    attribute = pytest.importorskip("benchmarks.attribute")
    history = pytest.importorskip("benchmarks.history")
    ratchet = pytest.importorskip("benchmarks.ratchet")

    history.append_record(
        "cpals", {"cells": {"yelp/segment": _cpals_cell()}},
        history_dir=tmp_path, sha="aaaaaaa", anchor=True)
    history.append_record(
        "cpals", {"cells": {"yelp/segment": _cpals_cell(sort=0.08)}},
        history_dir=tmp_path, sha="bbbbbbb")
    att = attribute.attribute_section("cpals", history_dir=tmp_path)
    assert att["kind"] == "routines" and att["culprit"] == "sort"
    text = attribute.format_attribution(att)
    assert "culprit routine = sort" in text

    rc = ratchet.main(["--history", str(tmp_path), "--section", "cpals",
                       "--attribute",
                       "--json", str(tmp_path / "verdicts.json")])
    assert rc == 1
    assert "culprit routine = sort" in capsys.readouterr().out
    verdicts = json.loads((tmp_path / "verdicts.json").read_text())
    assert verdicts[0]["attribution"]["culprit"] == "sort"


def test_attribute_section_metric_fallback(tmp_path):
    attribute = pytest.importorskip("benchmarks.attribute")
    history = pytest.importorskip("benchmarks.history")

    history.append_record("serve", {"serve_s": 1.0,
                                    "latency_ms_per_batch": 2.0},
                          history_dir=tmp_path, sha="aaaaaaa", anchor=True)
    history.append_record("serve", {"serve_s": 2.0,
                                    "latency_ms_per_batch": 2.0},
                          history_dir=tmp_path, sha="bbbbbbb")
    att = attribute.attribute_section("serve", history_dir=tmp_path)
    assert att["kind"] == "metrics"
    assert att["culprit"] == "serve.query"
    assert att["metrics"][0]["metric"] == "serve_s"


def test_attribute_section_needs_two_records(tmp_path):
    attribute = pytest.importorskip("benchmarks.attribute")
    history = pytest.importorskip("benchmarks.history")

    assert attribute.attribute_section("cpals",
                                       history_dir=tmp_path) is None
    history.append_record("cpals", {"cells": {}}, history_dir=tmp_path)
    assert attribute.attribute_section("cpals",
                                       history_dir=tmp_path) is None


def test_attribute_traces_diffs_trace_dirs(tmp_path):
    attribute = pytest.importorskip("benchmarks.attribute")

    with scoped_registry():
        live_session(tmp_path / "base").fit()
    with scoped_registry():
        cfg = RunConfig(method=MethodConfig(rank=4, niters=6, seed=0),
                        obs=ObsConfig(enabled=True,
                                      trace_dir=str(tmp_path / "head"
                                                    / "trace")))
        Session.from_config(cfg, tensor=lowrank()).fit()
    att = attribute.attribute_traces(tmp_path / "base" / "trace",
                                     tmp_path / "head" / "trace")
    assert att["kind"] == "traces"
    assert att["culprit"] in {"sort", "mttkrp", "epilogue"}
    assert any(r["delta_s"] > 0 for r in att["routines"])
