"""Property-based tests (hypothesis) for the system's core invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="hypothesis not installed in this environment")
from hypothesis import given, settings, strategies as st

from repro.core import (SparseTensor, build_csf, dedupe, gram,
                        init_factors, mttkrp, normalize, random_sparse)

SET = dict(max_examples=12, deadline=None)


@st.composite
def sparse_tensors(draw, max_dim=24, max_nnz=120):
    dims = tuple(draw(st.integers(2, max_dim)) for _ in range(3))
    nnz = draw(st.integers(4, max_nnz))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    inds = np.stack([rng.integers(0, d, nnz) for d in dims], 1).astype(np.int32)
    vals = rng.uniform(0.1, 1.0, nnz).astype(np.float32)
    t = SparseTensor(inds=jnp.asarray(inds), vals=jnp.asarray(vals),
                     dims=dims, nnz=nnz)
    return dedupe(t)


@settings(**SET)
@given(sparse_tensors(), st.integers(0, 2), st.integers(2, 6))
def test_mttkrp_linearity_in_values(t, mode, rank):
    """MTTKRP is linear in the tensor values."""
    factors = init_factors(t.dims, rank, jax.random.PRNGKey(0))
    t2 = SparseTensor(inds=t.inds, vals=2.5 * t.vals, dims=t.dims, nnz=t.nnz)
    m1 = mttkrp(t, factors, mode, impl="gather_scatter")
    m2 = mttkrp(t2, factors, mode, impl="gather_scatter")
    np.testing.assert_allclose(np.asarray(m2), 2.5 * np.asarray(m1),
                               rtol=2e-4, atol=1e-4)


@settings(**SET)
@given(sparse_tensors(), st.integers(0, 2), st.integers(0, 2**31 - 1))
def test_mttkrp_nonzero_order_invariance(t, mode, seed):
    """Permuting the non-zero list never changes the MTTKRP."""
    factors = init_factors(t.dims, 4, jax.random.PRNGKey(1))
    perm = np.random.default_rng(seed).permutation(t.nnz)
    tp = SparseTensor(inds=t.inds[perm], vals=t.vals[perm], dims=t.dims,
                      nnz=t.nnz)
    a = mttkrp(t, factors, mode, impl="gather_scatter")
    b = mttkrp(tp, factors, mode, impl="gather_scatter")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=1e-4)


@settings(**SET)
@given(sparse_tensors(), st.integers(0, 2))
def test_segment_equals_scatter(t, mode):
    """The no-lock (sorted segment) and atomic (scatter) paths agree."""
    factors = init_factors(t.dims, 5, jax.random.PRNGKey(2))
    a = mttkrp(t, factors, mode, impl="gather_scatter")
    b = mttkrp(build_csf(t, mode, block=32), factors, mode, impl="segment")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=1e-4)


@settings(**SET)
@given(sparse_tensors(), st.integers(0, 2))
def test_csf_build_preserves_multiset(t, mode):
    """Sorting/padding never loses or invents non-zeros."""
    csf = build_csf(t, mode, block=32)
    order = [mode] + [m for m in range(3) if m != mode]
    orig = sorted((tuple(int(t.inds[n, m]) for m in order), float(t.vals[n]))
                  for n in range(t.nnz))
    built = []
    for n in range(csf.padded_nnz):
        v = float(csf.vals[n])
        if v != 0.0:
            built.append(((int(csf.row_ids[n]),) +
                          tuple(int(csf.other_ids[n, i]) for i in range(2)), v))
    assert sorted(built) == orig


@settings(**SET)
@given(st.integers(3, 30), st.integers(1, 8), st.integers(0, 2**31 - 1))
def test_gram_psd(rows, rank, seed):
    a = jax.random.normal(jax.random.PRNGKey(seed), (rows, rank))
    g = np.asarray(gram(a))
    np.testing.assert_allclose(g, g.T, rtol=1e-4, atol=1e-5)
    w = np.linalg.eigvalsh(g)
    assert w.min() > -1e-3 * max(1.0, w.max())


@settings(**SET)
@given(st.integers(2, 20), st.integers(1, 6),
       st.sampled_from(["max", "2"]), st.integers(0, 2**31 - 1))
def test_normalize_invariant(rows, rank, kind, seed):
    """normalize() factors out lambda exactly; norms match their definition."""
    a = jax.random.uniform(jax.random.PRNGKey(seed), (rows, rank)) + 0.05
    an, lam = normalize(a, kind=kind)
    np.testing.assert_allclose(np.asarray(an * lam[None]), np.asarray(a),
                               rtol=1e-5, atol=1e-6)
    if kind == "2":
        np.testing.assert_allclose(np.asarray(lam),
                                   np.linalg.norm(np.asarray(a), axis=0),
                                   rtol=1e-5)


@settings(**SET)
@given(sparse_tensors())
def test_dedupe_idempotent_and_norm_preserving(t):
    t2 = dedupe(t)
    assert t2.nnz == t.nnz  # already deduped by the strategy
    d1 = np.asarray(t.to_dense())
    d2 = np.asarray(t2.to_dense())
    np.testing.assert_allclose(d1, d2, rtol=1e-6)


@settings(**SET)
@given(sparse_tensors(),
       st.sampled_from(["identity", "degree_sort", "random_block",
                        "compact"]))
def test_relabel_inverse_is_identity(t, kind):
    """relabel . inverse == identity, exactly (indices, values AND entry
    order), for every transform on arbitrary tensors."""
    from repro.ingest import relabel as R

    rel = (R.compact(t) if kind == "compact"
           else R.make_reorder(t, kind, seed=7))
    t2 = rel.apply(t)
    t3 = rel.invert().apply(t2)
    np.testing.assert_array_equal(np.asarray(t3.inds),
                                  np.asarray(t.inds[: t.nnz]))
    np.testing.assert_array_equal(np.asarray(t3.vals),
                                  np.asarray(t.vals[: t.nnz]))


@settings(**SET)
@given(sparse_tensors(), st.integers(1, 5), st.integers(0, 2**31 - 1))
def test_relabel_factor_roundtrip_property(t, rank, seed):
    """restore_factors . apply_factors == identity on random factors."""
    from repro.ingest import relabel as R

    rel = R.degree_sort(t)
    factors = init_factors(t.dims, rank, jax.random.PRNGKey(seed))
    back = rel.restore_factors(rel.apply_factors(factors))
    for a, b in zip(factors, back):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@settings(**SET)
@given(sparse_tensors(), st.integers(2, 5))
def test_pallas_mttkrp_property(t, rank):
    """Kernel == oracle on arbitrary tensors (hypothesis-driven shapes)."""
    from repro.core import build_csf_tiled
    from repro.kernels import ops, ref
    factors = init_factors(t.dims, rank, jax.random.PRNGKey(3))
    csf = build_csf_tiled(t, 0, block=32, row_tile=16)
    got = ops.mttkrp(csf, factors)
    want = ref.mttkrp_ref(csf, factors)[:, :rank]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=5e-4, atol=5e-4)


@st.composite
def packable_dims(draw, order):
    """Random dims whose packed widths fit the 64-bit linearized budget,
    biased toward powers of two so some dim EXACTLY fills its bit field
    (dim 2**k needs k bits and value dim-1 sets every one of them)."""
    from repro.core.linearized import PACK_BITS

    dims = []
    remaining = PACK_BITS
    for m in range(order):
        # leave >=1 bit for every mode still to draw
        cap = min(10, remaining - (order - 1 - m))
        width = draw(st.integers(1, max(1, cap)))
        exact = draw(st.booleans())
        dims.append(2 ** width if exact else draw(st.integers(
            max(2, 2 ** (width - 1) + 1), 2 ** width)))
        remaining -= width
    return tuple(dims)


@settings(**SET)
@given(st.integers(3, 4), st.data())
def test_linearize_roundtrip_bit_exact(order, data):
    """linearize -> delinearize is bit-exact for any in-budget dims and any
    coordinates — including dims that exactly fill their bit field — at
    order 3 and 4, for every sort mode."""
    from repro.core.linearized import (delinearize_coords, field_offsets,
                                       linearize_coords)

    dims = data.draw(packable_dims(order))
    sort_mode = data.draw(st.integers(0, order - 1))
    nnz = data.draw(st.integers(1, 64))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    # hit the field extremes (0 and dim-1) as well as uniform draws
    inds = np.stack([rng.integers(0, d, nnz) for d in dims], 1)
    inds[0] = [d - 1 for d in dims]
    inds[-1] = 0
    lin = linearize_coords(inds, dims, sort_mode=sort_mode)
    back = delinearize_coords(lin, dims, sort_mode=sort_mode)
    np.testing.assert_array_equal(back, inds.astype(np.int64))
    # the packed stream sorts by the sort mode's coordinate (msb field)
    order_by_lin = np.argsort(lin, kind="stable")
    assert (np.diff(inds[order_by_lin, sort_mode]) >= 0).all()
    offsets = field_offsets(dims, sort_mode=sort_mode)
    assert offsets[sort_mode] == max(offsets)


def test_linearize_rejects_over_budget_dims():
    """Dims needing more than 64 packed bits are rejected up front with an
    error naming the per-mode widths — never silently truncated."""
    from repro.core.linearized import check_bit_budget, linearize_coords

    dims = (2**40, 2**31, 4)
    with pytest.raises(ValueError, match="64-bit"):
        check_bit_budget(dims)
    with pytest.raises(ValueError, match="64-bit"):
        linearize_coords(np.zeros((3, 3), dtype=np.int64), dims)


@settings(**SET)
@given(
    st.dictionaries(
        st.sampled_from(["a.total_s", "b.total_s", "c.mttkrp_s",
                         "d.iter_ms", "e.serve_s"]),
        st.floats(1e-6, 1e6, allow_nan=False, allow_infinity=False),
        min_size=1, max_size=5),
    st.lists(st.floats(0.5, 2.0, allow_nan=False), min_size=1, max_size=5),
    st.randoms(use_true_random=False),
)
def test_ratchet_verdict_invariant_under_metric_reordering(base, factors,
                                                           rng):
    """The ratchet verdict (and its regression set) depends only on the
    metric VALUES, never on dict insertion order of either side."""
    from benchmarks.history import compare_metrics

    keys = list(base)
    new = {k: base[k] * factors[i % len(factors)]
           for i, k in enumerate(keys)}
    want = compare_metrics(base, new)

    for _ in range(3):
        kb, kn = list(base), list(new)
        rng.shuffle(kb), rng.shuffle(kn)
        got = compare_metrics({k: base[k] for k in kb},
                              {k: new[k] for k in kn})
        assert got == want
    # and the verdict agrees with first principles
    flagged = {r["metric"] for r in want}
    assert flagged == {k for k in base if new[k] > base[k] * 1.10}
