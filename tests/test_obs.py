"""repro.obs: span tracing, the metrics registry, and their wiring into
ingest -> plan -> fit -> serve.

The two contracts worth pinning hard:

* **Zero tracer traffic when disabled** — a fit with no active tracer
  must make zero ``Tracer.span`` / ``Tracer._record`` calls (counting
  monkeypatch, same technique as test_autotune's measure counter).  The
  module-level ``span()`` fast path never touches the class.
* **Chrome-trace schema round-trip** — ``export_jsonl`` output parses
  back via ``read_trace`` and every complete event carries the
  ``ph/ts/dur/pid/tid/args`` fields chrome://tracing needs.
"""
import json
import threading

import jax
import pytest

from conftest import exact_lowrank_tensor
from repro.api import ConfigError, MethodConfig, ObsConfig, RunConfig, Session
from repro.obs import (Counter, Gauge, Histogram, MetricsRegistry, Tracer,
                       current_tracer, get_registry, read_trace,
                       scoped_registry, span, tracing)
from repro.obs.report import routine_breakdown, trace_report
from repro.obs.trace import METRICS_FILENAME, TRACE_FILENAME

KEY = jax.random.PRNGKey(0)


def lowrank():
    return exact_lowrank_tensor((10, 9, 8), 3, KEY)


# ---------------------------------------------------------------------------
# Tracer core
# ---------------------------------------------------------------------------

def test_span_nesting_parent_links():
    tracer = Tracer(xla_annotations=False)
    with tracer.activate():
        with span("outer"):
            with span("inner", mode=1):
                pass
        with span("sibling"):
            pass
    events = {e["name"]: e for e in tracer.events()}
    assert set(events) == {"outer", "inner", "sibling"}
    assert events["inner"]["args"]["parent"] == events["outer"]["args"]["id"]
    assert "parent" not in events["outer"]["args"]  # a root
    assert "parent" not in events["sibling"]["args"]
    assert events["inner"]["args"]["mode"] == 1
    # children close before parents, so ts/dur containment holds too
    assert events["inner"]["ts"] >= events["outer"]["ts"]
    assert events["inner"]["dur"] <= events["outer"]["dur"]


def test_no_active_tracer_is_inert():
    assert current_tracer() is None
    assert not tracing()
    with span("anything"):  # no tracer: shared null span, records nowhere
        pass


def test_disabled_tracer_records_nothing():
    tracer = Tracer(enabled=False)
    with tracer.activate():
        assert not tracing()
        with tracer.span("x"):
            with span("y"):
                pass
    assert tracer.events() == []


def test_sample_rate_drops_whole_subtrees():
    tracer = Tracer(sample_rate=0.5, xla_annotations=False)
    with tracer.activate():
        for i in range(4):
            with span(f"root{i}"):
                with span("child"):
                    pass
    names = [e["name"] for e in tracer.events()]
    # stride 2: roots 0 and 2 kept WITH their children, 1 and 3 dropped
    # with theirs (no orphan children in the viewer)
    assert sorted(names) == ["child", "child", "root0", "root2"]


def test_tracer_validation():
    with pytest.raises(ValueError, match="sample_rate"):
        Tracer(sample_rate=0.0)
    with pytest.raises(ValueError, match="sample_rate"):
        Tracer(sample_rate=1.5)
    with pytest.raises(ValueError, match="routines"):
        Tracer(routines="both")


def test_traced_decorator():
    from repro.obs import traced

    tracer = Tracer(xla_annotations=False)

    @traced("work.step", kind="unit-test")
    def step(x):
        return x + 1

    with tracer.activate():
        assert step(1) == 2
    (e,) = tracer.events()
    assert e["name"] == "work.step"
    assert e["args"]["kind"] == "unit-test"
    assert step(1) == 2  # and inert again outside the activation


def test_thread_isolation():
    tracer = Tracer(xla_annotations=False)

    def worker(i):
        with tracer.activate():  # threads start with a fresh context
            with tracer.span(f"root-t{i}"):
                with tracer.span("child"):
                    pass

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    events = tracer.events()
    assert len(events) == 4
    roots = {e["name"]: e for e in events if e["name"].startswith("root")}
    children = [e for e in events if e["name"] == "child"]
    assert len(roots) == 2 and len(children) == 2
    # each child links to ITS thread's root, and the tids agree
    for child in children:
        root = next(r for r in roots.values()
                    if r["args"]["id"] == child["args"]["parent"])
        assert child["tid"] == root["tid"]
    assert len({r["tid"] for r in roots.values()}) == 2


def test_export_jsonl_chrome_schema_roundtrip(tmp_path):
    tracer = Tracer(xla_annotations=False)
    with tracer.activate():
        with span("mttkrp", mode=0, impl="segment"):
            pass
    path = tracer.export_jsonl(tmp_path / "t" / TRACE_FILENAME)
    lines = path.read_text().splitlines()
    first = json.loads(lines[0])
    assert first["ph"] == "M" and first["name"] == "process_name"
    events = read_trace(path)
    assert [e["ph"] for e in events] == ["M", "X"]
    x = events[1]
    for field in ("name", "cat", "ts", "dur", "pid", "tid", "args"):
        assert field in x
    assert x["name"] == "mttkrp" and x["args"]["impl"] == "segment"
    assert x["dur"] >= 0 and x["ts"] >= 0  # microseconds since epoch


def test_read_trace_skips_corrupt_lines(tmp_path):
    p = tmp_path / TRACE_FILENAME
    p.write_text('{"ph": "X", "name": "ok", "ts": 0, "dur": 1}\n'
                 "{not json}\n"
                 '["not", "a", "dict"]\n'
                 '{"no_ph": true}\n')
    events = read_trace(p)
    assert [e["name"] for e in events] == ["ok"]


def test_clear_resets_events_and_epoch():
    tracer = Tracer(xla_annotations=False)
    with tracer.activate(), span("a"):
        pass
    assert len(tracer.events()) == 1
    tracer.clear()
    assert tracer.events() == []
    with tracer.activate(), span("b"):
        pass
    (e,) = tracer.events()
    assert e["ts"] < 1e6  # fresh epoch: ts restarts near zero


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_counter_gauge_basics():
    c = Counter()
    assert c.inc() == 1.0 and c.inc(2.5) == 3.5
    with pytest.raises(ValueError):
        c.inc(-1)
    g = Gauge()
    g.set(7)
    assert g.value == 7.0


def test_histogram_percentiles():
    h = Histogram()
    for v in range(1, 101):
        h.observe(v)
    s = h.summary()
    assert s["count"] == 100 and s["min"] == 1 and s["max"] == 100
    assert s["mean"] == pytest.approx(50.5)
    assert s["p50"] == 50 and s["p90"] == 90 and s["p99"] == 99
    assert h.percentile(100) == 100
    assert Histogram().summary()["p50"] is None


def test_histogram_window_keeps_exact_totals():
    h = Histogram(window=4)
    for v in (1, 2, 3, 4, 100, 100, 100, 100):
        h.observe(v)
    # percentiles see only the retained window...
    assert h.percentile(50) == 100
    # ...but count/total/min/max stay exact over everything observed
    s = h.summary()
    assert s["count"] == 8 and s["min"] == 1 and s["max"] == 100


def test_registry_type_conflict_raises():
    r = MetricsRegistry()
    r.counter("x").inc()
    with pytest.raises(TypeError, match="asked for Gauge"):
        r.gauge("x")


def test_registry_snapshot_and_scoping():
    with scoped_registry() as r:
        assert get_registry() is r
        r.counter("a").inc(2)
        r.gauge("b").set(1.5)
        r.histogram("c").observe(10)
        snap = json.loads(r.to_json())
        assert snap["a"] == {"type": "counter", "value": 2.0}
        assert snap["b"] == {"type": "gauge", "value": 1.5}
        assert snap["c"]["type"] == "histogram" and snap["c"]["count"] == 1
    assert get_registry() is not r  # previous default restored


# ---------------------------------------------------------------------------
# the disabled-path contract: a fit makes ZERO tracer calls
# ---------------------------------------------------------------------------

def test_fit_with_obs_disabled_makes_zero_tracer_calls(monkeypatch):
    from repro.methods import fit as methods_fit

    calls = {"span": 0, "_record": 0}
    orig_span, orig_record = Tracer.span, Tracer._record

    def counting_span(self, *a, **k):
        calls["span"] += 1
        return orig_span(self, *a, **k)

    def counting_record(self, *a, **k):
        calls["_record"] += 1
        return orig_record(self, *a, **k)

    monkeypatch.setattr(Tracer, "span", counting_span)
    monkeypatch.setattr(Tracer, "_record", counting_record)
    result = methods_fit(lowrank(), 4, niters=2, key=KEY)
    assert float(result.fit) > 0
    assert calls == {"span": 0, "_record": 0}


# ---------------------------------------------------------------------------
# Session wiring: one trace across the pipeline
# ---------------------------------------------------------------------------

def traced_session(tmp_path, **obs_kw):
    obs_kw.setdefault("enabled", True)
    obs_kw.setdefault("trace_dir", str(tmp_path / "trace"))
    cfg = RunConfig(method=MethodConfig(rank=4, niters=3, seed=0),
                    obs=ObsConfig(**obs_kw))
    return Session.from_config(cfg, tensor=lowrank())


def test_session_fit_writes_trace_and_metrics(tmp_path):
    with scoped_registry():
        sess = traced_session(tmp_path)
        sess.fit()
        assert "# provenance:" in sess.plan_report()
    d = tmp_path / "trace"
    events = read_trace(d / TRACE_FILENAME)
    names = {e["name"] for e in events}
    assert {"stage.ingest", "stage.plan", "stage.fit",
            "iteration", "mttkrp", "epilogue", "sort"} <= names
    iters = [e for e in events if e.get("name") == "iteration"]
    assert len(iters) == 3
    assert all(e["args"]["method"] == "cp_als" for e in iters)
    # mttkrp spans carry the per-mode impl the planner chose
    m = next(e for e in events if e.get("name") == "mttkrp")
    assert "impl" in m["args"] and "mode" in m["args"]
    metrics = json.loads((d / METRICS_FILENAME).read_text())
    assert metrics["fit.iterations"]["value"] == 3.0
    assert metrics["fit.iteration_ms"]["count"] == 3


def test_session_split_routines_trace(tmp_path):
    with scoped_registry():
        sess = traced_session(tmp_path, routines="split")
        sess.fit()
    events = read_trace(tmp_path / "trace" / TRACE_FILENAME)
    names = {e["name"] for e in events}
    # the paper's full Table-III routine set replaces the fused epilogue
    assert {"ata", "mttkrp", "inverse", "norm", "fit"} <= names
    assert "epilogue" not in names


def test_session_obs_disabled_no_tracer(tmp_path):
    cfg = RunConfig(method=MethodConfig(rank=4, niters=2))
    sess = Session.from_config(cfg, tensor=lowrank())
    sess.fit()
    assert sess.tracer() is None
    assert sess.export_obs() is None


def test_serve_latency_histogram(tmp_path):
    with scoped_registry() as registry:
        sess = traced_session(tmp_path)
        sess.fit()
        bench = sess.serve_handle().benchmark(queries=64, batch=16)
        lat = bench["latency_ms"]
        assert lat["count"] > 0
        assert lat["p50"] is not None and lat["p99"] is not None
        assert lat["p50"] <= lat["p99"]
        assert registry.histogram("serve.query_ms").count > 0
    # query spans only land in the export AFTER serve ran — rewrite it
    sess.export_obs()
    events = read_trace(tmp_path / "trace" / TRACE_FILENAME)
    assert any(e.get("name") == "serve.query" for e in events)


# ---------------------------------------------------------------------------
# metric feeds: straggler escalations, cache hit/miss provenance
# ---------------------------------------------------------------------------

def test_straggler_escalations_feed_registry():
    from repro.dist.straggler import StragglerMonitor

    with scoped_registry() as registry:
        monitor = StragglerMonitor(window=4, threshold=1.5, patience=2)
        for _ in range(3):
            monitor.record(0, 1.0)
            monitor.record(1, 1.0)
            monitor.record(2, 10.0)
        assert monitor.check() == {2: "slow"}
        assert monitor.check() == {2: "persistent"}
        snap = registry.snapshot()
        assert snap["straggler.slow"]["value"] == 1.0
        assert snap["straggler.persistent"]["value"] == 1.0


def test_provenance_footer_variants():
    from repro.utils.report import _provenance_footer

    warm = _provenance_footer({"cache_hit": True,
                               "ingest": {"hits": 1, "misses": 0},
                               "autotune": {"hits": 3, "misses": 1}})
    assert "ingest-cache warm (hits=1 misses=0)" in warm
    assert "autotune hits=3 misses=1" in warm
    cold = _provenance_footer({"cache_hit": False,
                               "ingest": {"hits": 0, "misses": 1}})
    assert "ingest-cache cold" in cold
    none = _provenance_footer({"cache_hit": False})
    assert "no ingest cache" in none


def test_ingest_cache_counters_feed_registry(tmp_path):
    from repro.ingest import ingest

    with scoped_registry() as registry:
        ingest(lowrank(), cache=tmp_path / "cache")  # cold: miss + store
        ingest(lowrank(), cache=tmp_path / "cache")  # warm: hit
        snap = registry.snapshot()
        assert snap["ingest.cache.miss"]["value"] == 1.0
        assert snap["ingest.cache.hit"]["value"] == 1.0


# ---------------------------------------------------------------------------
# ObsConfig validation + round-trip
# ---------------------------------------------------------------------------

def test_obs_config_validation():
    with pytest.raises(ConfigError, match="obs.sample_rate"):
        ObsConfig(sample_rate=0.0)
    with pytest.raises(ConfigError, match="obs.routines"):
        ObsConfig(routines="both")
    with pytest.raises(ConfigError, match="obs.enabled"):
        ObsConfig(trace_dir="/tmp/x")  # tracing off would write nothing


def test_obs_config_roundtrip():
    cfg = RunConfig(obs=ObsConfig(enabled=True, trace_dir="artifacts/t",
                                  sample_rate=0.5, routines="split",
                                  xla_annotations=False))
    back = RunConfig.from_json(cfg.to_json())
    assert back == cfg and back.obs.routines == "split"


# ---------------------------------------------------------------------------
# the trace report + CLI
# ---------------------------------------------------------------------------

def test_routine_breakdown_aggregation():
    us = 1e6  # event times are microseconds
    events = [
        {"name": "stage.fit", "ph": "X", "ts": 0, "dur": 10 * us, "args": {}},
        {"name": "iteration", "ph": "X", "ts": 0, "dur": 5 * us,
         "args": {"method": "cp_als"}},
        {"name": "mttkrp", "ph": "X", "ts": 0, "dur": 2 * us,
         "args": {"mode": 0, "impl": "segment"}},
        {"name": "mttkrp", "ph": "X", "ts": 2 * us, "dur": 1 * us,
         "args": {"mode": 1, "impl": "gather_scatter"}},
        {"name": "epilogue", "ph": "X", "ts": 3 * us, "dur": 2 * us,
         "args": {"mode": 0}},
        {"name": "not-a-routine", "ph": "X", "ts": 0, "dur": 9 * us,
         "args": {}},
        {"name": "ignored", "ph": "M", "args": {}},
    ]
    s = routine_breakdown(events)
    assert s["fit_s"] == pytest.approx(10.0)
    assert s["iterations"] == 1 and s["methods"] == ["cp_als"]
    mt = s["routines"]["mttkrp"]
    assert mt["calls"] == 2 and mt["total_s"] == pytest.approx(3.0)
    assert mt["modes"][0]["impl"] == "segment"
    assert mt["modes"][1]["impl"] == "gather_scatter"
    # unaccounted = fit stage minus every routine total (5s here)
    assert s["unaccounted_s"] == pytest.approx(10.0 - 5.0)


def test_trace_report_and_cli(tmp_path, capsys):
    from repro.api.cli import main

    with scoped_registry():
        sess = traced_session(tmp_path)
        sess.fit()
    report = trace_report(tmp_path / "trace")
    assert "| routine |" in report and "mttkrp" in report
    assert "# metrics" in report
    assert "sort" in report  # the pre-loop CSF sort is its own row

    assert main(["trace", str(tmp_path / "trace")]) == 0
    out = capsys.readouterr().out
    assert "| routine |" in out and "% fit" in out

    assert main(["trace", str(tmp_path / "nope")]) == 2
    assert "no trace.jsonl" in capsys.readouterr().err


def test_trace_report_missing_dir(tmp_path):
    with pytest.raises(FileNotFoundError, match="--trace-dir"):
        trace_report(tmp_path / "missing")


def test_cli_trace_flags_map_to_obs_config(tmp_path):
    import argparse

    from repro.api.cli import config_from_args

    base = dict(config=None, source=None, dataset="yelp", scale=None,
                data_seed=None, reorder=None, compact=None, cache=None,
                impl=None, calibrate=None, method=None, rank=[4], iters=None,
                tol=None, seed=None, option=None, executor=None,
                checkpoint_dir=None, checkpoint_every=None, monitor=None,
                n_chunks=None, chunk_nnz=None)
    ns = argparse.Namespace(**base, trace_dir=str(tmp_path / "t"),
                            trace_split=True)
    cfg = config_from_args(ns)
    assert cfg.obs.enabled and cfg.obs.trace_dir == str(tmp_path / "t")
    assert cfg.obs.routines == "split"
    # no trace flags -> obs stays fully default (disabled)
    ns = argparse.Namespace(**base, trace_dir=None, trace_split=None)
    assert config_from_args(ns).obs == ObsConfig()
