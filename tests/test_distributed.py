"""Distributed CP-ALS + dry-run machinery, run in subprocesses with
xla_force_host_platform_device_count so the main pytest process keeps a
single device (per the dry-run isolation rule)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


def run_py(code: str, devices: int = 8, timeout: int = 600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = str(ROOT / "src")
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, timeout=timeout, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-4000:]}"
    return r.stdout


def test_dist_cpals_matches_single_device():
    """Medium-grained distributed CP-ALS == shared-memory CP-ALS (same init),
    on a 4x2 mesh of host devices."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import random_sparse, cp_als
        from repro.core.cpals import init_factors
        from repro.core.distributed import dist_cp_als
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        key = jax.random.PRNGKey(5)
        t = random_sparse((37, 23, 19), 1500, key)

        # single-device reference with the SAME (padded+zeroed) init
        i_p, j_p = 40, 24
        full = init_factors((i_p, j_p, 19), 5, jax.random.PRNGKey(0))
        from repro.core.coo import SparseTensor
        state_factors = (full[0][:37], full[1][:23], full[2])
        from repro.core.cpals import CPALSState
        st = CPALSState(state_factors, jnp.ones((5,)), jnp.array(0.0),
                        jnp.array(0.0), jnp.array(0, dtype=jnp.int32))
        ref = cp_als(t, rank=5, niters=6, state=st)

        factors, lam, fit = dist_cp_als(t, 5, mesh, niters=6,
                                        key=jax.random.PRNGKey(0))
        print("ref_fit", float(ref.fit), "dist_fit", float(fit))
        assert abs(float(ref.fit) - float(fit)) < 2e-3, (ref.fit, fit)
        for a, b in zip(ref.factors, factors):
            err = float(jnp.max(jnp.abs(a - b)))
            print("factor err", err)
            assert err < 5e-2
        print("DIST OK")
    """)
    assert "DIST OK" in out


def test_dist_cpals_multipod_mesh():
    """The pod axis joins the row partition: (pod=2, data=2, model=2)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.core import random_sparse
        from repro.core.distributed import dist_cp_als
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        t = random_sparse((29, 17, 13), 900, jax.random.PRNGKey(1))
        factors, lam, fit = dist_cp_als(t, 4, mesh, niters=4)
        assert all(bool(jnp.all(jnp.isfinite(f))) for f in factors)
        print("fit", float(fit))
        assert 0.0 < float(fit) <= 1.0
        print("MULTIPOD OK")
    """)
    assert "MULTIPOD OK" in out


def test_dryrun_mini_cell_and_roofline_parser():
    """Reduced arch through the real dry-run path on a small mesh; the HLO
    parser must find the data-parallel gradient all-reduce."""
    out = run_py("""
        import jax, jax.numpy as jnp, dataclasses
        from repro import configs
        from repro.launch.mesh import rules_for, sharding_fn, batch_sharding
        from repro.launch.steps import make_train_step
        from repro.models import Model
        from repro.models.config import ShapeConfig
        from repro.models.params import axes_tree
        from repro.optim import OPTIMIZERS
        from repro.utils import roofline as RL
        from repro.launch.dryrun import _map_axes, _sds

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = configs.smoke_of(configs.get("llama3.2-3b"))
        cfg = dataclasses.replace(cfg, vocab=1024, d_model=128, d_ff=256,
                                  num_heads=8, num_kv_heads=2)
        shape = ShapeConfig("mini", 128, 8, "train")
        rules = rules_for(cfg)
        sfn = sharding_fn(mesh, rules)
        model = Model(cfg)
        params_abs = model.abstract(sfn)
        bshapes = configs.batch_shapes(cfg, shape)
        batch_abs = {k: _sds(sh, dt, batch_sharding(mesh, rules, kind, sh))
                     for k, (sh, dt, kind) in bshapes.items()}
        optimizer = OPTIMIZERS["adamw"]()
        opt_shapes = jax.eval_shape(optimizer.init, params_abs)
        opt_axes = optimizer.state_axes(axes_tree(model.param_specs()))
        opt_abs = _map_axes(opt_shapes, opt_axes,
                            lambda s, a: _sds(s.shape, s.dtype, sfn(a, s.shape)))
        fn = make_train_step(model, optimizer)
        lowered = jax.jit(fn, donate_argnums=(0, 1)).lower(
            params_abs, opt_abs, batch_abs, _sds((), jnp.int32))
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        rl = RL.analyze(cost, hlo, n_chips=8, model_flops=6.0 * 1e6 * 1024)
        print("flops", rl.flops, "colls", sorted(rl.collectives))
        assert rl.flops > 0 and rl.bytes_accessed > 0
        assert "all-reduce" in rl.collectives, rl.collectives
        assert rl.collectives["all-reduce"]["wire"] > 0
        mem = compiled.memory_analysis()
        assert mem.temp_size_in_bytes > 0
        print("MINI DRYRUN OK")
    """)
    assert "MINI DRYRUN OK" in out


def test_dist_cpals_dryrun_lowering():
    """Abstract lowering of the distributed CP-ALS iteration on a small mesh
    (same code path the production dry-run uses for cpals-* cells)."""
    out = run_py("""
        import jax
        from repro.core.distributed import build_dist_cpals_lowered
        mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
        lowered, info = build_dist_cpals_lowered("cpals-yelp", mesh)
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        assert cost["flops"] > 0
        hlo = compiled.as_text()
        assert "all-reduce" in hlo
        print("CPALS LOWER OK", info["local_cap"])
    """)
    assert "CPALS LOWER OK" in out


def test_grad_compression_equivalence():
    """int8+EF compressed training stays close to exact training."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.dist.compress import (compress_grads_int8,
                                         decompress_grads_int8,
                                         init_error_feedback)
        key = jax.random.PRNGKey(0)
        w = jax.random.normal(key, (16, 4))
        x = jax.random.normal(jax.random.fold_in(key, 1), (64, 16))
        y = x @ jax.random.normal(jax.random.fold_in(key, 2), (16, 4))
        def loss(w):
            return jnp.mean((x @ w - y) ** 2)
        w1 = w; w2 = w; ef = init_error_feedback({'w': w})
        for i in range(60):
            g1 = jax.grad(loss)(w1)
            w1 = w1 - 0.01 * g1
            g2 = jax.grad(loss)(w2)
            q, s, ef = compress_grads_int8({'w': g2}, ef)
            g2d = decompress_grads_int8(q, s)['w']
            w2 = w2 - 0.01 * g2d
        l1, l2 = float(loss(w1)), float(loss(w2))
        print("exact", l1, "compressed", l2)
        assert l2 < l1 * 1.5 + 1e-3
        print("COMPRESS OK")
    """, devices=1)
    assert "COMPRESS OK" in out


def test_dist_cpals_shard_c_and_mode_order_equivalent():
    """The optimized mode-2 layout (shard_c) and auto mode ordering are
    numerically equivalent to the baseline distributed algorithm."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.core import random_sparse
        from repro.core.cpals import init_factors
        from repro.core.distributed import dist_cp_als
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        t = random_sparse((37, 23, 19), 1500, jax.random.PRNGKey(5))
        init = init_factors(t.dims, 5, jax.random.PRNGKey(0))
        f1, l1, fit1 = dist_cp_als(t, 5, mesh, niters=5, init=init)
        f2, l2, fit2 = dist_cp_als(t, 5, mesh, niters=5, init=init,
                                   shard_c=True)
        f3, l3, fit3 = dist_cp_als(t, 5, mesh, niters=5, init=init,
                                   shard_c=True, mode_order="auto")
        assert abs(float(fit1) - float(fit2)) < 1e-5
        assert abs(float(fit1) - float(fit3)) < 1e-5
        for a, b in zip(f1, f2):
            assert float(jnp.max(jnp.abs(a - b))) < 1e-4
        for a, b in zip(f1, f3):
            assert float(jnp.max(jnp.abs(a - b))) < 1e-4, \
                float(jnp.max(jnp.abs(a - b)))
        print("OPT EQUIV OK")
    """)
    assert "OPT EQUIV OK" in out


def test_dist_cpals_plan_interface():
    """dist_cp_als shares cp_als's planner interface: impl='auto' == an
    explicit DecompPlan, and the mixed local schedule stays numerically
    equivalent to the fixed scatter path."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.core import random_sparse
        from repro.core.cpals import init_factors
        from repro.core.distributed import dist_cp_als
        from repro.plan import plan_decomposition
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        t = random_sparse((37, 23, 19), 1500, jax.random.PRNGKey(5))
        init = init_factors(t.dims, 5, jax.random.PRNGKey(0))
        plan = plan_decomposition(t, "auto", rank=5,
                                  allow=("gather_scatter", "segment"))
        f1, l1, fit1 = dist_cp_als(t, 5, mesh, niters=4, init=init,
                                   impl="auto")
        f2, l2, fit2 = dist_cp_als(t, 5, mesh, niters=4, init=init,
                                   plan=plan)
        f3, l3, fit3 = dist_cp_als(t, 5, mesh, niters=4, init=init,
                                   impl="gather_scatter")
        assert abs(float(fit1) - float(fit2)) < 1e-6, (fit1, fit2)
        assert abs(float(fit1) - float(fit3)) < 1e-3, (fit1, fit3)
        for a, b in zip(f1, f2):
            assert float(jnp.max(jnp.abs(a - b))) < 1e-6
        print("PLAN IFACE OK", plan.summary())
    """)
    assert "PLAN IFACE OK" in out


def test_ep_moe_matches_dense_dispatch():
    """Expert-parallel shard_map MoE == dense-dispatch oracle (fwd + grads)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.models.config import ModelConfig, MoEConfig
        from repro.models.moe import moe_ffn_ep, _moe_ffn_dense_dispatch, moe_specs
        from repro.models.params import init_params
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        cfg = ModelConfig(name="m", family="moe", pattern=("moe",),
                          num_layers=1, d_model=32, num_heads=2,
                          num_kv_heads=2, head_dim=16, d_ff=64, vocab=64,
                          moe=MoEConfig(num_experts=8, top_k=2, d_ff=32,
                                        num_shared=1, capacity_factor=8.0),
                          param_dtype="float32", compute_dtype="float32")
        p = init_params(moe_specs(cfg), jax.random.PRNGKey(0), jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32)) * 0.5
        ref, _ = _moe_ffn_dense_dispatch(p, cfg, x)
        out, _ = jax.jit(lambda p, x: moe_ffn_ep(p, cfg, x, mesh))(p, x)
        assert float(jnp.max(jnp.abs(out - ref))) < 1e-4
        g1 = jax.jit(jax.grad(lambda p, x: jnp.sum(
            moe_ffn_ep(p, cfg, x, mesh)[0] ** 2)))(p, x)
        g2 = jax.grad(lambda p, x: jnp.sum(
            _moe_ffn_dense_dispatch(p, cfg, x)[0] ** 2))(p, x)
        for k in ("wg", "wd", "router", "shared_wg"):
            assert float(jnp.max(jnp.abs(g1[k] - g2[k]))) < 1e-2, k
        print("EP OK")
    """)
    assert "EP OK" in out
