"""The repro.api front door: RunConfig round-trip + validation, executor
registry capability gates, Session stage caching, old-API-vs-Session
bit-exact parity for every registered method, checkpoint kill-and-resume,
CLI translators, and the config golden file (schema-drift tripwire)."""
import json
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np
import pytest

from repro.api import (ConfigError, DataConfig, ExecConfig, MethodConfig,
                       PlanConfig, RunConfig, ServeConfig, ServeHandle,
                       Session, get_executor, require_capability, run)
from conftest import exact_lowrank_tensor

KEY = jax.random.PRNGKey(0)
ROOT = Path(__file__).resolve().parents[1]
GOLDEN = Path(__file__).parent / "data" / "runconfig_golden.json"


def lowrank():
    return exact_lowrank_tensor((10, 9, 8), 3, KEY)


# ---------------------------------------------------------------------------
# RunConfig: round-trip + validation
# ---------------------------------------------------------------------------

def test_roundtrip_default():
    cfg = RunConfig()
    assert RunConfig.from_dict(cfg.to_dict()) == cfg
    assert RunConfig.from_json(cfg.to_json()) == cfg


def test_roundtrip_nondefault_preserves_tuples():
    cfg = RunConfig(
        data=DataConfig(dataset="yelp", scale=0.25, reorder="degree_sort",
                        compact=True, tile=(256, 64)),
        plan=PlanConfig(policy="segment", allow=("segment", "gather_scatter")),
        method=MethodConfig(name="tucker_hooi", rank=(4, 3, 2), niters=7,
                            tol=1e-5, seed=3, options={"verbose": False}),
        exec=ExecConfig(checkpoint_dir="/tmp/ck", checkpoint_every=2,
                        monitor=True))
    back = RunConfig.from_json(cfg.to_json())
    assert back == cfg
    # JSON turns tuples into lists; from_dict must restore them bit-exactly
    assert back.method.rank == (4, 3, 2)
    assert back.data.tile == (256, 64)
    assert back.plan.allow == ("segment", "gather_scatter")
    # and a second dump is byte-identical (the full round-trip contract)
    assert back.to_json() == cfg.to_json()


def test_roundtrip_tuple_valued_method_options():
    """The bit-exact contract covers option payloads: tuple values inside
    method.options survive the JSON list detour."""
    cfg = RunConfig(method=MethodConfig(
        options={"mode_ranks": (2, 3), "nested": {"xs": (1, (2, 3))}}))
    back = RunConfig.from_json(cfg.to_json())
    assert back == cfg
    assert back.method.options["mode_ranks"] == (2, 3)
    assert back.method.options["nested"]["xs"] == (1, (2, 3))


def test_list_valued_fields_canonicalize_to_tuples():
    """Python callers may pass lists where the schema says tuple; the
    frozen config canonicalizes so the JSON round-trip equality holds."""
    cfg = RunConfig(
        data=DataConfig(source="x.tns", dims=[10, 10, 10], tile=[512, 128]),
        plan=PlanConfig(allow=["segment"]),
        method=MethodConfig(rank=[4, 3, 2], name="tucker_hooi"))
    assert cfg.data.dims == (10, 10, 10)
    assert cfg.data.tile == (512, 128)
    assert cfg.plan.allow == ("segment",)
    assert cfg.method.rank == (4, 3, 2)
    assert RunConfig.from_json(cfg.to_json()) == cfg


def test_roundtrip_serve_section_preserves_tuples():
    cfg = RunConfig(serve=ServeConfig(buckets=[8, 32, 128],
                                      tenants=["acme", "globex"],
                                      max_wait_ms=5.0, workers=2,
                                      max_resident_mb=64.0, port=0))
    assert cfg.serve.buckets == (8, 32, 128)  # lists canonicalize
    assert cfg.serve.tenants == ("acme", "globex")
    back = RunConfig.from_json(cfg.to_json())
    assert back == cfg
    assert back.serve.buckets == (8, 32, 128)
    assert back.to_json() == cfg.to_json()


def test_dict_valued_options_keep_identity():
    """Out-param options (the Table III ``timers`` dict) must keep their
    object identity through MethodConfig canonicalization."""
    timers: dict = {}
    cfg = MethodConfig(options={"timers": timers})
    assert cfg.options["timers"] is timers


def test_unknown_key_rejected_with_path_and_suggestion():
    with pytest.raises(ConfigError, match=r"method\.rnak.*did you mean 'rank'"):
        RunConfig.from_dict({"method": {"rnak": 8}})
    with pytest.raises(ConfigError, match=r"data\.reoder.*'reorder'"):
        RunConfig.from_dict({"data": {"reoder": "degree_sort"}})
    with pytest.raises(ConfigError, match="unknown section"):
        RunConfig.from_dict({"methods": {}})


@pytest.mark.parametrize("section,field,bad,match", [
    ("data", "reorder", "degre_sort", r"data\.reorder.*degree_sort"),
    ("data", "duplicates", "add", r"data\.duplicates"),
    ("data", "dataset", "yel", r"data\.dataset.*'yelp'"),
    ("data", "scale", -1.0, r"data\.scale"),
    ("plan", "policy", "segmnt", r"plan\.policy.*'segment'"),
    ("method", "name", "cp_alss", r"method\.name.*'cp_als'"),
    ("method", "rank", 0, r"method\.rank"),
    ("method", "niters", 0, r"method\.niters"),
    ("method", "tol", -0.1, r"method\.tol"),
    ("exec", "executor", "distt", r"exec\.executor.*'dist'"),
    ("exec", "checkpoint_every", 0, r"exec\.checkpoint_every"),
    ("serve", "buckets", [64, 16, 256], r"serve\.buckets.*increasing"),
    ("serve", "buckets", [], r"serve\.buckets"),
    ("serve", "buckets", [0, 16], r"serve\.buckets.*positive"),
    ("serve", "workers", 0, r"serve\.workers"),
    ("serve", "max_wait_ms", -1.0, r"serve\.max_wait_ms"),
    ("serve", "tenants", [], r"serve\.tenants"),
    ("serve", "tenants", ["a", "a"], r"serve\.tenants.*unique"),
    ("serve", "max_resident_mb", 0, r"serve\.max_resident_mb.*budget"),
    ("serve", "port", 70000, r"serve\.port"),
])
def test_validation_names_the_field(section, field, bad, match):
    with pytest.raises(ConfigError, match=match):
        RunConfig.from_dict({section: {field: bad}})


def test_source_and_dataset_are_exclusive():
    with pytest.raises(ConfigError, match=r"data\.source"):
        DataConfig(source="x.tns", dataset="yelp")


def test_golden_config_file_matches_defaults():
    """Schema tripwire: the committed golden file IS RunConfig()'s JSON.
    A new/renamed field or changed default must update the golden file (and
    therefore be a deliberate, reviewed act)."""
    golden = json.loads(GOLDEN.read_text())
    assert json.loads(RunConfig().to_json()) == golden
    assert RunConfig.from_dict(golden) == RunConfig()
    # the live-telemetry fields are part of the committed schema: an
    # accidental rename/retype of any of them must trip this, not just
    # the blanket equality above
    obs = golden["obs"]
    assert obs["http_port"] is None
    assert obs["heartbeat_s"] == 0.0
    assert obs["events_buffer"] == 1024


# ---------------------------------------------------------------------------
# executor registry + capability gates
# ---------------------------------------------------------------------------

def test_executor_registry_covers_the_split():
    assert get_executor("local").requires is None
    assert get_executor("dist").requires == "supports_dist"
    assert get_executor("streaming").requires == "supports_streaming"
    with pytest.raises(ValueError, match="did you mean 'local'"):
        get_executor("locl")


@pytest.mark.parametrize("method,executor", [
    ("cp_nn_hals", "dist"), ("tucker_hooi", "dist"),
    ("cp_als_streaming", "dist"),
    ("cp_als", "streaming"), ("cp_nn_hals", "streaming"),
    ("tucker_hooi", "streaming"),
])
def test_capability_gate_rejects_with_listing(method, executor):
    flag = "supports_dist" if executor == "dist" else "supports_streaming"
    with pytest.raises(ValueError, match=flag):
        require_capability(method, executor)
    # the same gate fires at RunConfig construction
    rank = (3, 3, 3) if method == "tucker_hooi" else 4
    with pytest.raises(ValueError, match=flag):
        RunConfig(method=MethodConfig(name=method, rank=rank),
                  exec=ExecConfig(executor=executor))


def test_gate_accepts_capable_combos():
    for method in ("cp_als", "cp_nn_hals", "tucker_hooi", "cp_als_streaming"):
        require_capability(method, "local")
    require_capability("cp_als", "dist")
    require_capability("cp_als_streaming", "streaming")


# ---------------------------------------------------------------------------
# Session: parity with the old API, bit-exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method,rank", [
    ("cp_als", 4), ("cp_nn_hals", 4), ("tucker_hooi", (3, 3, 3)),
])
def test_session_matches_methods_fit_bit_exactly(method, rank):
    from repro.ingest import ingest
    from repro.methods import fit as methods_fit

    t = lowrank()
    cfg = RunConfig(method=MethodConfig(name=method, rank=rank, niters=5))
    dec = run(cfg, tensor=t)
    # impl="auto" == the RunConfig's default plan policy (bare methods.fit
    # defaults to the pinned "segment" policy instead)
    ref = methods_fit(ingest(t), rank, method=method, niters=5, key=KEY,
                      impl="auto")
    np.testing.assert_array_equal(np.asarray(dec.fit), np.asarray(ref.fit))
    for a, b in zip(dec.factors, ref.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_streaming_executor_matches_methods_fit_bit_exactly():
    from repro.methods import fit as methods_fit

    t = lowrank()
    cfg = RunConfig(method=MethodConfig(name="cp_als_streaming", rank=4,
                                        niters=5),
                    exec=ExecConfig(executor="streaming", n_chunks=3))
    dec = run(cfg, tensor=t)
    ref = methods_fit(t, 4, method="cp_als_streaming", niters=5, key=KEY,
                      n_chunks=3)
    np.testing.assert_array_equal(np.asarray(dec.fit), np.asarray(ref.fit))
    for a, b in zip(dec.factors, ref.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_session_paper_tensor_parity_with_reorder():
    """Scaled paper tensor through degree_sort: Session == direct ingest +
    methods.fit, factors restored to original labels on both sides."""
    from repro.core import paper_dataset
    from repro.ingest import ingest
    from repro.methods import fit as methods_fit

    t = paper_dataset("yelp", KEY, scale=0.001)
    cfg = RunConfig(data=DataConfig(reorder="degree_sort"),
                    method=MethodConfig(rank=8, niters=3))
    dec = run(cfg, tensor=t)
    ref = methods_fit(ingest(t, reorder="degree_sort"), 8, niters=3, key=KEY,
                      impl="auto")
    np.testing.assert_array_equal(np.asarray(dec.fit), np.asarray(ref.fit))
    for a, b in zip(dec.factors, ref.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dist_executor_matches_dist_cp_als_bit_exactly():
    """The dist executor is the shard_map driver behind the facade
    (subprocess: forces 8 host devices without polluting this process)."""
    code = """
import jax, numpy as np
from repro.api import RunConfig, MethodConfig, ExecConfig, run
from repro.core import random_sparse
from repro.core.distributed import dist_cp_als
from repro.dist.collectives import make_mesh
t = random_sparse((37, 23, 19), 1500, jax.random.PRNGKey(5))
cfg = RunConfig(method=MethodConfig(rank=5, niters=4),
                exec=ExecConfig(executor="dist",
                                mesh_shape={"data": 4, "model": 2}))
dec = run(cfg, tensor=t)
f, lam, fit = dist_cp_als(t, 5, make_mesh((4, 2), ("data", "model")),
                          niters=4, key=jax.random.PRNGKey(0))
np.testing.assert_array_equal(np.asarray(dec.fit), np.asarray(fit))
for a, b in zip(dec.factors, f):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("DIST-API OK")
"""
    import os

    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=str(ROOT / "src"))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, timeout=600, env=env)
    assert r.returncode == 0, f"stdout:\n{r.stdout}\nstderr:\n{r.stderr[-3000:]}"
    assert "DIST-API OK" in r.stdout


# ---------------------------------------------------------------------------
# Session: lazy stage caching + serve handle
# ---------------------------------------------------------------------------

def test_stages_are_lazy_and_cached(monkeypatch):
    t = lowrank()
    sess = Session.from_config(
        RunConfig(method=MethodConfig(rank=4, niters=3)), tensor=t)
    ing1 = sess.ingest()
    assert sess.ingest() is ing1
    plan1 = sess.plan()
    assert sess.plan() is plan1
    dec1 = sess.fit()
    assert sess.fit() is dec1  # cached
    assert sess.fit(force=True) is not dec1


def test_streaming_method_has_no_plan():
    sess = Session.from_config(
        RunConfig(method=MethodConfig(name="cp_als_streaming", rank=4,
                                      niters=2)), tensor=lowrank())
    assert sess.plan() is None
    assert "no per-mode plan" in sess.plan_report()


def test_serve_handle_reconstructs_known_entries():
    t = lowrank()
    sess = Session.from_config(
        RunConfig(method=MethodConfig(rank=6, niters=20)), tensor=t)
    handle = sess.serve_handle()
    assert isinstance(handle, ServeHandle)
    assert handle.dims == t.dims
    got = handle.query(np.asarray(t.inds[:64]))
    np.testing.assert_allclose(np.asarray(got), np.asarray(t.vals[:64]),
                               rtol=0.05, atol=0.05)


def test_session_adopts_prebuilt_ingested_handle():
    """Several sessions can share one ingest (sort + stats + CSF built
    once): a pre-built Ingested handle passed as ``tensor`` IS the ingest
    stage, and the fit matches the from-raw-tensor session bit-exactly."""
    from repro.ingest import ingest

    t = lowrank()
    ing = ingest(t)
    cfg = RunConfig(method=MethodConfig(rank=4, niters=3))
    sess = Session.from_config(cfg, tensor=ing)
    assert sess.ingest() is ing
    dec = sess.fit()
    ref = run(cfg, tensor=t)
    np.testing.assert_array_equal(np.asarray(dec.fit), np.asarray(ref.fit))
    for a, b in zip(dec.factors, ref.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_dist_plan_allow_inexpressible_names_the_field():
    """Any plan.allow entry the shard_map body cannot express is rejected
    naming plan.allow — never silently filtered out, never a deep planner
    error with allow=()."""
    for allow in (("pallas",), ("segment", "pallas")):
        cfg = RunConfig(plan=PlanConfig(allow=allow),
                        exec=ExecConfig(executor="dist"))
        sess = Session.from_config(cfg, tensor=lowrank())
        with pytest.raises(ConfigError, match=r"plan\.allow.*pallas"):
            sess.plan()


def test_cli_missing_source_is_a_formatted_error(capsys):
    from repro.api.cli import main

    rc = main(["fit", "--source", "/no/such/file.tns", "--rank", "4",
               "--dryrun"])
    assert rc == 2
    assert "error:" in capsys.readouterr().err


def test_dist_executor_rejects_tol():
    cfg = RunConfig(method=MethodConfig(rank=4, niters=2, tol=1e-4),
                    exec=ExecConfig(executor="dist"))
    with pytest.raises(ValueError, match=r"method\.tol"):
        run(cfg, tensor=lowrank())


def test_local_streaming_honors_chunk_nnz():
    """exec.chunk_nnz must reach the chunk source under the local executor
    (n_chunks is only forwarded when actually configured)."""
    t = lowrank()  # 720 nnz
    cfg = RunConfig(method=MethodConfig(name="cp_als_streaming", rank=4,
                                        niters=2),
                    exec=ExecConfig(executor="local", chunk_nnz=100))
    from repro.methods import fit as methods_fit

    dec = run(cfg, tensor=t)
    ref = methods_fit(t, 4, method="cp_als_streaming", niters=2, key=KEY,
                      chunk_nnz=100)
    np.testing.assert_array_equal(np.asarray(dec.fit), np.asarray(ref.fit))


def test_reserved_method_option_rejected_at_construction():
    """An option that shadows a section-backed kwarg (niters/key/...) would
    be silently overwritten at dispatch — reject it up front."""
    with pytest.raises(ConfigError, match=r"method\.options.*niters"):
        MethodConfig(options={"niters": 50})
    # chunk geometry is exec-section-owned (exec.n_chunks/chunk_nnz)
    with pytest.raises(ConfigError, match=r"method\.options.*n_chunks"):
        MethodConfig(name="cp_als_streaming", options={"n_chunks": 8})


def test_serve_handle_is_cached():
    sess = Session.from_config(
        RunConfig(method=MethodConfig(rank=4, niters=2)), tensor=lowrank())
    h1 = sess.serve_handle()
    assert sess.serve_handle() is h1
    sess.fit(force=True)  # a re-fit invalidates the handle
    assert sess.serve_handle() is not h1


def _serve_sessions_natural_vs_reordered(rank=5, niters=25):
    """Two sessions over the SAME tensor, one ingested naturally and one
    through degree_sort+compact — the serving surface must answer both in
    the tensor's ORIGINAL label space."""
    t = lowrank()
    nat = Session.from_config(
        RunConfig(method=MethodConfig(rank=rank, niters=niters)), tensor=t)
    reo = Session.from_config(
        RunConfig(data=DataConfig(reorder="degree_sort", compact=True),
                  method=MethodConfig(rank=rank, niters=niters)), tensor=t)
    return t, nat, reo


def test_serve_labels_survive_reorder_values_at():
    """Batched values_at from a reordered-ingest session answers in
    ORIGINAL labels: same coordinate batch, (near-)same values as the
    natural-order session, and both match the tensor."""
    t, nat, reo = _serve_sessions_natural_vs_reordered()
    coords = np.asarray(t.inds[:64])
    got_nat = np.asarray(nat.serve_handle().query(coords))
    got_reo = np.asarray(reo.serve_handle().query(coords))
    np.testing.assert_allclose(got_reo, np.asarray(t.vals[:64]),
                               rtol=0.05, atol=0.05)
    np.testing.assert_allclose(got_reo, got_nat, rtol=0.1, atol=0.05)


def test_serve_labels_survive_reorder_top_k():
    """top_k_for_user item ids from a reordered-ingest session are
    ORIGINAL labels: identical id set/order as the natural session (both
    converge to the same ground truth) for every user, on the handle AND
    through the batching DecompServer."""
    from repro.serve import DecompServer

    t, nat, reo = _serve_sessions_natural_vs_reordered()
    k = 4
    for user in range(t.dims[0]):
        s_nat, i_nat = nat.serve_handle().top_k_for_user(user, k)
        s_reo, i_reo = reo.serve_handle().top_k_for_user(user, k)
        np.testing.assert_array_equal(np.asarray(i_reo), np.asarray(i_nat))
        np.testing.assert_allclose(np.asarray(s_reo), np.asarray(s_nat),
                                   rtol=0.05, atol=0.05)
    with DecompServer(buckets=(8,), max_wait_ms=0.5) as srv:
        srv.publish("reo", reo.serve_handle().decomp, reo.serve_handle().dims)
        scores, items = srv.top_k_for_user("reo", 0, k=k)
        _, ref_items = nat.serve_handle().top_k_for_user(0, k)
        np.testing.assert_array_equal(np.asarray(items),
                                      np.asarray(ref_items))


def test_unknown_method_option_rejected_with_field_path():
    """A typo'd method option fails with method.options named (and a
    nearest-name hint), not a raw TypeError from inside the fit."""
    cfg = RunConfig(method=MethodConfig(rank=4, niters=2,
                                        options={"timerz": {}}))
    with pytest.raises(ValueError,
                       match=r"method\.options.*timerz.*did you mean"):
        run(cfg, tensor=lowrank())


def test_streaming_rejects_pinned_plan_policy():
    """A plan policy streaming cannot execute is rejected, not silently
    dropped (parity with the dist executor's inexpressible-plan errors)."""
    cfg = RunConfig(plan=PlanConfig(policy="segment"),
                    method=MethodConfig(name="cp_als_streaming", rank=4,
                                        niters=2))
    with pytest.raises(ConfigError, match=r"plan\.policy"):
        Session.from_config(cfg, tensor=lowrank()).plan()


def test_streaming_rejects_allow_excluding_gather_scatter():
    cfg = RunConfig(plan=PlanConfig(allow=("segment",)),
                    method=MethodConfig(name="cp_als_streaming", rank=4,
                                        niters=2))
    with pytest.raises(ConfigError, match=r"plan\.policy"):
        Session.from_config(cfg, tensor=lowrank()).plan()


def test_batch_method_rejects_chunk_geometry():
    cfg = RunConfig(method=MethodConfig(rank=4, niters=2),
                    exec=ExecConfig(n_chunks=4))
    with pytest.raises(ValueError, match=r"exec\.n_chunks"):
        run(cfg, tensor=lowrank())


def test_cli_option_requires_key_value(capsys):
    from repro.api.cli import main

    rc = main(["fit", "--dataset", "yelp", "--option", "decay", "--dryrun"])
    assert rc == 2
    assert "expected KEY=VALUE" in capsys.readouterr().err


def test_session_rejects_tensor_plus_source():
    with pytest.raises(ValueError, match=r"data\.source"):
        Session.from_config(RunConfig(data=DataConfig(dataset="yelp")),
                            tensor=lowrank())


def test_session_without_data_errors_clearly():
    with pytest.raises(ValueError, match="names no data"):
        Session.from_config(RunConfig()).ingest()


# ---------------------------------------------------------------------------
# checkpoint / resume through the Session
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method,rank", [
    ("cp_als", 4), ("cp_nn_hals", 4), ("tucker_hooi", (3, 3, 3)),
    ("cp_als_streaming", 4),
])
def test_session_kill_and_resume_bit_exact(tmp_path, method, rank):
    """A fit killed mid-run (simulated: niters cut short) resumes from the
    checkpoint dir in a FRESH session — rebuilt from serialized config, as
    a restarted process would — and lands bit-exactly on the uninterrupted
    run's factors."""
    t = lowrank()
    nc = 3 if method == "cp_als_streaming" else None
    full = run(RunConfig(method=MethodConfig(name=method, rank=rank,
                                             niters=8),
                         exec=ExecConfig(n_chunks=nc)), tensor=t)

    ck = str(tmp_path / "ck")
    killed = RunConfig(
        method=MethodConfig(name=method, rank=rank, niters=3),
        exec=ExecConfig(checkpoint_dir=ck, n_chunks=nc))
    run(killed, tensor=t)

    resumed_cfg = RunConfig.from_json(
        killed.replace(method=MethodConfig(
            name=method, rank=rank, niters=8)).to_json())
    sess = Session.from_config(resumed_cfg, tensor=t)
    state = sess.resume_state()
    assert state is not None and int(state.iteration) == 3
    resumed = sess.fit()
    for a, b in zip(full.factors, resumed.factors):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(full.fit),
                                  np.asarray(resumed.fit))


def test_resume_rejects_rank_and_seed_mismatch(tmp_path):
    """Resuming a checkpoint written at a different rank (or seed) must
    fail loudly, not hand back a silently-wrong decomposition."""
    t = lowrank()
    ck = str(tmp_path / "ck")
    run(RunConfig(method=MethodConfig(rank=4, niters=2),
                  exec=ExecConfig(checkpoint_dir=ck)), tensor=t)
    with pytest.raises(ValueError, match=r"method\.rank.*4.*8"):
        run(RunConfig(method=MethodConfig(rank=8, niters=4),
                      exec=ExecConfig(checkpoint_dir=ck)), tensor=t)
    with pytest.raises(ValueError, match=r"method\.seed"):
        run(RunConfig(method=MethodConfig(rank=4, niters=4, seed=9),
                      exec=ExecConfig(checkpoint_dir=ck)), tensor=t)


def test_streaming_run_rejects_pinned_policy_programmatically():
    """The pinned-policy gate fires on run(cfg) too, not only when the CLI
    happens to call plan_report()."""
    cfg = RunConfig(plan=PlanConfig(policy="segment"),
                    method=MethodConfig(name="cp_als_streaming", rank=4,
                                        niters=2),
                    exec=ExecConfig(executor="streaming"))
    with pytest.raises(ConfigError, match=r"plan\.policy"):
        run(cfg, tensor=lowrank())


def test_resume_rejects_method_mismatch(tmp_path):
    t = lowrank()
    ck = str(tmp_path / "ck")
    run(RunConfig(method=MethodConfig(rank=4, niters=2),
                  exec=ExecConfig(checkpoint_dir=ck)), tensor=t)
    other = RunConfig(method=MethodConfig(name="cp_nn_hals", rank=4,
                                          niters=4),
                      exec=ExecConfig(checkpoint_dir=ck))
    with pytest.raises(ValueError, match="written by method"):
        Session.from_config(other, tensor=t).resume_state()


def test_dist_executor_rejects_checkpointing():
    cfg = RunConfig(method=MethodConfig(rank=4, niters=2),
                    exec=ExecConfig(executor="dist",
                                    checkpoint_dir="/tmp/nope"))
    with pytest.raises(ValueError, match="checkpoint"):
        run(cfg, tensor=lowrank())


# ---------------------------------------------------------------------------
# CLI: arg -> RunConfig translation, capability matrices, suggestions
# ---------------------------------------------------------------------------

def test_cli_list_matrices_come_from_registries(capsys):
    from repro.api.cli import main

    assert main(["--list-methods"]) == 0
    out = capsys.readouterr().out
    for name in ("cp_als", "cp_nn_hals", "tucker_hooi", "cp_als_streaming",
                 "local", "dist", "streaming"):
        assert name in out
    assert main(["--list-impls"]) == 0
    out = capsys.readouterr().out
    for name in ("gather_scatter", "segment", "pallas", "rowloop", "mttkrp",
                 "ttmc"):
        assert name in out


def test_cli_args_build_runconfig():
    import argparse

    from repro.api.cli import config_from_args, main

    ns = argparse.Namespace(
        config=None, source=None, dataset="yelp", scale=0.001, data_seed=None,
        reorder="degree_sort", compact=None, cache=None, impl="segment",
        calibrate=None, method="tucker_hooi", rank=[3, 3, 3], iters=4,
        tol=None, seed=9, option=["verbose=false"], executor=None,
        checkpoint_dir=None, checkpoint_every=None, monitor=None,
        n_chunks=None, chunk_nnz=None)
    cfg = config_from_args(ns)
    assert cfg.data.dataset == "yelp" and cfg.data.reorder == "degree_sort"
    assert cfg.plan.policy == "segment"
    assert cfg.method.name == "tucker_hooi" and cfg.method.rank == (3, 3, 3)
    assert cfg.method.options == {"verbose": False}
    assert cfg.method.seed == 9


def test_cli_config_file_plus_override(tmp_path):
    from repro.api.cli import main

    cfg = RunConfig(data=DataConfig(dataset="yelp", scale=0.0005),
                    method=MethodConfig(rank=8, niters=2))
    f = tmp_path / "run.json"
    f.write_text(cfg.to_json())
    # --dryrun plans without fitting; --rank overrides the file
    assert main(["fit", "--config", str(f), "--rank", "4", "--dryrun"]) == 0


def test_cli_config_file_bad_section_is_a_config_error(tmp_path, capsys):
    """A config file whose section is not a mapping must exit 2 with the
    formatted error even when CLI flags overlay that section."""
    from repro.api.cli import main

    f = tmp_path / "bad.json"
    f.write_text('{"data": []}')
    rc = main(["fit", "--config", str(f), "--dataset", "yelp", "--dryrun"])
    assert rc == 2
    assert "wants a mapping" in capsys.readouterr().err


def test_cli_unknown_method_suggests_nearest(capsys):
    from repro.api.cli import main

    rc = main(["fit", "--dataset", "yelp", "--method", "cp_alss", "--dryrun"])
    assert rc == 2
    err = capsys.readouterr().err
    assert "did you mean 'cp_als'" in err


def test_cli_smoke_fit_subprocess():
    """`python -m repro fit --dryrun` end to end in a real interpreter (the
    CI smoke job)."""
    import os

    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    r = subprocess.run(
        [sys.executable, "-m", "repro", "fit", "--dataset", "yelp",
         "--scale", "0.0005", "--rank", "8", "--iters", "2", "--dryrun"],
        capture_output=True, text=True, timeout=300, env=env)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "plan only, skipping execution" in r.stdout


# ---------------------------------------------------------------------------
# launchers ride the Session (no second plumbing)
# ---------------------------------------------------------------------------

def test_serve_cpd_config_shares_the_surface():
    from repro.launch.serve import cpd_config

    cfg = cpd_config("cpals-yelp", smoke=True, rank=8, niters=2,
                     policy="auto", seed=0, reorder="identity", cache=None,
                     method="cp_als")
    assert isinstance(cfg, RunConfig)
    assert cfg.data.dataset == "yelp" and cfg.data.scale == 0.002
    # unknown methods fail through the registry gate, with the listing
    with pytest.raises(ValueError, match="unknown method"):
        cpd_config("cpals-yelp", smoke=True, rank=8, niters=2, policy="auto",
                   seed=0, reorder="identity", cache=None, method="nope")


def test_legacy_cp_als_warns_deprecation_once():
    import warnings

    from repro.core import cpals as cpals_mod

    t = lowrank()
    cpals_mod._warned_legacy = False
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        cpals_mod.cp_als(t, rank=3, niters=1)
        cpals_mod.cp_als(t, rank=3, niters=1)
    depr = [x for x in w if issubclass(x.category, DeprecationWarning)
            and "repro.api" in str(x.message)]
    assert len(depr) == 1  # once per process, not per call
