"""Shared test helpers."""
import jax
import jax.numpy as jnp

from repro.core import SparseTensor


def exact_lowrank_tensor(dims, true_rank, key):
    """Fully-observed nonneg low-rank tensor in COO form (every cell a
    'non-zero').

    CP-ALS treats absent coordinates as structural zeros, so only a fully
    observed low-rank tensor is itself low-rank — a sparse *sample* of one
    is not (that would be tensor completion, a different SPLATT mode).  The
    ground-truth factors are positive, so the nonnegative methods can reach
    it too, and its multilinear rank is <= true_rank per mode for Tucker.
    """
    ks = jax.random.split(key, len(dims))
    true = [jax.random.uniform(k, (d, true_rank)) + 0.1
            for k, d in zip(ks, dims)]
    grids = jnp.meshgrid(*[jnp.arange(d) for d in dims], indexing="ij")
    inds = jnp.stack([g.reshape(-1) for g in grids], axis=1).astype(jnp.int32)
    prod = jnp.ones((inds.shape[0], true_rank))
    for m, a in enumerate(true):
        prod = prod * a[inds[:, m]]
    vals = jnp.sum(prod, axis=1)
    return SparseTensor(inds=inds, vals=vals, dims=tuple(dims),
                        nnz=inds.shape[0])
