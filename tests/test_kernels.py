"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle,
across shapes / ranks / dtypes / block sizes, plus CP-ALS integration."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import random_sparse, build_csf_tiled, init_factors, cp_als, mttkrp
from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def make_case(dims, nnz, rank, *, skew=0.0, block=128, row_tile=64, dtype=jnp.float32):
    kt, kf = jax.random.split(KEY)
    t = random_sparse(dims, nnz, kt, skew=skew)
    factors = tuple(f.astype(dtype) for f in init_factors(t.dims, rank, kf))
    csfs = [build_csf_tiled(t, m, block=block, row_tile=row_tile)
            for m in range(t.order)]
    return t, csfs, factors


# ---------------------------------------------------------------------------
# MTTKRP kernel sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dims,nnz", [
    ((50, 40, 30), 600),       # small
    ((200, 13, 77), 2000),     # ragged dims
    ((64, 64, 64), 4000),      # dense-ish
    ((500, 11, 9), 900),       # long sparse mode (many empty row tiles)
])
@pytest.mark.parametrize("mode", [0, 1, 2])
def test_mttkrp_kernel_shapes(dims, nnz, mode):
    t, csfs, factors = make_case(dims, nnz, rank=8)
    got = ops.mttkrp(csfs[mode], factors)
    want = ref.mttkrp_ref(csfs[mode], factors)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want[:, :8]),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("rank", [3, 8, 35, 64, 128, 150])
def test_mttkrp_kernel_rank_padding(rank):
    """R=35 is the paper's rank; sweep across / beyond the 128-lane boundary."""
    t, csfs, factors = make_case((40, 30, 20), 800, rank=rank)
    got = ops.mttkrp(csfs[0], factors)
    want = ref.mttkrp_ref(csfs[0], factors)[:, :rank]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("block,row_tile", [(64, 32), (128, 64), (256, 128), (512, 128)])
def test_mttkrp_kernel_blockings(block, row_tile):
    t, csfs, factors = make_case((100, 50, 25), 3000, rank=16,
                                 block=block, row_tile=row_tile)
    got = ops.mttkrp(csfs[0], factors)
    want = ref.mttkrp_ref(csfs[0], factors)[:, :16]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mttkrp_kernel_dtypes(dtype):
    t, csfs, factors = make_case((40, 30, 20), 700, rank=8, dtype=dtype)
    got = ops.mttkrp(csfs[0], factors)
    want = ref.mttkrp_ref(csfs[0], factors)[:, :8]
    tol = 5e-2 if dtype == jnp.bfloat16 else 2e-4
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want, dtype=np.float32),
                               rtol=tol, atol=tol)


def test_mttkrp_kernel_skewed_collisions():
    """YELP-like skew: many collisions inside a block — the one-hot matmul
    must resolve them exactly (this is the mutex-pool analogue test)."""
    t, csfs, factors = make_case((30, 20, 10), 4000, rank=8, skew=2.0)
    for mode in range(3):
        got = ops.mttkrp(csfs[mode], factors)
        want = ref.mttkrp_ref(csfs[mode], factors)[:, :8]
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-4, atol=5e-4)


def test_mttkrp_kernel_order4():
    t, csfs, factors = make_case((20, 15, 12, 10), 900, rank=8)
    got = ops.mttkrp(csfs[2], factors)
    want = ref.mttkrp_ref(csfs[2], factors)[:, :8]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_mttkrp_kernel_vs_segment_impl():
    """Cross-check the kernel against the independent segment implementation
    (different layout, different padding scheme)."""
    from repro.core import build_csf
    t, csfs, factors = make_case((60, 45, 30), 2500, rank=12)
    got = ops.mttkrp(csfs[1], factors)
    want = mttkrp(build_csf(t, 1, block=64), factors, 1, impl="segment")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# syrk kernel sweep
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rows,rank", [(100, 8), (512, 35), (1000, 64),
                                       (4096, 128), (333, 150)])
def test_syrk_kernel_shapes(rows, rank):
    a = jax.random.normal(KEY, (rows, rank), dtype=jnp.float32)
    got = ops.syrk(a, blk=256)
    want = ref.syrk_ref(a)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_syrk_kernel_dtypes(dtype):
    a = (jax.random.normal(KEY, (300, 40)) * 0.1).astype(dtype)
    got = ops.syrk(a)
    want = ref.syrk_ref(a)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, dtype=np.float32),
                               np.asarray(want, dtype=np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# end-to-end: CP-ALS with the pallas MTTKRP matches the segment impl
# ---------------------------------------------------------------------------

def test_cpals_pallas_impl_matches_segment():
    t = random_sparse((30, 25, 20), 1500, KEY)
    d_seg = cp_als(t, rank=5, niters=5, impl="segment", key=KEY)
    d_pal = cp_als(t, rank=5, niters=5, impl="pallas", key=KEY,
                   block=128, row_tile=64)
    np.testing.assert_allclose(float(d_pal.fit), float(d_seg.fit), atol=1e-4)
    for a, b in zip(d_pal.factors, d_seg.factors):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-2, atol=1e-2)
