"""End-to-end reproduction of the paper's experiment, through the one front
door: a declarative :class:`repro.api.RunConfig` per run, driven by
:class:`repro.api.Session` (ingest -> per-mode plan -> method registry).

Stage 1 reproduces Table III: 20 CP-ALS iterations at rank 35 on YELP- and
NELL-2-shaped tensors with the per-routine runtime breakdown, comparing the
implementation-strategy ablation (gather_scatter = atomic regime, segment =
no-lock regime, auto = the per-mode planner).

Stage 2 goes past the paper: the same tensors through every method in the
registry (nonnegative HALS, Tucker/HOOI over the TTMc kernel, streaming
CP-ALS over chunk batches) — fit vs wall time.  Each run is one RunConfig;
the equivalent CLI is printed alongside (``python -m repro fit ...``).

  PYTHONPATH=src python examples/decompose_end_to_end.py [--scale 0.004]
"""
import argparse
import time

import jax

from repro.api import ExecConfig, MethodConfig, PlanConfig, RunConfig, Session
from repro.core import paper_dataset
from repro.ingest import ingest
from repro.methods import available_methods, get_method

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=float, default=0.004,
                help="fraction of the published nnz (CPU-sized default)")
ap.add_argument("--rank", type=int, default=35)
ap.add_argument("--iters", type=int, default=20)
ap.add_argument("--skip-methods", action="store_true",
                help="only the Table III CP-ALS ablation")
args = ap.parse_args()

key = jax.random.PRNGKey(7)
for name in ("yelp", "nell-2"):
    t = paper_dataset(name, key, scale=args.scale)
    # ingest ONCE; every Session below adopts the same handle (sort + stats
    # + CSF builds are shared across all runs on this tensor)
    ing = ingest(t)
    print(f"\n=== {name}: dims={t.dims} nnz={t.nnz:,} (scale {args.scale}) ===")

    # --- Table III ablation: one method (cp_als), three impl policies ---
    for impl in ("gather_scatter", "segment", "auto"):
        # warmup/compile run, then the timed one; ``timers`` is a method
        # option — the per-routine out-param the Table III breakdown reads
        Session.from_config(RunConfig(
            plan=PlanConfig(policy=impl),
            method=MethodConfig(rank=args.rank, niters=2, seed=7,
                                options={"timers": {}})), tensor=ing).fit()
        timers: dict = {}
        cfg = RunConfig(plan=PlanConfig(policy=impl),
                        method=MethodConfig(rank=args.rank, niters=args.iters,
                                            seed=7,
                                            options={"timers": timers}))
        dec = Session.from_config(cfg, tensor=ing).fit()
        total = sum(timers.values())
        print(f"[cp_als/{impl:>14s}] fit={float(dec.fit):.4f} "
              f"total={total:.2f}s | "
              + "  ".join(f"{k}={timers.get(k, 0.0):.3f}s"
                          for k in ("sort", "mttkrp", "ata", "inverse",
                                    "norm", "fit")))

    # --- the registry: every method on the same tensor, one RunConfig each
    if args.skip_methods:
        continue
    for method in available_methods(order=t.order):
        spec = get_method(method)
        # HOOI converges in a few sweeps (and each sweep carries a thin SVD)
        niters = args.iters if spec.family == "cp" else min(args.iters, 5)
        cfg = RunConfig(
            method=MethodConfig(name=method, rank=args.rank, niters=niters,
                                seed=7),
            exec=ExecConfig(n_chunks=4 if spec.supports_streaming else None))
        sess = Session.from_config(cfg, tensor=ing)
        t0 = time.perf_counter()
        dec = sess.fit()
        jax.block_until_ready(dec.fit)
        wall = time.perf_counter() - t0
        print(f"[{method:>22s}] family={spec.family} kernel={spec.kernel} "
              f"fit={float(dec.fit):.4f} wall={wall:.2f}s")
