"""End-to-end reproduction of the paper's experiment, on the current stack:
ingest -> per-mode plan -> decomposition-method registry.

Stage 1 reproduces Table III: 20 CP-ALS iterations at rank 35 on YELP- and
NELL-2-shaped tensors with the per-routine runtime breakdown, comparing the
implementation-strategy ablation (gather_scatter = atomic regime, segment =
no-lock regime, auto = the per-mode planner).

Stage 2 goes past the paper: the same ingested tensors through every method
in the registry (nonnegative HALS, Tucker/HOOI over the TTMc kernel,
streaming CP-ALS over chunk batches) — fit vs wall time.

  PYTHONPATH=src python examples/decompose_end_to_end.py [--scale 0.004]
"""
import argparse
import time

import jax

from repro.core import paper_dataset
from repro.ingest import ingest
from repro.methods import available_methods, fit, get_method

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=float, default=0.004,
                help="fraction of the published nnz (CPU-sized default)")
ap.add_argument("--rank", type=int, default=35)
ap.add_argument("--iters", type=int, default=20)
ap.add_argument("--skip-methods", action="store_true",
                help="only the Table III CP-ALS ablation")
args = ap.parse_args()

key = jax.random.PRNGKey(7)
for name in ("yelp", "nell-2"):
    t = paper_dataset(name, key, scale=args.scale)
    ing = ingest(t)
    print(f"\n=== {name}: dims={t.dims} nnz={t.nnz:,} (scale {args.scale}) ===")

    # --- Table III ablation: one method (cp_als), three impl policies ---
    for impl in ("gather_scatter", "segment", "auto"):
        fit(ing, args.rank, method="cp_als", niters=2, impl=impl, key=key,
            timers={})
        timers: dict = {}
        dec = fit(ing, args.rank, method="cp_als", niters=args.iters,
                  impl=impl, key=key, timers=timers)
        total = sum(timers.values())
        print(f"[cp_als/{impl:>14s}] fit={float(dec.fit):.4f} "
              f"total={total:.2f}s | "
              + "  ".join(f"{k}={timers.get(k, 0.0):.3f}s"
                          for k in ("sort", "mttkrp", "ata", "inverse",
                                    "norm", "fit")))

    # --- the registry: every method on the same ingested tensor ---
    if args.skip_methods:
        continue
    for method in available_methods(order=t.order):
        spec = get_method(method)
        kwargs = {"n_chunks": 4} if spec.supports_streaming else {}
        x = ing.tensor if spec.supports_streaming else ing
        # HOOI converges in a few sweeps (and each sweep carries a thin SVD)
        niters = args.iters if spec.family == "cp" else min(args.iters, 5)
        t0 = time.perf_counter()
        dec = fit(x, args.rank, method=method, niters=niters, key=key,
                  **kwargs)
        jax.block_until_ready(dec.fit)
        wall = time.perf_counter() - t0
        print(f"[{method:>22s}] family={spec.family} kernel={spec.kernel} "
              f"fit={float(dec.fit):.4f} wall={wall:.2f}s")
