"""End-to-end reproduction of the paper's experiment: 20 CP-ALS iterations at
rank 35 on YELP- and NELL-2-shaped tensors with the per-routine runtime
breakdown of Table III, comparing the implementation-strategy ablation
(gather_scatter = atomic regime, segment = no-lock regime).

  PYTHONPATH=src python examples/decompose_end_to_end.py [--scale 0.004]
"""
import argparse

import jax

from repro.core import cp_als, paper_dataset

ap = argparse.ArgumentParser()
ap.add_argument("--scale", type=float, default=0.004,
                help="fraction of the published nnz (CPU-sized default)")
ap.add_argument("--rank", type=int, default=35)
ap.add_argument("--iters", type=int, default=20)
args = ap.parse_args()

key = jax.random.PRNGKey(7)
for name in ("yelp", "nell-2"):
    t = paper_dataset(name, key, scale=args.scale)
    print(f"\n=== {name}: dims={t.dims} nnz={t.nnz:,} (scale {args.scale}) ===")
    for impl in ("gather_scatter", "segment", "auto"):
        cp_als(t, rank=args.rank, niters=2, impl=impl, key=key, timers={})
        timers: dict = {}
        dec = cp_als(t, rank=args.rank, niters=args.iters, impl=impl,
                     key=key, timers=timers)
        total = sum(timers.values())
        print(f"[{impl:>14s}] fit={float(dec.fit):.4f} total={total:.2f}s | "
              + "  ".join(f"{k}={timers.get(k, 0.0):.3f}s"
                          for k in ("sort", "mttkrp", "ata", "inverse",
                                    "norm", "fit")))
