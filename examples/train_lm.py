"""End-to-end training driver: train a ~100M-param llama-style model for a
few hundred steps on the synthetic pipeline, with checkpoint/restart.

  PYTHONPATH=src python examples/train_lm.py                  # full (~100M)
  PYTHONPATH=src python examples/train_lm.py --tiny --steps 30  # CI-sized
"""
import argparse
import dataclasses

from repro import configs
from repro.launch.train import train

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--tiny", action="store_true")
ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
args = ap.parse_args()

if args.tiny:
    # smoke-sized model, quick check that the loop learns
    out = train("llama3.2-3b", smoke=True, steps=args.steps, batch=8,
                seq=64, ckpt_dir=args.ckpt_dir, ckpt_every=50)
else:
    # ~100M params: override the llama3.2 config down to a trainable size
    import repro.configs as C
    from repro.launch import train as T
    from repro.models import Model
    from repro.optim import OPTIMIZERS

    cfg = dataclasses.replace(
        C.get("llama3.2-3b"), name="llama-100m", num_layers=8, d_model=512,
        num_heads=8, num_kv_heads=4, head_dim=64, d_ff=2048, vocab=32_000,
        param_dtype="float32", compute_dtype="float32", remat=False)
    print(f"params ~= {cfg.param_count()/1e6:.0f}M")

    # reuse the launcher internals with the custom cfg
    orig_get = C.get
    C.get = lambda name: cfg if name == "llama-100m" else orig_get(name)
    out = T.train("llama-100m", smoke=False, steps=args.steps, batch=4,
                  seq=256, ckpt_dir=args.ckpt_dir, ckpt_every=100)
    C.get = orig_get

print(f"loss: {out['first_loss']:.3f} -> {out['final_loss']:.3f} "
      f"over {out['steps']} steps ({out['wall_s']:.0f}s)")
assert out["final_loss"] < out["first_loss"], "training did not reduce loss"
