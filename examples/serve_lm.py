"""Batched serving example: prefill a batch of prompts, then decode with the
per-arch cache (KV cache / RWKV state / RG-LRU state).

  PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-3b
  PYTHONPATH=src python examples/serve_lm.py --arch recurrentgemma-9b
"""
import argparse

from repro import configs
from repro.launch.serve import serve

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="llama3.2-3b", choices=configs.ARCH_NAMES)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--prompt-len", type=int, default=32)
ap.add_argument("--gen", type=int, default=16)
args = ap.parse_args()

out = serve(args.arch, smoke=True, batch=args.batch,
            prompt_len=args.prompt_len, gen=args.gen)
print(f"arch={args.arch}  prefill={out['prefill_s']:.2f}s  "
      f"decode={out['decode_s']:.2f}s  ({out['decode_tok_s']:,.0f} tok/s)")
for i in range(min(2, args.batch)):
    print(f"  request {i}: generated {out['tokens'][i][:10].tolist()} ...")
