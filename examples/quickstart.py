"""Quickstart: decompose a small sparse tensor with CP-ALS.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import cp_als, from_factors, init_factors, random_sparse

key = jax.random.PRNGKey(0)

# a synthetic 3rd-order sparse tensor (50k non-zeros, YELP-like skew)
tensor = random_sparse((500, 400, 300), 50_000, key, skew=1.0)
print(f"tensor: dims={tensor.dims} nnz={tensor.nnz} "
      f"density={tensor.density:.2e}")

# rank-16 CP decomposition, 10 ALS iterations (paper Alg. 1)
decomp = cp_als(tensor, rank=16, niters=10, impl="segment", key=key,
                verbose=True)
print(f"final fit: {float(decomp.fit):.4f}")
print(f"factor shapes: {[tuple(a.shape) for a in decomp.factors]}")
print(f"lambda[:4] = {decomp.lmbda[:4]}")

# reconstruct a few entries and compare
approx = decomp.values_at(tensor.inds[:5])
print("first 5 values  :", [round(float(v), 3) for v in tensor.vals[:5]])
print("reconstructions :", [round(float(v), 3) for v in approx])
