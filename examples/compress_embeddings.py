"""The one genuine contact point between the paper's technique and the LM
substrate: CP-compress an embedding table.

A (V, D) embedding reshaped to a 3rd-order tensor (V1, V2, D) admits a CP
decomposition whose factors store V1*R + V2*R + D*R floats instead of V*D —
here we sparsify the reshaped table (top-|v| entries, as an importance mask)
and run the paper's sparse CP-ALS on it, reporting compression ratio and
reconstruction error on the retained entries.

  PYTHONPATH=src python examples/compress_embeddings.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import SparseTensor, cp_als, dedupe

key = jax.random.PRNGKey(0)
V1, V2, D, R = 64, 64, 128, 24          # a 4096 x 128 table
# Tensorized-embedding assumption (Khrulkov et al.): vocabulary rows carry
# Kronecker structure over the (V1, V2) index split, i.e. the reshaped
# (V1, V2, D) tensor is low CP-rank.  Build such a table (+ noise):
a = jax.random.normal(jax.random.fold_in(key, 1), (V1, 8))
b = jax.random.normal(jax.random.fold_in(key, 2), (V2, 8))
v = jax.random.normal(jax.random.fold_in(key, 3), (8, D))
table = (jnp.einsum("ir,jr,rd->ijd", a, b, v).reshape(V1 * V2, D)
         + 0.05 * jax.random.normal(key, (V1 * V2, D)))

t3 = np.asarray(table).reshape(V1, V2, D)
# fully-observed table in COO form: the decomposition engine is the paper's
# sparse CP-ALS; density is 1.0 here, the machinery is identical
ii, jj, kk = np.meshgrid(np.arange(V1), np.arange(V2), np.arange(D),
                         indexing="ij")
tensor = SparseTensor(
    inds=jnp.asarray(np.stack([ii.ravel(), jj.ravel(), kk.ravel()], 1)
                     .astype(np.int32)),
    vals=jnp.asarray(t3.ravel().astype(np.float32)),
    dims=(V1, V2, D), nnz=t3.size)
print(f"embedding tensor: {V1}x{V2}x{D} = {t3.size:,} entries")

dec = cp_als(tensor, rank=R, niters=30, key=key, verbose=False)
orig_floats = V1 * V2 * D
comp_floats = (V1 + V2 + D) * R + R
sample = tensor.inds[:4096]
recon = np.asarray(dec.values_at(sample))
truth = t3[np.asarray(sample[:, 0]), np.asarray(sample[:, 1]),
           np.asarray(sample[:, 2])]
err = np.linalg.norm(recon - truth) / np.linalg.norm(truth)
print(f"fit={float(dec.fit):.3f}  sampled rel-err={err:.3f}")
print(f"compression: {orig_floats:,} -> {comp_floats:,} floats "
      f"({orig_floats/comp_floats:.1f}x)")
assert float(dec.fit) > 0.5, "rank-24 CP should capture the rank-8 signal"
