"""Planner ablation: `auto` vs fixed-impl CP-ALS iteration time.

The acceptance bar for the per-mode planner (repro.plan): on the paper's two
regime-defining tensor shapes — NELL-2-like (uniform, collision-light) and
YELP-like (skewed, contention-heavy) — the `auto` policy's fused ALS
iteration must land within a few percent of the best fixed impl, because it
*is* the per-mode argmin of the registered cost models.

Timed quantity: one fused jitted ALS iteration (MTTKRP + grams + solve +
normalize + fit) over a prebuilt workspace; the sort/build stage is excluded
(it is timed by bench_sort_build.py and amortized over all iterations).

`python -m benchmarks.run` aggregates this into BENCH_plan.json.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import init_factors, gram
from repro.core.cpals import _iteration

from .common import ingested_paper_dataset, timeit

POLICIES = ("gather_scatter", "segment", "auto")
DATASETS = ("yelp", "nell-2")


def run(scale: float = 0.004, rank: int = 16) -> list[dict]:
    key = jax.random.PRNGKey(0)
    rows = []
    for name in DATASETS:
        # ingest-cache-backed: a warm benchmark run skips sort + stats
        ing = ingested_paper_dataset(name, scale=scale)
        t = ing.tensor
        factors0 = init_factors(t.dims, rank, key)
        grams0 = tuple(gram(a) for a in factors0)
        norm_x_sq = jnp.sum(t.vals.astype(jnp.float32) ** 2)
        for policy in POLICIES:
            plan = ing.plan(policy, rank=rank,
                            calibrate=policy == "auto")
            ws = ing.workspace(plan)
            fn = partial(_iteration, ws, norm_kind="2", impls=plan.impls)
            sec = timeit(lambda f, g: fn(f, g, norm_x_sq), factors0, grams0)
            rows.append({
                "bench": "plan", "dataset": name, "policy": policy,
                "plan": plan.summary(), "nnz": t.nnz, "rank": rank,
                "iteration_ms": round(sec * 1e3, 3),
            })
    return rows


def summarize(rows: list[dict]) -> dict:
    """BENCH_plan.json payload: per-dataset policy times + auto/best ratio."""
    out: dict = {"bench": "plan", "datasets": {}}
    for name in {r["dataset"] for r in rows}:
        sub = {r["policy"]: r["iteration_ms"] for r in rows
               if r["dataset"] == name}
        fixed = {k: v for k, v in sub.items() if k != "auto"}
        best_fixed = min(fixed.values())
        out["datasets"][name] = {
            "iteration_ms": sub,
            "plan": next(r["plan"] for r in rows
                         if r["dataset"] == name and r["policy"] == "auto"),
            "best_fixed_ms": best_fixed,
            "auto_over_best_fixed": round(sub["auto"] / best_fixed, 4),
        }
    return out


if __name__ == "__main__":
    from .common import emit

    emit(run())
