"""Paper Table III + Figs 5-8: per-routine CP-ALS runtime breakdown.

Runs 20 ALS iterations at rank 35 (the paper's setting) on YELP- and
NELL-2-shaped synthetic tensors (CPU-scaled) and reports seconds per routine
(sort / mttkrp / ata / inverse / norm / fit) across MTTKRP impls — including
the ALTO-style ``linearized`` workspace — and, per impl, a ``+fused`` cell
where the whole post-MTTKRP chain runs as ONE jitted ``fused_mode_epilogue``
call (timed under the single ``epilogue`` key).

Every cell also reports an ``epilogue_s`` subtotal — ata+inverse+norm+fit
for the routine-by-routine cells, the fused call's own time for ``+fused``
cells — which is the lower-is-better metric the perf ratchet
(``benchmarks.history``) guards, locking in the fusion win.

  PYTHONPATH=src python -m benchmarks.bench_cpals_routines \
      [--quick] [--json BENCH_cpals.json]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax

from repro.core.cpals import EPILOGUE_ROUTINES, ROUTINES as ALL_ROUTINES
from repro.methods import cp_als

from .common import emit, paper_dataset_cached

ROUTINES = ALL_ROUTINES  # ("sort", "mttkrp", "ata", "inverse", "norm", "fit")
IMPLS = ("gather_scatter", "segment", "linearized")


def _epilogue_s(timers: dict, fused: bool) -> float:
    if fused:
        return timers.get("epilogue", 0.0)
    return sum(timers.get(k, 0.0) for k in EPILOGUE_ROUTINES)


def run(scale: float = 0.002, rank: int = 35, niters: int = 20):
    key = jax.random.PRNGKey(3)
    rows = []
    for name in ("yelp", "nell-2"):
        t = paper_dataset_cached(name, scale=scale, seed=3)
        for impl in IMPLS:
            for fused in (False, True):
                # warm every jit cache so per-routine timers measure
                # execution, not first-call compilation
                cp_als(t, rank=rank, niters=2, impl=impl, key=key, timers={},
                       fused_epilogue=fused)
                timers: dict = {}
                dec = cp_als(t, rank=rank, niters=niters, impl=impl, key=key,
                             timers=timers, fused_epilogue=fused)
                row = {"bench": "cpals_routines", "dataset": name,
                       "impl": impl + ("+fused" if fused else ""),
                       "nnz": t.nnz, "fit": round(float(dec.fit), 4)}
                for k in ROUTINES + ("epilogue",):
                    row[f"{k}_s"] = round(timers.get(k, 0.0), 4)
                row["epilogue_total_s"] = round(_epilogue_s(timers, fused), 4)
                row["total_s"] = round(
                    sum(timers.get(k, 0.0)
                        for k in ROUTINES + ("epilogue",)), 4)
                rows.append(row)
    return rows


def summarize(rows: list[dict]) -> dict:
    """JSON summary for the BENCH_cpals.json trajectory artifact: the
    per-routine timings and final fit the paper's Table III measures, plus
    the ``epilogue_s`` subtotal the ratchet guards."""
    cells = {}
    for r in rows:
        cells[f"{r['dataset']}/{r['impl']}"] = {
            "nnz": r["nnz"], "fit": r["fit"],
            "routines_s": {k: r[f"{k}_s"]
                           for k in ROUTINES + ("epilogue",)},
            "epilogue_s": r["epilogue_total_s"],
            "total_s": r["total_s"],
        }
    return {"bench": "cpals_routines", "cells": cells}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--json", type=Path, default=None,
                    help="also write the summarize() JSON here")
    args = ap.parse_args()
    scale = args.scale if args.scale is not None else (
        0.001 if args.quick else 0.002)
    rows = run(scale=scale, niters=5 if args.quick else 20)
    emit(rows)
    if args.json is not None:
        args.json.write_text(json.dumps(summarize(rows), indent=1))
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
