"""Paper Table III + Figs 5-8: per-routine CP-ALS runtime breakdown.

Runs 20 ALS iterations at rank 35 (the paper's setting) on YELP- and
NELL-2-shaped synthetic tensors (CPU-scaled) and reports seconds per routine
(sort / mttkrp / ata / inverse / norm / fit), for the naive and optimized
MTTKRP paths.
"""
from __future__ import annotations

import jax

from repro.core import cp_als

from .common import emit, paper_dataset_cached


def run(scale: float = 0.002, rank: int = 35, niters: int = 20):
    key = jax.random.PRNGKey(3)
    rows = []
    for name in ("yelp", "nell-2"):
        t = paper_dataset_cached(name, scale=scale, seed=3)
        for impl in ("gather_scatter", "segment"):
            # warm every jit cache so per-routine timers measure execution,
            # not first-call compilation
            cp_als(t, rank=rank, niters=2, impl=impl, key=key, timers={})
            timers: dict = {}
            dec = cp_als(t, rank=rank, niters=niters, impl=impl, key=key,
                         timers=timers)
            row = {"bench": "cpals_routines", "dataset": name, "impl": impl,
                   "nnz": t.nnz, "fit": round(float(dec.fit), 4)}
            for k in ("sort", "mttkrp", "ata", "inverse", "norm", "fit"):
                row[f"{k}_s"] = round(timers.get(k, 0.0), 4)
            rows.append(row)
    return rows


if __name__ == "__main__":
    emit(run())
