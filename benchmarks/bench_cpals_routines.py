"""Paper Table III + Figs 5-8: per-routine CP-ALS runtime breakdown.

Runs 20 ALS iterations at rank 35 (the paper's setting) on YELP- and
NELL-2-shaped synthetic tensors (CPU-scaled) and reports seconds per routine
(sort / mttkrp / ata / inverse / norm / fit), for the naive and optimized
MTTKRP paths.

  PYTHONPATH=src python -m benchmarks.bench_cpals_routines \
      [--quick] [--json BENCH_cpals.json]
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax

from repro.methods import cp_als

from .common import emit, paper_dataset_cached

ROUTINES = ("sort", "mttkrp", "ata", "inverse", "norm", "fit")


def run(scale: float = 0.002, rank: int = 35, niters: int = 20):
    key = jax.random.PRNGKey(3)
    rows = []
    for name in ("yelp", "nell-2"):
        t = paper_dataset_cached(name, scale=scale, seed=3)
        for impl in ("gather_scatter", "segment"):
            # warm every jit cache so per-routine timers measure execution,
            # not first-call compilation
            cp_als(t, rank=rank, niters=2, impl=impl, key=key, timers={})
            timers: dict = {}
            dec = cp_als(t, rank=rank, niters=niters, impl=impl, key=key,
                         timers=timers)
            row = {"bench": "cpals_routines", "dataset": name, "impl": impl,
                   "nnz": t.nnz, "fit": round(float(dec.fit), 4)}
            for k in ROUTINES:
                row[f"{k}_s"] = round(timers.get(k, 0.0), 4)
            rows.append(row)
    return rows


def summarize(rows: list[dict]) -> dict:
    """JSON summary for the BENCH_cpals.json trajectory artifact: the
    per-routine timings and final fit the paper's Table III measures."""
    cells = {}
    for r in rows:
        cells[f"{r['dataset']}/{r['impl']}"] = {
            "nnz": r["nnz"], "fit": r["fit"],
            "routines_s": {k: r[f"{k}_s"] for k in ROUTINES},
            "total_s": round(sum(r[f"{k}_s"] for k in ROUTINES), 4),
        }
    return {"bench": "cpals_routines", "cells": cells}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--scale", type=float, default=None)
    ap.add_argument("--json", type=Path, default=None,
                    help="also write the summarize() JSON here")
    args = ap.parse_args()
    scale = args.scale if args.scale is not None else (
        0.001 if args.quick else 0.002)
    rows = run(scale=scale, niters=5 if args.quick else 20)
    emit(rows)
    if args.json is not None:
        args.json.write_text(json.dumps(summarize(rows), indent=1))
        print(f"# wrote {args.json}")


if __name__ == "__main__":
    main()
