"""Benchmark trajectory store + the perf-ratchet comparison logic.

``benchmarks/run.py`` used to emit snapshot ``BENCH_*.json`` files that each
run overwrote — no way to tell whether this week's MTTKRP got slower than
last week's.  This module turns the snapshots into a *trajectory*: every
section appends one timestamped, git-sha-stamped record to
``BENCH_history/<section>.jsonl`` (append-only JSONL, one JSON object per
line), and :func:`ratchet_section` compares the latest record against the
last *anchor* — failing when any tracked lower-is-better time metric
regressed by more than ``tolerance`` (default 10%).

Record shape (one line)::

    {"section": "cpals", "ts": "2026-08-08T12:00:00+00:00",
     "git_sha": "b8b142e", "anchor": false, "summary": {...}}

*Anchors* are ordinary records re-appended with ``"anchor": true`` (see
:func:`promote_anchor` / ``ratchet.py --anchor``): the baseline for a
section is its **last anchor**, or the first record when no anchor exists
yet, so promoting an anchor is a plain append — history is never rewritten.

The :data:`SECTIONS` table is the single registry shared by ``run.py``
(which sections emit JSON summaries, where the legacy snapshot lands) and
``ratchet.py`` (which metrics inside each summary are ratcheted).  Metric
extractors return **lower-is-better** values only — fit/qps/speedup never
belong here, a "regression" in those is an improvement.
"""
from __future__ import annotations

import dataclasses
import json
import math
import subprocess
from datetime import datetime, timezone
from pathlib import Path
from typing import Callable, Optional

REPO_ROOT = Path(__file__).resolve().parents[1]
HISTORY_DIR = REPO_ROOT / "BENCH_history"
DEFAULT_TOLERANCE = 0.10


# ---------------------------------------------------------------------------
# record I/O
# ---------------------------------------------------------------------------


def git_sha(root: Path = REPO_ROOT) -> str:
    """Short sha of HEAD, or "unknown" outside a git checkout."""
    try:
        out = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             cwd=root, capture_output=True, text=True,
                             timeout=10)
    except (OSError, subprocess.TimeoutExpired):
        return "unknown"
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else "unknown"


def history_path(section: str, history_dir: Path = HISTORY_DIR) -> Path:
    return Path(history_dir) / f"{section}.jsonl"


def append_record(section: str, summary: dict, *,
                  history_dir: Path = HISTORY_DIR,
                  ts: Optional[str] = None, sha: Optional[str] = None,
                  anchor: bool = False) -> dict:
    """Append one record to the section's trajectory; returns the record."""
    rec = {
        "section": section,
        "ts": ts if ts is not None
        else datetime.now(timezone.utc).isoformat(timespec="seconds"),
        "git_sha": sha if sha is not None else git_sha(),
        "anchor": bool(anchor),
        "summary": summary,
    }
    path = history_path(section, history_dir)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "a") as f:
        f.write(json.dumps(rec, sort_keys=True) + "\n")
    return rec


def load_history(section: str,
                 history_dir: Path = HISTORY_DIR) -> list[dict]:
    """All records of a section, oldest first.  Corrupt lines (torn
    concurrent appends, hand edits) are skipped, never fatal."""
    path = history_path(section, history_dir)
    if not path.exists():
        return []
    records = []
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            continue
        if isinstance(rec, dict) and isinstance(rec.get("summary"), dict):
            records.append(rec)
    return records


def baseline_record(records: list[dict]) -> Optional[dict]:
    """The comparison baseline: the LAST anchor, else the first record."""
    for rec in reversed(records):
        if rec.get("anchor"):
            return rec
    return records[0] if records else None


def promote_anchor(section: str, *,
                   history_dir: Path = HISTORY_DIR) -> Optional[dict]:
    """Re-append the latest record as the section's new anchor (a plain
    append — the trajectory is never rewritten).  None when no history."""
    records = load_history(section, history_dir)
    if not records:
        return None
    latest = records[-1]
    return append_record(section, latest["summary"], history_dir=history_dir,
                         ts=latest.get("ts"), sha=latest.get("git_sha"),
                         anchor=True)


# ---------------------------------------------------------------------------
# metric extraction — lower-is-better time metrics ONLY
# ---------------------------------------------------------------------------


def _metrics_plan(s: dict) -> dict:
    out = {}
    for ds, d in s.get("datasets", {}).items():
        out[f"{ds}.auto_iteration_ms"] = d.get("iteration_ms", {}).get("auto")
        out[f"{ds}.best_fixed_ms"] = d.get("best_fixed_ms")
    return out


def _metrics_ingest(s: dict) -> dict:
    out = {}
    for k in ("cold_ms", "warm_ms"):
        out[f"cache.{k}"] = s.get("cache", {}).get(k)
    for mode, d in s.get("mttkrp", {}).items():
        out[f"{mode}.natural_ms"] = d.get("natural_ms")
        out[f"{mode}.degree_sort_ms"] = d.get("degree_sort_ms")
    return out


def _metrics_cpals(s: dict) -> dict:
    out = {}
    for cell, d in s.get("cells", {}).items():
        out[f"{cell}.total_s"] = d.get("total_s")
        out[f"{cell}.mttkrp_s"] = d.get("routines_s", {}).get("mttkrp")
        # the post-MTTKRP chain subtotal (ata+inverse+norm+fit, or the fused
        # epilogue call's own time) — guards the fused-epilogue win
        out[f"{cell}.epilogue_s"] = d.get("epilogue_s")
    return out


def _metrics_methods(s: dict) -> dict:
    out = {}
    for m, d in s.get("methods", {}).items():
        for ds, dd in d.get("datasets", {}).items():
            out[f"{m}.{ds}.iter_ms"] = dd.get("iter_ms")
    return out


def _metrics_api(s: dict) -> dict:
    return {"direct_s": s.get("direct_s"), "session_s": s.get("session_s")}


def _metrics_obs(s: dict) -> dict:
    # absolute traced/untraced fit times: catches both a tracer slowdown
    # and a fit slowdown the overhead ratio would hide (both sides moving
    # together).  The overhead *gates* live in bench_obs itself.
    # exposed_s (enabled + live exposition endpoint) is absent from
    # pre-phase-2 records; compare_metrics skips non-shared keys, so old
    # anchors stay comparable.
    return {"untraced_s": s.get("untraced_s"),
            "disabled_s": s.get("disabled_s"),
            "enabled_s": s.get("enabled_s"),
            "exposed_s": s.get("exposed_s")}


def _metrics_serve(s: dict) -> dict:
    # single-caller ServeHandle metrics plus (PR 10) the concurrent
    # DecompServer section's per-tenant tail latencies — all
    # lower-is-better.  qps/qps_ratio/batch_fill are higher-is-better and
    # deliberately absent; older anchors lack the per-tenant keys and
    # compare_metrics skips non-shared metrics, so history stays green.
    out = {"serve_s": s.get("serve_s"),
           "latency_ms_per_batch": s.get("latency_ms_per_batch"),
           "concurrent_s": s.get("concurrent_s")}
    for k, v in s.items():
        if k.endswith("_p50_ms") or k.endswith("_p99_ms"):
            out[k] = v
    return out


@dataclasses.dataclass(frozen=True)
class Section:
    """One ratcheted benchmark section: which snapshot file ``run.py``
    writes (the legacy ``--<name>-json`` flag keeps working) and which
    summary fields the ratchet compares."""

    name: str
    metrics: Callable[[dict], dict]

    @property
    def legacy_json(self) -> str:
        return f"BENCH_{self.name}.json"


SECTIONS: dict[str, Section] = {s.name: s for s in (
    Section("plan", _metrics_plan),
    Section("ingest", _metrics_ingest),
    Section("cpals", _metrics_cpals),
    Section("methods", _metrics_methods),
    Section("api", _metrics_api),
    Section("serve", _metrics_serve),
    Section("obs", _metrics_obs),
)}


def extract_metrics(section: str, summary: dict) -> dict:
    """The section's finite, positive, lower-is-better metrics.  NaN/inf,
    non-numeric and non-positive values are dropped here so every consumer
    (ratchet, tests, reports) sees only comparable numbers."""
    raw = SECTIONS[section].metrics(summary)
    return {k: float(v) for k, v in raw.items()
            if isinstance(v, (int, float)) and not isinstance(v, bool)
            and math.isfinite(v) and v > 0}


# ---------------------------------------------------------------------------
# comparison
# ---------------------------------------------------------------------------


def compare_metrics(base: dict, new: dict, *,
                    tolerance: float = DEFAULT_TOLERANCE) -> list[dict]:
    """Regressions of ``new`` vs ``base``: every shared metric whose new
    value exceeds base * (1 + tolerance).  Metrics present on only one side
    (benchmark grew/shrank a dataset) are not comparable and are skipped.
    Returns a deterministically ordered list of
    ``{"metric", "base", "new", "ratio"}`` dicts, worst first."""
    regressions = []
    for k in sorted(set(base) & set(new)):
        b, n = float(base[k]), float(new[k])
        if not (math.isfinite(b) and math.isfinite(n) and b > 0 and n > 0):
            continue
        if n > b * (1.0 + tolerance):
            regressions.append(
                {"metric": k, "base": b, "new": n, "ratio": n / b})
    regressions.sort(key=lambda r: (-r["ratio"], r["metric"]))
    return regressions


def ratchet_section(section: str, *, history_dir: Path = HISTORY_DIR,
                    tolerance: float = DEFAULT_TOLERANCE) -> dict:
    """Ratchet verdict for one section.

    Returns ``{"section", "status", "regressions", "base", "latest"}``
    where status is one of:

    * ``ok``        — latest within tolerance of the baseline (or latest IS
                      the baseline: a fresh anchor trivially passes);
    * ``regressed`` — at least one tracked metric slowed > tolerance;
    * ``missing``   — no history file / no parseable records;
    * ``no-metrics``— records exist but neither side yields a comparable
                      metric (e.g. all-NaN summaries) — reported, not fatal.
    """
    records = load_history(section, history_dir)
    if not records:
        return {"section": section, "status": "missing",
                "regressions": [], "base": None, "latest": None}
    base_rec = baseline_record(records)
    latest = records[-1]
    base_m = extract_metrics(section, base_rec["summary"])
    new_m = extract_metrics(section, latest["summary"])
    meta = {"section": section,
            "base": {"ts": base_rec.get("ts"),
                     "git_sha": base_rec.get("git_sha"),
                     "anchor": bool(base_rec.get("anchor"))},
            "latest": {"ts": latest.get("ts"),
                       "git_sha": latest.get("git_sha")}}
    if not (set(base_m) & set(new_m)):
        return {**meta, "status": "no-metrics", "regressions": []}
    regressions = compare_metrics(base_m, new_m, tolerance=tolerance)
    return {**meta,
            "status": "regressed" if regressions else "ok",
            "regressions": regressions}
