"""Benchmark suite (``python -m benchmarks.run``) — one section per paper
table/figure, plus the trajectory store and perf ratchet
(``benchmarks.history`` / ``python -m benchmarks.ratchet``)."""
