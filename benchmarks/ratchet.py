"""Perf ratchet over the benchmark trajectory.

  PYTHONPATH=src python -m benchmarks.ratchet                 # check all
  PYTHONPATH=src python -m benchmarks.ratchet --section cpals # check one
  PYTHONPATH=src python -m benchmarks.ratchet --anchor        # promote

Compares each section's **latest** ``BENCH_history/<section>.jsonl`` record
against its **baseline** (the last anchor, else the first record) and exits
nonzero when any tracked lower-is-better metric — MTTKRP time, per-iteration
time, serve latency — regressed by more than ``--tolerance`` (default 10%).

``--anchor`` promotes each section's latest record to the new anchor (an
append, never a rewrite) — run it after a deliberate perf change lands so
the ratchet measures drift from the new accepted floor, not from history.

Sections with no history yet report ``missing`` and do not fail the run
(a fresh checkout has nothing to regress against); ``--strict`` upgrades
``missing`` to a failure for CI jobs that must have produced history.

``--attribute`` joins each failed section's baseline and head records
with their per-routine breakdowns (``benchmarks/attribute.py``) and
prints which routine — sort / mttkrp / epilogue / serve query — accounts
for the regression.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Optional, Sequence

from .history import (DEFAULT_TOLERANCE, HISTORY_DIR, SECTIONS,
                      promote_anchor, ratchet_section)


def _print_result(res: dict, *, tolerance: float) -> None:
    status = res["status"]
    head = f"[{status:>9}] {res['section']}"
    if res.get("base") and res.get("latest"):
        head += (f"  base={res['base']['git_sha']}"
                 f"{' (anchor)' if res['base']['anchor'] else ''}"
                 f" -> latest={res['latest']['git_sha']}")
    print(head)
    for r in res["regressions"]:
        print(f"    {r['metric']}: {r['base']:.6g} -> {r['new']:.6g} "
              f"({(r['ratio'] - 1) * 100:+.1f}% > +{tolerance * 100:.0f}%)")


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        description="Fail when the latest benchmark record regressed >10% "
                    "against the last anchor (benchmarks/history.py).")
    ap.add_argument("--history", type=Path, default=HISTORY_DIR,
                    help="trajectory directory (BENCH_history)")
    ap.add_argument("--section", action="append", default=None,
                    choices=sorted(SECTIONS),
                    help="check only these sections (repeatable; "
                         "default: all)")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="allowed fractional slowdown (default 0.10)")
    ap.add_argument("--anchor", action="store_true",
                    help="promote each section's latest record to the new "
                         "anchor instead of checking")
    ap.add_argument("--strict", action="store_true",
                    help="missing history is a failure, not a skip")
    ap.add_argument("--attribute", action="store_true",
                    help="on failure, join base/head per-routine "
                         "breakdowns and name the regressed routine "
                         "(benchmarks/attribute.py)")
    ap.add_argument("--json", type=Path, default=None,
                    help="also write the verdicts as JSON here")
    args = ap.parse_args(argv)
    names = args.section or sorted(SECTIONS)

    if args.anchor:
        promoted = 0
        for name in names:
            rec = promote_anchor(name, history_dir=args.history)
            if rec is None:
                print(f"[  missing] {name}: no history to anchor")
            else:
                print(f"[ anchored] {name} @ {rec['git_sha']} ({rec['ts']})")
                promoted += 1
        return 0 if promoted else 1

    results = [ratchet_section(name, history_dir=args.history,
                               tolerance=args.tolerance) for name in names]
    for res in results:
        _print_result(res, tolerance=args.tolerance)
        if args.attribute and res["status"] == "regressed":
            from .attribute import attribute_section, format_attribution

            att = attribute_section(res["section"],
                                    history_dir=args.history,
                                    tolerance=args.tolerance)
            if att is not None:
                res["attribution"] = att
                print(format_attribution(att))
    if args.json is not None:
        args.json.write_text(json.dumps(results, indent=1, sort_keys=True))
        print(f"# wrote {args.json}")

    failed = [r for r in results if r["status"] == "regressed"
              or (args.strict and r["status"] == "missing")]
    if failed:
        print(f"# RATCHET FAILED: {', '.join(r['section'] for r in failed)}")
        return 1
    print(f"# ratchet ok: {len(results)} section(s) within "
          f"+{args.tolerance * 100:.0f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
